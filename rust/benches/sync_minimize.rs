//! E2 — Figure 1 / §2.1 ablation: minimize synchronization.
//!
//! Four engine configurations over the same workload:
//!   opt        — token-ID broadcast + local-top-k reduce (the paper)
//!   ids_only   — token-ID broadcast, full-logit allgather
//!   topk_only  — embedding-value broadcast, local-top-k reduce
//!   naive      — embedding-value broadcast + full-logit allgather
//!
//! Reported per decode step: wall latency, bytes on the (virtual) wire,
//! and the simulated cross-socket communication time.  The paper's
//! qualitative claim: `opt` moves orders of magnitude fewer bytes at the
//! round boundaries and scales better with world size.
//!
//! Run: `cargo bench --bench sync_minimize [-- --quick] [--json FILE]`

use xeonserve::benchkit::{self, CaseResult, JsonReport};
use xeonserve::config::{EngineConfig, OptFlags, Variant};
use xeonserve::engine::Engine;

fn run_case(name: &str, model: &str, world: usize, opt: OptFlags,
            steps: usize) -> anyhow::Result<CaseResult> {
    let cfg = EngineConfig {
        model: model.into(),
        variant: Variant::Parallel,
        world,
        batch: 1,
        opt,
        ..Default::default()
    };
    let mut engine = Engine::new(cfg)?;
    engine.enqueue(vec![1, 2, 3, 4, 5, 6], steps);
    let before = engine.comm_stats();
    engine.run_to_completion()?;
    let delta = engine.comm_stats().since(&before);

    let m = &mut engine.metrics;
    let n = m.decode_wall.count().max(1) as u64;
    let sim_ms = m.decode_sim.mean_us() / 1e3;
    Ok(CaseResult::from_stats(name, &mut m.decode_wall)
        .with("wire_B_per_tok", delta.wire_bytes / n)
        .with("bcast", if opt.broadcast_ids { "ids" } else { "embed" })
        .with("tail", if opt.local_topk { "topk" } else { "allgather" })
        .with("sim_ms_tok", format!("{sim_ms:.3}")))
}

fn main() -> anyhow::Result<()> {
    let steps = benchkit::iters(16);
    let mut rep = JsonReport::new("sync_minimize");
    for (model, world) in [("tiny", 4), ("small", 4)] {
        let cases = [
            ("opt", OptFlags { broadcast_ids: true, local_topk: true,
                               zero_copy: true }),
            ("ids_only", OptFlags { broadcast_ids: true, local_topk: false,
                                    zero_copy: true }),
            ("topk_only", OptFlags { broadcast_ids: false, local_topk: true,
                                     zero_copy: true }),
            ("naive", OptFlags { broadcast_ids: false, local_topk: false,
                                 zero_copy: true }),
        ];
        let mut results = Vec::new();
        for (name, opt) in cases {
            eprintln!("running {model} w{world} {name}...");
            results.push(run_case(name, model, world, opt, steps)?);
        }
        let bytes = |n: &str| {
            results
                .iter()
                .find(|r| r.name == n)
                .and_then(|r| {
                    r.extra
                        .iter()
                        .find(|(k, _)| k == "wire_B_per_tok")
                        .and_then(|(_, v)| v.parse::<f64>().ok())
                })
                .unwrap_or(0.0)
        };
        let ratio = bytes("naive") / bytes("opt").max(1.0);
        rep.section(
            &format!(
                "E2 §2.1 sync minimization — {model}, world={world} \
                 (Fig. 1: bcast ids + local top-k vs naive)"
            ),
            results,
        );
        println!("round-boundary traffic: naive/opt = {ratio:.1}x");
    }
    rep.finish()
}
