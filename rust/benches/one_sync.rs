//! E3 — Figure 2 / §2.2 ablation: one-time synchronization per layer.
//!
//! The parallel block (GPT-J/Falcon-style attention ∥ FFN) compiles to
//! ONE fused segment → one allreduce per decoder layer; the serial
//! (LLaMA-style) block needs two.  We measure both variants over the
//! same workload and report per-token latency, allreduce count per token
//! (from the ccl instrumentation — must be exactly L vs 2·L) and the
//! simulated cross-socket communication share.
//!
//! Note: the two variants are *different models* (the paper's point is
//! that for architectures with parallel blocks you can exploit the
//! structure); the comparison isolates the synchronization schedule at
//! equal parameter count and equal per-layer compute.
//!
//! Run: `cargo bench --bench one_sync [-- --quick] [--json FILE]`

use xeonserve::benchkit::{self, CaseResult, JsonReport};
use xeonserve::config::{EngineConfig, Variant};
use xeonserve::engine::Engine;

fn run_case(model: &str, world: usize, variant: Variant, steps: usize)
            -> anyhow::Result<CaseResult> {
    let cfg = EngineConfig {
        model: model.into(),
        variant,
        world,
        batch: 1,
        ..Default::default()
    };
    let mut engine = Engine::new(cfg)?;
    let n_layers = engine.preset().n_layers;
    engine.enqueue(vec![7, 8, 9, 10], steps);
    let before = engine.comm_stats();
    engine.run_to_completion()?;
    let delta = engine.comm_stats().since(&before);

    let m = &mut engine.metrics;
    let toks = m.decode_wall.count().max(1) as u64;
    // subtract the prefill round's allreduces (layers * syncs, 1 prefill)
    let prefill_ars = (n_layers * variant.syncs_per_layer()) as u64;
    let ars_per_tok =
        (delta.allreduces.saturating_sub(prefill_ars)) as f64 / toks as f64;
    let sim_ms = m.decode_sim.mean_us() / 1e3;
    Ok(CaseResult::from_stats(&format!("{variant}"), &mut m.decode_wall)
        .with("allreduce_per_tok", format!("{ars_per_tok:.1}"))
        .with("expected", n_layers * variant.syncs_per_layer())
        .with("sim_ms_tok", format!("{sim_ms:.3}")))
}

fn main() -> anyhow::Result<()> {
    let steps = benchkit::iters(16);
    let mut rep = JsonReport::new("one_sync");
    for (model, world) in [("tiny", 4), ("small", 4)] {
        let mut results = Vec::new();
        for variant in [Variant::Parallel, Variant::Serial] {
            eprintln!("running {model} w{world} {variant}...");
            results.push(run_case(model, world, variant, steps)?);
        }
        rep.section(
            &format!(
                "E3 §2.2 one-time synchronization — {model}, world={world} \
                 (Fig. 2: 1 vs 2 allreduces/layer)"
            ),
            results,
        );
    }
    rep.finish()
}
