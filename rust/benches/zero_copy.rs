//! E4 — Figure 3 / §2.3 ablation: minimize memory copy.
//!
//! Two levels:
//!
//! 1. **collective microbench** — the zero-copy shared-memory arena
//!    allreduce vs the staged (copy-per-hop) ring, across payload sizes
//!    and world sizes.  This isolates exactly the copies §2.3 removes.
//! 2. **engine level** — the same decode workload with `opt.zero_copy`
//!    on/off; reports per-token latency and the staged-copy bytes the
//!    baseline pays.
//!
//! Run: `cargo bench --bench zero_copy [-- --quick] [--json FILE]`

use std::sync::Arc;

use xeonserve::benchkit::{self, CaseResult, JsonReport};
use xeonserve::ccl::{CommGroup, Communicator, ReduceOp};
use xeonserve::config::{EngineConfig, OptFlags, Variant};
use xeonserve::engine::Engine;

/// Run `f` on every rank thread of a fresh group; returns per-rank outs.
fn on_group<R: Send + 'static>(
    world: usize,
    capacity: usize,
    f: impl Fn(Communicator) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let group = CommGroup::new_inproc(world, capacity);
    let f = Arc::new(f);
    group
        .into_communicators()
        .into_iter()
        .map(|c| {
            let f = f.clone();
            std::thread::spawn(move || f(c))
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect()
}

fn micro_case(world: usize, elems: usize, iters: usize)
              -> (CaseResult, CaseResult) {
    // zero-copy arena path
    let outs = on_group(world, elems, move |mut c| {
        let mut stats = xeonserve::metrics::LatencyStats::default();
        for i in 0..iters {
            {
                let slot = c.arena_mut(elems).unwrap();
                slot.fill(i as f32);
            }
            let t0 = std::time::Instant::now();
            c.allreduce_arena(elems, ReduceOp::Sum).unwrap();
            if c.rank() == 0 {
                stats.record(t0.elapsed());
            }
        }
        stats
    });
    let mut arena_stats = outs.into_iter().next().unwrap();

    // staged ring path
    let outs = on_group(world, elems, move |c| {
        let mut stats = xeonserve::metrics::LatencyStats::default();
        let mut buf = vec![0.0f32; elems];
        for i in 0..iters {
            buf.fill(i as f32);
            let t0 = std::time::Instant::now();
            c.allreduce_staged(&mut buf, ReduceOp::Sum).unwrap();
            if c.rank() == 0 {
                stats.record(t0.elapsed());
            }
        }
        stats
    });
    let mut staged_stats = outs.into_iter().next().unwrap();

    let kb = elems * 4 / 1024;
    (
        CaseResult::from_stats(&format!("arena_w{world}_{kb}KiB"),
                               &mut arena_stats)
            .with("staged_copies", 0),
        CaseResult::from_stats(&format!("staged_w{world}_{kb}KiB"),
                               &mut staged_stats)
            .with("staged_copies", 4 * (world - 1) * elems / world * 4),
    )
}

fn engine_case(zero_copy: bool, steps: usize)
               -> anyhow::Result<CaseResult> {
    let cfg = EngineConfig {
        model: "small".into(),
        variant: Variant::Parallel,
        world: 4,
        batch: 1,
        opt: OptFlags { zero_copy, ..Default::default() },
        ..Default::default()
    };
    let mut engine = Engine::new(cfg)?;
    engine.enqueue(vec![1, 2, 3], steps);
    let before = engine.comm_stats();
    engine.run_to_completion()?;
    let delta = engine.comm_stats().since(&before);
    let m = &mut engine.metrics;
    let toks = m.decode_wall.count().max(1) as u64;
    Ok(CaseResult::from_stats(
        if zero_copy { "engine_zero_copy" } else { "engine_staged" },
        &mut m.decode_wall,
    )
    .with("stagedB_per_tok", delta.staged_copy_bytes / toks))
}

fn main() -> anyhow::Result<()> {
    let iters = benchkit::iters(200);

    let mut rep = JsonReport::new("zero_copy");
    for world in [2usize, 4, 8] {
        let mut results = Vec::new();
        for elems in [256usize, 4096, 65536, 1 << 20] {
            let (a, s) = micro_case(world, elems, iters);
            results.push(a);
            results.push(s);
        }
        rep.section(
            &format!(
                "E4 §2.3 zero-copy vs staged allreduce — world={world} \
                 (Fig. 3 microbench)"
            ),
            results,
        );
    }

    let steps = benchkit::iters(12);
    let mut results = Vec::new();
    eprintln!("running engine zero-copy ablation (small, world=4)...");
    results.push(engine_case(true, steps)?);
    results.push(engine_case(false, steps)?);
    rep.section(
        "E4 §2.3 engine-level — small, world=4, decode",
        results,
    );
    rep.finish()
}
