//! E6 (supplementary) — collective microbenchmarks of the rccl
//! substrate: allreduce / broadcast / allgather / gather latency vs
//! world size and payload, on the in-process transport.
//!
//! These calibrate the α/β wire model's *software* floor and sanity-check
//! that collective cost scales the way the algorithms promise
//! (ring: ∝ (W−1)/W·n; tree bcast: ∝ ⌈log₂W⌉·n).
//!
//! Run: `cargo bench --bench ccl_micro [-- --quick] [--json FILE]`

use std::sync::Arc;

use xeonserve::benchkit::{self, CaseResult, JsonReport};
use xeonserve::ccl::{CommGroup, Communicator, ReduceOp};
use xeonserve::metrics::LatencyStats;

fn on_group<R: Send + 'static>(
    world: usize,
    capacity: usize,
    f: impl Fn(Communicator) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let group = CommGroup::new_inproc(world, capacity);
    let f = Arc::new(f);
    group
        .into_communicators()
        .into_iter()
        .map(|c| {
            let f = f.clone();
            std::thread::spawn(move || f(c))
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect()
}

fn rank0_stats(outs: Vec<LatencyStats>) -> LatencyStats {
    outs.into_iter().next().unwrap()
}

fn main() -> anyhow::Result<()> {
    let iters = benchkit::iters(300);

    let mut rep = JsonReport::new("ccl_micro");
    for world in [2usize, 4, 8] {
        let mut results = Vec::new();
        for elems in [1024usize, 65536] {
            // ring allreduce (staged)
            let outs = on_group(world, elems, move |c| {
                let mut stats = LatencyStats::default();
                let mut buf = vec![1.0f32; elems];
                for _ in 0..iters {
                    let t0 = std::time::Instant::now();
                    c.allreduce_staged(&mut buf, ReduceOp::Sum).unwrap();
                    if c.rank() == 0 {
                        stats.record(t0.elapsed());
                    }
                }
                stats
            });
            results.push(CaseResult::from_stats(
                &format!("ring_allreduce_{}KiB", elems * 4 / 1024),
                &mut rank0_stats(outs),
            ));

            // tree broadcast
            let outs = on_group(world, elems, move |c| {
                let mut stats = LatencyStats::default();
                for _ in 0..iters {
                    let mut buf = if c.rank() == 0 {
                        vec![7u8; elems * 4]
                    } else {
                        Vec::new()
                    };
                    let t0 = std::time::Instant::now();
                    c.broadcast(&mut buf, 0).unwrap();
                    if c.rank() == 0 {
                        stats.record(t0.elapsed());
                    }
                }
                stats
            });
            results.push(CaseResult::from_stats(
                &format!("tree_bcast_{}KiB", elems * 4 / 1024),
                &mut rank0_stats(outs),
            ));

            // ring allgather
            let outs = on_group(world, elems * world, move |c| {
                let mut stats = LatencyStats::default();
                let local = vec![c.rank() as f32; elems];
                let mut out = vec![0.0f32; elems * c.world()];
                for _ in 0..iters {
                    let t0 = std::time::Instant::now();
                    c.allgather(&local, &mut out).unwrap();
                    if c.rank() == 0 {
                        stats.record(t0.elapsed());
                    }
                }
                stats
            });
            results.push(CaseResult::from_stats(
                &format!("ring_allgather_{}KiB", elems * 4 / 1024),
                &mut rank0_stats(outs),
            ));
        }

        // design-choice ablation: direct vs ring allreduce crossover
        // (the auto-selection threshold in ccl::group)
        for elems in [256usize, 4096, 65536] {
            let outs = on_group(world, elems, move |c| {
                let mut stats = LatencyStats::default();
                let mut buf = vec![1.0f32; elems];
                for _ in 0..iters {
                    let t0 = std::time::Instant::now();
                    c.allreduce_direct(&mut buf, ReduceOp::Sum).unwrap();
                    if c.rank() == 0 {
                        stats.record(t0.elapsed());
                    }
                }
                stats
            });
            results.push(CaseResult::from_stats(
                &format!("direct_allreduce_{}KiB", elems * 4 / 1024),
                &mut rank0_stats(outs),
            ));
            let outs = on_group(world, elems, move |c| {
                let mut stats = LatencyStats::default();
                let mut buf = vec![1.0f32; elems];
                for _ in 0..iters {
                    let t0 = std::time::Instant::now();
                    c.allreduce_ring(&mut buf, ReduceOp::Sum).unwrap();
                    if c.rank() == 0 {
                        stats.record(t0.elapsed());
                    }
                }
                stats
            });
            results.push(CaseResult::from_stats(
                &format!("ring_only_allreduce_{}KiB", elems * 4 / 1024),
                &mut rank0_stats(outs),
            ));
        }

        // top-k pair gather (the §2.1b payload: 40 pairs = 320 B)
        let outs = on_group(world, 64, move |c| {
            let mut stats = LatencyStats::default();
            let payload = vec![0xabu8; 320];
            for _ in 0..iters {
                let t0 = std::time::Instant::now();
                c.gather(&payload, 0).unwrap();
                if c.rank() == 0 {
                    stats.record(t0.elapsed());
                }
            }
            stats
        });
        results.push(CaseResult::from_stats("gather_topk_320B",
                                            &mut rank0_stats(outs)));

        rep.section(
            &format!("E6 rccl collective microbench — world={world}"),
            results,
        );
    }
    rep.finish()
}
