//! E1 — the paper's §3 headline: time per output token, and its scaling
//! with tensor-parallel world size (the paper: Qwen-72B, 4 sockets,
//! input 512, batch 1 → 140 ms/token).
//!
//! We sweep (model preset × world size) at batch 1 and report both the
//! wall-clock per-token latency on this 1-core testbed and the
//! simulated-cluster latency (max-over-ranks compute + α/β wire model —
//! DESIGN.md §4).  The paper's qualitative claim to reproduce: per-token
//! latency *drops* as sockets are added at fixed model size, and stays
//! under the ~200 ms/token human-reading bar.
//!
//! Hermetic builds sweep the built-in presets on the reference backend;
//! `--features xla` builds additionally require the artifact set and
//! only run worlds the manifest was lowered for.
//!
//! Run: `cargo bench --bench token_latency [-- --quick] [--json FILE]`

use xeonserve::benchkit::{self, CaseResult, JsonReport};
use xeonserve::config::{EngineConfig, Manifest, ModelPreset, Variant};
use xeonserve::engine::Engine;

fn bench_case(model: &str, world: usize, steps: usize, prompt_len: usize)
              -> anyhow::Result<CaseResult> {
    let cfg = EngineConfig {
        model: model.into(),
        variant: Variant::Parallel,
        world,
        batch: 1,
        ..Default::default()
    };
    let mut engine = Engine::new(cfg)?;
    let prompt: Vec<i32> = (1..=prompt_len as i32).collect();
    engine.enqueue(prompt, steps);
    let t0 = std::time::Instant::now();
    engine.run_to_completion()?;
    let span = t0.elapsed();

    let params = engine.preset().params / 1_000_000;
    let m = &mut engine.metrics;
    let sim_ms = m.decode_sim.mean_us() / 1e3;
    let tput = m.throughput(span);
    Ok(CaseResult::from_stats(&format!("{model}_w{world}"),
                              &mut m.decode_wall)
        .with("sim_ms_tok", format!("{sim_ms:.3}"))
        .with("tok_per_s", format!("{tput:.1}"))
        .with("params", format!("{params}M")))
}

fn main() -> anyhow::Result<()> {
    // the XLA-backend default needs the lowered artifact set; the
    // hermetic reference backend only needs the built-in preset to
    // shard evenly over the world
    let manifest = if cfg!(feature = "xla") {
        Some(Manifest::load("artifacts")?)
    } else {
        None
    };
    let runnable = |model: &str, world: usize| -> bool {
        match &manifest {
            Some(m) => m
                .find(model, world, 1, "parallel_block", "decode", 1)
                .is_ok(),
            None => ModelPreset::builtin(model)
                .map(|p| p.supports_world(world) && world <= 8)
                .unwrap_or(false),
        }
    };

    let steps = benchkit::iters(24);
    let mut rep = JsonReport::new("token_latency");
    let mut results = Vec::new();
    for (model, prompt_len) in [("tiny", 8), ("small", 64), ("medium", 64)] {
        for world in [1usize, 2, 4, 8] {
            if !runnable(model, world) {
                continue;
            }
            eprintln!("running {model} w{world}...");
            results.push(bench_case(model, world, steps, prompt_len)?);
        }
    }
    rep.section(
        "E1 token latency vs world size (paper §3: 140 ms/token @ 72B/4 sockets)",
        results,
    );
    println!(
        "\nhuman-reading bar: 200 ms/token — see sim_ms_tok column \
         (simulated cluster; wall is 1-core time-sliced)"
    );
    rep.finish()
}
