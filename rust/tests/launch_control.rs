//! Launch control-plane integration tests: registration handshake,
//! config distribution, command/reply framing, and failure detection —
//! exercised against scripted workers so no artifacts/PJRT are needed
//! (the full 2-process serving path is the CI launch-smoke job).

use std::net::TcpStream;
use std::time::Duration;

use xeonserve::config::EngineConfig;
use xeonserve::engine::proto::{Cmd, Reply};
use xeonserve::engine::RankHost;
use xeonserve::launch::control::{read_msg, write_msg, ControlMsg, PROTO_VERSION};
use xeonserve::launch::{coordinate, LaunchOptions};

fn opts(world: usize, port: u16) -> LaunchOptions {
    LaunchOptions {
        world,
        control_addr: format!("127.0.0.1:{port}"),
        register_timeout: Duration::from_secs(30),
        ..Default::default()
    }
}

fn connect(addr: &str) -> TcpStream {
    for _ in 0..400 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    panic!("coordinator on {addr} never came up");
}

/// Register as `rank` and return the post-Start stream + the Welcome.
fn register(addr: &str, rank: usize) -> (TcpStream, ControlMsg) {
    let s = connect(addr);
    write_msg(&s, &ControlMsg::Hello { version: PROTO_VERSION, rank })
        .unwrap();
    let welcome = read_msg(&s).unwrap();
    match read_msg(&s).unwrap() {
        ControlMsg::Start => {}
        other => panic!("expected Start, got {other:?}"),
    }
    (s, welcome)
}

#[test]
fn handshake_config_distribution_and_command_roundtrip() {
    let mut cfg = EngineConfig { world: 2, ..Default::default() };
    cfg.sampling.seed = 1234; // must survive the trip to the workers
    let o = opts(2, 48621);
    let addr = o.control_addr.clone();

    let coord = {
        let cfg = cfg.clone();
        std::thread::spawn(move || coordinate(&cfg, &o).unwrap())
    };

    let workers: Vec<_> = (0..2)
        .map(|rank| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let (s, welcome) = register(&addr, rank);
                let ControlMsg::Welcome {
                    rank: r, world, config_toml, ..
                } = welcome
                else {
                    panic!("expected Welcome");
                };
                assert_eq!(r, rank);
                assert_eq!(world, 2);
                let got =
                    EngineConfig::from_toml_str(&config_toml).unwrap();
                assert_eq!(got.world, 2);
                assert_eq!(got.sampling.seed, 1234);

                // prove liveness traffic is transparent to the engine
                write_msg(&s, &ControlMsg::Heartbeat).unwrap();
                // serve the command stream like a rank worker would
                loop {
                    match read_msg(&s).unwrap() {
                        ControlMsg::Cmd(Cmd::Reset) => {
                            write_msg(&s, &ControlMsg::Reply(
                                Reply::ResetDone { rank })).unwrap();
                        }
                        ControlMsg::Cmd(Cmd::Shutdown) => return,
                        other => panic!("worker got {other:?}"),
                    }
                }
            })
        })
        .collect();

    let fleet = coord.join().unwrap();
    assert_eq!(fleet.hosts.len(), 2);
    for (i, h) in fleet.hosts.iter().enumerate() {
        assert_eq!(h.rank(), i);
        h.send(Cmd::Reset).unwrap();
    }
    let mut seen = [false; 2];
    for _ in 0..2 {
        match fleet.reply_rx.recv_timeout(Duration::from_secs(10)).unwrap()
        {
            Reply::ResetDone { rank } => seen[rank] = true,
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(seen[0] && seen[1]);

    drop(fleet); // hosts send Cmd::Shutdown — workers exit their loop
    for w in workers {
        w.join().unwrap();
    }
}

#[test]
fn killed_worker_surfaces_as_clean_error() {
    let cfg = EngineConfig { world: 1, ..Default::default() };
    let o = opts(1, 48631);
    let addr = o.control_addr.clone();

    let coord = {
        let cfg = cfg.clone();
        std::thread::spawn(move || coordinate(&cfg, &o).unwrap())
    };
    let worker = std::thread::spawn(move || {
        let (s, _) = register(&addr, 0);
        drop(s); // the process "dies" right after bring-up
    });

    let fleet = coord.join().unwrap();
    worker.join().unwrap();
    // the per-worker reader must inject an error, not leave the engine
    // blocking forever on its reply channel
    match fleet.reply_rx.recv_timeout(Duration::from_secs(10)).unwrap() {
        Reply::Error { rank: 0, message } => {
            assert!(message.contains("lost"), "message: {message}");
        }
        other => panic!("unexpected reply {other:?}"),
    }
}

#[test]
fn bad_registrations_are_refused() {
    let cfg = EngineConfig { world: 2, ..Default::default() };
    let o = opts(2, 48641);
    let addr = o.control_addr.clone();

    let coord = {
        let cfg = cfg.clone();
        std::thread::spawn(move || coordinate(&cfg, &o).unwrap())
    };

    // rank 0 registers normally (Welcome arrives right after Hello, so
    // this serializes: rank 0 is taken before the bad claims below)
    let s0 = connect(&addr);
    write_msg(&s0, &ControlMsg::Hello { version: PROTO_VERSION, rank: 0 })
        .unwrap();
    assert!(matches!(read_msg(&s0).unwrap(), ControlMsg::Welcome { .. }));

    // a second claim on rank 0 must be refused with Fatal
    let dup = connect(&addr);
    write_msg(&dup, &ControlMsg::Hello { version: PROTO_VERSION, rank: 0 })
        .unwrap();
    match read_msg(&dup).unwrap() {
        ControlMsg::Fatal { message } => {
            assert!(message.contains("already registered"),
                    "message: {message}");
        }
        other => panic!("expected Fatal, got {other:?}"),
    }

    // an out-of-range rank must be refused too
    let oob = connect(&addr);
    write_msg(&oob, &ControlMsg::Hello { version: PROTO_VERSION, rank: 7 })
        .unwrap();
    match read_msg(&oob).unwrap() {
        ControlMsg::Fatal { message } => {
            assert!(message.contains("out of range"), "message: {message}");
        }
        other => panic!("expected Fatal, got {other:?}"),
    }

    // a wrong protocol version must be refused
    let old = connect(&addr);
    write_msg(&old, &ControlMsg::Hello { version: 0, rank: 1 }).unwrap();
    match read_msg(&old).unwrap() {
        ControlMsg::Fatal { message } => {
            assert!(message.contains("version"), "message: {message}");
        }
        other => panic!("expected Fatal, got {other:?}"),
    }

    // rank 1 registers properly; the launch completes despite the noise
    let s1 = connect(&addr);
    write_msg(&s1, &ControlMsg::Hello { version: PROTO_VERSION, rank: 1 })
        .unwrap();
    assert!(matches!(read_msg(&s1).unwrap(), ControlMsg::Welcome { .. }));
    assert!(matches!(read_msg(&s0).unwrap(), ControlMsg::Start));
    assert!(matches!(read_msg(&s1).unwrap(), ControlMsg::Start));

    let fleet = coord.join().unwrap();
    assert_eq!(fleet.hosts.len(), 2);
    // graceful teardown reaches both workers
    drop(fleet);
    assert!(matches!(read_msg(&s0).unwrap(),
                     ControlMsg::Cmd(Cmd::Shutdown)));
    assert!(matches!(read_msg(&s1).unwrap(),
                     ControlMsg::Cmd(Cmd::Shutdown)));
}
