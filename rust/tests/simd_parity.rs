//! Cross-ISA parity gates for the runtime-dispatched SIMD kernels
//! (DESIGN.md §14): the instruction tier is a pure *scheduling*
//! choice for the f32 and int8-dequant paths — every served token
//! must be bit-identical to the pinned scalar chain at any tier, any
//! world size, any thread count, on both GEMM kernels and both
//! schedulers.  The vnni W8A8 scheme is a different numeric contract
//! (integer matmuls), so its gate is internal: deterministic and
//! world/thread/kernel-invariant with itself.
//!
//! CI runs this file once unforced (the cross-tier comparisons below)
//! and once per `XEONSERVE_FORCE_ISA` tier the host admits (`isa
//! --check`).  Under a forced tier every config resolves to that one
//! tier, so the cross-tier tests skip themselves and the invariance
//! tests — which compare runs *within* the resolved tier — carry the
//! leg.

use xeonserve::backend::simd::{self, Isa};
use xeonserve::config::{BackendKind, Dtype, EngineConfig, GemmKernel,
                        IsaKind, SchedulerKind, WeightSource};
use xeonserve::engine::Engine;

/// The tiers whose outputs must reproduce the scalar chain bit-for-
/// bit, paired with the detection handle that says whether this host
/// can run them (vnni is excluded: different contract, own gate).
const BIT_IDENTICAL_TIERS: [(IsaKind, Isa); 2] =
    [(IsaKind::Avx2, Isa::Avx2), (IsaKind::Avx512, Isa::Avx512)];

fn cfg(world: usize, isa: IsaKind, int8: bool) -> EngineConfig {
    let dt = if int8 { Dtype::Int8 } else { Dtype::F32 };
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch: 2,
        kernel: GemmKernel::Blocked,
        threads: 2,
        isa,
        weight_dtype: dt,
        kv_dtype: dt,
        weights: WeightSource::Synthetic { seed: 2024 },
        ..Default::default()
    }
}

fn tokens(c: &EngineConfig) -> Vec<Vec<i32>> {
    let mut engine = Engine::new(c.clone()).unwrap();
    engine
        .generate(&[vec![10, 20, 30, 40], vec![7, 7, 7]], 6)
        .unwrap()
}

/// A forced tier overrides every config's `isa`, so configs pinned to
/// *different* tiers would silently run the same one — the cross-tier
/// comparisons are vacuous and the pinned-vnni labels wrong.
fn forced() -> bool {
    std::env::var_os(simd::FORCE_ISA_ENV).is_some()
}

fn dt_name(int8: bool) -> &'static str {
    if int8 {
        "int8"
    } else {
        "f32"
    }
}

/// The tentpole gate: each SIMD f32/int8-dequant tier reproduces the
/// scalar tokens exactly, across worlds 1/2/4 and both dtypes, on the
/// threaded blocked kernel.
#[test]
fn simd_tiers_match_scalar_tokens_across_worlds_and_dtypes() {
    if forced() {
        return;
    }
    for int8 in [false, true] {
        let golden = tokens(&cfg(1, IsaKind::Scalar, int8));
        for (kind, isa) in BIT_IDENTICAL_TIERS {
            if !simd::available(isa) {
                continue;
            }
            for world in [1usize, 2, 4] {
                assert_eq!(
                    tokens(&cfg(world, kind, int8)),
                    golden,
                    "isa={kind} world={world} dtype={} diverged from \
                     the scalar chain",
                    dt_name(int8),
                );
            }
        }
    }
}

/// The ISA knob must be invisible on the scalar (single-thread) GEMM
/// kernel too — its row loops dispatch through the same tier.
#[test]
fn simd_tiers_match_scalar_tokens_on_the_scalar_kernel() {
    if forced() {
        return;
    }
    let single = |kind: IsaKind, int8: bool| {
        let mut c = cfg(1, kind, int8);
        c.kernel = GemmKernel::Scalar;
        c.threads = 0;
        c.batch = 1;
        c
    };
    for int8 in [false, true] {
        let golden = tokens(&single(IsaKind::Scalar, int8));
        for (kind, isa) in BIT_IDENTICAL_TIERS {
            if !simd::available(isa) {
                continue;
            }
            assert_eq!(
                tokens(&single(kind, int8)),
                golden,
                "isa={kind} dtype={} diverged on the scalar kernel",
                dt_name(int8),
            );
        }
    }
}

/// Tier parity must survive the continuous scheduler (more requests
/// than lanes, shared-prefix reuse live): admission order and KV
/// attach are scheduling, the tier is arithmetic, and neither may
/// observe the other.
#[test]
fn simd_tiers_match_under_the_continuous_scheduler() {
    if forced() {
        return;
    }
    // five requests over two lanes, all opening with the same four
    // tokens so the shared-prefix path actually publishes/attaches
    let prompts: Vec<Vec<i32>> = (0..5)
        .map(|i| vec![11, 12, 13, 14, i + 1, i + 2])
        .collect();
    let run = |kind: IsaKind, int8: bool| {
        let mut c = cfg(2, kind, int8);
        c.scheduler = SchedulerKind::Continuous;
        let mut engine = Engine::new(c).unwrap();
        engine.generate(&prompts, 4).unwrap()
    };
    for int8 in [false, true] {
        let golden = run(IsaKind::Scalar, int8);
        for (kind, isa) in BIT_IDENTICAL_TIERS {
            if !simd::available(isa) {
                continue;
            }
            assert_eq!(
                run(kind, int8),
                golden,
                "isa={kind} dtype={} diverged under the continuous \
                 scheduler",
                dt_name(int8),
            );
        }
    }
}

/// Whatever tier this process resolves to — auto-detected, or pinned
/// by `XEONSERVE_FORCE_ISA` in the CI per-ISA loop — its outputs must
/// be invariant under world size, thread count, and GEMM kernel.
/// This is the test that carries the forced legs: it compares runs
/// within one tier, so a forced environment only decides *which* tier
/// gets audited.
#[test]
fn resolved_tier_tokens_invariant_across_worlds_threads_kernels() {
    for int8 in [false, true] {
        let golden = tokens(&cfg(1, IsaKind::Auto, int8));
        for world in [2usize, 4] {
            assert_eq!(
                tokens(&cfg(world, IsaKind::Auto, int8)),
                golden,
                "world={world} dtype={} diverged at the resolved tier",
                dt_name(int8),
            );
        }
        for threads in [1usize, 4] {
            let mut c = cfg(1, IsaKind::Auto, int8);
            c.threads = threads;
            assert_eq!(
                tokens(&c),
                golden,
                "threads={threads} dtype={} diverged at the resolved \
                 tier",
                dt_name(int8),
            );
        }
        let mut sk = cfg(1, IsaKind::Auto, int8);
        sk.kernel = GemmKernel::Scalar;
        sk.threads = 0;
        assert_eq!(
            tokens(&sk),
            golden,
            "scalar kernel dtype={} diverged at the resolved tier",
            dt_name(int8),
        );
    }
}

/// The vnni W8A8 gate: the integer scheme is exactly reproducible on
/// any host (hardware dpbusd and the scalar emulation produce the
/// same i32 sums), so its tokens must be rerun-stable and invariant
/// under world size, thread count, and GEMM kernel.  Runs pinned
/// `isa = "vnni"` configs; under a forced environment the force wins
/// and this degenerates into a second invariance audit of the forced
/// tier, which is still sound.
#[test]
fn vnni_scheme_is_deterministic_and_partition_invariant() {
    let golden = tokens(&cfg(1, IsaKind::Vnni, true));
    assert_eq!(
        tokens(&cfg(1, IsaKind::Vnni, true)),
        golden,
        "vnni rerun diverged — the integer scheme must be exactly \
         reproducible",
    );
    for world in [2usize, 4] {
        assert_eq!(
            tokens(&cfg(world, IsaKind::Vnni, true)),
            golden,
            "vnni world={world} diverged",
        );
    }
    for threads in [1usize, 4] {
        let mut c = cfg(1, IsaKind::Vnni, true);
        c.threads = threads;
        assert_eq!(tokens(&c), golden, "vnni threads={threads} diverged");
    }
    let mut sk = cfg(1, IsaKind::Vnni, true);
    sk.kernel = GemmKernel::Scalar;
    sk.threads = 0;
    assert_eq!(tokens(&sk), golden, "vnni scalar kernel diverged");
}
