//! Determinism gates for the threaded blocked GEMM path (DESIGN.md
//! §10): threading and cache-blocking are pure *scheduling* changes —
//! every logit and every greedy token must be bit-identical to the
//! single-threaded scalar kernel, at any thread count, at any world
//! size, through the full distributed engine.

use xeonserve::backend::reference::ReferenceBackend;
use xeonserve::backend::{ExecBackend, StepCtx};
use xeonserve::config::{BackendKind, EngineConfig, GemmKernel, ModelPreset, Variant, WeightSource};
use xeonserve::engine::Engine;

fn cfg(world: usize, batch: usize, kernel: GemmKernel, threads: usize)
       -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch,
        kernel,
        threads,
        weights: WeightSource::Synthetic { seed: 2024 },
        ..Default::default()
    }
}

/// Straight-line greedy decode against the backend alone, returning
/// every step's full logit vector (world 1, lane 0).  `force_pool`
/// drops the inline-dispatch threshold to 0 so even the tiny preset
/// actually exercises the worker pool.
fn greedy_logits(c: &EngineConfig, n_new: usize, force_pool: bool)
                 -> Vec<Vec<f32>> {
    let preset = ModelPreset::builtin(&c.model).unwrap();
    let mut be = ReferenceBackend::new(c, 0, &preset).unwrap();
    if force_pool {
        be.set_par_threshold(0);
    }
    let (h, vocab) = (preset.hidden, preset.vocab);
    let segs = c.variant.syncs_per_layer();
    let prompt = [3i32, 1, 4, 1, 5, 9, 2, 6];
    let bucket = 16usize;
    let length = prompt.len();
    let mut padded = prompt.to_vec();
    padded.resize(bucket, 0);

    let ctx = StepCtx::Prefill { lane: 0, bucket, length, offset: 0 };
    let mut x = vec![0.0f32; bucket * h];
    let mut y = vec![0.0f32; bucket * h];
    be.embed(&ctx, &padded, &mut x).unwrap();
    for li in 0..preset.n_layers {
        for seg in 0..segs {
            be.layer_partial(&ctx, li, seg, &x, &mut y).unwrap();
            for (xi, yi) in x.iter_mut().zip(&y) {
                *xi += *yi;
            }
        }
    }
    let head: Vec<f32> = x[(length - 1) * h..length * h].to_vec();
    let mut logits = vec![0.0f32; vocab];
    be.lm_head(&head, &mut logits).unwrap();

    let argmax = |l: &[f32]| -> i32 {
        let mut best = 0usize;
        for (i, &v) in l.iter().enumerate() {
            if v > l[best] {
                best = i;
            }
        }
        best as i32
    };

    let mut out = vec![logits.clone()];
    let mut tok = argmax(&logits);
    let mut pos = length;
    let mut xd = vec![0.0f32; h];
    let mut yd = vec![0.0f32; h];
    for _ in 1..n_new {
        let positions = [pos as i32];
        let ctx = StepCtx::Decode { positions: &positions };
        be.embed(&ctx, &[tok], &mut xd).unwrap();
        for li in 0..preset.n_layers {
            for seg in 0..segs {
                be.layer_partial(&ctx, li, seg, &xd, &mut yd).unwrap();
                for (xi, yi) in xd.iter_mut().zip(&yd) {
                    *xi += *yi;
                }
            }
        }
        be.lm_head(&xd, &mut logits).unwrap();
        out.push(logits.clone());
        tok = argmax(&logits);
        pos += 1;
    }
    out
}

fn assert_logits_bits_eq(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: step counts differ");
    for (step, (x, y)) in a.iter().zip(b).enumerate() {
        for (j, (va, vb)) in x.iter().zip(y).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: step {step} logit {j}: {va} vs {vb}"
            );
        }
    }
}

/// The satellite gate: threaded GEMM produces bit-identical LOGITS —
/// not just tokens — vs. the scalar path, at thread counts 1/2/4.
#[test]
fn threaded_logits_bit_identical_to_scalar_path() {
    for variant in [Variant::Parallel, Variant::Serial] {
        let mut sc = cfg(1, 1, GemmKernel::Scalar, 0);
        sc.variant = variant;
        let golden = greedy_logits(&sc, 6, false);
        for threads in [1usize, 2, 4] {
            let mut bc = cfg(1, 1, GemmKernel::Blocked, threads);
            bc.variant = variant;
            let got = greedy_logits(&bc, 6, true);
            assert_logits_bits_eq(
                &golden,
                &got,
                &format!("{variant} threads={threads}"),
            );
        }
    }
}

fn engine_tokens(world: usize, kernel: GemmKernel, threads: usize)
                 -> Vec<Vec<i32>> {
    let mut engine =
        Engine::new(cfg(world, 2, kernel, threads)).unwrap();
    engine
        .generate(&[vec![10, 20, 30, 40], vec![7, 7, 7]], 6)
        .unwrap()
}

/// Cross-world parity must hold with threading enabled: worlds 1/2/4
/// on the threaded blocked kernel all reproduce the scalar w1 tokens.
#[test]
fn cross_world_parity_holds_with_threading() {
    let golden = engine_tokens(1, GemmKernel::Scalar, 0);
    for world in [1usize, 2, 4] {
        for threads in [2usize, 4] {
            let got = engine_tokens(world, GemmKernel::Blocked, threads);
            assert_eq!(
                got, golden,
                "world={world} threads={threads} diverged from the \
                 scalar single-thread reference"
            );
        }
    }
}

/// The kernel knob must not leak into served tokens even under
/// continuous batching (more requests than lanes, mixed lengths).
#[test]
fn kernel_choice_invisible_under_continuous_batching() {
    let prompts: Vec<Vec<i32>> =
        (0..5).map(|i| vec![i + 1, i + 2, i + 3]).collect();
    let mut outs = Vec::new();
    for (kernel, threads) in [
        (GemmKernel::Scalar, 0usize),
        (GemmKernel::Blocked, 1),
        (GemmKernel::Blocked, 3),
    ] {
        let mut engine =
            Engine::new(cfg(2, 2, kernel, threads)).unwrap();
        outs.push(engine.generate(&prompts, 4).unwrap());
    }
    assert_eq!(outs[0], outs[1], "blocked x1 vs scalar");
    assert_eq!(outs[0], outs[2], "blocked x3 vs scalar");
}

/// TOML-configured threading reaches the backend (the knob the launch
/// coordinator ships to remote workers must parse and apply).
#[test]
fn threads_knob_roundtrips_through_toml() {
    let mut c = cfg(2, 1, GemmKernel::Blocked, 4);
    c.kernel = GemmKernel::Scalar;
    let text = c.to_toml_string();
    let back = EngineConfig::from_toml_str(&text).unwrap();
    assert_eq!(back.threads, 4);
    assert_eq!(back.kernel, GemmKernel::Scalar);

    let preset = ModelPreset::builtin("tiny").unwrap();
    let be = ReferenceBackend::new(
        &EngineConfig { kernel: GemmKernel::Blocked, ..back.clone() },
        0,
        &preset,
    )
    .unwrap();
    assert_eq!(be.threads(), 4, "explicit thread count must stick");
    let scalar = ReferenceBackend::new(&back, 0, &preset).unwrap();
    assert_eq!(scalar.threads(), 1, "scalar kernel is single-threaded");
}
