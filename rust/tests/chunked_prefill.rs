//! Chunk-invariance determinism suite (DESIGN.md §12): chunked
//! prefill changes *when* work happens — a prompt trickles in as
//! fixed-size chunks interleaved with batched decode — but never
//! *what* is computed.  Because every output element keeps the same
//! single-accumulator ascending-k chain and KV appends land at the
//! same absolute positions, logits and greedy decodes must be
//! BIT-IDENTICAL to whole-prompt prefill at any chunk size, world
//! size, thread count, and dtype.  This file is that claim's pin.

use xeonserve::backend::reference::ReferenceBackend;
use xeonserve::backend::{ExecBackend, StepCtx};
use xeonserve::config::{BackendKind, Dtype, EngineConfig, ModelPreset, WeightSource};
use xeonserve::engine::Engine;
use xeonserve::scheduler::PrefillCursor;

fn cfg(world: usize, batch: usize, dtype: Dtype, chunk: usize)
       -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch,
        weight_dtype: dtype,
        kv_dtype: dtype,
        prefill_chunk: chunk,
        weights: WeightSource::Synthetic { seed: 0xC0FFEE },
        ..Default::default()
    }
}

// ---- backend-level logit invariance ------------------------------------

/// Straight-line forward pass against one backend: prefill `prompt`
/// (whole at `chunk == 0`, else in `chunk`-token pieces continuing the
/// KV region), then greedy-decode `n_new` tokens, returning every
/// step's full logit vector (world 1, lane 0).
fn greedy_logits(c: &EngineConfig, prompt: &[i32], chunk: usize,
                 n_new: usize) -> Vec<Vec<f32>> {
    fn forward(be: &mut ReferenceBackend, ctx: &StepCtx, n_layers: usize,
               segs: usize, x: &mut [f32], y: &mut [f32], n: usize) {
        for li in 0..n_layers {
            for seg in 0..segs {
                be.layer_partial(ctx, li, seg, &x[..n], &mut y[..n])
                    .unwrap();
                for (xi, yi) in x[..n].iter_mut().zip(&y[..n]) {
                    *xi += *yi;
                }
            }
        }
    }

    let preset = ModelPreset::builtin(&c.model).unwrap();
    let mut be = ReferenceBackend::new(c, 0, &preset).unwrap();
    let (h, vocab) = (preset.hidden, preset.vocab);
    let (layers, segs) = (preset.n_layers, c.variant.syncs_per_layer());
    let length = prompt.len();

    // prefill, whole (bucket-padded, like the engine's classic path)
    // or chunked (unpadded spans, like Cmd::PrefillChunk rounds)
    let mut last_row = vec![0.0f32; h];
    if chunk == 0 {
        let bucket = 16usize;
        let mut padded = prompt.to_vec();
        padded.resize(bucket, 0);
        let ctx = StepCtx::Prefill { lane: 0, bucket, length, offset: 0 };
        let mut x = vec![0.0f32; bucket * h];
        let mut y = vec![0.0f32; bucket * h];
        be.embed(&ctx, &padded, &mut x).unwrap();
        forward(&mut be, &ctx, layers, segs, &mut x, &mut y, bucket * h);
        last_row.copy_from_slice(&x[(length - 1) * h..length * h]);
    } else {
        let mut cursor = PrefillCursor::new(length, chunk);
        let mut x = vec![0.0f32; length * h];
        let mut y = vec![0.0f32; length * h];
        while let Some(span) = cursor.next_chunk() {
            let n = span.len * h;
            let ctx = StepCtx::Prefill {
                lane: 0,
                bucket: span.len,
                length: span.len,
                offset: span.start,
            };
            be.embed(&ctx, &prompt[span.start..span.start + span.len],
                     &mut x[..n])
                .unwrap();
            forward(&mut be, &ctx, layers, segs, &mut x, &mut y, n);
            if span.last {
                let row = (span.len - 1) * h;
                last_row.copy_from_slice(&x[row..row + h]);
            }
        }
    }
    let mut logits = vec![0.0f32; vocab];
    be.lm_head(&last_row, &mut logits).unwrap();

    let argmax = |l: &[f32]| -> i32 {
        let mut best = 0usize;
        for (i, &v) in l.iter().enumerate() {
            if v > l[best] {
                best = i;
            }
        }
        best as i32
    };

    let mut out = vec![logits.clone()];
    let mut tok = argmax(&logits);
    let mut pos = length;
    let mut xd = vec![0.0f32; h];
    let mut yd = vec![0.0f32; h];
    for _ in 1..n_new {
        let positions = [pos as i32];
        let ctx = StepCtx::Decode { positions: &positions };
        be.embed(&ctx, &[tok], &mut xd).unwrap();
        forward(&mut be, &ctx, layers, segs, &mut xd, &mut yd, h);
        be.lm_head(&xd, &mut logits).unwrap();
        out.push(logits.clone());
        tok = argmax(&logits);
        pos += 1;
    }
    out
}

fn assert_logits_bits_eq(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: step counts differ");
    for (step, (x, y)) in a.iter().zip(b).enumerate() {
        for (j, (va, vb)) in x.iter().zip(y).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: step {step} logit {j}: {va} vs {vb}"
            );
        }
    }
}

/// The §12 logit gate: every chunk size reproduces the whole-prompt
/// LOGITS — not just tokens — bit for bit, at both dtypes.  Chunk 1
/// (one position per round) and a chunk larger than the prompt (one
/// short span) are the edge cases folded into the matrix.
#[test]
fn chunked_logits_bit_identical_to_whole_prompt() {
    let prompt = [3i32, 9, 27, 4, 15, 6, 7, 8, 2, 11, 5];
    for dtype in [Dtype::F32, Dtype::Int8] {
        let c = cfg(1, 1, dtype, 0);
        let golden = greedy_logits(&c, &prompt, 0, 5);
        for chunk in [1usize, 7, 16] {
            let got = greedy_logits(&c, &prompt, chunk, 5);
            assert_logits_bits_eq(
                &golden,
                &got,
                &format!("{dtype:?} chunk={chunk} vs whole"),
            );
        }
    }
}

// ---- engine-level greedy-decode invariance -----------------------------

fn engine_tokens(world: usize, dtype: Dtype, chunk: usize)
                 -> Vec<Vec<i32>> {
    let mut engine = Engine::new(cfg(world, 2, dtype, chunk)).unwrap();
    engine
        .generate(
            &[
                vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100, 110],
                vec![7, 7, 7], // shorter than chunks 7 and 16
                vec![1, 2, 3, 4, 5, 6, 7, 8],
            ],
            8,
        )
        .unwrap()
}

/// The acceptance matrix: greedy decode bit-identical for chunk sizes
/// {1, 7, 16, whole} × worlds {1, 2, 4} × dtypes {f32, int8} through
/// the full distributed engine — continuous batching, ccl
/// collectives, chunk rounds interleaved with live decode steps.
#[test]
fn chunk_invariance_matrix_worlds_and_dtypes() {
    for dtype in [Dtype::F32, Dtype::Int8] {
        let golden = engine_tokens(1, dtype, 0);
        assert!(golden.iter().all(|t| !t.is_empty()));
        for world in [1usize, 2, 4] {
            for chunk in [0usize, 1, 7, 16] {
                if world == 1 && chunk == 0 {
                    continue; // that cell IS the golden run
                }
                let got = engine_tokens(world, dtype, chunk);
                assert_eq!(
                    got, golden,
                    "{dtype:?} world={world} chunk={chunk} diverged \
                     from the whole-prompt w1 reference"
                );
            }
        }
    }
}

/// Chunk-size-1 edge case, run deeper than the matrix: every prompt
/// position is its own round, so the engine drives prompt_len chunk
/// rounds per request against live decode traffic.
#[test]
fn chunk_size_one_matches_whole_prompt() {
    let golden = engine_tokens(2, Dtype::F32, 0);
    let got = engine_tokens(2, Dtype::F32, 1);
    assert_eq!(got, golden, "chunk=1 must reproduce whole-prompt");
}

/// A prompt shorter than one chunk is a single (short) span — the
/// degenerate chunking that must also match, including for the empty
/// prompt the whole-prompt path pads to one token.
#[test]
fn prompt_shorter_than_chunk_matches_whole_prompt() {
    for prompts in [vec![vec![5i32, 6, 7]], vec![vec![]]] {
        let mut whole = Engine::new(cfg(1, 1, Dtype::F32, 0)).unwrap();
        let golden = whole.generate(&prompts, 6).unwrap();
        let mut chunked = Engine::new(cfg(1, 1, Dtype::F32, 16)).unwrap();
        let got = chunked.generate(&prompts, 6).unwrap();
        assert_eq!(got, golden, "short prompt {prompts:?}");
    }
}

// ---- serving semantics around chunked prefill --------------------------

/// TTFT accounting spans a request's WHOLE prefill: one prefill_wall
/// sample per request, not one per chunk.
#[test]
fn ttft_counts_requests_not_chunks() {
    let mut engine = Engine::new(cfg(1, 2, Dtype::F32, 2)).unwrap();
    engine.enqueue(vec![1; 10], 4); // 5 chunks
    engine.enqueue(vec![2; 6], 4); // 3 chunks
    engine.run_to_completion().unwrap();
    assert_eq!(engine.metrics.prefill_wall.count(), 2,
               "one TTFT sample per request");
    assert_eq!(engine.metrics.requests_done, 2);
    // consecutive decode rounds ran with lanes busy, so the
    // decode-stall series has samples
    assert!(engine.metrics.decode_gap.count() > 0);
}

/// The engine's streaming feed: every generated token is emitted
/// exactly once, in order, tagged with its request — chunked or not.
#[test]
fn emitted_tokens_match_completions() {
    for chunk in [0usize, 4] {
        let mut engine = Engine::new(cfg(1, 2, Dtype::F32, chunk)).unwrap();
        let a = engine.enqueue(vec![1, 2, 3, 4, 5, 6, 7], 5);
        let b = engine.enqueue(vec![9, 8, 7], 3);
        let mut streamed: std::collections::HashMap<u64, Vec<i32>> =
            Default::default();
        let mut done = Vec::new();
        while engine.has_work() {
            done.extend(engine.step().unwrap());
            for (id, tok) in engine.take_new_tokens() {
                streamed.entry(id).or_default().push(tok);
            }
        }
        done.sort_by_key(|c| c.request_id);
        assert_eq!(done.len(), 2);
        for c in &done {
            assert_eq!(streamed.get(&c.request_id), Some(&c.tokens),
                       "chunk={chunk}: stream of request {} must equal \
                        its completion tokens", c.request_id);
        }
        assert!(streamed.contains_key(&a) && streamed.contains_key(&b));
    }
}

/// Cancellation never leaks: whether a request is still queued,
/// mid-chunked-prefill, or decoding, cancel() must return its lane
/// and KV pages to the pool — asserted via the LaneTable /
/// PagedAllocator occupancy probes.
#[test]
fn cancel_mid_prefill_frees_lane_and_pages() {
    let mut engine = Engine::new(cfg(1, 2, Dtype::F32, 2)).unwrap();
    let free_lanes0 = engine.free_lanes();
    let free_pages0 = engine.free_pages();
    assert_eq!(engine.total_pages(), free_pages0);

    // a long prompt (6 chunks) plus a decode companion
    let long = engine.enqueue(vec![1; 12], 8);
    let short = engine.enqueue(vec![5, 5], 8);
    // a few steps: both admitted, long still mid-prefill
    for _ in 0..3 {
        engine.step().unwrap();
    }
    assert_eq!(engine.free_lanes(), free_lanes0 - 2);
    assert!(engine.free_pages() < free_pages0);

    // cancel the mid-prefill request: lane + pages return immediately
    assert!(engine.cancel(long).unwrap());
    assert_eq!(engine.free_lanes(), free_lanes0 - 1,
               "cancelled prefill must free its lane within one step");
    assert!(!engine.cancel(long).unwrap(), "second cancel is a no-op");

    // the survivor finishes; the pool is whole again
    let done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].request_id, short);
    assert_eq!(engine.free_lanes(), free_lanes0);
    assert_eq!(engine.free_pages(), free_pages0,
               "cancelled request leaked KV pages");
}

/// Property sweep: random interleavings of submit / step / cancel
/// conserve lanes and pages — no schedule leaks.
#[test]
fn random_cancel_schedules_conserve_lanes_and_pages() {
    use xeonserve::util::SplitMix64;
    let mut rng = SplitMix64::new(0xD00D);
    for case in 0..8u64 {
        let chunk = [0usize, 1, 3][case as usize % 3];
        let mut engine =
            Engine::new(cfg(1, 2, Dtype::F32, chunk)).unwrap();
        let lanes0 = engine.free_lanes();
        let pages0 = engine.free_pages();
        let mut live: Vec<u64> = Vec::new();
        for step in 0..60 {
            match rng.next_below(4) {
                0 => {
                    let len = 1 + rng.next_below(12);
                    live.push(engine.enqueue(vec![1; len],
                                             1 + rng.next_below(6)));
                }
                1 if !live.is_empty() => {
                    let i = rng.next_below(live.len());
                    let id = live.swap_remove(i);
                    // may already have completed — either is fine,
                    // but it must never error
                    engine.cancel(id).unwrap();
                }
                _ => {
                    if engine.has_work() {
                        for c in engine.step().unwrap() {
                            live.retain(|&id| id != c.request_id);
                        }
                    }
                }
            }
            assert!(engine.free_pages() <= engine.total_pages(),
                    "case {case} step {step}: page pool oversubscribed");
        }
        // cancel everything left and drain: full pool must return
        for id in live {
            engine.cancel(id).unwrap();
        }
        engine.run_to_completion().unwrap();
        assert_eq!(engine.free_lanes(), lanes0, "case {case}: lane leak");
        assert_eq!(engine.free_pages(), pages0, "case {case}: page leak");
    }
}

/// The TOML knob reaches the engine via the same path the launch
/// coordinator ships configs through.
#[test]
fn prefill_chunk_roundtrips_through_toml_and_serves() {
    let c = cfg(1, 1, Dtype::F32, 3);
    let back = EngineConfig::from_toml_str(&c.to_toml_string()).unwrap();
    assert_eq!(back.prefill_chunk, 3);
    let mut engine = Engine::new(back).unwrap();
    let out = engine.generate(&[vec![1, 2, 3, 4, 5, 6, 7]], 4).unwrap();
    let mut whole = Engine::new(cfg(1, 1, Dtype::F32, 0)).unwrap();
    let golden = whole.generate(&[vec![1, 2, 3, 4, 5, 6, 7]], 4).unwrap();
    assert_eq!(out, golden);
}
