//! Connection-storm suite (DESIGN.md §16): the event-driven server
//! front — readiness-polled admission, bounded per-connection frame
//! queues, load-shedding — changes *how* replies reach clients, never
//! *what* the engine computes.  Token streams served through the
//! [`Front`] state machine must be bit-identical to a single-request
//! engine run across schedulers × worlds; a slow reader's frames
//! queue up to the bound and then its lane is cancelled (the engine
//! never blocks on one socket); deep backlogs answer `{"error":
//! "shed"}` instead of queueing unboundedly; a client that vanishes
//! mid-prefill is reaped before its first token; and randomized
//! connect / stream / stall / disconnect schedules conserve lanes,
//! KV pages, and connection bookkeeping exactly.
//!
//! The tests drive [`Front`] through the same push-in / pull-out
//! contract the TCP reactor uses — virtual connections backed by the
//! reactor's own bounded [`OutQ`] — so every code path under test is
//! the production path minus the socket syscalls.

use std::collections::BTreeMap;
use std::time::Instant;

use xeonserve::benchkit::suite::run_storm;
use xeonserve::config::{BackendKind, EngineConfig, SchedulerKind,
                        WeightSource};
use xeonserve::engine::Engine;
use xeonserve::server::conn::OutQ;
use xeonserve::server::Front;
use xeonserve::tokenizer::Tokenizer;
use xeonserve::util::{Json, SplitMix64};

fn cfg(world: usize, batch: usize, sched: SchedulerKind)
       -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch,
        scheduler: sched,
        weights: WeightSource::Synthetic { seed: 0xC0FFEE },
        ..Default::default()
    }
}

fn front_for(cfg: EngineConfig) -> Front {
    Front::new(Engine::new(cfg).unwrap()).unwrap()
}

/// Route every outbox line into its connection's bounded queue —
/// exactly what the reactor's routing pass does, minus the overflow
/// policy (tests that exercise overflow replicate it inline).
fn route(front: &mut Front, queues: &mut BTreeMap<u64, OutQ>) {
    for (cid, line) in front.take_outbox() {
        if let Some(q) = queues.get_mut(&cid) {
            q.push(&line, Instant::now())
                .expect("frame queue overflowed in a non-overflow test");
        }
    }
}

/// The reference stream: the prompt decoded alone on a fresh
/// single-lane engine — the tokens every served stream must
/// reproduce bit for bit, whatever the storm around it did.
fn golden_tokens(prompt: &str, max_new: usize) -> Vec<i32> {
    let mut e = Engine::new(cfg(1, 1, SchedulerKind::Fcfs)).unwrap();
    let tok = Tokenizer::byte_level(e.preset().vocab).unwrap();
    e.generate(&[tok.encode(prompt)], max_new).unwrap().pop().unwrap()
}

fn tokens_of(j: &Json) -> Vec<i32> {
    j.get("tokens")
        .expect("done frame without tokens")
        .as_arr()
        .expect("tokens not an array")
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect()
}

// ---- bit-identity under concurrency ------------------------------------

/// Headline gate: streams served through the event-driven front are
/// bit-identical to the single-request baseline across both admission
/// schedulers × worlds {1, 2}.  12 streaming clients over 6 distinct
/// prompts share 2 lanes, so lanes retire and refill mid-storm and
/// every composition the front can produce is compared token for
/// token — including the per-frame tokens, which must concatenate to
/// exactly the summary frame's array.
#[test]
fn storm_streams_bit_identical_across_schedulers_and_worlds() {
    let prompts: Vec<String> =
        (0..6).map(|i| format!("storm prompt {i}")).collect();
    let golden: Vec<Vec<i32>> =
        prompts.iter().map(|p| golden_tokens(p, 6)).collect();
    for world in [1usize, 2] {
        for sched in [SchedulerKind::Fcfs, SchedulerKind::Continuous] {
            let mut front = front_for(cfg(world, 2, sched));
            let mut queues: BTreeMap<u64, OutQ> = BTreeMap::new();
            for c in 0..12u64 {
                queues.insert(c + 1, OutQ::new(64, 1 << 20));
                front.on_line(c + 1, &format!(
                    "{{\"prompt\": \"{}\", \"max_new_tokens\": 6, \
                     \"stream\": true}}",
                    prompts[c as usize % prompts.len()]));
            }
            let mut streamed: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
            let mut done = 0usize;
            for _ in 0..2000 {
                if front.has_work() {
                    front.tick().unwrap();
                }
                route(&mut front, &mut queues);
                for (&cid, q) in queues.iter_mut() {
                    while let Some((line, _)) = q.pop_frame() {
                        let j = Json::parse(&line).unwrap();
                        if j.get("done").is_some() {
                            let want =
                                &golden[(cid as usize - 1)
                                        % prompts.len()];
                            assert_eq!(
                                &tokens_of(&j), want,
                                "w{world} {sched:?} conn {cid}: \
                                 summary diverged from baseline");
                            assert_eq!(
                                streamed.get(&cid).unwrap(), want,
                                "w{world} {sched:?} conn {cid}: \
                                 frames diverged from baseline");
                            done += 1;
                        } else {
                            assert!(j.get("error").is_none(),
                                    "unexpected error line {line}");
                            let t = j.get("token").unwrap()
                                .as_f64().unwrap() as i32;
                            streamed.entry(cid).or_default().push(t);
                        }
                    }
                }
                if done == 12 && !front.has_work() {
                    break;
                }
            }
            assert_eq!(done, 12,
                       "w{world} {sched:?}: streams did not finish");
            assert_eq!(front.inflight(), 0);
            assert_eq!(front.queued(), 0);
        }
    }
}

/// The acceptance-scale storm: 10 000 streaming clients go
/// idle-to-active against an 8-lane engine, and every one of the
/// 10 000 streams stays bit-identical to its single-request
/// baseline.  Clients arrive in waves, drain eagerly, and leave —
/// bounded memory, bounded queues, zero lost replies.
#[test]
fn ten_thousand_client_storm_stays_bit_identical() {
    let prompts: Vec<String> =
        (0..8).map(|i| format!("wave {i}")).collect();
    let golden: Vec<Vec<i32>> =
        prompts.iter().map(|p| golden_tokens(p, 2)).collect();
    let clients = 10_000usize;
    let wave = 64usize;
    let mut front = front_for(cfg(1, 8, SchedulerKind::Continuous));
    let mut queues: BTreeMap<u64, OutQ> = BTreeMap::new();
    let mut streamed: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    let mut submitted = 0usize;
    let mut finished = 0usize;
    for _ in 0..clients * 64 {
        for _ in 0..wave {
            if submitted >= clients {
                break;
            }
            let cid = submitted as u64 + 1;
            queues.insert(cid, OutQ::new(64, 1 << 20));
            front.on_line(cid, &format!(
                "{{\"prompt\": \"{}\", \"max_new_tokens\": 2, \
                 \"stream\": true}}",
                prompts[submitted % prompts.len()]));
            submitted += 1;
        }
        if front.has_work() {
            front.tick().unwrap();
        }
        route(&mut front, &mut queues);
        let mut closed: Vec<u64> = Vec::new();
        for (&cid, q) in queues.iter_mut() {
            while let Some((line, _)) = q.pop_frame() {
                let j = Json::parse(&line).unwrap();
                if j.get("done").is_some() {
                    let want = &golden[(cid as usize - 1)
                                       % prompts.len()];
                    assert_eq!(&tokens_of(&j), want,
                               "conn {cid}: stream diverged under \
                                the 10k-client storm");
                    assert_eq!(streamed.remove(&cid)
                                   .as_deref().unwrap_or(&[]),
                               want.as_slice(),
                               "conn {cid}: frames diverged");
                    finished += 1;
                    closed.push(cid);
                } else {
                    assert!(j.get("error").is_none(),
                            "unexpected error line {line}");
                    let t = j.get("token").unwrap()
                        .as_f64().unwrap() as i32;
                    streamed.entry(cid).or_default().push(t);
                }
            }
        }
        for cid in closed {
            queues.remove(&cid);
        }
        if finished == clients && !front.has_work() {
            break;
        }
    }
    assert_eq!(finished, clients, "storm lost replies");
    assert_eq!(front.inflight(), 0, "front bookkeeping leak");
    assert_eq!(front.queued(), 0);
    assert!(queues.is_empty(), "connection leak");
    let e = front.engine_mut();
    assert_eq!(e.metrics.requests_done as usize, clients);
    assert_eq!(e.free_lanes(), 8, "lane leak after the storm");
    assert_eq!(e.free_pages() + e.shared_pages(), e.total_pages(),
               "page leak after the storm");
}

/// The benchkit storm scenario (the row `BENCH_pr9.json` records)
/// agrees with the suite: its quick profile drives waves wider than
/// the shed bound, so the recorded row must show a real shed rate in
/// (0, 1) and clean accounting on both schedulers.
#[test]
fn quick_storm_scenario_records_shed_rate_and_frame_latency() {
    for sched in [SchedulerKind::Fcfs, SchedulerKind::Continuous] {
        let rec = run_storm(&cfg(1, 4, sched), true).unwrap();
        assert_eq!(rec.name, "connection_storm");
        assert_eq!(rec.scheduler, sched);
        assert_eq!(rec.requests, 96);
        assert!(rec.shed_rate > 0.0 && rec.shed_rate < 1.0,
                "{sched:?}: opening wave must shed its tail \
                 (got rate {})", rec.shed_rate);
        let shed = (rec.shed_rate * rec.requests as f64).round() as usize;
        assert_eq!(rec.requests_done as usize + shed, rec.requests,
                   "{sched:?}: served + shed must cover every client");
        assert!(rec.tokens_out > 0);
    }
}

// ---- load shedding -----------------------------------------------------

/// Queue-depth shedding is deterministic: with `shed_queue = 2`, a
/// burst of 10 arrivals from idle admits exactly 2 and answers the
/// other 8 with `{"error": "shed", "reason": "queue-depth"}` — and
/// the shed clients' lines carry the occupancy that refused them.
#[test]
fn queue_depth_bound_sheds_the_burst_tail() {
    let mut c = cfg(1, 1, SchedulerKind::Fcfs);
    c.shed_queue = 2;
    let mut front = front_for(c);
    for conn in 1..=10u64 {
        front.on_line(conn, r#"{"prompt": "burst", "max_new_tokens": 2}"#);
    }
    assert_eq!(front.queued(), 2);
    let shed: Vec<(u64, Json)> = front
        .take_outbox()
        .into_iter()
        .map(|(c, l)| (c, Json::parse(&l).unwrap()))
        .collect();
    assert_eq!(shed.len(), 8, "exactly the tail past the bound sheds");
    for (conn, j) in &shed {
        assert!(*conn >= 3, "an admitted client was shed");
        assert_eq!(j.get("error").unwrap().as_str(), Some("shed"));
        assert_eq!(j.get("reason").unwrap().as_str(),
                   Some("queue-depth"));
        assert_eq!(j.get("queued").unwrap().as_u64(), Some(2));
        assert!(j.get("oldest_wait_ms").unwrap().as_u64().is_some());
    }
    assert_eq!(front.stats.shed, 8);
    // the admitted two still complete normally
    let mut served = 0usize;
    for _ in 0..200 {
        if !front.has_work() {
            break;
        }
        front.tick().unwrap();
        for (conn, line) in front.take_outbox() {
            let j = Json::parse(&line).unwrap();
            assert!(conn <= 2);
            assert!(j.get("text").is_some(), "unexpected line {line}");
            served += 1;
        }
    }
    assert_eq!(served, 2);
    assert_eq!(front.engine_mut().metrics.requests_done, 2);
}

/// Wait-SLO shedding: once the queue head has waited past
/// `shed_wait_ms`, a new arrival is refused with reason
/// `oldest-wait` — and admission reopens as soon as the backlog
/// drains.
#[test]
fn oldest_wait_slo_sheds_new_arrivals_until_the_queue_drains() {
    let mut c = cfg(1, 1, SchedulerKind::Fcfs);
    c.shed_wait_ms = 1;
    let mut front = front_for(c);
    front.on_line(1, r#"{"prompt": "head", "max_new_tokens": 2}"#);
    assert!(front.take_outbox().is_empty(), "head must be admitted");
    std::thread::sleep(std::time::Duration::from_millis(10));
    front.on_line(2, r#"{"prompt": "late", "max_new_tokens": 2}"#);
    let lines = front.take_outbox();
    assert_eq!(lines.len(), 1);
    let (conn, j) = (lines[0].0, Json::parse(&lines[0].1).unwrap());
    assert_eq!(conn, 2);
    assert_eq!(j.get("error").unwrap().as_str(), Some("shed"));
    assert_eq!(j.get("reason").unwrap().as_str(), Some("oldest-wait"));
    assert!(j.get("oldest_wait_ms").unwrap().as_u64().unwrap() >= 1);
    // drain the backlog; the policy must admit again from idle
    for _ in 0..200 {
        if !front.has_work() {
            break;
        }
        front.tick().unwrap();
        front.take_outbox();
    }
    front.on_line(3, r#"{"prompt": "after drain", "max_new_tokens": 2}"#);
    assert!(front.take_outbox().is_empty(),
            "an empty queue must never wait-shed");
    assert_eq!(front.stats.shed, 1);
}

// ---- backpressure ------------------------------------------------------

/// A slow reader's frames queue up to the bound, then its lane is
/// cancelled — backpressure-then-cancel (DESIGN.md §16).  The engine
/// keeps running throughout, the already-queued frames survive for
/// whenever the reader returns, and the cancelled request never
/// counts as done.
#[test]
fn slow_reader_queues_to_the_bound_then_cancels() {
    let mut front = front_for(cfg(1, 1, SchedulerKind::Fcfs));
    // a 4-frame bound against a 16-token stream: overflow at frame 5
    let mut q = OutQ::new(4, 1 << 20);
    front.on_line(1, r#"{"prompt": "slow reader",
                         "max_new_tokens": 16, "stream": true}"#);
    let mut overflowed = false;
    for _ in 0..400 {
        if front.has_work() {
            front.tick().unwrap();
        }
        for (cid, line) in front.take_outbox() {
            assert_eq!(cid, 1);
            assert!(!overflowed,
                    "no frame may be produced after the cancel");
            if q.push(&line, Instant::now()).is_err() {
                // the reactor's overflow policy, verbatim
                front.stats.overflow_cancels += 1;
                front.on_disconnect(1);
                overflowed = true;
            }
        }
        if overflowed && !front.has_work() {
            break;
        }
    }
    assert!(overflowed, "the bounded queue never overflowed");
    assert!(!front.has_work());
    assert_eq!(front.stats.overflow_cancels, 1);
    assert_eq!(q.len(), 4, "queued frames must survive the cancel");
    assert_eq!(front.inflight(), 0);
    let e = front.engine_mut();
    assert_eq!(e.metrics.requests_done, 0,
               "a cancelled stream must not count as done");
    assert_eq!(e.free_lanes(), 1, "cancel must free the lane");
    assert_eq!(e.free_pages() + e.shared_pages(), e.total_pages(),
               "cancel must free the pages");
}

// ---- out-of-band disconnects -------------------------------------------

/// Drive one request to mid-prefill (chunked, so prefill spans
/// several ticks), then hang up.  The reap must be immediate — lane
/// and pages free before any token exists — and nothing may surface
/// later: no frames, no completion, no `requests_done` tick.
fn disconnect_mid_prefill(stream: bool) {
    let mut c = cfg(1, 1, SchedulerKind::Fcfs);
    c.prefill_chunk = 2;
    let mut front = front_for(c);
    // 14 prompt tokens / 2-token chunks = 7 prefill ticks; one tick
    // leaves the lane mid-prefill, guaranteed pre-token
    front.on_line(1, &format!(
        "{{\"prompt\": \"abcdefghijklmn\", \"max_new_tokens\": 8, \
         \"stream\": {stream}}}"));
    front.tick().unwrap();
    assert!(front.take_outbox().is_empty(),
            "no frame may exist mid-prefill");
    assert_eq!(front.engine().free_lanes(), 0,
               "request should hold its lane mid-prefill");
    front.on_disconnect(1); // the poller saw HUP
    assert_eq!(front.engine().free_lanes(), 1,
               "disconnect must free the lane immediately");
    assert_eq!(front.inflight(), 0);
    for _ in 0..100 {
        if !front.has_work() {
            break;
        }
        front.tick().unwrap();
        assert!(front.take_outbox().is_empty(),
                "a reaped request may not produce output");
    }
    let e = front.engine_mut();
    assert_eq!(e.metrics.requests_done, 0,
               "an abandoned request must not run to completion");
    assert_eq!(e.metrics.tokens_out, 0);
    assert_eq!(e.free_pages() + e.shared_pages(), e.total_pages());
}

/// Satellite regression: HUP during prefill reaps a *streaming*
/// request before its first token.
#[test]
fn disconnect_during_prefill_reaps_before_first_token() {
    disconnect_mid_prefill(true);
}

/// Satellite regression: an abandoned *one-shot* request — no frame
/// ever due until completion — is cancelled too, instead of running
/// to completion for a client that already left.
#[test]
fn abandoned_one_shot_request_is_cancelled_not_completed() {
    disconnect_mid_prefill(false);
}

// ---- cancel of still-queued requests -----------------------------------

/// Satellite regression: `{"cancel": id}` reaches a request still
/// sitting in the AdmissionQueue — before the fix the front only
/// asked the engine, so a queued id answered "unknown" and ran to
/// completion anyway.  fcfs at batch 1 pins the scenario: the burst
/// guard admits one queued request per tick while a stream decodes,
/// so the third arrival is reliably still queued when the cancel
/// lands.
#[test]
fn cancel_reaches_requests_still_queued_for_admission() {
    let mut front = front_for(cfg(1, 1, SchedulerKind::Fcfs));
    front.on_line(1, r#"{"prompt": "stream a",
                         "max_new_tokens": 8, "stream": true}"#);
    // A's first token frame reveals its engine id; B and C follow as
    // id_a + 1 and id_a + 2 (ids are monotonic in line order)
    let mut id_a = None;
    for _ in 0..50 {
        if front.has_work() {
            front.tick().unwrap();
        }
        for (cid, line) in front.take_outbox() {
            let j = Json::parse(&line).unwrap();
            if cid == 1 && j.get("token").is_some() && id_a.is_none() {
                id_a = j.get("id").unwrap().as_u64();
            }
        }
        if id_a.is_some() {
            break;
        }
    }
    let id_a = id_a.expect("stream never produced a token frame");
    front.on_line(2, r#"{"prompt": "b", "max_new_tokens": 2}"#);
    front.on_line(3, r#"{"prompt": "c", "max_new_tokens": 2}"#);
    assert_eq!(front.queued(), 2);
    front.tick().unwrap();
    assert_eq!(front.queued(), 1,
               "burst guard should hold C in the admission queue");
    front.on_line(4, &format!("{{\"cancel\": {}}}", id_a + 2));
    assert_eq!(front.queued(), 0, "cancel missed the queued request");
    let mut acked = false;
    let mut c_terminated = false;
    for (cid, line) in front.take_outbox() {
        let j = Json::parse(&line).unwrap();
        if cid == 4 {
            assert_eq!(j.get("cancelled").unwrap().as_u64(),
                       Some(id_a + 2));
            acked = true;
        }
        if cid == 3 {
            assert_eq!(j.get("error").unwrap().as_str(),
                       Some("cancelled"));
            c_terminated = true;
        }
    }
    assert!(acked, "canceller got no acknowledgement");
    assert!(c_terminated, "C's stream was not terminated");
    // cancelling the same id again is a clean error, not a wedge
    front.on_line(4, &format!("{{\"cancel\": {}}}", id_a + 2));
    let lines = front.take_outbox();
    assert_eq!(lines.len(), 1);
    assert!(lines[0].1.contains("unknown or already finished"));
    // A and B still complete; C never does
    let mut done = Vec::new();
    for _ in 0..200 {
        if !front.has_work() {
            break;
        }
        front.tick().unwrap();
        for (cid, line) in front.take_outbox() {
            let j = Json::parse(&line).unwrap();
            if j.get("done").is_some() || j.get("text").is_some() {
                done.push(cid);
            }
        }
    }
    done.sort_unstable();
    assert_eq!(done, vec![1, 2], "exactly A and B may complete");
    assert_eq!(front.engine_mut().metrics.requests_done, 2,
               "the cancelled request must not retire as done");
}

// ---- randomized schedules ----------------------------------------------

/// One seeded random schedule of connect / submit / drain / stall /
/// disconnect / tick ops, with the bookkeeping identity
/// `inflight == queued + engine-pending + engine-active` checked at
/// every step and full conservation (lanes, pages, connections) at
/// drain.
fn run_random_schedule(seed: u64, sched: SchedulerKind) {
    let mut rng = SplitMix64::new(seed);
    let mut c = cfg(1, 2, sched);
    c.shed_queue = 4; // shallow bound so shed paths fire mid-schedule
    let mut front = front_for(c);
    let lanes0 = front.engine().free_lanes();
    let pages0 = front.engine().free_pages();
    let mut queues: BTreeMap<u64, OutQ> = BTreeMap::new();
    let mut next_conn: u64 = 1;
    for op in 0..400usize {
        match rng.next_below(6) {
            0 | 1 => {
                // connect and submit (half the arrivals stream); an
                // undrained queue doubles as a stalled reader
                let cid = next_conn;
                next_conn += 1;
                queues.insert(cid, OutQ::new(1024, 1 << 20));
                let stream = rng.next_below(2) == 0;
                let n = 1 + rng.next_below(6);
                front.on_line(cid, &format!(
                    "{{\"prompt\": \"conn {cid}\", \
                     \"max_new_tokens\": {n}, \"stream\": {stream}}}"));
            }
            2 => {
                // a random reader catches up on its stream
                let pick = queues
                    .keys()
                    .nth(rng.next_below(queues.len().max(1)))
                    .copied();
                if let Some(cid) = pick {
                    let q = queues.get_mut(&cid).unwrap();
                    while let Some((line, _)) = q.pop_frame() {
                        Json::parse(&line).expect("non-JSON frame");
                    }
                }
            }
            3 => {
                // a random client hangs up mid-whatever
                let pick = queues
                    .keys()
                    .nth(rng.next_below(queues.len().max(1)))
                    .copied();
                if let Some(cid) = pick {
                    queues.remove(&cid);
                    front.on_disconnect(cid);
                }
            }
            _ => {
                if front.has_work() {
                    front.tick().unwrap();
                }
            }
        }
        // the reactor's routing pass: frames for vanished connections
        // are dropped
        for (cid, line) in front.take_outbox() {
            if let Some(q) = queues.get_mut(&cid) {
                q.push(&line, Instant::now()).unwrap();
            }
        }
        let e = front.engine();
        assert!(e.free_pages() + e.shared_pages() <= e.total_pages(),
                "seed {seed:#x} op {op}: page pool oversubscribed");
        assert_eq!(
            front.inflight(),
            front.queued() + e.pending_count() + e.active_count(),
            "seed {seed:#x} op {op}: owner map out of sync with the \
             queue and engine");
    }
    // quiesce: serve out everything still live
    for _ in 0..10_000 {
        if !front.has_work() {
            break;
        }
        front.tick().unwrap();
        for (cid, line) in front.take_outbox() {
            if let Some(q) = queues.get_mut(&cid) {
                q.push(&line, Instant::now()).unwrap();
            }
        }
    }
    assert!(!front.has_work(), "seed {seed:#x}: front never drained");
    assert_eq!(front.inflight(), 0, "seed {seed:#x}: owner leak");
    assert_eq!(front.queued(), 0);
    // every surviving connection hangs up; its queue must drain fully
    for (cid, mut q) in std::mem::take(&mut queues) {
        while q.pop_frame().is_some() {}
        assert!(q.is_empty());
        front.on_disconnect(cid);
    }
    let e = front.engine();
    assert_eq!(e.free_lanes(), lanes0, "seed {seed:#x}: lane leak");
    assert_eq!(e.free_pages() + e.shared_pages(), pages0,
               "seed {seed:#x}: page leak");
}

/// Property sweep: randomized connect / stream / stall / disconnect
/// schedules against both schedulers conserve lanes, pages, and
/// connection bookkeeping — no interleaving of arrivals, sheds,
/// hangups, and ticks leaks anything.
#[test]
fn random_storm_schedules_conserve_lanes_pages_and_connections() {
    for case in 0..4u64 {
        let sched = if case % 2 == 0 {
            SchedulerKind::Fcfs
        } else {
            SchedulerKind::Continuous
        };
        run_random_schedule(0x5704_0000 + case, sched);
    }
}
