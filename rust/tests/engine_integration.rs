//! Engine-level integration tests.
//!
//! The main body runs **hermetically** on the pure-Rust reference
//! backend (tiny preset, synthetic weights) — no artifacts, no native
//! libraries — and exercises the full distributed stack: in-process
//! rank threads, ccl collectives, continuous batching, KV/lane
//! bookkeeping, sampling.  The `xla_artifacts` module at the bottom
//! re-runs the key invariants against the AOT artifacts when the crate
//! is built with `--features xla` (CI's artifact job).

use xeonserve::config::{BackendKind, EngineConfig, OptFlags, Variant, WeightSource};
use xeonserve::engine::Engine;

#[macro_use]
#[path = "common/mod.rs"]
mod common;

fn cfg(world: usize, batch: usize) -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        variant: Variant::Parallel,
        world,
        batch,
        weights: WeightSource::Synthetic { seed: 99 },
        ..Default::default()
    }
}

/// THE tensor-parallel invariant the paper's design depends on: the
/// reference backend's fixed-granularity reductions make greedy decode
/// *bit-identical* across world sizes — for both block variants.
#[test]
fn greedy_decode_bit_identical_across_world_sizes() {
    for variant in [Variant::Parallel, Variant::Serial] {
        let prompts = vec![vec![10, 20, 30, 40]];
        let mut all = Vec::new();
        for world in [1usize, 2, 4] {
            let mut c = cfg(world, 1);
            c.variant = variant;
            let mut engine = Engine::new(c).unwrap();
            all.push(engine.generate(&prompts, 6).unwrap());
        }
        assert_eq!(all[0], all[1], "{variant}: w1 vs w2");
        assert_eq!(all[0], all[2], "{variant}: w1 vs w4");
    }
}

#[test]
fn optimizations_do_not_change_tokens() {
    // §2.1/§2.3 are pure communication changes; greedy output must be
    // bit-identical with them on or off.
    let prompts = vec![vec![3, 1, 4, 1, 5], vec![9, 2, 6]];
    let mut outs = Vec::new();
    for opt in [
        OptFlags::default(),
        OptFlags::naive(),
        OptFlags { zero_copy: false, ..Default::default() },
        OptFlags { local_topk: false, ..Default::default() },
        OptFlags { broadcast_ids: false, ..Default::default() },
    ] {
        let mut engine =
            Engine::new(EngineConfig { opt, ..cfg(2, 2) }).unwrap();
        outs.push(engine.generate(&prompts, 5).unwrap());
    }
    for o in &outs[1..] {
        assert_eq!(&outs[0], o);
    }
}

#[test]
fn continuous_batching_more_requests_than_lanes() {
    let mut engine = Engine::new(cfg(2, 2)).unwrap();
    // 5 requests through 2 lanes
    let prompts: Vec<Vec<i32>> =
        (0..5).map(|i| vec![i + 1, i + 2, i + 3]).collect();
    let outs = engine.generate(&prompts, 4).unwrap();
    assert_eq!(outs.len(), 5);
    for o in &outs {
        assert_eq!(o.len(), 4, "each request gets its max_new tokens");
        for &t in o {
            assert!((0..256).contains(&t), "token {t} out of tiny vocab");
        }
    }
    assert_eq!(engine.metrics.requests_done, 5);
}

#[test]
fn batched_lanes_match_single_lane_runs() {
    // the SAME request must produce the same tokens whether it shares a
    // batch with others or runs alone (lane isolation / masking)
    let a = vec![7, 7, 7, 7];
    let b = vec![100, 90, 80];
    let mut solo = Engine::new(cfg(2, 2)).unwrap();
    let solo_a = solo.generate(&[a.clone()], 5).unwrap();

    let mut batched = Engine::new(cfg(2, 2)).unwrap();
    let both = batched.generate(&[a, b], 5).unwrap();
    assert_eq!(solo_a[0], both[0], "lane sharing changed the tokens");
}

#[test]
fn sampled_generation_is_seeded_and_in_vocab() {
    let mut c = cfg(2, 1);
    c.sampling.temperature = 0.9;
    c.sampling.top_k = 20;
    c.sampling.seed = 1234;
    let mut e1 = Engine::new(c.clone()).unwrap();
    let mut e2 = Engine::new(c).unwrap();
    let p = vec![vec![1, 2, 3]];
    let o1 = e1.generate(&p, 8).unwrap();
    let o2 = e2.generate(&p, 8).unwrap();
    assert_eq!(o1, o2, "same seed must reproduce");
    assert!(o1[0].iter().all(|&t| (0..256).contains(&t)));
}

#[test]
fn reset_clears_state_and_reproduces() {
    let mut engine = Engine::new(cfg(2, 2)).unwrap();
    let p = vec![vec![5, 6, 7]];
    let first = engine.generate(&p, 5).unwrap();
    engine.reset().unwrap();
    let second = engine.generate(&p, 5).unwrap();
    assert_eq!(first, second, "reset must restore a fresh KV state");
}

#[test]
fn comm_stats_count_expected_collectives() {
    let mut engine = Engine::new(cfg(4, 1)).unwrap();
    let n_layers = engine.preset().n_layers;
    let before = engine.comm_stats();
    let steps = 4usize;
    engine.generate(&[vec![1, 2, 3]], steps).unwrap();
    let d = engine.comm_stats().since(&before);
    // rounds = 1 prefill + (steps-1) decodes; parallel variant: 1 AR/layer
    let rounds = steps as u64; // prefill + 3 decode
    assert_eq!(d.allreduces, rounds * n_layers as u64,
               "one allreduce per layer per round (§2.2)");
    assert_eq!(d.broadcasts, rounds, "one id-broadcast per round (§2.1a)");
    assert_eq!(d.gathers, rounds, "one top-k gather per round (§2.1b)");
    // §2.3: the allreduce path stages NOTHING; residual staged bytes come
    // only from the (tiny) id-broadcast + top-k gather messages.
    assert!(
        d.staged_copy_bytes < rounds * 8 * 1024,
        "zero-copy staged bytes should be control-plane only: {}",
        d.staged_copy_bytes
    );
}

#[test]
fn serial_variant_doubles_allreduces() {
    let mut c = cfg(2, 1);
    c.variant = Variant::Serial;
    let mut engine = Engine::new(c).unwrap();
    let n_layers = engine.preset().n_layers;
    let before = engine.comm_stats();
    engine.generate(&[vec![1, 2]], 3).unwrap();
    let d = engine.comm_stats().since(&before);
    assert_eq!(d.allreduces, 3 * 2 * n_layers as u64);
}

#[test]
fn long_generation_respects_max_seq() {
    // tiny max_seq = 64; prompt 16-bucket + many tokens must stop at cap
    let mut engine = Engine::new(cfg(1, 1)).unwrap();
    let out = engine.generate(&[vec![1; 10]], 500).unwrap();
    assert!(!out[0].is_empty());
    assert!(out[0].len() <= 64 - 10 + 1, "generation must stop at max_seq");
}

#[test]
fn invalid_model_or_world_fails_cleanly() {
    let mut c = cfg(2, 1);
    c.model = "nonexistent".into();
    assert!(Engine::new(c).is_err());
    let c2 = cfg(16, 1); // tiny does not shard over 16 ranks
    assert!(Engine::new(c2).is_err());
}

#[test]
fn oversized_prompt_truncates_to_bucket() {
    // tiny prefill bucket is 16; a 40-token prompt must still serve
    let mut engine = Engine::new(cfg(2, 1)).unwrap();
    let long: Vec<i32> = (0..40).map(|i| i % 200).collect();
    let outs = engine.generate(&[long], 3).unwrap();
    assert_eq!(outs[0].len(), 3);
}

#[test]
fn empty_prompt_serves_without_panic() {
    let mut engine = Engine::new(cfg(2, 1)).unwrap();
    let outs = engine.generate(&[vec![]], 3).unwrap();
    assert_eq!(outs[0].len(), 3);
}

#[test]
fn zero_max_new_yields_the_prefill_token() {
    // max_new_tokens = 0 degenerates to "sample once at prefill"
    let mut engine = Engine::new(cfg(2, 1)).unwrap();
    let outs = engine.generate(&[vec![1, 2, 3]], 0).unwrap();
    assert_eq!(outs[0].len(), 1);
    assert_eq!(engine.metrics.requests_done, 1);
}

#[test]
fn serial_and_parallel_are_different_models() {
    let mut p = Engine::new(cfg(2, 1)).unwrap();
    let mut c = cfg(2, 1);
    c.variant = Variant::Serial;
    let mut s = Engine::new(c).unwrap();
    let prompt = vec![vec![1, 2, 3, 4, 5]];
    let po = p.generate(&prompt, 6).unwrap();
    let so = s.generate(&prompt, 6).unwrap();
    assert_ne!(po, so, "variants should not coincide on synthetic weights");
}

#[test]
fn top_p_sampling_stays_in_candidate_set() {
    let mut c = cfg(2, 1);
    c.sampling.temperature = 1.2;
    c.sampling.top_p = 0.7;
    c.sampling.top_k = 8;
    let mut engine = Engine::new(c).unwrap();
    let outs = engine.generate(&[vec![4, 5, 6]], 10).unwrap();
    assert_eq!(outs[0].len(), 10);
    assert!(outs[0].iter().all(|&t| (0..256).contains(&t)));
}

#[test]
fn metrics_populated_after_run() {
    let mut engine = Engine::new(cfg(2, 1)).unwrap();
    engine.generate(&[vec![1, 2, 3, 4]], 4).unwrap();
    let m = &mut engine.metrics;
    assert_eq!(m.tokens_out, 4);
    assert!(m.decode_wall.count() >= 3);
    assert!(m.prefill_wall.count() == 1);
    assert!(m.decode_sim.p50_us() > 0);
}

#[test]
fn different_seeds_are_different_models() {
    let mut a = Engine::new(cfg(2, 1)).unwrap();
    let mut c = cfg(2, 1);
    c.weights = WeightSource::Synthetic { seed: 100 };
    let mut b = Engine::new(c).unwrap();
    let prompt = vec![vec![8, 9, 10, 11, 12]];
    let ao = a.generate(&prompt, 8).unwrap();
    let bo = b.generate(&prompt, 8).unwrap();
    assert_ne!(ao, bo, "weight seed must matter");
}

/// Artifact-gated variants: the same invariants on the XLA/PJRT
/// backend, exactly as they gated before the backend split.
#[cfg(feature = "xla")]
mod xla_artifacts {
    use super::*;

    fn xcfg(world: usize, batch: usize) -> EngineConfig {
        EngineConfig { backend: BackendKind::Xla, ..cfg(world, batch) }
    }

    #[test]
    fn optimizations_do_not_change_tokens_xla() {
        require_artifacts!();
        let prompts = vec![vec![3, 1, 4, 1, 5], vec![9, 2, 6]];
        let mut outs = Vec::new();
        for opt in [
            OptFlags::default(),
            OptFlags::naive(),
            OptFlags { zero_copy: false, ..Default::default() },
            OptFlags { local_topk: false, ..Default::default() },
            OptFlags { broadcast_ids: false, ..Default::default() },
        ] {
            let mut engine =
                Engine::new(EngineConfig { opt, ..xcfg(2, 2) }).unwrap();
            outs.push(engine.generate(&prompts, 5).unwrap());
        }
        for o in &outs[1..] {
            assert_eq!(&outs[0], o);
        }
    }

    #[test]
    fn world_size_does_not_change_tokens_xla() {
        require_artifacts!();
        // XLA reductions are exact up to f32 ordering; greedy tokens
        // must still agree across world sizes on the tiny model
        let prompts = vec![vec![10, 20, 30, 40]];
        let mut all = Vec::new();
        for world in [1usize, 2, 4] {
            let mut engine = Engine::new(xcfg(world, 1)).unwrap();
            all.push(engine.generate(&prompts, 6).unwrap());
        }
        assert_eq!(all[0], all[1], "w1 vs w2");
        assert_eq!(all[0], all[2], "w1 vs w4");
    }

    #[test]
    fn continuous_batching_xla() {
        require_artifacts!();
        let mut engine = Engine::new(xcfg(2, 2)).unwrap();
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|i| vec![i + 1, i + 2, i + 3]).collect();
        let outs = engine.generate(&prompts, 4).unwrap();
        assert_eq!(outs.len(), 5);
        for o in &outs {
            assert_eq!(o.len(), 4);
        }
    }

    /// The built-in preset table (`ModelPreset::builtin`) hand-mirrors
    /// python's configs.py / aot.py DEFAULT_SET; this pins the two
    /// together so the hermetic tier can't silently drift away from
    /// the architectures the artifact pipeline actually lowers.
    #[test]
    fn builtin_presets_match_generated_manifest() {
        require_artifacts!();
        use xeonserve::config::{Manifest, ModelPreset};
        let m = Manifest::load("artifacts").unwrap();
        for (name, mp) in &m.configs {
            let b = ModelPreset::builtin(name).unwrap_or_else(|_| {
                panic!("manifest config {name} has no built-in preset")
            });
            assert_eq!(b.n_layers, mp.n_layers, "{name} n_layers");
            assert_eq!(b.hidden, mp.hidden, "{name} hidden");
            assert_eq!(b.n_heads, mp.n_heads, "{name} n_heads");
            assert_eq!(b.n_kv_heads, mp.n_kv_heads, "{name} n_kv_heads");
            assert_eq!(b.head_dim, mp.head_dim, "{name} head_dim");
            assert_eq!(b.ffn, mp.ffn, "{name} ffn");
            assert_eq!(b.vocab, mp.vocab, "{name} vocab");
            assert_eq!(b.max_seq, mp.max_seq, "{name} max_seq");
            assert_eq!(b.params, mp.params, "{name} params");
            assert!((b.rope_theta - mp.rope_theta).abs() < 1e-9, "{name}");
            assert!((b.norm_eps - mp.norm_eps).abs() < 1e-12, "{name}");
            // bucket ladder: every (world, batch) combination the
            // manifest lowered for this preset must agree with the
            // built-in ladder the reference backend uses
            let mut combos: Vec<(usize, usize)> = m
                .segments
                .iter()
                .filter(|s| &s.config == name && s.mode == "prefill")
                .map(|s| (s.world, s.batch))
                .collect();
            combos.sort_unstable();
            combos.dedup();
            for (world, batch) in combos {
                assert_eq!(
                    m.prefill_buckets(name, world, batch),
                    b.builtin_prefill_buckets(),
                    "{name} buckets diverge at world={world} batch={batch}"
                );
            }
        }
    }

    #[test]
    fn reset_reproduces_xla() {
        require_artifacts!();
        let mut engine = Engine::new(xcfg(2, 2)).unwrap();
        let p = vec![vec![5, 6, 7]];
        let first = engine.generate(&p, 5).unwrap();
        engine.reset().unwrap();
        let second = engine.generate(&p, 5).unwrap();
        assert_eq!(first, second);
    }
}
