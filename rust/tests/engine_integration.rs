//! Engine-level integration tests over the real artifacts (tiny preset).
//!
//! Requires `make artifacts`.  These run the full three-layer stack per
//! test; the tiny model keeps each under a couple of seconds.

use xeonserve::config::{EngineConfig, OptFlags, Variant, WeightSource};
use xeonserve::engine::Engine;

#[macro_use]
#[path = "common/mod.rs"]
mod common;

fn cfg(world: usize, batch: usize) -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        variant: Variant::Parallel,
        world,
        batch,
        weights: WeightSource::Synthetic { seed: 99 },
        ..Default::default()
    }
}

#[test]
fn optimizations_do_not_change_tokens() {
    require_artifacts!();
    // §2.1/§2.3 are pure communication changes; greedy output must be
    // bit-identical with them on or off.
    let prompts = vec![vec![3, 1, 4, 1, 5], vec![9, 2, 6]];
    let mut outs = Vec::new();
    for opt in [
        OptFlags::default(),
        OptFlags::naive(),
        OptFlags { zero_copy: false, ..Default::default() },
        OptFlags { local_topk: false, ..Default::default() },
        OptFlags { broadcast_ids: false, ..Default::default() },
    ] {
        let mut engine = Engine::new(EngineConfig {
            opt,
            ..cfg(2, 2)
        })
        .unwrap();
        outs.push(engine.generate(&prompts, 5).unwrap());
    }
    for o in &outs[1..] {
        assert_eq!(&outs[0], o);
    }
}

#[test]
fn world_size_does_not_change_tokens() {
    require_artifacts!();
    // tensor-parallel partitioning is numerically exact up to f32
    // reduction order; greedy tokens must agree across world sizes
    let prompts = vec![vec![10, 20, 30, 40]];
    let mut all = Vec::new();
    for world in [1usize, 2, 4] {
        let mut engine = Engine::new(cfg(world, 1)).unwrap();
        all.push(engine.generate(&prompts, 6).unwrap());
    }
    assert_eq!(all[0], all[1], "w1 vs w2");
    assert_eq!(all[0], all[2], "w1 vs w4");
}

#[test]
fn continuous_batching_more_requests_than_lanes() {
    require_artifacts!();
    let mut engine = Engine::new(cfg(2, 2)).unwrap();
    // 5 requests through 2 lanes
    let prompts: Vec<Vec<i32>> =
        (0..5).map(|i| vec![i + 1, i + 2, i + 3]).collect();
    let outs = engine.generate(&prompts, 4).unwrap();
    assert_eq!(outs.len(), 5);
    for o in &outs {
        assert_eq!(o.len(), 4, "each request gets its max_new tokens");
        for &t in o {
            assert!((0..256).contains(&t), "token {t} out of tiny vocab");
        }
    }
    assert_eq!(engine.metrics.requests_done, 5);
}

#[test]
fn batched_lanes_match_single_lane_runs() {
    require_artifacts!();
    // the SAME request must produce the same tokens whether it shares a
    // batch with others or runs alone (lane isolation / masking)
    let a = vec![7, 7, 7, 7];
    let b = vec![100, 90, 80];
    let mut solo = Engine::new(cfg(2, 2)).unwrap();
    let solo_a = solo.generate(&[a.clone()], 5).unwrap();

    let mut batched = Engine::new(cfg(2, 2)).unwrap();
    let both = batched.generate(&[a, b], 5).unwrap();
    assert_eq!(solo_a[0], both[0], "lane sharing changed the tokens");
}

#[test]
fn sampled_generation_is_seeded_and_in_vocab() {
    require_artifacts!();
    let mut c = cfg(2, 1);
    c.sampling.temperature = 0.9;
    c.sampling.top_k = 20;
    c.sampling.seed = 1234;
    let mut e1 = Engine::new(c.clone()).unwrap();
    let mut e2 = Engine::new(c).unwrap();
    let p = vec![vec![1, 2, 3]];
    let o1 = e1.generate(&p, 8).unwrap();
    let o2 = e2.generate(&p, 8).unwrap();
    assert_eq!(o1, o2, "same seed must reproduce");
    assert!(o1[0].iter().all(|&t| (0..256).contains(&t)));
}

#[test]
fn reset_clears_state_and_reproduces() {
    require_artifacts!();
    let mut engine = Engine::new(cfg(2, 2)).unwrap();
    let p = vec![vec![5, 6, 7]];
    let first = engine.generate(&p, 5).unwrap();
    engine.reset().unwrap();
    let second = engine.generate(&p, 5).unwrap();
    assert_eq!(first, second, "reset must restore a fresh KV state");
}

#[test]
fn comm_stats_count_expected_collectives() {
    require_artifacts!();
    let mut engine = Engine::new(cfg(4, 1)).unwrap();
    let n_layers = engine.preset().n_layers;
    let before = engine.comm_stats();
    let steps = 4usize;
    engine.generate(&[vec![1, 2, 3]], steps).unwrap();
    let d = engine.comm_stats().since(&before);
    // rounds = 1 prefill + (steps-1) decodes; parallel variant: 1 AR/layer
    let rounds = steps as u64; // prefill + 3 decode
    assert_eq!(d.allreduces, rounds * n_layers as u64,
               "one allreduce per layer per round (§2.2)");
    assert_eq!(d.broadcasts, rounds, "one id-broadcast per round (§2.1a)");
    assert_eq!(d.gathers, rounds, "one top-k gather per round (§2.1b)");
    // §2.3: the allreduce path stages NOTHING; residual staged bytes come
    // only from the (tiny) id-broadcast + top-k gather messages.  Compare
    // against the staged baseline, which pays the layer activations.
    assert!(
        d.staged_copy_bytes < rounds * 8 * 1024,
        "zero-copy staged bytes should be control-plane only: {}",
        d.staged_copy_bytes
    );
}

#[test]
fn serial_variant_doubles_allreduces() {
    require_artifacts!();
    let mut c = cfg(2, 1);
    c.variant = Variant::Serial;
    let mut engine = Engine::new(c).unwrap();
    let n_layers = engine.preset().n_layers;
    let before = engine.comm_stats();
    engine.generate(&[vec![1, 2]], 3).unwrap();
    let d = engine.comm_stats().since(&before);
    assert_eq!(d.allreduces, 3 * 2 * n_layers as u64);
}

#[test]
fn long_generation_respects_max_seq() {
    require_artifacts!();
    // tiny max_seq = 64; prompt 16-bucket + many tokens must stop at cap
    let mut engine = Engine::new(cfg(1, 1)).unwrap();
    let out = engine.generate(&[vec![1; 10]], 500).unwrap();
    assert!(!out[0].is_empty());
    assert!(out[0].len() <= 64 - 10 + 1, "generation must stop at max_seq");
}

#[test]
fn invalid_model_or_world_fails_cleanly() {
    require_artifacts!();
    let mut c = cfg(2, 1);
    c.model = "nonexistent".into();
    assert!(Engine::new(c).is_err());
    let c2 = cfg(16, 1); // world 16 not in the artifact set
    assert!(Engine::new(c2).is_err());
}

#[test]
fn oversized_prompt_truncates_to_bucket() {
    require_artifacts!();
    // tiny prefill bucket is 16; a 40-token prompt must still serve
    let mut engine = Engine::new(cfg(2, 1)).unwrap();
    let long: Vec<i32> = (0..40).map(|i| i % 200).collect();
    let outs = engine.generate(&[long], 3).unwrap();
    assert_eq!(outs[0].len(), 3);
}

#[test]
fn empty_prompt_serves_without_panic() {
    require_artifacts!();
    let mut engine = Engine::new(cfg(2, 1)).unwrap();
    let outs = engine.generate(&[vec![]], 3).unwrap();
    assert_eq!(outs[0].len(), 3);
}

#[test]
fn serial_and_parallel_are_different_models() {
    require_artifacts!();
    let mut p = Engine::new(cfg(2, 1)).unwrap();
    let mut c = cfg(2, 1);
    c.variant = Variant::Serial;
    let mut s = Engine::new(c).unwrap();
    let prompt = vec![vec![1, 2, 3, 4, 5]];
    let po = p.generate(&prompt, 6).unwrap();
    let so = s.generate(&prompt, 6).unwrap();
    assert_ne!(po, so, "variants should not coincide on synthetic weights");
}

#[test]
fn top_p_sampling_stays_in_candidate_set() {
    require_artifacts!();
    let mut c = cfg(2, 1);
    c.sampling.temperature = 1.2;
    c.sampling.top_p = 0.7;
    c.sampling.top_k = 8;
    let mut engine = Engine::new(c).unwrap();
    let outs = engine.generate(&[vec![4, 5, 6]], 10).unwrap();
    assert_eq!(outs[0].len(), 10);
    assert!(outs[0].iter().all(|&t| (0..256).contains(&t)));
}

#[test]
fn metrics_populated_after_run() {
    require_artifacts!();
    let mut engine = Engine::new(cfg(2, 1)).unwrap();
    engine.generate(&[vec![1, 2, 3, 4]], 4).unwrap();
    let m = &mut engine.metrics;
    assert_eq!(m.tokens_out, 4);
    assert!(m.decode_wall.count() >= 3);
    assert!(m.prefill_wall.count() == 1);
    assert!(m.decode_wall.p50_us() > 0);
    assert!(m.decode_sim.p50_us() > 0);
}
