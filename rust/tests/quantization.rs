//! Gates for the INT8 quantized hot path (DESIGN.md §11):
//!
//! * accuracy — int8 logits stay within a bounded relative error of
//!   the f32 logits (tight on `tiny`, looser on the 12-layer `small`
//!   preset where quantization error accumulates);
//! * determinism — greedy decode through the full distributed engine
//!   is bit-identical across world sizes {1, 2, 4} at
//!   `weight_dtype = kv_dtype = "int8"`, exactly like f32;
//! * configuration — the dtype knobs ride the same TOML the launch
//!   coordinator ships to workers, and unknown dtype strings are
//!   rejected loudly;
//! * memory — the measured resident bytes the engine aggregates from
//!   rank Ready replies actually shrink.

use xeonserve::backend::reference::ReferenceBackend;
use xeonserve::backend::{ExecBackend, StepCtx};
use xeonserve::config::{BackendKind, Dtype, EngineConfig, ModelPreset, WeightSource};
use xeonserve::engine::Engine;

fn cfg(world: usize, batch: usize, wd: Dtype, kd: Dtype) -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch,
        weight_dtype: wd,
        kv_dtype: kd,
        weights: WeightSource::Synthetic { seed: 1234 },
        ..Default::default()
    }
}

/// Straight-line forward against the backend alone (world 1, rank 0):
/// prefill `plen` tokens in a `plen`-row bucket, decode `n_dec` greedy
/// steps, return each step's full logit vector.
fn greedy_logits(c: &EngineConfig, preset: &ModelPreset, plen: usize,
                 n_dec: usize) -> Vec<Vec<f32>> {
    let mut be = ReferenceBackend::new(c, 0, preset).unwrap();
    let (h, vocab) = (preset.hidden, preset.vocab);
    let segs = c.variant.syncs_per_layer();
    let prompt: Vec<i32> =
        (0..plen).map(|i| ((i * 31 + 7) % 150) as i32 + 1).collect();

    let ctx = StepCtx::Prefill { lane: 0, bucket: plen, length: plen, offset: 0 };
    let mut x = vec![0.0f32; plen * h];
    let mut y = vec![0.0f32; plen * h];
    be.embed(&ctx, &prompt, &mut x).unwrap();
    for li in 0..preset.n_layers {
        for seg in 0..segs {
            be.layer_partial(&ctx, li, seg, &x, &mut y).unwrap();
            for (xi, yi) in x.iter_mut().zip(&y) {
                *xi += *yi;
            }
        }
    }
    let head: Vec<f32> = x[(plen - 1) * h..plen * h].to_vec();
    let mut logits = vec![0.0f32; vocab];
    be.lm_head(&head, &mut logits).unwrap();

    let argmax = |l: &[f32]| -> i32 {
        let mut best = 0usize;
        for (i, &v) in l.iter().enumerate() {
            if v > l[best] {
                best = i;
            }
        }
        best as i32
    };

    let mut out = vec![logits.clone()];
    let mut tok = argmax(&logits);
    let mut pos = plen;
    let mut xd = vec![0.0f32; h];
    let mut yd = vec![0.0f32; h];
    for _ in 0..n_dec {
        let positions = [pos as i32];
        let ctx = StepCtx::Decode { positions: &positions };
        be.embed(&ctx, &[tok], &mut xd).unwrap();
        for li in 0..preset.n_layers {
            for seg in 0..segs {
                be.layer_partial(&ctx, li, seg, &xd, &mut yd).unwrap();
                for (xi, yi) in xd.iter_mut().zip(&yd) {
                    *xi += *yi;
                }
            }
        }
        be.lm_head(&xd, &mut logits).unwrap();
        out.push(logits.clone());
        tok = argmax(&logits);
        pos += 1;
    }
    out
}

/// Relative L2 error between two logit trajectories.
fn rel_l2(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        for (&xa, &yb) in x.iter().zip(y) {
            num += ((xa - yb) as f64).powi(2);
            den += (xa as f64).powi(2);
        }
    }
    (num / den.max(1e-30)).sqrt()
}

/// Tolerance gate on `tiny`: 2 layers, so quantization error stays
/// small — and the int8 run must not be bit-identical to f32 (that
/// would mean the quantized path silently fell back).
#[test]
fn int8_logits_close_to_f32_on_tiny() {
    let preset = ModelPreset::builtin("tiny").unwrap();
    let f = greedy_logits(&cfg(1, 1, Dtype::F32, Dtype::F32), &preset,
                          8, 4);
    let q = greedy_logits(&cfg(1, 1, Dtype::Int8, Dtype::Int8), &preset,
                          8, 4);
    let err = rel_l2(&f, &q);
    assert!(err < 0.15, "tiny int8 rel L2 error {err} too large");
    assert!(err > 0.0, "int8 identical to f32 — path not engaged");
}

/// Tolerance gate on the `small` preset (12 layers, hidden 768, vocab
/// 32000) — the satellite's accuracy check at realistic widths.  Short
/// trajectory (prefill 2, one decode) keeps the debug-build cost sane;
/// the bound is loose because error compounds across 12 layers.
#[test]
fn int8_logits_close_to_f32_on_small() {
    let preset = ModelPreset::builtin("small").unwrap();
    let mut c_f = cfg(1, 1, Dtype::F32, Dtype::F32);
    c_f.model = "small".into();
    let mut c_q = cfg(1, 1, Dtype::Int8, Dtype::Int8);
    c_q.model = "small".into();
    let f = greedy_logits(&c_f, &preset, 2, 1);
    let q = greedy_logits(&c_q, &preset, 2, 1);
    let err = rel_l2(&f, &q);
    assert!(err < 0.35, "small int8 rel L2 error {err} too large");
    assert!(err > 0.0, "int8 identical to f32 — path not engaged");
}

fn engine_tokens(world: usize, wd: Dtype, kd: Dtype) -> Vec<Vec<i32>> {
    let mut engine = Engine::new(cfg(world, 2, wd, kd)).unwrap();
    engine
        .generate(&[vec![11, 22, 33, 44], vec![5, 5, 5]], 6)
        .unwrap()
}

/// The §11 acceptance gate: greedy decode at int8 weights + int8 KV is
/// bit-identical across tensor-parallel worlds {1, 2, 4} through the
/// full distributed engine — quantizing before sharding keeps the
/// world-invariance the f32 path pins in `engine_integration`.
#[test]
fn int8_greedy_decode_is_world_invariant() {
    let golden = engine_tokens(1, Dtype::Int8, Dtype::Int8);
    assert!(!golden.is_empty() && !golden[0].is_empty());
    for world in [2usize, 4] {
        let got = engine_tokens(world, Dtype::Int8, Dtype::Int8);
        assert_eq!(got, golden,
                   "int8 greedy decode diverged at world={world}");
    }
}

/// Mixed-dtype combos must also be world-invariant (each knob is
/// independent).
#[test]
fn mixed_dtype_greedy_decode_is_world_invariant() {
    for (wd, kd) in [(Dtype::Int8, Dtype::F32), (Dtype::F32, Dtype::Int8)]
    {
        let golden = engine_tokens(1, wd, kd);
        let got = engine_tokens(2, wd, kd);
        assert_eq!(got, golden,
                   "weight={wd:?} kv={kd:?} diverged at world=2");
    }
}

/// The dtype knobs ride the coordinator→worker TOML distribution
/// (DESIGN.md §8): serialize → parse must preserve them, and the
/// parsed config must drive a working int8 backend.
#[test]
fn dtypes_survive_launch_config_distribution() {
    let c = cfg(2, 1, Dtype::Int8, Dtype::Int8);
    let shipped = c.to_toml_string();
    assert!(shipped.contains("weight_dtype = \"int8\""));
    assert!(shipped.contains("kv_dtype = \"int8\""));
    let back = EngineConfig::from_toml_str(&shipped).unwrap();
    assert_eq!(back.weight_dtype, Dtype::Int8);
    assert_eq!(back.kv_dtype, Dtype::Int8);

    let preset = ModelPreset::builtin("tiny").unwrap();
    let be = ReferenceBackend::new(&back, 0, &preset).unwrap();
    let mem = be.mem_usage();
    assert!(mem.weight_bytes > 0 && mem.kv_bytes > 0);
}

/// Unknown dtype strings in a shipped config are a clean parse error —
/// a worker must never fall back to f32 silently.
#[test]
fn unknown_dtype_strings_rejected() {
    for toml in ["weight_dtype = \"int4\"", "kv_dtype = \"bf16\"",
                 "weight_dtype = \"Int8\""] {
        let r = EngineConfig::from_toml_str(toml);
        assert!(r.is_err(), "{toml:?} must be rejected");
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("dtype"), "unhelpful error: {msg}");
    }
}

/// The engine aggregates per-rank Ready footprints; int8 must shrink
/// the deployment total.  The KV ratio is (hd + 4)/(4·hd) — ~0.26 at
/// head_dim 96, but 0.375 on `tiny` (head_dim 8, scale overhead
/// proportionally large) — so the bound here is <½, not <⅓.
#[test]
fn engine_mem_usage_shrinks_at_int8() {
    let f = Engine::new(cfg(2, 2, Dtype::F32, Dtype::F32))
        .unwrap()
        .mem_usage();
    let q = Engine::new(cfg(2, 2, Dtype::Int8, Dtype::Int8))
        .unwrap()
        .mem_usage();
    assert!(f.weight_bytes > 0 && f.kv_bytes > 0);
    assert!(q.weight_bytes < f.weight_bytes,
            "int8 weights {} !< f32 {}", q.weight_bytes, f.weight_bytes);
    assert!(q.kv_bytes * 2 < f.kv_bytes,
            "int8 kv {} not well under half of f32 {}", q.kv_bytes,
            f.kv_bytes);
}
