//! End-to-end server test: boot the TCP endpoint on an ephemeral port,
//! drive it with concurrent client connections, and check the JSON
//! protocol round-trips.  Requires `make artifacts` (tiny preset).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use xeonserve::config::EngineConfig;
use xeonserve::util::Json;

#[macro_use]
#[path = "common/mod.rs"]
mod common;

fn wait_for_port(addr: &str) -> TcpStream {
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("server on {addr} never came up");
}

#[test]
fn serve_roundtrip_and_concurrent_clients() {
    require_artifacts!();
    let addr = "127.0.0.1:47811";
    let cfg = EngineConfig {
        model: "tiny".into(),
        world: 2,
        batch: 2,
        ..Default::default()
    };
    std::thread::spawn(move || {
        // runs forever; the test process exits when done
        let _ = xeonserve::server::serve(cfg, addr);
    });

    // client 1: simple request
    let mut s1 = wait_for_port(addr);
    s1.write_all(b"{\"prompt\": \"hello\", \"max_new_tokens\": 4}\n")
        .unwrap();
    let mut line = String::new();
    BufReader::new(s1.try_clone().unwrap()).read_line(&mut line).unwrap();
    let j = Json::parse(&line).expect("valid json response");
    assert!(j.get("error").is_none(), "unexpected error: {line}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert!(j.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // two concurrent clients (exercises the batcher)
    let h: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let mut s = wait_for_port("127.0.0.1:47811");
                let req = format!(
                    "{{\"prompt\": \"client {i}\", \"max_new_tokens\": 3}}\n"
                );
                s.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                BufReader::new(s).read_line(&mut line).unwrap();
                let j = Json::parse(&line).unwrap();
                assert!(j.get("error").is_none(), "{line}");
                j.get("tokens").unwrap().as_arr().unwrap().len()
            })
        })
        .collect();
    for t in h {
        assert_eq!(t.join().unwrap(), 3);
    }

    // malformed request gets an error object, not a hangup
    let mut s2 = wait_for_port(addr);
    s2.write_all(b"this is not json\n").unwrap();
    let mut line = String::new();
    BufReader::new(s2).read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("error").is_some());
}
