//! End-to-end server tests: boot the TCP endpoint on a local port,
//! drive it with concurrent client connections, and check the JSON
//! protocol round-trips.
//!
//! Hermetic: runs on the pure-Rust reference backend (tiny preset) —
//! no artifacts required.  The `xla_artifacts` module re-runs the
//! round-trip against the PJRT backend under `--features xla`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use xeonserve::config::{BackendKind, EngineConfig};
use xeonserve::util::Json;

#[macro_use]
#[path = "common/mod.rs"]
mod common;

fn wait_for_port(addr: &str) -> TcpStream {
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("server on {addr} never came up");
}

fn request_line(stream: &mut TcpStream, line: &str) -> Json {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut out = String::new();
    BufReader::new(stream.try_clone().unwrap())
        .read_line(&mut out)
        .unwrap();
    Json::parse(&out)
        .unwrap_or_else(|e| panic!("invalid json response {out:?}: {e}"))
}

#[test]
fn serve_roundtrip_and_concurrent_clients() {
    let addr = "127.0.0.1:47811";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 2,
        batch: 2,
        ..Default::default()
    };
    std::thread::spawn(move || {
        // runs forever; the test process exits when done
        let _ = xeonserve::server::serve(cfg, addr);
    });

    // client 1: simple request
    let mut s1 = wait_for_port(addr);
    let j = request_line(&mut s1,
                         r#"{"prompt": "hello", "max_new_tokens": 4}"#);
    assert!(j.get("error").is_none(), "unexpected error: {j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert!(j.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // two concurrent clients (exercises the batcher)
    let h: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let mut s = wait_for_port("127.0.0.1:47811");
                let j = request_line(
                    &mut s,
                    &format!(
                        "{{\"prompt\": \"client {i}\", \
                         \"max_new_tokens\": 3}}"
                    ),
                );
                assert!(j.get("error").is_none(), "{j:?}");
                j.get("tokens").unwrap().as_arr().unwrap().len()
            })
        })
        .collect();
    for t in h {
        assert_eq!(t.join().unwrap(), 3);
    }

    // malformed request gets an error object, not a hangup
    let mut s2 = wait_for_port(addr);
    let j = request_line(&mut s2, "this is not json");
    assert!(j.get("error").is_some());

    // invalid max_new_tokens is rejected with a clean JSON error line
    // (it used to be silently coerced to the 16-token default)
    let mut s3 = wait_for_port(addr);
    let j = request_line(
        &mut s3, r#"{"prompt": "x", "max_new_tokens": "five"}"#);
    let err = j.get("error").expect("expected an error object").as_str()
        .unwrap().to_string();
    assert!(err.contains("max_new_tokens"),
            "error should name the bad field: {err}");
    // ...and the connection stays usable afterwards
    let j = request_line(&mut s3, r#"{"prompt": "y", "max_new_tokens": 2}"#);
    assert!(j.get("error").is_none(), "{j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn multi_line_session_reuses_connection() {
    let addr = "127.0.0.1:47813";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 1,
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });
    let mut s = wait_for_port(addr);
    for i in 0..3 {
        let j = request_line(
            &mut s,
            &format!("{{\"prompt\": \"turn {i}\", \"max_new_tokens\": 2}}"),
        );
        assert!(j.get("error").is_none(), "turn {i}: {j:?}");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
}

/// Artifact-gated variant: the same round-trip on the PJRT backend.
#[cfg(feature = "xla")]
mod xla_artifacts {
    use super::*;

    #[test]
    fn serve_roundtrip_xla() {
        require_artifacts!();
        let addr = "127.0.0.1:47815";
        let cfg = EngineConfig {
            model: "tiny".into(),
            backend: BackendKind::Xla,
            world: 2,
            batch: 2,
            ..Default::default()
        };
        std::thread::spawn(move || {
            let _ = xeonserve::server::serve(cfg, addr);
        });
        let mut s = wait_for_port(addr);
        let j = request_line(
            &mut s, r#"{"prompt": "hello", "max_new_tokens": 4}"#);
        assert!(j.get("error").is_none(), "{j:?}");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    }
}
