//! End-to-end server tests: boot the TCP endpoint on a local port,
//! drive it with concurrent client connections, and check the JSON
//! protocol round-trips.
//!
//! Hermetic: runs on the pure-Rust reference backend (tiny preset) —
//! no artifacts required.  The `xla_artifacts` module re-runs the
//! round-trip against the PJRT backend under `--features xla`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use xeonserve::config::{BackendKind, EngineConfig};
use xeonserve::util::Json;

#[macro_use]
#[path = "common/mod.rs"]
mod common;

fn wait_for_port(addr: &str) -> TcpStream {
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("server on {addr} never came up");
}

fn request_line(stream: &mut TcpStream, line: &str) -> Json {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut out = String::new();
    BufReader::new(stream.try_clone().unwrap())
        .read_line(&mut out)
        .unwrap();
    Json::parse(&out)
        .unwrap_or_else(|e| panic!("invalid json response {out:?}: {e}"))
}

#[test]
fn serve_roundtrip_and_concurrent_clients() {
    let addr = "127.0.0.1:47811";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 2,
        batch: 2,
        ..Default::default()
    };
    std::thread::spawn(move || {
        // runs forever; the test process exits when done
        let _ = xeonserve::server::serve(cfg, addr);
    });

    // client 1: simple request
    let mut s1 = wait_for_port(addr);
    let j = request_line(&mut s1,
                         r#"{"prompt": "hello", "max_new_tokens": 4}"#);
    assert!(j.get("error").is_none(), "unexpected error: {j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert!(j.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // two concurrent clients (exercises the batcher)
    let h: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let mut s = wait_for_port("127.0.0.1:47811");
                let j = request_line(
                    &mut s,
                    &format!(
                        "{{\"prompt\": \"client {i}\", \
                         \"max_new_tokens\": 3}}"
                    ),
                );
                assert!(j.get("error").is_none(), "{j:?}");
                j.get("tokens").unwrap().as_arr().unwrap().len()
            })
        })
        .collect();
    for t in h {
        assert_eq!(t.join().unwrap(), 3);
    }

    // malformed request gets an error object, not a hangup
    let mut s2 = wait_for_port(addr);
    let j = request_line(&mut s2, "this is not json");
    assert!(j.get("error").is_some());

    // invalid max_new_tokens is rejected with a clean JSON error line
    // (it used to be silently coerced to the 16-token default)
    let mut s3 = wait_for_port(addr);
    let j = request_line(
        &mut s3, r#"{"prompt": "x", "max_new_tokens": "five"}"#);
    let err = j.get("error").expect("expected an error object").as_str()
        .unwrap().to_string();
    assert!(err.contains("max_new_tokens"),
            "error should name the bad field: {err}");
    // ...and the connection stays usable afterwards
    let j = request_line(&mut s3, r#"{"prompt": "y", "max_new_tokens": 2}"#);
    assert!(j.get("error").is_none(), "{j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn multi_line_session_reuses_connection() {
    let addr = "127.0.0.1:47813";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 1,
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });
    let mut s = wait_for_port(addr);
    for i in 0..3 {
        let j = request_line(
            &mut s,
            &format!("{{\"prompt\": \"turn {i}\", \"max_new_tokens\": 2}}"),
        );
        assert!(j.get("error").is_none(), "turn {i}: {j:?}");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
}

/// Streamed replies (DESIGN.md §12): one frame per token, in
/// generation order, terminated by a `done` summary carrying the full
/// text — served over chunked prefill so the streaming path and the
/// chunk rounds compose.
#[test]
fn streamed_frames_ordered_and_final_carries_full_text() {
    let addr = "127.0.0.1:47817";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 2,
        prefill_chunk: 2, // stream over chunked prefill
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });
    let mut s = wait_for_port(addr);
    s.write_all(
        b"{\"prompt\": \"stream me\", \"max_new_tokens\": 5, \
          \"stream\": true}\n")
        .unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut tokens = Vec::new();
    let done = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line)
            .unwrap_or_else(|e| panic!("bad frame {line:?}: {e}"));
        assert!(j.get("error").is_none(), "unexpected error: {j:?}");
        if j.get("done").is_some() {
            assert_eq!(j.get("done").unwrap().as_bool(), Some(true));
            break j;
        }
        // a token frame: {"id": N, "token": T}
        assert!(j.get("id").is_some(), "token frame missing id: {j:?}");
        tokens.push(j.get("token").expect("frame without token or done")
            .as_f64().unwrap() as i32);
    };
    // every token arrived before the summary, in order (≤ 5: the
    // model may greedily emit EOS early; never 0, never more)
    assert!(!tokens.is_empty() && tokens.len() <= 5, "{tokens:?}");
    let final_tokens: Vec<i32> = done.get("tokens").unwrap().as_arr()
        .unwrap().iter().map(|t| t.as_f64().unwrap() as i32).collect();
    assert_eq!(tokens, final_tokens,
               "streamed frames must match the final token list");
    let text = done.get("text").unwrap().as_str().unwrap();
    assert!(!text.is_empty(), "final frame must carry the full text");
    assert!(done.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    // the same connection still serves one-shot requests afterwards
    let j = request_line(&mut s, r#"{"prompt": "y", "max_new_tokens": 2}"#);
    assert!(j.get("error").is_none(), "{j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);

    // and "stream" is strictly typed at the wire: a non-bool is a
    // clean JSON error naming the field, not a coercion
    let j = request_line(
        &mut s, r#"{"prompt": "x", "stream": "yes"}"#);
    let err = j.get("error").expect("expected error").as_str().unwrap();
    assert!(err.contains("stream"), "error should name the field: {err}");
}

/// Cancel-on-disconnect (DESIGN.md §12): a streaming client that
/// hangs up mid-generation must not pin its batch lane — with batch 1
/// the next client's request only runs once the lane frees, and the
/// `stats` probe proves it freed by CANCELLATION, not by decoding to
/// completion for nobody: a cancelled request never increments
/// `requests_done`.  (The one-step-retirement precision is pinned at
/// the engine level in chunked_prefill.rs; this is the end-to-end
/// path through the dead-socket detection.)
#[test]
fn disconnect_mid_stream_frees_the_lane() {
    let addr = "127.0.0.1:47819";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 1, // a leaked lane would wedge every later request
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });

    // client A: start a long stream, read two frames, hang up.  The
    // tiny preset has no EOS token, so A can only retire by reaching
    // max_new (48 decode rounds) — far beyond the 1-2 rounds the
    // dead-socket detection needs.
    {
        let mut a = wait_for_port(addr);
        a.write_all(
            b"{\"prompt\": \"abandoned\", \"max_new_tokens\": 48, \
              \"stream\": true}\n")
            .unwrap();
        let mut reader = BufReader::new(a.try_clone().unwrap());
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            assert!(j.get("error").is_none(), "{j:?}");
            assert!(j.get("token").is_some(), "expected a frame: {j:?}");
        }
        // drop both halves: the server's next frame write fails and
        // the engine cancels the request
    }

    // client B: must be admitted onto the (freed) single lane and
    // complete — and the server must keep serving streams after the
    // cancellation
    let mut b = wait_for_port(addr);
    let j = request_line(&mut b,
                         r#"{"prompt": "next", "max_new_tokens": 3}"#);
    assert!(j.get("error").is_none(), "lane never freed? {j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);

    // the probe that distinguishes cancellation from natural
    // retirement: only B may count as done; if A had decoded to
    // max_new instead of being cancelled, requests_done would be 2
    let j = request_line(&mut b, r#"{"stats": true}"#);
    let stats = j.get("stats").expect("stats reply");
    assert_eq!(stats.get("requests_done").unwrap().as_u64(), Some(1),
               "abandoned stream was retired, not cancelled: {j:?}");
    assert_eq!(stats.get("free_lanes").unwrap().as_u64(), Some(1),
               "cancelled stream leaked its lane: {j:?}");
    assert_eq!(stats.get("free_pages").unwrap().as_u64(),
               stats.get("total_pages").unwrap().as_u64(),
               "cancelled stream leaked KV pages: {j:?}");

    let mut c = wait_for_port(addr);
    c.write_all(
        b"{\"prompt\": \"again\", \"max_new_tokens\": 2, \
          \"stream\": true}\n")
        .unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    let mut frames = 0;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "{j:?}");
        frames += 1;
        if j.get("done").is_some() {
            break;
        }
    }
    assert!((2..=3).contains(&frames),
            "expected token frame(s) + done, got {frames}");
}

/// Explicit `{"cancel": id}` control surface (DESIGN.md §13): a
/// second connection cancels a live stream by the id its frames
/// carry; the stream gets a clean error frame; and the surface is
/// IDEMPOTENT — re-cancelling the same id (or a never-issued one)
/// answers a JSON error line naming the id, never a wedge, and the
/// connection keeps serving.
#[test]
fn explicit_cancel_is_idempotent() {
    let addr = "127.0.0.1:47821";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 1,
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });

    // client A: a stream far too long to finish on its own (no EOS in
    // the tiny preset); its first frame reveals the engine id
    let mut a = wait_for_port(addr);
    a.write_all(
        b"{\"prompt\": \"cancel me\", \"max_new_tokens\": 48, \
          \"stream\": true}\n")
        .unwrap();
    let mut a_reader = BufReader::new(a.try_clone().unwrap());
    let mut line = String::new();
    a_reader.read_line(&mut line).unwrap();
    let frame = Json::parse(&line).unwrap();
    let id = frame.get("id").expect("frame carries the request id")
        .as_u64().unwrap();

    // client B cancels it
    let mut b = wait_for_port(addr);
    let j = request_line(&mut b, &format!("{{\"cancel\": {id}}}"));
    assert_eq!(j.get("cancelled").and_then(Json::as_u64), Some(id),
               "first cancel must ack: {j:?}");

    // the stream is told, rather than silently starved (token frames
    // already in flight when the cancel landed may arrive first)
    let mut saw_cancel_frame = false;
    for _ in 0..60 {
        let mut line = String::new();
        a_reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("done").is_none(),
                "cancelled stream must not complete: {j:?}");
        if j.get("error").is_some() {
            assert_eq!(j.get("error").and_then(Json::as_str),
                       Some("cancelled"), "{j:?}");
            saw_cancel_frame = true;
            break;
        }
        assert!(j.get("token").is_some(), "unexpected frame: {j:?}");
    }
    assert!(saw_cancel_frame,
            "stream should see the cancellation error frame");

    // double-cancel: a clean error naming the id, not a wedge
    let j = request_line(&mut b, &format!("{{\"cancel\": {id}}}"));
    let err = j.get("error").expect("second cancel must error")
        .as_str().unwrap();
    assert!(err.contains("cancel") && err.contains(&id.to_string()),
            "error should name the operation and id: {err}");

    // cancelling an id that never existed is the same clean shape
    let j = request_line(&mut b, r#"{"cancel": 999999}"#);
    assert!(j.get("error").is_some(), "{j:?}");

    // the lane freed by the cancel, and the connection still serves
    let j = request_line(&mut b,
                         r#"{"prompt": "after", "max_new_tokens": 2}"#);
    assert!(j.get("error").is_none(), "lane never freed? {j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);

    // the stats probe confirms cancellation, not retirement
    let j = request_line(&mut b, r#"{"stats": true}"#);
    let stats = j.get("stats").expect("stats reply");
    assert_eq!(stats.get("requests_done").unwrap().as_u64(), Some(1),
               "cancelled request must not count as done: {j:?}");
    assert_eq!(stats.get("free_lanes").unwrap().as_u64(), Some(1),
               "cancelled request leaked its lane: {j:?}");
}

/// Hostile client #1 (DESIGN.md §16): a slowloris writer dripping a
/// request one fragment at a time, never finishing its line.  The
/// event loop must keep serving everyone else while the fragments
/// trickle in — the partial line just buffers — and when the dripper
/// finally sends its newline, the request parses and serves normally.
/// A dripper that hangs up mid-line costs nothing.
#[test]
fn slowloris_partial_lines_never_wedge_the_server() {
    let addr = "127.0.0.1:47823";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 1, // one lane: a wedge would starve every later client
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });

    let mut dripper = wait_for_port(addr);
    let fragments: &[&[u8]] =
        &[b"{\"prompt\"", b": \"drip\", ", b"\"max_new", b"_tokens\": 2"];
    for frag in fragments {
        dripper.write_all(frag).unwrap();
        dripper.flush().unwrap();
        // while the fragment sits unterminated, a well-behaved client
        // must be served end to end — the slow writer holds no lock,
        // no thread, and no lane
        let mut fast = wait_for_port(addr);
        let j = request_line(&mut fast,
                             r#"{"prompt": "fast", "max_new_tokens": 2}"#);
        assert!(j.get("error").is_none(),
                "slowloris wedged the server: {j:?}");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
    // the dripper completes its line: a normal, valid request
    let j = request_line(&mut dripper, "}");
    assert!(j.get("error").is_none(), "{j:?}");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);

    // a second dripper abandons mid-line; the server shrugs it off
    {
        let mut quitter = wait_for_port(addr);
        quitter.write_all(b"{\"prompt\": \"never finis").unwrap();
        quitter.flush().unwrap();
    }
    let mut after = wait_for_port(addr);
    let j = request_line(&mut after,
                         r#"{"prompt": "after", "max_new_tokens": 2}"#);
    assert!(j.get("error").is_none(), "{j:?}");
}

/// Hostile client #2 (DESIGN.md §16): a single line far past the
/// 64 KiB bound.  The reader discards it at the bound — memory never
/// grows with the line — answers one clean `{"error": ...}` naming
/// the limit, and the connection keeps serving; a second oversized
/// line behaves identically (the discard state machine resets).
#[test]
fn oversized_line_gets_clean_error_and_connection_survives() {
    let addr = "127.0.0.1:47825";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 1,
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });

    let mut s = wait_for_port(addr);
    let mut reader = BufReader::new(s.try_clone().unwrap());
    for round in 0..2 {
        // 80 000 junk bytes, one line: crosses the 65 536-byte bound
        let mut big = vec![b'x'; 80_000];
        big.push(b'\n');
        s.write_all(&big).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line)
            .unwrap_or_else(|e| panic!("round {round}: non-JSON reply \
                                        {line:?}: {e}"));
        let err = j.get("error").expect("expected an error line")
            .as_str().unwrap();
        assert!(err.contains("exceeds") && err.contains("bytes"),
                "round {round}: error should name the bound: {err}");

        // the same connection still serves real requests
        s.write_all(b"{\"prompt\": \"ok\", \"max_new_tokens\": 2}\n")
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "round {round}: {j:?}");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
}

/// Hostile client #3 (DESIGN.md §16): stats probes and cancels —
/// valid, unknown, and repeated — hammered from a control connection
/// while a storm of streams is in flight.  Every probe answers one
/// clean JSON line of the right shape, every stream still finishes
/// bit-normally, and a mid-storm cancel of a live stream lands.
#[test]
fn interleaved_stats_and_cancel_during_a_storm_stay_clean() {
    let addr = "127.0.0.1:47827";
    let cfg = EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world: 1,
        batch: 2,
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = xeonserve::server::serve(cfg, addr);
    });
    wait_for_port(addr);

    // the storm: 6 streaming clients decode concurrently
    let streams: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let mut s = wait_for_port("127.0.0.1:47827");
                s.write_all(format!(
                    "{{\"prompt\": \"storm {i}\", \"max_new_tokens\": 6, \
                     \"stream\": true}}\n").as_bytes()).unwrap();
                let mut reader = BufReader::new(s.try_clone().unwrap());
                let mut tokens = 0usize;
                loop {
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let j = Json::parse(&line).unwrap_or_else(
                        |e| panic!("client {i}: bad frame {line:?}: {e}"));
                    assert!(j.get("error").is_none(),
                            "client {i}: {line}");
                    if j.get("done").is_some() {
                        break;
                    }
                    tokens += 1;
                }
                tokens
            })
        })
        .collect();

    // the hostile control connection: stats and junk cancels, rapid
    // fire, while the storm decodes
    let mut ctl = wait_for_port(addr);
    for i in 0..20 {
        let j = request_line(&mut ctl, r#"{"stats": true}"#);
        let stats = j.get("stats")
            .unwrap_or_else(|| panic!("probe {i}: not a stats reply: \
                                       {j:?}"));
        assert!(stats.get("free_lanes").unwrap().as_u64().is_some());
        assert!(stats.get("frames_sent").unwrap().as_u64().is_some(),
                "stats must carry the serving counters: {j:?}");
        // a cancel of a never-issued id: clean error, never a wedge
        let j = request_line(&mut ctl, r#"{"cancel": 999999}"#);
        let err = j.get("error").expect("junk cancel must error")
            .as_str().unwrap();
        assert!(err.contains("cancel"), "{err}");
    }

    // every stream survived the probe barrage
    for (i, h) in streams.into_iter().enumerate() {
        let tokens = h.join().unwrap();
        assert!((1..=6).contains(&tokens),
                "client {i}: {tokens} token frames");
    }

    // a cancel aimed at a live stream still lands mid-storm: start
    // one more long stream, cancel it by id from the control conn
    let mut v = wait_for_port(addr);
    v.write_all(b"{\"prompt\": \"victim\", \"max_new_tokens\": 48, \
                   \"stream\": true}\n").unwrap();
    let mut v_reader = BufReader::new(v.try_clone().unwrap());
    let mut line = String::new();
    v_reader.read_line(&mut line).unwrap();
    let id = Json::parse(&line).unwrap().get("id").unwrap()
        .as_u64().unwrap();
    let j = request_line(&mut ctl, &format!("{{\"cancel\": {id}}}"));
    assert_eq!(j.get("cancelled").and_then(Json::as_u64), Some(id));
    loop {
        let mut line = String::new();
        v_reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("done").is_none(),
                "cancelled stream must not complete");
        if j.get("error").is_some() {
            assert_eq!(j.get("error").and_then(Json::as_str),
                       Some("cancelled"));
            break;
        }
    }
}

/// Artifact-gated variant: the same round-trip on the PJRT backend.
#[cfg(feature = "xla")]
mod xla_artifacts {
    use super::*;

    #[test]
    fn serve_roundtrip_xla() {
        require_artifacts!();
        let addr = "127.0.0.1:47815";
        let cfg = EngineConfig {
            model: "tiny".into(),
            backend: BackendKind::Xla,
            world: 2,
            batch: 2,
            ..Default::default()
        };
        std::thread::spawn(move || {
            let _ = xeonserve::server::serve(cfg, addr);
        });
        let mut s = wait_for_port(addr);
        let j = request_line(
            &mut s, r#"{"prompt": "hello", "max_new_tokens": 4}"#);
        assert!(j.get("error").is_none(), "{j:?}");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    }
}
