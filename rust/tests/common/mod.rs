//! Shared helpers for the integration-test crates (pulled in via
//! `#[macro_use] #[path = "common/mod.rs"] mod common;`).

/// Artifacts are a build product (`make artifacts`), not checked in;
/// skip (loudly) instead of failing when they are absent so the
/// artifact-free test tiers stay green.  CI always builds them first.
macro_rules! require_artifacts {
    () => {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("SKIP: artifacts/ not built — run `make artifacts`");
            return;
        }
    };
}
