//! Shared helpers for the integration-test crates (pulled in via
//! `#[macro_use] #[path = "common/mod.rs"] mod common;`).

/// Artifacts are a build product (`make artifacts`), not checked in;
/// skip (loudly) instead of failing when they are absent so the
/// artifact-free test tiers stay green.  CI's artifact job builds them
/// first, so the XLA-gated suites still gate there.  (Unused in the
/// hermetic build, where every integration test runs for real on the
/// reference backend.)
#[allow(unused_macros)]
macro_rules! require_artifacts {
    () => {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("SKIP: artifacts/ not built — run `make artifacts`");
            return;
        }
    };
}
