//! Elastic-worlds integration suite (DESIGN.md §17): a dead worker is
//! a non-event.  When a rank dies mid-decode the elastic engine tears
//! the fleet down, brings replacements up, re-shards the weights from
//! the world-invariant quant grid, and replays every in-flight lane —
//! so a streaming client sees a stall, never an error, and the
//! continuation is BIT-IDENTICAL to an uninterrupted run.  The same
//! quiesce → rebuild → restore path driven deliberately is a planned
//! live reshard, pinned here by post-reshard greedy tokens equal to a
//! fresh launch at the new world size.  Both claims are checked
//! across worlds {2, 4} × dtypes {f32, int8} × both admission
//! schedulers, together with lane/page/refcount conservation after
//! every rebuild.

use std::collections::HashMap;

use xeonserve::config::{BackendKind, Dtype, EngineConfig,
                        SchedulerKind, WeightSource};
use xeonserve::engine::elastic::{ChaosFactory, ElasticEngine};
use xeonserve::engine::Engine;

fn cfg(world: usize, dtype: Dtype, sched: SchedulerKind)
       -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch: 2,
        weight_dtype: dtype,
        kv_dtype: dtype,
        scheduler: sched,
        weights: WeightSource::Synthetic { seed: 0xC0FFEE },
        ..Default::default()
    }
}

/// Short enough that the fcfs bucket path (tiny's single 16-token
/// bucket) never truncates, so every scheduler serves the same
/// effective prompt.
fn prompts() -> Vec<Vec<i32>> {
    vec![
        vec![11, 23, 5, 42, 7],
        vec![3, 1, 4, 1, 5, 9, 2, 6],
    ]
}

/// Drive an elastic engine to completion by single steps, draining
/// the streaming feed after every step — the per-token view a server
/// front relays to its clients.  Returns (per-request streams,
/// completions).
fn drive(eng: &mut ElasticEngine)
         -> (HashMap<u64, Vec<i32>>, Vec<xeonserve::engine::Completion>) {
    let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut done = Vec::new();
    while eng.has_work() {
        done.extend(eng.step().expect(
            "a rank death must stall the stream, never error it"));
        for (id, tok) in eng.take_new_tokens() {
            streams.entry(id).or_default().push(tok);
        }
    }
    done.sort_by_key(|c| c.request_id);
    (streams, done)
}

/// Nothing may leak across a rebuild: all lanes free, every page
/// either free or pinned by a published shared prefix, and no
/// refcounted segment left behind on schedulers that never share.
fn assert_conserved(eng: &ElasticEngine) {
    assert_eq!(eng.free_lanes(), 2, "lanes leaked across rebuild");
    assert_eq!(eng.free_pages(),
               eng.total_pages() - eng.shared_pages(),
               "pages leaked across rebuild");
    if eng.config().scheduler == SchedulerKind::Fcfs {
        assert_eq!(eng.shared_pages(), 0,
                   "fcfs never publishes prefixes");
    }
}

/// The tentpole matrix: kill a worker mid-stream in every
/// (world × dtype × scheduler) cell; the full streams and completions
/// must come out bit-identical to an uninterrupted run, with
/// conserved resources afterwards.
#[test]
fn kill_mid_stream_is_bit_identical_across_worlds_dtypes_schedulers() {
    for world in [2usize, 4] {
        for dtype in [Dtype::F32, Dtype::Int8] {
            for sched in [SchedulerKind::Fcfs,
                          SchedulerKind::Continuous] {
                let label = format!("w{world} {dtype:?} {sched:?}");
                let c = cfg(world, dtype, sched);
                let expected = Engine::new(c.clone())
                    .unwrap()
                    .generate(&prompts(), 8)
                    .unwrap();

                // fuse 6: past both prefills, several tokens into
                // decode — the lanes hold live KV when the rank dies
                let factory = ChaosFactory {
                    victim: world - 1,
                    fuse: 6,
                    kills: 1,
                };
                let mut eng =
                    ElasticEngine::new(c, Box::new(factory)).unwrap();
                let ids: Vec<u64> = prompts()
                    .iter()
                    .map(|p| eng.enqueue(p.clone(), 8))
                    .collect();
                let (streams, done) = drive(&mut eng);

                assert_eq!(eng.recoveries(), 1,
                           "{label}: the chaos fuse must blow");
                assert_eq!(eng.tokens_lost(), 0, "{label}");
                assert!(eng.last_recovery_stall_ms() < 60_000,
                        "{label}: implausible stall");
                for (i, id) in ids.iter().enumerate() {
                    let c = done
                        .iter()
                        .find(|c| c.request_id == *id)
                        .unwrap_or_else(|| panic!(
                            "{label}: request {id} never completed"));
                    assert_eq!(c.tokens, expected[i],
                               "{label}: completion {id} diverged");
                    assert_eq!(streams[id], expected[i],
                               "{label}: stream {id} diverged");
                }
                assert_conserved(&eng);
            }
        }
    }
}

/// The kill with every KV-layout feature live at once: continuous
/// admission, chunked prefill, and a published shared prefix spanning
/// a full page — the hardest replay shape (prompts longer than the
/// fcfs bucket, KV rows split across private and shared segments).
#[test]
fn kill_under_chunked_continuous_shared_prefix() {
    for dtype in [Dtype::F32, Dtype::Int8] {
        let mut c = cfg(4, dtype, SchedulerKind::Continuous);
        c.prefill_chunk = 4;
        let shared: Vec<Vec<i32>> = vec![
            (0..20).collect::<Vec<i32>>(),
            (0..20).chain([99, 98]).collect(),
        ];
        let expected = Engine::new(c.clone())
            .unwrap()
            .generate(&shared, 6)
            .unwrap();

        let factory = ChaosFactory { victim: 0, fuse: 12, kills: 1 };
        let mut eng = ElasticEngine::new(c, Box::new(factory)).unwrap();
        let got = eng.generate(&shared, 6).unwrap();
        assert_eq!(eng.recoveries(), 1, "{dtype:?}: fuse must blow");
        assert_eq!(got, expected, "{dtype:?}: streams diverged");
        assert_conserved(&eng);
    }
}

/// Planned live reshard 4 → 2 → 4 mid-stream: every continuation
/// segment must be bit-identical to a fresh launch at that world size
/// (the world-invariance argument — same quant grid, same logits, so
/// one fresh-launch reference pins all three segments at once).
#[test]
fn planned_reshard_4_2_4_matches_fresh_launch() {
    for dtype in [Dtype::F32, Dtype::Int8] {
        let fresh2 = Engine::new(cfg(2, dtype, SchedulerKind::Fcfs))
            .unwrap()
            .generate(&prompts(), 10)
            .unwrap();
        let fresh4 = Engine::new(cfg(4, dtype, SchedulerKind::Fcfs))
            .unwrap()
            .generate(&prompts(), 10)
            .unwrap();
        assert_eq!(fresh2, fresh4,
                   "{dtype:?}: world invariance precondition");

        let mut eng = ElasticEngine::new_inproc(
            cfg(4, dtype, SchedulerKind::Fcfs)).unwrap();
        let ids: Vec<u64> = prompts()
            .iter()
            .map(|p| eng.enqueue(p.clone(), 10))
            .collect();
        let mut done = Vec::new();
        for _ in 0..3 {
            done.extend(eng.step().unwrap());
        }
        eng.resize(2).unwrap();
        assert_eq!(eng.config().world, 2);
        for _ in 0..2 {
            done.extend(eng.step().unwrap());
        }
        eng.resize(4).unwrap();
        assert_eq!(eng.config().world, 4);
        done.extend(eng.run_to_completion().unwrap());
        assert_eq!(eng.resizes(), 2);

        done.sort_by_key(|c| c.request_id);
        for (i, id) in ids.iter().enumerate() {
            let c = done.iter().find(|c| c.request_id == *id).unwrap();
            assert_eq!(c.tokens, fresh2[i],
                       "{dtype:?}: request {id} diverged across \
                        reshards");
        }
        assert_conserved(&eng);
    }
}

/// A resize nobody can shard over (tiny has 8 kv heads; 3 doesn't
/// divide) is refused before any quiesce work, and the running world
/// keeps serving untouched.
#[test]
fn refused_resize_leaves_the_world_serving() {
    let mut eng = ElasticEngine::new_inproc(
        cfg(2, Dtype::F32, SchedulerKind::Fcfs)).unwrap();
    let ids: Vec<u64> = prompts()
        .iter()
        .map(|p| eng.enqueue(p.clone(), 6))
        .collect();
    let err = eng.resize(3).unwrap_err();
    assert!(format!("{err:#}").contains("resize to world 3"),
            "unexpected refusal: {err:#}");
    assert_eq!(eng.resizes(), 0);
    let done = eng.run_to_completion().unwrap();
    assert_eq!(done.len(), ids.len());
    assert_conserved(&eng);
}
