//! Cross-layer correctness gates.
//!
//! Hermetic half (always runs): the full distributed engine — rank
//! threads, §2.1a id-broadcast, per-layer allreduce, §2.1b top-k
//! gather, sampling — must reproduce, token for token, a *straight-line
//! single-rank forward pass* driven directly against the reference
//! backend with none of that machinery.  Any bug in the distributed
//! plumbing (wrong positions, cache corruption, lane mixups, reduction
//! errors) shows up as a token mismatch.
//!
//! Artifact half (`--features xla` + `make artifacts`): the engine
//! running AOT-compiled HLO segments with jax-exported weight shards
//! must reproduce the jax reference composition greedily
//! (`aot.py write_golden`), on both block variants.

use xeonserve::backend::reference::ReferenceBackend;
use xeonserve::backend::{ExecBackend, StepCtx};
use xeonserve::config::{BackendKind, EngineConfig, ModelPreset, Variant, WeightSource};
use xeonserve::engine::Engine;

#[macro_use]
#[path = "common/mod.rs"]
mod common;

fn ref_cfg(world: usize, batch: usize, variant: Variant) -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        variant,
        world,
        batch,
        weights: WeightSource::Synthetic { seed: 2024 },
        ..Default::default()
    }
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Straight-line greedy decode at world=1, driven directly against the
/// backend: no engine, no scheduler, no collectives, no sampler.
/// Mirrors the engine's documented serving policy (bucket selection,
/// truncation, max_seq stop).
fn manual_reference_greedy(variant: Variant, prompt: &[i32], n_new: usize)
                           -> Vec<i32> {
    let cfg = ref_cfg(1, 1, variant);
    let preset = ModelPreset::builtin(&cfg.model).unwrap();
    let buckets = preset.builtin_prefill_buckets();
    let (h, max_seq, vocab) = (preset.hidden, preset.max_seq, preset.vocab);
    let segs = variant.syncs_per_layer();
    let mut be = ReferenceBackend::new(&cfg, 0, &preset).unwrap();

    // engine admission policy: smallest bucket that fits, else truncate
    let bucket = buckets
        .iter()
        .copied()
        .find(|&b| b >= prompt.len())
        .unwrap_or(*buckets.last().unwrap());
    let mut p = prompt.to_vec();
    p.truncate(bucket);
    let length = p.len().max(1);
    let mut padded = p;
    padded.resize(bucket, 0);

    // prefill: at world 1 the "allreduce" of a partial is the partial
    let ctx = StepCtx::Prefill { lane: 0, bucket, length, offset: 0 };
    let mut x = vec![0.0f32; bucket * h];
    let mut y = vec![0.0f32; bucket * h];
    be.embed(&ctx, &padded, &mut x).unwrap();
    for li in 0..preset.n_layers {
        for seg in 0..segs {
            be.layer_partial(&ctx, li, seg, &x, &mut y).unwrap();
            for (xi, yi) in x.iter_mut().zip(&y) {
                *xi += *yi;
            }
        }
    }
    let head: Vec<f32> = x[(length - 1) * h..length * h].to_vec();
    let mut logits = vec![0.0f32; vocab];
    be.lm_head(&head, &mut logits).unwrap();
    let mut toks = vec![argmax(&logits)];

    // greedy decode until max_new or the KV cap
    let mut pos = length;
    let mut xd = vec![0.0f32; h];
    let mut yd = vec![0.0f32; h];
    while toks.len() < n_new.max(1) && pos < max_seq {
        let positions = [pos as i32];
        let ctx = StepCtx::Decode { positions: &positions };
        be.embed(&ctx, &[*toks.last().unwrap()], &mut xd).unwrap();
        for li in 0..preset.n_layers {
            for seg in 0..segs {
                be.layer_partial(&ctx, li, seg, &xd, &mut yd).unwrap();
                for (xi, yi) in xd.iter_mut().zip(&yd) {
                    *xi += *yi;
                }
            }
        }
        be.lm_head(&xd, &mut logits).unwrap();
        toks.push(argmax(&logits));
        pos += 1;
    }
    toks
}

fn engine_greedy(world: usize, variant: Variant, prompt: &[i32],
                 n_new: usize) -> Vec<i32> {
    let mut engine = Engine::new(ref_cfg(world, 1, variant)).unwrap();
    engine
        .generate(&[prompt.to_vec()], n_new)
        .unwrap()
        .into_iter()
        .next()
        .unwrap()
}

#[test]
fn engine_matches_straight_line_reference_parallel() {
    let prompt = [3, 1, 4, 1, 5, 9, 2, 6];
    let golden = manual_reference_greedy(Variant::Parallel, &prompt, 8);
    assert_eq!(golden.len(), 8);
    for world in [1usize, 2, 4] {
        let got = engine_greedy(world, Variant::Parallel, &prompt, 8);
        assert_eq!(got, golden, "world={world} diverged from the \
                    straight-line reference");
    }
}

#[test]
fn engine_matches_straight_line_reference_serial() {
    let prompt = [42, 17, 200, 8];
    let golden = manual_reference_greedy(Variant::Serial, &prompt, 6);
    for world in [1usize, 2, 4] {
        let got = engine_greedy(world, Variant::Serial, &prompt, 6);
        assert_eq!(got, golden, "world={world} (serial) diverged");
    }
}

#[test]
fn naive_opt_flags_match_straight_line_reference() {
    // the three paper optimizations are pure communication changes:
    // even with all of them OFF the engine must hit the same tokens
    let prompt = [7, 7, 7];
    let golden = manual_reference_greedy(Variant::Parallel, &prompt, 5);
    let mut cfg = ref_cfg(2, 1, Variant::Parallel);
    cfg.opt = xeonserve::config::OptFlags::naive();
    let mut engine = Engine::new(cfg).unwrap();
    let got = engine.generate(&[prompt.to_vec()], 5).unwrap();
    assert_eq!(got[0], golden);
}

#[test]
fn max_seq_stop_matches_straight_line_reference() {
    // a generation that runs into the KV cap must stop at the same
    // token in both drivers
    let prompt = [1i32; 10];
    let golden = manual_reference_greedy(Variant::Parallel, &prompt, 500);
    let got = engine_greedy(2, Variant::Parallel, &prompt, 500);
    assert_eq!(got, golden);
    assert_eq!(golden.len(), 64 - 10 + 1, "should fill to max_seq");
}

/// The jax↔rust golden gate, unchanged: requires `--features xla` and
/// `make artifacts` (which exports the golden weight shards + tokens).
#[cfg(feature = "xla")]
mod xla_artifacts {
    use super::*;
    use xeonserve::config::Manifest;

    fn golden_i32(path: &std::path::Path) -> Vec<i32> {
        use xla::FromRawBytes;
        let lit = xla::Literal::read_npy(path, &()).expect("read npy");
        lit.to_vec::<i32>().expect("i32 npy")
    }

    fn run_golden(variant: Variant) {
        let manifest =
            Manifest::load("artifacts").expect("run `make artifacts`");
        let golden = manifest.golden.clone().expect("golden meta");
        let gdir = manifest.golden_dir(&variant.to_string()).unwrap();

        let tokens = golden_i32(&gdir.join("tokens.npy"));
        let lengths = golden_i32(&gdir.join("lengths.npy"));
        let greedy = golden_i32(&gdir.join("greedy_tokens.npy")); // [n, B]
        let n = golden.n_decode;
        let b = lengths.len();
        let s = tokens.len() / b;

        let cfg = EngineConfig {
            model: golden.config.clone(),
            backend: BackendKind::Xla,
            variant,
            world: golden.world,
            batch: b,
            weights: WeightSource::NpyDir { dir: gdir.clone() },
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine init");

        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|lane| {
                tokens[lane * s..lane * s + lengths[lane] as usize].to_vec()
            })
            .collect();
        let outs = engine.generate(&prompts, n).expect("generate");

        for lane in 0..b {
            let expect: Vec<i32> =
                (0..n).map(|step| greedy[step * b + lane]).collect();
            assert_eq!(
                outs[lane], expect,
                "variant={variant} lane={lane}: rust {:?} != golden {:?}",
                outs[lane], expect
            );
        }
    }

    #[test]
    fn parallel_block_matches_jax_reference() {
        require_artifacts!();
        run_golden(Variant::Parallel);
    }

    #[test]
    fn serial_block_matches_jax_reference() {
        require_artifacts!();
        run_golden(Variant::Serial);
    }

    /// The optimizations must not change the numbers: run the parallel
    /// golden with ALL paper optimizations disabled (naive baseline)
    /// and expect the same tokens.
    #[test]
    fn naive_baseline_produces_identical_tokens() {
        require_artifacts!();
        let manifest =
            Manifest::load("artifacts").expect("run `make artifacts`");
        let golden = manifest.golden.clone().expect("golden meta");
        let gdir = manifest.golden_dir("parallel").unwrap();

        let tokens = golden_i32(&gdir.join("tokens.npy"));
        let lengths = golden_i32(&gdir.join("lengths.npy"));
        let greedy = golden_i32(&gdir.join("greedy_tokens.npy"));
        let n = golden.n_decode;
        let b = lengths.len();
        let s = tokens.len() / b;

        let cfg = EngineConfig {
            model: golden.config.clone(),
            backend: BackendKind::Xla,
            variant: Variant::Parallel,
            world: golden.world,
            batch: b,
            weights: WeightSource::NpyDir { dir: gdir },
            opt: xeonserve::config::OptFlags::naive(),
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine init");
        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|lane| {
                tokens[lane * s..lane * s + lengths[lane] as usize].to_vec()
            })
            .collect();
        let outs = engine.generate(&prompts, n).expect("generate");
        for lane in 0..b {
            let expect: Vec<i32> =
                (0..n).map(|step| greedy[step * b + lane]).collect();
            assert_eq!(outs[lane], expect, "naive lane={lane}");
        }
    }
}
