//! THE cross-layer correctness gate: the rust engine, running the
//! AOT-compiled HLO segments with the tensor-parallel weight shards
//! exported by `aot.py write_golden`, must reproduce the jax reference
//! composition token-for-token (greedy) on both block variants.
//!
//! Requires `make artifacts` (manifest + golden/ present).

use xeonserve::config::{EngineConfig, Manifest, Variant, WeightSource};
use xeonserve::engine::Engine;

#[macro_use]
#[path = "common/mod.rs"]
mod common;

fn golden_i32(path: &std::path::Path) -> Vec<i32> {
    use xla::FromRawBytes;
    let lit = xla::Literal::read_npy(path, &()).expect("read npy");
    lit.to_vec::<i32>().expect("i32 npy")
}

fn run_golden(variant: Variant) {
    let manifest = Manifest::load("artifacts").expect("run `make artifacts`");
    let golden = manifest.golden.clone().expect("golden meta");
    let gdir = manifest.golden_dir(&variant.to_string()).unwrap();

    let tokens = golden_i32(&gdir.join("tokens.npy"));
    let lengths = golden_i32(&gdir.join("lengths.npy"));
    let greedy = golden_i32(&gdir.join("greedy_tokens.npy")); // [n, B]
    let n = golden.n_decode;
    let b = lengths.len();
    let s = tokens.len() / b;

    let cfg = EngineConfig {
        model: golden.config.clone(),
        variant,
        world: golden.world,
        batch: b,
        weights: WeightSource::NpyDir { dir: gdir.clone() },
        ..Default::default()
    };
    let mut engine = Engine::new(cfg).expect("engine init");

    let prompts: Vec<Vec<i32>> = (0..b)
        .map(|lane| {
            tokens[lane * s..lane * s + lengths[lane] as usize].to_vec()
        })
        .collect();
    let outs = engine.generate(&prompts, n).expect("generate");

    for lane in 0..b {
        let expect: Vec<i32> =
            (0..n).map(|step| greedy[step * b + lane]).collect();
        assert_eq!(
            outs[lane], expect,
            "variant={variant} lane={lane}: rust {:?} != golden {:?}",
            outs[lane], expect
        );
    }
}

#[test]
fn parallel_block_matches_jax_reference() {
    require_artifacts!();
    run_golden(Variant::Parallel);
}

#[test]
fn serial_block_matches_jax_reference() {
    require_artifacts!();
    run_golden(Variant::Serial);
}

/// The optimizations must not change the numbers: run the parallel golden
/// with ALL paper optimizations disabled (naive baseline) and expect the
/// same tokens.
#[test]
fn naive_baseline_produces_identical_tokens() {
    require_artifacts!();
    let manifest = Manifest::load("artifacts").expect("run `make artifacts`");
    let golden = manifest.golden.clone().expect("golden meta");
    let gdir = manifest.golden_dir("parallel").unwrap();

    let tokens = golden_i32(&gdir.join("tokens.npy"));
    let lengths = golden_i32(&gdir.join("lengths.npy"));
    let greedy = golden_i32(&gdir.join("greedy_tokens.npy"));
    let n = golden.n_decode;
    let b = lengths.len();
    let s = tokens.len() / b;

    let cfg = EngineConfig {
        model: golden.config.clone(),
        variant: Variant::Parallel,
        world: golden.world,
        batch: b,
        weights: WeightSource::NpyDir { dir: gdir },
        opt: xeonserve::config::OptFlags::naive(),
        ..Default::default()
    };
    let mut engine = Engine::new(cfg).expect("engine init");
    let prompts: Vec<Vec<i32>> = (0..b)
        .map(|lane| {
            tokens[lane * s..lane * s + lengths[lane] as usize].to_vec()
        })
        .collect();
    let outs = engine.generate(&prompts, n).expect("generate");
    for lane in 0..b {
        let expect: Vec<i32> =
            (0..n).map(|step| greedy[step * b + lane]).collect();
        assert_eq!(outs[lane], expect, "naive lane={lane}");
    }
}
