//! Randomized property tests for the rccl collective substrate
//! (proptest is unavailable offline; cases are drawn from a seeded
//! SplitMix64, 64 cases per property, covering world sizes 1..=8 and
//! irregular payload lengths).
//!
//! Invariants under test:
//!  * allreduce(sum|max) ≡ elementwise fold across ranks, both paths
//!  * arena path ≡ staged ring path bit-for-bit
//!  * broadcast delivers the root's bytes to every rank, any root
//!  * allgather concatenates shards in rank order
//!  * local-top-k merge ≡ global top-k for every shard split

use std::sync::Arc;

use xeonserve::ccl::{CommGroup, Communicator, ReduceOp};
use xeonserve::sampling;
use xeonserve::util::SplitMix64;

fn on_group<R: Send + 'static>(
    world: usize,
    capacity: usize,
    f: impl Fn(Communicator) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let group = CommGroup::new_inproc(world, capacity);
    let f = Arc::new(f);
    group
        .into_communicators()
        .into_iter()
        .map(|c| {
            let f = f.clone();
            std::thread::spawn(move || f(c))
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect()
}

#[test]
fn prop_allreduce_paths_agree_and_sum() {
    let mut rng = SplitMix64::new(0xA11);
    for case in 0..64 {
        let world = 1 + rng.next_below(8);
        let n = 1 + rng.next_below(300);
        let seed = rng.next_u64();
        let op = if case % 3 == 0 { ReduceOp::Max } else { ReduceOp::Sum };

        let outs = on_group(world, n, move |mut c| {
            let mut lrng =
                SplitMix64::new(seed.wrapping_add(c.rank() as u64));
            let data: Vec<f32> =
                (0..n).map(|_| lrng.next_normal()).collect();
            c.arena_mut(n).unwrap().copy_from_slice(&data);
            c.allreduce_arena(n, op).unwrap();
            let arena_out = c.arena(n).unwrap().to_vec();
            let mut staged = data.clone();
            c.allreduce_staged(&mut staged, op).unwrap();
            (data, arena_out, staged)
        });

        // reference fold
        let mut expect = outs[0].0.clone();
        for (data, _, _) in &outs[1..] {
            for (e, v) in expect.iter_mut().zip(data) {
                *e = op.apply(*e, *v);
            }
        }
        for (r, (_, arena_out, staged)) in outs.iter().enumerate() {
            // the two algorithms reduce in different association orders,
            // so agreement is to f32 tolerance (bit-exact only for W<=2)
            for (i, (a, s)) in arena_out.iter().zip(staged).enumerate() {
                assert!(
                    (a - s).abs() <= 1e-4 * s.abs().max(1.0),
                    "case {case} rank {r} idx {i}: arena {a} vs staged {s}"
                );
            }
            if world <= 2 {
                assert_eq!(arena_out, staged,
                           "case {case} rank {r}: W<=2 must be bit-exact");
            }
            for (i, (a, e)) in arena_out.iter().zip(&expect).enumerate() {
                assert!(
                    (a - e).abs() <= 1e-4 * e.abs().max(1.0),
                    "case {case} rank {r} idx {i}: {a} vs {e}"
                );
            }
        }
    }
}

#[test]
fn prop_broadcast_any_root_any_size() {
    let mut rng = SplitMix64::new(0xB0);
    for case in 0..64 {
        let world = 1 + rng.next_below(8);
        let root = rng.next_below(world);
        let len = rng.next_below(2000);
        let seed = rng.next_u64();

        let outs = on_group(world, 8, move |c| {
            let mut buf = if c.rank() == root {
                let mut lrng = SplitMix64::new(seed);
                (0..len).map(|_| lrng.next_u64() as u8).collect()
            } else {
                Vec::new()
            };
            c.broadcast(&mut buf, root).unwrap();
            buf
        });
        for (r, out) in outs.iter().enumerate() {
            assert_eq!(out, &outs[root],
                       "case {case} world {world} root {root} rank {r}");
            assert_eq!(out.len(), len);
        }
    }
}

#[test]
fn prop_allgather_rank_order() {
    let mut rng = SplitMix64::new(0xA6);
    for case in 0..48 {
        let world = 1 + rng.next_below(8);
        let n = 1 + rng.next_below(200);
        let seed = rng.next_u64();
        let outs = on_group(world, n, move |c| {
            let mut lrng =
                SplitMix64::new(seed.wrapping_mul(c.rank() as u64 + 1));
            let local: Vec<f32> =
                (0..n).map(|_| lrng.next_f32()).collect();
            let mut out = vec![0.0f32; n * c.world()];
            c.allgather(&local, &mut out).unwrap();
            (local, out)
        });
        let expect: Vec<f32> = outs
            .iter()
            .flat_map(|(local, _)| local.clone())
            .collect();
        for (r, (_, out)) in outs.iter().enumerate() {
            assert_eq!(out, &expect, "case {case} rank {r}");
        }
    }
}

#[test]
fn prop_local_topk_merge_equals_global() {
    let mut rng = SplitMix64::new(0x70EA);
    for case in 0..64 {
        let world = 1 + rng.next_below(8);
        let per_shard = 1 + rng.next_below(500);
        let vocab = per_shard * world;
        let k = 1 + rng.next_below(per_shard.min(64));
        let full: Vec<f32> =
            (0..vocab).map(|_| rng.next_normal()).collect();

        let per_rank: Vec<Vec<sampling::Candidate>> = (0..world)
            .map(|r| {
                sampling::local_topk(
                    &full[r * per_shard..(r + 1) * per_shard],
                    k,
                    r * per_shard,
                )
            })
            .collect();
        let merged = sampling::merge_topk(&per_rank, k);
        let global = sampling::global_topk(&full, k);
        assert_eq!(merged, global,
                   "case {case}: world={world} shard={per_shard} k={k}");
    }
}

#[test]
fn prop_gather_preserves_payloads() {
    let mut rng = SplitMix64::new(0x6A);
    for _case in 0..32 {
        let world = 1 + rng.next_below(6);
        let root = rng.next_below(world);
        let seed = rng.next_u64();
        let outs = on_group(world, 8, move |c| {
            let mut lrng =
                SplitMix64::new(seed ^ (c.rank() as u64) << 32);
            let len = 1 + (lrng.next_u64() % 64) as usize;
            let payload: Vec<u8> =
                (0..len).map(|_| lrng.next_u64() as u8).collect();
            (payload.clone(), c.gather(&payload, root).unwrap())
        });
        for (r, (_, gathered)) in outs.iter().enumerate() {
            if r == root {
                let lists = gathered.as_ref().unwrap();
                assert_eq!(lists.len(), world);
                for (s, (sent, _)) in outs.iter().enumerate() {
                    assert_eq!(&lists[s], sent, "payload from rank {s}");
                }
            } else {
                assert!(gathered.is_none());
            }
        }
    }
}
