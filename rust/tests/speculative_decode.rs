//! Greedy-acceptance equivalence suite for speculative decoding
//! (DESIGN.md §15): a draft model may only ever *propose* tokens —
//! the target's verify rounds accept exactly the prefix that greedy
//! target-only decode would have produced, so spec-on greedy output
//! must be BIT-IDENTICAL to spec-off output for every request, across
//! draft depths, world sizes, dtypes, and both admission schedulers.
//! This file is that claim's pin, plus the serving-side invariants:
//! mixed speculating/plain batches, lanes that age out of
//! eligibility, rejection-heavy and acceptance-heavy schedules, and
//! lane/page/refcount conservation under random join/leave/cancel
//! traffic with speculation live.

use xeonserve::config::{BackendKind, Dtype, EngineConfig, SchedulerKind,
                        WeightSource};
use xeonserve::engine::Engine;
use xeonserve::util::SplitMix64;

/// Spec-off baseline config (the reference semantics).
fn cfg(world: usize, batch: usize, dtype: Dtype, sched: SchedulerKind)
       -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch,
        weight_dtype: dtype,
        kv_dtype: dtype,
        scheduler: sched,
        weights: WeightSource::Synthetic { seed: 0xC0FFEE },
        ..Default::default()
    }
}

/// The same config with the nano draft speculating `k` tokens/step.
fn spec_cfg(world: usize, batch: usize, dtype: Dtype,
            sched: SchedulerKind, k: usize) -> EngineConfig {
    let mut c = cfg(world, batch, dtype, sched);
    c.spec_draft = "nano".into();
    c.spec_k = k;
    c
}

/// Prompts short enough that the fcfs bucket path (tiny's single
/// 16-token bucket) never truncates, so every matrix cell compares
/// exact equals.
fn prompts() -> Vec<Vec<i32>> {
    vec![
        vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100, 110],
        vec![7, 7, 7],
        vec![1, 2, 3, 4, 5, 6, 7, 8],
        vec![99, 3, 55, 4, 120, 6, 31, 8, 2, 11, 5, 44, 9, 14],
    ]
}

/// Spec-off reference: each prompt decoded greedily without any
/// draft, the tokens every speculative cell must reproduce.
fn baseline_tokens(dtype: Dtype, ps: &[Vec<i32>], n_new: usize)
                   -> Vec<Vec<i32>> {
    let mut e =
        Engine::new(cfg(1, 2, dtype, SchedulerKind::Fcfs)).unwrap();
    e.generate(ps, n_new).unwrap()
}

// ---- the acceptance matrix ---------------------------------------------

/// Headline gate: greedy decode bit-identical spec-on vs spec-off
/// across k ∈ {1, 2, 4} × worlds {1, 2, 4} × dtypes {f32, int8} ×
/// both schedulers.  Batch 2 over 4 requests, so lanes retire and
/// refill mid-run and speculation restarts on fresh lanes.  Every
/// cell must also actually speculate (proposals > 0) — a cell that
/// silently fell back to plain decode would pass vacuously.
#[test]
fn speculative_equivalence_matrix() {
    let ps = prompts();
    for dtype in [Dtype::F32, Dtype::Int8] {
        let golden = baseline_tokens(dtype, &ps, 8);
        assert!(golden.iter().all(|t| !t.is_empty()));
        for k in [1usize, 2, 4] {
            for world in [1usize, 2, 4] {
                for sched in [SchedulerKind::Fcfs,
                              SchedulerKind::Continuous] {
                    let mut e = Engine::new(
                        spec_cfg(world, 2, dtype, sched, k)).unwrap();
                    let got = e.generate(&ps, 8).unwrap();
                    assert_eq!(
                        got, golden,
                        "{dtype:?} k={k} world={world} {sched}: \
                         speculative run diverged from the spec-off \
                         reference"
                    );
                    assert!(e.metrics.spec_proposed > 0,
                            "{dtype:?} k={k} world={world} {sched}: \
                             no draft proposals — cell never \
                             speculated");
                    assert!(e.metrics.spec_accepted
                                <= e.metrics.spec_proposed);
                    let acc = e.metrics.accept_rate();
                    assert!((0.0..=1.0).contains(&acc));
                }
            }
        }
    }
}

/// Volume run: whatever accept/reject pattern the random nano draft
/// produces against the random tiny target — rejection at position 0
/// (the common case, full rollback), mid-chain rejection, or full
/// acceptance (the draft catch-up round) — long greedy streams stay
/// bit-identical to the spec-off reference, and the proposal
/// accounting stays consistent.  The pattern itself is a fixed
/// deterministic function of the synthetic seed, so this test is
/// stable; the bit-identity claim is what pins every branch that
/// fires.
#[test]
fn long_runs_stay_bit_identical_whatever_the_accept_pattern() {
    let ps = prompts();
    let golden = baseline_tokens(Dtype::F32, &ps, 40);
    for k in [1usize, 4] {
        let mut e = Engine::new(spec_cfg(1, 2, Dtype::F32,
                                         SchedulerKind::Continuous, k))
            .unwrap();
        let got = e.generate(&ps, 40).unwrap();
        assert_eq!(got, golden, "k={k}: long run diverged");
        let m = &e.metrics;
        // every decode round of an eligible lane must have proposed:
        // 4 requests × ≥ (39 decode tokens / (k+1) rows per round − 1
        // possibly-plain final round) spec rounds × k proposals each
        let floor = 4 * (39 / (k + 1)).saturating_sub(1) * k;
        assert!(m.spec_proposed as usize >= floor,
                "k={k}: {} proposals under the {floor} floor — lanes \
                 silently stopped speculating", m.spec_proposed);
        assert!(m.spec_accepted <= m.spec_proposed,
                "k={k}: accounting inversion");
        let acc = m.accept_rate();
        assert!((0.0..=1.0).contains(&acc), "k={k}: bad rate {acc}");
        println!("k={k}: {} proposed, {} accepted (rate {acc:.3})",
                 m.spec_proposed, m.spec_accepted);
    }
}

// ---- mixed speculating / plain batches ---------------------------------

/// A batch mixing speculating lanes with lanes that must decode plain
/// — one with `max_new = 1` (remaining < 2 never speculates) and one
/// near `max_seq` (no KV headroom for k+1 rows) — stays bit-identical
/// per lane, and the plain lanes really were served.
#[test]
fn mixed_speculating_and_plain_lanes_are_bit_identical() {
    // near-max_seq: tiny's max_seq is 64; a 61-token prompt at k=4
    // fails the len + k + 1 <= max_seq eligibility check for its
    // whole (short) generation, so the lane decodes plain throughout
    let long: Vec<i32> =
        (0..61).map(|t| ((t * 13) % 200) as i32 + 1).collect();
    let short = vec![10i32, 20, 30];
    let normal = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
    let budgets = [2usize, 1, 12];
    let reqs: Vec<(Vec<i32>, usize)> = vec![
        (long.clone(), budgets[0]),
        (short.clone(), budgets[1]),
        (normal.clone(), budgets[2]),
    ];
    // per-request spec-off reference (continuous admission: the long
    // prompt must not be bucket-truncated)
    let golden: Vec<Vec<i32>> = reqs
        .iter()
        .map(|(p, n)| {
            let mut e = Engine::new(cfg(1, 2, Dtype::F32,
                                        SchedulerKind::Continuous))
                .unwrap();
            e.generate(std::slice::from_ref(p), *n).unwrap()
                .pop().unwrap()
        })
        .collect();
    for world in [1usize, 2] {
        let mut e = Engine::new(spec_cfg(world, 3, Dtype::F32,
                                         SchedulerKind::Continuous, 4))
            .unwrap();
        let ids: Vec<u64> = reqs
            .iter()
            .map(|(p, n)| e.enqueue(p.clone(), *n))
            .collect();
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.request_id);
        assert_eq!(done.len(), 3);
        for ((c, id), want) in done.iter().zip(&ids).zip(&golden) {
            assert_eq!(c.request_id, *id);
            assert_eq!(&c.tokens, want,
                       "w{world}: lane in a mixed spec/plain batch \
                        diverged (request {id})");
        }
        // the normal lane speculated; the constrained lanes' plain
        // service shows up as verify rows smaller than a full
        // 3-lane × (k+1) speculative batch would be
        assert!(e.metrics.spec_proposed > 0,
                "w{world}: mixed batch never speculated");
        assert_eq!(e.free_lanes(), 3, "w{world}: lane leak");
        assert_eq!(e.free_pages() + e.shared_pages(), e.total_pages(),
                   "w{world}: page leak");
    }
}

/// A lane ages OUT of eligibility mid-request: generation carries it
/// from plenty of KV headroom to `len + k + 1 > max_seq`, so the
/// engine must switch that lane from speculating to plain decode
/// mid-stream without perturbing its tokens.
#[test]
fn lane_aging_out_of_headroom_switches_to_plain_mid_request() {
    let p: Vec<i32> =
        (0..40).map(|t| ((t * 7) % 200) as i32 + 1).collect();
    let golden = {
        let mut e = Engine::new(cfg(1, 1, Dtype::F32,
                                    SchedulerKind::Continuous))
            .unwrap();
        e.generate(std::slice::from_ref(&p), 23).unwrap().pop().unwrap()
    };
    // len walks 40 → 62; at k=4 eligibility (len + 5 <= 64) dies at
    // len 60, three tokens before the cap ends the request
    let mut e = Engine::new(spec_cfg(1, 1, Dtype::F32,
                                     SchedulerKind::Continuous, 4))
        .unwrap();
    let got = e.generate(std::slice::from_ref(&p), 23).unwrap()
        .pop().unwrap();
    assert_eq!(got, golden, "aging out of eligibility changed tokens");
    assert!(e.metrics.spec_proposed > 0);
}

// ---- probes ------------------------------------------------------------

/// `last_verify_rows` reports the speculative row count of the most
/// recent step — the number the server charges the scheduler's burst
/// budget with.  A step that runs a speculative decode round reports
/// `spec_lanes·(k+1) + plain_lanes`; a step that doesn't (prefill
/// only, or plain decode) reports 0.  One `step()` may do both a
/// lane's prefill and its first decode round, so this probes the
/// *set* of values a run produces rather than pinning phases to step
/// indices.
#[test]
fn verify_row_probe_tracks_step_shape() {
    let k = 3usize;
    let mut e = Engine::new(spec_cfg(1, 2, Dtype::F32,
                                     SchedulerKind::Continuous, k))
        .unwrap();
    assert_eq!(e.last_verify_rows(), 0, "fresh engine must report 0");
    e.enqueue(vec![1, 2, 3, 4], 8);
    e.enqueue(vec![9, 8, 7], 8);
    let (mut saw_one_lane, mut saw_two_lanes) = (false, false);
    while e.has_work() {
        e.step().unwrap();
        let rows = e.last_verify_rows();
        // batch 2: a speculative step is spec_lanes·(k+1) +
        // plain_lanes rows — a lane on its final token (remaining
        // < 2) rides along plain, giving the k+2 shape
        assert!(rows == 0 || rows == k + 1 || rows == k + 2
                    || rows == 2 * (k + 1),
                "unexpected verify row count {rows}");
        saw_one_lane |= rows == k + 1;
        saw_two_lanes |= rows == 2 * (k + 1);
    }
    // one lane retires before the other (different prompt lengths
    // stagger prefill), so both shapes must occur
    assert!(saw_one_lane,
            "no step ever verified a single speculating lane");
    assert!(saw_two_lanes,
            "two concurrent speculating lanes never produced a \
             2·(k+1)-row verify step");
    // spec-off engines always report 0
    let mut plain =
        Engine::new(cfg(1, 1, Dtype::F32, SchedulerKind::Fcfs)).unwrap();
    plain.enqueue(vec![1, 2, 3], 4);
    while plain.has_work() {
        plain.step().unwrap();
        assert_eq!(plain.last_verify_rows(), 0);
    }
}

// ---- random join/leave/cancel schedules --------------------------------

/// A 33-token system prompt whose 32-token page-aligned prefix
/// publishes as a shared segment — speculation must coexist with
/// copy-on-write prefix reuse (the draft cache mirrors every
/// attach/publish/drop).
fn system_prefix() -> Vec<i32> {
    (0..33).map(|t| ((t * 13) % 200) as i32 + 1).collect()
}

/// Drive one random schedule of submit / step / cancel against a
/// speculating continuous-batching engine, checking page accounting
/// every op and full conservation (lanes, pages, shared segments) at
/// drain.  Rollback truncation, retire-mid-verify, cancel-mid-spec,
/// and draft-KV mirroring all fire under this traffic.
fn run_spec_schedule(seed: u64, ops: usize, k: usize) {
    let mut rng = SplitMix64::new(seed);
    let mut engine = Engine::new(spec_cfg(1, 2, Dtype::F32,
                                          SchedulerKind::Continuous, k))
        .unwrap();
    let lanes0 = engine.free_lanes();
    let pages0 = engine.free_pages();
    let mut live: Vec<u64> = Vec::new();
    for op in 0..ops {
        match rng.next_below(4) {
            0 => {
                // half the arrivals open with the shared system
                // prompt (publish/attach traffic), half are private
                let len = 1 + rng.next_below(20);
                let prompt: Vec<i32> = if rng.next_below(2) == 0 {
                    let mut p = system_prefix();
                    p.truncate(len.max(4));
                    p
                } else {
                    (0..len)
                        .map(|_| rng.next_below(200) as i32 + 1)
                        .collect()
                };
                live.push(engine.enqueue(prompt,
                                         1 + rng.next_below(8)));
            }
            1 if !live.is_empty() => {
                let i = rng.next_below(live.len());
                let id = live.swap_remove(i);
                // may already have completed — either is fine, but
                // it must never error or double-free
                engine.cancel(id).unwrap();
            }
            _ => {
                if engine.has_work() {
                    for c in engine.step().unwrap() {
                        live.retain(|&id| id != c.request_id);
                    }
                }
            }
        }
        assert!(engine.free_pages() + engine.shared_pages()
                    <= engine.total_pages(),
                "seed {seed:#x} op {op}: page pool oversubscribed");
        assert_eq!(engine.shared_groups(), engine.prefix_entries(),
                   "seed {seed:#x} op {op}: allocator and prefix \
                    cache disagree on live segments");
        assert!(engine.last_verify_rows() <= 2 * (k + 1),
                "seed {seed:#x} op {op}: verify rows exceed the \
                 2-lane × (k+1) ceiling");
    }
    for id in live {
        engine.cancel(id).unwrap();
    }
    engine.run_to_completion().unwrap();
    assert_eq!(engine.free_lanes(), lanes0,
               "seed {seed:#x}: lane leak");
    assert_eq!(engine.free_pages() + engine.shared_pages(), pages0,
               "seed {seed:#x}: page leak");
    let m = &engine.metrics;
    assert!(m.spec_accepted <= m.spec_proposed,
            "seed {seed:#x}: accounting inversion");
}

/// Property sweep: random interleavings of submit / step / cancel
/// with speculation live — across draft depths, with shared-prefix
/// traffic mixed in — conserve lanes, pages, and segment refcounts.
/// No accept/reject schedule leaks.
#[test]
fn random_schedules_with_speculation_conserve_resources() {
    for case in 0..8u64 {
        let k = [1usize, 2, 4, 8][case as usize % 4];
        run_spec_schedule(0x5BEC + case, 60, k);
    }
}

// ---- config plumbing ---------------------------------------------------

/// The TOML knobs reach the engine via the same path the launch
/// coordinator ships configs through, and a parsed config actually
/// speculates — with output still pinned to the spec-off reference.
#[test]
fn spec_config_roundtrips_through_toml_and_serves() {
    let c = spec_cfg(1, 2, Dtype::F32, SchedulerKind::Continuous, 2);
    let back = EngineConfig::from_toml_str(&c.to_toml_string()).unwrap();
    assert_eq!(back.spec_draft, "nano");
    assert_eq!(back.spec_k, 2);
    assert!(back.spec_enabled());
    let ps = prompts();
    let golden = baseline_tokens(Dtype::F32, &ps, 8);
    let mut e = Engine::new(back).unwrap();
    assert_eq!(e.generate(&ps, 8).unwrap(), golden);
    assert!(e.metrics.spec_proposed > 0);
}
