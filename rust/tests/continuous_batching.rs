//! Batch-composition invariance suite (DESIGN.md §13): continuous
//! batching changes *which lanes share a step* — requests join and
//! retire every step instead of waiting for a fixed bucket — and
//! copy-on-write shared-prefix reuse changes *where KV rows live*,
//! but neither may change what is computed.  Every lane's logits
//! depend only on its own token stream, so greedy decodes must be
//! BIT-IDENTICAL whether a request runs alone, inside a full batch,
//! or joins mid-flight; whether its prefix KV is private or attached
//! to a shared segment; and across world sizes, dtypes, and both
//! admission schedulers.  This file is that claim's pin, plus the
//! resource-conservation properties (lanes, pages, refcounts) under
//! random join/leave/cancel schedules.

use xeonserve::config::{BackendKind, Dtype, EngineConfig, SchedulerKind,
                        WeightSource};
use xeonserve::engine::Engine;
use xeonserve::util::SplitMix64;

fn cfg(world: usize, batch: usize, dtype: Dtype, sched: SchedulerKind)
       -> EngineConfig {
    EngineConfig {
        model: "tiny".into(),
        backend: BackendKind::Reference,
        world,
        batch,
        weight_dtype: dtype,
        kv_dtype: dtype,
        scheduler: sched,
        weights: WeightSource::Synthetic { seed: 0xC0FFEE },
        ..Default::default()
    }
}

/// Prompts short enough that the fcfs bucket path (tiny's single
/// 16-token bucket) never truncates — the cross-scheduler cells of
/// the matrix compare exact equals.
fn short_prompts() -> Vec<Vec<i32>> {
    vec![
        vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100, 110],
        vec![7, 7, 7],
        vec![1, 2, 3, 4, 5, 6, 7, 8],
        vec![99, 3, 55, 4, 120, 6, 31, 8, 2, 11, 5, 44, 9, 14],
    ]
}

/// Run each prompt ALONE (batch 1, world 1, fcfs) — the composition-
/// free reference every matrix cell must reproduce.
fn alone_tokens(dtype: Dtype, prompts: &[Vec<i32>], n_new: usize)
                -> Vec<Vec<i32>> {
    prompts
        .iter()
        .map(|p| {
            let mut e = Engine::new(cfg(1, 1, dtype,
                                        SchedulerKind::Fcfs))
                .unwrap();
            e.generate(std::slice::from_ref(p), n_new).unwrap()
                .pop()
                .unwrap()
        })
        .collect()
}

// ---- the acceptance matrix ---------------------------------------------

/// Headline gate: greedy decode bit-identical alone vs. full batch,
/// across worlds {1, 2, 4} × dtypes {f32, int8} × both schedulers.
/// The batch runs at 2 lanes over 4 requests, so the scheduler
/// retires and refills lanes mid-run — every composition the engine
/// can produce must match the alone reference token for token.
#[test]
fn batch_composition_invariance_matrix() {
    let prompts = short_prompts();
    for dtype in [Dtype::F32, Dtype::Int8] {
        let golden = alone_tokens(dtype, &prompts, 8);
        assert!(golden.iter().all(|t| !t.is_empty()));
        for world in [1usize, 2, 4] {
            for sched in [SchedulerKind::Fcfs,
                          SchedulerKind::Continuous] {
                let mut e =
                    Engine::new(cfg(world, 2, dtype, sched)).unwrap();
                let got = e.generate(&prompts, 8).unwrap();
                assert_eq!(
                    got, golden,
                    "{dtype:?} world={world} {sched}: batched run \
                     diverged from the alone reference"
                );
            }
        }
    }
}

/// A request joining MID-FLIGHT — while another stream is already
/// decoding — must emit the same tokens as when it has the engine to
/// itself, and must not perturb the stream it joined.
#[test]
fn mid_flight_join_is_bit_invariant() {
    let a = vec![10i32, 20, 30, 40, 50, 60, 70];
    let b = vec![5i32, 4, 3, 2, 1];
    for dtype in [Dtype::F32, Dtype::Int8] {
        let golden = alone_tokens(dtype, &[a.clone(), b.clone()], 8);
        for world in [1usize, 2] {
            for sched in [SchedulerKind::Fcfs,
                          SchedulerKind::Continuous] {
                let mut e =
                    Engine::new(cfg(world, 2, dtype, sched)).unwrap();
                let ida = e.enqueue(a.clone(), 8);
                // a few steps: A is admitted, prefilled, and decoding
                for _ in 0..3 {
                    e.step().unwrap();
                }
                let idb = e.enqueue(b.clone(), 8);
                let mut done = e.run_to_completion().unwrap();
                done.sort_by_key(|c| c.request_id);
                assert_eq!(done.len(), 2);
                assert_eq!(done[0].request_id, ida);
                assert_eq!(done[1].request_id, idb);
                assert_eq!(done[0].tokens, golden[0],
                           "{dtype:?} w{world} {sched}: joined-into \
                            stream perturbed");
                assert_eq!(done[1].tokens, golden[1],
                           "{dtype:?} w{world} {sched}: mid-flight \
                            joiner diverged");
            }
        }
    }
}

// ---- shared-prefix equivalence -----------------------------------------

/// A 33-token system prompt: its 32-token page-aligned prefix
/// publishes as a two-page shared segment after the first (donor)
/// request prefills it.
fn system_prefix() -> Vec<i32> {
    (0..33).map(|t| ((t * 13) % 200) as i32 + 1).collect()
}

/// Follower prompt `i`: same first 20 tokens as the donor, private
/// tail — the partial-page shape (shared_len 16, copy_len 4) whose
/// divergence row sits mid-page, so attaching COW-copies rows 16..20
/// before prefilling the tail.
fn follower(i: usize) -> Vec<i32> {
    let mut p = system_prefix();
    p.truncate(20);
    for t in 0..6 {
        p.push(((t * 13 + i * 7 + 90) % 200) as i32 + 1);
    }
    p
}

/// A follower sharing the donor's WHOLE published segment (both
/// pages, shared_len 32, copy_len 0) with a private tail beyond it.
fn deep_follower() -> Vec<i32> {
    let mut p = system_prefix();
    for t in 0..6 {
        p.push(((t * 11 + 170) % 200) as i32 + 1);
    }
    p
}

/// The §13 equivalence gate: a request served off a shared prefix
/// segment (COW attach, prefill from the divergence point) emits
/// tokens bit-identical to the same request served with fully
/// private KV — across worlds and dtypes — and the engine really did
/// take the sharing path (hits > 0, a live segment).
#[test]
fn shared_prefix_reuse_is_bit_identical() {
    for dtype in [Dtype::F32, Dtype::Int8] {
        // private reference: each follower alone in a fresh engine —
        // its prefix cache is empty, so KV is fully private
        let prompts =
            vec![follower(0), follower(1), deep_follower()];
        let golden: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| {
                let mut e = Engine::new(cfg(1, 2, dtype,
                                            SchedulerKind::Continuous))
                    .unwrap();
                e.generate(std::slice::from_ref(p), 6).unwrap()
                    .pop()
                    .unwrap()
            })
            .collect();
        for world in [1usize, 2, 4] {
            let mut e = Engine::new(cfg(world, 2, dtype,
                                        SchedulerKind::Continuous))
                .unwrap();
            // donor run publishes the 32-token shared segment
            let donor = e.generate(&[system_prefix()], 4).unwrap();
            assert!(!donor[0].is_empty());
            assert_eq!(e.prefix_entries(), 1, "donor must publish");
            assert_eq!(e.shared_groups(), 1);
            assert_eq!(e.shared_pages(), 2,
                       "a 32-token segment spans two KV pages");
            // followers attach to it: two partial-page COW attaches
            // and one whole-segment attach
            let got = e.generate(&prompts, 6).unwrap();
            assert_eq!(e.metrics.prefix_hits, 3,
                       "all followers must attach, not re-prefill");
            for (i, (g, want)) in
                got.iter().zip(&golden).enumerate()
            {
                assert_eq!(g, want,
                           "{dtype:?} w{world}: shared-prefix \
                            follower {i} diverged from the \
                            private-KV reference");
            }
            // retired followers dropped their refs; the idle segment
            // stays cached, everything else returned to the pool
            assert_eq!(e.free_pages() + e.shared_pages(),
                       e.total_pages(),
                       "idle engine must account every page");
            assert_eq!(e.free_lanes(), 2);
        }
    }
}

/// Sharing survives memory pressure without corruption: more
/// prefix-sharing requests than the pool can hold at once are shed
/// (admission waits), never corrupted — everyone completes with the
/// right tokens and the pool balances.
#[test]
fn exhaustion_with_pinned_prefix_sheds_cleanly() {
    let golden: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let mut e = Engine::new(cfg(1, 2, Dtype::F32,
                                        SchedulerKind::Continuous))
                .unwrap();
            e.generate(&[follower(i)], 25).unwrap().pop().unwrap()
        })
        .collect();
    // batch 2 → an 8-page pool; each follower's worst case (26 prompt
    // + 25 decode → 4 pages, 3 private next to the shared page) plus
    // the two pinned segment pages saturate the pool, so admissions
    // beyond the first wave must wait for retires
    let mut e = Engine::new(cfg(1, 2, Dtype::F32,
                                SchedulerKind::Continuous))
        .unwrap();
    e.generate(&[system_prefix()], 4).unwrap();
    let prompts: Vec<Vec<i32>> = (0..6).map(follower).collect();
    let got = e.generate(&prompts, 25).unwrap();
    assert_eq!(got, golden, "shedding under pressure changed tokens");
    assert_eq!(e.metrics.requests_done, 7);
    assert!(e.metrics.prefix_hits >= 6);
    assert_eq!(e.free_pages() + e.shared_pages(), e.total_pages());
    assert_eq!(e.free_lanes(), 2);
}

// ---- random join/leave/cancel schedules --------------------------------

/// Drive one random schedule of submit / step / cancel against a
/// continuous-batching engine, checking page accounting every step
/// and full conservation (lanes, pages, shared segments) at drain.
fn run_schedule(seed: u64, ops: usize, chunk: usize) {
    let mut rng = SplitMix64::new(seed);
    let mut c = cfg(1, 2, Dtype::F32, SchedulerKind::Continuous);
    c.prefill_chunk = chunk;
    let mut engine = Engine::new(c).unwrap();
    let lanes0 = engine.free_lanes();
    let pages0 = engine.free_pages();
    let mut live: Vec<u64> = Vec::new();
    for op in 0..ops {
        match rng.next_below(4) {
            0 => {
                // half the arrivals open with the shared system
                // prompt (publish/attach traffic), half are private
                let len = 1 + rng.next_below(20);
                let prompt: Vec<i32> = if rng.next_below(2) == 0 {
                    let mut p = system_prefix();
                    p.truncate(len.max(4));
                    p
                } else {
                    (0..len)
                        .map(|_| rng.next_below(200) as i32 + 1)
                        .collect()
                };
                live.push(engine.enqueue(prompt,
                                         1 + rng.next_below(6)));
            }
            1 if !live.is_empty() => {
                let i = rng.next_below(live.len());
                let id = live.swap_remove(i);
                // may already have completed — either is fine, but
                // it must never error or double-free
                engine.cancel(id).unwrap();
            }
            _ => {
                if engine.has_work() {
                    for c in engine.step().unwrap() {
                        live.retain(|&id| id != c.request_id);
                    }
                }
            }
        }
        assert!(engine.free_pages() + engine.shared_pages()
                    <= engine.total_pages(),
                "seed {seed:#x} op {op}: page pool oversubscribed");
        assert_eq!(engine.shared_groups(), engine.prefix_entries(),
                   "seed {seed:#x} op {op}: allocator and prefix \
                    cache disagree on live segments");
    }
    // cancel everything left and drain: all private pages return;
    // only idle cached segments still hold pages, and exactly them
    for id in live {
        engine.cancel(id).unwrap();
    }
    engine.run_to_completion().unwrap();
    assert_eq!(engine.free_lanes(), lanes0,
               "seed {seed:#x}: lane leak");
    assert_eq!(engine.free_pages() + engine.shared_pages(), pages0,
               "seed {seed:#x}: page leak");
}

/// Property sweep: random interleavings of submit / step / cancel —
/// with and without shared prefixes, whole-prompt and chunked —
/// conserve lanes, pages, and segment refcounts.  No schedule leaks.
#[test]
fn random_join_leave_cancel_conserves_resources() {
    for case in 0..8u64 {
        let chunk = [0usize, 1, 3][case as usize % 3];
        run_schedule(0x1057 + case, 60, chunk);
    }
}

/// The CI soak (longer schedules, seed overridable so the nightly
/// matrix can roll it): same conservation claims, deeper
/// interleavings.  `XEONSERVE_SOAK_SEED` sets the base seed.
#[test]
fn seeded_soak_join_leave_cancel() {
    let base = std::env::var("XEONSERVE_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5EED_50A4);
    for case in 0..4u64 {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9));
        println!("soak case {case}: seed {seed:#x} \
                  (XEONSERVE_SOAK_SEED={base})");
        run_schedule(seed, 200, [0usize, 2][case as usize % 2]);
    }
}

// ---- serving semantics -------------------------------------------------

/// Cancelling a lane attached to a shared segment releases its ref
/// but never frees the segment out from under other attached lanes.
#[test]
fn cancel_attached_lane_keeps_segment_for_others() {
    let mut e = Engine::new(cfg(1, 2, Dtype::F32,
                                SchedulerKind::Continuous))
        .unwrap();
    e.generate(&[system_prefix()], 4).unwrap();
    let golden = {
        let mut solo = Engine::new(cfg(1, 2, Dtype::F32,
                                       SchedulerKind::Continuous))
            .unwrap();
        solo.generate(&[follower(1)], 8).unwrap().pop().unwrap()
    };
    let f0 = e.enqueue(follower(0), 8);
    let _f1 = e.enqueue(follower(1), 8);
    for _ in 0..3 {
        e.step().unwrap();
    }
    assert_eq!(e.metrics.prefix_hits, 2);
    assert!(e.cancel(f0).unwrap());
    assert_eq!(e.shared_groups(), 1,
               "cancel of one attached lane must not drop the segment");
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].tokens, golden,
               "survivor's stream perturbed by sibling cancel");
    assert_eq!(e.free_pages() + e.shared_pages(), e.total_pages());
}

/// The TOML knob reaches the engine via the same path the launch
/// coordinator ships configs through, and the serving behavior
/// (publish + attach) actually engages from a parsed config.
#[test]
fn scheduler_roundtrips_through_toml_and_serves() {
    let c = cfg(1, 2, Dtype::F32, SchedulerKind::Continuous);
    let back = EngineConfig::from_toml_str(&c.to_toml_string()).unwrap();
    assert_eq!(back.scheduler, SchedulerKind::Continuous);
    let mut e = Engine::new(back).unwrap();
    e.generate(&[system_prefix()], 4).unwrap();
    e.generate(&[follower(0)], 6).unwrap();
    assert_eq!(e.metrics.prefix_hits, 1);
    assert_eq!(e.metrics.prefix_misses, 1);
}
