//! Rank-side model state: synthetic tensor-parallel weight generation,
//! plus (behind `--features xla`) device-resident weight buffers.
//!
//! Shapes and argument order come from the manifest (the python side is
//! the source of truth — see `python/compile/model.py`); this module only
//! materializes values, from one of two sources:
//!
//! * `Synthetic { seed }` — deterministic random weights with fan-in
//!   scaling, for benches and examples;
//! * `NpyDir { dir }` — the tensor-parallel shards exported by
//!   `aot.py write_golden`, for the rust↔jax parity tests.
//!
//! The sharding scheme ([`synth_shard`]) is backend-independent: the
//! reference backend reuses it to build host-resident shards, so both
//! backends satisfy the same `concat(shards) == full-tensor` invariant
//! at every world size.

#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::Path;

#[cfg(feature = "xla")]
use anyhow::{bail, Context, Result};
#[cfg(feature = "xla")]
use xla::PjRtBuffer;

#[cfg(feature = "xla")]
use crate::config::{Manifest, SegmentMeta, WeightSource};
#[cfg(feature = "xla")]
use crate::runtime::RankRuntime;
use crate::backend::quant::QuantMat;
use crate::util::{fnv1a, SplitMix64};

/// All weight buffers one rank needs, keyed the way segments consume
/// them (`SegmentMeta::weight_args` names index into `layers[li]`).
#[cfg(feature = "xla")]
pub struct RankWeights {
    pub embedding: PjRtBuffer,
    pub layers: Vec<HashMap<String, PjRtBuffer>>,
    pub final_g: PjRtBuffer,
    pub lm_head: PjRtBuffer,
}

/// Union of per-layer weight tensor shapes, collected from the manifest's
/// decode segments for (config, world).
#[cfg(feature = "xla")]
pub fn layer_weight_shapes(
    manifest: &Manifest,
    config: &str,
    world: usize,
    batch: usize,
) -> Result<HashMap<String, Vec<usize>>> {
    let mut shapes: HashMap<String, Vec<usize>> = HashMap::new();
    for (kind, mode, seq) in [
        ("parallel_block", "decode", 1),
        ("serial_attn", "decode", 1),
        ("serial_ffn", "decode", 1),
    ] {
        let seg = manifest.find(config, world, batch, kind, mode, seq)?;
        collect_weight_shapes(seg, &mut shapes);
    }
    Ok(shapes)
}

#[cfg(feature = "xla")]
fn collect_weight_shapes(seg: &SegmentMeta,
                         shapes: &mut HashMap<String, Vec<usize>>) {
    for name in &seg.weight_args {
        if let Some(t) = seg.inputs.iter().find(|t| &t.name == name) {
            shapes.insert(name.clone(), t.shape.clone());
        }
    }
}

/// Which axis of a weight tensor is tensor-parallel sharded.
/// Column-parallel (axis 1): qkv/gate/up projections + lm head.
/// Row-parallel (axis 0): the partial-sum output projections.
fn shard_axis(name: &str) -> Option<usize> {
    match name {
        "wq" | "wk" | "wv" | "wg" | "wu" | "lm_head" => Some(1),
        "wo" | "wd" => Some(0),
        _ => None, // replicated: norms, embedding
    }
}

/// Initialization scale for a synthetic weight tensor, by name.
/// Mirrors python's `make_full_weights`: matmul weights are
/// `normal * fan_in^-0.5`; norm gains are `1 + 0.1*normal`.
fn synth_fill(name: &str, shape: &[usize], rng: &mut SplitMix64)
              -> Vec<f32> {
    let n: usize = shape.iter().product();
    if name.ends_with("_g") {
        return (0..n).map(|_| 1.0 + 0.1 * rng.next_normal()).collect();
    }
    let fan_in = shape.first().copied().unwrap_or(1).max(1);
    let scale = (fan_in as f32).powf(-0.5);
    rng.normal_vec(n, scale)
}

/// Generate rank `rank`'s shard of a synthetic tensor such that the
/// *concatenation across ranks equals one fixed full tensor* independent
/// of the world size.  This makes synthetic runs comparable across TP
/// degrees (E1 scalability measures the same model at every world) and
/// lets the engine tests assert world-invariant greedy tokens.
/// Both backends build their synthetic shards through this function.
pub(crate) fn synth_shard(name: &str, local_shape: &[usize], world: usize,
                          rank: usize, seed: u64) -> Vec<f32> {
    let axis = shard_axis(name);
    match axis {
        None => {
            let mut rng = SplitMix64::new(seed);
            synth_fill(name, local_shape, &mut rng)
        }
        Some(ax) => {
            // full tensor shape: local scaled on the sharded axis.
            let mut full_shape = local_shape.to_vec();
            full_shape[ax] *= world;
            // IMPORTANT: scale uses the FULL fan-in so w1 == concat(wN)
            let mut rng = SplitMix64::new(seed);
            let full = synth_fill(name, &full_shape, &mut rng);
            if world == 1 {
                return full;
            }
            let (rows_l, cols_l) = (local_shape[0], local_shape[1]);
            let cols_f = full_shape[1];
            let mut out = Vec::with_capacity(rows_l * cols_l);
            match ax {
                0 => {
                    let start = rank * rows_l * cols_f;
                    out.extend_from_slice(
                        &full[start..start + rows_l * cols_f]);
                }
                1 => {
                    for r in 0..rows_l {
                        let base = r * cols_f + rank * cols_l;
                        out.extend_from_slice(&full[base..base + cols_l]);
                    }
                }
                _ => unreachable!(),
            }
            out
        }
    }
}

/// INT8 variant of [`synth_shard`]: generate the same fixed full
/// tensor, quantize it on a `group`-row grid along the contraction
/// axis (DESIGN.md §11), and slice this rank's shard out of the
/// quantized values *and* their scales.  Quantizing before sharding is
/// what makes the reconstructed `q·s` values identical at every world
/// size — the world-parity guarantee at `weight_dtype = "int8"` rests
/// on it, exactly as the f32 guarantee rests on `concat(shards) ==
/// full`.
///
/// Only sharded matmul weights go through here; replicated tensors
/// (norm gains, embedding) stay f32.
pub(crate) fn synth_quant_shard(name: &str, local_shape: &[usize],
                                world: usize, rank: usize, seed: u64,
                                group: usize)
                                -> anyhow::Result<QuantMat> {
    let axis = shard_axis(name).ok_or_else(|| anyhow::anyhow!(
        "tensor {name:?} is replicated — it has no quantized form"))?;
    let mut full_shape = local_shape.to_vec();
    full_shape[axis] *= world;
    let mut rng = SplitMix64::new(seed);
    let full = synth_fill(name, &full_shape, &mut rng);
    let (k_f, cols_f) = (full_shape[0], full_shape[1]);
    let q = QuantMat::from_f32(&full, k_f, cols_f, group)?;
    if world == 1 {
        return Ok(q);
    }
    match axis {
        0 => {
            let k_l = local_shape[0];
            q.slice_rows(rank * k_l, (rank + 1) * k_l)
        }
        1 => {
            let c_l = local_shape[1];
            q.slice_cols(rank * c_l, (rank + 1) * c_l)
        }
        _ => unreachable!(),
    }
}

pub(crate) fn tensor_seed(base: u64, layer: i64, name: &str) -> u64 {
    let key = format!("{base}/{layer}/{name}");
    fnv1a(key.as_bytes())
}

/// Materialize a rank's weights on its PJRT device.
#[cfg(feature = "xla")]
pub fn load_rank_weights(
    rt: &RankRuntime,
    manifest: &Manifest,
    config: &str,
    world: usize,
    rank: usize,
    batch: usize,
    source: &WeightSource,
) -> Result<RankWeights> {
    let preset = manifest.preset(config)?;
    let n_layers = preset.n_layers;
    let layer_shapes = layer_weight_shapes(manifest, config, world, batch)?;

    // shapes of the non-layer tensors, also manifest-derived
    let embed_seg = manifest.find(config, world, batch, "embed", "decode", 1)?;
    let embed_shape = embed_seg.inputs[1].shape.clone();
    let head_seg = manifest.find(config, world, batch, "lm_head", "decode", 1)?;
    let final_g_shape = head_seg.inputs[1].shape.clone();
    let lm_head_shape = head_seg.inputs[2].shape.clone();

    match source {
        WeightSource::Synthetic { seed } => {
            let mut layers = Vec::with_capacity(n_layers);
            for li in 0..n_layers {
                let mut map = HashMap::new();
                for (name, shape) in &layer_shapes {
                    let data = synth_shard(
                        name, shape, world, rank,
                        tensor_seed(*seed, li as i64, name));
                    map.insert(name.clone(), rt.upload_f32(&data, shape)?);
                }
                layers.push(map);
            }
            // embedding + final norm gain are REPLICATED (identical on
            // every rank — §2.1a depends on this); lm_head is the vocab
            // shard of one fixed full tensor.
            let emb = synth_shard("embedding", &embed_shape, world, rank,
                                  tensor_seed(*seed, -1, "embedding"));
            let fg = synth_shard("final_g", &final_g_shape, world, rank,
                                 tensor_seed(*seed, -1, "final_g"));
            let lm = synth_shard("lm_head", &lm_head_shape, world, rank,
                                 tensor_seed(*seed, -1, "lm_head"));
            Ok(RankWeights {
                embedding: rt.upload_f32(&emb, &embed_shape)?,
                layers,
                final_g: rt.upload_f32(&fg, &final_g_shape)?,
                lm_head: rt.upload_f32(&lm, &lm_head_shape)?,
            })
        }
        WeightSource::NpyDir { dir } => {
            load_npy_weights(rt, dir, rank, n_layers, &layer_shapes)
        }
    }
}

#[cfg(feature = "xla")]
fn load_npy_weights(
    rt: &RankRuntime,
    dir: &Path,
    rank: usize,
    n_layers: usize,
    layer_shapes: &HashMap<String, Vec<usize>>,
) -> Result<RankWeights> {
    let file = |name: &str| dir.join(format!("r{rank}_{name}.npy"));
    if !file("embedding").exists() {
        bail!("golden weights not found in {dir:?} — run `make artifacts`");
    }
    let mut layers = Vec::with_capacity(n_layers);
    for li in 0..n_layers {
        let mut map = HashMap::new();
        for name in layer_shapes.keys() {
            let path = dir.join(format!("r{rank}_l{li}_{name}.npy"));
            map.insert(
                name.clone(),
                rt.load_npy(&path)
                    .with_context(|| format!("loading {path:?}"))?,
            );
        }
        layers.push(map);
    }
    Ok(RankWeights {
        embedding: rt.load_npy(file("embedding"))?,
        layers,
        final_g: rt.load_npy(file("final_g"))?,
        lm_head: rt.load_npy(file("lm_head"))?,
    })
}

#[cfg(feature = "xla")]
impl RankWeights {
    /// Weight buffers of layer `li` in a segment's argument order.
    pub fn layer_args<'a>(&'a self, li: usize, weight_args: &[String])
                          -> Result<Vec<&'a PjRtBuffer>> {
        let map = &self.layers[li];
        weight_args
            .iter()
            .map(|n| {
                map.get(n)
                    .with_context(|| format!("missing weight {n} in layer {li}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_seed_distinct() {
        let a = tensor_seed(0, 0, "wq");
        let b = tensor_seed(0, 1, "wq");
        let c = tensor_seed(0, 0, "wk");
        let d = tensor_seed(1, 0, "wq");
        let all = [a, b, c, d];
        for i in 0..4 {
            for j in i + 1..4 {
                assert_ne!(all[i], all[j]);
            }
        }
    }

    #[test]
    fn synth_shards_concat_to_full() {
        // column-parallel: concat along axis 1 must equal the w1 tensor
        let full = synth_shard("wq", &[6, 8], 1, 0, 42);
        for world in [2usize, 4] {
            let cols_l = 8 / world;
            for rank in 0..world {
                let shard = synth_shard("wq", &[6, cols_l], world, rank, 42);
                for r in 0..6 {
                    for c in 0..cols_l {
                        assert_eq!(
                            shard[r * cols_l + c],
                            full[r * 8 + rank * cols_l + c],
                            "w{world} rank{rank} ({r},{c})"
                        );
                    }
                }
            }
        }
        // row-parallel: concat along axis 0
        let full = synth_shard("wo", &[8, 4], 1, 0, 7);
        for rank in 0..2 {
            let shard = synth_shard("wo", &[4, 4], 2, rank, 7);
            assert_eq!(shard[..], full[rank * 16..(rank + 1) * 16]);
        }
    }

    #[test]
    fn quant_shards_reconstruct_full_tensor_values() {
        // the int8 analogue of synth_shards_concat_to_full: every
        // rank's dequantized shard must reproduce the world-1 values
        // bit-for-bit, on both shard axes
        for (name, rows, cols, group) in
            [("wq", 8usize, 16usize, 4usize), ("wo", 16, 8, 4)]
        {
            let full =
                synth_quant_shard(name, &[rows, cols], 1, 0, 42, group)
                    .unwrap();
            for world in [2usize, 4] {
                for rank in 0..world {
                    let (r_l, c_l, r0, c0) = match shard_axis(name) {
                        Some(0) => (rows / world, cols,
                                    rank * (rows / world), 0),
                        Some(1) => (rows, cols / world, 0,
                                    rank * (cols / world)),
                        _ => unreachable!(),
                    };
                    let shard = synth_quant_shard(
                        name, &[r_l, c_l], world, rank, 42, group)
                        .unwrap();
                    for r in 0..r_l {
                        for c in 0..c_l {
                            assert_eq!(
                                shard.dequant(r, c).to_bits(),
                                full.dequant(r0 + r, c0 + c).to_bits(),
                                "{name} w{world} rank{rank} ({r},{c})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quant_shard_rejects_replicated_tensors() {
        assert!(synth_quant_shard("ln1_g", &[32, 1], 2, 0, 5, 4).is_err());
    }

    #[test]
    fn replicated_tensors_identical_across_ranks() {
        let a = synth_shard("ln1_g", &[32], 4, 0, 5);
        let b = synth_shard("ln1_g", &[32], 4, 3, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn synth_fill_norm_gains_near_one() {
        let mut rng = SplitMix64::new(1);
        let g = synth_fill("ln1_g", &[256], &mut rng);
        let mean = g.iter().sum::<f32>() / g.len() as f32;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn synth_fill_matmul_scaled_by_fan_in() {
        let mut rng = SplitMix64::new(2);
        let w = synth_fill("wq", &[1024, 64], &mut rng);
        let var = w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        // expect var ≈ 1/1024
        assert!((var * 1024.0 - 1.0).abs() < 0.2, "var*fan_in {}", var * 1024.0);
    }

    #[test]
    fn synth_deterministic() {
        let mut a = SplitMix64::new(tensor_seed(5, 2, "wo"));
        let mut b = SplitMix64::new(tensor_seed(5, 2, "wo"));
        assert_eq!(synth_fill("wo", &[8, 8], &mut a),
                   synth_fill("wo", &[8, 8], &mut b));
    }
}
