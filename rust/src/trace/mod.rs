//! Workload trace generation + replay.
//!
//! The paper evaluates with a fixed workload (input 512 tokens, batch 1);
//! the serving benches additionally need open-loop request streams.  We
//! generate deterministic synthetic traces (Poisson arrivals, bounded
//! prompt/output length distributions) as the stand-in for production
//! traces we do not have — see DESIGN.md §4.

#![warn(missing_docs)]

use crate::util::SplitMix64;

/// One request in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRequest {
    /// request id, dense from 0 in arrival order
    pub id: u64,
    /// arrival time offset from trace start, microseconds
    pub arrival_us: u64,
    /// prompt token ids, each in `[0, vocab)`
    pub prompt_tokens: Vec<i32>,
    /// requested output budget (`max_new_tokens` of the API request)
    pub max_new_tokens: usize,
}

/// Synthetic workload parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    /// number of requests to generate
    pub n_requests: usize,
    /// mean arrival rate, requests/second (Poisson); 0 = all at t=0
    pub rate_per_s: f64,
    /// inclusive lower bound on prompt length (≥ 1)
    pub prompt_len_min: usize,
    /// inclusive upper bound on prompt length
    pub prompt_len_max: usize,
    /// inclusive lower bound on requested new tokens
    pub new_tokens_min: usize,
    /// inclusive upper bound on requested new tokens
    pub new_tokens_max: usize,
    /// token id range [0, vocab)
    pub vocab: usize,
    /// RNG seed: equal specs generate equal traces
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            n_requests: 16,
            rate_per_s: 0.0,
            prompt_len_min: 4,
            prompt_len_max: 12,
            new_tokens_min: 4,
            new_tokens_max: 8,
            vocab: 256,
            seed: 0,
        }
    }
}

/// Generate a deterministic trace from a spec.
pub fn generate(spec: &TraceSpec) -> Vec<TraceRequest> {
    assert!(spec.prompt_len_min >= 1);
    assert!(spec.prompt_len_max >= spec.prompt_len_min);
    assert!(spec.new_tokens_max >= spec.new_tokens_min);
    let mut rng = SplitMix64::new(spec.seed);
    let mut t_us = 0u64;
    (0..spec.n_requests)
        .map(|i| {
            if spec.rate_per_s > 0.0 {
                t_us += (rng.next_exp(spec.rate_per_s) * 1e6) as u64;
            }
            let plen = spec.prompt_len_min
                + rng.next_below(spec.prompt_len_max - spec.prompt_len_min
                    + 1);
            let nnew = spec.new_tokens_min
                + rng.next_below(spec.new_tokens_max - spec.new_tokens_min
                    + 1);
            TraceRequest {
                id: i as u64,
                arrival_us: t_us,
                prompt_tokens: (0..plen)
                    .map(|_| rng.next_below(spec.vocab) as i32)
                    .collect(),
                max_new_tokens: nnew,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = TraceSpec { seed: 7, ..Default::default() };
        assert_eq!(generate(&spec), generate(&spec));
    }

    #[test]
    fn respects_bounds() {
        let spec = TraceSpec {
            n_requests: 100,
            prompt_len_min: 3,
            prompt_len_max: 9,
            new_tokens_min: 2,
            new_tokens_max: 2,
            vocab: 64,
            ..Default::default()
        };
        for r in generate(&spec) {
            assert!((3..=9).contains(&r.prompt_tokens.len()));
            assert_eq!(r.max_new_tokens, 2);
            assert!(r.prompt_tokens.iter().all(|&t| (0..64).contains(&t)));
        }
    }

    #[test]
    fn arrivals_monotone() {
        let spec = TraceSpec {
            n_requests: 50,
            rate_per_s: 100.0,
            ..Default::default()
        };
        let trace = generate(&spec);
        for w in trace.windows(2) {
            assert!(w[1].arrival_us >= w[0].arrival_us);
        }
        assert!(trace.last().unwrap().arrival_us > 0);
    }

    #[test]
    fn zero_rate_all_arrive_at_start() {
        for r in generate(&TraceSpec::default()) {
            assert_eq!(r.arrival_us, 0);
        }
    }
}
