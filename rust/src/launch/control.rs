//! The coordinator ⇄ worker control protocol (DESIGN.md §8.2).
//!
//! One TCP connection per worker carries, in order:
//!
//! ```text
//! worker → Hello   { version, rank }                (registration)
//! coord  → Welcome { rank, world, config_toml,      (accept + config/
//!                    mesh_host, mesh_base_port }     mesh bootstrap)
//! coord  → Start                                    (all ranks present —
//!                                                    connect the mesh)
//! ...steady state...
//! coord  → Cmd(..)            engine commands       (engine::proto)
//! worker → Reply(..)          engine replies        (engine::proto)
//! worker → Heartbeat          every HEARTBEAT_PERIOD while idle
//! either → Fatal { message }  unrecoverable error, then close
//! ```
//!
//! Framing: `[len: u32 LE] [type: u8] [payload]`, everything
//! little-endian.  Failure detection is asymmetric by design: the
//! coordinator reads with a [`WORKER_LOSS_TIMEOUT`] deadline (workers
//! heartbeat every [`HEARTBEAT_PERIOD`], so silence means death), while
//! workers block forever and treat EOF/reset as "coordinator gone".

use std::io::{Read, Write};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::engine::proto::{self, Cmd, Reply, WireReader};

/// Bump when the control frame layout changes; `Hello.version` must
/// match the coordinator's or registration is refused.
///
/// v2: `Reply::Ready` grew `weight_bytes`/`kv_bytes` (the §11 memory
/// accounting) — a v1 worker's Ready frame no longer decodes.
///
/// v3: new `Cmd::PrefillChunk` (chunked prefill rounds, DESIGN.md
/// §12) — a v2 worker cannot decode the chunk command, so mixed
/// fleets are refused at registration.
///
/// v4: new reply-less shared-prefix delta commands
/// (`Cmd::AttachPrefix`/`DetachPrefix`/`PublishPrefix`/`DropPrefix`,
/// DESIGN.md §13) and the `scheduler` config key — a v3 worker can
/// decode neither, so mixed fleets are refused at registration.
///
/// v5: speculative decoding (DESIGN.md §15): new
/// `Cmd::DraftDecode`/`Verify`/`TruncateLane`, the `Reply::VerifyDone`
/// frame, and the `spec_draft`/`spec_k` config keys — a v4 worker can
/// decode none of them, so mixed fleets are refused at registration.
///
/// v6: elastic worlds (DESIGN.md §17): new reply-carrying
/// `Cmd::SnapshotLane`/`RestoreLane` and their
/// `Reply::LaneSnapshot`/`LaneRestored` frames, used by the planned
/// quiesce→reshard→restore path — a v5 worker can decode none of
/// them, so mixed fleets are refused at registration.
pub const PROTO_VERSION: u32 = 6;

/// How often an idle worker proves liveness to the coordinator.
pub const HEARTBEAT_PERIOD: Duration = Duration::from_secs(2);

/// Silence threshold after which the coordinator declares a worker
/// dead.  Several heartbeat periods of slack, and deliberately well
/// under [`crate::ccl::RECV_TIMEOUT`] (30 s): the coordinator reports a
/// dead rank before the surviving ranks' mesh collectives hit their own
/// timeout backstop.
pub const WORKER_LOSS_TIMEOUT: Duration = Duration::from_secs(10);

/// Hard cap on a control frame (largest real payload is a batched
/// decode reply: ~`batch · top_k · 8` bytes, far below this).
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// One message on the control connection.
#[derive(Debug, Clone, PartialEq)]
pub enum ControlMsg {
    /// worker → coordinator: request to register as `rank`
    Hello { version: u32, rank: usize },
    /// coordinator → worker: accepted; full config + mesh bootstrap
    Welcome {
        rank: usize,
        world: usize,
        /// `EngineConfig::to_toml_string()` of the coordinator's config
        config_toml: String,
        /// host the rank mesh binds/connects on
        mesh_host: String,
        /// base port of the `TcpTransport::connect_mesh` port block
        mesh_base_port: u16,
    },
    /// coordinator → worker: every rank registered; bring up the mesh
    Start,
    /// coordinator → worker: engine command
    Cmd(Cmd),
    /// worker → coordinator: engine reply
    Reply(Reply),
    /// worker → coordinator: liveness proof while idle
    Heartbeat,
    /// either direction: unrecoverable error, connection closes after
    Fatal { message: String },
}

impl ControlMsg {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ControlMsg::Hello { version, rank } => {
                out.push(0);
                proto::put_u32(out, *version);
                proto::put_u32(out, *rank as u32);
            }
            ControlMsg::Welcome {
                rank, world, config_toml, mesh_host, mesh_base_port,
            } => {
                out.push(1);
                proto::put_u32(out, *rank as u32);
                proto::put_u32(out, *world as u32);
                proto::put_str(out, config_toml);
                proto::put_str(out, mesh_host);
                proto::put_u32(out, *mesh_base_port as u32);
            }
            ControlMsg::Start => out.push(2),
            ControlMsg::Cmd(c) => {
                out.push(3);
                c.encode(out);
            }
            ControlMsg::Reply(r) => {
                out.push(4);
                r.encode(out);
            }
            ControlMsg::Heartbeat => out.push(5),
            ControlMsg::Fatal { message } => {
                out.push(6);
                proto::put_str(out, message);
            }
        }
    }

    fn decode(buf: &[u8]) -> Result<ControlMsg> {
        let mut r = WireReader::new(buf);
        let msg = match r.u8()? {
            0 => {
                let m = ControlMsg::Hello {
                    version: r.u32()?,
                    rank: r.usize32()?,
                };
                r.done()?;
                m
            }
            1 => {
                let m = ControlMsg::Welcome {
                    rank: r.usize32()?,
                    world: r.usize32()?,
                    config_toml: r.str()?,
                    mesh_host: r.str()?,
                    mesh_base_port: r.u32()? as u16,
                };
                r.done()?;
                m
            }
            2 => {
                r.done()?;
                ControlMsg::Start
            }
            // Cmd/Reply own the rest of the frame; their decoders check
            // for trailing bytes themselves.
            3 => ControlMsg::Cmd(Cmd::decode(&buf[1..])?),
            4 => ControlMsg::Reply(Reply::decode(&buf[1..])?),
            5 => {
                r.done()?;
                ControlMsg::Heartbeat
            }
            6 => {
                let m = ControlMsg::Fatal { message: r.str()? };
                r.done()?;
                m
            }
            d => bail!("unknown control message type {d}"),
        };
        Ok(msg)
    }
}

/// Write one length-prefixed control frame.
pub fn write_msg(mut w: impl Write, msg: &ControlMsg) -> Result<()> {
    let mut body = Vec::new();
    msg.encode(&mut body);
    w.write_all(&(body.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(&body))
        .and_then(|_| w.flush())
        .context("control connection write failed")?;
    Ok(())
}

/// Read one length-prefixed control frame (blocking; honors the
/// stream's read timeout).
pub fn read_msg(mut r: impl Read) -> Result<ControlMsg> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr).context("control connection closed")?;
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("control frame of {len} bytes exceeds cap");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("control connection closed")?;
    ControlMsg::decode(&body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::Candidate;

    fn roundtrip(m: ControlMsg) {
        let mut buf = Vec::new();
        write_msg(&mut buf, &m).unwrap();
        let back = read_msg(&buf[..]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn control_messages_roundtrip() {
        roundtrip(ControlMsg::Hello { version: PROTO_VERSION, rank: 3 });
        roundtrip(ControlMsg::Welcome {
            rank: 1,
            world: 4,
            config_toml: "model = \"tiny\"\nworld = 4\n".into(),
            mesh_host: "127.0.0.1".into(),
            mesh_base_port: 41900,
        });
        roundtrip(ControlMsg::Start);
        roundtrip(ControlMsg::Cmd(Cmd::Decode {
            tokens: Some(vec![1, 2]),
            positions: vec![5, 6],
        }));
        roundtrip(ControlMsg::Reply(Reply::StepDone {
            rank: 0,
            compute_us: 12,
            comm_us: 3,
            candidates: Some(vec![vec![Candidate { token: 7, logit: 0.5 }]]),
        }));
        roundtrip(ControlMsg::Heartbeat);
        roundtrip(ControlMsg::Fatal { message: "rank 2 lost".into() });
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_msg(&buf[..]).is_err());
    }

    #[test]
    fn garbage_rejected() {
        // valid length prefix, unknown discriminant
        let mut buf = Vec::new();
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(200);
        assert!(read_msg(&buf[..]).is_err());
        // truncated body
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.push(2);
        assert!(read_msg(&buf[..]).is_err());
    }

    /// Seeded byte-soup fuzz over the frame decoder (same idiom as the
    /// toml_mini and server-JSON fuzzes): a half-dead worker can emit
    /// arbitrary bytes, and the coordinator must turn every one of them
    /// into a clean `Err` (a logged disconnect) — never a panic.  Three
    /// flavors: raw soup through `read_msg`, raw soup straight into
    /// `ControlMsg::decode` (bypassing the length/cap checks), and
    /// bit-flipped corruptions of real frames, which exercise the
    /// deeper `Cmd`/`Reply` decode paths.
    #[test]
    fn decode_never_panics_on_seeded_byte_soup() {
        let mut rng = crate::util::SplitMix64::new(0xDEAD_50C5);
        for _ in 0..4000 {
            let len = rng.next_below(96);
            let soup: Vec<u8> =
                (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = read_msg(&soup[..]); // Ok or Err — just no panic
            let _ = ControlMsg::decode(&soup);
        }
        let real: Vec<ControlMsg> = vec![
            ControlMsg::Hello { version: PROTO_VERSION, rank: 1 },
            ControlMsg::Welcome {
                rank: 0,
                world: 2,
                config_toml: "model = \"tiny\"\n".into(),
                mesh_host: "127.0.0.1".into(),
                mesh_base_port: 41900,
            },
            ControlMsg::Cmd(Cmd::Verify {
                tokens: Some(vec![1, 2, 3]),
                lanes: vec![0, 0, 1],
                positions: vec![4, 5, 2],
            }),
            ControlMsg::Cmd(Cmd::RestoreLane {
                lane: 1,
                len: 2,
                bytes: vec![1, 2, 3, 4],
            }),
            ControlMsg::Reply(Reply::LaneSnapshot {
                rank: 0,
                lane: 1,
                bytes: vec![5, 6, 7],
            }),
            ControlMsg::Reply(Reply::StepDone {
                rank: 0,
                compute_us: 1,
                comm_us: 2,
                candidates: Some(vec![vec![Candidate {
                    token: 3,
                    logit: 0.5,
                }]]),
            }),
        ];
        for msg in &real {
            let mut frame = Vec::new();
            write_msg(&mut frame, msg).unwrap();
            for _ in 0..500 {
                let mut corrupt = frame.clone();
                let flips = 1 + rng.next_below(4);
                for _ in 0..flips {
                    let i = rng.next_below(corrupt.len());
                    corrupt[i] ^= 1 << rng.next_below(8);
                }
                let _ = read_msg(&corrupt[..]); // no panic
            }
        }
    }

    #[test]
    fn timeouts_are_ordered() {
        // heartbeat cadence < loss threshold < mesh recv backstop
        assert!(HEARTBEAT_PERIOD * 3 <= WORKER_LOSS_TIMEOUT);
        assert!(WORKER_LOSS_TIMEOUT < crate::ccl::RECV_TIMEOUT);
    }
}
