//! Multi-process deployment: the coordinator/worker launch runtime
//! (DESIGN.md §8).
//!
//! The paper's serving shape is one rank *process* per Xeon socket,
//! synchronizing over oneCCL.  This module makes that shape first-class
//! instead of an example:
//!
//! * `xeonserve launch --world N` runs the **coordinator**: it owns the
//!   [`EngineConfig`], accepts worker registrations on a control TCP
//!   port, ships each worker the config + mesh bootstrap info
//!   ([`control::ControlMsg::Welcome`]), and then drives the ordinary
//!   [`Engine`] serving loop with each rank behind a
//!   [`RemoteRankHost`].
//! * `xeonserve worker --rank R --coordinator HOST:PORT` runs one
//!   **rank worker** process: it registers, receives its config,
//!   connects the rank-to-rank [`TcpTransport`] mesh, and serves the
//!   same `engine::proto` command stream a rank thread would — the
//!   engine cannot tell the difference.
//!
//! Failure detection: workers heartbeat every
//! [`control::HEARTBEAT_PERIOD`]; the coordinator-side reader declares a
//! worker dead after [`control::WORKER_LOSS_TIMEOUT`] of silence (or
//! instantly on EOF) and injects a `Reply::Error` into the engine's
//! reply channel, so a killed worker surfaces as a clean engine error
//! instead of a hang.  Ranks already blocked inside a collective are
//! unblocked by the mesh's own [`crate::ccl::RECV_TIMEOUT`] backstop.
//!
//! Topology notes: the mesh bootstrap uses the `connect_mesh` port-block
//! scheme, which assumes all ranks can reach `mesh_host` — i.e. one
//! multi-socket machine or a localhost simulation.  The artifacts
//! directory named in the config must be readable by every worker
//! (shared filesystem for true multi-node).

pub mod control;

use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::ccl::{CommGroup, CommStats, TcpTransport};
use crate::config::{EngineConfig, WeightSource};
use crate::engine::proto::{Cmd, Reply};
use crate::engine::{rank::RankWorker, Engine, RankHost};

use control::{read_msg, write_msg, ControlMsg, HEARTBEAT_PERIOD,
              PROTO_VERSION, WORKER_LOSS_TIMEOUT};

/// Coordinator-side knobs for one launch.
#[derive(Clone, Debug)]
pub struct LaunchOptions {
    /// tensor-parallel world size (must equal the config's `world`)
    pub world: usize,
    /// control endpoint workers register against, e.g. "127.0.0.1:7200"
    pub control_addr: String,
    /// host the worker-to-worker mesh binds/connects on
    pub mesh_host: String,
    /// base port of the mesh port block (`connect_mesh` scheme)
    pub mesh_base_port: u16,
    /// how long to wait for all `world` workers to register
    pub register_timeout: Duration,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            world: 2,
            control_addr: "127.0.0.1:7200".into(),
            mesh_host: "127.0.0.1".into(),
            mesh_base_port: 41900,
            register_timeout: Duration::from_secs(120),
        }
    }
}

/// The coordinator's view of a registered worker fleet: one
/// [`RankHost`] per rank plus the funneled reply channel — exactly the
/// ingredients of [`Engine::from_rank_hosts`].
pub struct RankFleet {
    pub hosts: Vec<Box<dyn RankHost>>,
    pub reply_rx: Receiver<Reply>,
    pub stats: Arc<CommStats>,
}

impl RankFleet {
    /// Bring up the engine over this fleet (blocks until every worker
    /// compiled its segments and reported ready).
    pub fn into_engine(self, cfg: EngineConfig) -> Result<Engine> {
        Engine::from_rank_hosts(cfg, self.hosts, self.reply_rx, self.stats)
    }
}

/// A rank worker living in another OS process, driven over the control
/// connection.  The engine-facing mirror of `ThreadRankHost`.
pub struct RemoteRankHost {
    rank: usize,
    stream: TcpStream,
    reader: Option<JoinHandle<()>>,
    /// set before teardown so the reader doesn't report the resulting
    /// EOF as a worker loss
    closing: Arc<AtomicBool>,
}

impl RemoteRankHost {
    /// Wrap an accepted, post-handshake control connection.  Spawns the
    /// reader thread that forwards the worker's replies into
    /// `reply_tx` and watches liveness.
    fn new(rank: usize, stream: TcpStream, reply_tx: Sender<Reply>)
           -> Result<RemoteRankHost> {
        let closing = Arc::new(AtomicBool::new(false));
        let read_half = stream.try_clone().context("clone control stream")?;
        read_half
            .set_read_timeout(Some(WORKER_LOSS_TIMEOUT))
            .context("set control read timeout")?;
        let closing_r = closing.clone();
        let reader = std::thread::Builder::new()
            .name(format!("ctl-rank{rank}"))
            .spawn(move || {
                Self::reader_loop(rank, read_half, reply_tx, closing_r)
            })?;
        Ok(RemoteRankHost { rank, stream, reader: Some(reader), closing })
    }

    fn reader_loop(rank: usize, stream: TcpStream, reply_tx: Sender<Reply>,
                   closing: Arc<AtomicBool>) {
        loop {
            match read_msg(&stream) {
                Ok(ControlMsg::Reply(r)) => {
                    if reply_tx.send(r).is_err() {
                        return; // engine gone
                    }
                }
                Ok(ControlMsg::Heartbeat) => continue,
                Ok(ControlMsg::Fatal { message }) => {
                    let _ = reply_tx.send(Reply::Error { rank, message });
                    return;
                }
                Ok(other) => {
                    let _ = reply_tx.send(Reply::Error {
                        rank,
                        message: format!(
                            "protocol violation from worker: {other:?}"),
                    });
                    return;
                }
                Err(e) => {
                    if !closing.load(Ordering::SeqCst) {
                        let _ = reply_tx.send(Reply::Error {
                            rank,
                            message: format!(
                                "worker rank {rank} lost: {e:#}"),
                        });
                    }
                    return;
                }
            }
        }
    }
}

impl RankHost for RemoteRankHost {
    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&self, cmd: Cmd) -> Result<()> {
        write_msg(&self.stream, &ControlMsg::Cmd(cmd)).with_context(|| {
            format!("sending command to worker rank {}", self.rank)
        })
    }

    fn shutdown(&mut self) {
        self.closing.store(true, Ordering::SeqCst);
        let _ = write_msg(&self.stream, &ControlMsg::Cmd(Cmd::Shutdown));
        // unblock the reader thread (its blocking read returns EOF);
        // already-written frames are still delivered to the worker
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RemoteRankHost {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Coordinator bring-up: bind the control port, register `world`
/// workers (rank-discovery handshake + config distribution), and
/// release them into mesh bring-up.  Returns the fleet; feed it to
/// [`RankFleet::into_engine`].
pub fn coordinate(cfg: &EngineConfig, opts: &LaunchOptions)
                  -> Result<RankFleet> {
    ensure!(cfg.world == opts.world,
            "config world={} but launch --world {}", cfg.world, opts.world);
    let config_toml = cfg.to_toml_string();
    // the TOML number model is f64, so u64 seeds above 2^53 would be
    // silently rounded on the worker side — refuse to ship a config
    // that does not survive the round-trip
    let back = EngineConfig::from_toml_str(&config_toml)
        .context("engine config does not re-parse from TOML")?;
    let seeds_survive = back.sampling.seed == cfg.sampling.seed
        && match (&back.weights, &cfg.weights) {
            (WeightSource::Synthetic { seed: a },
             WeightSource::Synthetic { seed: b }) => a == b,
            (WeightSource::NpyDir { dir: a },
             WeightSource::NpyDir { dir: b }) => a == b,
            _ => false,
        };
    ensure!(seeds_survive,
            "config seeds do not survive TOML distribution (values above \
             2^53 round in the f64 number model) — pick smaller seeds");

    let listener = TcpListener::bind(&opts.control_addr)
        .with_context(|| format!("binding control {}", opts.control_addr))?;
    listener.set_nonblocking(true)?;
    eprintln!(
        "coordinator: waiting for {} workers on {}",
        opts.world, opts.control_addr
    );

    let deadline = Instant::now() + opts.register_timeout;
    let mut slots: Vec<Option<TcpStream>> =
        (0..opts.world).map(|_| None).collect();
    let mut registered = 0;
    while registered < opts.world {
        if Instant::now() > deadline {
            bail!(
                "only {registered} of {} workers registered within {:?}",
                opts.world, opts.register_timeout
            );
        }
        let (stream, peer) = match listener.accept() {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
            Err(e) => return Err(e).context("control accept"),
        };
        stream.set_nonblocking(false)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        match register_worker(&stream, &mut slots, opts, &config_toml) {
            Ok(rank) => {
                eprintln!("coordinator: rank {rank} registered from {peer}");
                registered += 1;
            }
            Err(e) => {
                eprintln!("coordinator: rejected {peer}: {e:#}");
                let _ = write_msg(&stream, &ControlMsg::Fatal {
                    message: format!("{e:#}"),
                });
            }
        }
    }

    // all present: release the fleet into mesh bring-up
    for s in slots.iter().flatten() {
        write_msg(s, &ControlMsg::Start)?;
    }

    let (reply_tx, reply_rx) = channel();
    let mut hosts: Vec<Box<dyn RankHost>> = Vec::with_capacity(opts.world);
    for (rank, slot) in slots.into_iter().enumerate() {
        let stream = slot.unwrap();
        stream.set_read_timeout(Some(WORKER_LOSS_TIMEOUT))?;
        hosts.push(Box::new(RemoteRankHost::new(
            rank, stream, reply_tx.clone())?));
    }
    Ok(RankFleet { hosts, reply_rx, stats: Arc::new(CommStats::default()) })
}

/// Handle one registration handshake; on success the stream is parked
/// in `slots[rank]`.
fn register_worker(stream: &TcpStream, slots: &mut [Option<TcpStream>],
                   opts: &LaunchOptions, config_toml: &str)
                   -> Result<usize> {
    let hello = read_msg(stream).context("reading Hello")?;
    let ControlMsg::Hello { version, rank } = hello else {
        bail!("expected Hello, got {hello:?}");
    };
    ensure!(version == PROTO_VERSION,
            "protocol version mismatch: worker {version}, \
             coordinator {PROTO_VERSION}");
    ensure!(rank < opts.world,
            "rank {rank} out of range for world {}", opts.world);
    ensure!(slots[rank].is_none(), "rank {rank} already registered");
    write_msg(stream, &ControlMsg::Welcome {
        rank,
        world: opts.world,
        config_toml: config_toml.to_string(),
        mesh_host: opts.mesh_host.clone(),
        mesh_base_port: opts.mesh_base_port,
    })?;
    slots[rank] = Some(
        stream.try_clone().context("cloning registered stream")?);
    Ok(rank)
}

/// Worker process entry point: register with the coordinator, receive
/// the config, join the rank mesh, and serve engine commands until
/// shutdown.  Returns once the coordinator says goodbye (clean) or
/// errors out if the coordinator disappears first.
pub fn run_worker(rank: usize, coordinator: &str) -> Result<()> {
    // the coordinator may still be binding its port — retry briefly
    let stream = connect_with_retry(coordinator, Duration::from_secs(30))?;
    stream.set_nodelay(true)?;
    write_msg(&stream, &ControlMsg::Hello { version: PROTO_VERSION, rank })?;

    let welcome = read_msg(&stream).context("reading Welcome")?;
    let (world, config_toml, mesh_host, mesh_base_port) = match welcome {
        ControlMsg::Welcome {
            rank: r, world, config_toml, mesh_host, mesh_base_port,
        } => {
            ensure!(r == rank, "coordinator assigned rank {r}, asked {rank}");
            (world, config_toml, mesh_host, mesh_base_port)
        }
        ControlMsg::Fatal { message } => {
            bail!("coordinator refused registration: {message}")
        }
        other => bail!("expected Welcome, got {other:?}"),
    };
    let cfg = EngineConfig::from_toml_str(&config_toml)
        .context("parsing coordinator config")?;
    ensure!(cfg.world == world,
            "coordinator config world={} but announced world={}",
            cfg.world, world);
    eprintln!("worker rank {rank}/{world}: registered, waiting for start");

    match read_msg(&stream).context("waiting for Start")? {
        ControlMsg::Start => {}
        ControlMsg::Fatal { message } => bail!("launch aborted: {message}"),
        other => bail!("expected Start, got {other:?}"),
    }

    let (cmd_tx, cmd_rx) = channel::<Cmd>();
    let (reply_tx, reply_rx) = channel::<Reply>();

    // command pump: control frames → RankWorker mailbox
    let read_half = stream.try_clone()?;
    let cmd_pump = std::thread::Builder::new()
        .name("cmd-pump".into())
        .spawn(move || loop {
            match read_msg(&read_half) {
                Ok(ControlMsg::Cmd(c)) => {
                    let stop = c == Cmd::Shutdown;
                    if cmd_tx.send(c).is_err() || stop {
                        return;
                    }
                }
                Ok(ControlMsg::Fatal { message }) => {
                    eprintln!("worker: coordinator aborted: {message}");
                    let _ = cmd_tx.send(Cmd::Shutdown);
                    return;
                }
                Ok(other) => {
                    eprintln!("worker: unexpected control frame {other:?}");
                    let _ = cmd_tx.send(Cmd::Shutdown);
                    return;
                }
                Err(e) => {
                    eprintln!("worker: coordinator gone ({e:#})");
                    let _ = cmd_tx.send(Cmd::Shutdown);
                    return;
                }
            }
        })?;

    // reply pump: RankWorker replies → control frames, heartbeats when
    // idle so the coordinator can tell silence from death
    let write_half = stream.try_clone()?;
    let reply_pump = std::thread::Builder::new()
        .name("reply-pump".into())
        .spawn(move || loop {
            let msg = match reply_rx.recv_timeout(HEARTBEAT_PERIOD) {
                Ok(r) => ControlMsg::Reply(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    ControlMsg::Heartbeat
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return;
                }
            };
            if write_msg(&write_half, &msg).is_err() {
                return; // coordinator gone; RankWorker will be told by
                        // the command pump
            }
        })?;

    // rank-to-rank data plane.  This runs AFTER both pumps are up: mesh
    // bring-up can legitimately take tens of seconds (accept deadlines,
    // connect retries), and the reply pump's idle heartbeats are what
    // keep the coordinator's WORKER_LOSS_TIMEOUT reader satisfied
    // meanwhile.  Commands arriving early just queue in the channel.
    let transport = TcpTransport::connect_mesh(
        world, rank, &mesh_host, mesh_base_port)
        .context("connecting rank mesh")?;
    let stats = Arc::new(CommStats::default());
    let comm = CommGroup::from_transport(Box::new(transport), stats);
    eprintln!("worker rank {rank}: mesh up, loading model");

    // the worker's main thread IS the rank worker (PJRT state stays
    // thread-local, same as the in-process rank threads)
    RankWorker::run(rank, cfg, comm, cmd_rx, reply_tx);

    // RankWorker dropped its reply sender, so the reply pump drains and
    // exits; then close the socket (all clones) to unblock the command
    // pump if it is still parked in a read.
    let _ = reply_pump.join();
    let _ = stream.shutdown(std::net::Shutdown::Both);
    let _ = cmd_pump.join();
    eprintln!("worker rank {rank}: clean shutdown");
    Ok(())
}

fn connect_with_retry(addr: &str, budget: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + budget;
    let mut last: Option<std::io::Error> = None;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
        if Instant::now() > deadline {
            bail!("connecting coordinator {addr} failed: {last:?}");
        }
        std::thread::sleep(Duration::from_millis(250));
    }
}

/// Spawn `world` local `xeonserve worker` subprocesses (re-exec'ing the
/// current executable), for single-machine launches and the CI smoke
/// job.  The caller's binary must understand
/// `worker --rank R --coordinator ADDR`.
pub fn spawn_local_workers(world: usize, coordinator: &str)
                           -> Result<Vec<Child>> {
    let exe = std::env::current_exe().context("locating own binary")?;
    let mut children = Vec::with_capacity(world);
    for rank in 0..world {
        children.push(
            Command::new(&exe)
                .args(["worker", "--rank", &rank.to_string(),
                       "--coordinator", coordinator])
                .spawn()
                .with_context(|| format!("spawning worker rank {rank}"))?,
        );
    }
    Ok(children)
}
