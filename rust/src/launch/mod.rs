//! Multi-process deployment: the coordinator/worker launch runtime
//! (DESIGN.md §8).
//!
//! The paper's serving shape is one rank *process* per Xeon socket,
//! synchronizing over oneCCL.  This module makes that shape first-class
//! instead of an example:
//!
//! * `xeonserve launch --world N` runs the **coordinator**: it owns the
//!   [`EngineConfig`], accepts worker registrations on a control TCP
//!   port, ships each worker the config + mesh bootstrap info
//!   ([`control::ControlMsg::Welcome`]), and then drives the ordinary
//!   [`Engine`] serving loop with each rank behind a
//!   [`RemoteRankHost`].
//! * `xeonserve worker --rank R --coordinator HOST:PORT` runs one
//!   **rank worker** process: it registers, receives its config,
//!   connects the rank-to-rank [`TcpTransport`] mesh, and serves the
//!   same `engine::proto` command stream a rank thread would — the
//!   engine cannot tell the difference.
//!
//! Failure detection: a dedicated timer thread on each worker
//! heartbeats every [`control::HEARTBEAT_PERIOD`] regardless of what
//! the reply pump is doing (a pump stalled mid-write on a large frame
//! must not read as death); the coordinator-side reader declares a
//! worker dead after [`control::WORKER_LOSS_TIMEOUT`] of silence (or
//! instantly on EOF) and injects a `worker rank N lost` `Reply::Error`
//! into the engine's reply channel, so a killed worker surfaces as a
//! clean engine error instead of a hang.  Ranks already blocked inside
//! a collective are unblocked by the mesh's own
//! [`crate::ccl::RECV_TIMEOUT`] backstop.
//!
//! Fault tolerance (DESIGN.md §17): that injected error is exactly the
//! shape [`crate::engine::elastic::ElasticEngine`] classifies as a rank
//! failure, and [`RelaunchFactory`] is the piece that closes the loop —
//! a [`crate::engine::elastic::HostFactory`] that re-runs coordination
//! on a fresh port generation so a replacement worker fleet can
//! re-register and the engine can re-shard and replay onto it.  A dead
//! worker then costs a stall, not the deployment.
//!
//! Topology notes: the mesh bootstrap uses the `connect_mesh` port-block
//! scheme, which assumes all ranks can reach `mesh_host` — i.e. one
//! multi-socket machine or a localhost simulation.  The artifacts
//! directory named in the config must be readable by every worker
//! (shared filesystem for true multi-node).

pub mod control;

use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::ccl::{CommGroup, CommStats, TcpTransport};
use crate::config::{EngineConfig, WeightSource};
use crate::engine::elastic::{Fleet, HostFactory};
use crate::engine::proto::{Cmd, Reply};
use crate::engine::{rank::RankWorker, Engine, RankHost};

use control::{read_msg, write_msg, ControlMsg, HEARTBEAT_PERIOD,
              PROTO_VERSION, WORKER_LOSS_TIMEOUT};

/// Coordinator-side knobs for one launch.
#[derive(Clone, Debug)]
pub struct LaunchOptions {
    /// tensor-parallel world size (must equal the config's `world`)
    pub world: usize,
    /// control endpoint workers register against, e.g. "127.0.0.1:7200"
    pub control_addr: String,
    /// host the worker-to-worker mesh binds/connects on
    pub mesh_host: String,
    /// base port of the mesh port block (`connect_mesh` scheme)
    pub mesh_base_port: u16,
    /// how long to wait for all `world` workers to register
    pub register_timeout: Duration,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            world: 2,
            control_addr: "127.0.0.1:7200".into(),
            mesh_host: "127.0.0.1".into(),
            mesh_base_port: 41900,
            register_timeout: Duration::from_secs(120),
        }
    }
}

/// The coordinator's view of a registered worker fleet: one
/// [`RankHost`] per rank plus the funneled reply channel — exactly the
/// ingredients of [`Engine::from_rank_hosts`].
pub struct RankFleet {
    pub hosts: Vec<Box<dyn RankHost>>,
    pub reply_rx: Receiver<Reply>,
    /// sending side of `reply_rx`, kept so elastic wrappers can inject
    /// replies (DESIGN.md §17)
    pub reply_tx: Sender<Reply>,
    pub stats: Arc<CommStats>,
}

impl RankFleet {
    /// Bring up the engine over this fleet (blocks until every worker
    /// compiled its segments and reported ready).
    pub fn into_engine(self, cfg: EngineConfig) -> Result<Engine> {
        Engine::from_rank_hosts(cfg, self.hosts, self.reply_rx, self.stats)
    }
}

/// A rank worker living in another OS process, driven over the control
/// connection.  The engine-facing mirror of `ThreadRankHost`.
pub struct RemoteRankHost {
    rank: usize,
    stream: TcpStream,
    reader: Option<JoinHandle<()>>,
    /// set before teardown so the reader doesn't report the resulting
    /// EOF as a worker loss
    closing: Arc<AtomicBool>,
}

impl RemoteRankHost {
    /// Wrap an accepted, post-handshake control connection.  Spawns the
    /// reader thread that forwards the worker's replies into
    /// `reply_tx` and watches liveness.
    fn new(rank: usize, stream: TcpStream, reply_tx: Sender<Reply>)
           -> Result<RemoteRankHost> {
        let closing = Arc::new(AtomicBool::new(false));
        let read_half = stream.try_clone().context("clone control stream")?;
        read_half
            .set_read_timeout(Some(WORKER_LOSS_TIMEOUT))
            .context("set control read timeout")?;
        let closing_r = closing.clone();
        let reader = std::thread::Builder::new()
            .name(format!("ctl-rank{rank}"))
            .spawn(move || {
                Self::reader_loop(rank, read_half, reply_tx, closing_r)
            })?;
        Ok(RemoteRankHost { rank, stream, reader: Some(reader), closing })
    }

    fn reader_loop(rank: usize, stream: TcpStream, reply_tx: Sender<Reply>,
                   closing: Arc<AtomicBool>) {
        loop {
            match read_msg(&stream) {
                Ok(ControlMsg::Reply(r)) => {
                    if reply_tx.send(r).is_err() {
                        return; // engine gone
                    }
                }
                Ok(ControlMsg::Heartbeat) => continue,
                Ok(ControlMsg::Fatal { message }) => {
                    let _ = reply_tx.send(Reply::Error { rank, message });
                    return;
                }
                Ok(other) => {
                    let _ = reply_tx.send(Reply::Error {
                        rank,
                        message: format!(
                            "protocol violation from worker: {other:?}"),
                    });
                    return;
                }
                Err(e) => {
                    if !closing.load(Ordering::SeqCst) {
                        let _ = reply_tx.send(Reply::Error {
                            rank,
                            message: format!(
                                "worker rank {rank} lost: {e:#}"),
                        });
                    }
                    return;
                }
            }
        }
    }
}

impl RankHost for RemoteRankHost {
    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&self, cmd: Cmd) -> Result<()> {
        write_msg(&self.stream, &ControlMsg::Cmd(cmd)).with_context(|| {
            format!("sending command to worker rank {}", self.rank)
        })
    }

    fn shutdown(&mut self) {
        self.closing.store(true, Ordering::SeqCst);
        let _ = write_msg(&self.stream, &ControlMsg::Cmd(Cmd::Shutdown));
        // unblock the reader thread (its blocking read returns EOF);
        // already-written frames are still delivered to the worker
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RemoteRankHost {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Coordinator bring-up: bind the control port, register `world`
/// workers (rank-discovery handshake + config distribution), and
/// release them into mesh bring-up.  Returns the fleet; feed it to
/// [`RankFleet::into_engine`].
pub fn coordinate(cfg: &EngineConfig, opts: &LaunchOptions)
                  -> Result<RankFleet> {
    ensure!(cfg.world == opts.world,
            "config world={} but launch --world {}", cfg.world, opts.world);
    let config_toml = cfg.to_toml_string();
    // the TOML number model is f64, so u64 seeds above 2^53 would be
    // silently rounded on the worker side — refuse to ship a config
    // that does not survive the round-trip
    let back = EngineConfig::from_toml_str(&config_toml)
        .context("engine config does not re-parse from TOML")?;
    let seeds_survive = back.sampling.seed == cfg.sampling.seed
        && match (&back.weights, &cfg.weights) {
            (WeightSource::Synthetic { seed: a },
             WeightSource::Synthetic { seed: b }) => a == b,
            (WeightSource::NpyDir { dir: a },
             WeightSource::NpyDir { dir: b }) => a == b,
            _ => false,
        };
    ensure!(seeds_survive,
            "config seeds do not survive TOML distribution (values above \
             2^53 round in the f64 number model) — pick smaller seeds");

    let listener = TcpListener::bind(&opts.control_addr)
        .with_context(|| format!("binding control {}", opts.control_addr))?;
    listener.set_nonblocking(true)?;
    eprintln!(
        "coordinator: waiting for {} workers on {}",
        opts.world, opts.control_addr
    );

    let deadline = Instant::now() + opts.register_timeout;
    let mut slots: Vec<Option<TcpStream>> =
        (0..opts.world).map(|_| None).collect();
    let mut registered = 0;
    while registered < opts.world {
        if Instant::now() > deadline {
            bail!(
                "only {registered} of {} workers registered within {:?}",
                opts.world, opts.register_timeout
            );
        }
        let (stream, peer) = match listener.accept() {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
            Err(e) => return Err(e).context("control accept"),
        };
        stream.set_nonblocking(false)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        match register_worker(&stream, &mut slots, opts, &config_toml) {
            Ok(rank) => {
                eprintln!("coordinator: rank {rank} registered from {peer}");
                registered += 1;
            }
            Err(e) => {
                eprintln!("coordinator: rejected {peer}: {e:#}");
                let _ = write_msg(&stream, &ControlMsg::Fatal {
                    message: format!("{e:#}"),
                });
            }
        }
    }

    // all present: release the fleet into mesh bring-up
    for s in slots.iter().flatten() {
        write_msg(s, &ControlMsg::Start)?;
    }

    fleet_from_slots(slots)
}

/// Assemble the [`RankFleet`] from the registration slots.  The
/// registration loop counts each rank exactly once, so a hole here is a
/// coordinator bookkeeping bug — but it must surface as a launch error
/// naming the rank, never as an `unwrap` panic that takes the
/// coordinator down with a useless backtrace.
fn fleet_from_slots(slots: Vec<Option<TcpStream>>) -> Result<RankFleet> {
    let (reply_tx, reply_rx) = channel();
    let mut hosts: Vec<Box<dyn RankHost>> =
        Vec::with_capacity(slots.len());
    for (rank, slot) in slots.into_iter().enumerate() {
        let stream = slot.with_context(|| {
            format!("launch bookkeeping error: rank {rank} counted as \
                     registered but holds no control stream")
        })?;
        stream.set_read_timeout(Some(WORKER_LOSS_TIMEOUT))?;
        hosts.push(Box::new(RemoteRankHost::new(
            rank, stream, reply_tx.clone())?));
    }
    Ok(RankFleet {
        hosts,
        reply_rx,
        reply_tx,
        stats: Arc::new(CommStats::default()),
    })
}

/// Handle one registration handshake; on success the stream is parked
/// in `slots[rank]`.
fn register_worker(stream: &TcpStream, slots: &mut [Option<TcpStream>],
                   opts: &LaunchOptions, config_toml: &str)
                   -> Result<usize> {
    let hello = read_msg(stream).context("reading Hello")?;
    let ControlMsg::Hello { version, rank } = hello else {
        bail!("expected Hello, got {hello:?}");
    };
    ensure!(version == PROTO_VERSION,
            "protocol version mismatch: worker {version}, \
             coordinator {PROTO_VERSION}");
    ensure!(rank < opts.world,
            "rank {rank} out of range for world {}", opts.world);
    ensure!(slots[rank].is_none(), "rank {rank} already registered");
    write_msg(stream, &ControlMsg::Welcome {
        rank,
        world: opts.world,
        config_toml: config_toml.to_string(),
        mesh_host: opts.mesh_host.clone(),
        mesh_base_port: opts.mesh_base_port,
    })?;
    slots[rank] = Some(
        stream.try_clone().context("cloning registered stream")?);
    Ok(rank)
}

/// Worker process entry point: register with the coordinator, receive
/// the config, join the rank mesh, and serve engine commands until
/// shutdown.  Returns once the coordinator says goodbye (clean) or
/// errors out if the coordinator disappears first.
pub fn run_worker(rank: usize, coordinator: &str) -> Result<()> {
    // the coordinator may still be binding its port — retry briefly
    let stream = connect_with_retry(coordinator, Duration::from_secs(30))?;
    stream.set_nodelay(true)?;
    write_msg(&stream, &ControlMsg::Hello { version: PROTO_VERSION, rank })?;

    let welcome = read_msg(&stream).context("reading Welcome")?;
    let (world, config_toml, mesh_host, mesh_base_port) = match welcome {
        ControlMsg::Welcome {
            rank: r, world, config_toml, mesh_host, mesh_base_port,
        } => {
            ensure!(r == rank, "coordinator assigned rank {r}, asked {rank}");
            (world, config_toml, mesh_host, mesh_base_port)
        }
        ControlMsg::Fatal { message } => {
            bail!("coordinator refused registration: {message}")
        }
        other => bail!("expected Welcome, got {other:?}"),
    };
    let cfg = EngineConfig::from_toml_str(&config_toml)
        .context("parsing coordinator config")?;
    ensure!(cfg.world == world,
            "coordinator config world={} but announced world={}",
            cfg.world, world);
    eprintln!("worker rank {rank}/{world}: registered, waiting for start");

    match read_msg(&stream).context("waiting for Start")? {
        ControlMsg::Start => {}
        ControlMsg::Fatal { message } => bail!("launch aborted: {message}"),
        other => bail!("expected Start, got {other:?}"),
    }

    let (cmd_tx, cmd_rx) = channel::<Cmd>();
    let (reply_tx, reply_rx) = channel::<Reply>();

    // command pump: control frames → RankWorker mailbox
    let read_half = stream.try_clone()?;
    let cmd_pump = std::thread::Builder::new()
        .name("cmd-pump".into())
        .spawn(move || loop {
            match read_msg(&read_half) {
                Ok(ControlMsg::Cmd(c)) => {
                    let stop = c == Cmd::Shutdown;
                    if cmd_tx.send(c).is_err() || stop {
                        return;
                    }
                }
                Ok(ControlMsg::Fatal { message }) => {
                    eprintln!("worker: coordinator aborted: {message}");
                    let _ = cmd_tx.send(Cmd::Shutdown);
                    return;
                }
                Ok(other) => {
                    eprintln!("worker: unexpected control frame {other:?}");
                    let _ = cmd_tx.send(Cmd::Shutdown);
                    return;
                }
                Err(e) => {
                    eprintln!("worker: coordinator gone ({e:#})");
                    let _ = cmd_tx.send(Cmd::Shutdown);
                    return;
                }
            }
        })?;

    // reply pump: RankWorker replies → control frames.  The write half
    // is shared with the heartbeat timer below; a control frame is two
    // write_all calls, so the mutex is what keeps the two frame streams
    // from interleaving mid-frame.
    let write_half = Arc::new(Mutex::new(stream.try_clone()?));
    let wh = write_half.clone();
    let reply_pump = std::thread::Builder::new()
        .name("reply-pump".into())
        .spawn(move || {
            while let Ok(r) = reply_rx.recv() {
                let guard = wh.lock().unwrap();
                if write_msg(&*guard, &ControlMsg::Reply(r)).is_err() {
                    return; // coordinator gone; RankWorker will be told
                            // by the command pump
                }
            }
        })?;

    // heartbeat timer: liveness on its own thread, unconditionally.
    // The old design heartbeated from the reply pump's recv timeout,
    // which starves exactly when liveness matters most: a pump stuck in
    // one large write (a multi-megabyte LaneSnapshot reply on a
    // congested socket) sends nothing for the whole stall, and after
    // WORKER_LOSS_TIMEOUT the coordinator declares this worker dead
    // mid-snapshot.  The timer keeps beating whenever the socket (and
    // the shared write mutex) come free, independent of reply traffic.
    let hb_stop = Arc::new(AtomicBool::new(false));
    let heartbeat = spawn_heartbeat(write_half.clone(), HEARTBEAT_PERIOD,
                                    hb_stop.clone())?;

    // rank-to-rank data plane.  This runs AFTER both pumps are up: mesh
    // bring-up can legitimately take tens of seconds (accept deadlines,
    // connect retries), and the reply pump's idle heartbeats are what
    // keep the coordinator's WORKER_LOSS_TIMEOUT reader satisfied
    // meanwhile.  Commands arriving early just queue in the channel.
    let transport = TcpTransport::connect_mesh(
        world, rank, &mesh_host, mesh_base_port)
        .context("connecting rank mesh")?;
    let stats = Arc::new(CommStats::default());
    let comm = CommGroup::from_transport(Box::new(transport), stats);
    eprintln!("worker rank {rank}: mesh up, loading model");

    // the worker's main thread IS the rank worker (PJRT state stays
    // thread-local, same as the in-process rank threads)
    RankWorker::run(rank, cfg, comm, cmd_rx, reply_tx);

    // RankWorker dropped its reply sender, so the reply pump drains and
    // exits; stop the heartbeat timer, then close the socket (all
    // clones) to unblock the command pump if it is still parked in a
    // read.
    let _ = reply_pump.join();
    hb_stop.store(true, Ordering::SeqCst);
    let _ = heartbeat.join();
    let _ = stream.shutdown(std::net::Shutdown::Both);
    let _ = cmd_pump.join();
    eprintln!("worker rank {rank}: clean shutdown");
    Ok(())
}

/// Spawn the worker-side liveness timer: one [`ControlMsg::Heartbeat`]
/// per `period` on `write_half`, sharing the frame mutex with the reply
/// pump so heartbeats never interleave into the middle of a reply
/// frame.  Exits when `stop` is raised (checked every 25 ms, so worker
/// shutdown stays prompt) or when the socket dies.
fn spawn_heartbeat(write_half: Arc<Mutex<TcpStream>>, period: Duration,
                   stop: Arc<AtomicBool>) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name("heartbeat".into())
        .spawn(move || {
            let tick = Duration::from_millis(25).min(period);
            let mut last = Instant::now();
            loop {
                std::thread::sleep(tick);
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if last.elapsed() < period {
                    continue;
                }
                let guard = write_half.lock().unwrap();
                if write_msg(&*guard, &ControlMsg::Heartbeat).is_err() {
                    return; // socket gone — the pumps own teardown
                }
                last = Instant::now();
            }
        })
        .context("spawning heartbeat thread")
}

fn connect_with_retry(addr: &str, budget: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + budget;
    let mut last: Option<std::io::Error> = None;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
        if Instant::now() > deadline {
            bail!("connecting coordinator {addr} failed: {last:?}");
        }
        std::thread::sleep(Duration::from_millis(250));
    }
}

/// Spawn `world` local `xeonserve worker` subprocesses (re-exec'ing the
/// current executable), for single-machine launches and the CI smoke
/// job.  The caller's binary must understand
/// `worker --rank R --coordinator ADDR`.
pub fn spawn_local_workers(world: usize, coordinator: &str)
                           -> Result<Vec<Child>> {
    let exe = std::env::current_exe().context("locating own binary")?;
    let mut children = Vec::with_capacity(world);
    for rank in 0..world {
        children.push(
            Command::new(&exe)
                .args(["worker", "--rank", &rank.to_string(),
                       "--coordinator", coordinator])
                .spawn()
                .with_context(|| format!("spawning worker rank {rank}"))?,
        );
    }
    Ok(children)
}

/// The distributed-deployment [`HostFactory`] (DESIGN.md §17): rebuild
/// a worker fleet by re-running coordination.  Each build uses a fresh
/// *port generation* — control port and mesh port block shifted by a
/// per-generation stride — because the previous generation's sockets
/// may still sit in TIME_WAIT, and a replacement fleet must not race
/// the corpse of the old one for its ports.
///
/// With `spawn_local`, every build re-execs `world` local worker
/// processes against the new control port (single-machine deployments
/// and the CI chaos leg); otherwise the factory only listens, and
/// re-admission is the operator's job — surviving workers are expected
/// to be restarted by whatever supervises them, pointing at the
/// generation's control address printed by the coordinator.
pub struct RelaunchFactory {
    opts: LaunchOptions,
    /// re-exec local worker processes on every build
    pub spawn_local: bool,
    generation: u16,
}

/// Port stride between fleet generations: covers the mesh port block of
/// any supported world size with room to spare.
const GENERATION_PORT_STRIDE: u16 = 64;

impl RelaunchFactory {
    /// Factory whose generation 0 matches `opts` exactly (so the first
    /// build is indistinguishable from a plain [`coordinate`] call).
    pub fn new(opts: LaunchOptions, spawn_local: bool) -> RelaunchFactory {
        RelaunchFactory { opts, spawn_local, generation: 0 }
    }

    /// Factory for a deployment whose *initial* fleet was already
    /// coordinated on `opts` by the caller: builds start at generation
    /// 1, so the first replacement fleet never fights the original's
    /// ports.
    pub fn for_replacements(opts: LaunchOptions, spawn_local: bool)
                            -> RelaunchFactory {
        RelaunchFactory { opts, spawn_local, generation: 1 }
    }

    /// The launch options of generation `g`.
    fn generation_opts(&self, g: u16, world: usize)
                       -> Result<LaunchOptions> {
        let mut opts = self.opts.clone();
        opts.world = world;
        let (host, port) = self
            .opts
            .control_addr
            .rsplit_once(':')
            .with_context(|| format!("control address {:?} has no port",
                                     self.opts.control_addr))?;
        let port: u16 = port.parse().with_context(|| {
            format!("control address {:?} port", self.opts.control_addr)
        })?;
        let shift = g.checked_mul(GENERATION_PORT_STRIDE)
            .context("fleet generation counter overflowed")?;
        opts.control_addr = format!(
            "{host}:{}",
            port.checked_add(shift)
                .context("control port generation overflowed")?);
        opts.mesh_base_port = self
            .opts
            .mesh_base_port
            .checked_add(shift)
            .context("mesh port generation overflowed")?;
        Ok(opts)
    }
}

impl HostFactory for RelaunchFactory {
    fn build(&mut self, cfg: &EngineConfig) -> Result<Fleet> {
        let opts = self.generation_opts(self.generation, cfg.world)?;
        self.generation += 1;
        if self.spawn_local {
            // children are detached on purpose: they exit on the
            // engine's Shutdown command, and a fleet that dies early is
            // exactly what the next generation recovers from
            let _ = spawn_local_workers(cfg.world, &opts.control_addr)?;
        } else {
            eprintln!(
                "coordinator: fleet generation {} registering on {}",
                self.generation, opts.control_addr
            );
        }
        let fleet = coordinate(cfg, &opts)?;
        Ok(Fleet {
            hosts: fleet.hosts,
            reply_rx: fleet.reply_rx,
            reply_tx: fleet.reply_tx,
            stats: fleet.stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite regression (PR 10): a hole in the registration slots
    /// must come back as a launch error naming the rank — the old code
    /// `unwrap()`ed the slot and took the whole coordinator down.
    #[test]
    fn fleet_assembly_reports_missing_rank_instead_of_panicking() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let c0 = TcpStream::connect(addr).unwrap();
        let (_s0, _) = listener.accept().unwrap();
        let err = fleet_from_slots(vec![Some(c0), None]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "error does not name the \
                                         missing rank: {msg}");
    }

    #[test]
    fn empty_slot_list_builds_an_empty_fleet() {
        let fleet = fleet_from_slots(Vec::new()).unwrap();
        assert!(fleet.hosts.is_empty());
    }

    /// Satellite regression (PR 10): heartbeats must keep flowing while
    /// the reply pump is busy or stalled — the old design only
    /// heartbeated from the pump's idle timeout, so a slow round of
    /// large replies starved liveness until the coordinator declared
    /// the worker dead.  Also pins the frame-interleaving contract:
    /// heartbeats and large reply frames share one socket and must
    /// never corrupt each other mid-frame.
    #[test]
    fn heartbeat_timer_survives_busy_reply_traffic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        client.set_nodelay(true).unwrap();

        let write_half = Arc::new(Mutex::new(client));
        let stop = Arc::new(AtomicBool::new(false));
        let period = Duration::from_millis(50);
        let hb = spawn_heartbeat(write_half.clone(), period,
                                 stop.clone())
            .unwrap();

        // a "reply pump" that goes quiet for 4 periods (the slow
        // round), then blasts large frames through the shared mutex
        let n_replies = 20usize;
        let pump = {
            let wh = write_half.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(200));
                for i in 0..n_replies {
                    let guard = wh.lock().unwrap();
                    write_msg(&*guard, &ControlMsg::Reply(Reply::Error {
                        rank: 0,
                        message: format!("{i}:").repeat(20_000),
                    }))
                    .unwrap();
                }
            })
        };

        server.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let (mut beats, mut replies) = (0usize, 0usize);
        while replies < n_replies {
            match read_msg(&server) {
                Ok(ControlMsg::Heartbeat) => beats += 1,
                Ok(ControlMsg::Reply(_)) => replies += 1,
                Ok(other) => panic!("unexpected frame {other:?}"),
                Err(e) => panic!("control stream corrupted: {e:#}"),
            }
        }
        assert!(beats >= 2,
                "only {beats} heartbeats during a 200 ms stall at 50 ms \
                 period — the timer starved");

        stop.store(true, Ordering::SeqCst);
        pump.join().unwrap();
        hb.join().unwrap();
    }

    /// Each fleet generation must move to a disjoint port block and
    /// carry the (possibly resized) world.
    #[test]
    fn relaunch_generations_shift_ports() {
        let opts = LaunchOptions {
            world: 4,
            control_addr: "127.0.0.1:7200".into(),
            mesh_base_port: 41900,
            ..LaunchOptions::default()
        };
        let f = RelaunchFactory::new(opts, false);
        let g0 = f.generation_opts(0, 4).unwrap();
        assert_eq!(g0.control_addr, "127.0.0.1:7200");
        assert_eq!(g0.mesh_base_port, 41900);
        assert_eq!(g0.world, 4);
        let g2 = f.generation_opts(2, 2).unwrap();
        assert_eq!(g2.control_addr, "127.0.0.1:7328");
        assert_eq!(g2.mesh_base_port, 42028);
        assert_eq!(g2.world, 2, "resize must ride the next generation");
        // a port near the top of the range overflows cleanly
        let high = RelaunchFactory::new(
            LaunchOptions {
                control_addr: "127.0.0.1:65530".into(),
                ..LaunchOptions::default()
            },
            false,
        );
        assert!(high.generation_opts(2, 2).is_err());
    }
}
