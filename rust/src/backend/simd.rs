//! Runtime CPU-feature detection and the SIMD row kernels behind the
//! reference backend's GEMMs (DESIGN.md §14).
//!
//! Detect-then-dispatch: the backend resolves an [`Isa`] tier once at
//! construction ([`resolve`]) and every GEMM inner loop funnels
//! through [`crate::backend::quant::WeightMat::mac_panel`], which
//! selects the matching row kernel here.  Three rules keep the repo's
//! bit-identity contract intact:
//!
//! * The f32 tiers (`avx2`, `avx512`) vectorize **across output
//!   columns** with *unfused* per-lane multiply-then-add — the exact
//!   two IEEE-754 operations of the scalar chain
//!   `acc[j] += x[k] * w[k][j]`, in the same ascending-k order, just
//!   8/16 columns per instruction.  No FMA (which rounds once instead
//!   of twice) and no horizontal re-association ever touches an
//!   accumulator, so every output bit matches the scalar kernel, and
//!   auto-detection is safe even on heterogeneous fleets: ranks may
//!   resolve different f32 tiers and still bit-agree.
//! * `vnni` is not an f32 tier: it is the W8A8 integer *scheme* —
//!   activations quantized to u8 per weight-quant-group, weights kept
//!   i8, dot products accumulated in exact i32 arithmetic.  Hardware
//!   `vpdpbusd` runs when the CPU has AVX-512 VNNI ([`vnni_hw`]) and
//!   an exact scalar integer emulation otherwise, so the tier is
//!   selectable (and CI-testable) on any host with identical results.
//!   Because its numerics differ from the f32 chain it is never
//!   auto-selected: `isa = "vnni"` is an explicit opt-in, and it only
//!   governs int8 weight matmuls (f32 matrices under a forced vnni
//!   run the scalar chain).
//! * Forcing a tier the CPU lacks is a hard error, never a silent
//!   fallback — a bench row or parity run must execute the tier its
//!   label claims.  (`scalar` and `vnni` are runnable everywhere.)

#![warn(missing_docs)]

use anyhow::{bail, Result};

use crate::config::IsaKind;

/// Environment override consumed by [`resolve`]: CI's ISA axis sets
/// `XEONSERVE_FORCE_ISA=scalar|avx2|avx512|vnni` per process so the
/// whole test suite and launch smokes run under one forced tier
/// without touching any config file.
pub const FORCE_ISA_ENV: &str = "XEONSERVE_FORCE_ISA";

/// A concrete instruction tier the backend executes with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar Rust — the pinned baseline every other tier is
    /// gated against.
    Scalar,
    /// 8-lane AVX2 f32 rows (unfused mul+add; bit-identical to
    /// scalar).
    Avx2,
    /// 16-lane AVX-512F f32 rows (unfused mul+add; bit-identical).
    Avx512,
    /// W8A8 integer scheme for int8 weights: hardware `vpdpbusd` when
    /// the CPU has AVX-512 VNNI, exact scalar emulation otherwise.
    Vnni,
}

impl Isa {
    /// Every tier, in escalation order (listings and CI loops).
    pub const ALL: [Isa; 4] =
        [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Vnni];

    /// Strict parse of the CLI/env spelling; unknown strings are a
    /// clean error, never a silent fallback.
    pub fn parse(s: &str) -> Result<Isa> {
        match s {
            "scalar" => Ok(Isa::Scalar),
            "avx2" => Ok(Isa::Avx2),
            "avx512" => Ok(Isa::Avx512),
            "vnni" => Ok(Isa::Vnni),
            _ => bail!("unknown isa {s:?} (scalar|avx2|avx512|vnni)"),
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Isa::Scalar => write!(f, "scalar"),
            Isa::Avx2 => write!(f, "avx2"),
            Isa::Avx512 => write!(f, "avx512"),
            Isa::Vnni => write!(f, "vnni"),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn have_avx2() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn have_avx2() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn have_avx512() -> bool {
    std::is_x86_feature_detected!("avx512f")
}

#[cfg(not(target_arch = "x86_64"))]
fn have_avx512() -> bool {
    false
}

/// Does the CPU have the `vpdpbusd` fast path for the vnni tier?
/// Purely a speed question: the emulation computes the identical
/// integer sums when this is false.
#[cfg(target_arch = "x86_64")]
pub fn vnni_hw() -> bool {
    std::is_x86_feature_detected!("avx512f")
        && std::is_x86_feature_detected!("avx512bw")
        && std::is_x86_feature_detected!("avx512vnni")
}

/// Non-x86 hosts never have the hardware path.
#[cfg(not(target_arch = "x86_64"))]
pub fn vnni_hw() -> bool {
    false
}

/// Can this CPU run `isa`?  `Scalar` always; `Vnni` always (the
/// scheme has an exact integer emulation — [`vnni_hw`] only gates the
/// fast path); the f32 tiers need their CPUID feature bits.
pub fn available(isa: Isa) -> bool {
    match isa {
        Isa::Scalar | Isa::Vnni => true,
        Isa::Avx2 => have_avx2(),
        Isa::Avx512 => have_avx512(),
    }
}

/// The widest *bit-identical* f32 tier this CPU has — what
/// `isa = "auto"` resolves to.  Never [`Isa::Vnni`]: its numerics
/// differ from the scalar chain, so it must be asked for by name.
pub fn detect_best() -> Isa {
    if available(Isa::Avx512) {
        Isa::Avx512
    } else if available(Isa::Avx2) {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

/// Map the config knob to a concrete tier, honoring the
/// [`FORCE_ISA_ENV`] override (highest precedence — CI's ISA axis).
/// Forcing a tier the CPU lacks is a hard error.
pub fn resolve(kind: IsaKind) -> Result<Isa> {
    let forced = std::env::var(FORCE_ISA_ENV).ok();
    resolve_with(forced.as_deref(), kind)
}

/// [`resolve`] with the env override passed explicitly, so the
/// precedence rules are testable without mutating process-global
/// state (env mutation would race the rest of the parallel test
/// binary through every backend construction).
pub fn resolve_with(env_force: Option<&str>, kind: IsaKind)
                    -> Result<Isa> {
    let want = match env_force {
        Some(s) => Some(Isa::parse(s).map_err(|e| {
            e.context(format!("parsing {FORCE_ISA_ENV}"))
        })?),
        None => match kind {
            IsaKind::Auto => None,
            IsaKind::Scalar => Some(Isa::Scalar),
            IsaKind::Avx2 => Some(Isa::Avx2),
            IsaKind::Avx512 => Some(Isa::Avx512),
            IsaKind::Vnni => Some(Isa::Vnni),
        },
    };
    match want {
        None => Ok(detect_best()),
        Some(isa) => {
            if !available(isa) {
                bail!(
                    "isa \"{isa}\" was forced but this CPU does not \
                     support it (auto would pick \"{}\"); a silent \
                     fallback would mislabel parity runs and bench \
                     rows, so this is a hard error",
                    detect_best()
                );
            }
            Ok(isa)
        }
    }
}

// ---------------------------------------------------------------------
// f32 row kernels: acc[j] += xk * w[j]
//
// Each wrapper is safe to call only through a resolved Isa (resolve
// checked the feature bits); the non-x86 bodies are unreachable in
// practice but keep the crate portable.
// ---------------------------------------------------------------------

/// `acc[j] += xk * w[j]` over 8-lane AVX2 with a scalar tail — the
/// unfused per-lane twin of the scalar chain.
pub fn mac_row_f32_avx2(xk: f32, w: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(w.len(), acc.len());
    #[cfg(target_arch = "x86_64")]
    // SAFETY: Isa::Avx2 only resolves when the avx2 feature bit was
    // detected at runtime (resolve/available).
    unsafe {
        mac_row_f32_avx2_impl(xk, w, acc)
    }
    #[cfg(not(target_arch = "x86_64"))]
    for (a, &wj) in acc.iter_mut().zip(w) {
        *a += xk * wj;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mac_row_f32_avx2_impl(xk: f32, w: &[f32], acc: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let xs = _mm256_set1_ps(xk);
    let mut j = 0;
    while j + 8 <= n {
        let wv = _mm256_loadu_ps(w.as_ptr().add(j));
        let av = _mm256_loadu_ps(acc.as_ptr().add(j));
        // unfused mul then add: the exact scalar op pair per lane
        let prod = _mm256_mul_ps(xs, wv);
        _mm256_storeu_ps(acc.as_mut_ptr().add(j),
                         _mm256_add_ps(av, prod));
        j += 8;
    }
    while j < n {
        acc[j] += xk * w[j];
        j += 1;
    }
}

/// `acc[j] += xk * w[j]` over 16-lane AVX-512F with a scalar tail.
pub fn mac_row_f32_avx512(xk: f32, w: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(w.len(), acc.len());
    #[cfg(target_arch = "x86_64")]
    // SAFETY: Isa::Avx512 only resolves when the avx512f feature bit
    // was detected at runtime (resolve/available).
    unsafe {
        mac_row_f32_avx512_impl(xk, w, acc)
    }
    #[cfg(not(target_arch = "x86_64"))]
    for (a, &wj) in acc.iter_mut().zip(w) {
        *a += xk * wj;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn mac_row_f32_avx512_impl(xk: f32, w: &[f32],
                                  acc: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let xs = _mm512_set1_ps(xk);
    let mut j = 0;
    while j + 16 <= n {
        let wv = _mm512_loadu_ps(w.as_ptr().add(j));
        let av = _mm512_loadu_ps(acc.as_ptr().add(j));
        let prod = _mm512_mul_ps(xs, wv);
        _mm512_storeu_ps(acc.as_mut_ptr().add(j),
                         _mm512_add_ps(av, prod));
        j += 16;
    }
    while j < n {
        acc[j] += xk * w[j];
        j += 1;
    }
}

// ---------------------------------------------------------------------
// int8-dequant row kernels: acc[j] += xk * (q[j] as f32 * s[j])
//
// i8 -> i32 -> f32 conversion is exact (|q| <= 127), and the three
// f32 ops replicate the scalar dequant chain in order, so these are
// bit-identical to WeightMat::mac_row on Int8 just like the f32
// kernels are on F32.
// ---------------------------------------------------------------------

/// int8-dequant row MAC over 8-lane AVX2 with a scalar tail.
pub fn mac_row_i8_avx2(xk: f32, q: &[i8], s: &[f32],
                       acc: &mut [f32]) {
    debug_assert_eq!(q.len(), acc.len());
    debug_assert_eq!(s.len(), acc.len());
    #[cfg(target_arch = "x86_64")]
    // SAFETY: Isa::Avx2 only resolves when the avx2 feature bit was
    // detected at runtime (resolve/available).
    unsafe {
        mac_row_i8_avx2_impl(xk, q, s, acc)
    }
    #[cfg(not(target_arch = "x86_64"))]
    for ((a, &qj), &sj) in acc.iter_mut().zip(q).zip(s) {
        *a += xk * (qj as f32 * sj);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mac_row_i8_avx2_impl(xk: f32, q: &[i8], s: &[f32],
                               acc: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let xs = _mm256_set1_ps(xk);
    let mut j = 0;
    while j + 8 <= n {
        // 8 bytes -> 8 exact f32 lanes
        let qb = _mm_loadl_epi64(q.as_ptr().add(j) as *const _);
        let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qb));
        let sv = _mm256_loadu_ps(s.as_ptr().add(j));
        // dequant then scale then add: the scalar chain's op order
        let deq = _mm256_mul_ps(qf, sv);
        let prod = _mm256_mul_ps(xs, deq);
        let av = _mm256_loadu_ps(acc.as_ptr().add(j));
        _mm256_storeu_ps(acc.as_mut_ptr().add(j),
                         _mm256_add_ps(av, prod));
        j += 8;
    }
    while j < n {
        acc[j] += xk * (q[j] as f32 * s[j]);
        j += 1;
    }
}

/// int8-dequant row MAC over 16-lane AVX-512F with a scalar tail.
pub fn mac_row_i8_avx512(xk: f32, q: &[i8], s: &[f32],
                         acc: &mut [f32]) {
    debug_assert_eq!(q.len(), acc.len());
    debug_assert_eq!(s.len(), acc.len());
    #[cfg(target_arch = "x86_64")]
    // SAFETY: Isa::Avx512 only resolves when the avx512f feature bit
    // was detected at runtime (resolve/available).
    unsafe {
        mac_row_i8_avx512_impl(xk, q, s, acc)
    }
    #[cfg(not(target_arch = "x86_64"))]
    for ((a, &qj), &sj) in acc.iter_mut().zip(q).zip(s) {
        *a += xk * (qj as f32 * sj);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn mac_row_i8_avx512_impl(xk: f32, q: &[i8], s: &[f32],
                                 acc: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let xs = _mm512_set1_ps(xk);
    let mut j = 0;
    while j + 16 <= n {
        let qb = _mm_loadu_si128(q.as_ptr().add(j) as *const _);
        let qf = _mm512_cvtepi32_ps(_mm512_cvtepi8_epi32(qb));
        let sv = _mm512_loadu_ps(s.as_ptr().add(j));
        let deq = _mm512_mul_ps(qf, sv);
        let prod = _mm512_mul_ps(xs, deq);
        let av = _mm512_loadu_ps(acc.as_ptr().add(j));
        _mm512_storeu_ps(acc.as_mut_ptr().add(j),
                         _mm512_add_ps(av, prod));
        j += 16;
    }
    while j < n {
        acc[j] += xk * (q[j] as f32 * s[j]);
        j += 1;
    }
}

// ---------------------------------------------------------------------
// vnni: the hardware vpdpbusd group dot over the QuantMat 4-k pack
// ---------------------------------------------------------------------

/// Hardware `vpdpbusd` group dot over a 4-k weight pack:
/// `idot[j - j0] += sum_k u[k] * q[k][j]` for 16-column blocks of
/// `[j0, j1)`.
///
/// `pack` is one quant group's panel region in the
/// [`crate::backend::quant::QuantMat`] pack layout: panel `p` holds,
/// for every column `j`, the 4 weight bytes of rows `4p..4p+4` at
/// byte offset `(p * cols + j) * 4` (zero-padded past the group
/// tail).  `u` is the group's quantized activation bytes; its tail
/// pad is zeroed here, and 0·0 contributes nothing, so ragged groups
/// sum exactly like the emulation.
///
/// Returns the number of columns processed — the largest multiple of
/// 16 `<= j1 - j0`; the caller finishes the ragged column tail with
/// the scalar emulation.
///
/// # Safety
///
/// Caller must have verified [`vnni_hw`] (the pack is only ever built
/// when it holds).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn dot_pack_dpbusd(u: &[u8], pack: &[i8], cols: usize,
                              j0: usize, j1: usize,
                              idot: &mut [i32]) -> usize {
    use std::arch::x86_64::*;
    let panels = u.len().div_ceil(4);
    debug_assert!(pack.len() >= panels * cols * 4);
    debug_assert!(idot.len() >= j1 - j0);
    // per-panel broadcast words: the same 4 activation bytes feed
    // every column lane of a vpdpbusd
    let words: Vec<i32> = (0..panels)
        .map(|p| {
            let mut b = [0u8; 4];
            for (i, dst) in b.iter_mut().enumerate() {
                if let Some(&v) = u.get(4 * p + i) {
                    *dst = v;
                }
            }
            i32::from_le_bytes(b)
        })
        .collect();
    let full = (j1 - j0) / 16 * 16;
    let mut jb = 0;
    while jb < full {
        let j = j0 + jb;
        let mut acc =
            _mm512_loadu_si512(idot.as_ptr().add(jb) as *const _);
        for (p, &word) in words.iter().enumerate() {
            let a = _mm512_set1_epi32(word);
            let w = _mm512_loadu_si512(
                pack.as_ptr().add((p * cols + j) * 4) as *const _);
            acc = _mm512_dpbusd_epi32(acc, a, w);
        }
        _mm512_storeu_si512(idot.as_mut_ptr().add(jb) as *mut _, acc);
        jb += 16;
    }
    full
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg_f32(state: &mut u64) -> f32 {
        *state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        // modest magnitudes so sums stay well inside f32 range
        ((*state >> 40) as i32 % 1000) as f32 / 257.0
    }

    fn scalar_f32(xk: f32, w: &[f32], acc: &mut [f32]) {
        for (a, &wj) in acc.iter_mut().zip(w) {
            *a += xk * wj;
        }
    }

    fn scalar_i8(xk: f32, q: &[i8], s: &[f32], acc: &mut [f32]) {
        for ((a, &qj), &sj) in acc.iter_mut().zip(q).zip(s) {
            *a += xk * (qj as f32 * sj);
        }
    }

    #[test]
    fn parse_display_roundtrip() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(&isa.to_string()).unwrap(), isa);
        }
        assert!(Isa::parse("sse").is_err());
        assert!(Isa::parse("AVX2").is_err());
        assert!(Isa::parse("auto").is_err(), "auto is a config kind, \
                 not a concrete tier");
    }

    #[test]
    fn resolve_precedence_and_availability() {
        // auto picks the detected best, never vnni
        let best = resolve_with(None, IsaKind::Auto).unwrap();
        assert_eq!(best, detect_best());
        assert_ne!(best, Isa::Vnni);
        // scalar and vnni resolve on every host
        assert_eq!(resolve_with(None, IsaKind::Scalar).unwrap(),
                   Isa::Scalar);
        assert_eq!(resolve_with(None, IsaKind::Vnni).unwrap(),
                   Isa::Vnni);
        // the env override wins over the config knob
        assert_eq!(resolve_with(Some("scalar"), IsaKind::Avx512)
                       .unwrap(),
                   Isa::Scalar);
        assert_eq!(resolve_with(Some("vnni"), IsaKind::Scalar)
                       .unwrap(),
                   Isa::Vnni);
        // garbage in the env is a clean error
        assert!(resolve_with(Some("amx"), IsaKind::Auto).is_err());
        // forcing an unavailable f32 tier is a hard error
        for isa in [Isa::Avx2, Isa::Avx512] {
            let kind = match isa {
                Isa::Avx2 => IsaKind::Avx2,
                _ => IsaKind::Avx512,
            };
            let r = resolve_with(None, kind);
            if available(isa) {
                assert_eq!(r.unwrap(), isa);
            } else {
                assert!(r.is_err());
            }
        }
    }

    #[test]
    fn detection_is_consistent() {
        assert!(available(Isa::Scalar));
        assert!(available(Isa::Vnni));
        assert!(available(detect_best()));
        if vnni_hw() {
            // the hardware fast path implies the avx512 f32 tier
            assert!(available(Isa::Avx512));
        }
    }

    #[test]
    fn f32_rows_match_scalar_bitwise() {
        // silently a no-op on hosts without the tiers (CI's ISA axis
        // covers them on capable runners)
        let mut st = 0x5eed_0001u64;
        for n in [1usize, 7, 8, 9, 15, 16, 17, 31, 64, 100] {
            let w: Vec<f32> =
                (0..n).map(|_| lcg_f32(&mut st)).collect();
            let xk = lcg_f32(&mut st);
            let base: Vec<f32> =
                (0..n).map(|_| lcg_f32(&mut st)).collect();
            let mut want = base.clone();
            scalar_f32(xk, &w, &mut want);
            if available(Isa::Avx2) {
                let mut got = base.clone();
                mac_row_f32_avx2(xk, &w, &mut got);
                assert_eq!(got.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           want.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           "avx2 f32 row diverged at n={n}");
            }
            if available(Isa::Avx512) {
                let mut got = base.clone();
                mac_row_f32_avx512(xk, &w, &mut got);
                assert_eq!(got.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           want.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           "avx512 f32 row diverged at n={n}");
            }
        }
    }

    #[test]
    fn i8_rows_match_scalar_bitwise() {
        let mut st = 0x5eed_0002u64;
        for n in [1usize, 7, 8, 9, 15, 16, 17, 31, 64, 100] {
            let q: Vec<i8> = (0..n)
                .map(|_| (lcg_f32(&mut st) * 64.0) as i8)
                .collect();
            let s: Vec<f32> = (0..n)
                .map(|_| lcg_f32(&mut st).abs() / 100.0 + 1e-3)
                .collect();
            let xk = lcg_f32(&mut st);
            let base: Vec<f32> =
                (0..n).map(|_| lcg_f32(&mut st)).collect();
            let mut want = base.clone();
            scalar_i8(xk, &q, &s, &mut want);
            if available(Isa::Avx2) {
                let mut got = base.clone();
                mac_row_i8_avx2(xk, &q, &s, &mut got);
                assert_eq!(got.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           want.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           "avx2 i8 row diverged at n={n}");
            }
            if available(Isa::Avx512) {
                let mut got = base.clone();
                mac_row_i8_avx512(xk, &q, &s, &mut got);
                assert_eq!(got.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           want.iter().map(|v| v.to_bits())
                               .collect::<Vec<_>>(),
                           "avx512 i8 row diverged at n={n}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn dpbusd_pack_matches_integer_emulation() {
        if !vnni_hw() {
            return; // hardware-only check; emulation is the referee
        }
        let mut st = 0x5eed_0003u64;
        for (group, cols) in
            [(4usize, 16usize), (8, 32), (6, 40), (64, 48)]
        {
            let q: Vec<i8> = (0..group * cols)
                .map(|_| (lcg_f32(&mut st) * 64.0) as i8)
                .collect();
            let u: Vec<u8> = (0..group)
                .map(|_| (lcg_f32(&mut st).abs() * 100.0) as u8)
                .collect();
            // build the 4-k pack for this one group
            let panels = group.div_ceil(4);
            let mut pack = vec![0i8; panels * cols * 4];
            for (k, row) in q.chunks(cols).enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    pack[((k / 4) * cols + j) * 4 + k % 4] = v;
                }
            }
            let mut want = vec![0i32; cols];
            for (k, row) in q.chunks(cols).enumerate() {
                for (d, &v) in want.iter_mut().zip(row) {
                    *d += u[k] as i32 * v as i32;
                }
            }
            let mut got = vec![0i32; cols];
            // SAFETY: vnni_hw() checked above
            let done = unsafe {
                dot_pack_dpbusd(&u, &pack, cols, 0, cols, &mut got)
            };
            // finish the ragged column tail like mac_panel does
            for (j, slot) in
                got.iter_mut().enumerate().skip(done)
            {
                let mut acc = 0i32;
                for (k, &uk) in u.iter().enumerate() {
                    acc += uk as i32 * q[k * cols + j] as i32;
                }
                *slot = acc;
            }
            assert_eq!(got, want,
                       "dpbusd diverged at group={group} cols={cols}");
        }
    }
}
