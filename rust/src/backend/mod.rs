//! Execution backends: where a rank's model math actually runs.
//!
//! The distributed machinery (leader, scheduler, KV accounting, ccl
//! collectives, launch runtime, server) is backend-agnostic: a rank
//! worker drives its compute through [`ExecBackend`] and owns every
//! synchronization point itself.  The trait boundary sits exactly at
//! the host-side activation hand-offs of the paper's design — the
//! points where partial sums enter the allreduce — so both backends
//! share the identical collective choreography (DESIGN.md §9):
//!
//! * [`reference::ReferenceBackend`] — a pure-Rust deterministic
//!   transformer (RMSNorm + RoPE + GQA attention + SiLU-gated FFN,
//!   the same architecture family the AOT pipeline lowers).  No
//!   native dependencies, no artifacts: the hermetic test tier runs
//!   the full engine/server/launch stack on it, and its
//!   fixed-granularity reductions make greedy decodes *bit-identical*
//!   across tensor-parallel world sizes.
//! * `xla::XlaBackend` (behind `--features xla`) — the PJRT runtime
//!   executing AOT-compiled HLO segments from `artifacts/`, the
//!   perf-bearing path the paper's numbers come from.
//!
//! Contract: a backend instance belongs to ONE rank and ONE thread
//! (PJRT state is `Rc`-based), holds that rank's weight shards and
//! device/KV state, and computes *rank-local partials only* — it never
//! communicates.  All methods are deterministic for a fixed
//! (config, rank) pair.
//!
//! The reference backend's weight and KV storage is dtype-selectable
//! (`EngineConfig::weight_dtype` / `kv_dtype`): dense f32 or per-block
//! symmetric INT8 ([`quant`], DESIGN.md §11).  Its GEMM inner loops
//! dispatch over a runtime-detected instruction tier
//! (`EngineConfig::isa`, [`simd`], DESIGN.md §14).  Backends report
//! their resident footprint through [`ExecBackend::mem_usage`] so the
//! bench suite can record measured bytes next to latency.

#![warn(missing_docs)]

pub mod pool;
pub mod quant;
pub mod reference;
pub mod simd;
#[cfg(feature = "xla")]
pub mod xla;

use anyhow::Result;

use crate::config::{BackendKind, EngineConfig, ResolvedModel};

/// What kind of engine round a backend call belongs to, carrying the
/// lane/position context the KV cache needs.
#[derive(Clone, Copy, Debug)]
pub enum StepCtx<'a> {
    /// Single-lane prefill over a padded `bucket`-token frame starting
    /// at absolute position `offset`: activations are
    /// `[1, bucket, hidden]`, the KV rows `[offset, offset + bucket)`
    /// of `lane` are (re)written, `length` is the valid prefix of the
    /// frame.  Whole-prompt prefill is `offset == 0`; a chunked
    /// prefill round (DESIGN.md §12) continues the lane's existing KV
    /// region at `offset > 0`, and row `r` attends over
    /// `[0, offset + r + 1)` — exactly the causal window it would see
    /// in a whole-prompt pass, which is why chunking never changes the
    /// computed bits.
    Prefill { lane: usize, bucket: usize, length: usize, offset: usize },
    /// One batched decode step: activations are `[batch, 1, hidden]`,
    /// lane `b` appends its KV at `positions[b]` and attends over
    /// `[0, positions[b]]`.
    Decode { positions: &'a [i32] },
    /// One speculative verify step (DESIGN.md §15): row `r` belongs to
    /// batch lane `lanes[r]`, appends its KV at `positions[r]` and
    /// attends over `[0, positions[r]]`.  Unlike `Decode`, rows are a
    /// *subset* of lanes and a lane may own several consecutive rows
    /// (its k+1 draft positions, strictly ascending) — the causal
    /// semantics per row are exactly one-at-a-time decode, which is
    /// the bit-identity argument for greedy-prefix acceptance.
    Verify {
        /// owning batch lane per activation row
        lanes: &'a [u32],
        /// KV append position per activation row (strictly ascending
        /// within a lane)
        positions: &'a [i32],
    },
}

impl StepCtx<'_> {
    /// Number of activation rows (`bucket` for prefill, `batch` rows
    /// for decode, one per verified position for verify).
    pub fn rows(&self, batch: usize) -> usize {
        match self {
            StepCtx::Prefill { bucket, .. } => *bucket,
            StepCtx::Decode { .. } => batch,
            StepCtx::Verify { lanes, .. } => lanes.len(),
        }
    }
}

/// Measured resident memory of one rank's backend state, in bytes —
/// the figure `xeonserve bench` records per scenario row (DESIGN.md
/// §11's memory/bandwidth accounting).  Weight bytes include
/// quantization scales; KV bytes include per-row scales.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemUsage {
    /// resident weight bytes (embedding + norms + matmul weights +
    /// scales)
    pub weight_bytes: u64,
    /// resident KV-cache bytes (all layers, full batch × max_seq
    /// capacity)
    pub kv_bytes: u64,
}

impl MemUsage {
    /// Element-wise sum (aggregating ranks into a deployment total).
    pub fn add(&self, other: &MemUsage) -> MemUsage {
        MemUsage {
            weight_bytes: self.weight_bytes + other.weight_bytes,
            kv_bytes: self.kv_bytes + other.kv_bytes,
        }
    }
}

/// One rank's compute provider.  `x`/`partial`/`logits` are dense
/// row-major f32 host buffers; sizes are fixed by the config and the
/// `StepCtx` (callers allocate).
pub trait ExecBackend {
    /// Token embedding (replicated table): fill `x` (`tokens.len() *
    /// hidden` floats) with the embedded rows.
    fn embed(&mut self, ctx: &StepCtx, tokens: &[i32], x: &mut [f32])
             -> Result<()>;

    /// Execute layer `li`, segment `seg` (0 = fused parallel block or
    /// serial attention, 1 = serial FFN) over the replicated residual
    /// activations `x`, writing this rank's *partial sum* into
    /// `partial` (same length as `x`) and updating KV state for
    /// attention segments.  The caller allreduces `partial` and adds
    /// it into `x`.
    fn layer_partial(&mut self, ctx: &StepCtx, li: usize, seg: usize,
                     x: &[f32], partial: &mut [f32]) -> Result<()>;

    /// Final-norm + lm-head over `[batch, hidden]` head inputs,
    /// writing this rank's vocab-shard logits (`batch * vocab_local`)
    /// into `logits`.
    fn lm_head(&mut self, x: &[f32], logits: &mut [f32]) -> Result<()>;

    /// Drop all KV-cache state (between bench iterations).
    fn reset(&mut self) -> Result<()>;

    /// Snapshot lane `lane`'s first `len` KV rows (every layer, every
    /// local head) into immutable shared segment `seg` (DESIGN.md §13).
    /// `len` is page-aligned by the engine; the segment is read-only
    /// until [`ExecBackend::drop_prefix`].  Default: unsupported —
    /// continuous batching is rejected at config validation for
    /// backends that do not override the prefix hooks, so the engine
    /// never reaches these defaults.
    fn publish_prefix(&mut self, seg: u32, lane: usize, len: usize)
                      -> Result<()> {
        let _ = (seg, lane, len);
        anyhow::bail!("this backend does not support shared prefixes")
    }

    /// Attach lane `lane` to shared segment `seg`: positions
    /// `[0, shared_len)` are read from the segment by reference, and
    /// the `copy_len` rows past them are copied into the lane's private
    /// storage (the copy-on-write of a partially matched page).
    fn attach_prefix(&mut self, lane: usize, seg: u32, shared_len: usize,
                     copy_len: usize) -> Result<()> {
        let _ = (lane, seg, shared_len, copy_len);
        anyhow::bail!("this backend does not support shared prefixes")
    }

    /// Detach lane `lane` from its shared segment (request retirement
    /// or cancel).  Default: Ok — detaching is a no-op for backends
    /// that never attached anything.
    fn detach_prefix(&mut self, lane: usize) -> Result<()> {
        let _ = lane;
        Ok(())
    }

    /// Free shared segment `seg`'s storage (pool eviction at refcount
    /// zero).
    fn drop_prefix(&mut self, seg: u32) -> Result<()> {
        let _ = seg;
        anyhow::bail!("this backend does not support shared prefixes")
    }

    /// Discard lane `lane`'s KV rows at positions `[new_len, max_seq)`
    /// — the speculative-decode rejection rollback (DESIGN.md §15).
    /// After this call the lane's cache must be indistinguishable from
    /// one that only ever appended `new_len` rows.  Default:
    /// unsupported — speculation is rejected at config validation for
    /// backends that do not override it.
    fn truncate_lane(&mut self, lane: usize, new_len: usize)
                     -> Result<()> {
        let _ = (lane, new_len);
        anyhow::bail!("this backend does not support KV truncation")
    }

    /// Serialize lane `lane`'s first `len` KV rows (every layer, every
    /// local head) as this rank's opaque shard of the lane image —
    /// layer-major `[layer][local_head][pos]` rows in
    /// `kvcache::KvLayer::export_row` format (DESIGN.md §17).  Rows an
    /// attached lane reads from a shared segment are exported from the
    /// segment, so the shard is always the lane's *logical* cache
    /// content.  Default: unsupported — elastic recovery is only wired
    /// to backends that override the snapshot hooks.
    fn snapshot_lane(&mut self, lane: usize, len: usize)
                     -> Result<Vec<u8>> {
        let _ = (lane, len);
        anyhow::bail!("this backend does not support KV snapshots")
    }

    /// Import a shard previously produced by
    /// [`ExecBackend::snapshot_lane`] (re-split for this world size),
    /// making lane `lane` hold `len` valid *private* rows — any shared
    /// attachment is cleared first, since segment ids do not survive a
    /// reshard.
    fn restore_lane(&mut self, lane: usize, len: usize, bytes: &[u8])
                    -> Result<()> {
        let _ = (lane, len, bytes);
        anyhow::bail!("this backend does not support KV snapshots")
    }

    /// Resident weight/KV bytes of this rank's state.  Default: zeros,
    /// meaning "not measured" (the XLA backend's buffers live on the
    /// PJRT device and are not tracked host-side).
    fn mem_usage(&self) -> MemUsage {
        MemUsage::default()
    }
}

/// Instantiate the backend `cfg` selects for `rank`, reusing the
/// already-resolved model (`rm`) so the manifest is parsed once per
/// rank.  Must be called on the thread that will use it (PJRT clients
/// are thread-local).
pub fn make_backend(cfg: &EngineConfig, rank: usize, rm: &ResolvedModel)
                    -> Result<Box<dyn ExecBackend>> {
    match cfg.backend {
        BackendKind::Reference => Ok(Box::new(
            reference::ReferenceBackend::new(cfg, rank, &rm.preset)?,
        )),
        #[cfg(feature = "xla")]
        BackendKind::Xla => {
            let manifest = rm.manifest.as_ref().ok_or_else(|| {
                anyhow::anyhow!("resolved model carries no manifest")
            })?;
            Ok(Box::new(xla::XlaBackend::new(cfg, rank, manifest)?))
        }
        #[cfg(not(feature = "xla"))]
        BackendKind::Xla => anyhow::bail!(
            "backend \"xla\" requires building with `--features xla`"
        ),
    }
}
