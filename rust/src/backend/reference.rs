//! The pure-Rust reference backend: a tiny deterministic transformer
//! that runs anywhere `cargo` runs — no PJRT, no artifacts.
//!
//! It implements the same architecture family the AOT pipeline lowers
//! (`python/compile/model.py`): RMSNorm → (GQA attention with RoPE ∥
//! SiLU-gated FFN) with real tensor-parallel sharding — query/kv heads,
//! FFN width and vocab split across ranks; embedding, norms and
//! activations replicated — and real lane/KV-cache semantics.  The rank
//! worker drives it through [`ExecBackend`] and moves its partial sums
//! through the ccl allreduce exactly as it does for the XLA backend.
//!
//! # World-invariant determinism
//!
//! The hermetic tier's headline assertion is that greedy decodes are
//! **bit-identical across world sizes 1/2/4** — the tensor-parallel
//! invariant the paper's design depends on.  f32 addition is not
//! associative, so a naive implementation would drift with the
//! allreduce's summation order.  This backend makes the reduction
//! *exact* instead:
//!
//! * every row-parallel contraction (the `wo`/`wd` partial-sum matmuls)
//!   is computed over a fixed grid of [`REDUCE_CHUNKS`] chunks of the
//!   FULL contraction axis, independent of how ranks partition it;
//! * each chunk's partial output is snapped to a dyadic grid
//!   ([`quantize_partial`]: multiples of 2⁻¹⁰, clamped to ±2⁹), so all
//!   subsequent additions — across chunks, across ranks, in any ring
//!   order — are exact in f32 and therefore order-independent;
//! * everything else (norms, RoPE, softmax, column-parallel matmuls)
//!   is computed per absolute head/column from replicated inputs, so
//!   every world size executes the identical float ops.
//!
//! Weights come from [`crate::model::synth_shard`], which slices each
//! rank's shard out of one fixed full tensor — the same scheme the XLA
//! synthetic path uses — so `concat(shards) == full` at every world.

use anyhow::{bail, ensure, Result};

use crate::config::{EngineConfig, ModelPreset, Variant, WeightSource};
use crate::model::{synth_shard, tensor_seed};

use super::{ExecBackend, StepCtx};

/// Fixed reduction granularity of the row-parallel matmuls: the full
/// contraction axis is always cut into this many chunks, whichever
/// world size runs.  Must be ≥ the largest supported world (8) and
/// divide the attention (`n_heads·head_dim`) and FFN widths.
pub const REDUCE_CHUNKS: usize = 8;

/// Snap a chunk partial to the exactness grid: multiples of 2⁻¹⁰
/// clamped to ±2⁹.  Sums of up to 2⁴ such values stay ≤ 2¹³ with a
/// 2⁻¹⁰ step — 2²³ representable steps, inside f32's 24-bit mantissa —
/// so every addition of quantized partials is exact (and associative).
#[inline]
fn quantize_partial(v: f32) -> f32 {
    const STEP: f32 = 1024.0;
    const LIM: f32 = 512.0;
    (v.clamp(-LIM, LIM) * STEP).round() / STEP
}

/// Reusable per-rank scratch buffers: the inner loops run per row ×
/// layer × step, so none of them may heap-allocate.
#[derive(Default)]
struct Scratch {
    h_n: Vec<f32>,    // [h] normed row
    q: Vec<f32>,      // [qd_l]
    k: Vec<f32>,      // [kvd_l]
    v: Vec<f32>,      // [kvd_l]
    ctxv: Vec<f32>,   // [qd_l] attention context
    head: Vec<f32>,   // [hd] one head's context
    tmp: Vec<f32>,    // [h] row-parallel chunk accumulator
    scores: Vec<f32>, // [≤ max_seq] attention scores
    g: Vec<f32>,      // [f_l] gate activations
    u: Vec<f32>,      // [f_l] up activations
}

struct LayerWeights {
    ln1_g: Vec<f32>, // [h]
    ln2_g: Vec<f32>, // [h]
    wq: Vec<f32>,    // [h, qd_l]
    wk: Vec<f32>,    // [h, kvd_l]
    wv: Vec<f32>,    // [h, kvd_l]
    wo: Vec<f32>,    // [qd_l, h]  (row-parallel)
    wg: Vec<f32>,    // [h, f_l]
    wu: Vec<f32>,    // [h, f_l]
    wd: Vec<f32>,    // [f_l, h]   (row-parallel)
}

/// One rank's deterministic in-memory model + KV caches.
pub struct ReferenceBackend {
    batch: usize,
    preset: ModelPreset,
    variant: Variant,
    // local shard dims
    n_heads_l: usize,
    n_kv_heads_l: usize,
    ffn_l: usize,
    vocab_l: usize,
    // weights
    embedding: Vec<f32>, // [vocab, h] (replicated)
    layers: Vec<LayerWeights>,
    final_g: Vec<f32>,   // [h] (replicated)
    lm_head: Vec<f32>,   // [h, vocab_l]
    /// per-layer (k, v) caches, each [batch, n_kv_heads_l, max_seq, hd]
    caches: Vec<(Vec<f32>, Vec<f32>)>,
    /// precomputed NeoX RoPE inverse frequencies, [hd/2]
    rope_inv: Vec<f32>,
    scratch: Scratch,
}

impl ReferenceBackend {
    /// Build rank `rank`'s model from `preset` (the caller resolves it —
    /// normally via `EngineConfig::resolve_model`, so the engine and the
    /// backend can never see different architectures).
    pub fn new(cfg: &EngineConfig, rank: usize, preset: &ModelPreset)
               -> Result<Self> {
        let preset = preset.clone();
        let world = cfg.world;
        ensure!(rank < world, "rank {rank} out of world {world}");
        ensure!(preset.supports_world(world),
                "model {} does not shard over world={world}", preset.name);
        let (h, hd) = (preset.hidden, preset.head_dim);
        let qd = preset.n_heads * hd;
        ensure!(
            world <= REDUCE_CHUNKS
                && REDUCE_CHUNKS % world == 0
                && qd % REDUCE_CHUNKS == 0
                && preset.ffn % REDUCE_CHUNKS == 0,
            "reference backend needs world ≤ {REDUCE_CHUNKS} and \
             attn/ffn widths divisible by {REDUCE_CHUNKS} \
             (model {}, world {world})",
            preset.name
        );
        let seed = match &cfg.weights {
            WeightSource::Synthetic { seed } => *seed,
            WeightSource::NpyDir { .. } => bail!(
                "the reference backend only supports synthetic weights \
                 (weights.kind = \"npydir\" is an XLA-backend golden-\
                 parity feature)"
            ),
        };

        let n_heads_l = preset.heads_local(world);
        let n_kv_heads_l = preset.kv_heads_local(world);
        let ffn_l = preset.ffn_local(world);
        let vocab_l = preset.vocab_local(world);
        let (qd_l, kvd_l) = (n_heads_l * hd, n_kv_heads_l * hd);

        let t = |li: i64, name: &str| tensor_seed(seed, li, name);
        let mut layers = Vec::with_capacity(preset.n_layers);
        for li in 0..preset.n_layers as i64 {
            layers.push(LayerWeights {
                ln1_g: synth_shard("ln1_g", &[h], world, rank,
                                   t(li, "ln1_g")),
                ln2_g: synth_shard("ln2_g", &[h], world, rank,
                                   t(li, "ln2_g")),
                wq: synth_shard("wq", &[h, qd_l], world, rank, t(li, "wq")),
                wk: synth_shard("wk", &[h, kvd_l], world, rank, t(li, "wk")),
                wv: synth_shard("wv", &[h, kvd_l], world, rank, t(li, "wv")),
                wo: synth_shard("wo", &[qd_l, h], world, rank, t(li, "wo")),
                wg: synth_shard("wg", &[h, ffn_l], world, rank, t(li, "wg")),
                wu: synth_shard("wu", &[h, ffn_l], world, rank, t(li, "wu")),
                wd: synth_shard("wd", &[ffn_l, h], world, rank, t(li, "wd")),
            });
        }
        let embedding = synth_shard("embedding", &[preset.vocab, h], world,
                                    rank, t(-1, "embedding"));
        let final_g =
            synth_shard("final_g", &[h], world, rank, t(-1, "final_g"));
        let lm_head = synth_shard("lm_head", &[h, vocab_l], world, rank,
                                  t(-1, "lm_head"));

        let cache_len = cfg.batch * n_kv_heads_l * preset.max_seq * hd;
        let caches = (0..preset.n_layers)
            .map(|_| (vec![0.0; cache_len], vec![0.0; cache_len]))
            .collect();
        let rope_inv = (0..hd / 2)
            .map(|i| {
                (preset.rope_theta as f32)
                    .powf(-(2.0 * i as f32) / hd as f32)
            })
            .collect();

        Ok(ReferenceBackend {
            batch: cfg.batch,
            variant: cfg.variant,
            n_heads_l,
            n_kv_heads_l,
            ffn_l,
            vocab_l,
            embedding,
            layers,
            final_g,
            lm_head,
            caches,
            rope_inv,
            scratch: Scratch::default(),
            preset,
        })
    }

    // ---- math helpers ----------------------------------------------------
    //
    // All contractions iterate the contraction index ascending, so the
    // same absolute column is computed with the identical op sequence
    // at every world size.

    fn rmsnorm(&self, x: &[f32], gain: &[f32], out: &mut [f32]) {
        let h = self.preset.hidden;
        let eps = self.preset.norm_eps as f32;
        let mut ss = 0.0f32;
        for &v in &x[..h] {
            ss += v * v;
        }
        let inv = 1.0 / (ss / h as f32 + eps).sqrt();
        for j in 0..h {
            out[j] = x[j] * inv * gain[j];
        }
    }

    /// Column-parallel matmul: `out[j] += Σ_k a[k]·w[k, j]` over the
    /// full (replicated) contraction axis.  `out` must be zeroed.
    fn col_matmul(a: &[f32], w: &[f32], cols: usize, out: &mut [f32]) {
        for (k, &ak) in a.iter().enumerate() {
            let row = &w[k * cols..(k + 1) * cols];
            for (o, &wkj) in out[..cols].iter_mut().zip(row) {
                *o += ak * wkj;
            }
        }
    }

    /// Row-parallel matmul with the fixed chunk grid: adds this rank's
    /// quantized partial `Σ_chunks q(a[chunk] @ w[chunk, :])` into
    /// `out[..h]`.  `k_full` is the FULL contraction width; `a`/`w`
    /// cover this rank's contiguous `k_local` slice of it.  `tmp` is
    /// caller-provided scratch (hot path — no allocation here).
    fn rowpar_matmul(&self, a: &[f32], w: &[f32], k_local: usize,
                     k_full: usize, tmp: &mut Vec<f32>, out: &mut [f32]) {
        let h = self.preset.hidden;
        let cs = k_full / REDUCE_CHUNKS;
        debug_assert_eq!(k_local % cs, 0);
        tmp.resize(h, 0.0);
        for c in 0..k_local / cs {
            tmp.fill(0.0);
            for k in c * cs..(c + 1) * cs {
                let ak = a[k];
                let row = &w[k * h..(k + 1) * h];
                for (t, &wkj) in tmp[..h].iter_mut().zip(row) {
                    *t += ak * wkj;
                }
            }
            for (o, &t) in out[..h].iter_mut().zip(&tmp[..h]) {
                *o += quantize_partial(t);
            }
        }
    }

    /// NeoX-style rotary embedding in place over `[n_heads, hd]` rows.
    fn rope(&self, v: &mut [f32], n_heads: usize, pos: i32) {
        let hd = self.preset.head_dim;
        let half = hd / 2;
        for head in 0..n_heads {
            let base = head * hd;
            for i in 0..half {
                let ang = pos as f32 * self.rope_inv[i];
                let (s, c) = ang.sin_cos();
                let a = v[base + i];
                let b = v[base + half + i];
                v[base + i] = a * c - b * s;
                v[base + half + i] = b * c + a * s;
            }
        }
    }

    /// Softmax-weighted value sum over cache entries `[0, hi)` of
    /// `(lane, kv_head)` for one query head; writes `hd` floats.
    /// `scores` is caller-provided scratch.
    #[allow(clippy::too_many_arguments)]
    fn attend_cache(&self, li: usize, lane: usize, kh: usize, q: &[f32],
                    hi: usize, scores: &mut Vec<f32>, out: &mut [f32]) {
        let hd = self.preset.head_dim;
        let t_max = self.preset.max_seq;
        let scale = 1.0 / (hd as f32).sqrt();
        let (kc, vc) = &self.caches[li];
        let base = (lane * self.n_kv_heads_l + kh) * t_max * hd;

        scores.clear();
        scores.resize(hi, 0.0);
        let mut m = f32::NEG_INFINITY;
        for (t, s) in scores.iter_mut().enumerate() {
            let krow = &kc[base + t * hd..base + (t + 1) * hd];
            let mut dot = 0.0f32;
            for (qa, kb) in q[..hd].iter().zip(krow) {
                dot += qa * kb;
            }
            *s = dot * scale;
            m = m.max(*s);
        }
        let mut denom = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            denom += *s;
        }
        let inv = 1.0 / denom.max(1e-20);
        out[..hd].fill(0.0);
        for (t, &p) in scores.iter().enumerate() {
            let w = p * inv;
            let vrow = &vc[base + t * hd..base + (t + 1) * hd];
            for (o, &vb) in out[..hd].iter_mut().zip(vrow) {
                *o += w * vb;
            }
        }
    }

    /// Attention partial for one activation row (already normed into
    /// `s.h_n`): project q/k/v, rope, append to the cache at `pos`
    /// (lane `lane`), attend over `[0, attend_hi)`, and add the
    /// quantized `context @ wo` partial into `out`.
    fn attn_row(&mut self, li: usize, lane: usize, pos: i32,
                attend_hi: usize, s: &mut Scratch, out: &mut [f32]) {
        let hd = self.preset.head_dim;
        let (qd_l, kvd_l) =
            (self.n_heads_l * hd, self.n_kv_heads_l * hd);
        let group = self.n_heads_l / self.n_kv_heads_l;
        let t_max = self.preset.max_seq;

        s.q.clear();
        s.q.resize(qd_l, 0.0);
        s.k.clear();
        s.k.resize(kvd_l, 0.0);
        s.v.clear();
        s.v.resize(kvd_l, 0.0);
        {
            let lw = &self.layers[li];
            Self::col_matmul(&s.h_n, &lw.wq, qd_l, &mut s.q);
            Self::col_matmul(&s.h_n, &lw.wk, kvd_l, &mut s.k);
            Self::col_matmul(&s.h_n, &lw.wv, kvd_l, &mut s.v);
        }
        self.rope(&mut s.q, self.n_heads_l, pos);
        self.rope(&mut s.k, self.n_kv_heads_l, pos);

        {
            let (kc, vc) = &mut self.caches[li];
            let t = pos as usize;
            for kh in 0..self.n_kv_heads_l {
                let dst =
                    ((lane * self.n_kv_heads_l + kh) * t_max + t) * hd;
                kc[dst..dst + hd]
                    .copy_from_slice(&s.k[kh * hd..(kh + 1) * hd]);
                vc[dst..dst + hd]
                    .copy_from_slice(&s.v[kh * hd..(kh + 1) * hd]);
            }
        }

        s.ctxv.clear();
        s.ctxv.resize(qd_l, 0.0);
        s.head.resize(hd, 0.0);
        for qh in 0..self.n_heads_l {
            let kh = qh / group;
            self.attend_cache(li, lane, kh, &s.q[qh * hd..(qh + 1) * hd],
                              attend_hi, &mut s.scores, &mut s.head);
            s.ctxv[qh * hd..(qh + 1) * hd].copy_from_slice(&s.head[..hd]);
        }
        let qd_full = self.preset.n_heads * hd;
        self.rowpar_matmul(&s.ctxv, &self.layers[li].wo, qd_l, qd_full,
                           &mut s.tmp, out);
    }

    /// FFN partial for one normed row (`s.h_n`): adds the quantized
    /// `(silu(h@wg) ⊙ (h@wu)) @ wd` partial into `out`.
    fn ffn_row(&self, li: usize, s: &mut Scratch, out: &mut [f32]) {
        let lw = &self.layers[li];
        let f_l = self.ffn_l;
        s.g.clear();
        s.g.resize(f_l, 0.0);
        s.u.clear();
        s.u.resize(f_l, 0.0);
        Self::col_matmul(&s.h_n, &lw.wg, f_l, &mut s.g);
        Self::col_matmul(&s.h_n, &lw.wu, f_l, &mut s.u);
        for (gi, &ui) in s.g.iter_mut().zip(&s.u) {
            let sig = *gi / (1.0 + (-*gi).exp()); // SiLU
            *gi = sig * ui;
        }
        self.rowpar_matmul(&s.g, &lw.wd, f_l, self.preset.ffn, &mut s.tmp,
                           out);
    }
}

impl ExecBackend for ReferenceBackend {
    fn embed(&mut self, _ctx: &StepCtx, tokens: &[i32], x: &mut [f32])
             -> Result<()> {
        let h = self.preset.hidden;
        ensure!(x.len() >= tokens.len() * h,
                "embed output buffer too small");
        for (i, &t) in tokens.iter().enumerate() {
            let t = (t.max(0) as usize).min(self.preset.vocab - 1);
            x[i * h..(i + 1) * h]
                .copy_from_slice(&self.embedding[t * h..(t + 1) * h]);
        }
        Ok(())
    }

    fn layer_partial(&mut self, ctx: &StepCtx, li: usize, seg: usize,
                     x: &[f32], partial: &mut [f32]) -> Result<()> {
        ensure!(li < self.preset.n_layers, "layer {li} out of range");
        let segs = self.variant.syncs_per_layer();
        ensure!(seg < segs, "segment {seg} out of range for {:?}",
                self.variant);
        let h = self.preset.hidden;
        let max_seq = self.preset.max_seq;
        let rows = ctx.rows(self.batch);
        ensure!(x.len() >= rows * h && partial.len() >= rows * h,
                "activation buffers too small");
        // reject malformed lane/position bookkeeping loudly: silently
        // clamping would turn an engine bug into KV corruption
        match ctx {
            StepCtx::Prefill { lane, bucket, length } => {
                ensure!(*bucket <= max_seq && *length >= 1
                            && *length <= *bucket,
                        "prefill shape out of range: bucket={bucket} \
                         length={length} max_seq={max_seq}");
                ensure!(*lane < self.batch,
                        "prefill lane {lane} out of range (batch {})",
                        self.batch);
            }
            StepCtx::Decode { positions } => {
                ensure!(positions.len() == rows,
                        "decode got {} positions for batch {rows}",
                        positions.len());
                for (b, &p) in positions.iter().enumerate() {
                    ensure!(p >= 0 && (p as usize) < max_seq,
                            "lane {b} position {p} out of range \
                             (max_seq {max_seq})");
                }
            }
        }
        partial[..rows * h].fill(0.0);

        let mut s = std::mem::take(&mut self.scratch);
        s.h_n.resize(h, 0.0);
        for r in 0..rows {
            let x_row = &x[r * h..(r + 1) * h];
            let out = r * h..(r + 1) * h;
            // (lane, pos, attend_hi) for this row's KV update
            let (lane, pos, hi) = match ctx {
                StepCtx::Prefill { lane, length, .. } => {
                    let hi = if r < *length { r + 1 } else { *length };
                    (*lane, r as i32, hi)
                }
                StepCtx::Decode { positions } => {
                    let pos = positions[r];
                    (r, pos, pos as usize + 1)
                }
            };
            match (self.variant, seg) {
                (Variant::Parallel, _) => {
                    // fused block: ONE partial sum (the paper's §2.2);
                    // attention and FFN share the ln1 norm, as in
                    // python's build_parallel_block_*
                    self.rmsnorm(x_row, &self.layers[li].ln1_g,
                                 &mut s.h_n);
                    self.attn_row(li, lane, pos, hi, &mut s,
                                  &mut partial[out.clone()]);
                    self.ffn_row(li, &mut s, &mut partial[out]);
                }
                (Variant::Serial, 0) => {
                    self.rmsnorm(x_row, &self.layers[li].ln1_g,
                                 &mut s.h_n);
                    self.attn_row(li, lane, pos, hi, &mut s,
                                  &mut partial[out]);
                }
                (Variant::Serial, _) => {
                    self.rmsnorm(x_row, &self.layers[li].ln2_g,
                                 &mut s.h_n);
                    self.ffn_row(li, &mut s, &mut partial[out]);
                }
            }
        }
        self.scratch = s;
        Ok(())
    }

    fn lm_head(&mut self, x: &[f32], logits: &mut [f32]) -> Result<()> {
        let h = self.preset.hidden;
        let v_l = self.vocab_l;
        let b = self.batch;
        ensure!(x.len() >= b * h && logits.len() >= b * v_l,
                "lm_head buffers too small");
        let mut s = std::mem::take(&mut self.scratch);
        s.h_n.resize(h, 0.0);
        for r in 0..b {
            self.rmsnorm(&x[r * h..(r + 1) * h], &self.final_g,
                         &mut s.h_n);
            let out = &mut logits[r * v_l..(r + 1) * v_l];
            out.fill(0.0);
            Self::col_matmul(&s.h_n, &self.lm_head, v_l, out);
        }
        self.scratch = s;
        Ok(())
    }

    fn reset(&mut self) -> Result<()> {
        for (kc, vc) in &mut self.caches {
            kc.fill(0.0);
            vc.fill(0.0);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;

    fn cfg(world: usize, batch: usize) -> EngineConfig {
        EngineConfig {
            backend: BackendKind::Reference,
            world,
            batch,
            weights: WeightSource::Synthetic { seed: 7 },
            ..Default::default()
        }
    }

    fn backend(c: &EngineConfig, rank: usize) -> Result<ReferenceBackend> {
        let preset = ModelPreset::builtin(&c.model)?;
        ReferenceBackend::new(c, rank, &preset)
    }

    #[test]
    fn quantized_grid_sums_are_exact_in_any_order() {
        // the invariant the world-parity guarantee rests on
        let vals: Vec<f32> = (0..16)
            .map(|i| quantize_partial((i as f32 * 0.377).sin() * 3.0))
            .collect();
        let fwd: f32 = vals.iter().sum();
        let rev: f32 = vals.iter().rev().sum();
        let pairs: f32 = vals.chunks(2).map(|c| c[0] + c[1]).sum();
        assert_eq!(fwd.to_bits(), rev.to_bits());
        assert_eq!(fwd.to_bits(), pairs.to_bits());
    }

    #[test]
    fn decode_partials_sum_identically_across_worlds() {
        // one decode step through one layer: Σ_ranks partial must be
        // bit-identical for world 1, 2 and 4
        let h = 64;
        let x: Vec<f32> =
            (0..h).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.05).collect();
        let mut sums: Vec<Vec<f32>> = Vec::new();
        for world in [1usize, 2, 4] {
            let mut total = vec![0.0f32; h];
            for rank in 0..world {
                let mut be = backend(&cfg(world, 1), rank).unwrap();
                let mut part = vec![0.0f32; h];
                let ctx = StepCtx::Decode { positions: &[0] };
                be.layer_partial(&ctx, 0, 0, &x, &mut part).unwrap();
                for (t, p) in total.iter_mut().zip(&part) {
                    *t += *p;
                }
            }
            sums.push(total);
        }
        for w in 1..sums.len() {
            for j in 0..h {
                assert_eq!(
                    sums[0][j].to_bits(),
                    sums[w][j].to_bits(),
                    "col {j} differs between world 1 and {}",
                    [1, 2, 4][w]
                );
            }
        }
    }

    #[test]
    fn lm_head_shards_concat_to_world1_logits() {
        let h = 64;
        let x: Vec<f32> = (0..h).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut be1 = backend(&cfg(1, 1), 0).unwrap();
        let mut full = vec![0.0f32; 256];
        be1.lm_head(&x, &mut full).unwrap();
        let world = 4;
        let v_l = 256 / world;
        for rank in 0..world {
            let mut be = backend(&cfg(world, 1), rank).unwrap();
            let mut local = vec![0.0f32; v_l];
            be.lm_head(&x, &mut local).unwrap();
            for j in 0..v_l {
                assert_eq!(local[j].to_bits(),
                           full[rank * v_l + j].to_bits());
            }
        }
    }

    #[test]
    fn reset_restores_fresh_kv_state() {
        let mut be = backend(&cfg(1, 1), 0).unwrap();
        let h = 64;
        let tokens = [5i32; 4];
        let ctx = StepCtx::Prefill { lane: 0, bucket: 4, length: 4 };
        let mut x = vec![0.0f32; 4 * h];
        be.embed(&ctx, &tokens, &mut x).unwrap();
        let mut p1 = vec![0.0f32; 4 * h];
        be.layer_partial(&ctx, 0, 0, &x, &mut p1).unwrap();
        be.reset().unwrap();
        let mut p2 = vec![0.0f32; 4 * h];
        be.layer_partial(&ctx, 0, 0, &x, &mut p2).unwrap();
        assert_eq!(p1, p2, "reset must reproduce the first run exactly");
    }

    #[test]
    fn npydir_weights_rejected() {
        let mut c = cfg(1, 1);
        c.weights = WeightSource::NpyDir { dir: "/tmp/x".into() };
        assert!(backend(&c, 0).is_err());
    }

    #[test]
    fn unknown_model_rejected() {
        let mut c = cfg(1, 1);
        c.model = "qwen72b".into();
        assert!(backend(&c, 0).is_err());
    }
}
