//! The pure-Rust reference backend: a tiny deterministic transformer
//! that runs anywhere `cargo` runs — no PJRT, no artifacts.
//!
//! It implements the same architecture family the AOT pipeline lowers
//! (`python/compile/model.py`): RMSNorm → (GQA attention with RoPE ∥
//! SiLU-gated FFN) with real tensor-parallel sharding — query/kv heads,
//! FFN width and vocab split across ranks; embedding, norms and
//! activations replicated — and real lane/KV-cache semantics.  The rank
//! worker drives it through [`ExecBackend`] and moves its partial sums
//! through the ccl allreduce exactly as it does for the XLA backend.
//!
//! # Kernels: scalar baseline vs blocked + threaded (DESIGN.md §10)
//!
//! Two interchangeable kernel implementations exist behind
//! [`GemmKernel`]:
//!
//! * `scalar` — the naive row-at-a-time loops: every activation row
//!   re-streams the full weight matrices.  Kept as the recorded
//!   perf baseline (`BENCH_*.json`) and as the reference the threaded
//!   path is bit-compared against.
//! * `blocked` (default) — cache-blocked GEMMs that process ALL rows of
//!   a step per weight pass (each weight matrix is streamed once per
//!   *step*, not once per *row* — the big win for batched decode and
//!   prefill), tiled over `ROW_TILE`×`COL_BLOCK` output tiles, and
//!   fanned out over a per-rank [`WorkerPool`] (`EngineConfig::threads`,
//!   0 = auto-detect cores/world).
//!
//! The two kernels are **bit-identical by construction**: every output
//! element is produced by the same single-accumulator, ascending-`k`
//! chain of f32 ops in both; blocking/tiling only reorders *independent*
//! elements, and the pool's fixed output-block partitioning only
//! changes which thread computes an element, never how.  Greedy decode
//! therefore does not depend on the kernel choice or the thread count —
//! the invariant `rust/tests/threading_determinism.rs` pins.
//!
//! # World-invariant determinism
//!
//! The hermetic tier's headline assertion is that greedy decodes are
//! **bit-identical across world sizes 1/2/4** — the tensor-parallel
//! invariant the paper's design depends on.  f32 addition is not
//! associative, so a naive implementation would drift with the
//! allreduce's summation order.  This backend makes the reduction
//! *exact* instead:
//!
//! * every row-parallel contraction (the `wo`/`wd` partial-sum matmuls)
//!   is computed over a fixed grid of [`REDUCE_CHUNKS`] chunks of the
//!   FULL contraction axis, independent of how ranks partition it;
//! * each chunk's partial output is snapped to a dyadic grid
//!   ([`quantize_partial`]: multiples of 2⁻¹⁰, clamped to ±2⁹), so all
//!   subsequent additions — across chunks, across ranks, in any ring
//!   order — are exact in f32 and therefore order-independent;
//! * everything else (norms, RoPE, softmax, column-parallel matmuls)
//!   is computed per absolute head/column from replicated inputs, so
//!   every world size executes the identical float ops.
//!
//! Weights come from [`crate::model::synth_shard`], which slices each
//! rank's shard out of one fixed full tensor — the same scheme the XLA
//! synthetic path uses — so `concat(shards) == full` at every world.
//!
//! # INT8 dtypes (DESIGN.md §11)
//!
//! `EngineConfig::weight_dtype` / `kv_dtype` select per-block
//! symmetric INT8 storage for the matmul weights ([`WeightMat`]) and
//! the KV cache ([`KvLayer`]): decode is memory-bandwidth-bound, so
//! quartering the bytes streamed per step is a direct ms/token win and
//! lets bigger models fit a node.  Every determinism property above
//! survives *at a fixed dtype*: dequantization (`q·s`) reconstructs a
//! fixed f32 per element (quantized from the FULL tensor before
//! sharding, so every world sees identical values), the kernels run
//! the same single-accumulator chains through [`WeightMat::mac_row`],
//! and KV rows are quantized once at append time by a pure function of
//! the row.  Changing the dtype changes the logits — that is the
//! accuracy/memory trade, pinned by the int8-vs-f32 tolerance tests.
//!
//! # ISA dispatch (DESIGN.md §14)
//!
//! Every GEMM inner loop funnels through [`WeightMat::mac_panel`],
//! dispatched once at construction over the tier
//! [`crate::backend::simd::resolve`] picks from `EngineConfig::isa`
//! (and the `XEONSERVE_FORCE_ISA` override).  The `avx2`/`avx512`
//! tiers vectorize the scalar chains with unfused per-lane ops —
//! bit-identical to `scalar` at both dtypes — while the opt-in `vnni`
//! tier swaps int8 weight matmuls for the W8A8 integer scheme, its own
//! deterministic numerics.  `rust/tests/simd_parity.rs` pins both
//! claims.

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use crate::config::{Dtype, EngineConfig, GemmKernel, ModelPreset, Variant, WeightSource};
use crate::kvcache::{row_bytes, KvLayer};
use crate::model::{synth_quant_shard, synth_shard, tensor_seed};

use super::pool::{auto_threads, DisjointSlices, FirstError, WorkerPool};
use super::quant::{quant_row_into, WeightMat, WEIGHT_QUANT_GROUP};
use super::simd::{self, Isa};
use super::{ExecBackend, MemUsage, StepCtx};

/// Fixed reduction granularity of the row-parallel matmuls: the full
/// contraction axis is always cut into this many chunks, whichever
/// world size runs.  Must be ≥ the largest supported world (8) and
/// divide the attention (`n_heads·head_dim`) and FFN widths.
pub const REDUCE_CHUNKS: usize = 8;

/// Snap a chunk partial to the exactness grid: multiples of 2⁻¹⁰
/// clamped to ±2⁹.  Sums of up to 2⁴ such values stay ≤ 2¹³ with a
/// 2⁻¹⁰ step — 2²³ representable steps, inside f32's 24-bit mantissa —
/// so every addition of quantized partials is exact (and associative).
#[inline]
fn quantize_partial(v: f32) -> f32 {
    const STEP: f32 = 1024.0;
    const LIM: f32 = 512.0;
    (v.clamp(-LIM, LIM) * STEP).round() / STEP
}

/// Output-column block width of the blocked kernels.  A pool unit owns
/// one block; the width is FIXED (never derived from the thread count)
/// so the unit grid — and with it every float op — is identical at any
/// parallelism.
const COL_BLOCK: usize = 64;

/// Row-tile height of the blocked kernels: output tiles of
/// `ROW_TILE × COL_BLOCK` accumulators stay register/L1-resident while
/// a weight column block streams through.
const ROW_TILE: usize = 16;

/// Below this many multiply-accumulates a phase runs inline on the
/// caller instead of waking the pool (a dispatch costs ~10 µs).
const PAR_THRESHOLD_MACS: usize = 1 << 17;

// ---- shared math helpers (both kernels) --------------------------------
//
// All contractions iterate the contraction index ascending with a
// single accumulator per output element, so the same absolute output
// is computed with the identical op sequence at every world size, on
// either kernel, at any thread count.

fn rmsnorm_into(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    let h = gain.len();
    let mut ss = 0.0f32;
    for &v in &x[..h] {
        ss += v * v;
    }
    let inv = 1.0 / (ss / h as f32 + eps).sqrt();
    for j in 0..h {
        out[j] = x[j] * inv * gain[j];
    }
}

/// NeoX-style rotary embedding over one head's `[hd]` slice.
fn rope_head(v: &mut [f32], rope_inv: &[f32], pos: i32) {
    let half = v.len() / 2;
    for i in 0..half {
        let ang = pos as f32 * rope_inv[i];
        let (s, c) = ang.sin_cos();
        let a = v[i];
        let b = v[half + i];
        v[i] = a * c - b * s;
        v[half + i] = b * c + a * s;
    }
}

/// Read-only view of one query head's slice of a shared-prefix
/// segment (DESIGN.md §13): attention positions `[0, len)` resolve to
/// the segment's rows starting at float offset `base`; positions past
/// `len` fall through to the lane's private cache rows.
struct SharedF32<'a> {
    k: &'a [f32],
    v: &'a [f32],
    base: usize,
    len: usize,
}

/// [`SharedF32`] for an INT8 segment: `row0` indexes quantized rows
/// (and their scale slots), mirroring [`attend_into_q8`]'s addressing.
struct SharedQ8<'a> {
    kq: &'a [i8],
    ks: &'a [f32],
    vq: &'a [i8],
    vs: &'a [f32],
    row0: usize,
    len: usize,
}

/// Softmax-weighted value sum over cache entries `[0, scores.len())`
/// at `base` for one query head; writes `hd` floats into `out`.
/// With `shared`, positions below the attachment's shared length read
/// the segment's rows instead of the lane's — those rows are
/// bit-identical to what the lane's own prefill would have written
/// (K/V rows are pure functions of token, position and weights), so
/// redirecting the reads changes no score/value chain and no output
/// bit — the invariant `tests/continuous_batching.rs` pins.
#[allow(clippy::too_many_arguments)]
fn attend_into(kc: &[f32], vc: &[f32], base: usize, hd: usize, q: &[f32],
               scores: &mut [f32], out: &mut [f32],
               shared: Option<&SharedF32<'_>>) {
    let (s_len, sk, sv, s_base) = match shared {
        Some(s) => (s.len, s.k, s.v, s.base),
        None => (0, kc, vc, base),
    };
    let scale = 1.0 / (hd as f32).sqrt();
    let mut m = f32::NEG_INFINITY;
    for (t, s) in scores.iter_mut().enumerate() {
        let krow = if t < s_len {
            &sk[s_base + t * hd..s_base + (t + 1) * hd]
        } else {
            &kc[base + t * hd..base + (t + 1) * hd]
        };
        let mut dot = 0.0f32;
        for (qa, kb) in q[..hd].iter().zip(krow) {
            dot += qa * kb;
        }
        *s = dot * scale;
        m = m.max(*s);
    }
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        denom += *s;
    }
    let inv = 1.0 / denom.max(1e-20);
    out[..hd].fill(0.0);
    for (t, &p) in scores.iter().enumerate() {
        let w = p * inv;
        let vrow = if t < s_len {
            &sv[s_base + t * hd..s_base + (t + 1) * hd]
        } else {
            &vc[base + t * hd..base + (t + 1) * hd]
        };
        for (o, &vb) in out[..hd].iter_mut().zip(vrow) {
            *o += w * vb;
        }
    }
}

/// [`attend_into`] over an INT8 cache: identical loop structure, with
/// each cache element dequantized in the inner products (`q_i8·s` — the
/// row's scale, one f32 per (lane, head, position) row).  `row0` is
/// the cache ROW index of this (lane, head)'s position 0, i.e.
/// `base / hd` of the f32 variant.  `shared` redirects positions below
/// the attachment's shared length to the segment's rows — quantized
/// bytes and scales transfer verbatim at publish/attach time, so the
/// dequantized values (and the output bits) are unchanged.
#[allow(clippy::too_many_arguments)]
fn attend_into_q8(kq: &[i8], ks: &[f32], vq: &[i8], vs: &[f32],
                  row0: usize, hd: usize, q: &[f32],
                  scores: &mut [f32], out: &mut [f32],
                  shared: Option<&SharedQ8<'_>>) {
    let (s_len, skq, sks, svq, svs, s_row0) = match shared {
        Some(s) => (s.len, s.kq, s.ks, s.vq, s.vs, s.row0),
        None => (0, kq, ks, vq, vs, row0),
    };
    let scale = 1.0 / (hd as f32).sqrt();
    let mut m = f32::NEG_INFINITY;
    for (t, s) in scores.iter_mut().enumerate() {
        let (kqr, ksr, r) = if t < s_len {
            (skq, sks, s_row0 + t)
        } else {
            (kq, ks, row0 + t)
        };
        let ksc = ksr[r];
        let krow = &kqr[r * hd..(r + 1) * hd];
        let mut dot = 0.0f32;
        for (qa, &kb) in q[..hd].iter().zip(krow) {
            dot += qa * (kb as f32 * ksc);
        }
        *s = dot * scale;
        m = m.max(*s);
    }
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        denom += *s;
    }
    let inv = 1.0 / denom.max(1e-20);
    out[..hd].fill(0.0);
    for (t, &p) in scores.iter().enumerate() {
        let w = p * inv;
        let (vqr, vsr, r) = if t < s_len {
            (svq, svs, s_row0 + t)
        } else {
            (vq, vs, row0 + t)
        };
        let vsc = vsr[r];
        let vrow = &vqr[r * hd..(r + 1) * hd];
        for (o, &vb) in out[..hd].iter_mut().zip(vrow) {
            *o += w * (vb as f32 * vsc);
        }
    }
}

// ---- blocked kernels ---------------------------------------------------

fn col_blocks(cols: usize) -> usize {
    (cols + COL_BLOCK - 1) / COL_BLOCK
}

fn block_range(b: usize, cols: usize) -> (usize, usize) {
    let j0 = b * COL_BLOCK;
    (j0, (j0 + COL_BLOCK).min(cols))
}

/// Columns `[j0, j1)` of `xn[rows, kdim] @ w[kdim, cols]` for every
/// row, OVERWRITING `out[r·out_stride + j]`.  Row-fused: the column
/// block of `w` is streamed once per row tile, not once per row.
/// Bit-compatible with [`col_matmul`]: each output element is one
/// ascending-`k` chain (through [`WeightMat::mac_panel`], which
/// dequantizes INT8 storage inside the MAC — same chain, fewer bytes
/// streamed — and vectorizes it per the resolved ISA tier).
#[allow(clippy::too_many_arguments)]
fn colpar_block(isa: Isa, xn: &[f32], kdim: usize, rows: usize,
                w: &WeightMat, cols: usize, j0: usize, j1: usize,
                out: &DisjointSlices<'_>, out_stride: usize) {
    let bw = j1 - j0;
    let mut r0 = 0;
    while r0 < rows {
        let rt = ROW_TILE.min(rows - r0);
        let mut tile = [0.0f32; ROW_TILE * COL_BLOCK];
        for ri in 0..rt {
            let xrow =
                &xn[(r0 + ri) * kdim..(r0 + ri + 1) * kdim];
            w.mac_panel(isa, 0, kdim, j0, j1, xrow,
                        &mut tile[ri * bw..ri * bw + bw]);
        }
        for ri in 0..rt {
            // SAFETY: this unit owns columns [j0, j1) of every row;
            // other units write disjoint column ranges.
            let dst = unsafe {
                out.slice((r0 + ri) * out_stride + j0, bw)
            };
            dst.copy_from_slice(&tile[ri * bw..ri * bw + bw]);
        }
        r0 += rt;
    }
}

/// Columns `[j0, j1)` of the fused FFN gate: `silu(xn@wg) ⊙ (xn@wu)`,
/// overwriting `out[r·cols + j]`.  Same per-element chains as running
/// [`col_matmul`] for `wg` and `wu` separately, then fusing.
#[allow(clippy::too_many_arguments)]
fn gateup_block(isa: Isa, xn: &[f32], kdim: usize, rows: usize,
                wg: &WeightMat, wu: &WeightMat, cols: usize, j0: usize,
                j1: usize, out: &DisjointSlices<'_>) {
    let bw = j1 - j0;
    let mut r0 = 0;
    while r0 < rows {
        let rt = ROW_TILE.min(rows - r0);
        let mut gt = [0.0f32; ROW_TILE * COL_BLOCK];
        let mut ut = [0.0f32; ROW_TILE * COL_BLOCK];
        for ri in 0..rt {
            let xrow =
                &xn[(r0 + ri) * kdim..(r0 + ri + 1) * kdim];
            wg.mac_panel(isa, 0, kdim, j0, j1, xrow,
                         &mut gt[ri * bw..ri * bw + bw]);
            wu.mac_panel(isa, 0, kdim, j0, j1, xrow,
                         &mut ut[ri * bw..ri * bw + bw]);
        }
        for ri in 0..rt {
            // SAFETY: disjoint column ranges per unit (see colpar_block)
            let dst = unsafe { out.slice((r0 + ri) * cols + j0, bw) };
            for jj in 0..bw {
                let g = gt[ri * bw + jj];
                let u = ut[ri * bw + jj];
                let sig = g / (1.0 + (-g).exp()); // SiLU
                dst[jj] = sig * u;
            }
        }
        r0 += rt;
    }
}

/// Columns `[j0, j1)` of the row-parallel `act[rows, k_local] @
/// w[k_local, h]` under the fixed [`REDUCE_CHUNKS`] grid (`cs` =
/// world-invariant chunk width), ADDING the quantized partial into
/// `out[r·h + j]`.  Bit-compatible with [`rowpar_scalar`]: identical
/// per-chunk chains, and quantized partials sum exactly in any order.
#[allow(clippy::too_many_arguments)]
fn rowpar_block(isa: Isa, act: &[f32], k_local: usize, rows: usize,
                w: &WeightMat, h: usize, cs: usize, j0: usize,
                j1: usize, out: &DisjointSlices<'_>) {
    let bw = j1 - j0;
    let n_chunks = k_local / cs;
    let mut r0 = 0;
    while r0 < rows {
        let rt = ROW_TILE.min(rows - r0);
        let mut acc = [0.0f32; ROW_TILE * COL_BLOCK];
        for c in 0..n_chunks {
            let mut part = [0.0f32; ROW_TILE * COL_BLOCK];
            for ri in 0..rt {
                let arow = &act[(r0 + ri) * k_local
                    ..(r0 + ri + 1) * k_local];
                w.mac_panel(isa, c * cs, (c + 1) * cs, j0, j1, arow,
                            &mut part[ri * bw..ri * bw + bw]);
            }
            for (a, &p) in
                acc[..rt * bw].iter_mut().zip(&part[..rt * bw])
            {
                *a += quantize_partial(p);
            }
        }
        for ri in 0..rt {
            // SAFETY: disjoint column ranges per unit (see colpar_block)
            let dst = unsafe { out.slice((r0 + ri) * h + j0, bw) };
            for (d, &a) in
                dst.iter_mut().zip(&acc[ri * bw..ri * bw + bw])
            {
                *d += a;
            }
        }
        r0 += rt;
    }
}

// ---- scalar kernels (the recorded baseline) ----------------------------

/// Row-parallel matmul with the fixed chunk grid, one row at a time:
/// adds this rank's quantized partial into `out[..h]`.  `k_full` is
/// the FULL contraction width; `a`/`w` cover this rank's contiguous
/// `k_local` slice of it.  `tmp` is caller-provided scratch.
#[allow(clippy::too_many_arguments)]
fn rowpar_scalar(isa: Isa, a: &[f32], w: &WeightMat, k_local: usize,
                 k_full: usize, h: usize, tmp: &mut Vec<f32>,
                 out: &mut [f32]) {
    let cs = k_full / REDUCE_CHUNKS;
    debug_assert_eq!(k_local % cs, 0);
    tmp.resize(h, 0.0);
    for c in 0..k_local / cs {
        tmp.fill(0.0);
        w.mac_panel(isa, c * cs, (c + 1) * cs, 0, h, a,
                    &mut tmp[..h]);
        for (o, &t) in out[..h].iter_mut().zip(&tmp[..h]) {
            *o += quantize_partial(t);
        }
    }
}

/// Reusable per-rank scratch buffers of the scalar kernel: its inner
/// loops run per row × layer × step, so none of them heap-allocate.
#[derive(Default)]
struct Scratch {
    h_n: Vec<f32>,    // [h] normed row
    q: Vec<f32>,      // [qd_l]
    k: Vec<f32>,      // [kvd_l]
    v: Vec<f32>,      // [kvd_l]
    ctxv: Vec<f32>,   // [qd_l] attention context
    head: Vec<f32>,   // [hd] one head's context
    tmp: Vec<f32>,    // [h] row-parallel chunk accumulator
    scores: Vec<f32>, // [≤ max_seq] attention scores
    g: Vec<f32>,      // [f_l] gate activations
    u: Vec<f32>,      // [f_l] up activations
}

/// Reusable scratch of the blocked kernel — whole-step activations,
/// sized `[rows, dim]` so phases can fan rows/columns out over the
/// pool with per-unit disjoint writes.
#[derive(Default)]
struct BlockScratch {
    h_n: Vec<f32>,    // [rows, h] normed inputs
    q: Vec<f32>,      // [rows, qd_l]
    k: Vec<f32>,      // [rows, kvd_l]
    v: Vec<f32>,      // [rows, kvd_l]
    ctxv: Vec<f32>,   // [rows, qd_l] attention context
    act: Vec<f32>,    // [rows, f_l] fused silu(g)·u
    scores: Vec<f32>, // [rows, max_seq] attention scores
}

struct LayerWeights {
    ln1_g: Vec<f32>,  // [h] (norm gains are always f32)
    ln2_g: Vec<f32>,  // [h]
    wq: WeightMat,    // [h, qd_l]
    wk: WeightMat,    // [h, kvd_l]
    wv: WeightMat,    // [h, kvd_l]
    wo: WeightMat,    // [qd_l, h]  (row-parallel)
    wg: WeightMat,    // [h, f_l]
    wu: WeightMat,    // [h, f_l]
    wd: WeightMat,    // [f_l, h]   (row-parallel)
}

/// One published shared-prefix segment (DESIGN.md §13): an immutable
/// snapshot of the first `len` KV rows of a prefilled lane, every
/// layer and local kv head.  Per layer, row `kh·len + t` holds
/// position `t` of local head `kh`.  Lanes attach by reference; the
/// engine's refcounted page accounting
/// ([`crate::kvcache::PagedAllocator`]) decides when a segment may be
/// dropped, so the backend only checks structural invariants here.
struct SharedSeg {
    len: usize,
    layers: Vec<KvLayer>,
}

/// One rank's deterministic in-memory model + KV caches.
pub struct ReferenceBackend {
    batch: usize,
    preset: ModelPreset,
    variant: Variant,
    kernel: GemmKernel,
    /// resolved instruction tier every [`WeightMat::mac_panel`] call
    /// dispatches on (DESIGN.md §14)
    isa: Isa,
    // local shard dims
    n_heads_l: usize,
    n_kv_heads_l: usize,
    ffn_l: usize,
    vocab_l: usize,
    // weights (dtype per EngineConfig::weight_dtype; embedding and
    // norm gains stay f32 — DESIGN.md §11)
    embedding: Vec<f32>, // [vocab, h] (replicated)
    layers: Vec<LayerWeights>,
    final_g: Vec<f32>,   // [h] (replicated)
    lm_head: WeightMat,  // [h, vocab_l]
    /// per-layer KV planes, [batch, n_kv_heads_l, max_seq, hd] rows in
    /// the configured `kv_dtype`
    caches: Vec<KvLayer>,
    /// published shared-prefix segments, by engine-assigned id
    shared_segs: HashMap<u32, SharedSeg>,
    /// per-lane attachment: `(segment id, shared_len)` when the lane
    /// reads its KV prefix from a shared segment
    attach: Vec<Option<(u32, usize)>>,
    /// precomputed NeoX RoPE inverse frequencies, [hd/2]
    rope_inv: Vec<f32>,
    scratch: Scratch,
    blk: BlockScratch,
    pool: WorkerPool,
    par_threshold: usize,
}

impl ReferenceBackend {
    /// Build rank `rank`'s model from `preset` (the caller resolves it —
    /// normally via `EngineConfig::resolve_model`, so the engine and the
    /// backend can never see different architectures).
    pub fn new(cfg: &EngineConfig, rank: usize, preset: &ModelPreset)
               -> Result<Self> {
        let preset = preset.clone();
        let world = cfg.world;
        ensure!(rank < world, "rank {rank} out of world {world}");
        ensure!(preset.supports_world(world),
                "model {} does not shard over world={world}", preset.name);
        let (h, hd) = (preset.hidden, preset.head_dim);
        let qd = preset.n_heads * hd;
        ensure!(
            world <= REDUCE_CHUNKS
                && REDUCE_CHUNKS % world == 0
                && qd % REDUCE_CHUNKS == 0
                && preset.ffn % REDUCE_CHUNKS == 0,
            "reference backend needs world ≤ {REDUCE_CHUNKS} and \
             attn/ffn widths divisible by {REDUCE_CHUNKS} \
             (model {}, world {world})",
            preset.name
        );
        let seed = match &cfg.weights {
            WeightSource::Synthetic { seed } => *seed,
            WeightSource::NpyDir { .. } => bail!(
                "the reference backend only supports synthetic weights \
                 (weights.kind = \"npydir\" is an XLA-backend golden-\
                 parity feature)"
            ),
        };

        if cfg.weight_dtype == Dtype::Int8 {
            ensure!(
                h % WEIGHT_QUANT_GROUP == 0,
                "weight_dtype = \"int8\" needs hidden divisible by the \
                 quant group {WEIGHT_QUANT_GROUP} (model {}, hidden {h})",
                preset.name
            );
        }

        let n_heads_l = preset.heads_local(world);
        let n_kv_heads_l = preset.kv_heads_local(world);
        let ffn_l = preset.ffn_local(world);
        let vocab_l = preset.vocab_local(world);
        let (qd_l, kvd_l) = (n_heads_l * hd, n_kv_heads_l * hd);

        let t = |li: i64, name: &str| tensor_seed(seed, li, name);
        // quant group per matrix (DESIGN.md §11): the reduction-chunk
        // width for row-parallel weights (shard- and chunk-aligned by
        // construction), the fixed group otherwise (k = hidden, which
        // is replicated)
        let quant_group = |name: &str| match name {
            "wo" => qd / REDUCE_CHUNKS,
            "wd" => preset.ffn / REDUCE_CHUNKS,
            _ => WEIGHT_QUANT_GROUP,
        };
        // one matmul weight, in the configured dtype; INT8 quantizes
        // the FULL tensor before sharding so `q·s` values are
        // world-invariant
        let wm = |name: &str, shape: &[usize], seed_v: u64|
                  -> Result<WeightMat> {
            match cfg.weight_dtype {
                Dtype::F32 => Ok(WeightMat::f32(
                    synth_shard(name, shape, world, rank, seed_v),
                    shape[1],
                )),
                Dtype::Int8 => Ok(WeightMat::Int8(synth_quant_shard(
                    name, shape, world, rank, seed_v, quant_group(name),
                )?)),
            }
        };
        // resolve the instruction tier once; every mac_panel call in
        // this backend dispatches on it (a forced-but-unavailable tier
        // fails loudly here, before any weights are built)
        let isa = simd::resolve(cfg.isa)?;

        let mut layers = Vec::with_capacity(preset.n_layers);
        for li in 0..preset.n_layers as i64 {
            layers.push(LayerWeights {
                ln1_g: synth_shard("ln1_g", &[h], world, rank,
                                   t(li, "ln1_g")),
                ln2_g: synth_shard("ln2_g", &[h], world, rank,
                                   t(li, "ln2_g")),
                wq: wm("wq", &[h, qd_l], t(li, "wq"))?,
                wk: wm("wk", &[h, kvd_l], t(li, "wk"))?,
                wv: wm("wv", &[h, kvd_l], t(li, "wv"))?,
                wo: wm("wo", &[qd_l, h], t(li, "wo"))?,
                wg: wm("wg", &[h, ffn_l], t(li, "wg"))?,
                wu: wm("wu", &[h, ffn_l], t(li, "wu"))?,
                wd: wm("wd", &[ffn_l, h], t(li, "wd"))?,
            });
        }
        let embedding = synth_shard("embedding", &[preset.vocab, h], world,
                                    rank, t(-1, "embedding"));
        let final_g =
            synth_shard("final_g", &[h], world, rank, t(-1, "final_g"));
        let mut lm_head = wm("lm_head", &[h, vocab_l], t(-1, "lm_head"))?;

        if isa == Isa::Vnni {
            // build the dpbusd weight packs once, up front (a no-op on
            // f32 matrices and on CPUs without the VNNI fast path —
            // the exact integer emulation then serves every group)
            for lw in &mut layers {
                for m in [&mut lw.wq, &mut lw.wk, &mut lw.wv,
                          &mut lw.wo, &mut lw.wg, &mut lw.wu,
                          &mut lw.wd]
                {
                    m.ensure_vnni_pack();
                }
            }
            lm_head.ensure_vnni_pack();
        }

        let cache_rows = cfg.batch * n_kv_heads_l * preset.max_seq;
        let caches = (0..preset.n_layers)
            .map(|_| KvLayer::new(cfg.kv_dtype, cache_rows, hd))
            .collect();
        let rope_inv = (0..hd / 2)
            .map(|i| {
                (preset.rope_theta as f32)
                    .powf(-(2.0 * i as f32) / hd as f32)
            })
            .collect();

        // the scalar baseline is single-threaded by definition; the
        // blocked kernel fans out over the configured/auto pool
        let threads = match cfg.kernel {
            GemmKernel::Scalar => 1,
            GemmKernel::Blocked => auto_threads(cfg.threads, world),
        };
        let pool = WorkerPool::new(threads)?;

        Ok(ReferenceBackend {
            batch: cfg.batch,
            variant: cfg.variant,
            kernel: cfg.kernel,
            isa,
            n_heads_l,
            n_kv_heads_l,
            ffn_l,
            vocab_l,
            embedding,
            layers,
            final_g,
            lm_head,
            caches,
            shared_segs: HashMap::new(),
            attach: vec![None; cfg.batch],
            rope_inv,
            scratch: Scratch::default(),
            blk: BlockScratch::default(),
            pool,
            par_threshold: PAR_THRESHOLD_MACS,
            preset,
        })
    }

    /// Threads the blocked kernel fans out over (1 for `scalar`).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Test hook: lower the inline-vs-pool cutoff so small models
    /// exercise the threaded code paths.  Not part of the public API.
    #[doc(hidden)]
    pub fn set_par_threshold(&mut self, macs: usize) {
        self.par_threshold = macs;
    }

    // ---- scalar kernel path --------------------------------------------

    /// Column-parallel matmul: `out[j] += Σ_k a[k]·w[k, j]` over the
    /// full (replicated) contraction axis.  `out` must be zeroed.
    fn col_matmul(isa: Isa, a: &[f32], w: &WeightMat, cols: usize,
                  out: &mut [f32]) {
        w.mac_panel(isa, 0, a.len(), 0, cols, a, &mut out[..cols]);
    }

    /// Attention partial for one activation row (already normed into
    /// `s.h_n`): project q/k/v, rope, append to the cache at `pos`
    /// (lane `lane`), attend over `[0, attend_hi)`, and add the
    /// quantized `context @ wo` partial into `out`.
    fn attn_row(&mut self, li: usize, lane: usize, pos: i32,
                attend_hi: usize, s: &mut Scratch, out: &mut [f32])
                -> Result<()> {
        let isa = self.isa;
        let hd = self.preset.head_dim;
        let (qd_l, kvd_l) =
            (self.n_heads_l * hd, self.n_kv_heads_l * hd);
        let group = self.n_heads_l / self.n_kv_heads_l;
        let t_max = self.preset.max_seq;

        s.q.clear();
        s.q.resize(qd_l, 0.0);
        s.k.clear();
        s.k.resize(kvd_l, 0.0);
        s.v.clear();
        s.v.resize(kvd_l, 0.0);
        {
            let lw = &self.layers[li];
            Self::col_matmul(isa, &s.h_n, &lw.wq, qd_l, &mut s.q);
            Self::col_matmul(isa, &s.h_n, &lw.wk, kvd_l, &mut s.k);
            Self::col_matmul(isa, &s.h_n, &lw.wv, kvd_l, &mut s.v);
        }
        for qh in 0..self.n_heads_l {
            rope_head(&mut s.q[qh * hd..(qh + 1) * hd], &self.rope_inv,
                      pos);
        }
        for kh in 0..self.n_kv_heads_l {
            rope_head(&mut s.k[kh * hd..(kh + 1) * hd], &self.rope_inv,
                      pos);
        }

        {
            // quantize-on-append at kv_dtype = int8; plain copy at f32
            let cache = &mut self.caches[li];
            let t = pos as usize;
            for kh in 0..self.n_kv_heads_l {
                let row = (lane * self.n_kv_heads_l + kh) * t_max + t;
                cache.append_row(row, (&s.k[kh * hd..(kh + 1) * hd],
                                       &s.v[kh * hd..(kh + 1) * hd]))?;
            }
        }

        s.ctxv.clear();
        s.ctxv.resize(qd_l, 0.0);
        s.head.resize(hd, 0.0);
        let att = self.attach[lane];
        for qh in 0..self.n_heads_l {
            let kh = qh / group;
            let row0 = (lane * self.n_kv_heads_l + kh) * t_max;
            s.scores.clear();
            s.scores.resize(attend_hi, 0.0);
            match &self.caches[li] {
                KvLayer::F32 { k: kc, v: vc } => {
                    let sh = match att {
                        Some((seg, slen)) => {
                            let g = &self.shared_segs[&seg];
                            match &g.layers[li] {
                                KvLayer::F32 { k, v } => Some(SharedF32 {
                                    k,
                                    v,
                                    base: kh * g.len * hd,
                                    len: slen,
                                }),
                                _ => unreachable!(
                                    "shared segment dtype mismatch"
                                ),
                            }
                        }
                        None => None,
                    };
                    attend_into(kc, vc, row0 * hd, hd,
                                &s.q[qh * hd..(qh + 1) * hd],
                                &mut s.scores, &mut s.head,
                                sh.as_ref());
                }
                KvLayer::Int8 { k: kc, v: vc, k_scale, v_scale } => {
                    let sh = match att {
                        Some((seg, slen)) => {
                            let g = &self.shared_segs[&seg];
                            match &g.layers[li] {
                                KvLayer::Int8 {
                                    k, v, k_scale: sks, v_scale: svs,
                                } => Some(SharedQ8 {
                                    kq: k,
                                    ks: sks,
                                    vq: v,
                                    vs: svs,
                                    row0: kh * g.len,
                                    len: slen,
                                }),
                                _ => unreachable!(
                                    "shared segment dtype mismatch"
                                ),
                            }
                        }
                        None => None,
                    };
                    attend_into_q8(kc, k_scale, vc, v_scale, row0, hd,
                                   &s.q[qh * hd..(qh + 1) * hd],
                                   &mut s.scores, &mut s.head,
                                   sh.as_ref());
                }
            }
            s.ctxv[qh * hd..(qh + 1) * hd].copy_from_slice(&s.head[..hd]);
        }
        let qd_full = self.preset.n_heads * hd;
        rowpar_scalar(isa, &s.ctxv, &self.layers[li].wo, qd_l, qd_full,
                      self.preset.hidden, &mut s.tmp, out);
        Ok(())
    }

    /// FFN partial for one normed row (`s.h_n`): adds the quantized
    /// `(silu(h@wg) ⊙ (h@wu)) @ wd` partial into `out`.
    fn ffn_row(&self, li: usize, s: &mut Scratch, out: &mut [f32]) {
        let isa = self.isa;
        let lw = &self.layers[li];
        let f_l = self.ffn_l;
        s.g.clear();
        s.g.resize(f_l, 0.0);
        s.u.clear();
        s.u.resize(f_l, 0.0);
        Self::col_matmul(isa, &s.h_n, &lw.wg, f_l, &mut s.g);
        Self::col_matmul(isa, &s.h_n, &lw.wu, f_l, &mut s.u);
        for (gi, &ui) in s.g.iter_mut().zip(&s.u) {
            let sig = *gi / (1.0 + (-*gi).exp()); // SiLU
            *gi = sig * ui;
        }
        rowpar_scalar(isa, &s.g, &lw.wd, f_l, self.preset.ffn,
                      self.preset.hidden, &mut s.tmp, out);
    }

    /// The scalar layer body: one row at a time through norm →
    /// attention → FFN, exactly the pre-blocking loop structure.
    fn layer_scalar(&mut self, ctx: &StepCtx, li: usize, seg: usize,
                    rows: usize, x: &[f32], partial: &mut [f32])
                    -> Result<()> {
        let h = self.preset.hidden;
        let eps = self.preset.norm_eps as f32;
        let mut s = std::mem::take(&mut self.scratch);
        s.h_n.resize(h, 0.0);
        let mut body = || -> Result<()> {
            for r in 0..rows {
                let x_row = &x[r * h..(r + 1) * h];
                let out = r * h..(r + 1) * h;
                let (lane, pos, hi) = row_meta(ctx, r);
                match (self.variant, seg) {
                    (Variant::Parallel, _) => {
                        // fused block: ONE partial sum (the paper's
                        // §2.2); attention and FFN share the ln1 norm,
                        // as in python's build_parallel_block_*
                        rmsnorm_into(x_row, &self.layers[li].ln1_g,
                                     eps, &mut s.h_n);
                        self.attn_row(li, lane, pos, hi, &mut s,
                                      &mut partial[out.clone()])?;
                        self.ffn_row(li, &mut s, &mut partial[out]);
                    }
                    (Variant::Serial, 0) => {
                        rmsnorm_into(x_row, &self.layers[li].ln1_g,
                                     eps, &mut s.h_n);
                        self.attn_row(li, lane, pos, hi, &mut s,
                                      &mut partial[out])?;
                    }
                    (Variant::Serial, _) => {
                        rmsnorm_into(x_row, &self.layers[li].ln2_g,
                                     eps, &mut s.h_n);
                        self.ffn_row(li, &mut s, &mut partial[out]);
                    }
                }
            }
            Ok(())
        };
        let r = body();
        self.scratch = s;
        r
    }

    // ---- blocked kernel path -------------------------------------------

    /// The blocked layer body: whole-step phases (norm → q/k/v GEMM →
    /// rope/KV → attention → wo ‖ gate/up → wd), each fanned out over
    /// the pool with fixed output-block units.  Bit-identical to
    /// [`Self::layer_scalar`] — see the module docs.
    fn layer_blocked(&mut self, ctx: &StepCtx, li: usize, seg: usize,
                     rows: usize, x: &[f32], partial: &mut [f32])
                     -> Result<()> {
        let isa = self.isa;
        let h = self.preset.hidden;
        let hd = self.preset.head_dim;
        let (n_h, n_kv) = (self.n_heads_l, self.n_kv_heads_l);
        let (qd_l, kvd_l) = (n_h * hd, n_kv * hd);
        let group = n_h / n_kv;
        let f_l = self.ffn_l;
        let t_max = self.preset.max_seq;
        let eps = self.preset.norm_eps as f32;
        let qd_full = self.preset.n_heads * hd;
        let ffn_full = self.preset.ffn;
        let thr = self.par_threshold;
        let variant = self.variant;
        let attn_seg = variant == Variant::Parallel || seg == 0;
        let ffn_seg = variant == Variant::Parallel || seg == 1;

        let hi_max =
            (0..rows).map(|r| row_meta(ctx, r).2).max().unwrap_or(1);

        let ReferenceBackend {
            layers, caches, blk, pool, rope_inv, shared_segs, attach, ..
        } = self;
        let lw = &layers[li];
        let rope_inv = &rope_inv[..];
        let shared_segs = &*shared_segs;
        let attach = &attach[..];

        blk.h_n.resize(rows * h, 0.0);
        blk.q.resize(rows * qd_l, 0.0);
        blk.k.resize(rows * kvd_l, 0.0);
        blk.v.resize(rows * kvd_l, 0.0);
        blk.ctxv.resize(rows * qd_l, 0.0);
        blk.act.resize(rows * f_l, 0.0);
        blk.scores.resize(rows * t_max, 0.0);
        let BlockScratch { h_n, q, k, v, ctxv, act, scores } = blk;

        // Phase N: norm every row (ln1 for attention / fused blocks,
        // ln2 for the serial FFN segment)
        {
            let gain =
                if attn_seg { &lw.ln1_g[..] } else { &lw.ln2_g[..] };
            let outs = DisjointSlices::new(&mut h_n[..rows * h]);
            pool.run_if_worth(rows, rows * h * 2, thr, &|r| {
                // SAFETY: one row per unit
                let dst = unsafe { outs.slice(r * h, h) };
                rmsnorm_into(&x[r * h..(r + 1) * h], gain, eps, dst);
            });
        }

        if attn_seg {
            let cache = &mut caches[li];
            // Phase P: q/k/v projections — each weight column block
            // streams once for ALL rows
            {
                let nq = col_blocks(qd_l);
                let nk = col_blocks(kvd_l);
                let qs = DisjointSlices::new(&mut q[..rows * qd_l]);
                let ks = DisjointSlices::new(&mut k[..rows * kvd_l]);
                let vs = DisjointSlices::new(&mut v[..rows * kvd_l]);
                let xn = &h_n[..rows * h];
                let macs = rows * h * (qd_l + 2 * kvd_l);
                pool.run_if_worth(nq + 2 * nk, macs, thr, &|u| {
                    if u < nq {
                        let (j0, j1) = block_range(u, qd_l);
                        colpar_block(isa, xn, h, rows, &lw.wq, qd_l,
                                     j0, j1, &qs, qd_l);
                    } else if u < nq + nk {
                        let (j0, j1) = block_range(u - nq, kvd_l);
                        colpar_block(isa, xn, h, rows, &lw.wk, kvd_l,
                                     j0, j1, &ks, kvd_l);
                    } else {
                        let (j0, j1) = block_range(u - nq - nk, kvd_l);
                        colpar_block(isa, xn, h, rows, &lw.wv, kvd_l,
                                     j0, j1, &vs, kvd_l);
                    }
                });
            }

            // Phase R: rope q/k and append k/v to the cache, per row —
            // ONE pool pass (the kv_dtype match sits outside the
            // dispatch, so the f32 path keeps PR 3's single fork/join
            // per attention segment).  Disjointness: decode rows are
            // distinct lanes, prefill rows are distinct positions of
            // one lane, so the per-(lane, head, pos) cache rows (and
            // their scale slots) are unique per unit.
            {
                let qs = DisjointSlices::new(&mut q[..rows * qd_l]);
                let ks = DisjointSlices::new(&mut k[..rows * kvd_l]);
                let vr = &v[..rows * kvd_l];
                let macs = rows * (qd_l + 2 * kvd_l);
                match cache {
                    KvLayer::F32 { k: kc, v: vc } => {
                        let kcs = DisjointSlices::new(&mut kc[..]);
                        let vcs = DisjointSlices::new(&mut vc[..]);
                        pool.run_if_worth(rows, macs, thr, &|r| {
                            let (lane, pos, _hi) = row_meta(ctx, r);
                            // SAFETY: one row per unit; cache rows are
                            // per-(lane,pos,head) and unique per row
                            let qrow =
                                unsafe { qs.slice(r * qd_l, qd_l) };
                            for qh in 0..n_h {
                                rope_head(
                                    &mut qrow[qh * hd..(qh + 1) * hd],
                                    rope_inv, pos);
                            }
                            let krow =
                                unsafe { ks.slice(r * kvd_l, kvd_l) };
                            for kh in 0..n_kv {
                                rope_head(
                                    &mut krow[kh * hd..(kh + 1) * hd],
                                    rope_inv, pos);
                                let dst = ((lane * n_kv + kh) * t_max
                                    + pos as usize)
                                    * hd;
                                unsafe { kcs.slice(dst, hd) }
                                    .copy_from_slice(
                                        &krow[kh * hd..(kh + 1) * hd]);
                                unsafe { vcs.slice(dst, hd) }
                                    .copy_from_slice(
                                        &vr[r * kvd_l + kh * hd
                                            ..r * kvd_l
                                                + (kh + 1) * hd]);
                            }
                        });
                    }
                    KvLayer::Int8 { k: kc, v: vc, k_scale, v_scale } => {
                        let kcs = DisjointSlices::new(&mut kc[..]);
                        let vcs = DisjointSlices::new(&mut vc[..]);
                        let kss = DisjointSlices::new(&mut k_scale[..]);
                        let vss = DisjointSlices::new(&mut v_scale[..]);
                        // quantization can refuse non-finite rows;
                        // units record the failure and the dispatch
                        // bails after the barrier
                        let qerr = FirstError::new();
                        pool.run_if_worth(rows, macs, thr, &|r| {
                            let (lane, pos, _hi) = row_meta(ctx, r);
                            // SAFETY: one row per unit; cache rows and
                            // their scale slots are per-(lane,pos,head)
                            // and unique per row
                            let qrow =
                                unsafe { qs.slice(r * qd_l, qd_l) };
                            for qh in 0..n_h {
                                rope_head(
                                    &mut qrow[qh * hd..(qh + 1) * hd],
                                    rope_inv, pos);
                            }
                            let krow =
                                unsafe { ks.slice(r * kvd_l, kvd_l) };
                            qerr.capture(|| {
                                for kh in 0..n_kv {
                                    rope_head(
                                        &mut krow[kh * hd
                                            ..(kh + 1) * hd],
                                        rope_inv, pos);
                                    let row = (lane * n_kv + kh)
                                        * t_max
                                        + pos as usize;
                                    let kq = unsafe {
                                        kcs.slice(row * hd, hd)
                                    };
                                    unsafe { kss.slice(row, 1) }[0] =
                                        quant_row_into(
                                            &krow[kh * hd
                                                ..(kh + 1) * hd],
                                            kq)?;
                                    let vq = unsafe {
                                        vcs.slice(row * hd, hd)
                                    };
                                    unsafe { vss.slice(row, 1) }[0] =
                                        quant_row_into(
                                            &vr[r * kvd_l + kh * hd
                                                ..r * kvd_l
                                                    + (kh + 1) * hd],
                                            vq)?;
                                }
                                Ok(())
                            });
                        });
                        if let Some(e) = qerr.take() {
                            return Err(e);
                        }
                    }
                }
            }

            // Phase A: attention per row over the (fully written)
            // cache, dequantizing int8 rows inside the inner products
            {
                let ctxs = DisjointSlices::new(&mut ctxv[..rows * qd_l]);
                let scs =
                    DisjointSlices::new(&mut scores[..rows * t_max]);
                let qr = &q[..rows * qd_l];
                let macs = rows * n_h * hi_max * hd * 2;
                match cache {
                    KvLayer::F32 { k: kc, v: vc } => {
                        let (kcr, vcr) = (&kc[..], &vc[..]);
                        pool.run_if_worth(rows, macs, thr, &|r| {
                            let (lane, _pos, hi) = row_meta(ctx, r);
                            let att = attach[lane];
                            // SAFETY: one row per unit
                            let sc =
                                unsafe { scs.slice(r * t_max, t_max) };
                            let out =
                                unsafe { ctxs.slice(r * qd_l, qd_l) };
                            for qh in 0..n_h {
                                let kh = qh / group;
                                let base = (lane * n_kv + kh) * t_max
                                    * hd;
                                let sh = match att {
                                    Some((seg, slen)) => {
                                        let g = &shared_segs[&seg];
                                        match &g.layers[li] {
                                            KvLayer::F32 { k, v } => {
                                                Some(SharedF32 {
                                                    k,
                                                    v,
                                                    base: kh * g.len
                                                        * hd,
                                                    len: slen,
                                                })
                                            }
                                            _ => unreachable!(
                                                "shared segment dtype \
                                                 mismatch"
                                            ),
                                        }
                                    }
                                    None => None,
                                };
                                attend_into(
                                    kcr, vcr, base, hd,
                                    &qr[r * qd_l + qh * hd
                                        ..r * qd_l + (qh + 1) * hd],
                                    &mut sc[..hi],
                                    &mut out[qh * hd..(qh + 1) * hd],
                                    sh.as_ref(),
                                );
                            }
                        });
                    }
                    KvLayer::Int8 { k: kc, v: vc, k_scale, v_scale } => {
                        let (kcr, vcr) = (&kc[..], &vc[..]);
                        let (ksr, vsr) = (&k_scale[..], &v_scale[..]);
                        pool.run_if_worth(rows, macs, thr, &|r| {
                            let (lane, _pos, hi) = row_meta(ctx, r);
                            let att = attach[lane];
                            // SAFETY: one row per unit
                            let sc =
                                unsafe { scs.slice(r * t_max, t_max) };
                            let out =
                                unsafe { ctxs.slice(r * qd_l, qd_l) };
                            for qh in 0..n_h {
                                let kh = qh / group;
                                let row0 = (lane * n_kv + kh) * t_max;
                                let sh = match att {
                                    Some((seg, slen)) => {
                                        let g = &shared_segs[&seg];
                                        match &g.layers[li] {
                                            KvLayer::Int8 {
                                                k,
                                                v,
                                                k_scale: sks,
                                                v_scale: svs,
                                            } => Some(SharedQ8 {
                                                kq: k,
                                                ks: sks,
                                                vq: v,
                                                vs: svs,
                                                row0: kh * g.len,
                                                len: slen,
                                            }),
                                            _ => unreachable!(
                                                "shared segment dtype \
                                                 mismatch"
                                            ),
                                        }
                                    }
                                    None => None,
                                };
                                attend_into_q8(
                                    kcr, ksr, vcr, vsr, row0, hd,
                                    &qr[r * qd_l + qh * hd
                                        ..r * qd_l + (qh + 1) * hd],
                                    &mut sc[..hi],
                                    &mut out[qh * hd..(qh + 1) * hd],
                                    sh.as_ref(),
                                );
                            }
                        });
                    }
                }
            }

            // Phase O: context @ wo row-parallel partial
            {
                let cs = qd_full / REDUCE_CHUNKS;
                let outs =
                    DisjointSlices::new(&mut partial[..rows * h]);
                let cr = &ctxv[..rows * qd_l];
                pool.run_if_worth(
                    col_blocks(h), rows * qd_l * h, thr, &|u| {
                        let (j0, j1) = block_range(u, h);
                        rowpar_block(isa, cr, qd_l, rows, &lw.wo, h,
                                     cs, j0, j1, &outs);
                    });
            }
        }

        if ffn_seg {
            // Phase G: fused gate/up GEMMs + SiLU
            {
                let acts = DisjointSlices::new(&mut act[..rows * f_l]);
                let xn = &h_n[..rows * h];
                pool.run_if_worth(
                    col_blocks(f_l), rows * h * 2 * f_l, thr, &|u| {
                        let (j0, j1) = block_range(u, f_l);
                        gateup_block(isa, xn, h, rows, &lw.wg, &lw.wu,
                                     f_l, j0, j1, &acts);
                    });
            }
            // Phase D: act @ wd row-parallel partial
            {
                let cs = ffn_full / REDUCE_CHUNKS;
                let outs =
                    DisjointSlices::new(&mut partial[..rows * h]);
                let ar = &act[..rows * f_l];
                pool.run_if_worth(
                    col_blocks(h), rows * f_l * h, thr, &|u| {
                        let (j0, j1) = block_range(u, h);
                        rowpar_block(isa, ar, f_l, rows, &lw.wd, h,
                                     cs, j0, j1, &outs);
                    });
            }
        }
        Ok(())
    }
}

/// Per-row `(lane, position, attend_hi)` for this step's KV update.
/// Prefill rows live at absolute positions `offset + r` and attend
/// over the full causal window `[0, offset + r + 1)` — for `offset >
/// 0` that window spans KV rows an *earlier chunk* appended, which is
/// what lets a chunked prefill reproduce the whole-prompt bits
/// (DESIGN.md §12).
fn row_meta(ctx: &StepCtx, r: usize) -> (usize, i32, usize) {
    match ctx {
        StepCtx::Prefill { lane, length, offset, .. } => {
            let hi = offset + if r < *length { r + 1 } else { *length };
            (*lane, (offset + r) as i32, hi)
        }
        StepCtx::Decode { positions } => {
            let pos = positions[r];
            (r, pos, pos as usize + 1)
        }
        StepCtx::Verify { lanes, positions } => {
            // verify rows carry their owning lane explicitly; each row
            // appends at its own position and attends over the same
            // causal window one-at-a-time decode would see (rows are
            // distinct (lane, pos) pairs — positions are strictly
            // ascending within a lane — so the blocked kernel's
            // per-row cache writes stay disjoint)
            let pos = positions[r];
            (lanes[r] as usize, pos, pos as usize + 1)
        }
    }
}

impl ExecBackend for ReferenceBackend {
    fn embed(&mut self, _ctx: &StepCtx, tokens: &[i32], x: &mut [f32])
             -> Result<()> {
        let h = self.preset.hidden;
        ensure!(x.len() >= tokens.len() * h,
                "embed output buffer too small");
        for (i, &t) in tokens.iter().enumerate() {
            let t = (t.max(0) as usize).min(self.preset.vocab - 1);
            x[i * h..(i + 1) * h]
                .copy_from_slice(&self.embedding[t * h..(t + 1) * h]);
        }
        Ok(())
    }

    fn layer_partial(&mut self, ctx: &StepCtx, li: usize, seg: usize,
                     x: &[f32], partial: &mut [f32]) -> Result<()> {
        ensure!(li < self.preset.n_layers, "layer {li} out of range");
        let segs = self.variant.syncs_per_layer();
        ensure!(seg < segs, "segment {seg} out of range for {:?}",
                self.variant);
        let h = self.preset.hidden;
        let max_seq = self.preset.max_seq;
        let rows = ctx.rows(self.batch);
        ensure!(x.len() >= rows * h && partial.len() >= rows * h,
                "activation buffers too small");
        // reject malformed lane/position bookkeeping loudly: silently
        // clamping would turn an engine bug into KV corruption
        match ctx {
            StepCtx::Prefill { lane, bucket, length, offset } => {
                ensure!(*offset + *bucket <= max_seq && *length >= 1
                            && *length <= *bucket,
                        "prefill shape out of range: bucket={bucket} \
                         length={length} offset={offset} \
                         max_seq={max_seq}");
                ensure!(*lane < self.batch,
                        "prefill lane {lane} out of range (batch {})",
                        self.batch);
            }
            StepCtx::Decode { positions } => {
                ensure!(positions.len() == rows,
                        "decode got {} positions for batch {rows}",
                        positions.len());
                for (b, &p) in positions.iter().enumerate() {
                    ensure!(p >= 0 && (p as usize) < max_seq,
                            "lane {b} position {p} out of range \
                             (max_seq {max_seq})");
                }
            }
            StepCtx::Verify { lanes, positions } => {
                ensure!(!lanes.is_empty(),
                        "verify step carries no rows");
                ensure!(lanes.len() == positions.len(),
                        "verify got {} lanes but {} positions",
                        lanes.len(), positions.len());
                let mut last = vec![i32::MIN; self.batch];
                for (r, (&l, &p)) in
                    lanes.iter().zip(positions.iter()).enumerate()
                {
                    ensure!((l as usize) < self.batch,
                            "verify row {r} lane {l} out of range \
                             (batch {})", self.batch);
                    ensure!(p >= 0 && (p as usize) < max_seq,
                            "verify row {r} position {p} out of range \
                             (max_seq {max_seq})");
                    // strictly ascending per lane: guarantees distinct
                    // (lane, pos) cache rows across this step's writes
                    ensure!(p > last[l as usize],
                            "verify positions for lane {l} must be \
                             strictly ascending (row {r}: {p})");
                    last[l as usize] = p;
                }
            }
        }
        partial[..rows * h].fill(0.0);
        match self.kernel {
            GemmKernel::Scalar => {
                self.layer_scalar(ctx, li, seg, rows, x, partial)
            }
            GemmKernel::Blocked => {
                self.layer_blocked(ctx, li, seg, rows, x, partial)
            }
        }
    }

    fn lm_head(&mut self, x: &[f32], logits: &mut [f32]) -> Result<()> {
        let h = self.preset.hidden;
        let v_l = self.vocab_l;
        let b = self.batch;
        let eps = self.preset.norm_eps as f32;
        ensure!(x.len() >= b * h && logits.len() >= b * v_l,
                "lm_head buffers too small");
        match self.kernel {
            GemmKernel::Scalar => {
                let isa = self.isa;
                let mut s = std::mem::take(&mut self.scratch);
                s.h_n.resize(h, 0.0);
                for r in 0..b {
                    rmsnorm_into(&x[r * h..(r + 1) * h], &self.final_g,
                                 eps, &mut s.h_n);
                    let out = &mut logits[r * v_l..(r + 1) * v_l];
                    out.fill(0.0);
                    Self::col_matmul(isa, &s.h_n, &self.lm_head, v_l,
                                     out);
                }
                self.scratch = s;
            }
            GemmKernel::Blocked => {
                let thr = self.par_threshold;
                let isa = self.isa;
                let ReferenceBackend {
                    blk, pool, final_g, lm_head, ..
                } = self;
                blk.h_n.resize(b * h, 0.0);
                let h_n = &mut blk.h_n;
                let final_g = &final_g[..];
                let lm_w = &*lm_head;
                {
                    let outs = DisjointSlices::new(&mut h_n[..b * h]);
                    pool.run_if_worth(b, b * h * 2, thr, &|r| {
                        // SAFETY: one row per unit
                        let dst = unsafe { outs.slice(r * h, h) };
                        rmsnorm_into(&x[r * h..(r + 1) * h], final_g,
                                     eps, dst);
                    });
                }
                {
                    let outs =
                        DisjointSlices::new(&mut logits[..b * v_l]);
                    let xn = &h_n[..b * h];
                    pool.run_if_worth(
                        col_blocks(v_l), b * h * v_l, thr, &|u| {
                            let (j0, j1) = block_range(u, v_l);
                            colpar_block(isa, xn, h, b, lm_w, v_l,
                                         j0, j1, &outs, v_l);
                        });
                }
            }
        }
        Ok(())
    }

    fn reset(&mut self) -> Result<()> {
        for layer in &mut self.caches {
            layer.reset();
        }
        self.shared_segs.clear();
        for a in &mut self.attach {
            *a = None;
        }
        Ok(())
    }

    fn publish_prefix(&mut self, seg: u32, lane: usize, len: usize)
                      -> Result<()> {
        let hd = self.preset.head_dim;
        let t_max = self.preset.max_seq;
        let n_kv = self.n_kv_heads_l;
        ensure!(lane < self.batch,
                "publish_prefix lane {lane} out of range (batch {})",
                self.batch);
        ensure!(len >= 1 && len <= t_max,
                "publish_prefix len {len} out of range (max_seq \
                 {t_max})");
        ensure!(!self.shared_segs.contains_key(&seg),
                "shared segment {seg} already exists");
        let dtype = self.caches[0].dtype();
        let mut seg_layers = Vec::with_capacity(self.caches.len());
        for cache in &self.caches {
            let mut layer = KvLayer::new(dtype, n_kv * len, hd);
            for kh in 0..n_kv {
                for t in 0..len {
                    // verbatim row transfer (bytes + scales at int8),
                    // so attached readers see the publisher's bits
                    layer.copy_row_from(
                        kh * len + t, cache,
                        (lane * n_kv + kh) * t_max + t, hd);
                }
            }
            seg_layers.push(layer);
        }
        self.shared_segs
            .insert(seg, SharedSeg { len, layers: seg_layers });
        Ok(())
    }

    fn attach_prefix(&mut self, lane: usize, seg: u32, shared_len: usize,
                     copy_len: usize) -> Result<()> {
        let hd = self.preset.head_dim;
        let t_max = self.preset.max_seq;
        let n_kv = self.n_kv_heads_l;
        ensure!(lane < self.batch,
                "attach_prefix lane {lane} out of range (batch {})",
                self.batch);
        let ReferenceBackend { caches, shared_segs, attach, .. } = self;
        let g = shared_segs.get(&seg).ok_or_else(|| {
            anyhow::anyhow!("attach_prefix: unknown shared segment {seg}")
        })?;
        ensure!(shared_len >= 1 && shared_len <= g.len,
                "attach_prefix shared_len {shared_len} out of segment \
                 length {}", g.len);
        ensure!(shared_len + copy_len <= g.len,
                "attach_prefix copy range {shared_len}+{copy_len} past \
                 segment length {}", g.len);
        // copy-on-write of the partially matched page: the divergent
        // tail rows become the lane's private copies
        for (cache, src) in caches.iter_mut().zip(&g.layers) {
            for kh in 0..n_kv {
                for t in shared_len..shared_len + copy_len {
                    cache.copy_row_from(
                        (lane * n_kv + kh) * t_max + t, src,
                        kh * g.len + t, hd);
                }
            }
        }
        attach[lane] = Some((seg, shared_len));
        Ok(())
    }

    fn detach_prefix(&mut self, lane: usize) -> Result<()> {
        ensure!(lane < self.batch,
                "detach_prefix lane {lane} out of range (batch {})",
                self.batch);
        self.attach[lane] = None;
        Ok(())
    }

    fn drop_prefix(&mut self, seg: u32) -> Result<()> {
        ensure!(self.shared_segs.contains_key(&seg),
                "drop_prefix: unknown shared segment {seg}");
        for (lane, a) in self.attach.iter().enumerate() {
            if let Some((s, _)) = a {
                ensure!(*s != seg,
                        "drop_prefix({seg}): lane {lane} is still \
                         attached");
            }
        }
        self.shared_segs.remove(&seg);
        Ok(())
    }

    fn truncate_lane(&mut self, lane: usize, new_len: usize)
                     -> Result<()> {
        let t_max = self.preset.max_seq;
        let hd = self.preset.head_dim;
        let n_kv = self.n_kv_heads_l;
        ensure!(lane < self.batch,
                "truncate_lane lane {lane} out of range (batch {})",
                self.batch);
        ensure!(new_len >= 1 && new_len <= t_max,
                "truncate_lane len {new_len} out of range (max_seq \
                 {t_max})");
        if let Some((seg, slen)) = self.attach[lane] {
            ensure!(new_len >= slen,
                    "truncate_lane({lane}, {new_len}) reaches into \
                     shared segment {seg} ({slen} rows by reference)");
        }
        // scrub the dead rows so the lane's cache is bit-identical to
        // one that only ever appended new_len rows — rollback leaves
        // no residue for tests (or a future snapshot path) to trip on
        for cache in &mut self.caches {
            for kh in 0..n_kv {
                for t in new_len..t_max {
                    cache.zero_row((lane * n_kv + kh) * t_max + t, hd);
                }
            }
        }
        Ok(())
    }

    fn snapshot_lane(&mut self, lane: usize, len: usize)
                     -> Result<Vec<u8>> {
        let t_max = self.preset.max_seq;
        let hd = self.preset.head_dim;
        let n_kv = self.n_kv_heads_l;
        ensure!(lane < self.batch,
                "snapshot_lane lane {lane} out of range (batch {})",
                self.batch);
        ensure!(len >= 1 && len <= t_max,
                "snapshot_lane len {len} out of range (max_seq {t_max})");
        // the lane's *logical* prefix: rows below an attachment's
        // shared_len live in the segment, everything else is private —
        // exporting resolves the indirection so the shard restores as
        // plain private rows on any future fleet
        let seg = match self.attach[lane] {
            Some((seg, slen)) => {
                let g = self.shared_segs.get(&seg).ok_or_else(|| {
                    anyhow::anyhow!(
                        "snapshot_lane: lane {lane} attached to unknown \
                         shared segment {seg}")
                })?;
                Some((g, slen))
            }
            None => None,
        };
        let mut out = Vec::with_capacity(
            self.caches.len() * n_kv * len
                * row_bytes(self.caches[0].dtype(), hd));
        for (li, cache) in self.caches.iter().enumerate() {
            for kh in 0..n_kv {
                for t in 0..len {
                    match seg {
                        Some((g, slen)) if t < slen => g.layers[li]
                            .export_row(kh * g.len + t, hd, &mut out),
                        _ => cache.export_row(
                            (lane * n_kv + kh) * t_max + t, hd, &mut out),
                    }
                }
            }
        }
        Ok(out)
    }

    fn restore_lane(&mut self, lane: usize, len: usize, bytes: &[u8])
                    -> Result<()> {
        let t_max = self.preset.max_seq;
        let hd = self.preset.head_dim;
        let n_kv = self.n_kv_heads_l;
        ensure!(lane < self.batch,
                "restore_lane lane {lane} out of range (batch {})",
                self.batch);
        ensure!(len >= 1 && len <= t_max,
                "restore_lane len {len} out of range (max_seq {t_max})");
        let rb = row_bytes(self.caches[0].dtype(), hd);
        let expect = self.caches.len() * n_kv * len * rb;
        ensure!(bytes.len() == expect,
                "restore_lane({lane}) shard is {} bytes, expected \
                 {expect} ({} layers × {n_kv} heads × {len} rows)",
                bytes.len(), self.caches.len());
        // restored rows are fully private — segment ids don't survive
        // a reshard, so any stale attachment is cleared first
        self.attach[lane] = None;
        let mut off = 0;
        for cache in &mut self.caches {
            for kh in 0..n_kv {
                for t in 0..len {
                    cache.import_row(
                        (lane * n_kv + kh) * t_max + t, hd,
                        &bytes[off..off + rb])?;
                    off += rb;
                }
                // scrub the tail so the lane is bit-identical to one
                // that only ever appended `len` rows
                for t in len..t_max {
                    cache.zero_row((lane * n_kv + kh) * t_max + t, hd);
                }
            }
        }
        Ok(())
    }

    fn mem_usage(&self) -> MemUsage {
        let mut weight_bytes =
            ((self.embedding.len() + self.final_g.len()) * 4) as u64;
        weight_bytes += self.lm_head.bytes();
        for lw in &self.layers {
            weight_bytes +=
                ((lw.ln1_g.len() + lw.ln2_g.len()) * 4) as u64;
            for m in [&lw.wq, &lw.wk, &lw.wv, &lw.wo, &lw.wg, &lw.wu,
                      &lw.wd]
            {
                weight_bytes += m.bytes();
            }
        }
        let mut kv_bytes: u64 =
            self.caches.iter().map(KvLayer::bytes).sum();
        for g in self.shared_segs.values() {
            kv_bytes += g.layers.iter().map(KvLayer::bytes).sum::<u64>();
        }
        MemUsage { weight_bytes, kv_bytes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;

    fn cfg(world: usize, batch: usize) -> EngineConfig {
        EngineConfig {
            backend: BackendKind::Reference,
            world,
            batch,
            weights: WeightSource::Synthetic { seed: 7 },
            ..Default::default()
        }
    }

    fn backend(c: &EngineConfig, rank: usize) -> Result<ReferenceBackend> {
        let preset = ModelPreset::builtin(&c.model)?;
        ReferenceBackend::new(c, rank, &preset)
    }

    #[test]
    fn quantized_grid_sums_are_exact_in_any_order() {
        // the invariant the world-parity guarantee rests on
        let vals: Vec<f32> = (0..16)
            .map(|i| quantize_partial((i as f32 * 0.377).sin() * 3.0))
            .collect();
        let fwd: f32 = vals.iter().sum();
        let rev: f32 = vals.iter().rev().sum();
        let pairs: f32 = vals.chunks(2).map(|c| c[0] + c[1]).sum();
        assert_eq!(fwd.to_bits(), rev.to_bits());
        assert_eq!(fwd.to_bits(), pairs.to_bits());
    }

    #[test]
    fn decode_partials_sum_identically_across_worlds() {
        // one decode step through one layer: Σ_ranks partial must be
        // bit-identical for world 1, 2 and 4
        let h = 64;
        let x: Vec<f32> =
            (0..h).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.05).collect();
        let mut sums: Vec<Vec<f32>> = Vec::new();
        for world in [1usize, 2, 4] {
            let mut total = vec![0.0f32; h];
            for rank in 0..world {
                let mut be = backend(&cfg(world, 1), rank).unwrap();
                let mut part = vec![0.0f32; h];
                let ctx = StepCtx::Decode { positions: &[0] };
                be.layer_partial(&ctx, 0, 0, &x, &mut part).unwrap();
                for (t, p) in total.iter_mut().zip(&part) {
                    *t += *p;
                }
            }
            sums.push(total);
        }
        for w in 1..sums.len() {
            for j in 0..h {
                assert_eq!(
                    sums[0][j].to_bits(),
                    sums[w][j].to_bits(),
                    "col {j} differs between world 1 and {}",
                    [1, 2, 4][w]
                );
            }
        }
    }

    #[test]
    fn lm_head_shards_concat_to_world1_logits() {
        let h = 64;
        let x: Vec<f32> = (0..h).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut be1 = backend(&cfg(1, 1), 0).unwrap();
        let mut full = vec![0.0f32; 256];
        be1.lm_head(&x, &mut full).unwrap();
        let world = 4;
        let v_l = 256 / world;
        for rank in 0..world {
            let mut be = backend(&cfg(world, 1), rank).unwrap();
            let mut local = vec![0.0f32; v_l];
            be.lm_head(&x, &mut local).unwrap();
            for j in 0..v_l {
                assert_eq!(local[j].to_bits(),
                           full[rank * v_l + j].to_bits());
            }
        }
    }

    #[test]
    fn reset_restores_fresh_kv_state() {
        let mut be = backend(&cfg(1, 1), 0).unwrap();
        let h = 64;
        let tokens = [5i32; 4];
        let ctx = StepCtx::Prefill { lane: 0, bucket: 4, length: 4, offset: 0 };
        let mut x = vec![0.0f32; 4 * h];
        be.embed(&ctx, &tokens, &mut x).unwrap();
        let mut p1 = vec![0.0f32; 4 * h];
        be.layer_partial(&ctx, 0, 0, &x, &mut p1).unwrap();
        be.reset().unwrap();
        let mut p2 = vec![0.0f32; 4 * h];
        be.layer_partial(&ctx, 0, 0, &x, &mut p2).unwrap();
        assert_eq!(p1, p2, "reset must reproduce the first run exactly");
    }

    #[test]
    fn npydir_weights_rejected() {
        let mut c = cfg(1, 1);
        c.weights = WeightSource::NpyDir { dir: "/tmp/x".into() };
        assert!(backend(&c, 0).is_err());
    }

    #[test]
    fn unknown_model_rejected() {
        let mut c = cfg(1, 1);
        c.model = "qwen72b".into();
        assert!(backend(&c, 0).is_err());
    }

    /// Run a prefill, two decode steps and an lm_head through one
    /// backend, returning every partial and the logits — the bit
    /// pattern the kernel/threading comparisons pin.
    fn forward_fingerprint(c: &EngineConfig, force_pool: bool)
                           -> Vec<Vec<f32>> {
        let preset = ModelPreset::builtin(&c.model).unwrap();
        let mut be = ReferenceBackend::new(c, 0, &preset).unwrap();
        if force_pool {
            be.set_par_threshold(0);
        }
        let h = preset.hidden;
        let segs = c.variant.syncs_per_layer();
        let mut out = Vec::new();

        let tokens = [3i32, 9, 27, 81];
        let ctx = StepCtx::Prefill { lane: 0, bucket: 8, length: 4, offset: 0 };
        let mut x = vec![0.0f32; 8 * h];
        be.embed(&ctx, &tokens, &mut x).unwrap();
        for li in 0..preset.n_layers {
            for seg in 0..segs {
                let mut p = vec![0.0f32; 8 * h];
                be.layer_partial(&ctx, li, seg, &x, &mut p).unwrap();
                for (xi, pi) in x.iter_mut().zip(&p) {
                    *xi += *pi;
                }
                out.push(p);
            }
        }
        for step in 0..2i32 {
            let positions = [4 + step];
            let ctx = StepCtx::Decode { positions: &positions };
            let mut xd = vec![0.0f32; h];
            be.embed(&ctx, &[7 + step], &mut xd).unwrap();
            for li in 0..preset.n_layers {
                for seg in 0..segs {
                    let mut p = vec![0.0f32; h];
                    be.layer_partial(&ctx, li, seg, &xd, &mut p).unwrap();
                    for (xi, pi) in xd.iter_mut().zip(&p) {
                        *xi += *pi;
                    }
                    out.push(p);
                }
            }
            let mut logits = vec![0.0f32; preset.vocab];
            be.lm_head(&xd, &mut logits).unwrap();
            out.push(logits);
        }
        out
    }

    fn assert_bits_eq(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: buffer counts");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.len(), y.len(), "{what}: buffer {i} len");
            for (j, (xa, yb)) in x.iter().zip(y).enumerate() {
                assert_eq!(xa.to_bits(), yb.to_bits(),
                           "{what}: buffer {i} elem {j}");
            }
        }
    }

    #[test]
    fn blocked_kernel_bit_identical_to_scalar() {
        for variant in [Variant::Parallel, Variant::Serial] {
            let mut base = cfg(2, 1);
            base.variant = variant;
            base.kernel = GemmKernel::Scalar;
            let golden = forward_fingerprint(&base, false);
            let mut blocked = base.clone();
            blocked.kernel = GemmKernel::Blocked;
            blocked.threads = 1;
            let got = forward_fingerprint(&blocked, false);
            assert_bits_eq(&golden, &got,
                           &format!("blocked vs scalar ({variant})"));
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let mut base = cfg(1, 1);
        base.kernel = GemmKernel::Scalar;
        let golden = forward_fingerprint(&base, false);
        for threads in [1usize, 2, 4] {
            let mut c = base.clone();
            c.kernel = GemmKernel::Blocked;
            c.threads = threads;
            // par_threshold 0 forces every phase through the pool
            let got = forward_fingerprint(&c, true);
            assert_bits_eq(&golden, &got,
                           &format!("threads={threads} vs scalar"));
        }
    }

    #[test]
    fn scalar_kernel_forces_one_thread() {
        let mut c = cfg(1, 1);
        c.kernel = GemmKernel::Scalar;
        c.threads = 8;
        let preset = ModelPreset::builtin(&c.model).unwrap();
        let be = ReferenceBackend::new(&c, 0, &preset).unwrap();
        assert_eq!(be.threads(), 1);
    }

    fn int8_cfg(world: usize, batch: usize) -> EngineConfig {
        let mut c = cfg(world, batch);
        c.weight_dtype = Dtype::Int8;
        c.kv_dtype = Dtype::Int8;
        c
    }

    /// At int8 the same invariant as f32 must hold: blocking, tiling
    /// and threading are scheduling-only — every partial and logit is
    /// bit-identical to the scalar int8 path.
    #[test]
    fn int8_blocked_kernel_bit_identical_to_scalar() {
        for variant in [Variant::Parallel, Variant::Serial] {
            let mut base = int8_cfg(2, 1);
            base.variant = variant;
            base.kernel = GemmKernel::Scalar;
            let golden = forward_fingerprint(&base, false);
            for threads in [1usize, 3] {
                let mut blocked = base.clone();
                blocked.kernel = GemmKernel::Blocked;
                blocked.threads = threads;
                let got = forward_fingerprint(&blocked, threads > 1);
                assert_bits_eq(
                    &golden,
                    &got,
                    &format!("int8 blocked x{threads} vs scalar \
                              ({variant})"),
                );
            }
        }
    }

    /// Cross-world exactness at int8: the dequantized weights are
    /// sliced from one full-tensor quantization grid, so rank partials
    /// must still sum bit-identically at every world size.
    #[test]
    fn int8_decode_partials_sum_identically_across_worlds() {
        let h = 64;
        let x: Vec<f32> =
            (0..h).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.05).collect();
        let mut sums: Vec<Vec<f32>> = Vec::new();
        for world in [1usize, 2, 4] {
            let mut total = vec![0.0f32; h];
            for rank in 0..world {
                let c = int8_cfg(world, 1);
                let preset = ModelPreset::builtin(&c.model).unwrap();
                let mut be =
                    ReferenceBackend::new(&c, rank, &preset).unwrap();
                let mut part = vec![0.0f32; h];
                let ctx = StepCtx::Decode { positions: &[0] };
                be.layer_partial(&ctx, 0, 0, &x, &mut part).unwrap();
                for (t, p) in total.iter_mut().zip(&part) {
                    *t += *p;
                }
            }
            sums.push(total);
        }
        for w in 1..sums.len() {
            for j in 0..h {
                assert_eq!(
                    sums[0][j].to_bits(),
                    sums[w][j].to_bits(),
                    "int8 col {j} differs between world 1 and {}",
                    [1, 2, 4][w]
                );
            }
        }
    }

    /// int8 must actually change the resident footprint — and the
    /// logits, or the quantized path silently fell back to f32.  On
    /// `tiny` (head_dim 8) the KV ratio is (8 + 4)/(4·8) = 0.375 (the
    /// per-row scale is proportionally large), so the bound is <½;
    /// wide-head presets reach ~0.26.
    #[test]
    fn int8_shrinks_memory_and_perturbs_logits() {
        let preset = ModelPreset::builtin("tiny").unwrap();
        let f = ReferenceBackend::new(&cfg(1, 1), 0, &preset).unwrap();
        let q = ReferenceBackend::new(&int8_cfg(1, 1), 0, &preset)
            .unwrap();
        let (fm, qm) = (f.mem_usage(), q.mem_usage());
        assert!(fm.weight_bytes > 0 && fm.kv_bytes > 0);
        // the replicated f32 embedding dominates tiny's weights, so
        // only the matmul portion shrinks — still strictly smaller
        assert!(qm.weight_bytes < fm.weight_bytes,
                "int8 weights {} !< f32 {}", qm.weight_bytes,
                fm.weight_bytes);
        assert!(qm.kv_bytes * 2 < fm.kv_bytes,
                "int8 kv {} not well under half of f32 {}", qm.kv_bytes,
                fm.kv_bytes);

        let f32_fp = forward_fingerprint(&cfg(1, 1), false);
        let int8_fp = forward_fingerprint(&int8_cfg(1, 1), false);
        let identical = f32_fp
            .iter()
            .zip(&int8_fp)
            .all(|(a, b)| {
                a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
            });
        assert!(!identical,
                "int8 logits bit-identical to f32 — quantized path \
                 not engaged");
    }

    /// The f32 SIMD tiers must reproduce the scalar chains bit-for-bit
    /// at both dtypes (DESIGN.md §14).  Skipped silently per-tier on
    /// hosts without the instructions, and entirely when a force-ISA
    /// env override is active (it would pin every config to one tier
    /// and make the cross-tier comparison vacuous).
    #[test]
    fn simd_tiers_reproduce_scalar_bits() {
        if std::env::var_os(simd::FORCE_ISA_ENV).is_some() {
            return;
        }
        for int8 in [false, true] {
            let mut base = if int8 { int8_cfg(1, 1) } else { cfg(1, 1) };
            base.isa = crate::config::IsaKind::Scalar;
            let golden = forward_fingerprint(&base, false);
            for (kind, isa) in
                [(crate::config::IsaKind::Avx2, Isa::Avx2),
                 (crate::config::IsaKind::Avx512, Isa::Avx512)]
            {
                if !simd::available(isa) {
                    continue;
                }
                let mut c = base.clone();
                c.isa = kind;
                let got = forward_fingerprint(&c, false);
                assert_bits_eq(&golden, &got,
                               &format!("{isa} vs scalar (int8={int8})"));
            }
        }
    }

    /// The vnni tier is its own (deterministic) numeric scheme: two
    /// runs agree bit-for-bit, and the logits differ from the
    /// dequantized-scalar chain — proof the integer path is engaged.
    #[test]
    fn vnni_tier_is_deterministic_and_engaged() {
        if std::env::var_os(simd::FORCE_ISA_ENV).is_some() {
            return;
        }
        let mut c = int8_cfg(1, 1);
        c.isa = crate::config::IsaKind::Vnni;
        let a = forward_fingerprint(&c, false);
        let b = forward_fingerprint(&c, false);
        assert_bits_eq(&a, &b, "vnni reruns");
        let mut s = int8_cfg(1, 1);
        s.isa = crate::config::IsaKind::Scalar;
        let scalar = forward_fingerprint(&s, false);
        let identical = a.iter().zip(&scalar).all(|(x, y)| {
            x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        });
        assert!(!identical,
                "vnni fingerprint bit-identical to the dequant scalar \
                 chain — the W8A8 scheme is not engaged");
    }

    /// Mixed dtypes are legal: each knob works independently.
    #[test]
    fn mixed_dtypes_run_and_reset() {
        for (wd, kd) in [(Dtype::Int8, Dtype::F32),
                         (Dtype::F32, Dtype::Int8)] {
            let mut c = cfg(1, 1);
            c.weight_dtype = wd;
            c.kv_dtype = kd;
            let preset = ModelPreset::builtin(&c.model).unwrap();
            let mut be =
                ReferenceBackend::new(&c, 0, &preset).unwrap();
            let h = preset.hidden;
            let ctx = StepCtx::Prefill { lane: 0, bucket: 4, length: 4, offset: 0 };
            let mut x = vec![0.0f32; 4 * h];
            be.embed(&ctx, &[1, 2, 3, 4], &mut x).unwrap();
            let mut p1 = vec![0.0f32; 4 * h];
            be.layer_partial(&ctx, 0, 0, &x, &mut p1).unwrap();
            be.reset().unwrap();
            let mut p2 = vec![0.0f32; 4 * h];
            be.layer_partial(&ctx, 0, 0, &x, &mut p2).unwrap();
            assert_eq!(p1, p2,
                       "reset must reproduce the first run at \
                        weight={wd:?} kv={kd:?}");
        }
    }

    /// Push `tokens` through a prefill of `lane` starting at absolute
    /// position `offset`, accumulating partials into the residual
    /// stream exactly as the world-1 engine would.
    fn prefill_at(be: &mut ReferenceBackend, lane: usize, tokens: &[i32],
                  offset: usize) {
        let h = be.preset.hidden;
        let n_layers = be.preset.n_layers;
        let segs = be.variant.syncs_per_layer();
        let n = tokens.len();
        let ctx = StepCtx::Prefill { lane, bucket: n, length: n, offset };
        let mut x = vec![0.0f32; n * h];
        be.embed(&ctx, tokens, &mut x).unwrap();
        for li in 0..n_layers {
            for seg in 0..segs {
                let mut p = vec![0.0f32; n * h];
                be.layer_partial(&ctx, li, seg, &x, &mut p).unwrap();
                for (xi, pi) in x.iter_mut().zip(&p) {
                    *xi += *pi;
                }
            }
        }
    }

    /// One batched decode step at world 1, returning the full logits.
    fn decode_logits(be: &mut ReferenceBackend, tokens: &[i32],
                     positions: &[i32]) -> Vec<f32> {
        let h = be.preset.hidden;
        let n_layers = be.preset.n_layers;
        let vocab_l = be.vocab_l;
        let segs = be.variant.syncs_per_layer();
        let b = tokens.len();
        let ctx = StepCtx::Decode { positions };
        let mut x = vec![0.0f32; b * h];
        be.embed(&ctx, tokens, &mut x).unwrap();
        for li in 0..n_layers {
            for seg in 0..segs {
                let mut p = vec![0.0f32; b * h];
                be.layer_partial(&ctx, li, seg, &x, &mut p).unwrap();
                for (xi, pi) in x.iter_mut().zip(&p) {
                    *xi += *pi;
                }
            }
        }
        let mut logits = vec![0.0f32; b * vocab_l];
        be.lm_head(&x, &mut logits).unwrap();
        logits
    }

    /// DESIGN.md §13's bit-invariance: a lane that reads its prompt
    /// prefix from a shared segment (plus the COW tail rows) must
    /// produce logits bit-identical to a lane that prefilled the whole
    /// prompt privately — at both KV dtypes, on both kernels.
    #[test]
    fn shared_prefix_reads_are_bit_identical_to_private_prefill() {
        for kv in [Dtype::F32, Dtype::Int8] {
            for kernel in [GemmKernel::Scalar, GemmKernel::Blocked] {
                let mut c = cfg(1, 2);
                c.kv_dtype = kv;
                c.kernel = kernel;
                let prompt: Vec<i32> =
                    (0..20).map(|i| (i * 7 + 3) % 251).collect();
                // baseline: both lanes prefill the prompt privately
                let mut a = backend(&c, 0).unwrap();
                prefill_at(&mut a, 0, &prompt, 0);
                prefill_at(&mut a, 1, &prompt, 0);
                let la = decode_logits(&mut a, &[11, 11], &[20, 20]);
                let la2 = decode_logits(&mut a, &[23, 23], &[21, 21]);
                // shared: lane 1 attaches to lane 0's published page
                // (shared_len 16, COW rows 16..19) and only prefills
                // its final prompt token
                let mut b = backend(&c, 0).unwrap();
                prefill_at(&mut b, 0, &prompt, 0);
                b.publish_prefix(7, 0, 19).unwrap();
                b.attach_prefix(1, 7, 16, 3).unwrap();
                prefill_at(&mut b, 1, &prompt[19..], 19);
                let lb = decode_logits(&mut b, &[11, 11], &[20, 20]);
                let lb2 = decode_logits(&mut b, &[23, 23], &[21, 21]);
                for (i, (x, y)) in la.iter().zip(&lb).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "logit {i} (kv={kv:?} {kernel:?})");
                }
                for (i, (x, y)) in la2.iter().zip(&lb2).enumerate() {
                    assert_eq!(
                        x.to_bits(), y.to_bits(),
                        "step-2 logit {i} (kv={kv:?} {kernel:?})");
                }
            }
        }
    }

    #[test]
    fn prefix_lifecycle_is_guarded() {
        let mut be = backend(&cfg(1, 2), 0).unwrap();
        let prompt: Vec<i32> = (0..16).collect();
        prefill_at(&mut be, 0, &prompt, 0);
        be.publish_prefix(1, 0, 16).unwrap();
        assert!(be.publish_prefix(1, 0, 16).is_err(), "dup seg id");
        assert!(be.attach_prefix(1, 9, 16, 0).is_err(), "unknown seg");
        assert!(be.attach_prefix(1, 1, 17, 0).is_err(),
                "shared_len past segment");
        assert!(be.attach_prefix(1, 1, 16, 1).is_err(),
                "copy range past segment");
        be.attach_prefix(1, 1, 16, 0).unwrap();
        assert!(be.drop_prefix(1).is_err(), "still attached");
        be.detach_prefix(1).unwrap();
        be.detach_prefix(1).unwrap(); // idempotent
        be.drop_prefix(1).unwrap();
        assert!(be.drop_prefix(1).is_err(), "already dropped");
        // reset clears segments and attachments alike
        be.publish_prefix(2, 0, 16).unwrap();
        be.reset().unwrap();
        assert!(be.attach_prefix(0, 2, 16, 0).is_err(),
                "reset must drop the segment");
        be.publish_prefix(2, 0, 16).unwrap(); // id reusable after reset
    }

    /// One speculative verify step at world 1: run `tokens` through
    /// embed + all layers under `StepCtx::Verify`, then chunk the R
    /// rows through the fixed-batch `lm_head` (zero-padded, exactly as
    /// the rank worker does) and return the R per-row logit vectors.
    fn verify_logits(be: &mut ReferenceBackend, lanes: &[u32],
                     positions: &[i32], tokens: &[i32]) -> Vec<Vec<f32>> {
        let h = be.preset.hidden;
        let n_layers = be.preset.n_layers;
        let vocab_l = be.vocab_l;
        let b = be.batch;
        let segs = be.variant.syncs_per_layer();
        let r_rows = lanes.len();
        let ctx = StepCtx::Verify { lanes, positions };
        let mut x = vec![0.0f32; r_rows * h];
        be.embed(&ctx, tokens, &mut x).unwrap();
        for li in 0..n_layers {
            for seg in 0..segs {
                let mut p = vec![0.0f32; r_rows * h];
                be.layer_partial(&ctx, li, seg, &x, &mut p).unwrap();
                for (xi, pi) in x.iter_mut().zip(&p) {
                    *xi += *pi;
                }
            }
        }
        let mut out = Vec::with_capacity(r_rows);
        for chunk in x.chunks(b * h) {
            let rows = chunk.len() / h;
            let mut head_in = vec![0.0f32; b * h];
            head_in[..chunk.len()].copy_from_slice(chunk);
            let mut logits = vec![0.0f32; b * vocab_l];
            be.lm_head(&head_in, &mut logits).unwrap();
            for r in 0..rows {
                out.push(logits[r * vocab_l..(r + 1) * vocab_l].to_vec());
            }
        }
        out
    }

    /// DESIGN.md §15's core claim: a multi-row verify step computes,
    /// per row, exactly the bits one-at-a-time batched decode computes
    /// — including rows that attend over KV appended by *earlier rows
    /// of the same verify step* — at both KV dtypes, on both kernels,
    /// with several rows per lane and multiple speculating lanes.
    #[test]
    fn verify_rows_bit_identical_to_sequential_decode() {
        for kv in [Dtype::F32, Dtype::Int8] {
            for kernel in [GemmKernel::Scalar, GemmKernel::Blocked] {
                let mut c = cfg(1, 4);
                c.kv_dtype = kv;
                c.kernel = kernel;
                let pa: Vec<i32> = (0..8).map(|i| (i * 5 + 2) % 251).collect();
                let pc: Vec<i32> = (0..5).map(|i| (i * 11 + 1) % 251).collect();
                let (a_toks, c_toks) = ([21i32, 22, 23], [31i32, 32, 33]);

                // baseline: three batched decode steps (lanes 1/3 free,
                // parked at position 0 as the engine does)
                let mut a = backend(&c, 0).unwrap();
                prefill_at(&mut a, 0, &pa, 0);
                prefill_at(&mut a, 2, &pc, 0);
                let mut base_logits = Vec::new();
                for i in 0..3 {
                    let l = decode_logits(
                        &mut a, &[a_toks[i], 0, c_toks[i], 0],
                        &[8 + i as i32, 0, 5 + i as i32, 0]);
                    let v = l.len() / 4;
                    base_logits.push((l[..v].to_vec(),
                                      l[2 * v..3 * v].to_vec()));
                }

                // speculative: ONE verify step carrying all six rows
                let mut b = backend(&c, 0).unwrap();
                prefill_at(&mut b, 0, &pa, 0);
                prefill_at(&mut b, 2, &pc, 0);
                let got = verify_logits(
                    &mut b, &[0, 0, 0, 2, 2, 2], &[8, 9, 10, 5, 6, 7],
                    &[a_toks[0], a_toks[1], a_toks[2],
                      c_toks[0], c_toks[1], c_toks[2]]);

                for i in 0..3 {
                    let (ref la, ref lc) = base_logits[i];
                    for (j, (x, y)) in la.iter().zip(&got[i]).enumerate() {
                        assert_eq!(x.to_bits(), y.to_bits(),
                                   "lane0 step {i} logit {j} \
                                    (kv={kv:?} {kernel:?})");
                    }
                    for (j, (x, y)) in
                        lc.iter().zip(&got[3 + i]).enumerate()
                    {
                        assert_eq!(x.to_bits(), y.to_bits(),
                                   "lane2 step {i} logit {j} \
                                    (kv={kv:?} {kernel:?})");
                    }
                }
            }
        }
    }

    /// Rollback invariance: speculate, truncate the rejected rows,
    /// and the lane must continue decoding bit-identically to a lane
    /// that never speculated past the accepted prefix.
    #[test]
    fn truncate_lane_restores_never_speculated_state() {
        for kv in [Dtype::F32, Dtype::Int8] {
            for kernel in [GemmKernel::Scalar, GemmKernel::Blocked] {
                let mut c = cfg(1, 1);
                c.kv_dtype = kv;
                c.kernel = kernel;
                let prompt: Vec<i32> =
                    (0..8).map(|i| (i * 7 + 3) % 251).collect();

                // speculated: verify 3 rows, then reject rows 9 and 10
                let mut s = backend(&c, 0).unwrap();
                prefill_at(&mut s, 0, &prompt, 0);
                verify_logits(&mut s, &[0, 0, 0], &[8, 9, 10],
                              &[40, 91, 17]);
                s.truncate_lane(0, 9).unwrap();

                // clean: only ever appended the accepted row
                let mut n = backend(&c, 0).unwrap();
                prefill_at(&mut n, 0, &prompt, 0);
                verify_logits(&mut n, &[0], &[8], &[40]);

                // both continue with the same tokens: bit-identical
                for (step, tok) in [(9, 55i32), (10, 66), (11, 77)] {
                    let ls = decode_logits(&mut s, &[tok], &[step]);
                    let ln = decode_logits(&mut n, &[tok], &[step]);
                    for (j, (x, y)) in ls.iter().zip(&ln).enumerate() {
                        assert_eq!(x.to_bits(), y.to_bits(),
                                   "post-rollback step {step} logit {j} \
                                    (kv={kv:?} {kernel:?})");
                    }
                }
            }
        }
    }

    #[test]
    fn verify_and_truncate_are_guarded() {
        let mut be = backend(&cfg(1, 2), 0).unwrap();
        let prompt: Vec<i32> = (0..16).collect();
        prefill_at(&mut be, 0, &prompt, 0);
        let h = 64;
        let x = vec![0.0f32; 4 * h];
        let mut p = vec![0.0f32; 4 * h];
        let bad = |lanes: &[u32], positions: &[i32],
                   be: &mut ReferenceBackend,
                   x: &[f32], p: &mut [f32]| {
            let ctx = StepCtx::Verify { lanes, positions };
            be.layer_partial(&ctx, 0, 0, x, p)
        };
        assert!(bad(&[], &[], &mut be, &x, &mut p).is_err(),
                "empty verify");
        assert!(bad(&[0, 0], &[16], &mut be, &x, &mut p).is_err(),
                "length mismatch");
        assert!(bad(&[5], &[16], &mut be, &x, &mut p).is_err(),
                "lane out of range");
        assert!(bad(&[0], &[-1], &mut be, &x, &mut p).is_err(),
                "negative position");
        assert!(bad(&[0], &[64], &mut be, &x, &mut p).is_err(),
                "position past max_seq");
        assert!(bad(&[0, 0], &[17, 16], &mut be, &x, &mut p).is_err(),
                "descending positions within a lane");
        assert!(bad(&[0, 0], &[16, 16], &mut be, &x, &mut p).is_err(),
                "duplicate position within a lane");
        // ascending per lane, interleaved across lanes: fine
        assert!(bad(&[0, 1, 0, 1], &[16, 3, 17, 4], &mut be, &x, &mut p)
                    .is_ok());

        assert!(be.truncate_lane(5, 4).is_err(), "lane out of range");
        assert!(be.truncate_lane(0, 0).is_err(), "zero length");
        assert!(be.truncate_lane(0, 65).is_err(), "past max_seq");
        // attached lanes refuse to truncate into the shared prefix
        be.publish_prefix(9, 0, 16).unwrap();
        be.attach_prefix(1, 9, 16, 0).unwrap();
        assert!(be.truncate_lane(1, 15).is_err(),
                "must not truncate into the attached shared prefix");
        be.truncate_lane(1, 16).unwrap(); // at the boundary: ok
        be.detach_prefix(1).unwrap();
        be.truncate_lane(1, 1).unwrap(); // detached: floor gone
    }

    #[test]
    fn mem_usage_counts_shared_segments() {
        for kv in [Dtype::F32, Dtype::Int8] {
            let mut c = cfg(1, 1);
            c.kv_dtype = kv;
            let mut be = backend(&c, 0).unwrap();
            let base = be.mem_usage().kv_bytes;
            let prompt: Vec<i32> = (0..16).collect();
            prefill_at(&mut be, 0, &prompt, 0);
            be.publish_prefix(3, 0, 16).unwrap();
            let with_seg = be.mem_usage().kv_bytes;
            assert!(with_seg > base,
                    "segment bytes not counted ({with_seg} !> {base})");
            be.drop_prefix(3).unwrap();
            assert_eq!(be.mem_usage().kv_bytes, base);
        }
    }
}
