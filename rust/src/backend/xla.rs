//! The PJRT execution backend: AOT-compiled HLO segments from
//! `artifacts/`, executed through the `xla` crate (feature `xla`).
//!
//! This is the perf-bearing path the paper's measurements come from.
//! One instance per rank thread: PJRT objects are `Rc`-based, so the
//! client, executables, weight shards and KV caches all stay
//! thread-local — exactly the paper's one-process-per-socket topology.
//!
//! Activations cross the host boundary at every segment edge (the
//! collective boundaries); weights and KV caches are device-resident
//! and chained through the segments (`DESIGN.md §3`).

use std::collections::HashMap;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use crate::config::{EngineConfig, Manifest, ModelPreset, Variant};
use crate::model::{load_rank_weights, RankWeights};
use crate::runtime::RankRuntime;

use super::{ExecBackend, StepCtx};

/// Segment-id bundle for one (variant, bucket) family.
struct SegIds {
    embed_decode: String,
    lm_head: String,
    /// decode-step layer segments in execution order
    layer_decode: Vec<(String, Vec<String>)>, // (id, weight_args)
    /// prefill segments per bucket size
    embed_prefill: HashMap<usize, String>,
    layer_prefill: HashMap<usize, Vec<(String, Vec<String>)>>,
}

/// One rank's PJRT-backed compute provider: compiled HLO segments,
/// device-resident weight shards and KV caches (f32 only — quantized
/// dtypes are a reference-backend feature, DESIGN.md §11).
pub struct XlaBackend {
    batch: usize,
    hidden: usize,
    vocab_local: usize,
    preset: ModelPreset,
    world: usize,
    rt: RankRuntime,
    weights: RankWeights,
    segs: SegIds,
    /// per-layer device-resident (k_cache, v_cache)
    caches: Vec<(PjRtBuffer, PjRtBuffer)>,
}

impl XlaBackend {
    /// Compile this rank's segments and materialize its weight shards
    /// on the PJRT device.  Must run on the thread that will use it.
    /// `manifest` is the already-loaded artifact manifest (see
    /// `EngineConfig::resolve_model`).
    pub fn new(cfg: &EngineConfig, rank: usize, manifest: &Manifest)
               -> Result<Self> {
        let preset = manifest.preset(&cfg.model)?.clone();
        let mut rt = RankRuntime::new()?;

        let (world, batch) = (cfg.world, cfg.batch);
        let layer_kinds: Vec<&str> = match cfg.variant {
            Variant::Parallel => vec!["parallel_block"],
            Variant::Serial => vec!["serial_attn", "serial_ffn"],
        };

        let mut to_compile = Vec::new();
        let segs = {
            let mut find = |kind: &str, mode: &str, seq: usize| -> Result<_> {
                let seg = manifest
                    .find(&cfg.model, world, batch, kind, mode, seq)?
                    .clone();
                to_compile.push(seg.clone());
                Ok(seg)
            };
            let embed_decode = find("embed", "decode", 1)?.id;
            let lm_head = find("lm_head", "decode", 1)?.id;
            let mut layer_decode = Vec::new();
            for kind in &layer_kinds {
                let seg = find(kind, "decode", 1)?;
                layer_decode.push((seg.id, seg.weight_args));
            }
            let buckets = manifest.prefill_buckets(&cfg.model, world, batch);
            let mut embed_prefill = HashMap::new();
            let mut layer_prefill = HashMap::new();
            for &s in &buckets {
                embed_prefill.insert(s, find("embed", "prefill", s)?.id);
                let mut layers = Vec::new();
                for kind in &layer_kinds {
                    let seg = find(kind, "prefill", s)?;
                    layers.push((seg.id, seg.weight_args));
                }
                layer_prefill.insert(s, layers);
            }
            SegIds {
                embed_decode,
                lm_head,
                layer_decode,
                embed_prefill,
                layer_prefill,
            }
        };
        for seg in &to_compile {
            rt.compile_segment(manifest, seg)?;
        }

        let weights = load_rank_weights(
            &rt, manifest, &cfg.model, world, rank, batch, &cfg.weights)?;
        let caches = Self::fresh_caches(&rt, &preset, world, batch)?;

        Ok(XlaBackend {
            batch,
            hidden: preset.hidden,
            vocab_local: preset.vocab_local(world),
            world,
            rt,
            weights,
            segs,
            caches,
            preset,
        })
    }

    fn fresh_caches(rt: &RankRuntime, preset: &ModelPreset, world: usize,
                    batch: usize) -> Result<Vec<(PjRtBuffer, PjRtBuffer)>> {
        let dims = [
            batch,
            preset.kv_heads_local(world),
            preset.max_seq,
            preset.head_dim,
        ];
        (0..preset.n_layers)
            .map(|_| Ok((rt.zeros_f32(&dims)?, rt.zeros_f32(&dims)?)))
            .collect()
    }
}

impl ExecBackend for XlaBackend {
    fn embed(&mut self, ctx: &StepCtx, tokens: &[i32], x: &mut [f32])
             -> Result<()> {
        let (seg_id, dims) = match ctx {
            StepCtx::Prefill { bucket, .. } => (
                self.segs
                    .embed_prefill
                    .get(bucket)
                    .with_context(|| {
                        format!("no prefill embed segment for bucket {bucket}")
                    })?
                    .as_str(),
                [1usize, *bucket],
            ),
            StepCtx::Decode { .. } => {
                (self.segs.embed_decode.as_str(), [self.batch, 1])
            }
        };
        let n = dims[0] * dims[1] * self.hidden;
        anyhow::ensure!(tokens.len() == dims[0] * dims[1] && x.len() >= n,
                        "embed buffer shapes");
        let tok_buf = self.rt.upload_i32(tokens, &dims)?;
        let outs = self
            .rt
            .execute(seg_id, &[&tok_buf, &self.weights.embedding])?;
        self.rt.download_f32_into(&outs[0], &mut x[..n])?;
        Ok(())
    }

    fn layer_partial(&mut self, ctx: &StepCtx, li: usize, seg: usize,
                     x: &[f32], partial: &mut [f32]) -> Result<()> {
        let h = self.hidden;
        // shape + segment lookup per round kind
        let (entry, dims, ctrl): (_, [usize; 3], Vec<i32>) = match ctx {
            StepCtx::Prefill { lane, bucket, length, offset } => {
                // the AOT prefill segments are lowered for offset-0
                // whole-prompt frames only; EngineConfig::validate
                // rejects prefill_chunk > 0 on this backend, so a
                // non-zero offset here is an engine bug
                anyhow::ensure!(*offset == 0,
                                "chunked prefill (offset {offset}) is \
                                 not supported on the xla backend");
                let layers =
                    self.segs.layer_prefill.get(bucket).with_context(|| {
                        format!("no prefill segments for bucket {bucket}")
                    })?;
                (&layers[seg], [1, *bucket, h],
                 vec![*lane as i32, *length as i32])
            }
            StepCtx::Decode { positions } => {
                (&self.segs.layer_decode[seg], [self.batch, 1, h],
                 positions.to_vec())
            }
        };
        let n = dims[0] * dims[1] * h;
        anyhow::ensure!(x.len() >= n && partial.len() >= n,
                        "activation buffer shapes");
        let (seg_id, wargs) = entry;
        let is_attn = wargs.iter().any(|w| w == "wq");

        let x_dev = self.rt.upload_f32(&x[..n], &dims)?;
        // control inputs of the attention segments: (lane, length) for
        // prefill, per-lane positions for decode
        let ctrl_bufs: Vec<PjRtBuffer> = if is_attn {
            match ctx {
                StepCtx::Prefill { .. } => vec![
                    self.rt.upload_i32(&ctrl[..1], &[1])?,
                    self.rt.upload_i32(&ctrl[1..], &[1])?,
                ],
                StepCtx::Decode { .. } => {
                    vec![self.rt.upload_i32(&ctrl, &[self.batch])?]
                }
            }
        } else {
            Vec::new()
        };

        let wbufs = self.weights.layer_args(li, wargs)?;
        let mut args: Vec<&PjRtBuffer> = vec![&x_dev];
        let (kc, vc) = &self.caches[li];
        if is_attn {
            args.extend([kc, vc]);
            args.extend(ctrl_bufs.iter());
        }
        args.extend(wbufs);
        let mut outs = self.rt.execute(seg_id, &args)?;
        drop(args);
        if is_attn {
            let vc_new = outs.pop().context("missing v_cache output")?;
            let kc_new = outs.pop().context("missing k_cache output")?;
            self.caches[li] = (kc_new, vc_new);
        }
        let y_buf = outs.pop().context("missing partial output")?;
        self.rt.download_f32_into(&y_buf, &mut partial[..n])?;
        Ok(())
    }

    fn lm_head(&mut self, x: &[f32], logits: &mut [f32]) -> Result<()> {
        let (b, h) = (self.batch, self.hidden);
        let n_logits = b * self.vocab_local;
        anyhow::ensure!(x.len() >= b * h && logits.len() >= n_logits,
                        "lm_head buffer shapes");
        let x_dev = self.rt.upload_f32(&x[..b * h], &[b, 1, h])?;
        let outs = self.rt.execute(
            &self.segs.lm_head,
            &[&x_dev, &self.weights.final_g, &self.weights.lm_head],
        )?;
        self.rt.download_f32_into(&outs[0], &mut logits[..n_logits])?;
        Ok(())
    }

    fn reset(&mut self) -> Result<()> {
        self.caches =
            Self::fresh_caches(&self.rt, &self.preset, self.world,
                               self.batch)?;
        Ok(())
    }
}
