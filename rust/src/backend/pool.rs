//! Per-rank worker thread pool for data-parallel kernel loops.
//!
//! The reference backend's blocked GEMMs split their *output* index
//! space into fixed units (column blocks, rows) and fan the units out
//! over this pool (DESIGN.md §10).  Determinism contract: a unit's
//! arithmetic never depends on which thread runs it — every float op
//! sequence is a pure function of the unit index — so any thread
//! count (including 1, the scalar path) produces bit-identical
//! results.  The pool only decides *who* computes a unit, never *how*.
//!
//! Workers are parked on a condvar between dispatches, so a dispatch
//! costs roughly one mutex round-trip plus a wakeup (~10 µs), cheap
//! against the per-layer GEMM work it amortizes.  Small jobs should
//! bypass the pool entirely via [`WorkerPool::run_if_worth`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use anyhow::{Context, Result};

/// Resolve a configured thread count (`EngineConfig::threads`):
/// `0` = auto — available cores divided by the tensor-parallel world
/// (every rank runs its own pool, so a world of R ranks on C cores
/// gets C/R threads each), clamped to `[1, 64]`.
pub fn auto_threads(cfg_threads: usize, world: usize) -> usize {
    let t = if cfg_threads == 0 {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            / world.max(1)
    } else {
        cfg_threads
    };
    t.clamp(1, 64)
}

/// Erased pointer to the caller's task closure.  Only ever dereferenced
/// between the epoch hand-off and the completion barrier in
/// [`WorkerPool::run`], which keeps the caller's borrow alive for the
/// whole window.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is Sync (shared calls are safe) and `run` barriers
// before the underlying borrow ends.
unsafe impl Send for TaskPtr {}

struct PoolState {
    /// bumped once per dispatch; workers use it to detect new work
    epoch: u64,
    task: Option<TaskPtr>,
    n_units: usize,
    /// workers still executing the current epoch
    running: usize,
    /// a worker's task panicked this epoch
    panicked: bool,
    stop: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    go: Condvar,
    done: Condvar,
}

/// A fixed-size pool of parked worker threads executing unit-indexed
/// tasks; see the module docs for the determinism contract.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool that executes tasks on `threads` threads total:
    /// `threads - 1` parked workers plus the calling thread.  `threads
    /// <= 1` spawns nothing and [`run`](Self::run) executes inline.
    pub fn new(threads: usize) -> Result<WorkerPool> {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                task: None,
                n_units: 0,
                running: 0,
                panicked: false,
                stop: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let n_extra = threads.max(1) - 1;
        let stride = n_extra + 1;
        let mut workers = Vec::with_capacity(n_extra);
        for wid in 1..=n_extra {
            let sh = shared.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("gemm{wid}"))
                    .spawn(move || worker_loop(&sh, wid, stride))
                    .context("spawning gemm pool worker")?,
            );
        }
        Ok(WorkerPool { shared, workers })
    }

    /// Total threads participating in a dispatch (workers + caller).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Execute `task(u)` for every `u` in `0..n_units`, split across
    /// the pool with a fixed stride partition (thread `t` of `T` runs
    /// units `t, t+T, t+2T, …`).  Blocks until every unit completes.
    ///
    /// Each unit runs exactly once, on exactly one thread.  `task` must
    /// confine its writes to per-unit disjoint state (see
    /// [`DisjointSlices`]); reads of shared state are unrestricted.
    /// Panics in `task` are propagated to the caller after the barrier,
    /// leaving the pool reusable.  Takes `&mut self`: the epoch/barrier
    /// protocol supports one dispatch at a time, so concurrent `run`
    /// calls are rejected at compile time.
    pub fn run(&mut self, n_units: usize, task: &(dyn Fn(usize) + Sync)) {
        let stride = self.workers.len() + 1;
        if stride == 1 || n_units <= 1 {
            for u in 0..n_units {
                task(u);
            }
            return;
        }
        // Erase the borrow's lifetime: the barrier below outlives every
        // worker dereference, so the pointee stays valid throughout.
        let ptr = TaskPtr(unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(task)
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert_eq!(st.running, 0, "pool dispatched re-entrantly");
            st.epoch = st.epoch.wrapping_add(1);
            st.task = Some(ptr);
            st.n_units = n_units;
            st.running = self.workers.len();
            self.shared.go.notify_all();
        }
        // the caller is thread 0 of the partition
        let caller = catch_unwind(AssertUnwindSafe(|| {
            let mut u = 0;
            while u < n_units {
                task(u);
                u += stride;
            }
        }));
        let mut st = self.shared.state.lock().unwrap();
        while st.running > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.task = None;
        let worker_panicked = std::mem::replace(&mut st.panicked, false);
        drop(st);
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("worker pool task panicked");
        }
    }

    /// [`run`](Self::run), but executed inline on the caller when the
    /// estimated work (`est_macs`, multiply-accumulates) is too small
    /// to amortize a dispatch wakeup.  `threshold` is the cutoff in
    /// MACs; results are bit-identical either way.
    pub fn run_if_worth(
        &mut self,
        n_units: usize,
        est_macs: usize,
        threshold: usize,
        task: &(dyn Fn(usize) + Sync),
    ) {
        if est_macs < threshold {
            for u in 0..n_units {
                task(u);
            }
        } else {
            self.run(n_units, task);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.stop = true;
            self.shared.go.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: &Shared, wid: usize, stride: usize) {
    let mut seen = 0u64;
    loop {
        let (ptr, n_units) = {
            let mut st = sh.state.lock().unwrap();
            while !st.stop && st.epoch == seen {
                st = sh.go.wait(st).unwrap();
            }
            if st.stop {
                return;
            }
            seen = st.epoch;
            let ptr = st.task.expect("task set when epoch advances");
            (ptr, st.n_units)
        };
        // SAFETY: the dispatching `run` call blocks on the completion
        // barrier until we decrement `running`, so the closure behind
        // `ptr` is alive for the whole execution window.
        let task = unsafe { &*ptr.0 };
        let r = catch_unwind(AssertUnwindSafe(|| {
            let mut u = wid;
            while u < n_units {
                task(u);
                u += stride;
            }
        }));
        let mut st = sh.state.lock().unwrap();
        if r.is_err() {
            st.panicked = true;
        }
        st.running -= 1;
        if st.running == 0 {
            sh.done.notify_all();
        }
    }
}

/// Shared view of one `&mut [T]` that pool tasks carve per-unit
/// mutable sub-slices out of.  `T` defaults to `f32` (the activation
/// buffers); the INT8 KV-cache path instantiates it at `i8` for the
/// quantized value planes.
///
/// The borrow checker cannot prove units write disjoint ranges, so the
/// proof obligation moves to the caller: every [`slice`](Self::slice)
/// range handed to concurrently running units MUST be disjoint.  All
/// uses in this crate derive ranges from the unit index over
/// non-overlapping row/column blocks.
pub struct DisjointSlices<'a, T = f32> {
    ptr: *mut T,
    len: usize,
    _borrow: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: access is only through `unsafe fn slice`, whose contract
// (disjoint ranges across threads) makes concurrent use sound for any
// T that may itself cross threads.
unsafe impl<T: Send + Sync> Send for DisjointSlices<'_, T> {}
unsafe impl<T: Send + Sync> Sync for DisjointSlices<'_, T> {}

impl<'a, T> DisjointSlices<'a, T> {
    /// Wrap a buffer for per-unit sub-slicing.
    pub fn new(buf: &'a mut [T]) -> Self {
        DisjointSlices {
            ptr: buf.as_mut_ptr(),
            len: buf.len(),
            _borrow: std::marker::PhantomData,
        }
    }

    /// Mutable view of `[start, start + len)`.
    ///
    /// # Safety
    /// Ranges taken by distinct units that may run concurrently must
    /// not overlap, and a unit must not hold two overlapping slices.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        assert!(
            start.checked_add(len).is_some_and(|end| end <= self.len),
            "disjoint slice [{start}, {start}+{len}) out of bounds ({})",
            self.len
        );
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// First-error capture for fallible pool tasks.  `run`'s task closures
/// return `()` (units must be independent), so a unit that *can* fail
/// — e.g. KV quantization rejecting a non-finite activation — records
/// its error here and returns; after the barrier the dispatching code
/// [`take`](Self::take)s the earliest-recorded error and bails.  Which
/// unit's error wins under concurrency is scheduling-dependent, but
/// whether *any* error is reported is not, which is all the
/// determinism contract needs from a failure path.
#[derive(Default)]
pub struct FirstError {
    slot: Mutex<Option<anyhow::Error>>,
}

impl FirstError {
    /// An empty capture slot.
    pub fn new() -> FirstError {
        FirstError::default()
    }

    /// Record `err` if no earlier unit already recorded one.
    pub fn record(&self, err: anyhow::Error) {
        let mut slot = self.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(err);
        }
    }

    /// Run `f` and record its error, keeping the unit's control flow a
    /// plain statement at the call site.
    pub fn capture(&self, f: impl FnOnce() -> Result<()>) {
        if let Err(e) = f() {
            self.record(e);
        }
    }

    /// Take the recorded error, leaving the slot empty for reuse.
    pub fn take(&self) -> Option<anyhow::Error> {
        self.slot.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_unit_exactly_once() {
        for threads in [1usize, 2, 4] {
            let mut pool = WorkerPool::new(threads).unwrap();
            for n_units in [0usize, 1, 3, 17, 64] {
                let hits: Vec<AtomicUsize> =
                    (0..n_units).map(|_| AtomicUsize::new(0)).collect();
                pool.run(n_units, &|u| {
                    hits[u].fetch_add(1, Ordering::SeqCst);
                });
                for (u, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::SeqCst), 1,
                               "unit {u} at threads={threads}");
                }
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_dispatches() {
        let mut pool = WorkerPool::new(3).unwrap();
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(8, &|u| {
                total.fetch_add(u + 1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 50 * 36);
    }

    #[test]
    fn disjoint_writes_land_in_place() {
        let mut pool = WorkerPool::new(4).unwrap();
        let mut buf = vec![0.0f32; 1024];
        {
            let out = DisjointSlices::new(&mut buf);
            pool.run(16, &|u| {
                let s = unsafe { out.slice(u * 64, 64) };
                for (i, v) in s.iter_mut().enumerate() {
                    *v = (u * 64 + i) as f32;
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let mut pool = WorkerPool::new(2).unwrap();
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|u| {
                if u == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the caller");
        // the pool keeps working afterwards
        let n = AtomicUsize::new(0);
        pool.run(4, &|_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn run_if_worth_inlines_small_jobs() {
        let mut pool = WorkerPool::new(2).unwrap();
        let n = AtomicUsize::new(0);
        pool.run_if_worth(4, 10, 1000, &|_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        pool.run_if_worth(4, 10_000, 1000, &|_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn first_error_keeps_the_earliest_and_resets_on_take() {
        let fe = FirstError::new();
        assert!(fe.take().is_none());
        fe.capture(|| Ok(()));
        assert!(fe.take().is_none());
        fe.record(anyhow::anyhow!("first"));
        fe.record(anyhow::anyhow!("second"));
        assert_eq!(fe.take().unwrap().to_string(), "first");
        assert!(fe.take().is_none(), "take must drain the slot");
        // usable from pool tasks
        let mut pool = WorkerPool::new(2).unwrap();
        pool.run(8, &|u| {
            fe.capture(|| {
                anyhow::ensure!(u % 2 == 0, "odd unit {u}");
                Ok(())
            });
        });
        assert!(fe.take().unwrap().to_string().starts_with("odd unit"));
    }

    #[test]
    fn auto_threads_divides_by_world() {
        assert_eq!(auto_threads(3, 1), 3);
        assert_eq!(auto_threads(0, usize::MAX), 1); // never 0
        assert!(auto_threads(0, 1) >= 1);
        assert_eq!(auto_threads(1000, 1), 64); // clamped
    }
}
