//! Per-block symmetric INT8 quantization for the hermetic hot path
//! (DESIGN.md §11).
//!
//! Decode on CPUs is memory-bandwidth-bound: every step streams the
//! full weight set plus the growing KV cache through the cores, so the
//! bytes *stored* per parameter are the bytes *moved* per token.  This
//! module stores weights as `i8` with per-block `f32` scales — ~3.8×
//! fewer bytes than f32 — and the GEMM kernels dequantize inside the
//! multiply-accumulate (`x · (q·s)`), so the full-precision tensor is
//! never materialized.
//!
//! # Scheme
//!
//! A [`QuantMat`] is a row-major `[k, cols]` matrix whose contraction
//! axis `k` is cut into fixed *quantization groups* of `group` rows.
//! Each (group, column) block stores one `f32` scale
//! `s = max|w| / 127` and the block's weights as
//! `q = round(w / s) ∈ [-127, 127]`, so the reconstruction error is
//! bounded per element: `|w − q·s| ≤ s/2`.
//!
//! Group placement is what keeps the backend's determinism guarantees
//! intact (DESIGN.md §9.1/§10.1):
//!
//! * groups run along `k`, never along the output columns, so a
//!   column-parallel shard (columns split across ranks) slices scale
//!   *columns* exactly like weight columns — no group ever straddles a
//!   rank boundary;
//! * for row-parallel matrices (`k` split across ranks) the group is
//!   the §9.1 reduction-chunk width `k_full / REDUCE_CHUNKS`, which
//!   every supported world size divides — so shard boundaries land on
//!   group boundaries there too.
//!
//! Quantization always runs over the FULL tensor before sharding
//! ([`crate::model`]'s `synth_quant_shard`): every rank reconstructs
//! bit-identical `q·s` values for the elements it owns, at any world
//! size, which is why greedy decode stays bit-identical across worlds
//! {1,2,4,8} at a fixed dtype.

use anyhow::{ensure, Result};

use super::simd::{self, Isa};

/// Quantization group width (rows of the contraction axis per scale)
/// used for column-parallel weights and the lm head.  Row-parallel
/// weights use the reduction-chunk width instead (module docs).
pub const WEIGHT_QUANT_GROUP: usize = 64;

/// A dense row-major `[k, cols]` f32 weight matrix (the non-quantized
/// storage behind [`WeightMat::F32`]).
pub struct F32Mat {
    pub(crate) w: Vec<f32>,
    pub(crate) cols: usize,
}

impl F32Mat {
    /// Wrap a row-major `[w.len()/cols, cols]` buffer.
    pub fn new(w: Vec<f32>, cols: usize) -> F32Mat {
        debug_assert!(cols > 0 && w.len() % cols == 0);
        F32Mat { w, cols }
    }
}

/// A per-block symmetric INT8 matrix: row-major `[k, cols]` values in
/// `q`, one `f32` scale per (`group` rows of `k`) × column in `scales`
/// (row-major `[k/group, cols]`).
///
/// ```
/// use xeonserve::backend::quant::QuantMat;
///
/// // quantize → dequantize roundtrip: per-element error is bounded by
/// // half a quantization step (amax/254 of the element's block)
/// let k = 8;
/// let cols = 4;
/// let w: Vec<f32> =
///     (0..k * cols).map(|i| ((i * 37 % 23) as f32 - 11.0) * 0.17).collect();
/// let m = QuantMat::from_f32(&w, k, cols, 4).unwrap();
/// let amax = w.iter().fold(0.0f32, |a, x| a.max(x.abs()));
/// for r in 0..k {
///     for c in 0..cols {
///         let err = (m.dequant(r, c) - w[r * cols + c]).abs();
///         assert!(err <= amax / 254.0 + 1e-6, "row {r} col {c}: {err}");
///     }
/// }
/// ```
pub struct QuantMat {
    pub(crate) q: Vec<i8>,
    /// `[k/group, cols]` scales; `scales[(k/group)*cols + j]` covers
    /// element `(k, j)`
    pub(crate) scales: Vec<f32>,
    /// `[k/group, cols]` per-(group, column) sums of `q` — the
    /// zero-point correction term of the vnni W8A8 scheme
    /// (DESIGN.md §14); always materialized alongside the scales
    pub(crate) colsums: Vec<i32>,
    /// 4-k packed weight panels for the hardware `vpdpbusd` path,
    /// built on demand by [`QuantMat::ensure_vnni_pack`]
    pub(crate) vnni_pack: Option<Vec<i8>>,
    pub(crate) cols: usize,
    pub(crate) group: usize,
}

impl QuantMat {
    /// Quantize a row-major `[k, cols]` f32 matrix with `group`-row
    /// blocks along the contraction axis.  `group` must divide `k`.
    ///
    /// Non-finite input is rejected with a descriptive error:
    /// `f32::max` silently discards NaN operands, so a NaN or ±inf
    /// weight would otherwise produce a finite scale and a silently
    /// corrupted element instead of a diagnosis.
    pub fn from_f32(w: &[f32], k: usize, cols: usize, group: usize)
                    -> Result<QuantMat> {
        ensure!(cols > 0 && w.len() == k * cols,
                "quantize: {} elems for [{k}, {cols}]", w.len());
        ensure!(group > 0 && k % group == 0,
                "quant group {group} must divide k={k}");
        let n_groups = k / group;
        // pass 1: per-(group, column) absolute maxima, streamed row-major
        let mut amax = vec![0.0f32; n_groups * cols];
        for kk in 0..k {
            let row = &w[kk * cols..(kk + 1) * cols];
            let arow = &mut amax[(kk / group) * cols..][..cols];
            for (j, (a, &v)) in
                arow.iter_mut().zip(row).enumerate()
            {
                ensure!(v.is_finite(),
                        "non-finite weight {v} at ({kk}, {j}): \
                         refusing to quantize (the amax scan would \
                         drop it and the element would round-trip \
                         as garbage)");
                *a = a.max(v.abs());
            }
        }
        let scales: Vec<f32> =
            amax.iter().map(|&a| a / 127.0).collect();
        // pass 2: snap to the grid, accumulating the per-(group,
        // column) value sums the vnni zero-point correction needs
        let mut q = vec![0i8; k * cols];
        let mut colsums = vec![0i32; n_groups * cols];
        for kk in 0..k {
            let srow = &scales[(kk / group) * cols..][..cols];
            let wrow = &w[kk * cols..(kk + 1) * cols];
            let qrow = &mut q[kk * cols..(kk + 1) * cols];
            for ((qe, &we), &s) in
                qrow.iter_mut().zip(wrow).zip(srow)
            {
                *qe = if s > 0.0 {
                    (we / s).round().clamp(-127.0, 127.0) as i8
                } else {
                    0
                };
            }
            let crow = &mut colsums[(kk / group) * cols..][..cols];
            for (c, &qe) in crow.iter_mut().zip(qrow.iter()) {
                *c += qe as i32;
            }
        }
        Ok(QuantMat { q, scales, colsums, vnni_pack: None, cols,
                      group })
    }

    /// Number of `k` rows stored.
    pub fn k_rows(&self) -> usize {
        self.q.len() / self.cols
    }

    /// Reconstructed f32 value of element `(k, j)` — exactly the value
    /// the fused kernels multiply by.
    pub fn dequant(&self, k: usize, j: usize) -> f32 {
        self.q[k * self.cols + j] as f32
            * self.scales[(k / self.group) * self.cols + j]
    }

    /// Slice columns `[j0, j1)` out of every row (column-parallel
    /// sharding).  Scale columns travel with the weight columns, so
    /// the shard reconstructs the identical values.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> Result<QuantMat> {
        ensure!(j0 < j1 && j1 <= self.cols,
                "bad column slice [{j0}, {j1}) of {}", self.cols);
        let (k, bw) = (self.k_rows(), j1 - j0);
        let mut q = Vec::with_capacity(k * bw);
        for r in 0..k {
            q.extend_from_slice(&self.q[r * self.cols + j0
                ..r * self.cols + j1]);
        }
        let n_groups = k / self.group;
        let mut scales = Vec::with_capacity(n_groups * bw);
        let mut colsums = Vec::with_capacity(n_groups * bw);
        for g in 0..n_groups {
            scales.extend_from_slice(&self.scales[g * self.cols + j0
                ..g * self.cols + j1]);
            colsums.extend_from_slice(&self.colsums[g * self.cols + j0
                ..g * self.cols + j1]);
        }
        Ok(QuantMat { q, scales, colsums, vnni_pack: None, cols: bw,
                      group: self.group })
    }

    /// Slice rows `[k0, k1)` (row-parallel sharding).  Both bounds
    /// must land on group boundaries so no scale block is split.
    pub fn slice_rows(&self, k0: usize, k1: usize) -> Result<QuantMat> {
        ensure!(k0 < k1 && k1 <= self.k_rows(),
                "bad row slice [{k0}, {k1}) of {}", self.k_rows());
        ensure!(k0 % self.group == 0 && k1 % self.group == 0,
                "row slice [{k0}, {k1}) not aligned to group {}",
                self.group);
        let q = self.q[k0 * self.cols..k1 * self.cols].to_vec();
        let scales = self.scales[(k0 / self.group) * self.cols
            ..(k1 / self.group) * self.cols]
            .to_vec();
        let colsums = self.colsums[(k0 / self.group) * self.cols
            ..(k1 / self.group) * self.cols]
            .to_vec();
        Ok(QuantMat { q, scales, colsums, vnni_pack: None,
                      cols: self.cols, group: self.group })
    }

    /// Build the 4-k packed weight panels the hardware `vpdpbusd`
    /// kernel reads (DESIGN.md §14): panel `p` of group `g` holds,
    /// for every column `j`, the 4 weight bytes of rows
    /// `g·group + 4p .. g·group + 4p + 4` contiguously at byte offset
    /// `((g·panels + p)·cols + j)·4`, zero-padded past the group tail
    /// (zero weights contribute nothing to the integer dot, so
    /// padding never changes a sum).  Idempotent, and a no-op on CPUs
    /// without the VNNI fast path — the pack's only reader is the
    /// `dpbusd` kernel, and leaving it unbuilt keeps that unsafe call
    /// unreachable ([`WeightMat::mac_panel`] then uses the exact
    /// integer emulation, which computes identical sums).
    pub fn ensure_vnni_pack(&mut self) {
        if !simd::vnni_hw() || self.vnni_pack.is_some() {
            return;
        }
        let k = self.k_rows();
        let ppg = self.group.div_ceil(4); // panels per group
        let n_groups = k / self.group;
        let mut pack = vec![0i8; n_groups * ppg * self.cols * 4];
        for kk in 0..k {
            let g = kk / self.group;
            let p = (kk % self.group) / 4;
            let lane = kk % 4;
            let base = (g * ppg + p) * self.cols * 4;
            let row = &self.q[kk * self.cols..(kk + 1) * self.cols];
            for (j, &v) in row.iter().enumerate() {
                pack[base + j * 4 + lane] = v;
            }
        }
        self.vnni_pack = Some(pack);
    }

    /// Hardware `vpdpbusd` prefix of one group's integer dot: fills
    /// `idot[..ret]` for the leading 16-column blocks of `[j0, j1)`
    /// and returns how many columns it covered (0 when the pack is
    /// absent — no VNNI hardware — and the emulation does everything).
    #[cfg(target_arch = "x86_64")]
    fn dpbusd_prefix(&self, g: usize, j0: usize, j1: usize, u: &[u8],
                     idot: &mut [i32]) -> usize {
        match &self.vnni_pack {
            None => 0,
            Some(pack) => {
                let ppg = self.group.div_ceil(4);
                let region = &pack[g * ppg * self.cols * 4
                    ..(g + 1) * ppg * self.cols * 4];
                // SAFETY: the pack is only built when simd::vnni_hw()
                // holds (ensure_vnni_pack), so the required CPU
                // features are present.
                unsafe {
                    simd::dot_pack_dpbusd(u, region, self.cols, j0,
                                          j1, idot)
                }
            }
        }
    }

    /// Non-x86 hosts never build a pack; the emulation covers all
    /// columns.
    #[cfg(not(target_arch = "x86_64"))]
    fn dpbusd_prefix(&self, _g: usize, _j0: usize, _j1: usize,
                     _u: &[u8], _idot: &mut [i32]) -> usize {
        0
    }

    /// The W8A8 integer panel MAC (DESIGN.md §14): per quant group,
    /// quantize the activation sub-row to asymmetric u8, integer-dot
    /// it against the int8 weight columns (hardware `vpdpbusd` over
    /// the 4-k pack when built, exact scalar emulation otherwise —
    /// identical sums either way), then apply the combined scale and
    /// zero-point correction once per (group, column):
    ///
    /// `acc[j] += f32(idot − zp·colsum[g][j]) · (sx · sw[g][j])`
    ///
    /// accumulated over ascending groups.  Everything between the
    /// activation quantization and the final two f32 multiplies is
    /// exact integer arithmetic, so a group's contribution is a pure
    /// function of its activation values and weight block — invariant
    /// under threading, column blocking, and (because groups align
    /// with the §9.1 reduction-chunk grid) world size.
    fn mac_panel_vnni(&self, k0: usize, k1: usize, j0: usize,
                      j1: usize, x: &[f32], acc: &mut [f32]) {
        debug_assert!(k0 % self.group == 0 && k1 % self.group == 0,
                      "vnni panel [{k0}, {k1}) must align to group {}",
                      self.group);
        let bw = j1 - j0;
        // group widths vary by matrix (64 or the reduction-chunk
        // width), so per-call heap scratch keeps this correct for
        // every preset; both rows are reused across the groups
        let mut u = vec![0u8; self.group];
        let mut idot = vec![0i32; bw];
        for g in (k0 / self.group)..(k1 / self.group) {
            let ks = g * self.group;
            let (sx, zp) = quant_activation_row(
                &x[ks..ks + self.group], &mut u);
            if sx == 0.0 {
                continue; // all-zero activation group contributes 0
            }
            idot.fill(0);
            let done = self.dpbusd_prefix(g, j0, j1, &u, &mut idot);
            // exact integer emulation: the scheme's defining sums —
            // the whole block without hardware, the ragged column
            // tail with it
            for (i, &uk) in u.iter().enumerate() {
                if uk == 0 {
                    continue;
                }
                let row = &self.q[(ks + i) * self.cols + j0 + done
                    ..(ks + i) * self.cols + j1];
                for (d, &qv) in idot[done..].iter_mut().zip(row) {
                    *d += uk as i32 * qv as i32;
                }
            }
            let srow =
                &self.scales[g * self.cols + j0..g * self.cols + j1];
            let crow =
                &self.colsums[g * self.cols + j0..g * self.cols + j1];
            for (((a, &d), &sw), &cs) in
                acc.iter_mut().zip(&idot).zip(srow).zip(crow)
            {
                *a += (d - zp * cs) as f32 * (sx * sw);
            }
        }
    }
}

/// Quantize one activation sub-row to asymmetric u8 for the vnni
/// W8A8 scheme: `x ≈ (u − zp)·scale` with `u ∈ [0, 255]`,
/// `lo = min(0, min x)`, `hi = max(0, max x)` — zero is always
/// exactly representable, so sparse activations cost no error.
/// Returns `(scale, zp)`; a zero scale means the whole sub-row is
/// zero and contributes nothing.  A pure ascending scan of `x`:
/// identical bytes at any thread count, blocking, or world size.
pub fn quant_activation_row(x: &[f32], u: &mut [u8]) -> (f32, i32) {
    debug_assert_eq!(x.len(), u.len());
    let (mut lo, mut hi) = (0.0f32, 0.0f32);
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if hi <= lo {
        u.fill(0);
        return (0.0, 0);
    }
    let scale = (hi - lo) / 255.0;
    let zp = (-lo / scale).round().clamp(0.0, 255.0) as i32;
    for (ue, &v) in u.iter_mut().zip(x) {
        *ue = (v / scale + zp as f32).round().clamp(0.0, 255.0) as u8;
    }
    (scale, zp)
}

/// One weight matrix of the reference backend, in whichever storage
/// `EngineConfig::weight_dtype` selects.  The GEMM kernels are written
/// against [`WeightMat::mac_panel`] (an ISA-dispatched loop over the
/// [`WeightMat::mac_row`] chain), so both storages run the identical
/// single-accumulator, ascending-`k` chains — the property every
/// determinism guarantee rests on (module docs).
pub enum WeightMat {
    /// Dense f32 (4 bytes/weight).
    F32(F32Mat),
    /// Per-block symmetric INT8 (1 byte/weight + 8/`group` of scales
    /// and vnni column sums).
    Int8(QuantMat),
}

impl WeightMat {
    /// Wrap a dense row-major f32 buffer with `cols` columns.
    pub fn f32(w: Vec<f32>, cols: usize) -> WeightMat {
        WeightMat::F32(F32Mat::new(w, cols))
    }

    /// Fused multiply-accumulate of one weight row's column block:
    /// `acc[j - j0] += xk · w[k, j]` for `j ∈ [j0, j1)`.
    ///
    /// For INT8 the dequantization happens inside the MAC
    /// (`xk · (q·s)`) — only 1 byte per weight crosses the memory bus.
    /// Both arms add the same f32 value for a given element, in the
    /// same order, so kernel/thread/world bit-parity is unaffected by
    /// blocking or partitioning at a fixed dtype.
    #[inline]
    pub fn mac_row(&self, k: usize, j0: usize, j1: usize, xk: f32,
                   acc: &mut [f32]) {
        match self {
            WeightMat::F32(m) => {
                let row = &m.w[k * m.cols + j0..k * m.cols + j1];
                for (a, &wj) in acc.iter_mut().zip(row) {
                    *a += xk * wj;
                }
            }
            WeightMat::Int8(m) => {
                let qrow = &m.q[k * m.cols + j0..k * m.cols + j1];
                let srow = &m.scales[(k / m.group) * m.cols + j0
                    ..(k / m.group) * m.cols + j1];
                for ((a, &qj), &sj) in
                    acc.iter_mut().zip(qrow).zip(srow)
                {
                    *a += xk * (qj as f32 * sj);
                }
            }
        }
    }

    /// Multiply-accumulate a whole k-panel into one column block:
    /// `acc[j − j0] += Σ_{k ∈ [k0, k1)} x[k] · w[k, j]`, dispatching
    /// on the resolved instruction tier (DESIGN.md §14).  This is the
    /// single hook every GEMM inner loop funnels through; blocking,
    /// threading, and sharding only change which (row, column-block,
    /// k-panel) triples are combined, never a per-element chain.
    ///
    /// * `scalar` runs the per-k [`WeightMat::mac_row`] chain — the
    ///   pinned baseline.
    /// * `avx2` / `avx512` run the same ascending-k chain with each
    ///   row vectorized across columns by unfused per-lane mul+add
    ///   ([`crate::backend::simd`]) — bit-identical to scalar.
    /// * `vnni` (int8 storage only) runs the W8A8 integer scheme per
    ///   quant group ([`QuantMat`]'s `mac_panel_vnni`); `k0`/`k1`
    ///   must land on group boundaries, which the §9.1 reduction-
    ///   chunk grid guarantees at every kernel call site.  On f32
    ///   storage `vnni` degrades to the scalar chain — the tier only
    ///   governs int8 weight matmuls.
    #[allow(clippy::too_many_arguments)]
    pub fn mac_panel(&self, isa: Isa, k0: usize, k1: usize, j0: usize,
                     j1: usize, x: &[f32], acc: &mut [f32]) {
        match self {
            WeightMat::F32(m) => match isa {
                Isa::Avx2 => {
                    for k in k0..k1 {
                        let row =
                            &m.w[k * m.cols + j0..k * m.cols + j1];
                        simd::mac_row_f32_avx2(x[k], row, acc);
                    }
                }
                Isa::Avx512 => {
                    for k in k0..k1 {
                        let row =
                            &m.w[k * m.cols + j0..k * m.cols + j1];
                        simd::mac_row_f32_avx512(x[k], row, acc);
                    }
                }
                Isa::Scalar | Isa::Vnni => {
                    for k in k0..k1 {
                        self.mac_row(k, j0, j1, x[k], acc);
                    }
                }
            },
            WeightMat::Int8(m) => match isa {
                Isa::Avx2 => {
                    for k in k0..k1 {
                        let g = k / m.group;
                        let qrow =
                            &m.q[k * m.cols + j0..k * m.cols + j1];
                        let srow = &m.scales[g * m.cols + j0
                            ..g * m.cols + j1];
                        simd::mac_row_i8_avx2(x[k], qrow, srow, acc);
                    }
                }
                Isa::Avx512 => {
                    for k in k0..k1 {
                        let g = k / m.group;
                        let qrow =
                            &m.q[k * m.cols + j0..k * m.cols + j1];
                        let srow = &m.scales[g * m.cols + j0
                            ..g * m.cols + j1];
                        simd::mac_row_i8_avx512(x[k], qrow, srow,
                                                acc);
                    }
                }
                Isa::Vnni => {
                    m.mac_panel_vnni(k0, k1, j0, j1, x, acc);
                }
                Isa::Scalar => {
                    for k in k0..k1 {
                        self.mac_row(k, j0, j1, x[k], acc);
                    }
                }
            },
        }
    }

    /// Build the `vpdpbusd` weight pack on int8 storage (no-op on f32
    /// and on CPUs without the VNNI fast path) — the backend calls
    /// this once per matrix at construction when the vnni tier is
    /// selected.
    pub fn ensure_vnni_pack(&mut self) {
        if let WeightMat::Int8(m) = self {
            m.ensure_vnni_pack();
        }
    }

    /// Resident bytes of this matrix (values + scales, plus the vnni
    /// colsums and — when built — the `vpdpbusd` pack).
    pub fn bytes(&self) -> u64 {
        match self {
            WeightMat::F32(m) => (m.w.len() * 4) as u64,
            WeightMat::Int8(m) => {
                let pack =
                    m.vnni_pack.as_ref().map_or(0, |p| p.len());
                (m.q.len() + m.scales.len() * 4
                    + m.colsums.len() * 4 + pack) as u64
            }
        }
    }
}

/// Quantize one KV-cache row (`vals.len()` contiguous values sharing
/// one scale) into `q`, returning the scale.  The amax scan and the
/// rounding both run ascending over the row, so the stored bytes are a
/// pure function of the row's f32 content — identical at any thread
/// count or world size.  Non-finite input is rejected: `f32::max`
/// discards NaN operands, so a NaN value would otherwise yield a
/// finite scale and a silently-zeroed element.
pub fn quant_row_into(vals: &[f32], q: &mut [i8]) -> Result<f32> {
    debug_assert_eq!(vals.len(), q.len());
    let mut amax = 0.0f32;
    for (i, &v) in vals.iter().enumerate() {
        ensure!(
            v.is_finite(),
            "non-finite value {v} at index {i}: refusing to \
             quantize (the amax scan would drop it and the element \
             would round-trip as garbage)"
        );
        amax = amax.max(v.abs());
    }
    let scale = amax / 127.0;
    if scale > 0.0 {
        for (qe, &v) in q.iter_mut().zip(vals) {
            *qe = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    } else {
        q.fill(0);
    }
    Ok(scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 29 % 17) as f32 - 8.0) * 0.31).collect()
    }

    #[test]
    fn roundtrip_error_bounded_per_block() {
        let (k, cols, group) = (16, 6, 4);
        let w = ramp(k * cols);
        let m = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for r in 0..k {
            for c in 0..cols {
                // block amax for this element
                let g = r / group;
                let amax = (g * group..(g + 1) * group)
                    .map(|kk| w[kk * cols + c].abs())
                    .fold(0.0f32, f32::max);
                let err = (m.dequant(r, c) - w[r * cols + c]).abs();
                assert!(err <= amax / 254.0 + 1e-6,
                        "({r},{c}): err {err} > bound {}", amax / 254.0);
            }
        }
    }

    #[test]
    fn zero_block_quantizes_to_zero() {
        let m = QuantMat::from_f32(&[0.0; 8], 4, 2, 4).unwrap();
        for r in 0..4 {
            for c in 0..2 {
                assert_eq!(m.dequant(r, c), 0.0);
            }
        }
    }

    #[test]
    fn col_slice_preserves_dequant_values() {
        let (k, cols, group) = (8, 12, 4);
        let w = ramp(k * cols);
        let full = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for (j0, j1) in [(0, 3), (3, 9), (9, 12)] {
            let s = full.slice_cols(j0, j1).unwrap();
            for r in 0..k {
                for c in j0..j1 {
                    assert_eq!(s.dequant(r, c - j0).to_bits(),
                               full.dequant(r, c).to_bits());
                }
            }
        }
        assert!(full.slice_cols(4, 4).is_err());
        assert!(full.slice_cols(0, 13).is_err());
    }

    #[test]
    fn row_slice_preserves_dequant_values() {
        let (k, cols, group) = (16, 5, 4);
        let w = ramp(k * cols);
        let full = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for (k0, k1) in [(0, 4), (4, 12), (12, 16)] {
            let s = full.slice_rows(k0, k1).unwrap();
            for r in k0..k1 {
                for c in 0..cols {
                    assert_eq!(s.dequant(r - k0, c).to_bits(),
                               full.dequant(r, c).to_bits());
                }
            }
        }
        // misaligned slice must be rejected, not silently re-scaled
        assert!(full.slice_rows(2, 6).is_err());
    }

    #[test]
    fn mac_row_matches_dequant_chain() {
        let (k, cols, group) = (8, 10, 4);
        let w = ramp(k * cols);
        let qm = QuantMat::from_f32(&w, k, cols, group).unwrap();
        let wm = WeightMat::Int8(qm);
        let x = ramp(k);
        // reference: explicit ascending-k chain over dequant values
        let qm2 = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for (j0, j1) in [(0usize, 10usize), (2, 7)] {
            let bw = j1 - j0;
            let mut acc = vec![0.0f32; bw];
            for (kk, &xk) in x.iter().enumerate() {
                wm.mac_row(kk, j0, j1, xk, &mut acc);
            }
            let mut want = vec![0.0f32; bw];
            for (kk, &xk) in x.iter().enumerate() {
                for j in j0..j1 {
                    want[j - j0] += xk * qm2.dequant(kk, j);
                }
            }
            for (a, b) in acc.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn f32_mac_row_is_the_plain_chain() {
        let (k, cols) = (6, 8);
        let w = ramp(k * cols);
        let wm = WeightMat::f32(w.clone(), cols);
        let x = ramp(k);
        let mut acc = vec![0.0f32; cols];
        for (kk, &xk) in x.iter().enumerate() {
            wm.mac_row(kk, 0, cols, xk, &mut acc);
        }
        let mut want = vec![0.0f32; cols];
        for (kk, &xk) in x.iter().enumerate() {
            for j in 0..cols {
                want[j] += xk * w[kk * cols + j];
            }
        }
        for (a, b) in acc.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bytes_reflect_storage() {
        let (k, cols, group) = (64, 32, 64);
        let w = ramp(k * cols);
        let f = WeightMat::f32(w.clone(), cols);
        let q = WeightMat::Int8(
            QuantMat::from_f32(&w, k, cols, group).unwrap());
        assert_eq!(f.bytes(), (k * cols * 4) as u64);
        // q + scales (4B) + colsums (4B) per (group, column)
        assert_eq!(q.bytes(),
                   (k * cols + (k / group) * cols * 8) as u64);
        assert!(q.bytes() * 3 < f.bytes(),
                "int8 must be well under a third of f32");
    }

    #[test]
    fn quant_row_roundtrip_bound() {
        let vals = ramp(96);
        let mut q = vec![0i8; 96];
        let s = quant_row_into(&vals, &mut q).unwrap();
        let amax = vals.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        assert!((s - amax / 127.0).abs() < 1e-9);
        for (&qe, &v) in q.iter().zip(&vals) {
            assert!((qe as f32 * s - v).abs() <= s / 2.0 + 1e-6);
        }
        // all-zero row
        let z = vec![0.0f32; 8];
        let mut qz = vec![1i8; 8];
        assert_eq!(quant_row_into(&z, &mut qz).unwrap(), 0.0);
        assert!(qz.iter().all(|&b| b == 0));
    }

    #[test]
    fn non_finite_weights_are_rejected() {
        let mut w = ramp(8 * 4);
        w[13] = f32::NAN;
        let err = QuantMat::from_f32(&w, 8, 4, 4).unwrap_err();
        assert!(err.to_string().contains("non-finite"),
                "unexpected message: {err}");
        w[13] = f32::INFINITY;
        assert!(QuantMat::from_f32(&w, 8, 4, 4).is_err());

        let mut row = ramp(16);
        row[5] = f32::NAN;
        let mut q = vec![0i8; 16];
        let err = quant_row_into(&row, &mut q).unwrap_err();
        assert!(err.to_string().contains("index 5"),
                "unexpected message: {err}");
        row[5] = f32::NEG_INFINITY;
        assert!(quant_row_into(&row, &mut q).is_err());
    }

    #[test]
    fn mac_panel_matches_mac_row_chain_per_tier() {
        let (k, cols, group) = (16, 20, 4);
        let w = ramp(k * cols);
        let x = ramp(k);
        let mats = [
            WeightMat::f32(w.clone(), cols),
            WeightMat::Int8(
                QuantMat::from_f32(&w, k, cols, group).unwrap()),
        ];
        for wm in &mats {
            for (j0, j1) in [(0usize, cols), (4, 15)] {
                let bw = j1 - j0;
                let mut want = vec![0.0f32; bw];
                for (kk, &xk) in x.iter().enumerate() {
                    wm.mac_row(kk, j0, j1, xk, &mut want);
                }
                for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512] {
                    if !simd::available(isa) {
                        continue;
                    }
                    let mut acc = vec![0.0f32; bw];
                    wm.mac_panel(isa, 0, k, j0, j1, &x, &mut acc);
                    for (a, b) in acc.iter().zip(&want) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "{isa} diverged from scalar");
                    }
                }
                // vnni over f32 storage must be the scalar chain
                if matches!(wm, WeightMat::F32(_)) {
                    let mut acc = vec![0.0f32; bw];
                    wm.mac_panel(Isa::Vnni, 0, k, j0, j1, &x,
                                 &mut acc);
                    for (a, b) in acc.iter().zip(&want) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn activation_row_quantization_roundtrips() {
        let x = ramp(64);
        let mut u = vec![0u8; 64];
        let (s, zp) = quant_activation_row(&x, &mut u);
        assert!(s > 0.0);
        for (&ue, &v) in u.iter().zip(&x) {
            let back = (ue as i32 - zp) as f32 * s;
            assert!((back - v).abs() <= s / 2.0 + 1e-6,
                    "{v} -> {ue} -> {back} (scale {s}, zp {zp})");
        }
        // all-zero row maps to (0.0, 0) and zeroed codes
        let z = vec![0.0f32; 8];
        let mut uz = vec![9u8; 8];
        assert_eq!(quant_activation_row(&z, &mut uz), (0.0, 0));
        assert!(uz.iter().all(|&b| b == 0));
    }

    #[test]
    fn vnni_panel_is_invariant_under_slicing() {
        // vnni results must not depend on how the output columns or
        // the k-panels are blocked — only on which (group, column)
        // pairs are combined — or world-size invariance breaks.
        let (k, cols, group) = (16, 24, 4);
        let w = ramp(k * cols);
        let x = ramp(k);
        let full = QuantMat::from_f32(&w, k, cols, group).unwrap();
        let mut whole = vec![0.0f32; cols];
        full.mac_panel_vnni(0, k, 0, cols, &x, &mut whole);

        // column blocking + col slices
        for (j0, j1) in [(0usize, 8usize), (8, 17), (17, 24)] {
            let mut blk = vec![0.0f32; j1 - j0];
            full.mac_panel_vnni(0, k, j0, j1, &x, &mut blk);
            for (a, b) in blk.iter().zip(&whole[j0..j1]) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            let sliced =
                WeightMat::Int8(full.slice_cols(j0, j1).unwrap());
            let mut s_acc = vec![0.0f32; j1 - j0];
            sliced.mac_panel(Isa::Vnni, 0, k, 0, j1 - j0, &x,
                             &mut s_acc);
            for (a, b) in s_acc.iter().zip(&whole[j0..j1]) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }

        // k-panel blocking at group boundaries sums the same values
        let mut panels = vec![0.0f32; cols];
        for (k0, k1) in [(0usize, 8usize), (8, 12), (12, 16)] {
            full.mac_panel_vnni(k0, k1, 0, cols, &x, &mut panels);
        }
        for (a, b) in panels.iter().zip(&whole) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // a row slice fed the tail of the activation row (as the
        // §9.1 chunk grid does on row-parallel shards)
        let half = full.slice_rows(8, 16).unwrap();
        let mut tail = vec![0.0f32; cols];
        half.mac_panel_vnni(0, 8, 0, cols, &x[8..], &mut tail);
        let mut want_tail = vec![0.0f32; cols];
        full.mac_panel_vnni(8, 16, 0, cols, &x, &mut want_tail);
        for (a, b) in tail.iter().zip(&want_tail) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn vnni_panel_is_accurate_and_distinct_from_dequant() {
        let (k, cols, group) = (128, 16, 64);
        let w = ramp(k * cols);
        let x: Vec<f32> =
            (0..k).map(|i| ((i * 13 % 31) as f32 - 15.0) * 0.07)
                  .collect();
        let qm = QuantMat::from_f32(&w, k, cols, group).unwrap();
        let wm = WeightMat::Int8(
            QuantMat::from_f32(&w, k, cols, group).unwrap());

        let mut vnni = vec![0.0f32; cols];
        qm.mac_panel_vnni(0, k, 0, cols, &x, &mut vnni);

        // accuracy: close to the exact f32 chain in relative l2
        let mut exact = vec![0.0f32; cols];
        for (kk, &xk) in x.iter().enumerate() {
            for j in 0..cols {
                exact[j] += xk * w[kk * cols + j];
            }
        }
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, b) in vnni.iter().zip(&exact) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 0.15, "vnni rel-l2 {rel} too far from f32");

        // engagement: the W8A8 scheme quantizes activations, so it
        // must NOT be bit-identical to the dequantized-scalar chain
        let mut dequant = vec![0.0f32; cols];
        wm.mac_panel(Isa::Scalar, 0, k, 0, cols, &x, &mut dequant);
        assert!(vnni.iter().zip(&dequant)
                    .any(|(a, b)| a.to_bits() != b.to_bits()),
                "vnni path produced the dequant chain bit-for-bit — \
                 the integer scheme is not engaged");
    }

    #[test]
    fn vnni_pack_is_gated_on_hardware() {
        let (k, cols, group) = (8, 4, 4);
        let w = ramp(k * cols);
        let mut wm = WeightMat::Int8(
            QuantMat::from_f32(&w, k, cols, group).unwrap());
        let before = wm.bytes();
        wm.ensure_vnni_pack();
        if simd::vnni_hw() {
            // pack holds ppg = group/4 panels × 4 lanes per column
            assert_eq!(wm.bytes(), before + (k * cols) as u64);
        } else {
            assert_eq!(wm.bytes(), before);
        }
        // packing must never change results
        let x = ramp(k);
        let mut with_pack = vec![0.0f32; cols];
        wm.mac_panel(Isa::Vnni, 0, k, 0, cols, &x, &mut with_pack);
        let plain = WeightMat::Int8(
            QuantMat::from_f32(&w, k, cols, group).unwrap());
        let mut without = vec![0.0f32; cols];
        plain.mac_panel(Isa::Vnni, 0, k, 0, cols, &x, &mut without);
        for (a, b) in with_pack.iter().zip(&without) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
