//! Per-block symmetric INT8 quantization for the hermetic hot path
//! (DESIGN.md §11).
//!
//! Decode on CPUs is memory-bandwidth-bound: every step streams the
//! full weight set plus the growing KV cache through the cores, so the
//! bytes *stored* per parameter are the bytes *moved* per token.  This
//! module stores weights as `i8` with per-block `f32` scales — ~3.8×
//! fewer bytes than f32 — and the GEMM kernels dequantize inside the
//! multiply-accumulate (`x · (q·s)`), so the full-precision tensor is
//! never materialized.
//!
//! # Scheme
//!
//! A [`QuantMat`] is a row-major `[k, cols]` matrix whose contraction
//! axis `k` is cut into fixed *quantization groups* of `group` rows.
//! Each (group, column) block stores one `f32` scale
//! `s = max|w| / 127` and the block's weights as
//! `q = round(w / s) ∈ [-127, 127]`, so the reconstruction error is
//! bounded per element: `|w − q·s| ≤ s/2`.
//!
//! Group placement is what keeps the backend's determinism guarantees
//! intact (DESIGN.md §9.1/§10.1):
//!
//! * groups run along `k`, never along the output columns, so a
//!   column-parallel shard (columns split across ranks) slices scale
//!   *columns* exactly like weight columns — no group ever straddles a
//!   rank boundary;
//! * for row-parallel matrices (`k` split across ranks) the group is
//!   the §9.1 reduction-chunk width `k_full / REDUCE_CHUNKS`, which
//!   every supported world size divides — so shard boundaries land on
//!   group boundaries there too.
//!
//! Quantization always runs over the FULL tensor before sharding
//! ([`crate::model`]'s `synth_quant_shard`): every rank reconstructs
//! bit-identical `q·s` values for the elements it owns, at any world
//! size, which is why greedy decode stays bit-identical across worlds
//! {1,2,4,8} at a fixed dtype.

use anyhow::{ensure, Result};

/// Quantization group width (rows of the contraction axis per scale)
/// used for column-parallel weights and the lm head.  Row-parallel
/// weights use the reduction-chunk width instead (module docs).
pub const WEIGHT_QUANT_GROUP: usize = 64;

/// A dense row-major `[k, cols]` f32 weight matrix (the non-quantized
/// storage behind [`WeightMat::F32`]).
pub struct F32Mat {
    pub(crate) w: Vec<f32>,
    pub(crate) cols: usize,
}

impl F32Mat {
    /// Wrap a row-major `[w.len()/cols, cols]` buffer.
    pub fn new(w: Vec<f32>, cols: usize) -> F32Mat {
        debug_assert!(cols > 0 && w.len() % cols == 0);
        F32Mat { w, cols }
    }
}

/// A per-block symmetric INT8 matrix: row-major `[k, cols]` values in
/// `q`, one `f32` scale per (`group` rows of `k`) × column in `scales`
/// (row-major `[k/group, cols]`).
///
/// ```
/// use xeonserve::backend::quant::QuantMat;
///
/// // quantize → dequantize roundtrip: per-element error is bounded by
/// // half a quantization step (amax/254 of the element's block)
/// let k = 8;
/// let cols = 4;
/// let w: Vec<f32> =
///     (0..k * cols).map(|i| ((i * 37 % 23) as f32 - 11.0) * 0.17).collect();
/// let m = QuantMat::from_f32(&w, k, cols, 4).unwrap();
/// let amax = w.iter().fold(0.0f32, |a, x| a.max(x.abs()));
/// for r in 0..k {
///     for c in 0..cols {
///         let err = (m.dequant(r, c) - w[r * cols + c]).abs();
///         assert!(err <= amax / 254.0 + 1e-6, "row {r} col {c}: {err}");
///     }
/// }
/// ```
pub struct QuantMat {
    pub(crate) q: Vec<i8>,
    /// `[k/group, cols]` scales; `scales[(k/group)*cols + j]` covers
    /// element `(k, j)`
    pub(crate) scales: Vec<f32>,
    pub(crate) cols: usize,
    pub(crate) group: usize,
}

impl QuantMat {
    /// Quantize a row-major `[k, cols]` f32 matrix with `group`-row
    /// blocks along the contraction axis.  `group` must divide `k`.
    pub fn from_f32(w: &[f32], k: usize, cols: usize, group: usize)
                    -> Result<QuantMat> {
        ensure!(cols > 0 && w.len() == k * cols,
                "quantize: {} elems for [{k}, {cols}]", w.len());
        ensure!(group > 0 && k % group == 0,
                "quant group {group} must divide k={k}");
        let n_groups = k / group;
        // pass 1: per-(group, column) absolute maxima, streamed row-major
        let mut amax = vec![0.0f32; n_groups * cols];
        for kk in 0..k {
            let row = &w[kk * cols..(kk + 1) * cols];
            let arow = &mut amax[(kk / group) * cols..][..cols];
            for (a, &v) in arow.iter_mut().zip(row) {
                *a = a.max(v.abs());
            }
        }
        let scales: Vec<f32> =
            amax.iter().map(|&a| a / 127.0).collect();
        // pass 2: snap to the grid
        let mut q = vec![0i8; k * cols];
        for kk in 0..k {
            let srow = &scales[(kk / group) * cols..][..cols];
            let wrow = &w[kk * cols..(kk + 1) * cols];
            let qrow = &mut q[kk * cols..(kk + 1) * cols];
            for ((qe, &we), &s) in
                qrow.iter_mut().zip(wrow).zip(srow)
            {
                *qe = if s > 0.0 {
                    (we / s).round().clamp(-127.0, 127.0) as i8
                } else {
                    0
                };
            }
        }
        Ok(QuantMat { q, scales, cols, group })
    }

    /// Number of `k` rows stored.
    pub fn k_rows(&self) -> usize {
        self.q.len() / self.cols
    }

    /// Reconstructed f32 value of element `(k, j)` — exactly the value
    /// the fused kernels multiply by.
    pub fn dequant(&self, k: usize, j: usize) -> f32 {
        self.q[k * self.cols + j] as f32
            * self.scales[(k / self.group) * self.cols + j]
    }

    /// Slice columns `[j0, j1)` out of every row (column-parallel
    /// sharding).  Scale columns travel with the weight columns, so
    /// the shard reconstructs the identical values.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> Result<QuantMat> {
        ensure!(j0 < j1 && j1 <= self.cols,
                "bad column slice [{j0}, {j1}) of {}", self.cols);
        let (k, bw) = (self.k_rows(), j1 - j0);
        let mut q = Vec::with_capacity(k * bw);
        for r in 0..k {
            q.extend_from_slice(&self.q[r * self.cols + j0
                ..r * self.cols + j1]);
        }
        let n_groups = k / self.group;
        let mut scales = Vec::with_capacity(n_groups * bw);
        for g in 0..n_groups {
            scales.extend_from_slice(&self.scales[g * self.cols + j0
                ..g * self.cols + j1]);
        }
        Ok(QuantMat { q, scales, cols: bw, group: self.group })
    }

    /// Slice rows `[k0, k1)` (row-parallel sharding).  Both bounds
    /// must land on group boundaries so no scale block is split.
    pub fn slice_rows(&self, k0: usize, k1: usize) -> Result<QuantMat> {
        ensure!(k0 < k1 && k1 <= self.k_rows(),
                "bad row slice [{k0}, {k1}) of {}", self.k_rows());
        ensure!(k0 % self.group == 0 && k1 % self.group == 0,
                "row slice [{k0}, {k1}) not aligned to group {}",
                self.group);
        let q = self.q[k0 * self.cols..k1 * self.cols].to_vec();
        let scales = self.scales[(k0 / self.group) * self.cols
            ..(k1 / self.group) * self.cols]
            .to_vec();
        Ok(QuantMat { q, scales, cols: self.cols, group: self.group })
    }
}

/// One weight matrix of the reference backend, in whichever storage
/// `EngineConfig::weight_dtype` selects.  The GEMM kernels are written
/// against [`WeightMat::mac_row`], so both storages run the identical
/// single-accumulator, ascending-`k` chains — the property every
/// determinism guarantee rests on (module docs).
pub enum WeightMat {
    /// Dense f32 (4 bytes/weight).
    F32(F32Mat),
    /// Per-block symmetric INT8 (1 byte/weight + 4/`group` of scales).
    Int8(QuantMat),
}

impl WeightMat {
    /// Wrap a dense row-major f32 buffer with `cols` columns.
    pub fn f32(w: Vec<f32>, cols: usize) -> WeightMat {
        WeightMat::F32(F32Mat::new(w, cols))
    }

    /// Fused multiply-accumulate of one weight row's column block:
    /// `acc[j - j0] += xk · w[k, j]` for `j ∈ [j0, j1)`.
    ///
    /// For INT8 the dequantization happens inside the MAC
    /// (`xk · (q·s)`) — only 1 byte per weight crosses the memory bus.
    /// Both arms add the same f32 value for a given element, in the
    /// same order, so kernel/thread/world bit-parity is unaffected by
    /// blocking or partitioning at a fixed dtype.
    #[inline]
    pub fn mac_row(&self, k: usize, j0: usize, j1: usize, xk: f32,
                   acc: &mut [f32]) {
        match self {
            WeightMat::F32(m) => {
                let row = &m.w[k * m.cols + j0..k * m.cols + j1];
                for (a, &wj) in acc.iter_mut().zip(row) {
                    *a += xk * wj;
                }
            }
            WeightMat::Int8(m) => {
                let qrow = &m.q[k * m.cols + j0..k * m.cols + j1];
                let srow = &m.scales[(k / m.group) * m.cols + j0
                    ..(k / m.group) * m.cols + j1];
                for ((a, &qj), &sj) in
                    acc.iter_mut().zip(qrow).zip(srow)
                {
                    *a += xk * (qj as f32 * sj);
                }
            }
        }
    }

    /// Resident bytes of this matrix (values + scales).
    pub fn bytes(&self) -> u64 {
        match self {
            WeightMat::F32(m) => (m.w.len() * 4) as u64,
            WeightMat::Int8(m) => {
                (m.q.len() + m.scales.len() * 4) as u64
            }
        }
    }
}

/// Quantize one KV-cache row (`vals.len()` contiguous values sharing
/// one scale) into `q`, returning the scale.  The amax scan and the
/// rounding both run ascending over the row, so the stored bytes are a
/// pure function of the row's f32 content — identical at any thread
/// count or world size.
pub fn quant_row_into(vals: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(vals.len(), q.len());
    let mut amax = 0.0f32;
    for &v in vals {
        amax = amax.max(v.abs());
    }
    let scale = amax / 127.0;
    if scale > 0.0 {
        for (qe, &v) in q.iter_mut().zip(vals) {
            *qe = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    } else {
        q.fill(0);
    }
    scale
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 29 % 17) as f32 - 8.0) * 0.31).collect()
    }

    #[test]
    fn roundtrip_error_bounded_per_block() {
        let (k, cols, group) = (16, 6, 4);
        let w = ramp(k * cols);
        let m = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for r in 0..k {
            for c in 0..cols {
                // block amax for this element
                let g = r / group;
                let amax = (g * group..(g + 1) * group)
                    .map(|kk| w[kk * cols + c].abs())
                    .fold(0.0f32, f32::max);
                let err = (m.dequant(r, c) - w[r * cols + c]).abs();
                assert!(err <= amax / 254.0 + 1e-6,
                        "({r},{c}): err {err} > bound {}", amax / 254.0);
            }
        }
    }

    #[test]
    fn zero_block_quantizes_to_zero() {
        let m = QuantMat::from_f32(&[0.0; 8], 4, 2, 4).unwrap();
        for r in 0..4 {
            for c in 0..2 {
                assert_eq!(m.dequant(r, c), 0.0);
            }
        }
    }

    #[test]
    fn col_slice_preserves_dequant_values() {
        let (k, cols, group) = (8, 12, 4);
        let w = ramp(k * cols);
        let full = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for (j0, j1) in [(0, 3), (3, 9), (9, 12)] {
            let s = full.slice_cols(j0, j1).unwrap();
            for r in 0..k {
                for c in j0..j1 {
                    assert_eq!(s.dequant(r, c - j0).to_bits(),
                               full.dequant(r, c).to_bits());
                }
            }
        }
        assert!(full.slice_cols(4, 4).is_err());
        assert!(full.slice_cols(0, 13).is_err());
    }

    #[test]
    fn row_slice_preserves_dequant_values() {
        let (k, cols, group) = (16, 5, 4);
        let w = ramp(k * cols);
        let full = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for (k0, k1) in [(0, 4), (4, 12), (12, 16)] {
            let s = full.slice_rows(k0, k1).unwrap();
            for r in k0..k1 {
                for c in 0..cols {
                    assert_eq!(s.dequant(r - k0, c).to_bits(),
                               full.dequant(r, c).to_bits());
                }
            }
        }
        // misaligned slice must be rejected, not silently re-scaled
        assert!(full.slice_rows(2, 6).is_err());
    }

    #[test]
    fn mac_row_matches_dequant_chain() {
        let (k, cols, group) = (8, 10, 4);
        let w = ramp(k * cols);
        let qm = QuantMat::from_f32(&w, k, cols, group).unwrap();
        let wm = WeightMat::Int8(qm);
        let x = ramp(k);
        // reference: explicit ascending-k chain over dequant values
        let qm2 = QuantMat::from_f32(&w, k, cols, group).unwrap();
        for (j0, j1) in [(0usize, 10usize), (2, 7)] {
            let bw = j1 - j0;
            let mut acc = vec![0.0f32; bw];
            for (kk, &xk) in x.iter().enumerate() {
                wm.mac_row(kk, j0, j1, xk, &mut acc);
            }
            let mut want = vec![0.0f32; bw];
            for (kk, &xk) in x.iter().enumerate() {
                for j in j0..j1 {
                    want[j - j0] += xk * qm2.dequant(kk, j);
                }
            }
            for (a, b) in acc.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn f32_mac_row_is_the_plain_chain() {
        let (k, cols) = (6, 8);
        let w = ramp(k * cols);
        let wm = WeightMat::f32(w.clone(), cols);
        let x = ramp(k);
        let mut acc = vec![0.0f32; cols];
        for (kk, &xk) in x.iter().enumerate() {
            wm.mac_row(kk, 0, cols, xk, &mut acc);
        }
        let mut want = vec![0.0f32; cols];
        for (kk, &xk) in x.iter().enumerate() {
            for j in 0..cols {
                want[j] += xk * w[kk * cols + j];
            }
        }
        for (a, b) in acc.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bytes_reflect_storage() {
        let (k, cols, group) = (64, 32, 64);
        let w = ramp(k * cols);
        let f = WeightMat::f32(w.clone(), cols);
        let q = WeightMat::Int8(
            QuantMat::from_f32(&w, k, cols, group).unwrap());
        assert_eq!(f.bytes(), (k * cols * 4) as u64);
        assert_eq!(q.bytes(), (k * cols + (k / group) * cols * 4) as u64);
        assert!(q.bytes() * 3 < f.bytes(),
                "int8 must be well under a third of f32");
    }

    #[test]
    fn quant_row_roundtrip_bound() {
        let vals = ramp(96);
        let mut q = vec![0i8; 96];
        let s = quant_row_into(&vals, &mut q);
        let amax = vals.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        assert!((s - amax / 127.0).abs() < 1e-9);
        for (&qe, &v) in q.iter().zip(&vals) {
            assert!((qe as f32 * s - v).abs() <= s / 2.0 + 1e-6);
        }
        // all-zero row
        let z = vec![0.0f32; 8];
        let mut qz = vec![1i8; 8];
        assert_eq!(quant_row_into(&z, &mut qz), 0.0);
        assert!(qz.iter().all(|&b| b == 0));
    }
}
