//! PJRT runtime wrapper: loads AOT HLO-text artifacts and executes them.
//!
//! One [`RankRuntime`] per rank thread.  PJRT objects in the `xla` crate
//! are `Rc`-based (not `Send`), so each rank owns its *own* client,
//! executables and buffers — which is exactly the paper's process
//! topology (one inference process per socket, communicating through the
//! collective library, never sharing device state).
//!
//! Weights and KV caches live as device-resident [`PjRtBuffer`]s and are
//! passed by reference via `execute_b`; the only host crossings on the
//! decode path are the activation hand-offs at the collective boundaries
//! (and those land directly in the ccl arena — §2.3).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::config::{Manifest, SegmentMeta};

/// Per-rank PJRT state: client + compiled segment cache.
pub struct RankRuntime {
    client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
}

impl RankRuntime {
    pub fn new() -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RankRuntime { client, exes: HashMap::new() })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Compile a segment's HLO text (idempotent per segment id).
    pub fn compile_segment(&mut self, manifest: &Manifest,
                           seg: &SegmentMeta) -> Result<()> {
        if self.exes.contains_key(&seg.id) {
            return Ok(());
        }
        let path = manifest.hlo_path(seg);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling segment {}", seg.id))?;
        self.exes.insert(seg.id.clone(), exe);
        Ok(())
    }

    pub fn has_segment(&self, id: &str) -> bool {
        self.exes.contains_key(id)
    }

    /// Execute a compiled segment on device-resident buffers.  Returns
    /// one buffer per segment output (the vendored xla crate is patched
    /// with `untuple_result = true`).
    pub fn execute(&self, seg_id: &str, args: &[&PjRtBuffer])
                   -> Result<Vec<PjRtBuffer>> {
        let exe = self
            .exes
            .get(seg_id)
            .with_context(|| format!("segment {seg_id} not compiled"))?;
        let mut out = exe
            .execute_b(args)
            .with_context(|| format!("executing {seg_id}"))?;
        anyhow::ensure!(!out.is_empty(), "no replica outputs from {seg_id}");
        Ok(out.swap_remove(0))
    }

    // ---- host <-> device helpers -------------------------------------

    pub fn upload_f32(&self, data: &[f32], dims: &[usize])
                      -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize])
                      -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn zeros_f32(&self, dims: &[usize]) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        self.upload_f32(&vec![0.0; n], dims)
    }

    /// Download a buffer's f32 contents into `dst` (the §2.3 hand-off:
    /// `dst` is typically a ccl arena slot).
    ///
    /// Note: the CPU PJRT plugin does not implement `CopyRawToHost`, so
    /// the transfer goes through one intermediate literal (device →
    /// literal → dst).  The *staged* path below additionally materializes
    /// an owned `Vec` and pays the ring's per-hop copies — that delta is
    /// what the §2.3 bench measures.
    pub fn download_f32_into(&self, buf: &PjRtBuffer, dst: &mut [f32])
                             -> Result<()> {
        let lit = buf.to_literal_sync()?;
        lit.copy_raw_to(dst)?;
        Ok(())
    }

    /// Download through a staged literal (the baseline path; counts the
    /// extra copies the zero-copy hand-off avoids).
    pub fn download_f32_staged(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?; // copy 1: device -> literal
        Ok(lit.to_vec::<f32>()?) // copy 2: literal -> vec
    }

    /// Load an .npy file as a device buffer (golden weights).
    ///
    /// Goes through `buffer_from_host_buffer` (synchronous host copy)
    /// rather than `buffer_from_host_literal`: the literal path copies
    /// asynchronously on the client's thread pool and races literal
    /// destruction (observed SIGSEGV in `CopyFromLiteral` with
    /// xla_extension 0.5.1, even when awaiting the ready future).
    pub fn load_npy(&self, path: impl AsRef<Path>) -> Result<PjRtBuffer> {
        let lit = Literal::read_npy(path.as_ref(), &())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>()?;
                self.upload_f32(&data, &dims)
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>()?;
                self.upload_i32(&data, &dims)
            }
            ty => anyhow::bail!("unsupported npy dtype {ty:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build + run a computation without artifacts: (x + y) * 2.
    #[test]
    fn execute_builder_computation() {
        let rt = RankRuntime::new().unwrap();
        let b = xla::XlaBuilder::new("t");
        let shape = xla::Shape::array::<f32>(vec![4]);
        let x = b.parameter_s(0, &shape, "x").unwrap();
        let y = b.parameter_s(1, &shape, "y").unwrap();
        let sum = (x + y).unwrap();
        let out = sum.add_(&sum).unwrap();
        let comp = out.build().unwrap();
        let exe = rt.client.compile(&comp).unwrap();

        let xb = rt.upload_f32(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
        let yb = rt.upload_f32(&[10.0, 20.0, 30.0, 40.0], &[4]).unwrap();
        let outs = exe.execute_b(&[&xb, &yb]).unwrap();
        let mut dst = vec![0.0f32; 4];
        rt.download_f32_into(&outs[0][0], &mut dst).unwrap();
        assert_eq!(dst, vec![22.0, 44.0, 66.0, 88.0]); // (x+y)*2
    }

    #[test]
    fn upload_download_roundtrip() {
        let rt = RankRuntime::new().unwrap();
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let buf = rt.upload_f32(&data, &[3, 4]).unwrap();
        let mut back = vec![0.0f32; 12];
        rt.download_f32_into(&buf, &mut back).unwrap();
        assert_eq!(back, data);
        let staged = rt.download_f32_staged(&buf).unwrap();
        assert_eq!(staged, data);
    }

    #[test]
    fn missing_segment_errors() {
        let rt = RankRuntime::new().unwrap();
        assert!(rt.execute("nope", &[]).is_err());
    }
}
