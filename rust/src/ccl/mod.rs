//! `rccl` — the oneCCL-analog collective communication library.
//!
//! The paper drives all cross-socket traffic through oneCCL; this module
//! is the rust substrate playing that role for the simulated cluster.
//! Two data paths exist on purpose, because their difference *is* the
//! paper's §2.3 experiment:
//!
//! * **arena path (optimized)** — a shared-memory arena with one slot per
//!   rank.  The compute module writes its partial result *directly* into
//!   its slot (straight from the PJRT buffer), and the allreduce runs in
//!   place over the slots: zero staging copies.  This mirrors oneCCL's
//!   same-node shared-memory transport plus the paper's zero-copy
//!   compute→comm hand-off.
//! * **staged path (baseline)** — a classic ring implementation over a
//!   message-passing transport: every hop allocates and copies, and the
//!   user buffer is staged in and out, exactly the copies §2.3 removes.
//!
//! All collectives are instrumented ([`CommStats`]): wire bytes, staged
//! copy bytes, and synchronization counts — the quantities the paper's
//! three optimizations reduce.  An analytic [`wire`] model converts byte
//! counts into simulated cross-socket time for the scaled-up series.

mod arena;
mod group;
mod ring;
mod stats;
mod transport;
pub mod wire;

pub use arena::ArenaHandle;
pub use group::{CommGroup, Communicator};
pub use ring::ring_chunk_range;
pub use stats::{CommStats, StatsSnapshot};
pub use transport::{bytes_f32 as bytes_to_f32, InProcTransport,
                    PtpTransport, TcpTransport, RECV_TIMEOUT};

/// Owned little-endian byte image of an f32 slice (broadcast payloads).
pub fn f32_to_bytes(data: &[f32]) -> Vec<u8> {
    transport::f32_bytes(data).to_vec()
}

/// Reduction operator for allreduce/reduce collectives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
}

impl ReduceOp {
    #[inline]
    pub fn apply(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
        }
    }
}
