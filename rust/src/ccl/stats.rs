//! Communication instrumentation.
//!
//! Every collective records what the paper's optimizations are about:
//! how many synchronization points, how many bytes crossed the (virtual)
//! wire, and how many bytes were memcpy'd through staging buffers.  The
//! ablation benches (E2/E3/E4 in DESIGN.md §6) read these counters.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, lock-free counters for one communicator group.
#[derive(Debug, Default)]
pub struct CommStats {
    /// collective invocations that synchronize all ranks
    pub sync_points: AtomicU64,
    /// logical bytes moved between ranks (per-rank wire traffic, summed)
    pub wire_bytes: AtomicU64,
    /// bytes memcpy'd through staging buffers (0 on the zero-copy path)
    pub staged_copy_bytes: AtomicU64,
    /// number of discrete messages (for the per-message latency model)
    pub messages: AtomicU64,
    pub allreduces: AtomicU64,
    pub broadcasts: AtomicU64,
    pub gathers: AtomicU64,
    pub allgathers: AtomicU64,
}

/// Point-in-time copy of [`CommStats`], subtractable for deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub sync_points: u64,
    pub wire_bytes: u64,
    pub staged_copy_bytes: u64,
    pub messages: u64,
    pub allreduces: u64,
    pub broadcasts: u64,
    pub gathers: u64,
    pub allgathers: u64,
}

impl CommStats {
    pub fn record_collective(
        &self,
        kind: CollectiveKind,
        wire_bytes: u64,
        messages: u64,
        staged_bytes: u64,
    ) {
        self.sync_points.fetch_add(1, Ordering::Relaxed);
        self.wire_bytes.fetch_add(wire_bytes, Ordering::Relaxed);
        self.messages.fetch_add(messages, Ordering::Relaxed);
        self.staged_copy_bytes.fetch_add(staged_bytes, Ordering::Relaxed);
        let ctr = match kind {
            CollectiveKind::Allreduce => &self.allreduces,
            CollectiveKind::Broadcast => &self.broadcasts,
            CollectiveKind::Gather => &self.gathers,
            CollectiveKind::Allgather => &self.allgathers,
        };
        ctr.fetch_add(1, Ordering::Relaxed);
    }

    /// Extra staging memcpy bytes (outside a collective), e.g. the
    /// copy-in/copy-out the baseline pays at the compute↔comm boundary.
    pub fn record_staging(&self, bytes: u64) {
        self.staged_copy_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            sync_points: self.sync_points.load(Ordering::Relaxed),
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            staged_copy_bytes: self.staged_copy_bytes.load(Ordering::Relaxed),
            messages: self.messages.load(Ordering::Relaxed),
            allreduces: self.allreduces.load(Ordering::Relaxed),
            broadcasts: self.broadcasts.load(Ordering::Relaxed),
            gathers: self.gathers.load(Ordering::Relaxed),
            allgathers: self.allgathers.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub enum CollectiveKind {
    Allreduce,
    Broadcast,
    Gather,
    Allgather,
}

impl StatsSnapshot {
    /// Delta between two snapshots (self at end, `earlier` at start).
    pub fn since(&self, earlier: &StatsSnapshot) -> StatsSnapshot {
        StatsSnapshot {
            sync_points: self.sync_points - earlier.sync_points,
            wire_bytes: self.wire_bytes - earlier.wire_bytes,
            staged_copy_bytes: self.staged_copy_bytes
                - earlier.staged_copy_bytes,
            messages: self.messages - earlier.messages,
            allreduces: self.allreduces - earlier.allreduces,
            broadcasts: self.broadcasts - earlier.broadcasts,
            gathers: self.gathers - earlier.gathers,
            allgathers: self.allgathers - earlier.allgathers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let s = CommStats::default();
        s.record_collective(CollectiveKind::Allreduce, 100, 2, 0);
        s.record_collective(CollectiveKind::Broadcast, 4, 1, 4);
        let snap = s.snapshot();
        assert_eq!(snap.sync_points, 2);
        assert_eq!(snap.wire_bytes, 104);
        assert_eq!(snap.staged_copy_bytes, 4);
        assert_eq!(snap.allreduces, 1);
        assert_eq!(snap.broadcasts, 1);
    }

    #[test]
    fn delta() {
        let s = CommStats::default();
        s.record_collective(CollectiveKind::Allreduce, 10, 1, 0);
        let a = s.snapshot();
        s.record_collective(CollectiveKind::Allreduce, 30, 1, 0);
        let b = s.snapshot();
        let d = b.since(&a);
        assert_eq!(d.wire_bytes, 30);
        assert_eq!(d.allreduces, 1);
    }
}
