//! Communicator groups: the engine-facing collective API.
//!
//! A [`CommGroup`] builds one [`Communicator`] per rank; each communicator
//! is moved into its rank's thread (they are `Send` but deliberately not
//! `Clone`/`Sync`).  In-process groups carry both data paths — the
//! zero-copy arena and the staged ring — so the engine can flip §2.3 on
//! and off at runtime.  TCP groups only have the ring (there is no shared
//! memory across processes), matching oneCCL's transport split.

use std::sync::Arc;

use anyhow::Result;

use super::arena::{ArenaHandle, ArenaShared};
use super::ring;
use super::stats::CommStats;
use super::transport::{InProcTransport, PtpTransport};
use super::ReduceOp;

/// Payloads at or below this take the direct all-exchange allreduce;
/// larger ones take the ring (bandwidth-optimal).  Crossover measured on
/// this testbed with `ccl_micro` (direct wins ≤ ~16 KiB at world ≤ 8).
pub const ALLREDUCE_DIRECT_MAX_BYTES: usize = 16 * 1024;

/// Factory for the per-rank communicators of one group.
pub struct CommGroup {
    pub stats: Arc<CommStats>,
    comms: Vec<Communicator>,
}

impl CommGroup {
    /// In-process group: arena + channel mesh.
    /// `arena_capacity` is in f32 elements (the largest single collective).
    pub fn new_inproc(world: usize, arena_capacity: usize) -> CommGroup {
        let stats = Arc::new(CommStats::default());
        let arena = ArenaShared::new(world, arena_capacity);
        let mesh = InProcTransport::mesh(world);
        let comms = mesh
            .into_iter()
            .enumerate()
            .map(|(rank, t)| Communicator {
                rank,
                world,
                transport: Box::new(t),
                arena: Some(ArenaHandle::new(arena.clone(), rank)),
                stats: stats.clone(),
            })
            .collect();
        CommGroup { stats, comms }
    }

    /// Wrap an externally-connected transport (e.g. TCP) into a single
    /// communicator for this process's rank.
    pub fn from_transport(
        transport: Box<dyn PtpTransport>,
        stats: Arc<CommStats>,
    ) -> Communicator {
        Communicator {
            rank: transport.rank(),
            world: transport.world(),
            transport,
            arena: None,
            stats,
        }
    }

    /// Take the per-rank communicators (in rank order).
    pub fn into_communicators(self) -> Vec<Communicator> {
        self.comms
    }
}

/// One rank's endpoint for all collectives.
pub struct Communicator {
    rank: usize,
    world: usize,
    transport: Box<dyn PtpTransport>,
    arena: Option<ArenaHandle>,
    stats: Arc<CommStats>,
}

impl Communicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    pub fn has_arena(&self) -> bool {
        self.arena.is_some()
    }

    /// Zero-copy landing zone for this rank's partial result (§2.3).
    /// Errors when the group has no arena (TCP) — callers fall back to
    /// the staged path.
    pub fn arena_mut(&mut self, n: usize) -> Result<&mut [f32]> {
        match &mut self.arena {
            Some(a) => a.slot_mut(n),
            None => anyhow::bail!("no arena on this transport"),
        }
    }

    /// Read the (reduced) arena contents.
    pub fn arena(&self, n: usize) -> Result<&[f32]> {
        match &self.arena {
            Some(a) => a.slot(n),
            None => anyhow::bail!("no arena on this transport"),
        }
    }

    /// In-place zero-copy allreduce over the arena slots (§2.3 ON).
    pub fn allreduce_arena(&mut self, n: usize, op: ReduceOp) -> Result<()> {
        let stats = self.stats.clone();
        match &mut self.arena {
            Some(a) => a.allreduce_in_place(n, op, &stats),
            None => anyhow::bail!("no arena on this transport"),
        }
    }

    /// Staged allreduce (§2.3 OFF, and the TCP data path).
    ///
    /// Algorithm auto-selection, oneCCL-style: small payloads take the
    /// direct all-exchange (one α per peer), large ones the
    /// bandwidth-optimal ring.  Crossover measured by `cargo bench
    /// --bench ccl_micro` (see DESIGN.md §7 ablations).
    pub fn allreduce_staged(&self, buf: &mut [f32], op: ReduceOp)
                            -> Result<()> {
        if buf.len() * 4 <= ALLREDUCE_DIRECT_MAX_BYTES {
            ring::direct_allreduce(self.transport.as_ref(), buf, op,
                                   &self.stats)
        } else {
            ring::ring_allreduce(self.transport.as_ref(), buf, op,
                                 &self.stats)
        }
    }

    /// Force the ring algorithm (benches pin algorithms explicitly).
    pub fn allreduce_ring(&self, buf: &mut [f32], op: ReduceOp)
                          -> Result<()> {
        ring::ring_allreduce(self.transport.as_ref(), buf, op, &self.stats)
    }

    /// Force the direct algorithm.
    pub fn allreduce_direct(&self, buf: &mut [f32], op: ReduceOp)
                            -> Result<()> {
        ring::direct_allreduce(self.transport.as_ref(), buf, op,
                               &self.stats)
    }

    /// Broadcast raw bytes from `root` (token IDs in §2.1a, or embedding
    /// activations in the baseline).
    pub fn broadcast(&self, buf: &mut Vec<u8>, root: usize) -> Result<()> {
        ring::tree_broadcast(self.transport.as_ref(), buf, root, &self.stats)
    }

    /// Allgather f32 shards in rank order (the full-logit baseline of
    /// §2.1b measures against this).
    pub fn allgather(&self, local: &[f32], out: &mut [f32]) -> Result<()> {
        ring::ring_allgather(self.transport.as_ref(), local, out, &self.stats)
    }

    /// Gather opaque payloads to `root` (the k (value,index) pairs of the
    /// local-top-k reduction, §2.1b).
    pub fn gather(&self, local: &[u8], root: usize)
                  -> Result<Option<Vec<Vec<u8>>>> {
        ring::gather_to_root(self.transport.as_ref(), local, root,
                             &self.stats)
    }

    /// Group barrier (arena groups only; ring groups synchronize through
    /// their collectives).
    pub fn barrier(&self) {
        if let Some(a) = &self.arena {
            a.barrier();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_group<F, R>(world: usize, f: F) -> Vec<R>
    where
        F: Fn(Communicator) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let group = CommGroup::new_inproc(world, 1024);
        let f = Arc::new(f);
        let handles: Vec<_> = group
            .into_communicators()
            .into_iter()
            .map(|c| {
                let f = f.clone();
                std::thread::spawn(move || f(c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn arena_and_staged_agree() {
        let outs = spawn_group(4, |mut c| {
            let r = c.rank();
            let n = 100;
            {
                let slot = c.arena_mut(n).unwrap();
                for (i, v) in slot.iter_mut().enumerate() {
                    *v = (r * n + i) as f32;
                }
            }
            c.allreduce_arena(n, ReduceOp::Sum).unwrap();
            let arena_out = c.arena(n).unwrap().to_vec();

            let mut staged: Vec<f32> =
                (0..n).map(|i| (r * n + i) as f32).collect();
            c.allreduce_staged(&mut staged, ReduceOp::Sum).unwrap();
            (arena_out, staged)
        });
        for (arena_out, staged) in outs {
            assert_eq!(arena_out, staged);
        }
    }

    #[test]
    fn broadcast_token_ids() {
        let outs = spawn_group(3, |c| {
            let mut buf = if c.rank() == 0 {
                vec![42u8, 0, 1, 2]
            } else {
                vec![]
            };
            c.broadcast(&mut buf, 0).unwrap();
            buf
        });
        for out in outs {
            assert_eq!(out, vec![42, 0, 1, 2]);
        }
    }

    #[test]
    fn world_one_collectives_are_noops() {
        let outs = spawn_group(1, |mut c| {
            c.arena_mut(4).unwrap().fill(3.0);
            c.allreduce_arena(4, ReduceOp::Sum).unwrap();
            let a = c.arena(4).unwrap().to_vec();
            let mut b = vec![5.0f32; 4];
            c.allreduce_staged(&mut b, ReduceOp::Sum).unwrap();
            (a, b)
        });
        assert_eq!(outs[0].0, vec![3.0; 4]);
        assert_eq!(outs[0].1, vec![5.0; 4]);
    }
}
