//! Staged (copy-based) collective algorithms over a point-to-point
//! transport: ring allreduce, ring allgather, binomial-tree broadcast,
//! linear gather.
//!
//! These are the §2.3 *baseline*: every hop allocates an owned message
//! (one copy on send, one on receive-apply), exactly the staging traffic
//! the arena path eliminates.  They are also the real data path for the
//! TCP transport, where a shared-memory arena does not exist.

use anyhow::Result;

use super::stats::{CollectiveKind, CommStats};
use super::transport::{bytes_f32, f32_bytes, PtpTransport};
use super::ReduceOp;

/// Element range `[lo, hi)` of rank `r`'s chunk when `n` elements are
/// split as evenly as possible across `world` ranks.
pub fn ring_chunk_range(n: usize, world: usize, r: usize) -> (usize, usize) {
    let base = n / world;
    let rem = n % world;
    let lo = r * base + r.min(rem);
    let size = base + usize::from(r < rem);
    (lo, lo + size)
}

/// Ring allreduce: reduce-scatter then allgather, 2*(W-1) hops.
/// `buf` holds the local contribution on entry, the reduction on exit.
pub fn ring_allreduce(
    t: &dyn PtpTransport,
    buf: &mut [f32],
    op: ReduceOp,
    stats: &CommStats,
) -> Result<()> {
    let world = t.world();
    let rank = t.rank();
    if world == 1 {
        stats.record_collective(CollectiveKind::Allreduce, 0, 0, 0);
        return Ok(());
    }
    let n = buf.len();
    let right = (rank + 1) % world;
    let left = (rank + world - 1) % world;
    let mut wire = 0u64;
    let mut staged = 0u64;
    let mut msgs = 0u64;

    // reduce-scatter: after step s, rank owns the full reduction of chunk
    // (rank + 1) mod world ... converging to chunk (rank+1)%world? —
    // standard schedule: in step s, send chunk (rank - s) and reduce into
    // chunk (rank - s - 1).
    for s in 0..world - 1 {
        let send_c = (rank + world - s) % world;
        let recv_c = (rank + world - s - 1) % world;
        let (slo, shi) = ring_chunk_range(n, world, send_c);
        let (rlo, rhi) = ring_chunk_range(n, world, recv_c);
        t.send(right, tag(0, s), f32_bytes(&buf[slo..shi]))?;
        let incoming = bytes_f32(&t.recv(left, tag(0, s))?);
        for (dst, src) in buf[rlo..rhi].iter_mut().zip(incoming.iter()) {
            *dst = op.apply(*dst, *src);
        }
        wire += ((shi - slo) * 4) as u64;
        // owned message on send + parse on receive = 2 staging copies
        staged += ((shi - slo) * 4 + (rhi - rlo) * 4) as u64;
        msgs += 1;
    }
    // allgather: circulate the reduced chunks.
    for s in 0..world - 1 {
        let send_c = (rank + world + 1 - s) % world;
        let recv_c = (rank + world - s) % world;
        let (slo, shi) = ring_chunk_range(n, world, send_c);
        let (rlo, rhi) = ring_chunk_range(n, world, recv_c);
        t.send(right, tag(1, s), f32_bytes(&buf[slo..shi]))?;
        let incoming = bytes_f32(&t.recv(left, tag(1, s))?);
        buf[rlo..rhi].copy_from_slice(&incoming);
        wire += ((shi - slo) * 4) as u64;
        staged += ((shi - slo) * 4 + (rhi - rlo) * 4) as u64;
        msgs += 1;
    }

    // rank 0 records for the whole group (avoid W-fold double counting);
    // per-rank traffic is symmetric, so scale by world.
    if rank == 0 {
        stats.record_collective(
            CollectiveKind::Allreduce,
            wire * world as u64,
            msgs * world as u64,
            staged * world as u64,
        );
    }
    Ok(())
}

/// Direct (all-exchange) allreduce: every rank sends its full buffer to
/// every other rank and reduces locally in **fixed rank order** — the
/// small-message algorithm (one α per peer, no 2(W−1)-step chain like the
/// ring).  oneCCL makes the same algorithm switch; `ALLREDUCE_DIRECT_MAX`
/// in group.rs holds the crossover.  Deterministic reduction order
/// (rank 0..W) keeps results identical to the arena path.
pub fn direct_allreduce(
    t: &dyn PtpTransport,
    buf: &mut [f32],
    op: ReduceOp,
    stats: &CommStats,
) -> Result<()> {
    let world = t.world();
    let rank = t.rank();
    if world == 1 {
        stats.record_collective(CollectiveKind::Allreduce, 0, 0, 0);
        return Ok(());
    }
    let n = buf.len();
    for peer in 0..world {
        if peer != rank {
            t.send(peer, tag(5, rank), f32_bytes(buf))?;
        }
    }
    // reduce contributions in rank order for determinism
    let mine = buf.to_vec();
    let mut first = true;
    for src in 0..world {
        let contribution;
        let data: &[f32] = if src == rank {
            &mine
        } else {
            contribution = bytes_f32(&t.recv(src, tag(5, src))?);
            &contribution
        };
        if first {
            buf.copy_from_slice(data);
            first = false;
        } else {
            for (dst, v) in buf.iter_mut().zip(data) {
                *dst = op.apply(*dst, *v);
            }
        }
    }
    if rank == 0 {
        let per_rank = ((world - 1) * n * 4) as u64;
        stats.record_collective(
            CollectiveKind::Allreduce,
            per_rank * world as u64,
            (world * (world - 1)) as u64,
            // owned send copies + owned recv parses + the local stage
            (per_rank * 2 + (n * 4) as u64) * world as u64,
        );
    }
    Ok(())
}

/// Binomial-tree broadcast of raw bytes from `root`.
pub fn tree_broadcast(
    t: &dyn PtpTransport,
    buf: &mut Vec<u8>,
    root: usize,
    stats: &CommStats,
) -> Result<()> {
    let world = t.world();
    let rank = t.rank();
    if world == 1 {
        stats.record_collective(CollectiveKind::Broadcast, 0, 0, 0);
        return Ok(());
    }
    let vrank = (rank + world - root) % world;

    // Receive phase: a non-root receives at its lowest set bit `m` from
    // vrank - m (whose lower bits are all zero).
    let mut mask = 1usize;
    while mask < world {
        if vrank & mask != 0 {
            let src = (vrank - mask + root) % world;
            *buf = t.recv(src, tag(2, mask))?;
            break;
        }
        mask <<= 1;
    }
    // Forward phase: from the bit below where we received (for the root:
    // the highest power of two < 2*world) down to 1.
    let mut m = mask >> 1;
    while m >= 1 {
        if vrank + m < world {
            let dst = (vrank + m + root) % world;
            t.send(dst, tag(2, m), buf)?;
        }
        m >>= 1;
    }

    if rank == root {
        // root counts the whole tree: W-1 messages of len bytes
        stats.record_collective(
            CollectiveKind::Broadcast,
            buf.len() as u64 * (world as u64 - 1),
            world as u64 - 1,
            buf.len() as u64 * (world as u64 - 1), // owned msg per hop
        );
    }
    Ok(())
}

/// Ring allgather: each rank contributes `local`; `out` receives all
/// contributions in rank order (`out.len() == world * local.len()`).
pub fn ring_allgather(
    t: &dyn PtpTransport,
    local: &[f32],
    out: &mut [f32],
    stats: &CommStats,
) -> Result<()> {
    let world = t.world();
    let rank = t.rank();
    let n = local.len();
    assert_eq!(out.len(), n * world, "allgather output size");
    out[rank * n..(rank + 1) * n].copy_from_slice(local);
    if world == 1 {
        stats.record_collective(CollectiveKind::Allgather, 0, 0, 0);
        return Ok(());
    }
    let right = (rank + 1) % world;
    let left = (rank + world - 1) % world;
    let mut wire = 0u64;
    let mut staged = 0u64;
    for s in 0..world - 1 {
        let send_c = (rank + world - s) % world;
        let recv_c = (rank + world - s - 1) % world;
        t.send(right, tag(3, s), f32_bytes(&out[send_c * n..(send_c + 1) * n]))?;
        let incoming = bytes_f32(&t.recv(left, tag(3, s))?);
        out[recv_c * n..(recv_c + 1) * n].copy_from_slice(&incoming);
        wire += (n * 4) as u64;
        staged += (2 * n * 4) as u64;
    }
    if rank == 0 {
        stats.record_collective(
            CollectiveKind::Allgather,
            wire * world as u64,
            (world * (world - 1)) as u64,
            staged * world as u64,
        );
    }
    Ok(())
}

/// Linear gather of per-rank byte payloads to `root`.  Returns
/// `Some(payloads)` (rank-ordered) on the root, `None` elsewhere.
pub fn gather_to_root(
    t: &dyn PtpTransport,
    local: &[u8],
    root: usize,
    stats: &CommStats,
) -> Result<Option<Vec<Vec<u8>>>> {
    let world = t.world();
    let rank = t.rank();
    if rank != root {
        t.send(root, tag(4, rank), local)?;
        return Ok(None);
    }
    let mut out = Vec::with_capacity(world);
    let mut wire = 0u64;
    for src in 0..world {
        if src == root {
            out.push(local.to_vec());
        } else {
            let data = t.recv(src, tag(4, src))?;
            wire += data.len() as u64;
            out.push(data);
        }
    }
    stats.record_collective(
        CollectiveKind::Gather,
        wire,
        world as u64 - 1,
        wire, // each message is an owned copy
    );
    Ok(Some(out))
}

#[inline]
fn tag(kind: u32, step: usize) -> u32 {
    kind * 1000 + step as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccl::transport::InProcTransport;
    use std::sync::Arc;

    fn run_world<F, R>(world: usize, f: F) -> Vec<R>
    where
        F: Fn(usize, InProcTransport, Arc<CommStats>) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let stats = Arc::new(CommStats::default());
        let mesh = InProcTransport::mesh(world);
        let f = Arc::new(f);
        let handles: Vec<_> = mesh
            .into_iter()
            .enumerate()
            .map(|(r, t)| {
                let f = f.clone();
                let stats = stats.clone();
                std::thread::spawn(move || f(r, t, stats))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn chunk_ranges_partition() {
        for n in [0usize, 1, 5, 16, 33] {
            for world in [1usize, 2, 3, 4, 8] {
                let mut covered = 0;
                for r in 0..world {
                    let (lo, hi) = ring_chunk_range(n, world, r);
                    assert_eq!(lo, covered);
                    covered = hi;
                }
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn allreduce_sums() {
        for world in [1usize, 2, 3, 4] {
            let outs = run_world(world, move |r, t, stats| {
                let mut buf: Vec<f32> =
                    (0..10).map(|i| (r * 10 + i) as f32).collect();
                ring_allreduce(&t, &mut buf, ReduceOp::Sum, &stats).unwrap();
                buf
            });
            let expect: Vec<f32> = (0..10)
                .map(|i| {
                    (0..world).map(|r| (r * 10 + i) as f32).sum::<f32>()
                })
                .collect();
            for out in outs {
                assert_eq!(out, expect, "world={world}");
            }
        }
    }

    #[test]
    fn allreduce_max() {
        let outs = run_world(3, |r, t, stats| {
            let mut buf = vec![r as f32, -(r as f32)];
            ring_allreduce(&t, &mut buf, ReduceOp::Max, &stats).unwrap();
            buf
        });
        for out in outs {
            assert_eq!(out, vec![2.0, 0.0]);
        }
    }

    #[test]
    fn direct_allreduce_sums_any_world() {
        for world in [1usize, 2, 3, 4, 8] {
            let outs = run_world(world, move |r, t, stats| {
                let mut buf: Vec<f32> =
                    (0..7).map(|i| (r * 7 + i) as f32).collect();
                direct_allreduce(&t, &mut buf, ReduceOp::Sum, &stats)
                    .unwrap();
                buf
            });
            let expect: Vec<f32> = (0..7)
                .map(|i| (0..world).map(|r| (r * 7 + i) as f32).sum())
                .collect();
            for out in outs {
                assert_eq!(out, expect, "world={world}");
            }
        }
    }

    #[test]
    fn direct_matches_ring_to_tolerance() {
        let outs = run_world(4, |r, t, stats| {
            let mut a: Vec<f32> =
                (0..33).map(|i| (r as f32 + 1.0) * 0.1 * i as f32).collect();
            let mut b = a.clone();
            direct_allreduce(&t, &mut a, ReduceOp::Sum, &stats).unwrap();
            ring_allreduce(&t, &mut b, ReduceOp::Sum, &stats).unwrap();
            (a, b)
        });
        for (a, b) in outs {
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-4 * y.abs().max(1.0));
            }
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for world in [1usize, 2, 3, 4, 5, 8] {
            for root in 0..world {
                let outs = run_world(world, move |r, t, stats| {
                    let mut buf = if r == root {
                        vec![1, 2, 3, root as u8]
                    } else {
                        vec![]
                    };
                    tree_broadcast(&t, &mut buf, root, &stats).unwrap();
                    buf
                });
                for out in outs {
                    assert_eq!(out, vec![1, 2, 3, root as u8],
                               "world={world} root={root}");
                }
            }
        }
    }

    #[test]
    fn allgather_orders_by_rank() {
        for world in [1usize, 2, 4] {
            let outs = run_world(world, move |r, t, stats| {
                let local = vec![r as f32; 3];
                let mut out = vec![0.0; 3 * world];
                ring_allgather(&t, &local, &mut out, &stats).unwrap();
                out
            });
            let expect: Vec<f32> = (0..world)
                .flat_map(|r| vec![r as f32; 3])
                .collect();
            for out in outs {
                assert_eq!(out, expect);
            }
        }
    }

    #[test]
    fn gather_collects_on_root_only() {
        let outs = run_world(3, |r, t, stats| {
            gather_to_root(&t, &[r as u8; 2], 1, &stats).unwrap()
        });
        assert!(outs[0].is_none());
        assert!(outs[2].is_none());
        let got = outs[1].as_ref().unwrap();
        assert_eq!(got[0], vec![0, 0]);
        assert_eq!(got[1], vec![1, 1]);
        assert_eq!(got[2], vec![2, 2]);
    }

    #[test]
    fn allreduce_counts_staged_copies() {
        let stats_out = run_world(2, |_r, t, stats| {
            let mut buf = vec![1.0f32; 8];
            ring_allreduce(&t, &mut buf, ReduceOp::Sum, &stats).unwrap();
            stats.snapshot()
        });
        let snap = stats_out[0];
        assert!(snap.staged_copy_bytes > 0);
        assert!(snap.wire_bytes > 0);
        assert_eq!(snap.allreduces, 1);
    }
}
