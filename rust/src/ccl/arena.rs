//! Zero-copy shared-memory arena — the §2.3 optimization.
//!
//! One f32 slot per rank.  The compute module writes its partial result
//! *directly* into its own slot (e.g. `PjRtBuffer::copy_raw_to_host_sync`
//! straight off the device buffer), and the allreduce then runs **in
//! place** over the slots: each rank reduces its element chunk across all
//! slots and writes the result back into every slot.  No message
//! allocation, no pack/unpack staging — the copies the staged ring pays
//! are simply gone.
//!
//! ## Safety protocol
//!
//! Slot `r` is written only by rank `r` outside collectives (each
//! [`super::Communicator`] is move-only and owned by exactly one rank
//! thread).  During `allreduce_in_place`, barriers delimit the exchange
//! phase, and inside it each rank reads/writes only its own disjoint
//! *element chunk* of every slot, so no byte is ever written concurrently
//! with another access.  The two barriers provide the happens-before
//! edges for cross-thread visibility.

use std::cell::UnsafeCell;
use std::sync::{Arc, Barrier};

use anyhow::{bail, Result};

use super::ring::ring_chunk_range;
use super::stats::{CollectiveKind, CommStats};
use super::ReduceOp;

/// Slot sized at construction; fixed capacity so no reallocation can
/// move the storage while other ranks hold raw pointers to it.
struct Slot {
    data: UnsafeCell<Box<[f32]>>,
}

// Access is coordinated by the protocol above.
unsafe impl Sync for Slot {}

pub(super) struct ArenaShared {
    slots: Vec<Slot>,
    barrier: Barrier,
    capacity: usize,
    world: usize,
}

impl ArenaShared {
    pub(super) fn new(world: usize, capacity: usize) -> Arc<Self> {
        Arc::new(ArenaShared {
            slots: (0..world)
                .map(|_| Slot {
                    data: UnsafeCell::new(
                        vec![0.0f32; capacity].into_boxed_slice(),
                    ),
                })
                .collect(),
            barrier: Barrier::new(world),
            capacity,
            world,
        })
    }
}

/// Per-rank handle to the arena (owned by that rank's thread).
pub struct ArenaHandle {
    shared: Arc<ArenaShared>,
    rank: usize,
    /// reusable chunk scratch, so steady-state allreduces allocate nothing
    scratch: Vec<f32>,
}

impl ArenaHandle {
    pub(super) fn new(shared: Arc<ArenaShared>, rank: usize) -> Self {
        ArenaHandle { shared, rank, scratch: Vec::new() }
    }

    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Mutable view of the first `n` elements of this rank's slot — the
    /// zero-copy landing zone for compute results.
    ///
    /// Must not be held across a collective call (the borrow rules
    /// enforce this: `allreduce_in_place` takes `&mut self`).
    pub fn slot_mut(&mut self, n: usize) -> Result<&mut [f32]> {
        if n > self.shared.capacity {
            bail!("arena request {n} exceeds capacity {}",
                  self.shared.capacity);
        }
        let slot = &self.shared.slots[self.rank];
        // Sole writer of this slot outside collectives (see protocol).
        let slice: &mut [f32] = unsafe { &mut **slot.data.get() };
        Ok(&mut slice[..n])
    }

    /// Read-only view of this rank's slot (e.g. after an allreduce the
    /// slot holds the full reduction).
    pub fn slot(&self, n: usize) -> Result<&[f32]> {
        if n > self.shared.capacity {
            bail!("arena request {n} exceeds capacity {}",
                  self.shared.capacity);
        }
        let slot = &self.shared.slots[self.rank];
        let slice: &[f32] = unsafe { &**slot.data.get() };
        Ok(&slice[..n])
    }

    /// In-place allreduce over the first `n` elements of all slots.
    /// On return every slot holds the element-wise reduction.
    ///
    /// Collective: all ranks must call with the same `n` and `op`.
    pub fn allreduce_in_place(
        &mut self,
        n: usize,
        op: ReduceOp,
        stats: &CommStats,
    ) -> Result<()> {
        let world = self.shared.world;
        if n > self.shared.capacity {
            bail!("arena allreduce {n} exceeds capacity {}",
                  self.shared.capacity);
        }
        if world == 1 {
            stats.record_collective(CollectiveKind::Allreduce, 0, 0, 0);
            return Ok(());
        }
        // Phase boundary: all ranks' slots are fully written.
        self.shared.barrier.wait();

        let (lo, hi) = ring_chunk_range(n, world, self.rank);
        let chunk = hi - lo;
        self.scratch.clear();
        self.scratch.resize(chunk, 0.0);

        unsafe {
            // accumulate chunk [lo, hi) across all slots
            for s in 0..world {
                let src: &[f32] =
                    &(&**self.shared.slots[s].data.get())[lo..hi];
                if s == 0 {
                    self.scratch.copy_from_slice(src);
                } else {
                    for (acc, v) in self.scratch.iter_mut().zip(src) {
                        *acc = op.apply(*acc, *v);
                    }
                }
            }
            // write the reduced chunk back into every slot; element range
            // [lo, hi) is touched only by this rank.
            for s in 0..world {
                let dst: &mut [f32] = &mut (&mut **self.shared.slots[s]
                    .data
                    .get())[lo..hi];
                dst.copy_from_slice(&self.scratch);
            }
        }

        // Phase boundary: all chunks written before anyone reads results.
        self.shared.barrier.wait();

        if self.rank == 0 {
            // logical wire traffic ≈ ring equivalent: each rank reads
            // (W-1) foreign chunks and writes (W-1) foreign chunks.
            let per_rank = 2 * (world - 1) * chunk * 4;
            stats.record_collective(
                CollectiveKind::Allreduce,
                (per_rank * world) as u64,
                (2 * world * (world - 1)) as u64,
                0, // the point: zero staged copies
            );
        }
        Ok(())
    }

    /// Barrier over the group (used by the engine for phase alignment).
    pub fn barrier(&self) {
        if self.shared.world > 1 {
            self.shared.barrier.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_arena<F, R>(world: usize, capacity: usize, f: F) -> Vec<R>
    where
        F: Fn(usize, ArenaHandle, Arc<CommStats>) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let shared = ArenaShared::new(world, capacity);
        let stats = Arc::new(CommStats::default());
        let f = Arc::new(f);
        let handles: Vec<_> = (0..world)
            .map(|r| {
                let h = ArenaHandle::new(shared.clone(), r);
                let f = f.clone();
                let stats = stats.clone();
                std::thread::spawn(move || f(r, h, stats))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_sums_across_ranks() {
        for world in [1usize, 2, 3, 4, 8] {
            let n = 37; // deliberately not divisible by world
            let outs = run_arena(world, 64, move |r, mut h, stats| {
                {
                    let slot = h.slot_mut(n).unwrap();
                    for (i, v) in slot.iter_mut().enumerate() {
                        *v = (r + 1) as f32 * i as f32;
                    }
                }
                h.allreduce_in_place(n, ReduceOp::Sum, &stats).unwrap();
                h.slot(n).unwrap().to_vec()
            });
            let tot: f32 = (1..=world).map(|r| r as f32).sum();
            for out in outs {
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, tot * i as f32, "world={world} i={i}");
                }
            }
        }
    }

    #[test]
    fn allreduce_max() {
        let outs = run_arena(4, 8, |r, mut h, stats| {
            h.slot_mut(4).unwrap().copy_from_slice(&[
                r as f32,
                -(r as f32),
                1.0,
                r as f32 * 10.0,
            ]);
            h.allreduce_in_place(4, ReduceOp::Max, &stats).unwrap();
            h.slot(4).unwrap().to_vec()
        });
        for out in outs {
            assert_eq!(out, vec![3.0, 0.0, 1.0, 30.0]);
        }
    }

    #[test]
    fn zero_staged_copies() {
        let outs = run_arena(2, 16, |_r, mut h, stats| {
            h.slot_mut(16).unwrap().fill(1.0);
            h.allreduce_in_place(16, ReduceOp::Sum, &stats).unwrap();
            stats.snapshot()
        });
        assert_eq!(outs[0].staged_copy_bytes, 0);
        assert!(outs[0].wire_bytes > 0);
    }

    #[test]
    fn capacity_enforced() {
        let outs = run_arena(1, 8, |_r, mut h, _stats| {
            h.slot_mut(9).is_err()
        });
        assert!(outs[0]);
    }

    #[test]
    fn repeated_allreduces_reuse_slots() {
        let outs = run_arena(2, 8, |r, mut h, stats| {
            let mut results = vec![];
            for round in 0..3 {
                h.slot_mut(4)
                    .unwrap()
                    .fill((r + round) as f32);
                h.allreduce_in_place(4, ReduceOp::Sum, &stats).unwrap();
                results.push(h.slot(4).unwrap()[0]);
            }
            results
        });
        // round i: (0+i) + (1+i) = 1 + 2i
        assert_eq!(outs[0], vec![1.0, 3.0, 5.0]);
        assert_eq!(outs[1], vec![1.0, 3.0, 5.0]);
    }
}
