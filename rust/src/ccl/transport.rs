//! Point-to-point transports underlying the staged (ring) collectives.
//!
//! * [`InProcTransport`] — mpsc channels between rank threads in one
//!   process; models oneCCL's same-node path for the staged baseline
//!   (every message is an owned, copied `Vec`).
//! * [`TcpTransport`] — real sockets, one stream per directed peer pair,
//!   for genuine multi-process runs: the rank mesh of `xeonserve worker`
//!   processes (see `crate::launch` and `examples/multiproc_tcp.rs`).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

/// Timeout for blocking receives; converts SPMD divergence bugs
/// (mismatched collective schedules) into errors instead of deadlocks.
pub const RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// A tagged point-to-point message transport between `world` ranks.
///
/// All staged collectives ([`crate::ccl::Communicator`]'s ring/direct
/// allreduce, tree broadcast, gathers) are built from these two
/// primitives, so a new fabric only has to implement `send`/`recv`.
///
/// # Example
///
/// ```
/// use xeonserve::ccl::{InProcTransport, PtpTransport};
///
/// // a 2-rank in-process mesh; rank 1 sends, rank 0 receives
/// let mut mesh = InProcTransport::mesh(2);
/// let r1 = mesh.pop().unwrap();
/// let r0 = mesh.pop().unwrap();
/// let h = std::thread::spawn(move || r1.send(0, 7, b"hi").unwrap());
/// assert_eq!(r0.recv(1, 7).unwrap(), b"hi".to_vec());
/// h.join().unwrap();
/// ```
pub trait PtpTransport: Send {
    fn world(&self) -> usize;
    fn rank(&self) -> usize;
    /// Send `data` to rank `to`. `tag` disambiguates concurrent patterns.
    fn send(&self, to: usize, tag: u32, data: &[u8]) -> Result<()>;
    /// Blocking receive of the next message from rank `from`;
    /// the received tag must equal `tag`.
    fn recv(&self, from: usize, tag: u32) -> Result<Vec<u8>>;
}

type Msg = (u32, Vec<u8>);

/// In-process transport: one mpsc channel per directed rank pair.
pub struct InProcTransport {
    world: usize,
    rank: usize,
    /// senders\[dst\]: this rank -> dst
    senders: Vec<Sender<Msg>>,
    /// receivers\[src\]: src -> this rank
    receivers: Vec<Mutex<Receiver<Msg>>>,
}

impl InProcTransport {
    /// Build the full `world`-sized mesh; returns one transport per rank.
    pub fn mesh(world: usize) -> Vec<InProcTransport> {
        // chan[src][dst]
        let mut txs: Vec<Vec<Option<Sender<Msg>>>> = Vec::new();
        let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> = Vec::new();
        for _ in 0..world {
            txs.push((0..world).map(|_| None).collect());
            rxs.push((0..world).map(|_| None).collect());
        }
        for src in 0..world {
            for dst in 0..world {
                let (tx, rx) = std::sync::mpsc::channel();
                txs[src][dst] = Some(tx);
                rxs[src][dst] = Some(rx);
            }
        }
        let mut out = Vec::with_capacity(world);
        for rank in 0..world {
            let senders =
                txs[rank].iter_mut().map(|t| t.take().unwrap()).collect();
            let receivers = (0..world)
                .map(|src| Mutex::new(rxs[src][rank].take().unwrap()))
                .collect();
            out.push(InProcTransport { world, rank, senders, receivers });
        }
        out
    }
}

impl PtpTransport for InProcTransport {
    fn world(&self) -> usize {
        self.world
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&self, to: usize, tag: u32, data: &[u8]) -> Result<()> {
        // The copy here is the point: the staged baseline pays an owned
        // allocation + memcpy per message, like a send into a comm buffer.
        self.senders[to]
            .send((tag, data.to_vec()))
            .map_err(|_| anyhow!("rank {to} hung up"))
    }

    fn recv(&self, from: usize, tag: u32) -> Result<Vec<u8>> {
        let rx = self.receivers[from].lock().unwrap();
        let (got_tag, data) = rx
            .recv_timeout(RECV_TIMEOUT)
            .with_context(|| format!("recv from {from} tag {tag} timed out"))?;
        if got_tag != tag {
            bail!("tag mismatch from {from}: got {got_tag}, want {tag}");
        }
        Ok(data)
    }
}

/// TCP transport: rank 0 listens and the mesh bootstraps through it.
///
/// Frame format: `[tag: u32 LE] [len: u32 LE] [payload]`.
///
/// Every stream carries a receive timeout (default [`RECV_TIMEOUT`]) so
/// a peer process that dies mid-collective turns into an error on the
/// survivors instead of a hang; the launch control plane (see
/// `crate::launch`) detects the death faster via heartbeats, and this
/// timeout is the backstop that unblocks ranks already inside a
/// collective.
pub struct TcpTransport {
    world: usize,
    rank: usize,
    streams: HashMap<usize, Mutex<TcpStream>>,
    recv_timeout: Option<Duration>,
}

impl TcpTransport {
    /// Connect the full mesh. Every rank calls this with the same
    /// `base_port`; rank pairs (a < b) use port `base_port + a*world + b`
    /// with `a` listening. Suitable for localhost/multi-process runs.
    pub fn connect_mesh(world: usize, rank: usize, host: &str,
                        base_port: u16) -> Result<TcpTransport> {
        let mut streams = HashMap::new();
        for peer in 0..world {
            if peer == rank {
                continue;
            }
            let (a, b) = (rank.min(peer), rank.max(peer));
            let port = base_port + (a * world + b) as u16;
            let stream = if rank == a {
                // accept with a deadline: if the peer dies before ever
                // connecting, bring-up must error out, not hang forever
                let listener = TcpListener::bind((host, port))
                    .with_context(|| format!("bind {host}:{port}"))?;
                listener.set_nonblocking(true)?;
                let deadline = std::time::Instant::now() + RECV_TIMEOUT;
                let s = loop {
                    match listener.accept() {
                        Ok((s, _)) => break s,
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            if std::time::Instant::now() > deadline {
                                bail!(
                                    "rank {peer} never connected \
                                     {host}:{port} within {RECV_TIMEOUT:?}"
                                );
                            }
                            std::thread::sleep(Duration::from_millis(50));
                        }
                        Err(e) => {
                            return Err(e).with_context(|| {
                                format!("accept on {host}:{port}")
                            })
                        }
                    }
                };
                s.set_nonblocking(false)?;
                s
            } else {
                // retry while the peer's listener comes up
                let mut last = None;
                let mut s = None;
                for _ in 0..600 {
                    match TcpStream::connect((host, port)) {
                        Ok(ok) => {
                            s = Some(ok);
                            break;
                        }
                        Err(e) => {
                            last = Some(e);
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    }
                }
                s.ok_or_else(|| {
                    anyhow!("connect {host}:{port} failed: {last:?}")
                })?
            };
            stream.set_nodelay(true)?;
            streams.insert(peer, Mutex::new(stream));
        }
        let t = TcpTransport {
            world,
            rank,
            streams,
            recv_timeout: Some(RECV_TIMEOUT),
        };
        t.apply_recv_timeout()?;
        Ok(t)
    }

    /// Override the receive timeout on every peer stream (`None`
    /// blocks forever).  Tests use short timeouts to exercise the
    /// dead-peer path quickly; production keeps [`RECV_TIMEOUT`].
    pub fn set_recv_timeout(&mut self, timeout: Option<Duration>)
                            -> Result<()> {
        self.recv_timeout = timeout;
        self.apply_recv_timeout()
    }

    fn apply_recv_timeout(&self) -> Result<()> {
        for s in self.streams.values() {
            s.lock().unwrap().set_read_timeout(self.recv_timeout)?;
        }
        Ok(())
    }
}

impl PtpTransport for TcpTransport {
    fn world(&self) -> usize {
        self.world
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&self, to: usize, tag: u32, data: &[u8]) -> Result<()> {
        let mut s = self.streams[&to].lock().unwrap();
        s.write_all(&tag.to_le_bytes())?;
        s.write_all(&(data.len() as u32).to_le_bytes())?;
        s.write_all(data)?;
        Ok(())
    }

    fn recv(&self, from: usize, tag: u32) -> Result<Vec<u8>> {
        let classify = |e: std::io::Error| -> anyhow::Error {
            match e.kind() {
                // SO_RCVTIMEO expiry surfaces as WouldBlock (unix) or
                // TimedOut (windows): the peer is silent, likely dead or
                // diverged from the SPMD collective schedule.
                std::io::ErrorKind::WouldBlock
                | std::io::ErrorKind::TimedOut => anyhow!(
                    "recv from rank {from} tag {tag} timed out after \
                     {:?} (peer dead or SPMD schedule mismatch)",
                    self.recv_timeout
                ),
                // EOF: the peer closed its end — it exited or was killed.
                std::io::ErrorKind::UnexpectedEof => anyhow!(
                    "rank {from} hung up mid-collective (peer process \
                     exited or was killed)"
                ),
                _ => anyhow::Error::new(e)
                    .context(format!("recv from rank {from} tag {tag}")),
            }
        };
        let mut s = self.streams[&from].lock().unwrap();
        let mut hdr = [0u8; 8];
        s.read_exact(&mut hdr).map_err(classify)?;
        let got_tag = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        let len = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
        if got_tag != tag {
            bail!("tcp tag mismatch from {from}: got {got_tag}, want {tag}");
        }
        let mut data = vec![0u8; len];
        s.read_exact(&mut data).map_err(classify)?;
        Ok(data)
    }
}

/// Reinterpret f32 slice as bytes (little-endian platforms).
pub fn f32_bytes(data: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   std::mem::size_of_val(data))
    }
}

/// Parse bytes back into f32s.
pub fn bytes_f32(data: &[u8]) -> Vec<f32> {
    data.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip() {
        let mut mesh = InProcTransport::mesh(2);
        let t1 = mesh.pop().unwrap();
        let t0 = mesh.pop().unwrap();
        let h = std::thread::spawn(move || {
            t1.send(0, 7, &[1, 2, 3]).unwrap();
            t1.recv(0, 8).unwrap()
        });
        assert_eq!(t0.recv(1, 7).unwrap(), vec![1, 2, 3]);
        t0.send(1, 8, &[9]).unwrap();
        assert_eq!(h.join().unwrap(), vec![9]);
    }

    #[test]
    fn inproc_tag_mismatch_errors() {
        let mut mesh = InProcTransport::mesh(2);
        let t1 = mesh.pop().unwrap();
        let t0 = mesh.pop().unwrap();
        t0.send(1, 1, &[0]).unwrap();
        assert!(t1.recv(0, 2).is_err());
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_f32(f32_bytes(&xs)), xs);
    }

    #[test]
    fn tcp_mesh_roundtrip() {
        let h = std::thread::spawn(|| {
            let t = TcpTransport::connect_mesh(2, 1, "127.0.0.1", 39310)
                .unwrap();
            t.send(0, 3, &[5, 6]).unwrap();
            t.recv(0, 4).unwrap()
        });
        let t = TcpTransport::connect_mesh(2, 0, "127.0.0.1", 39310).unwrap();
        assert_eq!(t.recv(1, 3).unwrap(), vec![5, 6]);
        t.send(1, 4, &[7]).unwrap();
        assert_eq!(h.join().unwrap(), vec![7]);
    }

    #[test]
    fn tcp_recv_errors_on_dropped_peer() {
        // rank 1 connects the mesh and immediately exits; rank 0's recv
        // must fail promptly (EOF) instead of hanging.
        let h = std::thread::spawn(|| {
            let t = TcpTransport::connect_mesh(2, 1, "127.0.0.1", 39320)
                .unwrap();
            drop(t); // peer process "dies"
        });
        let t = TcpTransport::connect_mesh(2, 0, "127.0.0.1", 39320).unwrap();
        h.join().unwrap();
        let t0 = std::time::Instant::now();
        let err = t.recv(1, 9).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(err.to_string().contains("hung up"),
                "unexpected error: {err:#}");
    }

    #[test]
    fn tcp_recv_times_out_on_silent_peer() {
        // peer is alive but never sends (SPMD divergence): recv must
        // return the timeout error once the configured deadline passes.
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let h = std::thread::spawn(move || {
            let t = TcpTransport::connect_mesh(2, 1, "127.0.0.1", 39330)
                .unwrap();
            // hold the connection open, silently, until the test is done
            let _ = done_rx.recv();
            drop(t);
        });
        let mut t =
            TcpTransport::connect_mesh(2, 0, "127.0.0.1", 39330).unwrap();
        t.set_recv_timeout(Some(Duration::from_millis(200))).unwrap();
        let t0 = std::time::Instant::now();
        let err = t.recv(1, 9).unwrap_err();
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(150), "waited {waited:?}");
        assert!(waited < Duration::from_secs(10), "waited {waited:?}");
        assert!(err.to_string().contains("timed out"),
                "unexpected error: {err:#}");
        done_tx.send(()).unwrap();
        h.join().unwrap();
    }
}
