//! Analytic wire-cost model for the *simulated* cross-socket cluster.
//!
//! The testbed runs all ranks on one CPU, so measured wall-clock contains
//! no real cross-socket latency.  The engine therefore also reports a
//! simulated per-step latency: max-over-ranks compute time plus this
//! model's cost for every collective the step issued (an α/β model — per-
//! message latency α, per-byte cost 1/B — the standard first-order model
//! for collectives, calibrated to UPI-class links in configs/*.toml).

/// α/β link model.
#[derive(Clone, Copy, Debug)]
pub struct WireModel {
    /// per-message latency, microseconds (link + software stack)
    pub alpha_us: f64,
    /// link bandwidth, GB/s
    pub beta_gbps: f64,
}

impl Default for WireModel {
    fn default() -> Self {
        // UPI-class socket interconnect: ~1.1 µs one-way + ~20 GB/s
        WireModel { alpha_us: 1.1, beta_gbps: 20.0 }
    }
}

impl WireModel {
    fn xfer_us(&self, bytes: u64) -> f64 {
        self.alpha_us + bytes as f64 / (self.beta_gbps * 1e3)
    }

    /// Ring allreduce of `n` payload bytes across `world` ranks:
    /// 2·(W−1) steps, each moving ≈ n/W bytes per rank.
    pub fn allreduce_us(&self, bytes: u64, world: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let w = world as u64;
        let steps = 2 * (world - 1);
        steps as f64 * self.xfer_us(bytes / w)
    }

    /// Binomial-tree broadcast: ⌈log2 W⌉ sequential hops of `bytes`.
    pub fn broadcast_us(&self, bytes: u64, world: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let hops = (usize::BITS - (world - 1).leading_zeros()) as f64;
        hops * self.xfer_us(bytes)
    }

    /// Linear gather to root: W−1 messages serialized at the root.
    pub fn gather_us(&self, bytes_per_rank: u64, world: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        (world - 1) as f64 * self.xfer_us(bytes_per_rank)
    }

    /// Ring allgather: W−1 steps of the per-rank shard.
    pub fn allgather_us(&self, shard_bytes: u64, world: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        (world - 1) as f64 * self.xfer_us(shard_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        let m = WireModel::default();
        assert_eq!(m.allreduce_us(1 << 20, 1), 0.0);
        assert_eq!(m.broadcast_us(64, 1), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        let m = WireModel::default();
        let small = m.allreduce_us(1024, 4);
        let big = m.allreduce_us(1024 * 1024, 4);
        assert!(big > small);
    }

    #[test]
    fn broadcast_is_log_hops() {
        let m = WireModel { alpha_us: 1.0, beta_gbps: 1e9 }; // α-dominated
        assert!((m.broadcast_us(8, 2) - 1.0).abs() < 1e-6);
        assert!((m.broadcast_us(8, 4) - 2.0).abs() < 1e-6);
        assert!((m.broadcast_us(8, 8) - 3.0).abs() < 1e-6);
        assert!((m.broadcast_us(8, 5) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn id_bcast_cheaper_than_embedding_bcast() {
        // §2.1a in model form: 4-byte ids vs hidden*4-byte activations
        let m = WireModel::default();
        let ids = m.broadcast_us(4, 4);
        let emb = m.broadcast_us(8192 * 4, 4);
        assert!(emb > ids * 2.0);
    }

    #[test]
    fn topk_gather_cheaper_than_full_allgather() {
        // §2.1b in model form: k pairs vs vocab-shard logits
        let m = WireModel::default();
        let topk = m.gather_us(50 * 8, 4);
        let full = m.allgather_us(152064 / 4 * 4, 4); // Qwen vocab shard
        // α dominates small messages, so the time ratio is modest even
        // though the byte ratio is ~95×
        assert!(full > topk * 2.0, "full={full} topk={topk}");
    }
}
