//! Serving metrics: latency distributions, throughput, and the per-step
//! timing breakdown the perf pass and the benches consume.
//!
//! [`LatencyStats`] is the single quantile implementation everything
//! else (benchkit cases, the scenario suite, `RunMetrics`) builds on;
//! its percentile definition is pinned in the docs below so
//! `BENCH_*.json` files stay comparable across PRs.

#![warn(missing_docs)]

use std::time::Duration;

/// Reservoir-free latency recorder: keeps every sample (bench-scale runs
/// are small) and reports exact quantiles.
///
/// # Percentile definition
///
/// Quantiles use the *nearest-rank* method on the sorted samples:
/// `quantile(q)` returns the sample at rank `max(1, ceil(q·n))`
/// (1-based), i.e. the smallest sample such that at least `q·n`
/// samples are ≤ it.  This is well-defined for every sample count:
///
/// * `n = 0` → all statistics return 0 (documented sentinel, no panic);
/// * `n = 1` → every quantile is the single sample;
/// * `n = 2` → p50 is the *lower* sample, p95/p99/max the upper;
/// * `q ≤ 0` → the minimum, `q ≥ 1` → the maximum (q is clamped).
///
/// No interpolation is performed: reported values are always real
/// measured samples.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl LatencyStats {
    /// Record one duration sample (microsecond resolution).
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    /// Record one sample already expressed in microseconds.
    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
        self.sorted = false;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// True when no samples have been recorded (all stats read 0).
    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    /// Arithmetic mean in microseconds; 0.0 when empty.
    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64
            / self.samples_us.len() as f64
    }

    /// Exact nearest-rank quantile (see the type docs); `q` is clamped
    /// to `[0, 1]` and the empty recorder returns 0.
    pub fn quantile_us(&mut self, q: f64) -> u64 {
        let n = self.samples_us.len();
        if n == 0 {
            return 0;
        }
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = (n as f64 * q).ceil() as usize;
        self.samples_us[rank.max(1).min(n) - 1]
    }

    /// Median (nearest-rank).
    pub fn p50_us(&mut self) -> u64 {
        self.quantile_us(0.50)
    }

    /// 95th percentile (nearest-rank).
    pub fn p95_us(&mut self) -> u64 {
        self.quantile_us(0.95)
    }

    /// 99th percentile (nearest-rank).
    pub fn p99_us(&mut self) -> u64 {
        self.quantile_us(0.99)
    }

    /// Largest recorded sample.
    pub fn max_us(&mut self) -> u64 {
        self.quantile_us(1.0)
    }
}

/// Per-decode-step timing breakdown (µs).  `wall_*` is measured on this
/// testbed (ranks time-slice one core); `sim_*` is the simulated-cluster
/// view — see DESIGN.md §4 and ccl::wire.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// leader-measured wall time of the whole step
    pub wall_us: u64,
    /// sum over ranks of segment-execute time
    pub compute_total_us: u64,
    /// max over ranks of segment-execute time.  NOTE: on the 1-core
    /// testbed a rank's Instant-measured duration includes time spent
    /// descheduled while other ranks run, so this ≈ wall; the simulated
    /// estimate uses the work-conserving `compute_total / world` instead.
    pub compute_max_us: u64,
    /// tensor-parallel world size (for the equal-split estimate)
    pub world: u64,
    /// host-side collective time actually measured
    pub comm_wall_us: u64,
    /// analytic cross-socket communication cost
    pub comm_sim_us: u64,
    /// sampling epilogue (top-k, merge, sample)
    pub sample_us: u64,
}

impl StepTiming {
    /// Simulated per-token latency on the paper-style cluster:
    /// equal-split compute + analytic wire cost + sampling epilogue.
    pub fn sim_total_us(&self) -> u64 {
        let per_rank = self.compute_total_us / self.world.max(1);
        per_rank + self.comm_sim_us + self.sample_us
    }

    /// Fold one collective round's timing into a multi-round step (a
    /// speculative step runs k draft rounds + one verify + an optional
    /// catch-up — DESIGN.md §15).  Sums are additive; the per-round
    /// maxima add too, because the rounds run sequentially: the step's
    /// critical path is the sum of each round's slowest rank.
    pub fn accumulate_round(&mut self, round: &StepTiming) {
        self.compute_total_us += round.compute_total_us;
        self.compute_max_us += round.compute_max_us;
        self.comm_wall_us += round.comm_wall_us;
    }
}

/// Aggregates step timings for a run; feeds the bench tables and the
/// `BENCH_*.json` scenario records.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// wall-clock latency of each batched decode step
    pub decode_wall: LatencyStats,
    /// simulated-cluster latency of each decode step (DESIGN.md §4)
    pub decode_sim: LatencyStats,
    /// wall-clock latency of each prefill round (≈ time to first token)
    pub prefill_wall: LatencyStats,
    /// decode-stall distribution: the wall-clock gap between
    /// consecutive batched decode rounds while decode lanes stayed
    /// busy.  A whole-shot prefill injected between two decode rounds
    /// shows up here as one large gap; chunked prefill (DESIGN.md §12)
    /// bounds every gap to roughly one chunk's compute.  Recorded by
    /// the engine only while at least one decode-phase request is in
    /// flight, so idle periods never pollute the distribution.
    pub decode_gap: LatencyStats,
    /// tokens emitted (prefill-sampled + decode)
    pub tokens_out: u64,
    /// requests fully retired
    pub requests_done: u64,
    /// admissions that attached to a shared KV prefix (DESIGN.md §13)
    pub prefix_hits: u64,
    /// admissions that found no reusable prefix (includes every
    /// admission under the fcfs scheduler, which never shares)
    pub prefix_misses: u64,
    /// draft tokens proposed by speculative decoding (`spec_k` per
    /// speculating lane per step — DESIGN.md §15)
    pub spec_proposed: u64,
    /// draft proposals the target verified and accepted
    pub spec_accepted: u64,
}

impl RunMetrics {
    /// Record one decode step that produced `new_tokens` tokens.
    pub fn record_decode(&mut self, t: &StepTiming, new_tokens: u64) {
        self.decode_wall.record_us(t.wall_us);
        self.decode_sim.record_us(t.sim_total_us());
        self.tokens_out += new_tokens;
    }

    /// Record one prefill round's wall time.
    pub fn record_prefill(&mut self, wall: Duration) {
        self.prefill_wall.record(wall);
    }

    /// Record one inter-decode-round gap (the decode-stall sample).
    pub fn record_decode_gap(&mut self, gap: Duration) {
        self.decode_gap.record(gap);
    }

    /// Fraction of admissions that reused a shared prefix, in `[0, 1]`
    /// (0.0 when nothing was admitted — the documented sentinel the
    /// bench schema carries for non-sharing rows).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / total as f64
    }

    /// Fraction of draft proposals the target accepted, in `[0, 1]`
    /// (0.0 when nothing was proposed — the documented sentinel the
    /// bench schema carries for spec-off rows, mirroring
    /// [`Self::prefix_hit_rate`]).
    pub fn accept_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    /// tokens/s over a measured span.
    pub fn throughput(&self, span: Duration) -> f64 {
        if span.is_zero() {
            return 0.0;
        }
        self.tokens_out as f64 / span.as_secs_f64()
    }

    /// One-line human summary of the run.
    pub fn report(&mut self) -> String {
        format!(
            "decode wall p50={}us p95={}us mean={:.0}us | sim p50={}us | \
             prefill p50={}us | tokens={} requests={}",
            self.decode_wall.p50_us(),
            self.decode_wall.p95_us(),
            self.decode_wall.mean_us(),
            self.decode_sim.p50_us(),
            self.prefill_wall.p50_us(),
            self.tokens_out,
            self.requests_done,
        )
    }
}

/// Serving-layer counters for the event-driven front end (DESIGN.md
/// §16), kept beside the engine's [`RunMetrics`]: admission sheds,
/// frames written to clients, the deepest any per-connection outbound
/// queue ever got, and the frame-latency distribution (enqueue into a
/// connection's outbound queue → fully written to the socket).  Owned
/// by the single-threaded server front, so plain counters suffice.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// requests refused by the load-shedding admission guard
    pub shed: u64,
    /// reply frames fully written to client sockets
    pub frames_sent: u64,
    /// connections reaped because their outbound queue overflowed
    /// (slow readers — backpressure-then-cancel)
    pub overflow_cancels: u64,
    /// deepest outbound frame queue observed on any connection
    pub frame_queue_peak: usize,
    /// frame delivery latency: outbound-queue enqueue → last byte
    /// written (p99 is the bench headline)
    pub frame_lat: LatencyStats,
}

impl ServeStats {
    /// Note a connection's outbound queue depth after an enqueue.
    pub fn note_queue_depth(&mut self, depth: usize) {
        self.frame_queue_peak = self.frame_queue_peak.max(depth);
    }

    /// Record one fully-written frame and its delivery latency.
    pub fn record_frame(&mut self, lat: Duration) {
        self.frames_sent += 1;
        self.frame_lat.record(lat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_exact() {
        let mut s = LatencyStats::default();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            s.record_us(v);
        }
        assert_eq!(s.p50_us(), 50);
        assert_eq!(s.max_us(), 100);
        assert_eq!(s.count(), 10);
        assert!((s.mean_us() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut s = LatencyStats::default();
        assert_eq!(s.p50_us(), 0);
        assert_eq!(s.p95_us(), 0);
        assert_eq!(s.max_us(), 0);
        assert_eq!(s.mean_us(), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn small_sample_counts_are_well_defined() {
        // n = 1: every quantile is the sample
        let mut s = LatencyStats::default();
        s.record_us(42);
        assert_eq!(s.p50_us(), 42);
        assert_eq!(s.p95_us(), 42);
        assert_eq!(s.p99_us(), 42);
        assert_eq!(s.max_us(), 42);

        // n = 2: nearest-rank picks the lower sample at p50, the
        // upper at p95+ (ranks ceil(0.5·2)=1, ceil(0.95·2)=2)
        let mut s = LatencyStats::default();
        s.record_us(100);
        s.record_us(10);
        assert_eq!(s.p50_us(), 10);
        assert_eq!(s.p95_us(), 100);
        assert_eq!(s.max_us(), 100);

        // n = 3: p50 is the middle sample
        let mut s = LatencyStats::default();
        for v in [30u64, 10, 20] {
            s.record_us(v);
        }
        assert_eq!(s.p50_us(), 20);
        assert_eq!(s.p95_us(), 30);
    }

    #[test]
    fn quantile_q_is_clamped() {
        let mut s = LatencyStats::default();
        for v in [1u64, 2, 3] {
            s.record_us(v);
        }
        assert_eq!(s.quantile_us(-1.0), 1);
        assert_eq!(s.quantile_us(0.0), 1);
        assert_eq!(s.quantile_us(2.0), 3);
    }

    #[test]
    fn sim_total_uses_equal_split_compute() {
        let t = StepTiming {
            wall_us: 1000,
            compute_total_us: 800,
            compute_max_us: 900, // inflated by descheduling: ignored
            world: 4,
            comm_wall_us: 100,
            comm_sim_us: 40,
            sample_us: 10,
        };
        assert_eq!(t.sim_total_us(), 200 + 40 + 10);
    }

    #[test]
    fn decode_gap_is_a_plain_latency_series() {
        let mut m = RunMetrics::default();
        assert!(m.decode_gap.is_empty());
        m.record_decode_gap(Duration::from_micros(100));
        m.record_decode_gap(Duration::from_micros(900));
        assert_eq!(m.decode_gap.count(), 2);
        assert_eq!(m.decode_gap.p99_us(), 900);
    }

    #[test]
    fn prefix_hit_rate_is_a_safe_ratio() {
        let mut m = RunMetrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0, "no admissions → 0.0");
        m.prefix_misses = 3;
        m.prefix_hits = 1;
        assert!((m.prefix_hit_rate() - 0.25).abs() < 1e-12);
        m.prefix_hits = 0;
        assert_eq!(m.prefix_hit_rate(), 0.0);
    }

    #[test]
    fn accept_rate_is_a_safe_ratio() {
        let mut m = RunMetrics::default();
        assert_eq!(m.accept_rate(), 0.0, "no proposals → 0.0");
        m.spec_proposed = 8;
        m.spec_accepted = 2;
        assert!((m.accept_rate() - 0.25).abs() < 1e-12);
        m.spec_accepted = 8;
        assert!((m.accept_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accumulate_round_sums_the_critical_path() {
        let mut step = StepTiming::default();
        let round = StepTiming {
            compute_total_us: 100,
            compute_max_us: 60,
            comm_wall_us: 10,
            ..StepTiming::default()
        };
        step.accumulate_round(&round);
        step.accumulate_round(&round);
        assert_eq!(step.compute_total_us, 200);
        assert_eq!(step.compute_max_us, 120);
        assert_eq!(step.comm_wall_us, 20);
        assert_eq!(step.wall_us, 0, "wall is measured by the caller");
    }

    #[test]
    fn throughput_counts_tokens() {
        let mut m = RunMetrics::default();
        let t = StepTiming::default();
        m.record_decode(&t, 4);
        m.record_decode(&t, 4);
        let tput = m.throughput(Duration::from_secs(2));
        assert!((tput - 4.0).abs() < 1e-9);
    }

    #[test]
    fn serve_stats_track_peaks_and_frame_latency() {
        let mut s = ServeStats::default();
        assert_eq!(s.frame_queue_peak, 0);
        assert_eq!(s.frame_lat.p99_us(), 0, "empty recorder reads 0");
        s.note_queue_depth(3);
        s.note_queue_depth(1); // peak is sticky
        assert_eq!(s.frame_queue_peak, 3);
        s.record_frame(Duration::from_micros(10));
        s.record_frame(Duration::from_micros(90));
        assert_eq!(s.frames_sent, 2);
        assert_eq!(s.frame_lat.p99_us(), 90);
        assert_eq!(s.shed, 0);
        assert_eq!(s.overflow_cancels, 0);
    }
}
