//! xeonserve CLI — the launcher (hand-rolled argument parsing; the
//! offline build environment has no clap).
//!
//! ```text
//! xeonserve serve    [--config FILE] [--addr 127.0.0.1:7070]
//! xeonserve launch   --world N [--config FILE] [--control HOST:PORT]
//!                    [--prompt "hello" [-n 16] | --addr HOST:PORT]
//! xeonserve worker   --rank R --coordinator HOST:PORT
//! xeonserve generate [--config FILE] --prompt "hello" [-n 16]
//! xeonserve bench    [--config FILE] [--model tiny] [--worlds 1,2,4]
//!                    [--json BENCH.json] [--quick true]
//! xeonserve bench    --validate BENCH.json
//! xeonserve bench    [--steps 32] [--prompt-len 8]   (legacy one-shot)
//! xeonserve storm    --addr HOST:PORT [--clients N] [-n N]
//! xeonserve resize   --addr HOST:PORT --world N
//! xeonserve isa      [--check scalar|avx2|avx512|vnni]
//! xeonserve info     [--artifacts artifacts]
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use xeonserve::benchkit::{self, suite};
use xeonserve::config::{EngineConfig, Manifest};
use xeonserve::engine::elastic::ElasticEngine;
use xeonserve::engine::Engine;
use xeonserve::launch::{self, LaunchOptions};
use xeonserve::tokenizer::Tokenizer;
use xeonserve::util::Json;

const USAGE: &str = "\
xeonserve — distributed LLM inference on CPUs (He et al. 2024 reproduction)

USAGE:
  xeonserve serve    [--config FILE] [--addr HOST:PORT]
  xeonserve launch   --world N [--config FILE] [--control HOST:PORT]
                     [--mesh-port PORT] [--spawn-workers true]
                     [--prompt TEXT [-n N] | --addr HOST:PORT]
  xeonserve worker   --rank R --coordinator HOST:PORT
  xeonserve generate [--config FILE] --prompt TEXT [-n N]
  xeonserve bench    [--config FILE] [--model NAME] [--worlds 1,2,4]
                     [--json FILE] [--quick true] [--threads N]
                     [--label NAME]
  xeonserve bench    --validate FILE
  xeonserve bench    [--steps N] [--prompt-len N]   (legacy one-shot)
  xeonserve storm    --addr HOST:PORT [--clients N] [-n N]
  xeonserve resize   --addr HOST:PORT --world N
  xeonserve isa      [--check scalar|avx2|avx512|vnni]
  xeonserve info     [--artifacts DIR]

serve runs every rank as an in-process thread.  launch/worker is the
distributed deployment (DESIGN.md \u{a7}8): the coordinator registers
--world worker processes on the control port, ships them the config,
and then either answers one --prompt and exits, or serves the JSON API
on --addr.  With --spawn-workers true the coordinator forks the
workers itself (single-machine convenience; CI smoke path starts them
explicitly).

bench runs the recording suite (DESIGN.md \u{a7}10-\u{a7}15): the
standard scenarios (single-stream / batched decode, prefill-heavy,
mixed, long-prompt interactive, shared-prefix storm, speculative
decode) per world size, on the blocked kernel plus the scalar
batched-decode baseline, int8 weights+KV decode rows, the
chunked-prefill decode-stall pair, the fcfs-vs-continuous
shared_prefix_storm pair, and the spec-off-vs-spec-on
speculative_decode pair (nano draft, spec_k = 4), and writes the
xeonserve-bench/v1 JSON (--json) that BENCH_*.json files in the repo
are recorded with — every row carries its weight/KV dtype, prefill
chunk size, scheduler, prefix hit rate, spec_k / accept_rate,
instruction tier (isa), and measured resident bytes; batched_decode
additionally records one row per instruction tier the host can run
(DESIGN.md \u{a7}14).
--validate schema-checks such a file and exits; every failure names
the validator rule and row that tripped it.  Serving knobs live in
the TOML: weight_dtype / kv_dtype = \"int8\" (reference backend
only), prefill_chunk = N (0 = whole-prompt; chunked prefill,
reference backend only), scheduler = \"fcfs\" | \"continuous\"
(continuous batching + copy-on-write shared-prefix KV reuse,
reference backend only), and isa = \"auto\" | \"scalar\" | \"avx2\"
| \"avx512\" | \"vnni\" (GEMM instruction tier, reference backend
only; vnni requires weight_dtype = \"int8\" — DESIGN.md \u{a7}14),
and spec_draft = \"off\" | PRESET with spec_k = 1..8 (greedy
speculative decoding with a smaller draft model, reference backend
only, greedy sampling only — DESIGN.md \u{a7}15).  The
serve/launch JSON API streams per-token
reply frames when a request carries \"stream\": true, and
{\"cancel\": id} aborts an in-flight request idempotently.  The
server runs a single-threaded readiness-polling event loop with
load-shedding admission (shed_queue / shed_wait_ms in the TOML —
DESIGN.md \u{a7}16); bench additionally records the
connection_storm serving-front pair (p99 frame latency + shed rate
per scheduler).

storm is the matching external load driver: it opens --clients
concurrent streaming connections (default 256) against a running
serve/launch --addr deployment and prints one JSON summary line —
{\"clients\":N,\"ok\":A,\"shed\":B,\"errors\":C} — where every
client must end in a clean done frame or a shed line for the CI
smoke to pass.

The serving stack is elastic (DESIGN.md \u{a7}17): a worker that
dies mid-decode is detected by heartbeat loss, the fleet is rebuilt,
and every in-flight request replays prompt + emitted tokens onto the
new fleet — streaming clients see a stall, never an error and never
a changed token.  resize drives the same quiesce/reshard/restore
path deliberately: {\"resize\": N} reshards a running deployment to
N ranks with lane KV carried across as world-invariant images, and
{\"stats\": true} reports recoveries / resizes /
recovery_stall_ms / tokens_lost next to the occupancy counters.

Without --config the built-in default is used (tiny model, world=2,
all paper optimizations ON).  See configs/*.toml for presets.";

/// Tiny flag parser: --key value / -k value pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = &argv[i];
            if !k.starts_with('-') {
                bail!("unexpected argument {k:?}\n\n{USAGE}");
            }
            let key = k.trim_start_matches('-').to_string();
            let v = argv
                .get(i + 1)
                .with_context(|| format!("flag {k} needs a value"))?;
            flags.insert(key, v.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

fn load_cfg(args: &Args) -> Result<EngineConfig> {
    match args.get("config") {
        Some(p) => EngineConfig::from_toml_file(p),
        None => Ok(EngineConfig::default()),
    }
}

/// Coordinator body: bring up the worker fleet, then either answer one
/// `--prompt` and exit (the smoke/one-shot mode) or serve the JSON API
/// on `--addr`.
fn run_launch(cfg: EngineConfig, opts: &LaunchOptions, args: &Args)
              -> Result<()> {
    match args.get("prompt") {
        Some(prompt) => {
            let prompt = prompt.to_string();
            let n = args.get_usize("n", 16)?;
            let fleet = launch::coordinate(&cfg, opts)?;
            let mut engine = fleet.into_engine(cfg)?;
            let tok = Tokenizer::byte_level(engine.preset().vocab)?;
            let ids = tok.encode(&prompt);
            let out = engine.generate(&[ids], n)?;
            println!("{}", tok.decode(&out[0]));
            println!("tokens: {:?}", out[0]);
            Ok(())
        }
        None => {
            let addr =
                args.get("addr").unwrap_or("127.0.0.1:7070").to_string();
            let opts = opts.clone();
            let spawn = args.get("spawn-workers") == Some("true");
            xeonserve::server::serve_with(
                move || {
                    let fleet = launch::coordinate(&cfg, &opts)?;
                    let engine = fleet.into_engine(cfg)?;
                    // replacement fleets re-coordinate on fresh port
                    // generations; with --spawn-workers the factory
                    // also re-execs the local worker processes, so a
                    // SIGKILL'd worker is replaced without operator
                    // action (DESIGN.md §17)
                    Ok(ElasticEngine::from_engine(
                        engine,
                        Box::new(launch::RelaunchFactory::for_replacements(
                            opts, spawn)),
                    ))
                },
                &addr,
            )
        }
    }
}

/// `xeonserve resize`: drive a planned live reshard on a running
/// deployment (DESIGN.md §17) by posting `{"resize": N}` to its JSON
/// API and printing the acknowledgement.
fn run_resize(args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let addr = args
        .get("addr")
        .context("resize requires --addr HOST:PORT")?;
    let world = args.get_usize("world", 0)?;
    if world == 0 {
        bail!("resize requires --world N (the new world size)\n\n{USAGE}");
    }
    let mut sock = TcpStream::connect(addr)
        .with_context(|| format!("connecting {addr}"))?;
    writeln!(sock, "{{\"resize\": {world}}}")?;
    let mut line = String::new();
    BufReader::new(sock).read_line(&mut line)?;
    let line = line.trim();
    if line.is_empty() {
        bail!("server closed the connection without answering");
    }
    println!("{line}");
    let j = Json::parse(line).context("unparseable resize reply")?;
    if let Some(e) = j.get("error").and_then(Json::as_str) {
        bail!("resize refused: {e}");
    }
    Ok(())
}

/// `xeonserve isa`: report the host's instruction tiers (DESIGN.md
/// §14).  Bare, it lists every tier with availability and how vnni
/// would run (hardware dpbusd vs. exact emulation); `--check TIER`
/// answers via the exit code — the CI per-ISA test loop gates each
/// `XEONSERVE_FORCE_ISA` leg on it.
fn run_isa(args: &Args) -> Result<()> {
    use xeonserve::backend::simd::{self, Isa};
    if let Some(t) = args.get("check") {
        let isa = Isa::parse(t)?;
        if !simd::available(isa) {
            bail!("isa {isa}: not available on this host");
        }
        println!("isa {isa}: available");
        return Ok(());
    }
    println!("detected best tier: {} (vnni is opt-in — DESIGN.md §14)",
             simd::detect_best());
    for isa in Isa::ALL {
        let note = match isa {
            Isa::Vnni if simd::vnni_hw() => " (hardware dpbusd)",
            Isa::Vnni => " (exact integer emulation)",
            _ => "",
        };
        println!("  {isa}: {}{note}",
                 if simd::available(isa) { "available" }
                 else { "unavailable" });
    }
    Ok(())
}

/// `xeonserve storm`: the external connection-storm driver (DESIGN.md
/// §16).  Opens `--clients` concurrent streaming connections against a
/// running deployment, one request each, and prints a single JSON
/// summary line.  A client counts `ok` on a clean done frame, `shed`
/// on a `{"error": "shed", ...}` refusal, and `errors` otherwise
/// (protocol garbage, premature EOF, timeouts) — the CI smoke greps
/// for `"errors":0`.
fn run_storm_cli(args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    let addr = args
        .get("addr")
        .context("storm requires --addr HOST:PORT")?
        .to_string();
    let clients = args.get_usize("clients", 256)?;
    let n = args.get_usize("n", 4)?;
    let (tx, rx) = std::sync::mpsc::channel::<&'static str>();
    let mut handles = Vec::with_capacity(clients);
    for i in 0..clients {
        let tx = tx.clone();
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let outcome = (|| -> Result<&'static str> {
                let mut sock = TcpStream::connect(&addr)?;
                // a wedged stream must fail the client, not hang the
                // driver: the reaper tests pin liveness server-side,
                // this guards the CI smoke end-to-end
                sock.set_read_timeout(Some(Duration::from_secs(120)))?;
                writeln!(
                    sock,
                    "{{\"prompt\": \"storm client {i}\", \
                     \"max_new_tokens\": {n}, \"stream\": true}}"
                )?;
                let mut rd = BufReader::new(sock);
                let mut line = String::new();
                loop {
                    line.clear();
                    if rd.read_line(&mut line)? == 0 {
                        bail!("eof before a terminal frame");
                    }
                    let j = Json::parse(line.trim())?;
                    if let Some(e) = j.get("error").and_then(Json::as_str)
                    {
                        return Ok(if e == "shed" { "shed" }
                                  else { "error" });
                    }
                    if j.get("done").is_some() {
                        return Ok("ok");
                    }
                    // anything else must be a token frame
                    if j.get("token").is_none() {
                        bail!("unexpected frame {line:?}");
                    }
                }
            })();
            let _ = tx.send(outcome.unwrap_or("error"));
        }));
    }
    drop(tx);
    let (mut ok, mut shed, mut errors) = (0u64, 0u64, 0u64);
    for outcome in rx {
        match outcome {
            "ok" => ok += 1,
            "shed" => shed += 1,
            _ => errors += 1,
        }
    }
    for h in handles {
        let _ = h.join();
    }
    println!("{{\"clients\":{clients},\"ok\":{ok},\"shed\":{shed},\
              \"errors\":{errors}}}");
    Ok(())
}

/// `xeonserve bench`: the recording suite (default), the schema
/// validator (`--validate FILE`), or the legacy one-shot run when
/// `--steps`/`--prompt-len` are given.
fn run_bench(args: &Args) -> Result<()> {
    if let Some(path) = args.get("validate") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing {path}"))?;
        suite::validate_bench(&j)
            .with_context(|| format!("validating {path}"))?;
        let rows = j.get("scenarios").and_then(Json::as_arr)
            .map(|a| a.len()).unwrap_or(0);
        println!("{path}: valid {} ({rows} scenario rows)",
                 suite::SCHEMA);
        return Ok(());
    }

    let mut cfg = load_cfg(args)?;
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(t) = args.get("threads") {
        cfg.threads = t.parse().context("--threads must be an integer")?;
    }

    // legacy one-shot mode: a single engine, one request, raw report
    if args.get("steps").is_some() || args.get("prompt-len").is_some() {
        let steps = args.get_usize("steps", 32)?;
        let prompt_len = args.get_usize("prompt-len", 8)?;
        let mut engine = Engine::new(cfg)?;
        let prompt: Vec<i32> =
            (0..prompt_len as i32).map(|i| i % 200).collect();
        engine.enqueue(prompt, steps);
        engine.run_to_completion()?;
        println!("{}", engine.metrics.report());
        let ms = engine.metrics.decode_wall.mean_us() / 1e3;
        let sim = engine.metrics.decode_sim.mean_us() / 1e3;
        println!(
            "time per output token: {ms:.2} ms/token (wall, 1-core \
             testbed) | {sim:.2} ms/token (simulated cluster)"
        );
        println!("comm stats: {:?}", engine.comm_stats());
        return Ok(());
    }

    let quick = match args.get("quick") {
        None => false,
        Some("true") => true,
        Some("false") => false,
        Some(v) => bail!("--quick takes true|false, got {v:?}"),
    };
    let worlds: Vec<usize> = match args.get("worlds") {
        Some(csv) => csv
            .split(',')
            .map(|w| w.trim().parse::<usize>()
                .with_context(|| format!("bad world {w:?} in --worlds")))
            .collect::<Result<_>>()?,
        None => vec![1, 2, 4],
    };
    eprintln!(
        "bench suite: model={} worlds={worlds:?} quick={quick}",
        cfg.model
    );
    let records = suite::run_matrix(&cfg, &worlds, quick,
                                    |what| eprintln!("  running {what}"))?;
    let cases: Vec<_> =
        records.iter().map(suite::ScenarioRecord::to_case).collect();
    benchkit::report(
        &format!("bench suite — model={} (DESIGN.md §10)", cfg.model),
        &cases,
    );
    // --label names the recording (e.g. "pr3" for a committed
    // BENCH_pr3.json baseline)
    let label = args.get("label").unwrap_or("xeonserve-bench");
    let doc = suite::matrix_to_json(label, &cfg.model, quick, &worlds,
                                    &records);
    for &w in &worlds {
        if let Some(s) = suite::batched_speedup(&doc, w) {
            println!(
                "batched_decode w{w}: blocked(threads>=2) is {s:.2}x \
                 the scalar baseline"
            );
        }
        if let Some(s) = suite::int8_speedup(&doc, w) {
            println!(
                "batched_decode w{w}: int8 weights+KV is {s:.2}x the \
                 f32 blocked row"
            );
        }
        if let Some(s) = suite::chunked_stall_ratio(&doc, w) {
            println!(
                "long_prompt_interactive w{w}: whole-prompt decode-\
                 stall p99 is {s:.2}x the chunked row's (DESIGN.md §12)"
            );
        }
        if let (Some(f), Some(c)) = (suite::storm_row(&doc, w, "fcfs"),
                                     suite::storm_row(&doc, w,
                                                      "continuous"))
        {
            println!(
                "shared_prefix_storm w{w}: continuous ttft {:.2} ms \
                 vs fcfs {:.2} ms, prefix hit rate {:.2} \
                 (DESIGN.md §13)",
                c.0, f.0, c.2
            );
        }
        if let (Some(off), Some(on)) =
            (suite::spec_row(&doc, w, false),
             suite::spec_row(&doc, w, true))
        {
            println!(
                "speculative_decode w{w}: spec-on {:.2} ms/token at \
                 accept rate {:.2} vs spec-off {:.2} ms/token \
                 (DESIGN.md §15)",
                on.0, on.2, off.0
            );
        }
        if let (Some(f), Some(c)) =
            (suite::conn_storm_row(&doc, w, "fcfs"),
             suite::conn_storm_row(&doc, w, "continuous"))
        {
            println!(
                "connection_storm w{w}: fcfs frame p99 {:.0} us at \
                 shed rate {:.2} vs continuous {:.0} us at {:.2} \
                 (DESIGN.md §16)",
                f.0, f.1, c.0, c.1
            );
        }
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, doc.to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;

    match cmd.as_str() {
        "serve" => {
            let cfg = load_cfg(&args)?;
            let addr = args.get("addr").unwrap_or("127.0.0.1:7070");
            xeonserve::server::serve(cfg, addr)
        }
        "launch" => {
            let mut cfg = load_cfg(&args)?;
            let defaults = LaunchOptions::default();
            let world = args.get_usize("world", cfg.world)?;
            cfg.world = world;
            let control_addr = args
                .get("control")
                .unwrap_or(&defaults.control_addr)
                .to_string();
            let mesh_base_port = args
                .get_usize("mesh-port", defaults.mesh_base_port as usize)?
                as u16;
            let opts = LaunchOptions {
                world,
                control_addr,
                mesh_base_port,
                ..defaults
            };
            let spawn = args.get("spawn-workers") == Some("true");
            let mut children = if spawn {
                launch::spawn_local_workers(world, &opts.control_addr)?
            } else {
                Vec::new()
            };
            let result = run_launch(cfg, &opts, &args);
            for (rank, c) in children.iter_mut().enumerate() {
                match c.wait() {
                    Ok(st) if !st.success() => {
                        eprintln!("worker rank {rank} exited: {st}")
                    }
                    Err(e) => eprintln!("worker rank {rank}: wait: {e}"),
                    _ => {}
                }
            }
            result
        }
        "worker" => {
            let rank = args
                .get_usize("rank", usize::MAX)?;
            if rank == usize::MAX {
                bail!("worker requires --rank\n\n{USAGE}");
            }
            let coordinator = args
                .get("coordinator")
                .context("worker requires --coordinator HOST:PORT")?;
            launch::run_worker(rank, coordinator)
        }
        "generate" => {
            let cfg = load_cfg(&args)?;
            let prompt = args
                .get("prompt")
                .context("generate requires --prompt")?
                .to_string();
            let n = args.get_usize("n", 16)?;
            let mut engine = Engine::new(cfg)?;
            let tok = Tokenizer::byte_level(engine.preset().vocab)?;
            let ids = tok.encode(&prompt);
            let out = engine.generate(&[ids], n)?;
            println!("{}", tok.decode(&out[0]));
            println!("tokens: {:?}", out[0]);
            Ok(())
        }
        "bench" => run_bench(&args),
        "storm" => run_storm_cli(&args),
        "resize" => run_resize(&args),
        "isa" => run_isa(&args),
        "info" => {
            let dir =
                PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
            let m = Manifest::load(&dir)?;
            println!("manifest v{} — {} segments", m.version,
                     m.segments.len());
            let mut names: Vec<_> = m.configs.keys().collect();
            names.sort();
            for name in names {
                let p = &m.configs[name];
                println!(
                    "  model {name}: {} layers, hidden {}, vocab {}, \
                     ~{:.0}M params",
                    p.n_layers, p.hidden, p.vocab, p.params as f64 / 1e6
                );
            }
            let mut by_cfg: std::collections::BTreeMap<String, usize> =
                Default::default();
            for s in &m.segments {
                *by_cfg
                    .entry(format!("{} w{} b{}", s.config, s.world, s.batch))
                    .or_default() += 1;
            }
            for (k, v) in by_cfg {
                println!("  {k}: {v} segments");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}
