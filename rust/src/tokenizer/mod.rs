//! Byte-level tokenizer.
//!
//! The reproduction serves synthetic-weight models, so a learned BPE
//! vocabulary would be meaningless; what matters is a *total, lossless*
//! mapping between text and token ids the server can round-trip.  We use
//! byte-level encoding (ids 0..=255 are raw bytes) plus reserved control
//! ids, the same base layer GPT-2-style BPEs bottom out in.  Models with
//! vocab > 256 simply have head room (sampled high ids render as the
//! replacement glyph).

use anyhow::{bail, Result};

pub const BYTE_VOCAB: usize = 256;

/// Reserved ids directly above the byte range.
pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;
pub const N_SPECIAL: usize = 3;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// model vocab size; ids >= vocab never produced by encode
    vocab: usize,
    add_bos: bool,
}

impl Tokenizer {
    /// `vocab` is the model's vocabulary size (>= 256).  BOS is emitted
    /// only when the vocab has room for the special ids.
    pub fn byte_level(vocab: usize) -> Result<Tokenizer> {
        if vocab < BYTE_VOCAB {
            bail!("vocab {vocab} smaller than byte range");
        }
        Ok(Tokenizer {
            vocab,
            add_bos: vocab >= BYTE_VOCAB + N_SPECIAL,
        })
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn eos(&self) -> Option<i32> {
        (self.vocab >= BYTE_VOCAB + N_SPECIAL).then_some(EOS)
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        if self.add_bos {
            ids.push(BOS);
        }
        ids.extend(text.bytes().map(|b| b as i32));
        ids
    }

    /// Lossy decode: byte ids reassemble into UTF-8 (invalid sequences
    /// render U+FFFD); special/out-of-range ids are skipped.
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| (0..BYTE_VOCAB as i32).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let t = Tokenizer::byte_level(32000).unwrap();
        let ids = t.encode("hello");
        assert_eq!(ids[0], BOS);
        assert_eq!(&ids[1..], &[104, 101, 108, 108, 111]);
        assert_eq!(t.decode(&ids), "hello");
    }

    #[test]
    fn utf8_roundtrip() {
        let t = Tokenizer::byte_level(32000).unwrap();
        let s = "héllo → 世界 🚀";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tiny_vocab_has_no_bos() {
        let t = Tokenizer::byte_level(256).unwrap();
        let ids = t.encode("ab");
        assert_eq!(ids, vec![97, 98]);
        assert!(t.eos().is_none());
    }

    #[test]
    fn out_of_range_ids_skipped_in_decode() {
        let t = Tokenizer::byte_level(32000).unwrap();
        assert_eq!(t.decode(&[104, 300, 105, BOS, EOS]), "hi");
    }

    #[test]
    fn sub_byte_vocab_rejected() {
        assert!(Tokenizer::byte_level(100).is_err());
    }

    #[test]
    fn randomized_utf8_roundtrip() {
        // property: decode(encode(s)) == s for arbitrary valid UTF-8
        use crate::util::SplitMix64;
        let t = Tokenizer::byte_level(32000).unwrap();
        let mut rng = SplitMix64::new(0x707);
        for _ in 0..200 {
            let len = rng.next_below(64);
            let s: String = (0..len)
                .map(|_| {
                    char::from_u32((rng.next_u64() % 0x24F) as u32)
                        .unwrap_or('x')
                })
                .collect();
            assert_eq!(t.decode(&t.encode(&s)), s, "failed for {s:?}");
        }
    }

    #[test]
    fn arbitrary_byte_ids_never_panic_decode() {
        use crate::util::SplitMix64;
        let t = Tokenizer::byte_level(32000).unwrap();
        let mut rng = SplitMix64::new(9);
        for _ in 0..100 {
            let ids: Vec<i32> = (0..rng.next_below(32))
                .map(|_| (rng.next_u64() % 40000) as i32 - 100)
                .collect();
            let _ = t.decode(&ids); // must not panic on any input
        }
    }
}
