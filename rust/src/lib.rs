//! # xeonserve
//!
//! Reproduction of *"Distributed Inference Performance Optimization for
//! LLMs on CPUs"* (He et al., Intel, 2024): a tensor-parallel LLM serving
//! runtime whose request path is pure rust, with the model compute AOT-
//! compiled from JAX/Pallas to XLA HLO and executed through PJRT.
//!
//! The paper's three optimizations are first-class, switchable features:
//!
//! * **§2.1 minimize synchronization** — rank 0 broadcasts *token IDs*
//!   (not embedding activations) at the start of each round, and every
//!   rank reduces only its *local top-k* (not the full logit shard) at
//!   the end: [`engine`] + [`sampling`].
//! * **§2.2 one-time synchronization** — parallel-block layers compile to
//!   a single fused segment with ONE allreduce per layer: [`model`],
//!   [`engine`].
//! * **§2.3 minimize memory copy** — compute results land directly in the
//!   communication arena; the allreduce runs in place: [`ccl`].
//!
//! Architecture (DESIGN.md has the full map):
//!
//! ```text
//! server → scheduler → engine(leader) ⇄ rank hosts ⇄ rccl collectives
//!                                        │
//!                                        ├─ in-process rank threads
//!                                        │    (shared-memory arena)
//!                                        ├─ worker processes over TCP
//!                                        │    (launch coordinator,
//!                                        │     §8 deployment shape)
//!                                        └─ runtime (PJRT) ← artifacts/*.hlo.txt
//! ```
//!
//! Deployment modes (DESIGN.md §8): `xeonserve serve` runs every rank as
//! an in-process thread; `xeonserve launch` + `xeonserve worker` run one
//! OS process per rank — the paper's actual shape — with the same
//! engine driving either through [`engine::RankHost`].
//!
//! Execution backends (DESIGN.md §9): each rank's model math runs
//! behind [`backend::ExecBackend`] — the PJRT/XLA artifact path
//! (`--features xla`) or the dependency-free pure-Rust reference
//! transformer that makes the whole distributed stack testable
//! hermetically.

pub mod backend;
pub mod benchkit;
pub mod ccl;
pub mod config;
pub mod engine;
pub mod kvcache;
pub mod launch;
pub mod metrics;
pub mod model;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod tokenizer;
pub mod trace;
pub mod util;

pub use config::{BackendKind, Dtype, EngineConfig, GemmKernel, Variant};
pub use engine::{Completion, Engine};
