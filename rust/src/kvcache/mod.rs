//! KV-cache management: batch-lane allocation + paged capacity accounting.
//!
//! The physical KV cache is a device-resident tensor per (rank, layer)
//! shaped `[batch_lanes, kv_heads_local, max_seq, head_dim]`, chained
//! through the decode segments (it never crosses the host boundary).
//! This module is the L3 brain on top of it:
//!
//! * [`LaneTable`] — which request owns which batch lane, and the valid
//!   sequence length per lane (the `pos`/`length` inputs of the decode
//!   segments are read straight from here).
//! * [`PagedAllocator`] — vLLM-style page accounting used by the
//!   scheduler for admission control: a request is only admitted when its
//!   worst-case page need fits, so decode can never run out of cache
//!   mid-flight.
//! * [`KvLayer`] — one layer's physical K/V storage on the reference
//!   backend, in the dtype `EngineConfig::kv_dtype` selects: dense f32,
//!   or per-row symmetric INT8 with one f32 scale per (lane, head,
//!   position) row — quantized on append, dequantized inside the
//!   attention inner loop (DESIGN.md §11).

#![warn(missing_docs)]

use anyhow::{bail, Result};

use crate::backend::quant::quant_row_into;
use crate::config::Dtype;

/// One transformer layer's physical K/V cache planes on the reference
/// backend, shaped `[lanes · kv_heads_local · max_seq]` rows of
/// `head_dim` values each.
///
/// The INT8 variant stores each row as `i8` values plus ONE `f32`
/// scale per row (`scale = max|row| / 127`, the per-lane scale of
/// DESIGN.md §11): a cache row costs `head_dim + 4` bytes instead of
/// `4·head_dim`.  Rows are quantized exactly once, at append time, by
/// an ascending scan over the row — a pure function of the row's f32
/// content — so the stored bytes never depend on thread count, world
/// size, or the order lanes were filled in, and greedy decode stays
/// bit-identical across worlds at `kv_dtype = "int8"`.
///
/// Fields are exposed (as enum payloads) because the blocked kernel
/// appends rows from pool workers through per-row disjoint slices;
/// everything else should go through [`KvLayer::append_row`].
#[derive(Debug)]
pub enum KvLayer {
    /// Dense f32 planes (`k`/`v` hold `rows · head_dim` floats).
    F32 {
        /// key plane
        k: Vec<f32>,
        /// value plane
        v: Vec<f32>,
    },
    /// Per-row symmetric INT8 planes with one f32 scale per row.
    Int8 {
        /// quantized key plane (`rows · head_dim` bytes)
        k: Vec<i8>,
        /// quantized value plane
        v: Vec<i8>,
        /// per-row key scales (`rows` floats)
        k_scale: Vec<f32>,
        /// per-row value scales
        v_scale: Vec<f32>,
    },
}

impl KvLayer {
    /// Allocate zeroed storage for `rows` cache rows of `head_dim`
    /// values in `dtype`.
    pub fn new(dtype: Dtype, rows: usize, head_dim: usize) -> KvLayer {
        let n = rows * head_dim;
        match dtype {
            Dtype::F32 => KvLayer::F32 { k: vec![0.0; n], v: vec![0.0; n] },
            Dtype::Int8 => KvLayer::Int8 {
                k: vec![0; n],
                v: vec![0; n],
                k_scale: vec![0.0; rows],
                v_scale: vec![0.0; rows],
            },
        }
    }

    /// The storage dtype of this layer.
    pub fn dtype(&self) -> Dtype {
        match self {
            KvLayer::F32 { .. } => Dtype::F32,
            KvLayer::Int8 { .. } => Dtype::Int8,
        }
    }

    /// Write one (lane, head, position) row: copy at f32, quantize
    /// (ascending scan) at int8.  `kv` are the roped key row and the
    /// value row, each `head_dim` long.  Errs at int8 when a row value
    /// is non-finite (quantizing it would silently corrupt the cache).
    pub fn append_row(&mut self, row: usize, kv: (&[f32], &[f32]))
                      -> Result<()> {
        let (krow, vrow) = kv;
        debug_assert_eq!(krow.len(), vrow.len());
        let hd = krow.len();
        match self {
            KvLayer::F32 { k, v } => {
                k[row * hd..(row + 1) * hd].copy_from_slice(krow);
                v[row * hd..(row + 1) * hd].copy_from_slice(vrow);
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                k_scale[row] = quant_row_into(
                    krow, &mut k[row * hd..(row + 1) * hd])?;
                v_scale[row] = quant_row_into(
                    vrow, &mut v[row * hd..(row + 1) * hd])?;
            }
        }
        Ok(())
    }

    /// Copy one row (values *and* scales) from `src` — the
    /// copy-on-write primitive of shared-prefix attach (DESIGN.md §13).
    /// A bitwise move of already-stored content: at int8 the quantized
    /// bytes and the row scale transfer verbatim, so a copied row is
    /// indistinguishable from one the destination appended itself.
    /// Panics on dtype mismatch — segments are always allocated in the
    /// lane cache's dtype.
    pub fn copy_row_from(&mut self, dst_row: usize, src: &KvLayer,
                         src_row: usize, head_dim: usize) {
        let (d, s, hd) = (dst_row, src_row, head_dim);
        match (self, src) {
            (KvLayer::F32 { k, v }, KvLayer::F32 { k: sk, v: sv }) => {
                k[d * hd..(d + 1) * hd]
                    .copy_from_slice(&sk[s * hd..(s + 1) * hd]);
                v[d * hd..(d + 1) * hd]
                    .copy_from_slice(&sv[s * hd..(s + 1) * hd]);
            }
            (
                KvLayer::Int8 { k, v, k_scale, v_scale },
                KvLayer::Int8 {
                    k: sk, v: sv, k_scale: sks, v_scale: svs,
                },
            ) => {
                k[d * hd..(d + 1) * hd]
                    .copy_from_slice(&sk[s * hd..(s + 1) * hd]);
                v[d * hd..(d + 1) * hd]
                    .copy_from_slice(&sv[s * hd..(s + 1) * hd]);
                k_scale[d] = sks[s];
                v_scale[d] = svs[s];
            }
            _ => panic!("copy_row_from across dtypes"),
        }
    }

    /// Zero one row (values and scales) — the speculative-decode
    /// rollback path: a truncated lane's dead rows are scrubbed so its
    /// cache is bit-identical to one that never appended them.
    pub fn zero_row(&mut self, row: usize, head_dim: usize) {
        let hd = head_dim;
        match self {
            KvLayer::F32 { k, v } => {
                k[row * hd..(row + 1) * hd].fill(0.0);
                v[row * hd..(row + 1) * hd].fill(0.0);
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                k[row * hd..(row + 1) * hd].fill(0);
                v[row * hd..(row + 1) * hd].fill(0);
                k_scale[row] = 0.0;
                v_scale[row] = 0.0;
            }
        }
    }

    /// Zero all rows (and scales) — the backend `reset` path.
    pub fn reset(&mut self) {
        match self {
            KvLayer::F32 { k, v } => {
                k.fill(0.0);
                v.fill(0.0);
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                k.fill(0);
                v.fill(0);
                k_scale.fill(0.0);
                v_scale.fill(0.0);
            }
        }
    }

    /// Resident bytes of this layer (values + scales).
    pub fn bytes(&self) -> u64 {
        match self {
            KvLayer::F32 { k, v } => ((k.len() + v.len()) * 4) as u64,
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                (k.len() + v.len()
                    + (k_scale.len() + v_scale.len()) * 4) as u64
            }
        }
    }

    /// Serialize one row (values + scales) onto `out` — the lane
    /// checkpoint primitive of DESIGN.md §17.  Layout per row:
    /// f32 → `k[head_dim]·4 ‖ v[head_dim]·4` LE floats; int8 →
    /// `k[head_dim] ‖ v[head_dim] ‖ k_scale·4 ‖ v_scale·4`.  A pure
    /// bitwise copy of stored content (no re-quantization), so an
    /// export/import round trip is exact in either dtype.
    pub fn export_row(&self, row: usize, head_dim: usize,
                      out: &mut Vec<u8>) {
        let hd = head_dim;
        match self {
            KvLayer::F32 { k, v } => {
                for x in &k[row * hd..(row + 1) * hd] {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                for x in &v[row * hd..(row + 1) * hd] {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                out.extend(
                    k[row * hd..(row + 1) * hd].iter().map(|b| *b as u8));
                out.extend(
                    v[row * hd..(row + 1) * hd].iter().map(|b| *b as u8));
                out.extend_from_slice(&k_scale[row].to_le_bytes());
                out.extend_from_slice(&v_scale[row].to_le_bytes());
            }
        }
    }

    /// Deserialize one row previously written by [`KvLayer::export_row`]
    /// into `row`.  `bytes` must be exactly [`row_bytes`] long and in
    /// this layer's dtype — callers slice the shard by fixed-size row
    /// arithmetic, so a length mismatch means the shard geometry
    /// disagrees with the cache and the restore must fail loudly.
    pub fn import_row(&mut self, row: usize, head_dim: usize,
                      bytes: &[u8]) -> Result<()> {
        let hd = head_dim;
        if bytes.len() != row_bytes(self.dtype(), hd) {
            bail!("KV row image is {} bytes, expected {} ({:?})",
                  bytes.len(), row_bytes(self.dtype(), hd), self.dtype());
        }
        match self {
            KvLayer::F32 { k, v } => {
                for (i, c) in bytes[..hd * 4].chunks_exact(4).enumerate() {
                    k[row * hd + i] =
                        f32::from_le_bytes(c.try_into().unwrap());
                }
                for (i, c) in bytes[hd * 4..].chunks_exact(4).enumerate() {
                    v[row * hd + i] =
                        f32::from_le_bytes(c.try_into().unwrap());
                }
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                for (i, b) in bytes[..hd].iter().enumerate() {
                    k[row * hd + i] = *b as i8;
                }
                for (i, b) in bytes[hd..2 * hd].iter().enumerate() {
                    v[row * hd + i] = *b as i8;
                }
                k_scale[row] = f32::from_le_bytes(
                    bytes[2 * hd..2 * hd + 4].try_into().unwrap());
                v_scale[row] = f32::from_le_bytes(
                    bytes[2 * hd + 4..].try_into().unwrap());
            }
        }
        Ok(())
    }
}

/// Serialized size of one KV row in `dtype`: both planes' values plus
/// (at int8) the two per-row scales.
pub fn row_bytes(dtype: Dtype, head_dim: usize) -> usize {
    match dtype {
        Dtype::F32 => 2 * head_dim * 4,
        Dtype::Int8 => 2 * head_dim + 8,
    }
}

/// Merge per-rank lane shards (each `[layer][local_head][pos]` rows as
/// written by [`KvLayer::export_row`], local heads in rank order) into
/// the world-invariant full image `[layer][global_head][pos]`.
///
/// KV head shards are contiguous per rank (rank `r` of world `w` owns
/// global heads `[r·H/w, (r+1)·H/w)` — the column-parallel slice of
/// the quantize-before-shard grid), so merging is byte concatenation
/// of head blocks per layer and the result is identical no matter
/// which world size exported it.
pub fn merge_rank_shards(shards: &[Vec<u8>], n_layers: usize, len: usize,
                         dtype: Dtype, head_dim: usize,
                         kv_heads_total: usize) -> Result<Vec<u8>> {
    let world = shards.len();
    if world == 0 || kv_heads_total % world != 0 {
        bail!("cannot merge {world} shards over {kv_heads_total} KV heads");
    }
    let heads_l = kv_heads_total / world;
    let rb = row_bytes(dtype, head_dim);
    let layer_block = heads_l * len * rb;
    for (r, s) in shards.iter().enumerate() {
        if s.len() != n_layers * layer_block {
            bail!("rank {r} shard is {} bytes, expected {} \
                   ({n_layers} layers × {heads_l} heads × {len} rows)",
                  s.len(), n_layers * layer_block);
        }
    }
    let mut image =
        Vec::with_capacity(n_layers * world * layer_block);
    for li in 0..n_layers {
        for shard in shards {
            image.extend_from_slice(
                &shard[li * layer_block..(li + 1) * layer_block]);
        }
    }
    Ok(image)
}

/// Split a full lane image (as produced by [`merge_rank_shards`]) into
/// per-rank shards for a `world`-rank fleet — the exact inverse of the
/// merge at any world size that divides `kv_heads_total`.
pub fn split_image(image: &[u8], world: usize, n_layers: usize,
                   len: usize, dtype: Dtype, head_dim: usize,
                   kv_heads_total: usize) -> Result<Vec<Vec<u8>>> {
    if world == 0 || kv_heads_total % world != 0 {
        bail!("cannot split over {world} ranks ({kv_heads_total} KV heads)");
    }
    let heads_l = kv_heads_total / world;
    let rb = row_bytes(dtype, head_dim);
    let head_block = len * rb;
    let layer_block = kv_heads_total * head_block;
    if image.len() != n_layers * layer_block {
        bail!("lane image is {} bytes, expected {} \
               ({n_layers} layers × {kv_heads_total} heads × {len} rows)",
              image.len(), n_layers * layer_block);
    }
    let mut shards =
        vec![Vec::with_capacity(n_layers * heads_l * head_block); world];
    for li in 0..n_layers {
        for (r, shard) in shards.iter_mut().enumerate() {
            let start = li * layer_block + r * heads_l * head_block;
            shard.extend_from_slice(
                &image[start..start + heads_l * head_block]);
        }
    }
    Ok(shards)
}

/// State of one batch lane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Unowned — available for the next admitted request.
    Free,
    /// Owned by `request_id` with `len` valid KV positions.
    Active {
        /// owning request
        request_id: u64,
        /// valid sequence length (next decode appends at this position)
        len: usize,
    },
}

/// Tracks ownership + sequence length of every batch lane.
#[derive(Debug)]
pub struct LaneTable {
    lanes: Vec<Lane>,
    max_seq: usize,
}

impl LaneTable {
    /// A table of `n_lanes` free lanes, each bounded by `max_seq`.
    pub fn new(n_lanes: usize, max_seq: usize) -> Self {
        LaneTable { lanes: vec![Lane::Free; n_lanes], max_seq }
    }

    /// Total lanes (the engine's decode batch width).
    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane sequence-length bound (the model's `max_seq`).
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Claim a free lane for `request_id` with initial length `len`.
    pub fn alloc(&mut self, request_id: u64, len: usize) -> Result<usize> {
        if len == 0 || len > self.max_seq {
            bail!("initial length {len} out of range (max_seq {})",
                  self.max_seq);
        }
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            if *lane == Lane::Free {
                *lane = Lane::Active { request_id, len };
                return Ok(i);
            }
        }
        bail!("no free lane");
    }

    /// Release an active lane.  Double-frees and out-of-range lanes are
    /// errors: both indicate the engine's lane bookkeeping diverged from
    /// the cache state, which must never pass silently.
    pub fn free(&mut self, lane: usize) -> Result<()> {
        let n = self.lanes.len();
        match self.lanes.get_mut(lane) {
            None => bail!("lane {lane} out of range ({n} lanes)"),
            Some(l @ Lane::Active { .. }) => {
                *l = Lane::Free;
                Ok(())
            }
            Some(Lane::Free) => bail!("double free of lane {lane}"),
        }
    }

    /// The state of one lane.
    pub fn lane(&self, lane: usize) -> &Lane {
        &self.lanes[lane]
    }

    /// Is this lane owned by a request?
    pub fn is_active(&self, lane: usize) -> bool {
        matches!(self.lanes[lane], Lane::Active { .. })
    }

    /// Indices of all active lanes, ascending.
    pub fn active_lanes(&self) -> Vec<usize> {
        (0..self.lanes.len()).filter(|&i| self.is_active(i)).collect()
    }

    /// Number of currently free lanes.
    pub fn free_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| **l == Lane::Free).count()
    }

    /// Length of an active lane.
    pub fn len_of(&self, lane: usize) -> Option<usize> {
        match self.lanes[lane] {
            Lane::Active { len, .. } => Some(len),
            Lane::Free => None,
        }
    }

    /// Advance an active lane by one decoded token. Errors at max_seq —
    /// the scheduler must retire the request before the cache overflows.
    pub fn advance(&mut self, lane: usize) -> Result<usize> {
        match &mut self.lanes[lane] {
            Lane::Active { len, .. } => {
                if *len >= self.max_seq {
                    bail!("lane {lane} at max_seq {}", self.max_seq);
                }
                *len += 1;
                Ok(*len)
            }
            Lane::Free => bail!("lane {lane} is free"),
        }
    }

    /// Roll an active lane back to `new_len` valid positions — the
    /// speculative-decode rejection path (DESIGN.md §15).  Truncating
    /// to zero would leave an active lane with no KV to attend over
    /// (even a fresh prefill holds ≥ 1 row), and growing a lane is
    /// [`LaneTable::advance`]'s job, so both are errors.
    pub fn truncate(&mut self, lane: usize, new_len: usize) -> Result<()> {
        let n = self.lanes.len();
        match self.lanes.get_mut(lane) {
            None => bail!("lane {lane} out of range ({n} lanes)"),
            Some(Lane::Active { len, .. }) => {
                if new_len == 0 {
                    bail!("cannot truncate lane {lane} to zero length");
                }
                if new_len > *len {
                    bail!("truncate of lane {lane} to {new_len} would \
                           grow it (len {len})");
                }
                *len = new_len;
                Ok(())
            }
            Some(Lane::Free) => bail!("lane {lane} is free"),
        }
    }

    /// Per-lane `pos` vector for the decode segment: active lanes insert
    /// at their current length; free lanes park at position 0 (their
    /// output is discarded and row 0 is rewritten by the next prefill).
    pub fn positions(&self) -> Vec<i32> {
        self.lanes
            .iter()
            .map(|l| match l {
                Lane::Active { len, .. } => *len as i32,
                Lane::Free => 0,
            })
            .collect()
    }

    /// request_id of an active lane.
    pub fn request_of(&self, lane: usize) -> Option<u64> {
        match self.lanes[lane] {
            Lane::Active { request_id, .. } => Some(request_id),
            Lane::Free => None,
        }
    }
}

/// Page-granular capacity accounting (admission control).
///
/// Pages are *logical* here — the physical cache is dense per lane — but
/// the accounting is exactly vLLM's: a request holding `ceil(len/page)`
/// pages, admitted only if its worst-case need fits the pool.
///
/// Shared-prefix groups (DESIGN.md §13) extend the model: a *group* is
/// a page-aligned run of prompt KV published once and attached by many
/// lanes.  Its pages are reserved out of the same pool, refcounted by
/// attach/release, and only return to the pool through an explicit
/// evict at refcount zero — retirement of an attached lane can never
/// free shared pages early.  The conservation invariant becomes
/// `free + Σ held + Σ group pages == total`.
#[derive(Debug)]
pub struct PagedAllocator {
    page_size: usize,
    n_pages: usize,
    free_pages: usize,
    /// pages held per lane
    held: Vec<usize>,
    /// per-lane truncate floor in *tokens*: the page-aligned shared
    /// prefix length an attached lane reads by reference.  Rollback
    /// (speculative-decode rejection) must never truncate below this —
    /// those positions live in a refcounted shared group, not in the
    /// lane's private pages.
    floor: Vec<usize>,
    /// shared-prefix groups: id → (pages reserved, attached lanes)
    shared: std::collections::HashMap<u32, SharedGroup>,
}

/// One shared-prefix page group's accounting record.
#[derive(Clone, Copy, Debug)]
struct SharedGroup {
    pages: usize,
    refs: usize,
}

impl PagedAllocator {
    /// A pool of `n_pages` pages of `page_size` tokens, accounting for
    /// `n_lanes` lanes.
    pub fn new(page_size: usize, n_pages: usize, n_lanes: usize) -> Self {
        PagedAllocator {
            page_size,
            n_pages,
            free_pages: n_pages,
            held: vec![0; n_lanes],
            floor: vec![0; n_lanes],
            shared: std::collections::HashMap::new(),
        }
    }

    /// Pages needed to hold `len` tokens (rounded up).
    pub fn pages_for(&self, len: usize) -> usize {
        len.div_ceil(self.page_size)
    }

    /// Pages not currently reserved by any lane.
    pub fn free_pages(&self) -> usize {
        self.free_pages
    }

    /// Total pool capacity in pages.
    pub fn total_pages(&self) -> usize {
        self.n_pages
    }

    /// Can a request with worst-case total length `max_len` be admitted?
    pub fn can_admit(&self, max_len: usize) -> bool {
        self.pages_for(max_len) <= self.free_pages
    }

    /// Reserve pages for a lane's worst case. Errors if short or if the
    /// lane index is out of range — the pool must never over-commit.
    pub fn admit(&mut self, lane: usize, max_len: usize) -> Result<()> {
        if lane >= self.held.len() {
            bail!("lane {lane} out of range ({} lanes)", self.held.len());
        }
        let need = self.pages_for(max_len);
        if need > self.free_pages {
            bail!("paged allocator: need {need} pages, have {}",
                  self.free_pages);
        }
        self.free_pages -= need;
        self.held[lane] += need;
        self.floor[lane] = 0;
        Ok(())
    }

    /// Release a lane's pages when its request retires.
    pub fn release(&mut self, lane: usize) {
        self.free_pages += self.held[lane];
        self.held[lane] = 0;
        self.floor[lane] = 0;
        debug_assert!(self.free_pages <= self.n_pages);
    }

    /// Pages currently reserved by `lane`.
    pub fn held_by(&self, lane: usize) -> usize {
        self.held[lane]
    }

    /// Can a request with worst-case length `max_len` be admitted when
    /// `shared_pages` of its prefix are already resident in a shared
    /// group?  Only the private remainder must fit.
    pub fn can_admit_attached(&self, max_len: usize, shared_pages: usize)
                              -> bool {
        self.pages_for(max_len).saturating_sub(shared_pages)
            <= self.free_pages
    }

    /// Reserve only the private remainder of a lane's worst case: the
    /// first `shared_pages` pages ride on a shared group the caller
    /// has attached via [`PagedAllocator::attach_shared`].
    pub fn admit_attached(&mut self, lane: usize, max_len: usize,
                          shared_pages: usize) -> Result<()> {
        if lane >= self.held.len() {
            bail!("lane {lane} out of range ({} lanes)", self.held.len());
        }
        let need =
            self.pages_for(max_len).saturating_sub(shared_pages);
        if need > self.free_pages {
            bail!("paged allocator: need {need} private pages, have {}",
                  self.free_pages);
        }
        self.free_pages -= need;
        self.held[lane] += need;
        self.floor[lane] = shared_pages * self.page_size;
        Ok(())
    }

    /// Roll a lane's page accounting back to `new_len` tokens — the
    /// speculative-decode rejection path.
    ///
    /// Deliberately does NOT release pages: `held` is the lane's
    /// *worst-case* reservation (`max_len` at admission), which is what
    /// keeps decode from running out of cache mid-flight.  Returning
    /// rolled-back pages to the pool would let a new admission claim
    /// them, and the truncated lane — which may still decode up to its
    /// `max_len` — could then oversubscribe the pool.  So this method
    /// only *validates* the rollback: the lane must be admitted, the
    /// target length non-zero, and — refcount safety — at or above the
    /// lane's shared-prefix floor (those positions belong to a
    /// refcounted group; rewriting them would corrupt every other
    /// attached lane).  Conservation `free + Σheld + Σshared == total`
    /// is untouched by construction.
    pub fn truncate_lane(&mut self, lane: usize, new_len: usize)
                         -> Result<()> {
        if lane >= self.held.len() {
            bail!("lane {lane} out of range ({} lanes)", self.held.len());
        }
        if self.held[lane] == 0 && self.floor[lane] == 0 {
            bail!("truncate of unadmitted lane {lane}");
        }
        if new_len == 0 {
            bail!("cannot truncate lane {lane} to zero length");
        }
        if new_len < self.floor[lane] {
            bail!("truncate of lane {lane} to {new_len} reaches into \
                   its shared prefix ({} tokens by reference)",
                  self.floor[lane]);
        }
        Ok(())
    }

    /// The lane's truncate floor in tokens (its by-reference shared
    /// prefix length; 0 for plain admissions).
    pub fn floor_of(&self, lane: usize) -> usize {
        self.floor[lane]
    }

    /// Reserve `pages` pool pages as shared-prefix group `seg`,
    /// starting at refcount zero (the prefix cache entry pins the
    /// group's existence; lanes pin it via attach).  Errors — never
    /// partial effects — on a duplicate id, zero pages, or a pool too
    /// empty to hold the group.
    pub fn publish_shared(&mut self, seg: u32, pages: usize) -> Result<()> {
        if self.shared.contains_key(&seg) {
            bail!("shared group {seg} already published");
        }
        if pages == 0 {
            bail!("shared group {seg} must hold at least one page");
        }
        if pages > self.free_pages {
            bail!("paged allocator: shared group needs {pages} pages, \
                   have {}", self.free_pages);
        }
        self.free_pages -= pages;
        self.shared.insert(seg, SharedGroup { pages, refs: 0 });
        Ok(())
    }

    /// Attach a lane to shared group `seg` (refcount +1); returns the
    /// group's page count so admission can size the private remainder.
    pub fn attach_shared(&mut self, seg: u32) -> Result<usize> {
        match self.shared.get_mut(&seg) {
            None => bail!("attach to unknown shared group {seg}"),
            Some(g) => {
                g.refs += 1;
                Ok(g.pages)
            }
        }
    }

    /// Detach a lane from shared group `seg` (refcount −1).  Releasing
    /// below zero is an error — it means the engine's attach
    /// bookkeeping double-freed a shared page, which must never pass
    /// silently.  The group's pages stay reserved either way.
    pub fn release_shared(&mut self, seg: u32) -> Result<()> {
        match self.shared.get_mut(&seg) {
            None => bail!("release of unknown shared group {seg}"),
            Some(g) if g.refs == 0 => {
                bail!("double free of shared group {seg}")
            }
            Some(g) => {
                g.refs -= 1;
                Ok(())
            }
        }
    }

    /// Return an *unreferenced* shared group's pages to the pool.
    /// Refuses while any lane is attached — eviction must never yank
    /// pages out from under a live reader.
    pub fn evict_shared(&mut self, seg: u32) -> Result<()> {
        match self.shared.get(&seg) {
            None => bail!("evict of unknown shared group {seg}"),
            Some(g) if g.refs > 0 => {
                bail!("shared group {seg} still has {} attached lane(s)",
                      g.refs)
            }
            Some(g) => {
                self.free_pages += g.pages;
                self.shared.remove(&seg);
                debug_assert!(self.free_pages <= self.n_pages);
                Ok(())
            }
        }
    }

    /// Current refcount of a shared group (`None` if unknown).
    pub fn shared_refs(&self, seg: u32) -> Option<usize> {
        self.shared.get(&seg).map(|g| g.refs)
    }

    /// Pages reserved by a shared group (`None` if unknown).
    pub fn shared_pages(&self, seg: u32) -> Option<usize> {
        self.shared.get(&seg).map(|g| g.pages)
    }

    /// Total pages reserved across all shared groups.
    pub fn shared_pages_total(&self) -> usize {
        self.shared.values().map(|g| g.pages).sum()
    }

    /// Number of live shared groups.
    pub fn shared_groups(&self) -> usize {
        self.shared.len()
    }
}

/// A prefix-sharing match: how much of a prompt rides on segment `seg`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefixMatch {
    /// shared-segment id to attach to
    pub seg: u32,
    /// page-aligned token count read from the segment by reference
    pub shared_len: usize,
    /// tokens copied out of the segment into the lane's private pages
    /// (the partial page past `shared_len` — copy-on-write up front,
    /// so the first divergent append never lands in shared storage)
    pub copy_len: usize,
}

/// The prefix-hash table of DESIGN.md §13: published prompt prefixes
/// (page-aligned token runs) keyed by their token content, looked up
/// by longest usable match.
///
/// The cap at `prompt_len − 1` is load-bearing: the final prompt token
/// must always run through the model so the request produces its
/// first-token logits — a prompt fully contained in a published prefix
/// still prefills (at least) that last row.
#[derive(Debug, Default)]
pub struct PrefixCache {
    entries: Vec<(u32, Vec<i32>)>,
}

impl PrefixCache {
    /// An empty cache.
    pub fn new() -> Self {
        PrefixCache { entries: Vec::new() }
    }

    /// Register segment `seg` as holding the KV of `tokens` (must be a
    /// non-empty multiple of `page_size` — groups are page-granular).
    pub fn insert(&mut self, seg: u32, tokens: Vec<i32>,
                  page_size: usize) -> Result<()> {
        if tokens.is_empty() || tokens.len() % page_size != 0 {
            bail!("prefix of {} tokens is not a positive multiple of \
                   the {page_size}-token page", tokens.len());
        }
        if self.entries.iter().any(|(s, _)| *s == seg) {
            bail!("segment {seg} already in the prefix cache");
        }
        self.entries.push((seg, tokens));
        Ok(())
    }

    /// Longest usable match for `prompt`: over all entries, maximize
    /// the raw common prefix `M = min(lcp, prompt_len − 1)`, and
    /// return it split into a page-aligned by-reference part and a
    /// copied remainder.  `None` unless at least one full page is
    /// reusable (attaching for less costs more bookkeeping than it
    /// saves).
    pub fn lookup(&self, prompt: &[i32], page_size: usize)
                  -> Option<PrefixMatch> {
        let mut best: Option<PrefixMatch> = None;
        for (seg, tokens) in &self.entries {
            let lcp = tokens
                .iter()
                .zip(prompt.iter())
                .take_while(|(a, b)| a == b)
                .count();
            let m = lcp.min(prompt.len().saturating_sub(1));
            let shared_len = (m / page_size) * page_size;
            if shared_len < page_size {
                continue;
            }
            let cand = PrefixMatch {
                seg: *seg,
                shared_len,
                copy_len: m - shared_len,
            };
            let better = match best {
                None => true,
                Some(b) => cand.shared_len + cand.copy_len
                    > b.shared_len + b.copy_len,
            };
            if better {
                best = Some(cand);
            }
        }
        best
    }

    /// Would publishing `tokens` duplicate an existing entry?
    pub fn contains_prefix(&self, tokens: &[i32]) -> bool {
        self.entries.iter().any(|(_, t)| t == tokens)
    }

    /// Drop segment `seg` from the cache (a pool eviction).
    pub fn remove(&mut self, seg: u32) {
        self.entries.retain(|(s, _)| *s != seg);
    }

    /// Ids of all cached segments, in insertion (publish) order.
    pub fn segs(&self) -> Vec<u32> {
        self.entries.iter().map(|(s, _)| *s).collect()
    }

    /// Number of cached prefixes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut t = LaneTable::new(2, 64);
        let a = t.alloc(100, 5).unwrap();
        let b = t.alloc(200, 8).unwrap();
        assert_ne!(a, b);
        assert!(t.alloc(300, 1).is_err());
        t.free(a).unwrap();
        let c = t.alloc(300, 1).unwrap();
        assert_eq!(c, a);
        assert_eq!(t.request_of(c), Some(300));
    }

    #[test]
    fn advance_tracks_length() {
        let mut t = LaneTable::new(1, 8);
        let l = t.alloc(1, 6).unwrap();
        assert_eq!(t.advance(l).unwrap(), 7);
        assert_eq!(t.advance(l).unwrap(), 8);
        assert!(t.advance(l).is_err(), "must refuse past max_seq");
    }

    #[test]
    fn positions_for_mixed_lanes() {
        let mut t = LaneTable::new(3, 64);
        t.alloc(1, 5).unwrap();
        let b = t.alloc(2, 9).unwrap();
        t.free(b).unwrap();
        assert_eq!(t.positions(), vec![5, 0, 0]);
        assert_eq!(t.active_lanes(), vec![0]);
        assert_eq!(t.free_lanes(), 2);
    }

    #[test]
    fn zero_or_oversized_initial_length_rejected() {
        let mut t = LaneTable::new(1, 8);
        assert!(t.alloc(1, 0).is_err());
        assert!(t.alloc(1, 9).is_err());
    }

    #[test]
    fn paged_admission() {
        let mut p = PagedAllocator::new(16, 8, 4); // 128 tokens capacity
        assert!(p.can_admit(128));
        assert!(!p.can_admit(129));
        p.admit(0, 100).unwrap(); // 7 pages
        assert_eq!(p.free_pages(), 1);
        assert!(p.can_admit(16));
        assert!(!p.can_admit(17));
        assert!(p.admit(1, 32).is_err());
        p.release(0);
        assert_eq!(p.free_pages(), 8);
    }

    #[test]
    fn pages_for_rounds_up() {
        let p = PagedAllocator::new(16, 4, 1);
        assert_eq!(p.pages_for(1), 1);
        assert_eq!(p.pages_for(16), 1);
        assert_eq!(p.pages_for(17), 2);
        assert_eq!(p.pages_for(0), 0);
    }

    #[test]
    fn randomized_alloc_free_sequences_conserve_pages() {
        // property: after any sequence of admits/releases the page pool
        // is conserved and never oversubscribed
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xCAFE);
        for _case in 0..50 {
            let n_lanes = 1 + rng.next_below(8);
            let mut lanes = LaneTable::new(n_lanes, 64);
            let mut pages = PagedAllocator::new(8, n_lanes * 8, n_lanes);
            let mut live: Vec<usize> = Vec::new();
            for step in 0..100 {
                if rng.next_f32() < 0.6 && lanes.free_lanes() > 0 {
                    let len = 1 + rng.next_below(32);
                    if pages.can_admit(len + 8) {
                        let lane = lanes.alloc(step as u64, len).unwrap();
                        pages.admit(lane, len + 8).unwrap();
                        live.push(lane);
                    }
                } else if let Some(i) =
                    (!live.is_empty()).then(|| rng.next_below(live.len()))
                {
                    let lane = live.swap_remove(i);
                    lanes.free(lane).unwrap();
                    pages.release(lane);
                }
                // invariants
                let held: usize =
                    (0..n_lanes).map(|l| pages.held_by(l)).sum();
                assert_eq!(held + pages.free_pages(), pages.total_pages());
                assert_eq!(lanes.active_lanes().len(), live.len());
            }
        }
    }

    #[test]
    fn double_free_and_out_of_range_error() {
        let mut t = LaneTable::new(2, 8);
        let a = t.alloc(1, 3).unwrap();
        t.free(a).unwrap();
        assert!(t.free(a).is_err(), "double free must be rejected");
        assert!(t.free(99).is_err(), "out-of-range free must be rejected");
        // a free that failed must not corrupt the table
        let b = t.alloc(2, 1).unwrap();
        assert_eq!(t.request_of(b), Some(2));
    }

    #[test]
    fn lane_alloc_free_len_roundtrip_property() {
        // property: for any interleaving, len_of/request_of reflect
        // exactly the live set and freed lanes become reusable
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xBEEF);
        for _case in 0..40 {
            let n = 1 + rng.next_below(6);
            let mut t = LaneTable::new(n, 32);
            let mut live: Vec<(usize, u64, usize)> = Vec::new(); // lane,id,len
            for step in 0..200u64 {
                if rng.next_f32() < 0.5 && t.free_lanes() > 0 {
                    let len = 1 + rng.next_below(16);
                    let lane = t.alloc(step, len).unwrap();
                    assert!(!live.iter().any(|(l, ..)| *l == lane),
                            "alloc handed out a live lane");
                    live.push((lane, step, len));
                } else if !live.is_empty() {
                    match rng.next_below(3) {
                        0 => {
                            let i = rng.next_below(live.len());
                            let (lane, ..) = live.swap_remove(i);
                            t.free(lane).unwrap();
                            assert!(t.free(lane).is_err());
                        }
                        _ => {
                            let i = rng.next_below(live.len());
                            let (lane, _, len) = &mut live[i];
                            if *len < 32 {
                                *len = t.advance(*lane).unwrap();
                            }
                        }
                    }
                }
                for (lane, id, len) in &live {
                    assert_eq!(t.len_of(*lane), Some(*len));
                    assert_eq!(t.request_of(*lane), Some(*id));
                }
                assert_eq!(t.free_lanes(), n - live.len());
            }
        }
    }

    #[test]
    fn paged_allocator_never_overcommits_property() {
        // property: whatever sequence of admits is attempted (including
        // rejected ones), held + free == total and free never goes
        // negative — the pool cannot be over-committed
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xF00D);
        for _case in 0..40 {
            let n_lanes = 1 + rng.next_below(4);
            let n_pages = 4 + rng.next_below(12);
            let mut p = PagedAllocator::new(4, n_pages, n_lanes);
            let mut live: Vec<usize> = Vec::new();
            for _ in 0..300 {
                let lane = rng.next_below(n_lanes + 1); // sometimes OOR
                if rng.next_f32() < 0.6 {
                    let len = rng.next_below(n_pages * 4 + 8);
                    let fits =
                        lane < n_lanes && p.can_admit(len);
                    let r = p.admit(lane, len);
                    assert_eq!(r.is_ok(), fits,
                               "admit must succeed iff can_admit and \
                                lane in range");
                    if r.is_ok() && !live.contains(&lane) {
                        live.push(lane);
                    }
                } else if let Some(i) =
                    (!live.is_empty()).then(|| rng.next_below(live.len()))
                {
                    let lane = live.swap_remove(i);
                    p.release(lane);
                    assert_eq!(p.held_by(lane), 0);
                }
                let held: usize =
                    (0..n_lanes).map(|l| p.held_by(l)).sum();
                assert_eq!(held + p.free_pages(), p.total_pages());
            }
        }
    }

    #[test]
    fn kv_layer_f32_roundtrips_rows() {
        let hd = 8;
        let mut layer = KvLayer::new(Dtype::F32, 4, hd);
        let krow: Vec<f32> = (0..hd).map(|i| i as f32 * 0.5).collect();
        let vrow: Vec<f32> = (0..hd).map(|i| -(i as f32)).collect();
        layer.append_row(2, (&krow, &vrow)).unwrap();
        match &layer {
            KvLayer::F32 { k, v } => {
                assert_eq!(&k[2 * hd..3 * hd], &krow[..]);
                assert_eq!(&v[2 * hd..3 * hd], &vrow[..]);
            }
            _ => panic!("wrong dtype"),
        }
        layer.reset();
        match &layer {
            KvLayer::F32 { k, .. } => assert!(k.iter().all(|&x| x == 0.0)),
            _ => unreachable!(),
        }
    }

    #[test]
    fn kv_layer_int8_quantizes_within_half_step() {
        let hd = 16;
        let mut layer = KvLayer::new(Dtype::Int8, 3, hd);
        let krow: Vec<f32> =
            (0..hd).map(|i| ((i * 7 % 5) as f32 - 2.0) * 0.33).collect();
        let vrow: Vec<f32> =
            (0..hd).map(|i| ((i * 3 % 11) as f32 - 5.0) * 0.21).collect();
        layer.append_row(1, (&krow, &vrow)).unwrap();
        match &layer {
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                for (i, &orig) in krow.iter().enumerate() {
                    let deq = k[hd + i] as f32 * k_scale[1];
                    assert!((deq - orig).abs() <= k_scale[1] / 2.0 + 1e-6);
                }
                for (i, &orig) in vrow.iter().enumerate() {
                    let deq = v[hd + i] as f32 * v_scale[1];
                    assert!((deq - orig).abs() <= v_scale[1] / 2.0 + 1e-6);
                }
                // untouched rows stay zero
                assert!(k[..hd].iter().all(|&b| b == 0));
                assert_eq!(k_scale[0], 0.0);
            }
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn kv_layer_bytes_int8_is_about_a_quarter() {
        let (rows, hd) = (64, 96);
        let f = KvLayer::new(Dtype::F32, rows, hd);
        let q = KvLayer::new(Dtype::Int8, rows, hd);
        assert_eq!(f.bytes(), (2 * rows * hd * 4) as u64);
        assert_eq!(q.bytes(), (2 * rows * hd + 2 * rows * 4) as u64);
        assert!(q.bytes() * 3 < f.bytes());
        assert_eq!(f.dtype(), Dtype::F32);
        assert_eq!(q.dtype(), Dtype::Int8);
    }

    #[test]
    fn shared_group_publish_attach_release_cycle() {
        let mut p = PagedAllocator::new(16, 8, 4);
        p.publish_shared(7, 2).unwrap();
        assert_eq!(p.free_pages(), 6);
        assert_eq!(p.shared_refs(7), Some(0));
        assert_eq!(p.shared_pages(7), Some(2));
        assert_eq!(p.shared_pages_total(), 2);
        // duplicate ids, empty groups, oversized groups: clean errors
        assert!(p.publish_shared(7, 1).is_err());
        assert!(p.publish_shared(8, 0).is_err());
        assert!(p.publish_shared(9, 7).is_err());
        // two lanes attach; each reserves only its private remainder
        assert_eq!(p.attach_shared(7).unwrap(), 2);
        assert!(p.can_admit_attached(64, 2)); // 4 pages − 2 shared
        p.admit_attached(0, 64, 2).unwrap();
        assert_eq!(p.held_by(0), 2);
        assert_eq!(p.attach_shared(7).unwrap(), 2);
        p.admit_attached(1, 64, 2).unwrap();
        assert_eq!(p.free_pages(), 2);
        // conservation with a shared group in play
        let held: usize = (0..4).map(|l| p.held_by(l)).sum();
        assert_eq!(held + p.free_pages() + p.shared_pages_total(),
                   p.total_pages());
        // retiring an attached lane releases private pages + one ref —
        // never the shared pages themselves
        p.release(0);
        p.release_shared(7).unwrap();
        assert_eq!(p.free_pages(), 4);
        assert_eq!(p.shared_refs(7), Some(1));
        assert!(p.evict_shared(7).is_err(), "pinned group must not evict");
        p.release(1);
        p.release_shared(7).unwrap();
        p.evict_shared(7).unwrap();
        assert_eq!(p.free_pages(), 8);
        assert_eq!(p.shared_groups(), 0);
    }

    #[test]
    fn shared_group_double_free_is_an_error() {
        // satellite: double-free of a shared page group must be loud —
        // a silently negative refcount would let eviction free pages a
        // live lane still reads
        let mut p = PagedAllocator::new(16, 4, 2);
        p.publish_shared(1, 1).unwrap();
        p.attach_shared(1).unwrap();
        p.release_shared(1).unwrap();
        assert!(p.release_shared(1).is_err(),
                "refcount must not go below zero");
        assert!(p.release_shared(99).is_err(), "unknown group");
        // the failed releases left the group intact and evictable
        assert_eq!(p.shared_refs(1), Some(0));
        p.evict_shared(1).unwrap();
        assert!(p.evict_shared(1).is_err(), "double evict");
        assert_eq!(p.free_pages(), 4);
    }

    #[test]
    fn exhaustion_while_prefix_pinned_sheds_cleanly() {
        // satellite: when the pool runs dry while prefix pages are
        // pinned, admission must shed (can_admit* false, admit* Err)
        // without corrupting any accounting
        let mut p = PagedAllocator::new(16, 6, 4);
        p.publish_shared(1, 2).unwrap(); // pinned by the cache
        p.attach_shared(1).unwrap();
        p.admit_attached(0, 64, 2).unwrap(); // 2 private pages
        p.admit(1, 32).unwrap(); // 2 pages → pool dry
        assert_eq!(p.free_pages(), 0);
        assert!(!p.can_admit(1));
        assert!(!p.can_admit_attached(64, 2));
        assert!(p.admit(2, 1).is_err());
        assert!(p.admit_attached(2, 64, 2).is_err());
        // ...and attach itself still works: it costs no new pages
        assert_eq!(p.attach_shared(1).unwrap(), 2);
        p.release_shared(1).unwrap();
        // conservation held throughout
        let held: usize = (0..4).map(|l| p.held_by(l)).sum();
        assert_eq!(held + p.free_pages() + p.shared_pages_total(),
                   p.total_pages());
        // eviction is the shed path once the last reader detaches
        p.release(0);
        p.release_shared(1).unwrap();
        p.evict_shared(1).unwrap();
        assert!(p.can_admit(32));
    }

    #[test]
    fn randomized_shared_groups_conserve_pages_property() {
        // property: any interleaving of publish/attach/release/evict
        // with plain admits keeps free + Σheld + Σshared == total and
        // refcounts exact
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0x5EED);
        for _case in 0..40 {
            let n_lanes = 1 + rng.next_below(4);
            let n_pages = 6 + rng.next_below(10);
            let mut p = PagedAllocator::new(4, n_pages, n_lanes);
            let mut live: Vec<(usize, Option<u32>)> = Vec::new();
            let mut free_lanes: Vec<usize> = (0..n_lanes).collect();
            let mut refs: std::collections::HashMap<u32, usize> =
                std::collections::HashMap::new();
            let mut next_seg = 0u32;
            for _ in 0..200 {
                match rng.next_below(5) {
                    0 if refs.len() < 3 => {
                        let pages = 1 + rng.next_below(2);
                        if p.publish_shared(next_seg, pages).is_ok() {
                            refs.insert(next_seg, 0);
                            next_seg += 1;
                        }
                    }
                    1 if !live.is_empty() => {
                        let i = rng.next_below(live.len());
                        let (lane, seg) = live.swap_remove(i);
                        p.release(lane);
                        free_lanes.push(lane);
                        if let Some(seg) = seg {
                            p.release_shared(seg).unwrap();
                            *refs.get_mut(&seg).unwrap() -= 1;
                        }
                    }
                    2 => {
                        // evict: must succeed iff known and unreferenced
                        let seg = rng.next_below(next_seg as usize + 1)
                            as u32;
                        let ok = refs.get(&seg) == Some(&0);
                        assert_eq!(p.evict_shared(seg).is_ok(), ok);
                        if ok {
                            refs.remove(&seg);
                        }
                    }
                    _ if !free_lanes.is_empty() => {
                        let lane = *free_lanes.last().unwrap();
                        let attach = (!refs.is_empty()
                            && rng.next_f32() < 0.5)
                            .then(|| {
                                let keys: Vec<u32> =
                                    refs.keys().copied().collect();
                                keys[rng.next_below(keys.len())]
                            });
                        let len = 1 + rng.next_below(n_pages * 4);
                        match attach {
                            Some(seg) => {
                                let shared =
                                    p.attach_shared(seg).unwrap();
                                if p.admit_attached(lane, len, shared)
                                    .is_ok()
                                {
                                    *refs.get_mut(&seg).unwrap() += 1;
                                    live.push((lane, Some(seg)));
                                    free_lanes.pop();
                                } else {
                                    p.release_shared(seg).unwrap();
                                }
                            }
                            None => {
                                if p.admit(lane, len).is_ok() {
                                    live.push((lane, None));
                                    free_lanes.pop();
                                }
                            }
                        }
                    }
                    _ => {}
                }
                let held: usize =
                    (0..n_lanes).map(|l| p.held_by(l)).sum();
                assert_eq!(
                    held + p.free_pages() + p.shared_pages_total(),
                    p.total_pages(),
                    "page conservation violated"
                );
                for (seg, r) in &refs {
                    assert_eq!(p.shared_refs(*seg), Some(*r));
                }
                assert_eq!(p.shared_groups(), refs.len());
            }
        }
    }

    #[test]
    fn prefix_cache_longest_usable_match() {
        let page = 16;
        let mut c = PrefixCache::new();
        assert!(c.is_empty());
        let sys: Vec<i32> = (0..32).collect();
        c.insert(1, sys.clone(), page).unwrap();
        // shorter entry sharing the first page
        c.insert(2, (0..16).collect(), page).unwrap();
        assert_eq!(c.len(), 2);
        // misaligned or empty prefixes are rejected
        assert!(c.insert(3, vec![1; 17], page).is_err());
        assert!(c.insert(3, vec![], page).is_err());
        // duplicate segment ids are rejected
        assert!(c.insert(1, vec![0; 16], page).is_err());

        // a prompt extending the 32-token entry: M = 32, one partial
        // token beyond would copy — here prompt diverges at 40
        let mut prompt: Vec<i32> = (0..40).collect();
        prompt[35] = -7;
        let m = c.lookup(&prompt, page).unwrap();
        assert_eq!(m, PrefixMatch { seg: 1, shared_len: 32, copy_len: 0 });

        // divergence mid-page: lcp 20 → 16 by reference + 4 copied
        let mut d: Vec<i32> = (0..40).collect();
        d[20] = -1;
        assert_eq!(c.lookup(&d, page).unwrap(),
                   PrefixMatch { seg: 1, shared_len: 16, copy_len: 4 });

        // the last prompt token never attaches: an exactly-matching
        // 32-token prompt caps at M = 31 → 16 shared + 15 copied
        assert_eq!(c.lookup(&sys, page).unwrap(),
                   PrefixMatch { seg: 1, shared_len: 16, copy_len: 15 });

        // under one page of match → None
        assert!(c.lookup(&sys[..10], page).is_none());
        let unrelated: Vec<i32> = (100..140).collect();
        assert!(c.lookup(&unrelated, page).is_none());

        // removal (pool eviction) drops the entry
        assert!(c.contains_prefix(&sys));
        c.remove(1);
        assert!(!c.contains_prefix(&sys));
        assert_eq!(c.segs(), vec![2]);
    }

    /// Canonical byte image of a layer, for bit-level comparisons.
    fn layer_image(l: &KvLayer) -> Vec<u8> {
        let mut img = Vec::new();
        match l {
            KvLayer::F32 { k, v } => {
                for x in k.iter().chain(v.iter()) {
                    img.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                img.extend(k.iter().map(|b| *b as u8));
                img.extend(v.iter().map(|b| *b as u8));
                for x in k_scale.iter().chain(v_scale.iter()) {
                    img.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
        }
        img
    }

    #[test]
    fn cow_copy_under_concurrent_lane_appends_via_disjoint_slices() {
        // satellite: copy-on-write must compose with the blocked
        // kernel's concurrency model — pool workers appending to
        // *different* rows of the same planes through DisjointSlices
        // while copied shared rows keep their exact bytes.  The
        // threaded run must match the serial run bit-for-bit at both
        // dtypes.
        use crate::backend::pool::DisjointSlices;
        use crate::backend::quant::quant_row_into;
        let hd = 8;
        let rows = 32;
        let krow_for = |row: usize| -> Vec<f32> {
            (0..hd).map(|i| ((row * 31 + i) % 17) as f32 * 0.1).collect()
        };
        let vrow_for = |row: usize| -> Vec<f32> {
            (0..hd).map(|i| ((row * 13 + i) % 11) as f32 * -0.2).collect()
        };
        for dtype in [Dtype::F32, Dtype::Int8] {
            // a 16-row shared segment (one page of prompt KV)
            let mut shared = KvLayer::new(dtype, 16, hd);
            for r in 0..16 {
                shared.append_row(r, (&krow_for(r), &vrow_for(r)))
                      .unwrap();
            }
            // the copied rows must be bit-identical to rows the lane
            // would have appended itself (quantize-once property)
            let mut direct = KvLayer::new(dtype, 16, hd);
            for r in 0..16 {
                direct.append_row(r, (&krow_for(r), &vrow_for(r)))
                      .unwrap();
            }
            assert_eq!(layer_image(&shared), layer_image(&direct));

            // serial reference: COW copy + appends past the page
            let mut serial = KvLayer::new(dtype, rows, hd);
            for r in 0..16 {
                serial.copy_row_from(r, &shared, r, hd);
            }
            for r in 16..rows {
                serial.append_row(r, (&krow_for(r), &vrow_for(r)))
                      .unwrap();
            }

            // threaded: same copies, then 4 threads append disjoint
            // row spans through DisjointSlices
            let mut lane = KvLayer::new(dtype, rows, hd);
            for r in 0..16 {
                lane.copy_row_from(r, &shared, r, hd);
            }
            match &mut lane {
                KvLayer::F32 { k, v } => {
                    let ks = DisjointSlices::new(k);
                    let vs = DisjointSlices::new(v);
                    std::thread::scope(|scope| {
                        for t in 0..4 {
                            let (ks, vs) = (&ks, &vs);
                            let (kf, vf) = (&krow_for, &vrow_for);
                            scope.spawn(move || {
                                for r in
                                    (16 + t * 4)..(16 + (t + 1) * 4)
                                {
                                    unsafe { ks.slice(r * hd, hd) }
                                        .copy_from_slice(&kf(r));
                                    unsafe { vs.slice(r * hd, hd) }
                                        .copy_from_slice(&vf(r));
                                }
                            });
                        }
                    });
                }
                KvLayer::Int8 { k, v, k_scale, v_scale } => {
                    let ks = DisjointSlices::new(k);
                    let vs = DisjointSlices::new(v);
                    let kss = DisjointSlices::new(k_scale);
                    let vss = DisjointSlices::new(v_scale);
                    std::thread::scope(|scope| {
                        for t in 0..4 {
                            let (ks, vs) = (&ks, &vs);
                            let (kss, vss) = (&kss, &vss);
                            let (kf, vf) = (&krow_for, &vrow_for);
                            scope.spawn(move || {
                                for r in
                                    (16 + t * 4)..(16 + (t + 1) * 4)
                                {
                                    let kd =
                                        unsafe { ks.slice(r * hd, hd) };
                                    let vd =
                                        unsafe { vs.slice(r * hd, hd) };
                                    unsafe { kss.slice(r, 1) }[0] =
                                        quant_row_into(&kf(r), kd)
                                            .unwrap();
                                    unsafe { vss.slice(r, 1) }[0] =
                                        quant_row_into(&vf(r), vd)
                                            .unwrap();
                                }
                            });
                        }
                    });
                }
            }
            assert_eq!(layer_image(&serial), layer_image(&lane),
                       "COW + concurrent appends diverged at {dtype}");
        }
    }

    #[test]
    fn lane_truncate_rolls_back_length() {
        let mut t = LaneTable::new(2, 16);
        let a = t.alloc(1, 4).unwrap();
        t.advance(a).unwrap();
        t.advance(a).unwrap();
        assert_eq!(t.len_of(a), Some(6));
        t.truncate(a, 4).unwrap();
        assert_eq!(t.len_of(a), Some(4));
        // no-op truncate to the current length is fine
        t.truncate(a, 4).unwrap();
        // growing, zeroing, free lanes, out-of-range: errors
        assert!(t.truncate(a, 5).is_err(), "truncate must not grow");
        assert!(t.truncate(a, 0).is_err());
        assert!(t.truncate(1, 3).is_err(), "free lane");
        assert!(t.truncate(99, 3).is_err());
        // the lane is still usable after a rollback
        assert_eq!(t.advance(a).unwrap(), 5);
        assert_eq!(t.positions()[a], 5);
    }

    #[test]
    fn truncate_lane_validates_without_releasing_pages() {
        let mut p = PagedAllocator::new(16, 8, 4);
        p.admit(0, 64).unwrap(); // 4 pages
        assert_eq!(p.held_by(0), 4);
        // rollback keeps the worst-case reservation: pages unchanged
        p.truncate_lane(0, 10).unwrap();
        assert_eq!(p.held_by(0), 4);
        assert_eq!(p.free_pages(), 4);
        // zero target, unadmitted lane, out-of-range lane: errors
        assert!(p.truncate_lane(0, 0).is_err());
        assert!(p.truncate_lane(1, 4).is_err(), "unadmitted lane");
        assert!(p.truncate_lane(99, 4).is_err());
        // attached lanes carry a floor at their shared prefix length
        p.publish_shared(7, 2).unwrap(); // 32 tokens by reference
        p.attach_shared(7).unwrap();
        p.admit_attached(1, 64, 2).unwrap();
        assert_eq!(p.floor_of(1), 32);
        p.truncate_lane(1, 33).unwrap();
        p.truncate_lane(1, 32).unwrap(); // exactly at the floor: ok
        assert!(p.truncate_lane(1, 31).is_err(),
                "must not truncate into a still-referenced shared seg");
        // retiring clears the floor
        p.release(1);
        p.release_shared(7).unwrap();
        assert_eq!(p.floor_of(1), 0);
        // a later plain admission of the same lane has no floor
        p.admit(1, 16).unwrap();
        p.truncate_lane(1, 1).unwrap();
    }

    #[test]
    fn randomized_truncate_schedules_conserve_pages_property() {
        // satellite: randomized truncate/append(advance)/cancel(free)
        // schedules — with shared-prefix attaches in the mix — keep
        // free + Σheld + Σshared == total, and truncation never
        // reaches into a still-referenced shared segment
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0x7B0C);
        let page = 4;
        let max_seq = 64;
        for _case in 0..40 {
            let n_lanes = 1 + rng.next_below(4);
            let n_pages = 8 + rng.next_below(24);
            let mut lanes = LaneTable::new(n_lanes, max_seq);
            let mut pages = PagedAllocator::new(page, n_pages, n_lanes);
            // one shared group, published up front when it fits
            let seg = 1u32;
            let shared_pages = 1 + rng.next_below(2);
            let published =
                pages.publish_shared(seg, shared_pages).is_ok();
            let floor_tokens = shared_pages * page;
            // live: (lane, len, attached)
            let mut live: Vec<(usize, usize, bool)> = Vec::new();
            for step in 0..300u64 {
                match rng.next_below(5) {
                    // admit (plain or attached)
                    0 if lanes.free_lanes() > 0 => {
                        let attach = published && rng.next_f32() < 0.5;
                        if attach {
                            let len =
                                floor_tokens + 1 + rng.next_below(8);
                            let max_len =
                                (len + 8).min(max_seq);
                            let sp = pages.attach_shared(seg).unwrap();
                            if pages.can_admit_attached(max_len, sp) {
                                let lane =
                                    lanes.alloc(step, len).unwrap();
                                pages
                                    .admit_attached(lane, max_len, sp)
                                    .unwrap();
                                assert_eq!(pages.floor_of(lane),
                                           floor_tokens);
                                live.push((lane, len, true));
                            } else {
                                pages.release_shared(seg).unwrap();
                            }
                        } else {
                            let len = 1 + rng.next_below(16);
                            let max_len = (len + 8).min(max_seq);
                            if pages.can_admit(max_len) {
                                let lane =
                                    lanes.alloc(step, len).unwrap();
                                pages.admit(lane, max_len).unwrap();
                                assert_eq!(pages.floor_of(lane), 0);
                                live.push((lane, len, false));
                            }
                        }
                    }
                    // append: advance a live lane a few tokens
                    1 if !live.is_empty() => {
                        let i = rng.next_below(live.len());
                        let (lane, len, _) = &mut live[i];
                        for _ in 0..(1 + rng.next_below(4)) {
                            if *len < max_seq {
                                *len = lanes.advance(*lane).unwrap();
                            }
                        }
                    }
                    // truncate: roll a live lane back; must succeed
                    // iff the target respects the lane's shared floor
                    2 if !live.is_empty() => {
                        let i = rng.next_below(live.len());
                        let (lane, len, attached) = &mut live[i];
                        let new_len = 1 + rng.next_below(*len);
                        let floor =
                            if *attached { floor_tokens } else { 0 };
                        let ok = new_len >= floor;
                        assert_eq!(
                            pages.truncate_lane(*lane, new_len).is_ok(),
                            ok,
                            "truncate_lane must succeed iff at or \
                             above the shared floor"
                        );
                        if ok {
                            lanes.truncate(*lane, new_len).unwrap();
                            *len = new_len;
                        }
                    }
                    // cancel: retire a live lane mid-flight
                    3 if !live.is_empty() => {
                        let i = rng.next_below(live.len());
                        let (lane, _, attached) = live.swap_remove(i);
                        lanes.free(lane).unwrap();
                        pages.release(lane);
                        if attached {
                            pages.release_shared(seg).unwrap();
                        }
                        assert_eq!(pages.floor_of(lane), 0);
                    }
                    _ => {}
                }
                // invariants after every step
                let held: usize =
                    (0..n_lanes).map(|l| pages.held_by(l)).sum();
                assert_eq!(
                    held + pages.free_pages()
                        + pages.shared_pages_total(),
                    pages.total_pages(),
                    "page conservation violated"
                );
                for (lane, len, _) in &live {
                    assert_eq!(lanes.len_of(*lane), Some(*len));
                }
                if published {
                    let refs =
                        live.iter().filter(|(_, _, a)| *a).count();
                    assert_eq!(pages.shared_refs(seg), Some(refs));
                }
            }
        }
    }

    #[test]
    fn positions_track_advances() {
        let mut t = LaneTable::new(2, 16);
        let a = t.alloc(1, 4).unwrap();
        t.alloc(2, 7).unwrap();
        t.advance(a).unwrap();
        t.advance(a).unwrap();
        assert_eq!(t.positions(), vec![6, 7]);
    }

    #[test]
    fn kv_row_export_import_roundtrip_is_bitwise_both_dtypes() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xE1A5);
        let hd = 6;
        let rows = 5;
        for dtype in [Dtype::F32, Dtype::Int8] {
            let mut src = KvLayer::new(dtype, rows, hd);
            for r in 0..rows {
                let krow: Vec<f32> =
                    (0..hd).map(|_| rng.next_normal()).collect();
                let vrow: Vec<f32> =
                    (0..hd).map(|_| rng.next_normal()).collect();
                src.append_row(r, (&krow, &vrow)).unwrap();
            }
            let rb = row_bytes(dtype, hd);
            let mut dst = KvLayer::new(dtype, rows, hd);
            for r in 0..rows {
                let mut img = Vec::new();
                src.export_row(r, hd, &mut img);
                assert_eq!(img.len(), rb, "row_bytes mismatch at {dtype}");
                dst.import_row(r, hd, &img).unwrap();
                let mut back = Vec::new();
                dst.export_row(r, hd, &mut back);
                assert_eq!(img, back,
                           "export/import not bitwise at {dtype}");
            }
            // a short or long row image must be rejected, not sliced
            assert!(dst.import_row(0, hd, &vec![0u8; rb - 1]).is_err());
            assert!(dst.import_row(0, hd, &vec![0u8; rb + 1]).is_err());
        }
    }

    #[test]
    fn lane_image_merge_split_roundtrip_is_world_invariant() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0x5AFE);
        let (n_layers, len, hd, kv_heads) = (3, 7, 4, 4);
        for dtype in [Dtype::F32, Dtype::Int8] {
            let image: Vec<u8> = (0..n_layers * kv_heads * len
                    * row_bytes(dtype, hd))
                .map(|_| rng.next_u64() as u8)
                .collect();
            let mut merged_per_world = Vec::new();
            for world in [1usize, 2, 4] {
                let shards = split_image(&image, world, n_layers, len,
                                         dtype, hd, kv_heads)
                    .unwrap();
                assert_eq!(shards.len(), world);
                let back = merge_rank_shards(&shards, n_layers, len,
                                             dtype, hd, kv_heads)
                    .unwrap();
                assert_eq!(back, image,
                           "split→merge not identity at world {world}");
                merged_per_world.push(back);
            }
            // the full image is the same no matter which world size
            // produced the shards — the reshard bit-compat invariant
            assert!(merged_per_world.windows(2).all(|w| w[0] == w[1]));
            // geometry mismatches fail loudly
            assert!(split_image(&image, 3, n_layers, len, dtype, hd,
                                kv_heads).is_err(),
                    "world must divide the KV head count");
            assert!(split_image(&image[1..], 2, n_layers, len, dtype,
                                hd, kv_heads).is_err());
            let mut shards = split_image(&image, 2, n_layers, len,
                                         dtype, hd, kv_heads).unwrap();
            shards[1].pop();
            assert!(merge_rank_shards(&shards, n_layers, len, dtype,
                                      hd, kv_heads).is_err());
        }
    }
}
