//! KV-cache management: batch-lane allocation + paged capacity accounting.
//!
//! The physical KV cache is a device-resident tensor per (rank, layer)
//! shaped `[batch_lanes, kv_heads_local, max_seq, head_dim]`, chained
//! through the decode segments (it never crosses the host boundary).
//! This module is the L3 brain on top of it:
//!
//! * [`LaneTable`] — which request owns which batch lane, and the valid
//!   sequence length per lane (the `pos`/`length` inputs of the decode
//!   segments are read straight from here).
//! * [`PagedAllocator`] — vLLM-style page accounting used by the
//!   scheduler for admission control: a request is only admitted when its
//!   worst-case page need fits, so decode can never run out of cache
//!   mid-flight.
//! * [`KvLayer`] — one layer's physical K/V storage on the reference
//!   backend, in the dtype `EngineConfig::kv_dtype` selects: dense f32,
//!   or per-row symmetric INT8 with one f32 scale per (lane, head,
//!   position) row — quantized on append, dequantized inside the
//!   attention inner loop (DESIGN.md §11).

#![warn(missing_docs)]

use anyhow::{bail, Result};

use crate::backend::quant::quant_row_into;
use crate::config::Dtype;

/// One transformer layer's physical K/V cache planes on the reference
/// backend, shaped `[lanes · kv_heads_local · max_seq]` rows of
/// `head_dim` values each.
///
/// The INT8 variant stores each row as `i8` values plus ONE `f32`
/// scale per row (`scale = max|row| / 127`, the per-lane scale of
/// DESIGN.md §11): a cache row costs `head_dim + 4` bytes instead of
/// `4·head_dim`.  Rows are quantized exactly once, at append time, by
/// an ascending scan over the row — a pure function of the row's f32
/// content — so the stored bytes never depend on thread count, world
/// size, or the order lanes were filled in, and greedy decode stays
/// bit-identical across worlds at `kv_dtype = "int8"`.
///
/// Fields are exposed (as enum payloads) because the blocked kernel
/// appends rows from pool workers through per-row disjoint slices;
/// everything else should go through [`KvLayer::append_row`].
#[derive(Debug)]
pub enum KvLayer {
    /// Dense f32 planes (`k`/`v` hold `rows · head_dim` floats).
    F32 {
        /// key plane
        k: Vec<f32>,
        /// value plane
        v: Vec<f32>,
    },
    /// Per-row symmetric INT8 planes with one f32 scale per row.
    Int8 {
        /// quantized key plane (`rows · head_dim` bytes)
        k: Vec<i8>,
        /// quantized value plane
        v: Vec<i8>,
        /// per-row key scales (`rows` floats)
        k_scale: Vec<f32>,
        /// per-row value scales
        v_scale: Vec<f32>,
    },
}

impl KvLayer {
    /// Allocate zeroed storage for `rows` cache rows of `head_dim`
    /// values in `dtype`.
    pub fn new(dtype: Dtype, rows: usize, head_dim: usize) -> KvLayer {
        let n = rows * head_dim;
        match dtype {
            Dtype::F32 => KvLayer::F32 { k: vec![0.0; n], v: vec![0.0; n] },
            Dtype::Int8 => KvLayer::Int8 {
                k: vec![0; n],
                v: vec![0; n],
                k_scale: vec![0.0; rows],
                v_scale: vec![0.0; rows],
            },
        }
    }

    /// The storage dtype of this layer.
    pub fn dtype(&self) -> Dtype {
        match self {
            KvLayer::F32 { .. } => Dtype::F32,
            KvLayer::Int8 { .. } => Dtype::Int8,
        }
    }

    /// Write one (lane, head, position) row: copy at f32, quantize
    /// (ascending scan) at int8.  `kv` are the roped key row and the
    /// value row, each `head_dim` long.
    pub fn append_row(&mut self, row: usize, kv: (&[f32], &[f32])) {
        let (krow, vrow) = kv;
        debug_assert_eq!(krow.len(), vrow.len());
        let hd = krow.len();
        match self {
            KvLayer::F32 { k, v } => {
                k[row * hd..(row + 1) * hd].copy_from_slice(krow);
                v[row * hd..(row + 1) * hd].copy_from_slice(vrow);
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                k_scale[row] =
                    quant_row_into(krow, &mut k[row * hd..(row + 1) * hd]);
                v_scale[row] =
                    quant_row_into(vrow, &mut v[row * hd..(row + 1) * hd]);
            }
        }
    }

    /// Zero all rows (and scales) — the backend `reset` path.
    pub fn reset(&mut self) {
        match self {
            KvLayer::F32 { k, v } => {
                k.fill(0.0);
                v.fill(0.0);
            }
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                k.fill(0);
                v.fill(0);
                k_scale.fill(0.0);
                v_scale.fill(0.0);
            }
        }
    }

    /// Resident bytes of this layer (values + scales).
    pub fn bytes(&self) -> u64 {
        match self {
            KvLayer::F32 { k, v } => ((k.len() + v.len()) * 4) as u64,
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                (k.len() + v.len()
                    + (k_scale.len() + v_scale.len()) * 4) as u64
            }
        }
    }
}

/// State of one batch lane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Unowned — available for the next admitted request.
    Free,
    /// Owned by `request_id` with `len` valid KV positions.
    Active {
        /// owning request
        request_id: u64,
        /// valid sequence length (next decode appends at this position)
        len: usize,
    },
}

/// Tracks ownership + sequence length of every batch lane.
#[derive(Debug)]
pub struct LaneTable {
    lanes: Vec<Lane>,
    max_seq: usize,
}

impl LaneTable {
    /// A table of `n_lanes` free lanes, each bounded by `max_seq`.
    pub fn new(n_lanes: usize, max_seq: usize) -> Self {
        LaneTable { lanes: vec![Lane::Free; n_lanes], max_seq }
    }

    /// Total lanes (the engine's decode batch width).
    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane sequence-length bound (the model's `max_seq`).
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Claim a free lane for `request_id` with initial length `len`.
    pub fn alloc(&mut self, request_id: u64, len: usize) -> Result<usize> {
        if len == 0 || len > self.max_seq {
            bail!("initial length {len} out of range (max_seq {})",
                  self.max_seq);
        }
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            if *lane == Lane::Free {
                *lane = Lane::Active { request_id, len };
                return Ok(i);
            }
        }
        bail!("no free lane");
    }

    /// Release an active lane.  Double-frees and out-of-range lanes are
    /// errors: both indicate the engine's lane bookkeeping diverged from
    /// the cache state, which must never pass silently.
    pub fn free(&mut self, lane: usize) -> Result<()> {
        let n = self.lanes.len();
        match self.lanes.get_mut(lane) {
            None => bail!("lane {lane} out of range ({n} lanes)"),
            Some(l @ Lane::Active { .. }) => {
                *l = Lane::Free;
                Ok(())
            }
            Some(Lane::Free) => bail!("double free of lane {lane}"),
        }
    }

    /// The state of one lane.
    pub fn lane(&self, lane: usize) -> &Lane {
        &self.lanes[lane]
    }

    /// Is this lane owned by a request?
    pub fn is_active(&self, lane: usize) -> bool {
        matches!(self.lanes[lane], Lane::Active { .. })
    }

    /// Indices of all active lanes, ascending.
    pub fn active_lanes(&self) -> Vec<usize> {
        (0..self.lanes.len()).filter(|&i| self.is_active(i)).collect()
    }

    /// Number of currently free lanes.
    pub fn free_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| **l == Lane::Free).count()
    }

    /// Length of an active lane.
    pub fn len_of(&self, lane: usize) -> Option<usize> {
        match self.lanes[lane] {
            Lane::Active { len, .. } => Some(len),
            Lane::Free => None,
        }
    }

    /// Advance an active lane by one decoded token. Errors at max_seq —
    /// the scheduler must retire the request before the cache overflows.
    pub fn advance(&mut self, lane: usize) -> Result<usize> {
        match &mut self.lanes[lane] {
            Lane::Active { len, .. } => {
                if *len >= self.max_seq {
                    bail!("lane {lane} at max_seq {}", self.max_seq);
                }
                *len += 1;
                Ok(*len)
            }
            Lane::Free => bail!("lane {lane} is free"),
        }
    }

    /// Per-lane `pos` vector for the decode segment: active lanes insert
    /// at their current length; free lanes park at position 0 (their
    /// output is discarded and row 0 is rewritten by the next prefill).
    pub fn positions(&self) -> Vec<i32> {
        self.lanes
            .iter()
            .map(|l| match l {
                Lane::Active { len, .. } => *len as i32,
                Lane::Free => 0,
            })
            .collect()
    }

    /// request_id of an active lane.
    pub fn request_of(&self, lane: usize) -> Option<u64> {
        match self.lanes[lane] {
            Lane::Active { request_id, .. } => Some(request_id),
            Lane::Free => None,
        }
    }
}

/// Page-granular capacity accounting (admission control).
///
/// Pages are *logical* here — the physical cache is dense per lane — but
/// the accounting is exactly vLLM's: a request holding `ceil(len/page)`
/// pages, admitted only if its worst-case need fits the pool.
#[derive(Debug)]
pub struct PagedAllocator {
    page_size: usize,
    n_pages: usize,
    free_pages: usize,
    /// pages held per lane
    held: Vec<usize>,
}

impl PagedAllocator {
    /// A pool of `n_pages` pages of `page_size` tokens, accounting for
    /// `n_lanes` lanes.
    pub fn new(page_size: usize, n_pages: usize, n_lanes: usize) -> Self {
        PagedAllocator {
            page_size,
            n_pages,
            free_pages: n_pages,
            held: vec![0; n_lanes],
        }
    }

    /// Pages needed to hold `len` tokens (rounded up).
    pub fn pages_for(&self, len: usize) -> usize {
        len.div_ceil(self.page_size)
    }

    /// Pages not currently reserved by any lane.
    pub fn free_pages(&self) -> usize {
        self.free_pages
    }

    /// Total pool capacity in pages.
    pub fn total_pages(&self) -> usize {
        self.n_pages
    }

    /// Can a request with worst-case total length `max_len` be admitted?
    pub fn can_admit(&self, max_len: usize) -> bool {
        self.pages_for(max_len) <= self.free_pages
    }

    /// Reserve pages for a lane's worst case. Errors if short or if the
    /// lane index is out of range — the pool must never over-commit.
    pub fn admit(&mut self, lane: usize, max_len: usize) -> Result<()> {
        if lane >= self.held.len() {
            bail!("lane {lane} out of range ({} lanes)", self.held.len());
        }
        let need = self.pages_for(max_len);
        if need > self.free_pages {
            bail!("paged allocator: need {need} pages, have {}",
                  self.free_pages);
        }
        self.free_pages -= need;
        self.held[lane] += need;
        Ok(())
    }

    /// Release a lane's pages when its request retires.
    pub fn release(&mut self, lane: usize) {
        self.free_pages += self.held[lane];
        self.held[lane] = 0;
        debug_assert!(self.free_pages <= self.n_pages);
    }

    /// Pages currently reserved by `lane`.
    pub fn held_by(&self, lane: usize) -> usize {
        self.held[lane]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut t = LaneTable::new(2, 64);
        let a = t.alloc(100, 5).unwrap();
        let b = t.alloc(200, 8).unwrap();
        assert_ne!(a, b);
        assert!(t.alloc(300, 1).is_err());
        t.free(a).unwrap();
        let c = t.alloc(300, 1).unwrap();
        assert_eq!(c, a);
        assert_eq!(t.request_of(c), Some(300));
    }

    #[test]
    fn advance_tracks_length() {
        let mut t = LaneTable::new(1, 8);
        let l = t.alloc(1, 6).unwrap();
        assert_eq!(t.advance(l).unwrap(), 7);
        assert_eq!(t.advance(l).unwrap(), 8);
        assert!(t.advance(l).is_err(), "must refuse past max_seq");
    }

    #[test]
    fn positions_for_mixed_lanes() {
        let mut t = LaneTable::new(3, 64);
        t.alloc(1, 5).unwrap();
        let b = t.alloc(2, 9).unwrap();
        t.free(b).unwrap();
        assert_eq!(t.positions(), vec![5, 0, 0]);
        assert_eq!(t.active_lanes(), vec![0]);
        assert_eq!(t.free_lanes(), 2);
    }

    #[test]
    fn zero_or_oversized_initial_length_rejected() {
        let mut t = LaneTable::new(1, 8);
        assert!(t.alloc(1, 0).is_err());
        assert!(t.alloc(1, 9).is_err());
    }

    #[test]
    fn paged_admission() {
        let mut p = PagedAllocator::new(16, 8, 4); // 128 tokens capacity
        assert!(p.can_admit(128));
        assert!(!p.can_admit(129));
        p.admit(0, 100).unwrap(); // 7 pages
        assert_eq!(p.free_pages(), 1);
        assert!(p.can_admit(16));
        assert!(!p.can_admit(17));
        assert!(p.admit(1, 32).is_err());
        p.release(0);
        assert_eq!(p.free_pages(), 8);
    }

    #[test]
    fn pages_for_rounds_up() {
        let p = PagedAllocator::new(16, 4, 1);
        assert_eq!(p.pages_for(1), 1);
        assert_eq!(p.pages_for(16), 1);
        assert_eq!(p.pages_for(17), 2);
        assert_eq!(p.pages_for(0), 0);
    }

    #[test]
    fn randomized_alloc_free_sequences_conserve_pages() {
        // property: after any sequence of admits/releases the page pool
        // is conserved and never oversubscribed
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xCAFE);
        for _case in 0..50 {
            let n_lanes = 1 + rng.next_below(8);
            let mut lanes = LaneTable::new(n_lanes, 64);
            let mut pages = PagedAllocator::new(8, n_lanes * 8, n_lanes);
            let mut live: Vec<usize> = Vec::new();
            for step in 0..100 {
                if rng.next_f32() < 0.6 && lanes.free_lanes() > 0 {
                    let len = 1 + rng.next_below(32);
                    if pages.can_admit(len + 8) {
                        let lane = lanes.alloc(step as u64, len).unwrap();
                        pages.admit(lane, len + 8).unwrap();
                        live.push(lane);
                    }
                } else if let Some(i) =
                    (!live.is_empty()).then(|| rng.next_below(live.len()))
                {
                    let lane = live.swap_remove(i);
                    lanes.free(lane).unwrap();
                    pages.release(lane);
                }
                // invariants
                let held: usize =
                    (0..n_lanes).map(|l| pages.held_by(l)).sum();
                assert_eq!(held + pages.free_pages(), pages.total_pages());
                assert_eq!(lanes.active_lanes().len(), live.len());
            }
        }
    }

    #[test]
    fn double_free_and_out_of_range_error() {
        let mut t = LaneTable::new(2, 8);
        let a = t.alloc(1, 3).unwrap();
        t.free(a).unwrap();
        assert!(t.free(a).is_err(), "double free must be rejected");
        assert!(t.free(99).is_err(), "out-of-range free must be rejected");
        // a free that failed must not corrupt the table
        let b = t.alloc(2, 1).unwrap();
        assert_eq!(t.request_of(b), Some(2));
    }

    #[test]
    fn lane_alloc_free_len_roundtrip_property() {
        // property: for any interleaving, len_of/request_of reflect
        // exactly the live set and freed lanes become reusable
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xBEEF);
        for _case in 0..40 {
            let n = 1 + rng.next_below(6);
            let mut t = LaneTable::new(n, 32);
            let mut live: Vec<(usize, u64, usize)> = Vec::new(); // lane,id,len
            for step in 0..200u64 {
                if rng.next_f32() < 0.5 && t.free_lanes() > 0 {
                    let len = 1 + rng.next_below(16);
                    let lane = t.alloc(step, len).unwrap();
                    assert!(!live.iter().any(|(l, ..)| *l == lane),
                            "alloc handed out a live lane");
                    live.push((lane, step, len));
                } else if !live.is_empty() {
                    match rng.next_below(3) {
                        0 => {
                            let i = rng.next_below(live.len());
                            let (lane, ..) = live.swap_remove(i);
                            t.free(lane).unwrap();
                            assert!(t.free(lane).is_err());
                        }
                        _ => {
                            let i = rng.next_below(live.len());
                            let (lane, _, len) = &mut live[i];
                            if *len < 32 {
                                *len = t.advance(*lane).unwrap();
                            }
                        }
                    }
                }
                for (lane, id, len) in &live {
                    assert_eq!(t.len_of(*lane), Some(*len));
                    assert_eq!(t.request_of(*lane), Some(*id));
                }
                assert_eq!(t.free_lanes(), n - live.len());
            }
        }
    }

    #[test]
    fn paged_allocator_never_overcommits_property() {
        // property: whatever sequence of admits is attempted (including
        // rejected ones), held + free == total and free never goes
        // negative — the pool cannot be over-committed
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xF00D);
        for _case in 0..40 {
            let n_lanes = 1 + rng.next_below(4);
            let n_pages = 4 + rng.next_below(12);
            let mut p = PagedAllocator::new(4, n_pages, n_lanes);
            let mut live: Vec<usize> = Vec::new();
            for _ in 0..300 {
                let lane = rng.next_below(n_lanes + 1); // sometimes OOR
                if rng.next_f32() < 0.6 {
                    let len = rng.next_below(n_pages * 4 + 8);
                    let fits =
                        lane < n_lanes && p.can_admit(len);
                    let r = p.admit(lane, len);
                    assert_eq!(r.is_ok(), fits,
                               "admit must succeed iff can_admit and \
                                lane in range");
                    if r.is_ok() && !live.contains(&lane) {
                        live.push(lane);
                    }
                } else if let Some(i) =
                    (!live.is_empty()).then(|| rng.next_below(live.len()))
                {
                    let lane = live.swap_remove(i);
                    p.release(lane);
                    assert_eq!(p.held_by(lane), 0);
                }
                let held: usize =
                    (0..n_lanes).map(|l| p.held_by(l)).sum();
                assert_eq!(held + p.free_pages(), p.total_pages());
            }
        }
    }

    #[test]
    fn kv_layer_f32_roundtrips_rows() {
        let hd = 8;
        let mut layer = KvLayer::new(Dtype::F32, 4, hd);
        let krow: Vec<f32> = (0..hd).map(|i| i as f32 * 0.5).collect();
        let vrow: Vec<f32> = (0..hd).map(|i| -(i as f32)).collect();
        layer.append_row(2, (&krow, &vrow));
        match &layer {
            KvLayer::F32 { k, v } => {
                assert_eq!(&k[2 * hd..3 * hd], &krow[..]);
                assert_eq!(&v[2 * hd..3 * hd], &vrow[..]);
            }
            _ => panic!("wrong dtype"),
        }
        layer.reset();
        match &layer {
            KvLayer::F32 { k, .. } => assert!(k.iter().all(|&x| x == 0.0)),
            _ => unreachable!(),
        }
    }

    #[test]
    fn kv_layer_int8_quantizes_within_half_step() {
        let hd = 16;
        let mut layer = KvLayer::new(Dtype::Int8, 3, hd);
        let krow: Vec<f32> =
            (0..hd).map(|i| ((i * 7 % 5) as f32 - 2.0) * 0.33).collect();
        let vrow: Vec<f32> =
            (0..hd).map(|i| ((i * 3 % 11) as f32 - 5.0) * 0.21).collect();
        layer.append_row(1, (&krow, &vrow));
        match &layer {
            KvLayer::Int8 { k, v, k_scale, v_scale } => {
                for (i, &orig) in krow.iter().enumerate() {
                    let deq = k[hd + i] as f32 * k_scale[1];
                    assert!((deq - orig).abs() <= k_scale[1] / 2.0 + 1e-6);
                }
                for (i, &orig) in vrow.iter().enumerate() {
                    let deq = v[hd + i] as f32 * v_scale[1];
                    assert!((deq - orig).abs() <= v_scale[1] / 2.0 + 1e-6);
                }
                // untouched rows stay zero
                assert!(k[..hd].iter().all(|&b| b == 0));
                assert_eq!(k_scale[0], 0.0);
            }
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn kv_layer_bytes_int8_is_about_a_quarter() {
        let (rows, hd) = (64, 96);
        let f = KvLayer::new(Dtype::F32, rows, hd);
        let q = KvLayer::new(Dtype::Int8, rows, hd);
        assert_eq!(f.bytes(), (2 * rows * hd * 4) as u64);
        assert_eq!(q.bytes(), (2 * rows * hd + 2 * rows * 4) as u64);
        assert!(q.bytes() * 3 < f.bytes());
        assert_eq!(f.dtype(), Dtype::F32);
        assert_eq!(q.dtype(), Dtype::Int8);
    }

    #[test]
    fn positions_track_advances() {
        let mut t = LaneTable::new(2, 16);
        let a = t.alloc(1, 4).unwrap();
        t.alloc(2, 7).unwrap();
        t.advance(a).unwrap();
        t.advance(a).unwrap();
        assert_eq!(t.positions(), vec![6, 7]);
    }
}
