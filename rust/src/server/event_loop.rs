//! Readiness-polling reactor: the event-driven replacement for the
//! thread-per-connection accept loop (DESIGN.md §16).
//!
//! One thread runs everything — `poll(2)` over the listener and every
//! client socket, nonblocking line-buffered reads, the engine step
//! (via [`super::Front::tick`]), and nonblocking bounded writes.  The
//! engine is not `Send` (PJRT buffers are thread-local); building it
//! on the reactor thread means it never has to cross one, and the
//! single-threaded loop needs no channels, locks, or wakeup pipes:
//! when the engine has work the poll timeout is zero, when it is idle
//! the loop blocks in `poll` until a socket turns readable.
//!
//! The `poll(2)` wrapper is a ~20-line hand-rolled FFI declaration —
//! the repo's no-heavy-deps stance (no tokio, no mio, no libc crate;
//! std already links libc on every supported target).

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::time::Instant;

use anyhow::{Context, Result};

use super::conn::{
    LineEvent, LineReader, OutQ, MAX_LINE_BYTES, MAX_OUT_BYTES,
    MAX_OUT_FRAMES,
};
use super::{error_json, ConnId, Front};

/// `struct pollfd` from `poll(2)` — identical layout on every libc
/// the crate targets.
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: std::os::raw::c_int,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "linux")]
type NfdsT = u64;
#[cfg(not(target_os = "linux"))]
type NfdsT = u32;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
}

/// `poll(2)` with EINTR retry.  `timeout_ms < 0` blocks indefinitely.
fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe {
            poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms)
        };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let e = io::Error::last_os_error();
        if e.kind() != io::ErrorKind::Interrupted {
            return Err(e);
        }
    }
}

/// Reactor-side state for one client connection.
struct Conn {
    sock: TcpStream,
    reader: LineReader,
    outq: OutQ,
}

/// Run the serving loop forever: accept, read lines into
/// [`Front::on_line`], tick the engine, route reply frames into
/// bounded per-connection queues, and flush them as sockets accept
/// bytes.  Returns only on listener failure or an engine error (after
/// best-effort error delivery to every connected client).
pub(crate) fn run_reactor(listener: TcpListener, mut front: Front)
                          -> Result<()> {
    listener
        .set_nonblocking(true)
        .context("setting the listener nonblocking")?;
    let mut conns: BTreeMap<ConnId, Conn> = BTreeMap::new();
    let mut next_conn_id: ConnId = 1;
    let mut buf = [0u8; 16 * 1024];

    loop {
        // (re)build the poll set: listener first, then connections in
        // id order.  Write interest only while frames are queued —
        // otherwise an idle socket's permanent writability would turn
        // the blocking poll into a busy loop.
        let order: Vec<ConnId> = conns.keys().copied().collect();
        let mut fds = Vec::with_capacity(order.len() + 1);
        fds.push(PollFd {
            fd: listener.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        });
        for id in &order {
            let c = &conns[id];
            let mut events = POLLIN;
            if !c.outq.is_empty() {
                events |= POLLOUT;
            }
            fds.push(PollFd { fd: c.sock.as_raw_fd(), events, revents: 0 });
        }
        // engine work pending → don't sleep, just sample readiness;
        // fully idle → block until a socket (or the listener) wakes us
        let timeout = if front.has_work() { 0 } else { -1 };
        poll_fds(&mut fds, timeout).context("poll")?;

        // accept every pending connection (edge-free: loop to
        // WouldBlock so a burst of SYNs lands in one iteration)
        if fds[0].revents & POLLIN != 0 {
            loop {
                match listener.accept() {
                    Ok((sock, _peer)) => {
                        if sock.set_nonblocking(true).is_err() {
                            continue; // stillborn socket: drop it
                        }
                        let id = next_conn_id;
                        next_conn_id += 1;
                        conns.insert(id, Conn {
                            sock,
                            reader: LineReader::new(MAX_LINE_BYTES),
                            outq: OutQ::new(MAX_OUT_FRAMES,
                                            MAX_OUT_BYTES),
                        });
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        break;
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e).context("accept"),
                }
            }
        }

        // read side: drain every readable socket into the line
        // assembler; EOF / error / bare HUP is a disconnect.  The HUP
        // path is the out-of-band liveness probe the blocking server
        // lacked — a client that vanishes during prefill is reaped
        // here, before any token is produced.
        let mut dead: Vec<ConnId> = Vec::new();
        for (i, &id) in order.iter().enumerate() {
            let revents = fds[i + 1].revents;
            if revents == 0 {
                continue;
            }
            if revents & (POLLERR | POLLNVAL) != 0 {
                dead.push(id);
                continue;
            }
            if revents & POLLIN != 0 {
                let Some(c) = conns.get_mut(&id) else { continue };
                loop {
                    match c.sock.read(&mut buf) {
                        Ok(0) => {
                            dead.push(id);
                            break;
                        }
                        Ok(n) => {
                            for ev in c.reader.push(&buf[..n]) {
                                match ev {
                                    LineEvent::Line(l) => {
                                        if !l.trim().is_empty() {
                                            front.on_line(id, &l);
                                        }
                                    }
                                    LineEvent::Oversized => {
                                        front.reply_raw(id, error_json(
                                            &format!(
                                                "request line exceeds \
                                                 {MAX_LINE_BYTES} bytes")));
                                    }
                                }
                            }
                        }
                        Err(e)
                            if e.kind()
                                == io::ErrorKind::WouldBlock =>
                        {
                            break;
                        }
                        Err(e)
                            if e.kind()
                                == io::ErrorKind::Interrupted => {}
                        Err(_) => {
                            dead.push(id);
                            break;
                        }
                    }
                }
            } else if revents & POLLHUP != 0 {
                dead.push(id);
            }
        }

        // engine side: admissions + one step, producing reply frames
        if front.has_work() {
            if let Err(e) = front.tick() {
                // engine failure is fatal; deliver the error lines the
                // tick queued (best effort, blocking) before bailing
                for (cid, line) in front.take_outbox() {
                    if let Some(c) = conns.get_mut(&cid) {
                        let _ = c.sock.set_nonblocking(false);
                        let _ = c.sock.write_all(line.as_bytes());
                        let _ = c.sock.write_all(b"\n");
                    }
                }
                return Err(e);
            }
        }

        // route frames into per-connection bounded queues.  Overflow
        // means the reader is too slow for its own stream: cancel its
        // work (backpressure-then-cancel) instead of blocking the
        // engine or growing without bound.  Frames for connections
        // that vanished are dropped silently.
        for (cid, line) in front.take_outbox() {
            let Some(c) = conns.get_mut(&cid) else { continue };
            if c.outq.push(&line, Instant::now()).is_err() {
                front.stats.overflow_cancels += 1;
                dead.push(cid);
                continue;
            }
            front.stats.note_queue_depth(c.outq.len());
        }

        // write side: flush whatever each socket will take now
        for (&id, c) in conns.iter_mut() {
            if c.outq.is_empty() {
                continue;
            }
            if c.outq.flush(&mut c.sock, &mut front.stats).is_err() {
                dead.push(id);
            }
        }

        // reap: close the socket, cancel the connection's queued and
        // in-flight work so lanes/pages free immediately
        for id in dead {
            if conns.remove(&id).is_some() {
                front.on_disconnect(id);
            }
        }
    }
}
