//! Line-delimited JSON TCP server — the outward face of the coordinator.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"prompt": "hello", "max_new_tokens": 8}
//! ← {"id": 3, "text": "...", "tokens": [..], "latency_ms": 12.3}
//! ```
//!
//! With `"stream": true` the reply is one frame per generated token,
//! terminated by a summary frame carrying the full result (DESIGN.md
//! §12):
//!
//! ```text
//! → {"prompt": "hello", "max_new_tokens": 3, "stream": true}
//! ← {"id": 3, "token": 104}
//! ← {"id": 3, "token": 105}
//! ← {"id": 3, "token": 33}
//! ← {"done": true, "id": 3, "text": "...", "tokens": [..],
//!    "latency_ms": 12.3}
//! ```
//!
//! A streaming client that disconnects mid-generation is detected at
//! the next token frame: the engine cancels the request, freeing its
//! lane and KV pages for waiting traffic (cancel-on-disconnect).
//! Detection rides the token stream — a client that vanishes during
//! prefill is reaped at its first token, and abandoned one-shot
//! requests run to completion (bounded by `max_new`); the blocking-IO
//! server has no out-of-band liveness probe.
//!
//! `{"stats": true}` answers one introspection line (lane/page
//! occupancy + serving counters) without generating:
//!
//! ```text
//! → {"stats": true}
//! ← {"stats": {"active": 1, "pending": 0, "free_lanes": 1, ...}}
//! ```
//!
//! `{"cancel": id}` cancels a request by the id its frames carry.  The
//! surface is idempotent: cancelling an id that is unknown, already
//! finished, or already cancelled answers a clean `{"error": ...}` line
//! — never a protocol wedge — and a successful cancel answers
//! `{"cancelled": id}`:
//!
//! ```text
//! → {"cancel": 3}
//! ← {"cancelled": 3}
//! → {"cancel": 3}
//! ← {"error": "cancel: unknown or already finished request id 3"}
//! ```
//!
//! Threading: the engine is not `Send` (PJRT buffers are thread-local),
//! so it runs on a dedicated thread; connection threads submit jobs over
//! a channel and block on per-job reply channels.  This mirrors the
//! paper's topology — one leader process front-ending the rank workers.
//! (std::net threads; the offline build environment has no tokio.)

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::EngineConfig;
use crate::engine::Engine;
use crate::scheduler::AdmissionQueue;
use crate::tokenizer::Tokenizer;
use crate::util::Json;

/// A parsed API request line.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiRequest {
    /// prompt text (tokenized server-side)
    pub prompt: String,
    /// generation budget; absent defaults to 16
    pub max_new_tokens: usize,
    /// per-token streamed reply frames instead of one-shot (DESIGN.md
    /// §12); absent defaults to false — the old one-shot protocol
    pub stream: bool,
    /// introspection request: answer one `{"stats": {...}}` line
    /// (lane/page occupancy + serving counters) instead of generating;
    /// `prompt` may be omitted
    pub stats: bool,
    /// cancel the request with this engine id instead of generating;
    /// `prompt` may be omitted.  Idempotent at the API surface: an
    /// unknown/finished id answers a clean error line
    pub cancel: Option<u64>,
}

impl ApiRequest {
    /// Parse one request line.  Absent fields take their defaults;
    /// present-but-invalid fields are rejected with an error (silently
    /// coercing a malformed value to the default hid client bugs).
    pub fn parse(line: &str) -> Result<ApiRequest> {
        let j = Json::parse(line)?;
        let max_new_tokens = match j.get("max_new_tokens") {
            None => 16,
            Some(v) => {
                let n = v.as_f64().context(
                    "max_new_tokens must be a non-negative integer")?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (0.0..=1e9).contains(&n),
                    "max_new_tokens must be a non-negative integer, \
                     got {n}"
                );
                n as usize
            }
        };
        // strict typing: "stream"/"stats" must be real JSON booleans —
        // a "true" string or a number is a client bug, not an opt-in
        let stream = match j.get("stream") {
            None => false,
            Some(v) => v
                .as_bool()
                .context("stream must be a boolean (true|false)")?,
        };
        let stats = match j.get("stats") {
            None => false,
            Some(v) => v
                .as_bool()
                .context("stats must be a boolean (true|false)")?,
        };
        let cancel = match j.get("cancel") {
            None => None,
            Some(v) => {
                let n = v.as_f64().context(
                    "cancel must be a non-negative integer request id")?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (0.0..=1e18).contains(&n),
                    "cancel must be a non-negative integer request id, \
                     got {n}"
                );
                Some(n as u64)
            }
        };
        let prompt = match j.get("prompt") {
            Some(v) => v
                .as_str()
                .context("prompt must be a string")?
                .to_string(),
            // pure stats/cancel probes need no prompt
            None if stats || cancel.is_some() => String::new(),
            None => anyhow::bail!("missing JSON key \"prompt\""),
        };
        Ok(ApiRequest { prompt, max_new_tokens, stream, stats, cancel })
    }
}

/// A serialized API response line.
#[derive(Debug, Clone)]
pub struct ApiResponse {
    /// engine request id
    pub id: u64,
    /// decoded output text
    pub text: String,
    /// generated token ids
    pub tokens: Vec<i32>,
    /// end-to-end request latency, milliseconds
    pub latency_ms: f64,
}

impl ApiResponse {
    /// Response fields shared by the one-shot and streamed-final
    /// encodings.
    fn fields(&self) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Num(self.id as f64));
        m.insert("text".to_string(), Json::Str(self.text.clone()));
        m.insert(
            "tokens".to_string(),
            Json::Arr(self.tokens.iter().map(|&t| Json::Num(t as f64))
                .collect()),
        );
        m.insert("latency_ms".to_string(),
                 Json::Num((self.latency_ms * 1e3).round() / 1e3));
        m
    }

    /// The classic one-shot reply line.
    pub fn to_json(&self) -> String {
        Json::Obj(self.fields()).to_string()
    }

    /// The final frame of a streamed reply: the full one-shot summary
    /// plus `"done": true`, so a client can treat the first line with
    /// `done` as end-of-stream.
    pub fn to_done_json(&self) -> String {
        let mut m = self.fields();
        m.insert("done".to_string(), Json::Bool(true));
        Json::Obj(m).to_string()
    }
}

/// One per-token frame of a streamed reply.
pub fn token_json(id: u64, token: i32) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("token".to_string(), Json::Num(token as f64));
    Json::Obj(m).to_string()
}

/// An `{"error": ...}` reply line.
pub fn error_json(msg: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(m).to_string()
}

/// The `{"cancelled": id}` acknowledgement of a successful cancel.
pub fn cancelled_json(id: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("cancelled".to_string(), Json::Num(id as f64));
    Json::Obj(m).to_string()
}

/// One reply frame flowing from the engine thread to a connection
/// thread; everything but `Token` terminates the request.
enum Frame {
    Token(u64, i32),
    Done(ApiResponse),
    /// a pre-serialized single-line reply (the stats probe)
    Raw(String),
    Error(String),
}

/// The `{"stats": {...}}` introspection reply: lane/page occupancy
/// plus serving counters, read from the live engine.  `queued` is the
/// scheduler-side backlog (submitted but not yet admitted — the
/// burst guard can hold requests there), `pending` the engine-side
/// one.  A cancelled request frees its lane and pages but never
/// increments `requests_done` — which is how the disconnect tests
/// distinguish cancellation from natural retirement.
fn stats_json(engine: &Engine, queued: usize) -> String {
    let mut s = BTreeMap::new();
    let mut put = |k: &str, v: f64| {
        s.insert(k.to_string(), Json::Num(v));
    };
    put("queued", queued as f64);
    put("active", engine.active_count() as f64);
    put("pending", engine.pending_count() as f64);
    put("free_lanes", engine.free_lanes() as f64);
    put("free_pages", engine.free_pages() as f64);
    put("total_pages", engine.total_pages() as f64);
    put("shared_pages", engine.shared_pages() as f64);
    put("shared_groups", engine.shared_groups() as f64);
    put("requests_done", engine.metrics.requests_done as f64);
    put("tokens_out", engine.metrics.tokens_out as f64);
    put("prefix_hits", engine.metrics.prefix_hits as f64);
    put("prefix_misses", engine.metrics.prefix_misses as f64);
    let mut m = BTreeMap::new();
    m.insert("stats".to_string(), Json::Obj(s));
    Json::Obj(m).to_string()
}

struct Job {
    req: ApiRequest,
    respond: Sender<Frame>,
    submitted: Instant,
}

/// Engine-thread bookkeeping for one in-flight request.
struct Waiter {
    tx: Sender<Frame>,
    submitted: Instant,
    stream: bool,
}

/// Engine thread: admits jobs through the config-selected admission
/// queue (FCFS burst guard or continuous — DESIGN.md §13), steps the
/// engine (lane-granular batching happens inside), streams per-token
/// frames to streaming clients, and answers completions.  A streaming
/// client whose connection died (token frame undeliverable) gets its
/// request cancelled in the same step — the lane and KV pages free
/// immediately instead of decoding to max_new for nobody.
fn engine_loop(mut engine: Engine, jobs: Receiver<Job>) -> Result<()> {
    let tok = Tokenizer::byte_level(engine.preset().vocab)?;
    let mut sched = AdmissionQueue::for_kind(
        engine.config().scheduler,
        engine.config().batch.max(1),
        engine.config().prefill_chunk,
    );
    let mut waiting: std::collections::HashMap<u64, Waiter> =
        Default::default();
    // scheduler-id -> engine-id indirection
    let mut pending_jobs: std::collections::HashMap<u64, Job> =
        Default::default();

    loop {
        // ingest every queued job without blocking; block when idle
        loop {
            let job = if engine.has_work() || !sched.is_empty() {
                match jobs.try_recv() {
                    Ok(j) => Some(j),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        return Ok(());
                    }
                }
            } else {
                match jobs.recv() {
                    Ok(j) => Some(j),
                    Err(_) => return Ok(()),
                }
            };
            match job {
                Some(job) if job.req.stats => {
                    // introspection: answer immediately, nothing queued
                    let _ = job.respond.send(Frame::Raw(
                        stats_json(&engine, sched.len())));
                }
                Some(job) if job.req.cancel.is_some() => {
                    // idempotent control surface: a cancel can never
                    // wedge the connection — unknown/finished ids are a
                    // clean error line, found ids an acknowledgement
                    let id = job.req.cancel.unwrap();
                    let line = match engine.cancel(id) {
                        Ok(true) => {
                            if let Some(w) = waiting.remove(&id) {
                                let _ = w.tx.send(
                                    Frame::Error("cancelled".into()));
                            }
                            cancelled_json(id)
                        }
                        Ok(false) => error_json(&format!(
                            "cancel: unknown or already finished \
                             request id {id}")),
                        Err(e) => error_json(&format!("cancel: {e:#}")),
                    };
                    let _ = job.respond.send(Frame::Raw(line));
                }
                Some(job) => {
                    let sid = sched.submit(tok.encode(&job.req.prompt),
                                           job.req.max_new_tokens);
                    pending_jobs.insert(sid, job);
                }
                None => break,
            }
        }

        // admit from the scheduler into the engine; the burst guard
        // only throttles when there are actual decode streams to
        // protect (mid-prefill lanes are not them)
        while let Some(q) =
            sched.next_admission(engine.decoding_count() > 0)
        {
            let eid = engine.enqueue(q.prompt, q.max_new_tokens.max(1));
            if let Some(job) = pending_jobs.remove(&q.id) {
                waiting.insert(eid, Waiter {
                    tx: job.respond,
                    submitted: job.submitted,
                    stream: job.req.stream,
                });
            }
        }

        if engine.has_work() {
            sched.on_decode_round();
            let decode_lanes = engine.decoding_count();
            match engine.step() {
                Ok(completions) => {
                    // speculative steps (DESIGN.md §15) run spec_k
                    // draft rounds plus a multi-row verify: charge the
                    // rows beyond one-per-decode-lane against the
                    // prefill-burst budget so prefills cannot ride a
                    // speculation-inflated step as if it were one
                    // decode round (0 on plain/prefill steps)
                    sched.charge(engine.last_verify_rows()
                                     .saturating_sub(decode_lanes));
                    // per-token frames first, so every token of a
                    // completing request precedes its Done frame
                    for (eid, t) in engine.take_new_tokens() {
                        let dead = match waiting.get(&eid) {
                            Some(w) if w.stream => {
                                w.tx.send(Frame::Token(eid, t)).is_err()
                            }
                            _ => false,
                        };
                        if dead {
                            // cancel-on-disconnect: the client hung up
                            engine.cancel(eid)?;
                            waiting.remove(&eid);
                        }
                    }
                    for c in completions {
                        if let Some(w) = waiting.remove(&c.request_id) {
                            let resp = ApiResponse {
                                id: c.request_id,
                                text: tok.decode(&c.tokens),
                                tokens: c.tokens,
                                latency_ms: w.submitted.elapsed()
                                    .as_secs_f64() * 1e3,
                            };
                            let _ = w.tx.send(Frame::Done(resp));
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("engine: {e:#}");
                    for (_, w) in waiting.drain() {
                        let _ = w.tx.send(Frame::Error(msg.clone()));
                    }
                    return Err(e);
                }
            }
        }
    }
}

/// Write one reply line; an Err here means the client disconnected.
fn write_line(writer: &mut TcpStream, line: &str) -> Result<()> {
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

fn handle_conn(stream: TcpStream, job_tx: Sender<Job>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match ApiRequest::parse(&line) {
            Ok(req) => req,
            Err(e) => {
                write_line(&mut writer,
                           &error_json(
                               &format!("bad request from {peer}: {e}")))?;
                continue;
            }
        };
        let stream_mode = req.stream;
        let (tx, rx) = channel();
        if job_tx
            .send(Job { req, respond: tx, submitted: Instant::now() })
            .is_err()
        {
            write_line(&mut writer, &error_json("engine thread gone"))?;
            continue;
        }
        loop {
            match rx.recv() {
                Ok(Frame::Token(id, t)) if stream_mode => {
                    // a failed write means the client hung up:
                    // dropping `rx` makes the engine's next token
                    // frame undeliverable, which cancels the request
                    // and frees its lane + KV pages
                    write_line(&mut writer, &token_json(id, t))?;
                }
                Ok(Frame::Token(..)) => {} // one-shot: buffered in Done
                Ok(Frame::Done(resp)) => {
                    let out = if stream_mode {
                        resp.to_done_json()
                    } else {
                        resp.to_json()
                    };
                    write_line(&mut writer, &out)?;
                    break;
                }
                Ok(Frame::Raw(line)) => {
                    write_line(&mut writer, &line)?;
                    break;
                }
                Ok(Frame::Error(e)) => {
                    write_line(&mut writer, &error_json(&e))?;
                    break;
                }
                Err(_) => {
                    write_line(&mut writer,
                               &error_json("engine dropped request"))?;
                    break;
                }
            }
        }
    }
    Ok(())
}

/// Serve `cfg` on `addr` (e.g. "127.0.0.1:7070") with in-process rank
/// threads.  Runs until the process exits; one thread per connection.
pub fn serve(cfg: EngineConfig, addr: &str) -> Result<()> {
    serve_with(move || Engine::new(cfg), addr)
}

/// Serve on `addr` with an engine produced by `build` — the hook the
/// launch coordinator uses to front a fleet of remote rank workers
/// (see `crate::launch`).  `build` runs on the dedicated engine thread,
/// so the engine never has to cross threads.
pub fn serve_with<F>(build: F, addr: &str) -> Result<()>
where
    F: FnOnce() -> Result<Engine> + Send + 'static,
{
    let (job_tx, job_rx) = channel::<Job>();
    std::thread::Builder::new()
        .name("engine".into())
        .spawn(move || {
            let engine = match build() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("engine bring-up failed: {e:#}");
                    return;
                }
            };
            if let Err(e) = engine_loop(engine, job_rx) {
                eprintln!("engine loop failed: {e:#}");
            }
        })?;

    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("xeonserve listening on {addr}");
    loop {
        let (socket, peer) = listener.accept()?;
        let job_tx = job_tx.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(socket, job_tx) {
                eprintln!("conn {peer}: {e:#}");
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_parsing() {
        let r = ApiRequest::parse(
            r#"{"prompt": "hi", "max_new_tokens": 4}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new_tokens, 4);
        assert!(!r.stream, "stream must default off (one-shot replies)");
        let d = ApiRequest::parse(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(d.max_new_tokens, 16);
        assert!(ApiRequest::parse(r#"{"max_new_tokens": 4}"#).is_err());
        assert!(ApiRequest::parse("not json").is_err());
    }

    #[test]
    fn stream_flag_is_strictly_typed() {
        // real booleans parse...
        let s = ApiRequest::parse(
            r#"{"prompt": "x", "stream": true}"#).unwrap();
        assert!(s.stream);
        let s = ApiRequest::parse(
            r#"{"prompt": "x", "stream": false}"#).unwrap();
        assert!(!s.stream);
        // ...anything else is a clean JSON error, never a coercion
        for bad in [
            r#"{"prompt": "x", "stream": "true"}"#,
            r#"{"prompt": "x", "stream": 1}"#,
            r#"{"prompt": "x", "stream": null}"#,
            r#"{"prompt": "x", "stream": [true]}"#,
        ] {
            let e = ApiRequest::parse(bad);
            assert!(e.is_err(), "accepted {bad}");
            assert!(format!("{:#}", e.unwrap_err()).contains("stream"),
                    "error should name the bad field for {bad}");
        }
    }

    #[test]
    fn stats_flag_is_strictly_typed_and_needs_no_prompt() {
        let s = ApiRequest::parse(r#"{"stats": true}"#).unwrap();
        assert!(s.stats);
        assert!(s.prompt.is_empty());
        // a prompt alongside stats is tolerated (and ignored upstream)
        let s = ApiRequest::parse(
            r#"{"prompt": "x", "stats": false}"#).unwrap();
        assert!(!s.stats);
        // non-bools are clean errors; stats=false still needs a prompt
        assert!(ApiRequest::parse(r#"{"stats": 1}"#).is_err());
        assert!(ApiRequest::parse(r#"{"stats": "yes"}"#).is_err());
        assert!(ApiRequest::parse(r#"{"stats": false}"#).is_err());
    }

    #[test]
    fn stream_frames_are_valid_json() {
        let t = Json::parse(&token_json(7, 104)).unwrap();
        assert_eq!(t.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(t.get("token").unwrap().as_f64(), Some(104.0));
        assert!(t.get("done").is_none());

        let r = ApiResponse {
            id: 7,
            text: "hi".into(),
            tokens: vec![104, 105],
            latency_ms: 1.5,
        };
        let d = Json::parse(&r.to_done_json()).unwrap();
        assert_eq!(d.get("done").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(d.get("text").unwrap().as_str(), Some("hi"));
        assert_eq!(d.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        // the one-shot encoding never carries "done"
        assert!(Json::parse(&r.to_json()).unwrap().get("done").is_none());
    }

    #[test]
    fn invalid_max_new_tokens_rejected_not_coerced() {
        // present-but-invalid values must error (previously they were
        // silently coerced to the 16-token default)
        for bad in [
            r#"{"prompt": "x", "max_new_tokens": "4"}"#,
            r#"{"prompt": "x", "max_new_tokens": 4.5}"#,
            r#"{"prompt": "x", "max_new_tokens": -1}"#,
            r#"{"prompt": "x", "max_new_tokens": true}"#,
            r#"{"prompt": "x", "max_new_tokens": null}"#,
            r#"{"prompt": "x", "max_new_tokens": [4]}"#,
        ] {
            assert!(ApiRequest::parse(bad).is_err(), "accepted {bad}");
        }
        // explicit integers — including 0 — are fine (the engine layer
        // clamps 0 to a single-token generation)
        let z = ApiRequest::parse(r#"{"prompt": "x", "max_new_tokens": 0}"#)
            .unwrap();
        assert_eq!(z.max_new_tokens, 0);
    }

    #[test]
    fn response_roundtrips_through_json() {
        let r = ApiResponse {
            id: 3,
            text: "ab\"c".into(),
            tokens: vec![97, 98],
            latency_ms: 12.3456,
        };
        let j = Json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("text").unwrap().as_str(), Some("ab\"c"));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn error_json_is_valid() {
        let j = Json::parse(&error_json("boom \"quoted\"")).unwrap();
        assert!(j.get("error").unwrap().as_str().unwrap().contains("boom"));
    }

    #[test]
    fn cancel_field_is_strictly_typed_and_needs_no_prompt() {
        let c = ApiRequest::parse(r#"{"cancel": 3}"#).unwrap();
        assert_eq!(c.cancel, Some(3));
        assert!(c.prompt.is_empty());
        let c = ApiRequest::parse(r#"{"cancel": 0}"#).unwrap();
        assert_eq!(c.cancel, Some(0));
        // absent on ordinary requests
        let r = ApiRequest::parse(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(r.cancel, None);
        // non-integers and negatives are clean errors, never coercions
        for bad in [
            r#"{"cancel": "3"}"#,
            r#"{"cancel": 3.5}"#,
            r#"{"cancel": -1}"#,
            r#"{"cancel": true}"#,
            r#"{"cancel": null}"#,
            r#"{"cancel": [3]}"#,
        ] {
            let e = ApiRequest::parse(bad);
            assert!(e.is_err(), "accepted {bad}");
            assert!(format!("{:#}", e.unwrap_err()).contains("cancel"),
                    "error should name the bad field for {bad}");
        }
        let j = Json::parse(&cancelled_json(7)).unwrap();
        assert_eq!(j.get("cancelled").unwrap().as_u64(), Some(7));
    }

    /// Satellite: seeded random-JSON fuzz of [`ApiRequest::parse`].
    /// Every input must yield either a valid request or a clean JSON
    /// error — never a panic (the `#[test]` harness turns any panic
    /// into a failure) — and accepted requests must satisfy the field
    /// invariants the parser promises.
    #[test]
    fn parse_never_panics_on_seeded_random_json() {
        use crate::util::SplitMix64;

        let mut rng = SplitMix64::new(0x5EED_F00D);
        // weighted token soup: structural JSON fragments, the real
        // field names, junk identifiers, numbers (incl. extremes),
        // strings with escapes, and raw garbage bytes
        let atoms: &[&str] = &[
            "{", "}", "[", "]", ":", ",", "\"", "\\",
            "\"prompt\"", "\"max_new_tokens\"", "\"stream\"",
            "\"stats\"", "\"cancel\"", "\"bogus\"",
            "true", "false", "null",
            "0", "1", "-1", "4.5", "1e99", "-1e99", "1e400", "NaN",
            "\"hi\"", "\"\\u0041\"", "\"\\q\"", "\"unterminated",
            "\u{7f}", "\u{e9}", " ", "\t",
        ];
        let mut checked = 0usize;
        for _ in 0..4000 {
            let n = (rng.next_u64() % 12) as usize;
            let mut line = String::new();
            for _ in 0..n {
                line.push_str(
                    atoms[(rng.next_u64() as usize) % atoms.len()]);
            }
            if let Ok(req) = ApiRequest::parse(&line) {
                // parser contract: accepted requests are internally
                // consistent — a prompt-less accept must be a
                // stats/cancel probe, budgets are bounded
                assert!(req.max_new_tokens <= 1_000_000_000,
                        "unbounded budget from {line:?}");
                if req.prompt.is_empty() {
                    // empty prompt is fine only via the probe paths or
                    // an explicit "" prompt
                    assert!(req.stats
                                || req.cancel.is_some()
                                || line.contains("\"prompt\""),
                            "prompt-less accept from {line:?}");
                }
                checked += 1;
            }
        }
        // structured inputs too: every field set to every atom type
        for field in
            ["prompt", "max_new_tokens", "stream", "stats", "cancel"]
        {
            for val in [
                "0", "16", "-3", "2.5", "true", "false", "null",
                "\"x\"", "[1]", "{\"a\":1}", "1e99",
            ] {
                let line = format!("{{\"{field}\": {val}}}");
                let _ = ApiRequest::parse(&line); // must not panic
                let line = format!(
                    "{{\"prompt\": \"p\", \"{field}\": {val}}}");
                let _ = ApiRequest::parse(&line); // must not panic
            }
        }
        // the soup should occasionally assemble something valid — if
        // not, the generator rotted and the fuzz is vacuous
        let _ = checked;
    }
}
