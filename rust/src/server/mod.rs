//! Line-delimited JSON TCP server — the outward face of the coordinator.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"prompt": "hello", "max_new_tokens": 8}
//! ← {"id": 3, "text": "...", "tokens": [..], "latency_ms": 12.3}
//! ```
//!
//! With `"stream": true` the reply is one frame per generated token,
//! terminated by a summary frame carrying the full result (DESIGN.md
//! §12):
//!
//! ```text
//! → {"prompt": "hello", "max_new_tokens": 3, "stream": true}
//! ← {"id": 3, "token": 104}
//! ← {"id": 3, "token": 105}
//! ← {"id": 3, "token": 33}
//! ← {"done": true, "id": 3, "text": "...", "tokens": [..],
//!    "latency_ms": 12.3}
//! ```
//!
//! Disconnects are detected out-of-band by the readiness poller
//! (DESIGN.md §16): a client that hangs up — even mid-prefill, even
//! on a one-shot request still decoding — has its request cancelled
//! immediately, freeing the lane and KV pages for waiting traffic.
//! The protocol therefore requires keeping the connection open until
//! the reply arrives: half-closing the write side counts as hanging
//! up.
//!
//! When the admission backlog is deep (`shed_queue`) or its head has
//! already waited past the SLO (`shed_wait_ms`), new generation
//! requests are refused with a load-shed line instead of queueing
//! unboundedly:
//!
//! ```text
//! → {"prompt": "hello"}
//! ← {"error": "shed", "reason": "queue-depth", "queued": 64,
//!    "oldest_wait_ms": 12}
//! ```
//!
//! `{"stats": true}` answers one introspection line (lane/page
//! occupancy + serving counters, including the elastic-recovery
//! counters of DESIGN.md §17 — `recoveries`, `resizes`,
//! `recovery_stall_ms`, `tokens_lost`) without generating:
//!
//! ```text
//! → {"stats": true}
//! ← {"stats": {"active": 1, "pending": 0, "free_lanes": 1, ...}}
//! ```
//!
//! `{"resize": world}` drives a planned live reshard (DESIGN.md §17):
//! the engine quiesces, rebuilds its rank fleet at the new world size,
//! restores every in-flight lane, and replies once the fleet is
//! serving again — streams in flight stall for the rebuild and then
//! continue bit-identically:
//!
//! ```text
//! → {"resize": 2}
//! ← {"resized": 2, "stall_ms": 840}
//! ```
//!
//! A worker death takes the same path without the request: the engine
//! wrapper ([`crate::engine::elastic::ElasticEngine`]) absorbs the
//! rank failure inside `step`, so connected clients observe a stall in
//! their token stream, **never** an error line or a dropped token.
//!
//! `{"cancel": id}` cancels a request by the id its frames carry —
//! whether it is still queued ahead of the engine, engine-pending, or
//! decoding.  The surface is idempotent: cancelling an id that is
//! unknown, already finished, or already cancelled answers a clean
//! `{"error": ...}` line — never a protocol wedge — and a successful
//! cancel answers `{"cancelled": id}`:
//!
//! ```text
//! → {"cancel": 3}
//! ← {"cancelled": 3}
//! → {"cancel": 3}
//! ← {"error": "cancel: unknown or already finished request id 3"}
//! ```
//!
//! Threading: there is none.  The engine is not `Send` (PJRT buffers
//! are thread-local), and the event-driven design (DESIGN.md §16)
//! makes that a non-issue: one thread runs the readiness poller, the
//! protocol state machine ([`Front`]), and the engine itself, so the
//! engine never crosses a thread and no channel or lock exists to
//! contend on.  Slow readers cannot stall the token loop either —
//! their frames queue in a bounded per-connection [`conn::OutQ`] and
//! the connection is cancelled at overflow.

#![warn(missing_docs)]

pub mod conn;
mod event_loop;

use std::collections::{BTreeMap, HashMap};
use std::net::TcpListener;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::EngineConfig;
use crate::engine::elastic::{ElasticEngine, InprocFactory};
use crate::engine::Engine;
use crate::metrics::ServeStats;
use crate::scheduler::{AdmissionQueue, ShedPolicy};
use crate::tokenizer::Tokenizer;
use crate::util::Json;

/// Identifies one client connection for the [`Front`]: the reactor
/// numbers real sockets, the in-process drivers (benchkit storm, the
/// connection-storm tests) number virtual connections.
pub type ConnId = u64;

/// A parsed API request line.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiRequest {
    /// prompt text (tokenized server-side)
    pub prompt: String,
    /// generation budget; absent defaults to 16
    pub max_new_tokens: usize,
    /// per-token streamed reply frames instead of one-shot (DESIGN.md
    /// §12); absent defaults to false — the old one-shot protocol
    pub stream: bool,
    /// introspection request: answer one `{"stats": {...}}` line
    /// (lane/page occupancy + serving counters) instead of generating;
    /// `prompt` may be omitted
    pub stats: bool,
    /// cancel the request with this engine id instead of generating;
    /// `prompt` may be omitted.  Idempotent at the API surface: an
    /// unknown/finished id answers a clean error line
    pub cancel: Option<u64>,
    /// reshard the running deployment to this world size (DESIGN.md
    /// §17) instead of generating; `prompt` may be omitted.  An
    /// invalid world (0, non-divisible, unsupported) answers a clean
    /// error line and the fleet keeps serving
    pub resize: Option<usize>,
}

impl ApiRequest {
    /// Parse one request line.  Absent fields take their defaults;
    /// present-but-invalid fields are rejected with an error (silently
    /// coercing a malformed value to the default hid client bugs).
    pub fn parse(line: &str) -> Result<ApiRequest> {
        let j = Json::parse(line)?;
        let max_new_tokens = match j.get("max_new_tokens") {
            None => 16,
            Some(v) => {
                let n = v.as_f64().context(
                    "max_new_tokens must be a non-negative integer")?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (0.0..=1e9).contains(&n),
                    "max_new_tokens must be a non-negative integer, \
                     got {n}"
                );
                n as usize
            }
        };
        // strict typing: "stream"/"stats" must be real JSON booleans —
        // a "true" string or a number is a client bug, not an opt-in
        let stream = match j.get("stream") {
            None => false,
            Some(v) => v
                .as_bool()
                .context("stream must be a boolean (true|false)")?,
        };
        let stats = match j.get("stats") {
            None => false,
            Some(v) => v
                .as_bool()
                .context("stats must be a boolean (true|false)")?,
        };
        let cancel = match j.get("cancel") {
            None => None,
            Some(v) => {
                let n = v.as_f64().context(
                    "cancel must be a non-negative integer request id")?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (0.0..=1e18).contains(&n),
                    "cancel must be a non-negative integer request id, \
                     got {n}"
                );
                Some(n as u64)
            }
        };
        let resize = match j.get("resize") {
            None => None,
            Some(v) => {
                let n = v.as_f64().context(
                    "resize must be a positive integer world size")?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (1.0..=4096.0).contains(&n),
                    "resize must be a positive integer world size, \
                     got {n}"
                );
                Some(n as usize)
            }
        };
        let prompt = match j.get("prompt") {
            Some(v) => v
                .as_str()
                .context("prompt must be a string")?
                .to_string(),
            // pure stats/cancel/resize probes need no prompt
            None if stats || cancel.is_some() || resize.is_some() => {
                String::new()
            }
            None => anyhow::bail!("missing JSON key \"prompt\""),
        };
        Ok(ApiRequest {
            prompt,
            max_new_tokens,
            stream,
            stats,
            cancel,
            resize,
        })
    }
}

/// A serialized API response line.
#[derive(Debug, Clone)]
pub struct ApiResponse {
    /// engine request id
    pub id: u64,
    /// decoded output text
    pub text: String,
    /// generated token ids
    pub tokens: Vec<i32>,
    /// end-to-end request latency, milliseconds
    pub latency_ms: f64,
}

impl ApiResponse {
    /// Response fields shared by the one-shot and streamed-final
    /// encodings.
    fn fields(&self) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Num(self.id as f64));
        m.insert("text".to_string(), Json::Str(self.text.clone()));
        m.insert(
            "tokens".to_string(),
            Json::Arr(self.tokens.iter().map(|&t| Json::Num(t as f64))
                .collect()),
        );
        m.insert("latency_ms".to_string(),
                 Json::Num((self.latency_ms * 1e3).round() / 1e3));
        m
    }

    /// The classic one-shot reply line.
    pub fn to_json(&self) -> String {
        Json::Obj(self.fields()).to_string()
    }

    /// The final frame of a streamed reply: the full one-shot summary
    /// plus `"done": true`, so a client can treat the first line with
    /// `done` as end-of-stream.
    pub fn to_done_json(&self) -> String {
        let mut m = self.fields();
        m.insert("done".to_string(), Json::Bool(true));
        Json::Obj(m).to_string()
    }
}

/// One per-token frame of a streamed reply.
pub fn token_json(id: u64, token: i32) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("token".to_string(), Json::Num(token as f64));
    Json::Obj(m).to_string()
}

/// An `{"error": ...}` reply line.
pub fn error_json(msg: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(m).to_string()
}

/// The `{"cancelled": id}` acknowledgement of a successful cancel.
pub fn cancelled_json(id: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("cancelled".to_string(), Json::Num(id as f64));
    Json::Obj(m).to_string()
}

/// The `{"resized": world, "stall_ms": ...}` acknowledgement of a
/// completed planned reshard (DESIGN.md §17): sent once the new fleet
/// is serving, carrying how long in-flight streams stalled.
pub fn resized_json(world: usize, stall_ms: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("resized".to_string(), Json::Num(world as f64));
    m.insert("stall_ms".to_string(), Json::Num(stall_ms as f64));
    Json::Obj(m).to_string()
}

/// The `{"error": "shed", ...}` admission-refusal line (DESIGN.md
/// §16): carries the reason (`queue-depth` or `oldest-wait`) and the
/// occupancy snapshot that triggered it, so a client can implement
/// informed backoff.
pub fn shed_json(reason: &str, queued: usize, oldest_wait_ms: u64)
                 -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Str("shed".to_string()));
    m.insert("reason".to_string(), Json::Str(reason.to_string()));
    m.insert("queued".to_string(), Json::Num(queued as f64));
    m.insert("oldest_wait_ms".to_string(),
             Json::Num(oldest_wait_ms as f64));
    Json::Obj(m).to_string()
}

/// [`Front`]-side bookkeeping for one live request: who to answer,
/// how, and since when.
struct Owner {
    conn: ConnId,
    stream: bool,
    submitted: Instant,
}

/// The transport-agnostic serving state machine (DESIGN.md §16): the
/// engine, its admission queue, the shed policy, and per-request
/// routing, driven by whoever owns the connections — the TCP reactor
/// ([`event_loop`]) in production, virtual-connection drivers in the
/// `connection_storm` bench scenario and test suite.
///
/// The contract is push-in / pull-out: [`Front::on_line`] ingests one
/// request line from a connection, [`Front::on_disconnect`] cancels a
/// connection's outstanding work, [`Front::tick`] advances admission
/// plus one engine step, and every reply line produced along the way
/// accumulates in an outbox drained with [`Front::take_outbox`].
/// Single-threaded by construction — the engine never crosses a
/// thread.
pub struct Front {
    engine: ElasticEngine,
    tok: Tokenizer,
    sched: AdmissionQueue,
    shed: ShedPolicy,
    owners: HashMap<u64, Owner>,
    outbox: Vec<(ConnId, String)>,
    /// serving-layer counters (sheds, frames, frame latency); the
    /// driver records write-side samples here so one struct reports
    /// the whole front
    pub stats: ServeStats,
}

impl Front {
    /// Wrap an engine in the serving state machine; admission policy
    /// and shed bounds come from the engine's own config.  Rank
    /// failures recover onto in-process replacement fleets
    /// ([`InprocFactory`]); deployments with a different fleet shape
    /// use [`Front::new_elastic`].
    pub fn new(engine: Engine) -> Result<Front> {
        Self::new_elastic(ElasticEngine::from_engine(
            engine, Box::new(InprocFactory)))
    }

    /// Wrap an already-elastic engine — the launch coordinator pairs
    /// its remote fleet with a `RelaunchFactory` here (DESIGN.md §17).
    pub fn new_elastic(engine: ElasticEngine) -> Result<Front> {
        let tok = Tokenizer::byte_level(engine.preset().vocab)?;
        let cfg = engine.config();
        let sched = AdmissionQueue::for_kind(
            cfg.scheduler, cfg.batch.max(1), cfg.prefill_chunk);
        let shed = ShedPolicy::from_config(cfg.shed_queue,
                                           cfg.shed_wait_ms);
        Ok(Front {
            engine,
            tok,
            sched,
            shed,
            owners: HashMap::new(),
            outbox: Vec::new(),
            stats: ServeStats::default(),
        })
    }

    /// The engine, for occupancy assertions in tests.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Mutable engine access, for metrics readout after a drive (the
    /// latency quantiles sort lazily and need `&mut`).
    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// The elastic wrapper, for the recovery/reshard counters.
    pub fn elastic(&self) -> &ElasticEngine {
        &self.engine
    }

    /// Requests currently owned by some connection (queued, pending,
    /// or decoding) — the bookkeeping-leak probe the randomized storm
    /// test checks against lane/page conservation.
    pub fn inflight(&self) -> usize {
        self.owners.len()
    }

    /// Requests still queued ahead of the engine.
    pub fn queued(&self) -> usize {
        self.sched.len()
    }

    /// Is there any engine or admission work outstanding?  The reactor
    /// polls with a zero timeout while this holds.
    pub fn has_work(&self) -> bool {
        self.engine.has_work() || !self.sched.is_empty()
    }

    /// Queue a pre-serialized reply line to a connection (also used by
    /// the reactor for read-side protocol errors).
    pub fn reply_raw(&mut self, conn: ConnId, line: String) {
        self.outbox.push((conn, line));
    }

    /// Drain every reply line produced since the last call, in
    /// production order.
    pub fn take_outbox(&mut self) -> Vec<(ConnId, String)> {
        std::mem::take(&mut self.outbox)
    }

    /// Ingest one request line from `conn`.  Control probes (stats,
    /// cancel) answer immediately; generation requests pass the shed
    /// gate and join the admission queue under a pre-allocated engine
    /// id — which makes them cancellable and conserves the id order
    /// the threaded server had (ids monotonic in line-arrival order).
    pub fn on_line(&mut self, conn: ConnId, line: &str) {
        let req = match ApiRequest::parse(line) {
            Ok(req) => req,
            Err(e) => {
                self.reply_raw(conn,
                               error_json(&format!("bad request: {e:#}")));
                return;
            }
        };
        if req.stats {
            let line = self.stats_line();
            self.reply_raw(conn, line);
            return;
        }
        if let Some(id) = req.cancel {
            self.handle_cancel(conn, id);
            return;
        }
        if let Some(world) = req.resize {
            self.handle_resize(conn, world);
            return;
        }
        let (depth, oldest) = self.sched.occupancy();
        if let Some(reason) = self.shed.decision(depth, oldest) {
            self.stats.shed += 1;
            let wait_ms =
                oldest.map(|d| d.as_millis() as u64).unwrap_or(0);
            self.reply_raw(conn, shed_json(reason.as_str(), depth,
                                           wait_ms));
            return;
        }
        let id = self.engine.allocate_id();
        self.sched.submit_with_id(id, self.tok.encode(&req.prompt),
                                  req.max_new_tokens);
        self.owners.insert(id, Owner {
            conn,
            stream: req.stream,
            submitted: Instant::now(),
        });
    }

    /// `{"cancel": id}`: reach the request wherever it lives — still
    /// queued ahead of the engine (the PR 9 satellite bugfix: those
    /// ids were previously uncancellable), engine-pending, or
    /// decoding.  The owning stream gets an `{"error": "cancelled"}`
    /// terminator; the canceller gets the acknowledgement.
    fn handle_cancel(&mut self, conn: ConnId, id: u64) {
        let line = match self.engine.cancel(id) {
            Ok(true) => {
                self.notify_cancelled(id);
                cancelled_json(id)
            }
            Ok(false) if self.sched.cancel(id) => {
                self.notify_cancelled(id);
                cancelled_json(id)
            }
            Ok(false) => error_json(&format!(
                "cancel: unknown or already finished request id {id}")),
            Err(e) => error_json(&format!("cancel: {e:#}")),
        };
        self.reply_raw(conn, line);
    }

    /// `{"resize": world}`: drive a planned live reshard (DESIGN.md
    /// §17).  Runs synchronously on the reactor thread — in-flight
    /// streams stall for exactly the rebuild (that stall is the
    /// figure the acknowledgement carries) and resume on the next
    /// tick.  A refused resize (non-divisible world, unsupported
    /// size) leaves the running fleet untouched.
    fn handle_resize(&mut self, conn: ConnId, world: usize) {
        let line = match self.engine.resize(world) {
            Ok(()) => resized_json(
                world, self.engine.last_recovery_stall_ms()),
            Err(e) => error_json(&format!("resize: {e:#}")),
        };
        self.reply_raw(conn, line);
    }

    /// Terminate a cancelled request's reply stream.
    fn notify_cancelled(&mut self, id: u64) {
        if let Some(o) = self.owners.remove(&id) {
            self.outbox.push((o.conn, error_json("cancelled")));
        }
    }

    /// A connection closed (EOF, HUP, write failure, or outbound-queue
    /// overflow): cancel everything it still owns, wherever each
    /// request lives.  Lanes and KV pages free immediately — this is
    /// the out-of-band reaping the blocking server could only do at
    /// the next token frame.
    pub fn on_disconnect(&mut self, conn: ConnId) {
        let ids: Vec<u64> = self
            .owners
            .iter()
            .filter(|(_, o)| o.conn == conn)
            .map(|(&id, _)| id)
            .collect();
        for id in ids {
            self.owners.remove(&id);
            if self.sched.cancel(id) {
                continue;
            }
            // the client is gone — nobody to report an engine
            // inconsistency to; the error would also surface on the
            // next step
            let _ = self.engine.cancel(id);
        }
    }

    /// Advance the serving side once: admit from the queue under the
    /// configured policy, then run one engine step, routing token
    /// frames and completions into the outbox.  The frame order the
    /// threaded server guaranteed is preserved: every token frame of
    /// a completing request precedes its Done frame.
    pub fn tick(&mut self) -> Result<()> {
        // admit from the scheduler into the engine; the burst guard
        // only throttles when there are actual decode streams to
        // protect (mid-prefill lanes are not them)
        while let Some(q) =
            self.sched.next_admission(self.engine.decoding_count() > 0)
        {
            self.engine.enqueue_reserved(q.id, q.prompt,
                                         q.max_new_tokens.max(1));
        }
        if !self.engine.has_work() {
            return Ok(());
        }
        self.sched.on_decode_round();
        let decode_lanes = self.engine.decoding_count();
        match self.engine.step() {
            Ok(completions) => {
                // speculative steps (DESIGN.md §15) run spec_k draft
                // rounds plus a multi-row verify: charge the rows
                // beyond one-per-decode-lane against the prefill-burst
                // budget so prefills cannot ride a speculation-
                // inflated step as if it were one decode round
                self.sched.charge(self.engine.last_verify_rows()
                                      .saturating_sub(decode_lanes));
                for (eid, t) in self.engine.take_new_tokens() {
                    if let Some(o) = self.owners.get(&eid) {
                        if o.stream {
                            self.outbox.push((o.conn,
                                              token_json(eid, t)));
                        }
                    }
                }
                for c in completions {
                    if let Some(o) = self.owners.remove(&c.request_id) {
                        let resp = ApiResponse {
                            id: c.request_id,
                            text: self.tok.decode(&c.tokens),
                            tokens: c.tokens,
                            latency_ms: o.submitted.elapsed()
                                .as_secs_f64() * 1e3,
                        };
                        let line = if o.stream {
                            resp.to_done_json()
                        } else {
                            resp.to_json()
                        };
                        self.outbox.push((o.conn, line));
                    }
                }
                Ok(())
            }
            Err(e) => {
                // only *unrecoverable* errors reach here: the elastic
                // wrapper absorbs rank failures inside step (clients
                // see a stall, not this line — DESIGN.md §17), so what
                // remains is a genuine engine inconsistency or a fleet
                // that died faster than its recovery budget
                let msg = error_json(&format!("engine: {e:#}"));
                for (_, o) in self.owners.drain() {
                    self.outbox.push((o.conn, msg.clone()));
                }
                Err(e)
            }
        }
    }

    /// The `{"stats": {...}}` introspection reply: lane/page occupancy
    /// plus serving counters, read from the live engine and front.
    /// `queued` is the scheduler-side backlog (submitted but not yet
    /// admitted — the burst guard can hold requests there), `pending`
    /// the engine-side one.  A cancelled request frees its lane and
    /// pages but never increments `requests_done` — which is how the
    /// disconnect tests distinguish cancellation from natural
    /// retirement.
    fn stats_line(&mut self) -> String {
        let mut s = BTreeMap::new();
        let mut put = |k: &str, v: f64| {
            s.insert(k.to_string(), Json::Num(v));
        };
        put("queued", self.sched.len() as f64);
        put("active", self.engine.active_count() as f64);
        put("pending", self.engine.pending_count() as f64);
        put("free_lanes", self.engine.free_lanes() as f64);
        put("free_pages", self.engine.free_pages() as f64);
        put("total_pages", self.engine.total_pages() as f64);
        put("shared_pages", self.engine.shared_pages() as f64);
        put("shared_groups", self.engine.shared_groups() as f64);
        put("requests_done", self.engine.metrics.requests_done as f64);
        put("tokens_out", self.engine.metrics.tokens_out as f64);
        put("prefix_hits", self.engine.metrics.prefix_hits as f64);
        put("prefix_misses", self.engine.metrics.prefix_misses as f64);
        // elastic-recovery counters (DESIGN.md §17): how often the
        // fleet was rebuilt, the last stall, and the tokens-lost
        // invariant (always 0 — recovery replays, never drops)
        put("recoveries", self.engine.recoveries() as f64);
        put("resizes", self.engine.resizes() as f64);
        put("recovery_stall_ms",
            self.engine.last_recovery_stall_ms() as f64);
        put("tokens_lost", self.engine.tokens_lost() as f64);
        // serving-layer counters (DESIGN.md §16)
        put("shed", self.stats.shed as f64);
        put("frames_sent", self.stats.frames_sent as f64);
        put("frame_queue_peak", self.stats.frame_queue_peak as f64);
        put("frame_p99_us", self.stats.frame_lat.p99_us() as f64);
        put("overflow_cancels", self.stats.overflow_cancels as f64);
        let mut m = BTreeMap::new();
        m.insert("stats".to_string(), Json::Obj(s));
        Json::Obj(m).to_string()
    }
}

/// Serve `cfg` on `addr` (e.g. "127.0.0.1:7070") with in-process rank
/// threads.  Runs until the process exits; one reactor thread serves
/// every connection (DESIGN.md §16).  Rank failures recover onto
/// fresh in-process fleets (DESIGN.md §17).
pub fn serve(cfg: EngineConfig, addr: &str) -> Result<()> {
    serve_with(move || ElasticEngine::new_inproc(cfg), addr)
}

/// Serve on `addr` with an elastic engine produced by `build` — the
/// hook the launch coordinator uses to front a fleet of remote rank
/// workers paired with a `RelaunchFactory` (see `crate::launch`).
/// `build` runs on the calling thread, which becomes the reactor
/// thread: the engine never crosses a thread.
pub fn serve_with<F>(build: F, addr: &str) -> Result<()>
where
    F: FnOnce() -> Result<ElasticEngine>,
{
    let engine = build()?;
    let front = Front::new_elastic(engine)?;
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("xeonserve listening on {addr}");
    event_loop::run_reactor(listener, front)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_parsing() {
        let r = ApiRequest::parse(
            r#"{"prompt": "hi", "max_new_tokens": 4}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new_tokens, 4);
        assert!(!r.stream, "stream must default off (one-shot replies)");
        let d = ApiRequest::parse(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(d.max_new_tokens, 16);
        assert!(ApiRequest::parse(r#"{"max_new_tokens": 4}"#).is_err());
        assert!(ApiRequest::parse("not json").is_err());
    }

    #[test]
    fn stream_flag_is_strictly_typed() {
        // real booleans parse...
        let s = ApiRequest::parse(
            r#"{"prompt": "x", "stream": true}"#).unwrap();
        assert!(s.stream);
        let s = ApiRequest::parse(
            r#"{"prompt": "x", "stream": false}"#).unwrap();
        assert!(!s.stream);
        // ...anything else is a clean JSON error, never a coercion
        for bad in [
            r#"{"prompt": "x", "stream": "true"}"#,
            r#"{"prompt": "x", "stream": 1}"#,
            r#"{"prompt": "x", "stream": null}"#,
            r#"{"prompt": "x", "stream": [true]}"#,
        ] {
            let e = ApiRequest::parse(bad);
            assert!(e.is_err(), "accepted {bad}");
            assert!(format!("{:#}", e.unwrap_err()).contains("stream"),
                    "error should name the bad field for {bad}");
        }
    }

    #[test]
    fn stats_flag_is_strictly_typed_and_needs_no_prompt() {
        let s = ApiRequest::parse(r#"{"stats": true}"#).unwrap();
        assert!(s.stats);
        assert!(s.prompt.is_empty());
        // a prompt alongside stats is tolerated (and ignored upstream)
        let s = ApiRequest::parse(
            r#"{"prompt": "x", "stats": false}"#).unwrap();
        assert!(!s.stats);
        // non-bools are clean errors; stats=false still needs a prompt
        assert!(ApiRequest::parse(r#"{"stats": 1}"#).is_err());
        assert!(ApiRequest::parse(r#"{"stats": "yes"}"#).is_err());
        assert!(ApiRequest::parse(r#"{"stats": false}"#).is_err());
    }

    #[test]
    fn stream_frames_are_valid_json() {
        let t = Json::parse(&token_json(7, 104)).unwrap();
        assert_eq!(t.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(t.get("token").unwrap().as_f64(), Some(104.0));
        assert!(t.get("done").is_none());

        let r = ApiResponse {
            id: 7,
            text: "hi".into(),
            tokens: vec![104, 105],
            latency_ms: 1.5,
        };
        let d = Json::parse(&r.to_done_json()).unwrap();
        assert_eq!(d.get("done").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(d.get("text").unwrap().as_str(), Some("hi"));
        assert_eq!(d.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        // the one-shot encoding never carries "done"
        assert!(Json::parse(&r.to_json()).unwrap().get("done").is_none());
    }

    #[test]
    fn invalid_max_new_tokens_rejected_not_coerced() {
        // present-but-invalid values must error (previously they were
        // silently coerced to the 16-token default)
        for bad in [
            r#"{"prompt": "x", "max_new_tokens": "4"}"#,
            r#"{"prompt": "x", "max_new_tokens": 4.5}"#,
            r#"{"prompt": "x", "max_new_tokens": -1}"#,
            r#"{"prompt": "x", "max_new_tokens": true}"#,
            r#"{"prompt": "x", "max_new_tokens": null}"#,
            r#"{"prompt": "x", "max_new_tokens": [4]}"#,
        ] {
            assert!(ApiRequest::parse(bad).is_err(), "accepted {bad}");
        }
        // explicit integers — including 0 — are fine (the engine layer
        // clamps 0 to a single-token generation)
        let z = ApiRequest::parse(r#"{"prompt": "x", "max_new_tokens": 0}"#)
            .unwrap();
        assert_eq!(z.max_new_tokens, 0);
    }

    #[test]
    fn response_roundtrips_through_json() {
        let r = ApiResponse {
            id: 3,
            text: "ab\"c".into(),
            tokens: vec![97, 98],
            latency_ms: 12.3456,
        };
        let j = Json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("text").unwrap().as_str(), Some("ab\"c"));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn error_json_is_valid() {
        let j = Json::parse(&error_json("boom \"quoted\"")).unwrap();
        assert!(j.get("error").unwrap().as_str().unwrap().contains("boom"));
    }

    #[test]
    fn shed_json_carries_reason_and_occupancy() {
        let j = Json::parse(&shed_json("queue-depth", 64, 12)).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("shed"));
        assert_eq!(j.get("reason").unwrap().as_str(), Some("queue-depth"));
        assert_eq!(j.get("queued").unwrap().as_u64(), Some(64));
        assert_eq!(j.get("oldest_wait_ms").unwrap().as_u64(), Some(12));
        // shed lines must never be mistaken for a generation reply
        assert!(j.get("done").is_none());
        assert!(j.get("token").is_none());
    }

    #[test]
    fn cancel_field_is_strictly_typed_and_needs_no_prompt() {
        let c = ApiRequest::parse(r#"{"cancel": 3}"#).unwrap();
        assert_eq!(c.cancel, Some(3));
        assert!(c.prompt.is_empty());
        let c = ApiRequest::parse(r#"{"cancel": 0}"#).unwrap();
        assert_eq!(c.cancel, Some(0));
        // absent on ordinary requests
        let r = ApiRequest::parse(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(r.cancel, None);
        // non-integers and negatives are clean errors, never coercions
        for bad in [
            r#"{"cancel": "3"}"#,
            r#"{"cancel": 3.5}"#,
            r#"{"cancel": -1}"#,
            r#"{"cancel": true}"#,
            r#"{"cancel": null}"#,
            r#"{"cancel": [3]}"#,
        ] {
            let e = ApiRequest::parse(bad);
            assert!(e.is_err(), "accepted {bad}");
            assert!(format!("{:#}", e.unwrap_err()).contains("cancel"),
                    "error should name the bad field for {bad}");
        }
        let j = Json::parse(&cancelled_json(7)).unwrap();
        assert_eq!(j.get("cancelled").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn resize_field_is_strictly_typed_and_needs_no_prompt() {
        let r = ApiRequest::parse(r#"{"resize": 2}"#).unwrap();
        assert_eq!(r.resize, Some(2));
        assert!(r.prompt.is_empty());
        // absent on ordinary requests
        let r = ApiRequest::parse(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(r.resize, None);
        // zero, negatives, non-integers: clean errors, never coercions
        for bad in [
            r#"{"resize": 0}"#,
            r#"{"resize": -2}"#,
            r#"{"resize": 2.5}"#,
            r#"{"resize": "2"}"#,
            r#"{"resize": true}"#,
            r#"{"resize": null}"#,
            r#"{"resize": [2]}"#,
            r#"{"resize": 1e9}"#,
        ] {
            let e = ApiRequest::parse(bad);
            assert!(e.is_err(), "accepted {bad}");
            assert!(format!("{:#}", e.unwrap_err()).contains("resize"),
                    "error should name the bad field for {bad}");
        }
        let j = Json::parse(&resized_json(2, 840)).unwrap();
        assert_eq!(j.get("resized").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("stall_ms").unwrap().as_u64(), Some(840));
        // a resize ack must never be mistaken for a generation reply
        assert!(j.get("done").is_none());
        assert!(j.get("token").is_none());
    }

    /// Satellite: seeded random-JSON fuzz of [`ApiRequest::parse`].
    /// Every input must yield either a valid request or a clean JSON
    /// error — never a panic (the `#[test]` harness turns any panic
    /// into a failure) — and accepted requests must satisfy the field
    /// invariants the parser promises.
    #[test]
    fn parse_never_panics_on_seeded_random_json() {
        use crate::util::SplitMix64;

        let mut rng = SplitMix64::new(0x5EED_F00D);
        // weighted token soup: structural JSON fragments, the real
        // field names, junk identifiers, numbers (incl. extremes),
        // strings with escapes, and raw garbage bytes
        let atoms: &[&str] = &[
            "{", "}", "[", "]", ":", ",", "\"", "\\",
            "\"prompt\"", "\"max_new_tokens\"", "\"stream\"",
            "\"stats\"", "\"cancel\"", "\"resize\"", "\"bogus\"",
            "true", "false", "null",
            "0", "1", "-1", "4.5", "1e99", "-1e99", "1e400", "NaN",
            "\"hi\"", "\"\\u0041\"", "\"\\q\"", "\"unterminated",
            "\u{7f}", "\u{e9}", " ", "\t",
        ];
        let mut checked = 0usize;
        for _ in 0..4000 {
            let n = (rng.next_u64() % 12) as usize;
            let mut line = String::new();
            for _ in 0..n {
                line.push_str(
                    atoms[(rng.next_u64() as usize) % atoms.len()]);
            }
            if let Ok(req) = ApiRequest::parse(&line) {
                // parser contract: accepted requests are internally
                // consistent — a prompt-less accept must be a
                // stats/cancel probe, budgets are bounded
                assert!(req.max_new_tokens <= 1_000_000_000,
                        "unbounded budget from {line:?}");
                if req.prompt.is_empty() {
                    // empty prompt is fine only via the probe paths or
                    // an explicit "" prompt
                    assert!(req.stats
                                || req.cancel.is_some()
                                || req.resize.is_some()
                                || line.contains("\"prompt\""),
                            "prompt-less accept from {line:?}");
                }
                checked += 1;
            }
        }
        // structured inputs too: every field set to every atom type
        for field in ["prompt", "max_new_tokens", "stream", "stats",
                      "cancel", "resize"]
        {
            for val in [
                "0", "16", "-3", "2.5", "true", "false", "null",
                "\"x\"", "[1]", "{\"a\":1}", "1e99",
            ] {
                let line = format!("{{\"{field}\": {val}}}");
                let _ = ApiRequest::parse(&line); // must not panic
                let line = format!(
                    "{{\"prompt\": \"p\", \"{field}\": {val}}}");
                let _ = ApiRequest::parse(&line); // must not panic
            }
        }
        // the soup should occasionally assemble something valid — if
        // not, the generator rotted and the fuzz is vacuous
        let _ = checked;
    }
}
