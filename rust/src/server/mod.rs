//! Line-delimited JSON TCP server — the outward face of the coordinator.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"prompt": "hello", "max_new_tokens": 8}
//! ← {"id": 3, "text": "...", "tokens": [..], "latency_ms": 12.3}
//! ```
//!
//! Threading: the engine is not `Send` (PJRT buffers are thread-local),
//! so it runs on a dedicated thread; connection threads submit jobs over
//! a channel and block on per-job reply channels.  This mirrors the
//! paper's topology — one leader process front-ending the rank workers.
//! (std::net threads; the offline build environment has no tokio.)

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::EngineConfig;
use crate::engine::Engine;
use crate::scheduler::FcfsScheduler;
use crate::tokenizer::Tokenizer;
use crate::util::Json;

/// A parsed API request line.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
}

impl ApiRequest {
    pub fn parse(line: &str) -> Result<ApiRequest> {
        let j = Json::parse(line)?;
        // absent => default; present-but-invalid => reject.  Silently
        // coercing a malformed value to the default hid client bugs.
        let max_new_tokens = match j.get("max_new_tokens") {
            None => 16,
            Some(v) => {
                let n = v.as_f64().context(
                    "max_new_tokens must be a non-negative integer")?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (0.0..=1e9).contains(&n),
                    "max_new_tokens must be a non-negative integer, \
                     got {n}"
                );
                n as usize
            }
        };
        Ok(ApiRequest {
            prompt: j
                .req("prompt")?
                .as_str()
                .context("prompt must be a string")?
                .to_string(),
            max_new_tokens,
        })
    }
}

/// A serialized API response line.
#[derive(Debug, Clone)]
pub struct ApiResponse {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<i32>,
    pub latency_ms: f64,
}

impl ApiResponse {
    pub fn to_json(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Num(self.id as f64));
        m.insert("text".to_string(), Json::Str(self.text.clone()));
        m.insert(
            "tokens".to_string(),
            Json::Arr(self.tokens.iter().map(|&t| Json::Num(t as f64))
                .collect()),
        );
        m.insert("latency_ms".to_string(),
                 Json::Num((self.latency_ms * 1e3).round() / 1e3));
        Json::Obj(m).to_string()
    }
}

pub fn error_json(msg: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(m).to_string()
}

struct Job {
    req: ApiRequest,
    respond: Sender<std::result::Result<ApiResponse, String>>,
    submitted: Instant,
}

/// Engine thread: admits jobs through the FCFS scheduler, steps the
/// engine (continuous batching happens inside), and answers completions.
fn engine_loop(mut engine: Engine, jobs: Receiver<Job>) -> Result<()> {
    let tok = Tokenizer::byte_level(engine.preset().vocab)?;
    let mut sched = FcfsScheduler::new(engine.config().batch.max(1));
    let mut waiting: std::collections::HashMap<
        u64,
        (Sender<std::result::Result<ApiResponse, String>>, Instant),
    > = Default::default();
    // scheduler-id -> engine-id indirection
    let mut pending_jobs: std::collections::HashMap<u64, Job> =
        Default::default();

    loop {
        // ingest every queued job without blocking; block when idle
        loop {
            let job = if engine.has_work() || !sched.is_empty() {
                match jobs.try_recv() {
                    Ok(j) => Some(j),
                    Err(std::sync::mpsc::TryRecvError::Empty) => None,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        return Ok(());
                    }
                }
            } else {
                match jobs.recv() {
                    Ok(j) => Some(j),
                    Err(_) => return Ok(()),
                }
            };
            match job {
                Some(job) => {
                    let sid = sched.submit(tok.encode(&job.req.prompt),
                                           job.req.max_new_tokens);
                    pending_jobs.insert(sid, job);
                }
                None => break,
            }
        }

        // admit from the scheduler into the engine
        while let Some(q) =
            sched.next_admission(engine.active_count() > 0)
        {
            let eid = engine.enqueue(q.prompt, q.max_new_tokens.max(1));
            if let Some(job) = pending_jobs.remove(&q.id) {
                waiting.insert(eid, (job.respond, job.submitted));
            }
        }

        if engine.has_work() {
            sched.on_decode_round();
            match engine.step() {
                Ok(completions) => {
                    for c in completions {
                        if let Some((tx, t0)) = waiting.remove(&c.request_id)
                        {
                            let resp = ApiResponse {
                                id: c.request_id,
                                text: tok.decode(&c.tokens),
                                tokens: c.tokens,
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                            };
                            let _ = tx.send(Ok(resp));
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("engine: {e:#}");
                    for (_, (tx, _)) in waiting.drain() {
                        let _ = tx.send(Err(msg.clone()));
                    }
                    return Err(e);
                }
            }
        }
    }
}

fn handle_conn(stream: TcpStream, job_tx: Sender<Job>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let out = match ApiRequest::parse(&line) {
            Ok(req) => {
                let (tx, rx) = channel();
                if job_tx
                    .send(Job { req, respond: tx, submitted: Instant::now() })
                    .is_err()
                {
                    error_json("engine thread gone")
                } else {
                    match rx.recv() {
                        Ok(Ok(resp)) => resp.to_json(),
                        Ok(Err(e)) => error_json(&e),
                        Err(_) => error_json("engine dropped request"),
                    }
                }
            }
            Err(e) => error_json(&format!("bad request from {peer}: {e}")),
        };
        writer.write_all(out.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

/// Serve `cfg` on `addr` (e.g. "127.0.0.1:7070") with in-process rank
/// threads.  Runs until the process exits; one thread per connection.
pub fn serve(cfg: EngineConfig, addr: &str) -> Result<()> {
    serve_with(move || Engine::new(cfg), addr)
}

/// Serve on `addr` with an engine produced by `build` — the hook the
/// launch coordinator uses to front a fleet of remote rank workers
/// (see `crate::launch`).  `build` runs on the dedicated engine thread,
/// so the engine never has to cross threads.
pub fn serve_with<F>(build: F, addr: &str) -> Result<()>
where
    F: FnOnce() -> Result<Engine> + Send + 'static,
{
    let (job_tx, job_rx) = channel::<Job>();
    std::thread::Builder::new()
        .name("engine".into())
        .spawn(move || {
            let engine = match build() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("engine bring-up failed: {e:#}");
                    return;
                }
            };
            if let Err(e) = engine_loop(engine, job_rx) {
                eprintln!("engine loop failed: {e:#}");
            }
        })?;

    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("xeonserve listening on {addr}");
    loop {
        let (socket, peer) = listener.accept()?;
        let job_tx = job_tx.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(socket, job_tx) {
                eprintln!("conn {peer}: {e:#}");
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_parsing() {
        let r = ApiRequest::parse(
            r#"{"prompt": "hi", "max_new_tokens": 4}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new_tokens, 4);
        let d = ApiRequest::parse(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(d.max_new_tokens, 16);
        assert!(ApiRequest::parse(r#"{"max_new_tokens": 4}"#).is_err());
        assert!(ApiRequest::parse("not json").is_err());
    }

    #[test]
    fn invalid_max_new_tokens_rejected_not_coerced() {
        // present-but-invalid values must error (previously they were
        // silently coerced to the 16-token default)
        for bad in [
            r#"{"prompt": "x", "max_new_tokens": "4"}"#,
            r#"{"prompt": "x", "max_new_tokens": 4.5}"#,
            r#"{"prompt": "x", "max_new_tokens": -1}"#,
            r#"{"prompt": "x", "max_new_tokens": true}"#,
            r#"{"prompt": "x", "max_new_tokens": null}"#,
            r#"{"prompt": "x", "max_new_tokens": [4]}"#,
        ] {
            assert!(ApiRequest::parse(bad).is_err(), "accepted {bad}");
        }
        // explicit integers — including 0 — are fine (the engine layer
        // clamps 0 to a single-token generation)
        let z = ApiRequest::parse(r#"{"prompt": "x", "max_new_tokens": 0}"#)
            .unwrap();
        assert_eq!(z.max_new_tokens, 0);
    }

    #[test]
    fn response_roundtrips_through_json() {
        let r = ApiResponse {
            id: 3,
            text: "ab\"c".into(),
            tokens: vec![97, 98],
            latency_ms: 12.3456,
        };
        let j = Json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("text").unwrap().as_str(), Some("ab\"c"));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn error_json_is_valid() {
        let j = Json::parse(&error_json("boom \"quoted\"")).unwrap();
        assert!(j.get("error").unwrap().as_str().unwrap().contains("boom"));
    }
}
