//! Per-connection nonblocking I/O primitives for the event-driven
//! server (DESIGN.md §16): bounded line assembly on the read side and
//! a bounded outbound frame queue with partial-write resume on the
//! write side.  Both are plain byte-level state machines with no
//! socket dependency, so the reactor ([`super::event_loop`]), the
//! in-process storm driver (`benchkit`), and the unit tests below all
//! drive the exact same code.

use std::collections::VecDeque;
use std::io::{self, Write};
use std::time::Instant;

use crate::metrics::ServeStats;

/// Longest accepted request line, in bytes.  A line that grows past
/// this bound is discarded up to its terminating newline and reported
/// as [`LineEvent::Oversized`] — the connection survives, memory does
/// not grow with hostile input (slowloris / log-bomb clients).
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Most frames a connection's outbound queue may hold before the
/// backpressure policy gives up on the reader (DESIGN.md §16):
/// a slow reader's frames queue up to here, then its work is
/// cancelled — the engine never blocks on one socket.
pub const MAX_OUT_FRAMES: usize = 1024;

/// Most queued outbound bytes per connection (same overflow policy as
/// [`MAX_OUT_FRAMES`], catching few-but-huge frames).
pub const MAX_OUT_BYTES: usize = 1 << 20;

/// One read-side event from [`LineReader::push`].
#[derive(Debug, PartialEq, Eq)]
pub enum LineEvent {
    /// a complete newline-terminated line (terminator stripped,
    /// invalid UTF-8 replaced)
    Line(String),
    /// a line exceeded [`LineReader`]'s bound and was discarded;
    /// reported once per oversized line, when the bound is crossed
    Oversized,
}

/// Bounded incremental line assembler over nonblocking reads.
///
/// Feed it whatever `read(2)` returned; it hands back complete lines.
/// A line longer than `max_line` bytes flips the reader into discard
/// mode until the next newline: the partial bytes are dropped, one
/// [`LineEvent::Oversized`] is reported, and the following line
/// parses normally — a hostile writer can never grow the buffer past
/// the bound.
#[derive(Debug)]
pub struct LineReader {
    buf: Vec<u8>,
    discarding: bool,
    max_line: usize,
}

impl LineReader {
    /// A reader that accepts lines up to `max_line` bytes.
    pub fn new(max_line: usize) -> LineReader {
        LineReader { buf: Vec::new(), discarding: false, max_line }
    }

    /// Bytes currently buffered toward an incomplete line (bounded by
    /// `max_line` — the overflow test pins this).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Feed freshly read bytes; returns the events they complete, in
    /// order.
    pub fn push(&mut self, data: &[u8]) -> Vec<LineEvent> {
        let mut out = Vec::new();
        for &b in data {
            if b == b'\n' {
                if self.discarding {
                    // the oversized line just ended; resume normally
                    self.discarding = false;
                } else {
                    let line = std::mem::take(&mut self.buf);
                    out.push(LineEvent::Line(
                        String::from_utf8_lossy(&line).into_owned()));
                }
                continue;
            }
            if self.discarding {
                continue;
            }
            self.buf.push(b);
            if self.buf.len() > self.max_line {
                self.buf.clear();
                self.buf.shrink_to_fit();
                self.discarding = true;
                out.push(LineEvent::Oversized);
            }
        }
        out
    }
}

/// The error [`OutQ::push`] reports when a connection's outbound
/// queue is full: the reader is too slow, and per the backpressure
/// policy its work gets cancelled rather than the engine blocked.
#[derive(Debug, PartialEq, Eq)]
pub struct Overflow;

/// Bounded per-connection outbound frame queue with partial-write
/// resume.
///
/// Frames (reply lines) enter via [`OutQ::push`], stamped with their
/// enqueue time; [`OutQ::flush`] writes as much as the socket accepts
/// — `WouldBlock` mid-frame leaves a cursor so the next flush resumes
/// at the exact byte — and records each fully-written frame's
/// delivery latency into [`ServeStats`].  [`OutQ::pop_frame`] is the
/// socketless drain the virtual-connection drivers use.
#[derive(Debug)]
pub struct OutQ {
    frames: VecDeque<(Vec<u8>, Instant)>,
    /// bytes of the front frame already written
    cursor: usize,
    queued_bytes: usize,
    max_frames: usize,
    max_bytes: usize,
}

impl OutQ {
    /// A queue bounded to `max_frames` frames / `max_bytes` bytes.
    pub fn new(max_frames: usize, max_bytes: usize) -> OutQ {
        OutQ {
            frames: VecDeque::new(),
            cursor: 0,
            queued_bytes: 0,
            max_frames: max_frames.max(1),
            max_bytes: max_bytes.max(1),
        }
    }

    /// Queued frames.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// Is the queue fully drained?
    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Queued bytes not yet written.
    pub fn queued_bytes(&self) -> usize {
        self.queued_bytes - self.cursor
    }

    /// Enqueue one reply line (newline appended here).  `Err` means
    /// the bound is blown: the caller cancels this connection's work.
    pub fn push(&mut self, line: &str, now: Instant)
                -> Result<(), Overflow> {
        let frame_bytes = line.len() + 1;
        if self.frames.len() >= self.max_frames
            || self.queued_bytes + frame_bytes > self.max_bytes
        {
            return Err(Overflow);
        }
        let mut frame = Vec::with_capacity(frame_bytes);
        frame.extend_from_slice(line.as_bytes());
        frame.push(b'\n');
        self.queued_bytes += frame_bytes;
        self.frames.push_back((frame, now));
        Ok(())
    }

    /// Write queued frames until the sink stops accepting bytes
    /// (`WouldBlock`, reported as `Ok`) or the queue drains.  Real
    /// socket errors surface as `Err` — the connection is dead.
    pub fn flush(&mut self, w: &mut dyn Write, stats: &mut ServeStats)
                 -> io::Result<()> {
        while let Some((frame, enqueued)) = self.frames.front() {
            match w.write(&frame[self.cursor..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "socket accepted zero bytes"));
                }
                Ok(n) => {
                    self.cursor += n;
                    if self.cursor == frame.len() {
                        stats.record_frame(enqueued.elapsed());
                        self.queued_bytes -= frame.len();
                        self.cursor = 0;
                        self.frames.pop_front();
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    return Ok(());
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Dequeue the front frame whole (newline stripped) with its
    /// enqueue time — the virtual-connection drain used by the
    /// in-process storm driver and tests.  Partial socket writes never
    /// mix with this path on one queue.
    pub fn pop_frame(&mut self) -> Option<(String, Instant)> {
        let (mut frame, enqueued) = self.frames.pop_front()?;
        self.queued_bytes -= frame.len();
        if frame.last() == Some(&b'\n') {
            frame.pop();
        }
        Some((String::from_utf8_lossy(&frame).into_owned(), enqueued))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_reader_assembles_split_lines_in_order() {
        let mut r = LineReader::new(64);
        assert!(r.push(b"{\"a\":").is_empty());
        assert_eq!(r.buffered(), 6);
        let evs = r.push(b"1}\nsecond\nthi");
        assert_eq!(evs, vec![
            LineEvent::Line("{\"a\":1}".into()),
            LineEvent::Line("second".into()),
        ]);
        assert_eq!(r.push(b"rd\n"),
                   vec![LineEvent::Line("third".into())]);
        assert_eq!(r.buffered(), 0);
        // empty lines are real (the server skips them upstream)
        assert_eq!(r.push(b"\n\n"),
                   vec![LineEvent::Line(String::new()),
                        LineEvent::Line(String::new())]);
    }

    #[test]
    fn oversized_line_is_discarded_once_and_reader_recovers() {
        let mut r = LineReader::new(8);
        // 9 bytes crosses the bound mid-line: one Oversized event, and
        // the buffer must not keep growing with further bytes
        let evs = r.push(b"012345678");
        assert_eq!(evs, vec![LineEvent::Oversized]);
        assert!(r.push(b"_more_garbage_no_second_event").is_empty(),
                "discard mode must report the oversized line once");
        assert_eq!(r.buffered(), 0, "discarded bytes must not buffer");
        // the newline ends the bad line; the next one parses normally
        let evs = r.push(b"tail\nok\n");
        assert_eq!(evs, vec![LineEvent::Line("ok".into())]);
    }

    #[test]
    fn line_reader_buffer_stays_bounded_under_slowloris_drip() {
        // a hostile writer dripping one byte at a time, never sending
        // a newline: memory must stay at the bound, forever
        let mut r = LineReader::new(16);
        let mut oversized = 0;
        for _ in 0..10_000 {
            for ev in r.push(b"x") {
                assert_eq!(ev, LineEvent::Oversized);
                oversized += 1;
            }
            assert!(r.buffered() <= 16);
        }
        assert_eq!(oversized, 1, "one event per oversized line");
    }

    #[test]
    fn invalid_utf8_is_replaced_not_fatal() {
        let mut r = LineReader::new(64);
        let evs = r.push(b"ab\xff\xfecd\n");
        assert_eq!(evs.len(), 1);
        match &evs[0] {
            LineEvent::Line(l) => {
                assert!(l.starts_with("ab") && l.ends_with("cd"));
            }
            other => panic!("unexpected event {other:?}"),
        }
    }

    /// A sink that accepts at most `cap` bytes per write call and can
    /// be switched to refuse with `WouldBlock` — a deterministic model
    /// of a nonblocking socket with a tiny send buffer.
    struct ThrottledSink {
        written: Vec<u8>,
        cap: usize,
        blocked: bool,
    }

    impl Write for ThrottledSink {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.blocked {
                return Err(io::Error::new(
                    io::ErrorKind::WouldBlock, "full"));
            }
            let n = buf.len().min(self.cap);
            self.written.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn outq_resumes_partial_writes_at_the_exact_byte() {
        let mut q = OutQ::new(8, 1024);
        let now = Instant::now();
        q.push("hello", now).unwrap();
        q.push("world!", now).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.queued_bytes(), 13); // 2 newlines included

        let mut sink =
            ThrottledSink { written: Vec::new(), cap: 4, blocked: false };
        let mut stats = ServeStats::default();
        q.flush(&mut sink, &mut stats).unwrap();
        // 4-byte write calls, drained to completion within one flush
        assert_eq!(sink.written, b"hello\nworld!\n");
        assert!(q.is_empty());
        assert_eq!(q.queued_bytes(), 0);
        assert_eq!(stats.frames_sent, 2);
        assert_eq!(stats.frame_lat.count(), 2);

        // WouldBlock mid-frame: cursor holds, nothing is lost or
        // duplicated when the socket opens up again
        q.push("abcdefgh", Instant::now()).unwrap();
        let mut sink =
            ThrottledSink { written: Vec::new(), cap: 3, blocked: false };
        // accept one 3-byte write, then block
        let n = {
            let (frame, _) = q.frames.front().unwrap();
            sink.write(&frame[..]).unwrap()
        };
        q.cursor = n; // simulate the partial write the flush path does
        sink.blocked = true;
        q.flush(&mut sink, &mut stats).unwrap(); // WouldBlock == Ok
        assert_eq!(q.len(), 1, "partially written frame must stay");
        sink.blocked = false;
        q.flush(&mut sink, &mut stats).unwrap();
        assert_eq!(sink.written, b"abcdefgh\n");
        assert!(q.is_empty());
    }

    #[test]
    fn outq_overflow_at_frame_and_byte_bounds() {
        // frame-count bound
        let mut q = OutQ::new(2, 1024);
        let now = Instant::now();
        q.push("a", now).unwrap();
        q.push("b", now).unwrap();
        assert_eq!(q.push("c", now), Err(Overflow));
        assert_eq!(q.len(), 2, "overflowing push must not enqueue");

        // byte bound: 10 bytes max, "12345678" + newline = 9 fits,
        // one more byte does not
        let mut q = OutQ::new(64, 10);
        q.push("12345678", now).unwrap();
        assert_eq!(q.push("", now), Err(Overflow));
        // draining reopens capacity
        let mut stats = ServeStats::default();
        let mut sink = ThrottledSink {
            written: Vec::new(), cap: 1024, blocked: false };
        q.flush(&mut sink, &mut stats).unwrap();
        q.push("ok", now).unwrap();
    }

    #[test]
    fn outq_pop_frame_strips_newline_and_tracks_bytes() {
        let mut q = OutQ::new(8, 1024);
        q.push("{\"id\":1}", Instant::now()).unwrap();
        q.push("{\"id\":2}", Instant::now()).unwrap();
        let (l1, t1) = q.pop_frame().unwrap();
        assert_eq!(l1, "{\"id\":1}");
        assert!(t1.elapsed().as_secs() < 3600);
        assert_eq!(q.pop_frame().unwrap().0, "{\"id\":2}");
        assert!(q.pop_frame().is_none());
        assert_eq!(q.queued_bytes(), 0);
    }

    #[test]
    fn outq_write_error_is_fatal_not_silent() {
        struct BrokenPipe;
        impl Write for BrokenPipe {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                Err(io::Error::new(io::ErrorKind::BrokenPipe, "gone"))
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut q = OutQ::new(8, 1024);
        q.push("x", Instant::now()).unwrap();
        let mut stats = ServeStats::default();
        assert!(q.flush(&mut BrokenPipe, &mut stats).is_err());
        assert_eq!(stats.frames_sent, 0);
    }
}
