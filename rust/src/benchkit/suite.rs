//! The serving-level benchmark suite behind `xeonserve bench`
//! (DESIGN.md §10).
//!
//! A [`Scenario`] is a named, deterministic workload (batch shape,
//! prompt/output length mix) driven through the full [`Engine`] —
//! rank workers, collectives, continuous batching, sampling — exactly
//! like production traffic.  [`run_matrix`] sweeps the standard
//! scenarios over tensor-parallel world sizes plus the scalar-kernel
//! baseline and the int8 weights+KV rows (DESIGN.md §11), and the
//! results serialize to the stable `xeonserve-bench/v1` JSON schema
//! (`BENCH_*.json`) — every row carrying its dtype and measured
//! resident bytes — so any later PR can diff its hot-path numbers
//! against the recorded trajectory.
//!
//! Scenario → paper mapping (DESIGN.md §10 has the full table):
//! `single_stream_decode` mirrors the §3 headline measurement
//! (batch 1, long decode — the 140 ms/token row), `batched_decode`
//! the throughput view, `prefill_heavy` the first-token path, and
//! `mixed` a serving mix of all three.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::backend::pool::auto_threads;
use crate::backend::simd::{self, Isa};
use crate::benchkit::CaseResult;
use crate::ccl::StatsSnapshot;
use crate::config::{BackendKind, Dtype, EngineConfig, GemmKernel,
                    IsaKind, SchedulerKind};
use crate::engine::elastic::{ChaosFactory, ElasticEngine};
use crate::engine::{Completion, Engine};
use crate::server::conn::OutQ;
use crate::server::Front;
use crate::util::Json;

/// Identifier of the scenario-suite JSON schema this module emits and
/// [`validate_bench`] accepts.
pub const SCHEMA: &str = "xeonserve-bench/v1";

/// In a [`Scenario`]'s `prompt_lens`, the sentinel meaning "as long as
/// the model's largest prefill bucket" (resolved per model at run
/// time, so one suite definition covers every preset).
pub const PROMPT_FILL_BUCKET: usize = 0;

/// One named, deterministic serving workload.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// stable scenario name (a schema key — do not rename casually)
    pub name: String,
    /// decode batch lanes the engine is configured with
    pub batch: usize,
    /// total requests enqueued (continuous batching refills lanes)
    pub requests: usize,
    /// per-request prompt lengths, cycled; [`PROMPT_FILL_BUCKET`]
    /// resolves to the model's largest prefill bucket
    pub prompt_lens: Vec<usize>,
    /// per-request `max_new_tokens`, cycled
    pub new_tokens: Vec<usize>,
    /// first `shared_prefix_len` prompt tokens are identical across
    /// every request (a system-prompt workload — DESIGN.md §13); 0
    /// means fully independent prompts
    pub shared_prefix_len: usize,
}

impl Scenario {
    fn new(name: &str, batch: usize, requests: usize,
           prompt_lens: &[usize], new_tokens: &[usize]) -> Scenario {
        Scenario {
            name: name.to_string(),
            batch,
            requests,
            prompt_lens: prompt_lens.to_vec(),
            new_tokens: new_tokens.to_vec(),
            shared_prefix_len: 0,
        }
    }

    fn with_shared_prefix(mut self, len: usize) -> Scenario {
        self.shared_prefix_len = len;
        self
    }

    /// Shrink the workload for CI smoke runs (`--quick`): fewer
    /// requests and shorter decodes, same shapes.  At least one full
    /// cycle of `prompt_lens` is kept, so a workload built around a
    /// late-arriving request (`long_prompt_interactive`'s injected
    /// long prompt at index `batch`) still exercises it while
    /// single-shape scenarios shrink to the lane count as before.
    pub fn quicken(mut self) -> Scenario {
        let keep = self.prompt_lens.len().max(self.batch).max(2);
        self.requests = self.requests.min(keep);
        for n in &mut self.new_tokens {
            *n = (*n / 4).max(4);
        }
        self
    }
}

/// Chunk size (tokens) of the chunked `long_prompt_interactive` row
/// [`run_matrix`] records next to the whole-prompt baseline — fixed,
/// like the 2-thread default, so recordings stay comparable across
/// machines (DESIGN.md §12).
pub const BENCH_PREFILL_CHUNK: usize = 16;

/// The standard scenario suite every `BENCH_*.json` records.
pub fn standard_suite() -> Vec<Scenario> {
    vec![
        // the paper's §3 headline shape: one stream, decode-dominated
        Scenario::new("single_stream_decode", 1, 2, &[8], &[32]),
        // batched decode: the blocked-GEMM headline (weights stream
        // once per step instead of once per row)
        Scenario::new("batched_decode", 4, 8, &[8], &[32]),
        // prefill-dominated: long prompts, almost no decode
        Scenario::new("prefill_heavy", 2, 6, &[PROMPT_FILL_BUCKET], &[4]),
        // a serving mix of short/long prompts and outputs
        Scenario::new(
            "mixed", 4, 10,
            &[2, 8, PROMPT_FILL_BUCKET, 5],
            &[8, 32, 4, 16],
        ),
        // one long prefill injected over a decode steady state
        // (DESIGN.md §12): the short-prompt streams staggered-retire so
        // the bucket-filling prompt (request 2) is admitted while the
        // other lane is still mid-decode — its prefill stalls that
        // stream, and `decode_stall_p99_us` records by how much,
        // with/without chunking
        Scenario::new(
            "long_prompt_interactive", 2, 5,
            &[2, 2, PROMPT_FILL_BUCKET, 2, 2],
            &[24, 40, 4, 16, 16],
        ),
        // a system-prompt storm (DESIGN.md §13): every request opens
        // with the same 32-token prefix; under the continuous
        // scheduler the first prefill publishes it and later arrivals
        // attach by reference, prefilling only their 8-token tails —
        // the TTFT/throughput delta vs. the fcfs row is the §13
        // acceptance figure.  prompt_lens repeats so quick mode keeps
        // more requests than lanes: the reuse only kicks in for
        // arrivals after the first full wave of misses
        Scenario::new(
            "shared_prefix_storm", 4, 16,
            &[40, 40, 40, 40, 40, 40, 40, 40],
            &[8],
        )
        .with_shared_prefix(32),
        // decode-dominated workload for the speculative pair
        // (DESIGN.md §15): run_matrix records it spec-off like every
        // scenario, then once more with the nano draft speculating —
        // the ms/token delta at the measured accept_rate is the §15
        // acceptance comparison
        Scenario::new("speculative_decode", 4, 8, &[8], &[32]),
    ]
}

/// One recorded (scenario × world × kernel × threads × dtype) run.
#[derive(Clone, Debug)]
pub struct ScenarioRecord {
    /// scenario name (see [`standard_suite`])
    pub name: String,
    /// tensor-parallel world size
    pub world: usize,
    /// resolved per-rank compute threads (auto already applied);
    /// 0 = not applicable (a backend that ignores the GEMM knobs)
    pub threads: usize,
    /// GEMM kernel the reference backend ran
    pub kernel: GemmKernel,
    /// instruction tier the reference backend's GEMM dispatched to
    /// (DESIGN.md §14) — the *resolved* tier, after auto-detection
    /// and any `XEONSERVE_FORCE_ISA` override; `"scalar"` on
    /// backends that ignore the ISA knob
    pub isa: String,
    /// execution backend that measured this row (int8 rows only exist
    /// for `reference` — DESIGN.md §11)
    pub backend: BackendKind,
    /// weight storage dtype of the run (DESIGN.md §11)
    pub weight_dtype: Dtype,
    /// KV-cache storage dtype of the run
    pub kv_dtype: Dtype,
    /// prefill chunk size of the run (0 = whole-prompt) — DESIGN.md §12
    pub prefill_chunk: usize,
    /// admission policy the run served under (DESIGN.md §13)
    pub scheduler: SchedulerKind,
    /// fraction of admissions that attached to a shared prefix
    /// (0.0 on fcfs rows and on workloads with nothing to share)
    pub prefix_hit_rate: f64,
    /// draft tokens proposed per speculative step (DESIGN.md §15);
    /// 0 = speculation off for this row
    pub spec_k: usize,
    /// fraction of proposed draft tokens the verify rounds accepted
    /// (0.0 when speculation is off)
    pub accept_rate: f64,
    /// measured resident weight bytes, summed over ranks (0 = the
    /// backend doesn't measure)
    pub weight_bytes: u64,
    /// measured resident KV bytes, summed over ranks
    pub kv_bytes: u64,
    /// decode batch lanes
    pub batch: usize,
    /// requests served
    pub requests: usize,
    /// mean wall-clock decode latency, ms per output token (per-step
    /// wall divided by the tokens a step produced)
    pub ms_per_token: f64,
    /// mean wall-clock latency of one batched decode step, ms
    pub ms_per_step: f64,
    /// simulated-cluster decode latency, ms per output token
    pub ms_per_token_sim: f64,
    /// mean time to first token (prefill wall), ms
    pub ttft_ms: f64,
    /// end-to-end output tokens per second
    pub tokens_per_s: f64,
    /// decode wall p50, µs
    pub decode_p50_us: u64,
    /// decode wall p95, µs
    pub decode_p95_us: u64,
    /// decode-stall p99: worst-case gap between consecutive decode
    /// rounds while decode lanes stayed busy, µs (DESIGN.md §12 —
    /// the figure chunked prefill bounds)
    pub decode_stall_p99_us: u64,
    /// prefill wall p50, µs
    pub prefill_p50_us: u64,
    /// tokens emitted over the run
    pub tokens_out: u64,
    /// requests retired over the run
    pub requests_done: u64,
    /// fraction of submitted requests refused by load-shedding
    /// admission (DESIGN.md §16) — 0.0 on engine-direct scenarios,
    /// which bypass the serving front entirely
    pub shed_rate: f64,
    /// p99 outbound-frame queue residence, µs (DESIGN.md §16) — 0 on
    /// engine-direct scenarios
    pub frame_p99_us: u64,
    /// rank-failure recoveries the run absorbed (DESIGN.md §17) —
    /// non-zero only on `failover` rows, which sever one rank
    /// mid-decode on purpose
    pub recoveries: u64,
    /// wall-clock stall of the most recent fleet rebuild, ms — the
    /// gap a streaming client rode out while survivors re-sharded and
    /// in-flight lanes replayed (0 when nothing was recovered)
    pub recovery_stall_ms: u64,
    /// ccl counters accumulated over the run
    pub comm: StatsSnapshot,
}

impl ScenarioRecord {
    /// Serialize one row of the `xeonserve-bench/v1` schema.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            o.insert(k.to_string(), v);
        };
        put("name", Json::Str(self.name.clone()));
        put("world", Json::Num(self.world as f64));
        put("threads", Json::Num(self.threads as f64));
        put("kernel", Json::Str(self.kernel.to_string()));
        put("isa", Json::Str(self.isa.clone()));
        put("backend", Json::Str(self.backend.to_string()));
        put("weight_dtype", Json::Str(self.weight_dtype.to_string()));
        put("kv_dtype", Json::Str(self.kv_dtype.to_string()));
        put("prefill_chunk", Json::Num(self.prefill_chunk as f64));
        put("scheduler", Json::Str(self.scheduler.to_string()));
        put("prefix_hit_rate", Json::Num(self.prefix_hit_rate));
        put("spec_k", Json::Num(self.spec_k as f64));
        put("accept_rate", Json::Num(self.accept_rate));
        put("weight_bytes", Json::Num(self.weight_bytes as f64));
        put("kv_bytes", Json::Num(self.kv_bytes as f64));
        put("batch", Json::Num(self.batch as f64));
        put("requests", Json::Num(self.requests as f64));
        put("ms_per_token", Json::Num(self.ms_per_token));
        put("ms_per_step", Json::Num(self.ms_per_step));
        put("ms_per_token_sim", Json::Num(self.ms_per_token_sim));
        put("ttft_ms", Json::Num(self.ttft_ms));
        put("tokens_per_s", Json::Num(self.tokens_per_s));
        put("decode_p50_us", Json::Num(self.decode_p50_us as f64));
        put("decode_p95_us", Json::Num(self.decode_p95_us as f64));
        put("decode_stall_p99_us",
            Json::Num(self.decode_stall_p99_us as f64));
        put("prefill_p50_us", Json::Num(self.prefill_p50_us as f64));
        put("tokens_out", Json::Num(self.tokens_out as f64));
        put("requests_done", Json::Num(self.requests_done as f64));
        put("shed_rate", Json::Num(self.shed_rate));
        put("frame_p99_us", Json::Num(self.frame_p99_us as f64));
        put("recoveries", Json::Num(self.recoveries as f64));
        put("recovery_stall_ms",
            Json::Num(self.recovery_stall_ms as f64));
        let c = &self.comm;
        let mut comm = BTreeMap::new();
        for (k, v) in [
            ("sync_points", c.sync_points),
            ("wire_bytes", c.wire_bytes),
            ("staged_copy_bytes", c.staged_copy_bytes),
            ("messages", c.messages),
            ("allreduces", c.allreduces),
            ("broadcasts", c.broadcasts),
            ("gathers", c.gathers),
            ("allgathers", c.allgathers),
        ] {
            comm.insert(k.to_string(), Json::Num(v as f64));
        }
        put("comm", Json::Obj(comm));
        Json::Obj(o)
    }

    /// Condense to a [`CaseResult`] row for the human table.
    pub fn to_case(&self) -> CaseResult {
        // label both dtypes when they differ so mixed-dtype rows never
        // collide with pure rows in the table
        let dtype = if self.weight_dtype == self.kv_dtype {
            self.weight_dtype.to_string()
        } else {
            format!("{}+kv{}", self.weight_dtype, self.kv_dtype)
        };
        // tag chunked rows so they never collide with whole-prompt rows
        let chunk = if self.prefill_chunk == 0 {
            String::new()
        } else {
            format!("_c{}", self.prefill_chunk)
        };
        // tag continuous rows likewise (fcfs is the unmarked default)
        let sched = match self.scheduler {
            SchedulerKind::Fcfs => "",
            SchedulerKind::Continuous => "_cont",
        };
        // tag speculating rows (spec-off is the unmarked default)
        let spec = if self.spec_k == 0 {
            String::new()
        } else {
            format!("_spec{}", self.spec_k)
        };
        CaseResult {
            // the isa tag keeps the per-ISA batched_decode rows from
            // colliding with the auto-resolved standard rows
            name: format!("{}_w{}_{}x{}_{}_{}{}{}{}", self.name,
                          self.world, self.kernel, self.threads,
                          self.isa, dtype, chunk, sched, spec),
            iters: self.tokens_out as usize,
            mean_us: self.ms_per_token * 1e3,
            p50_us: self.decode_p50_us,
            p95_us: self.decode_p95_us,
            extra: Vec::new(),
        }
        .with("ms_tok", format!("{:.2}", self.ms_per_token))
        .with("sim_ms", format!("{:.2}", self.ms_per_token_sim))
        .with("ttft_ms", format!("{:.2}", self.ttft_ms))
        .with("stall_p99_ms",
              format!("{:.2}", self.decode_stall_p99_us as f64 / 1e3))
        .with("tok_s", format!("{:.1}", self.tokens_per_s))
        .with("mem_mb", format!("{:.0}",
                                (self.weight_bytes + self.kv_bytes)
                                    as f64 / 1e6))
    }
}

/// Run one scenario through a fully configured engine (`cfg.world`,
/// `cfg.kernel`, `cfg.threads` already set by the caller).
pub fn run_scenario(cfg: &EngineConfig, sc: &Scenario)
                    -> Result<ScenarioRecord> {
    let mut cfg = cfg.clone();
    cfg.batch = sc.batch;
    cfg.validate()?;
    let rm = cfg.resolve_model()?;
    let max_bucket = *rm.prefill_buckets.iter().max().unwrap();
    let max_seq = rm.preset.max_seq;

    let mut engine = Engine::new(cfg.clone())
        .with_context(|| format!("bringing up {} w{}", sc.name,
                                 cfg.world))?;
    let before = engine.comm_stats();
    for i in 0..sc.requests {
        let plen = match sc.prompt_lens[i % sc.prompt_lens.len()] {
            PROMPT_FILL_BUCKET => max_bucket,
            n => n,
        };
        // leave decode headroom when the prompt fills the bucket
        let plen = plen.min(max_seq.saturating_sub(4)).max(1);
        // the first `shared_prefix_len` tokens are i-independent, so
        // every request opens identically (request 0's stream
        // coincides with the shared form by construction)
        let prompt: Vec<i32> = (0..plen)
            .map(|t| {
                if t < sc.shared_prefix_len {
                    ((t * 13) % 200) as i32 + 1
                } else {
                    ((t * 13 + i * 7) % 200) as i32 + 1
                }
            })
            .collect();
        let n_new = sc.new_tokens[i % sc.new_tokens.len()];
        engine.enqueue(prompt, n_new);
    }
    let t0 = Instant::now();
    engine.run_to_completion()?;
    let span = t0.elapsed();
    finish_record(&sc.name, &cfg, &mut engine, span, &before,
                  sc.batch, sc.requests, 0.0, 0)
}

/// Assemble one [`ScenarioRecord`] from a finished engine run — the
/// shared tail of [`run_scenario`] and [`run_storm`], so the
/// front-driven rows report every field through the same formulas as
/// the engine-direct ones.
#[allow(clippy::too_many_arguments)]
fn finish_record(name: &str, cfg: &EngineConfig, engine: &mut Engine,
                 span: Duration, before: &StatsSnapshot, batch: usize,
                 requests: usize, shed_rate: f64, frame_p99_us: u64)
                 -> Result<ScenarioRecord> {
    let comm = engine.comm_stats().since(before);

    // the kernel/threads knobs are reference-backend GEMM settings;
    // other backends (xla) ignore them, so report 0 = not applicable
    // rather than a thread count the run never used
    let threads = match (cfg.backend, cfg.kernel) {
        (BackendKind::Reference, GemmKernel::Scalar) => 1,
        (BackendKind::Reference, GemmKernel::Blocked) => {
            auto_threads(cfg.threads, cfg.world)
        }
        _ => 0,
    };
    // record the tier the GEMM actually dispatched to — resolve()
    // applies the same auto-detect + env-override chain the backend
    // ran under (DESIGN.md §14); non-reference backends ignore the
    // knob entirely, so their rows pin the neutral "scalar"
    let isa = match cfg.backend {
        BackendKind::Reference => simd::resolve(cfg.isa)?.to_string(),
        _ => Isa::Scalar.to_string(),
    };
    let mem = engine.mem_usage();
    let m = &mut engine.metrics;
    let tokens_per_s = m.throughput(span);
    // decode steps emit (tokens_out - requests_done) tokens: each
    // request's first token comes from its prefill round
    let steps = m.decode_wall.count() as f64;
    let decode_tokens =
        (m.tokens_out.saturating_sub(m.requests_done)).max(1) as f64;
    let per_token = |mean_step_us: f64| -> f64 {
        if steps == 0.0 {
            0.0
        } else {
            mean_step_us * steps / decode_tokens / 1e3
        }
    };
    Ok(ScenarioRecord {
        name: name.to_string(),
        world: cfg.world,
        threads,
        kernel: cfg.kernel,
        isa,
        backend: cfg.backend,
        weight_dtype: cfg.weight_dtype,
        kv_dtype: cfg.kv_dtype,
        prefill_chunk: cfg.prefill_chunk,
        scheduler: cfg.scheduler,
        prefix_hit_rate: m.prefix_hit_rate(),
        spec_k: if cfg.spec_enabled() { cfg.spec_k } else { 0 },
        accept_rate: m.accept_rate(),
        weight_bytes: mem.weight_bytes,
        kv_bytes: mem.kv_bytes,
        batch,
        requests,
        ms_per_token: per_token(m.decode_wall.mean_us()),
        ms_per_step: m.decode_wall.mean_us() / 1e3,
        ms_per_token_sim: per_token(m.decode_sim.mean_us()),
        ttft_ms: m.prefill_wall.mean_us() / 1e3,
        tokens_per_s,
        decode_p50_us: m.decode_wall.p50_us(),
        decode_p95_us: m.decode_wall.p95_us(),
        decode_stall_p99_us: m.decode_gap.p99_us(),
        prefill_p50_us: m.prefill_wall.p50_us(),
        tokens_out: m.tokens_out,
        requests_done: m.requests_done,
        shed_rate,
        frame_p99_us,
        recoveries: 0,
        recovery_stall_ms: 0,
        comm,
    })
}

/// Admission-queue bound the `connection_storm` rows pin
/// (`shed_queue`), fixed like [`BENCH_PREFILL_CHUNK`] so recordings
/// stay comparable across machines (DESIGN.md §16).
pub const STORM_SHED_QUEUE: usize = 64;

/// The `connection_storm` serving-front scenario (DESIGN.md §16): a
/// storm of idle-to-active streaming clients — 10 000 full, 96 quick —
/// arriving in waves over a steady decode state, driven through the
/// full [`Front`] (admission, load shedding, per-connection bounded
/// frame queues) as in-process virtual connections.  Real sockets
/// would hit fd limits at this scale and add nothing: the reactor's
/// socket handling is pinned by the server tests, and everything above
/// it is exactly this code path.
///
/// Clients "read" their frame queues once per wave, so
/// `frame_p99_us` measures queue residence across a full engine step —
/// the serving-side latency a slow-but-alive reader sees.  `shed_rate`
/// is the fraction of the storm refused at admission under the pinned
/// [`STORM_SHED_QUEUE`] depth bound (wait-based shedding stays off:
/// depth-only decisions don't depend on host speed).
pub fn run_storm(cfg: &EngineConfig, quick: bool)
                 -> Result<ScenarioRecord> {
    let mut cfg = cfg.clone();
    cfg.batch = 4;
    cfg.shed_queue = STORM_SHED_QUEUE;
    cfg.shed_wait_ms = 0;
    cfg.validate()?;
    let clients: usize = if quick { 96 } else { 10_000 };
    // waves are wider than STORM_SHED_QUEUE, so the opening wave —
    // submitted from idle, before any engine step can drain the queue
    // — always sheds its tail: the quick smoke exercises the shed
    // path deterministically, independent of engine retirement timing
    let wave: usize = if quick { 80 } else { 100 };

    let engine = Engine::new(cfg.clone())
        .with_context(|| format!("bringing up connection_storm w{}",
                                 cfg.world))?;
    let before = engine.comm_stats();
    let mut front = Front::new(engine)?;
    // virtual connections: same bounded OutQ the reactor gives a
    // socket, drained by the driver instead of a TCP stream
    let mut queues: BTreeMap<u64, OutQ> = BTreeMap::new();
    let mut submitted = 0usize;
    let mut finished = 0usize; // done frames + shed/error replies
    let t0 = Instant::now();
    // generous bound so a routing bug fails loudly instead of hanging
    let max_iters = clients * 64 + 1024;
    for _ in 0..max_iters {
        // clients catch up on their streams first: frames produced by
        // the previous tick have sat one wave — that residence is the
        // frame latency
        for q in queues.values_mut() {
            while let Some((line, enqueued)) = q.pop_frame() {
                front.stats.record_frame(enqueued.elapsed());
                let j = Json::parse(&line).with_context(
                    || format!("storm client got non-JSON {line:?}"))?;
                if j.get("done").is_some() || j.get("error").is_some() {
                    finished += 1;
                }
            }
        }
        // a wave of new arrivals goes idle-to-active
        for _ in 0..wave {
            if submitted >= clients {
                break;
            }
            let id = submitted as u64 + 1;
            queues.insert(id, OutQ::new(
                crate::server::conn::MAX_OUT_FRAMES,
                crate::server::conn::MAX_OUT_BYTES));
            front.on_line(id, &format!(
                "{{\"prompt\": \"storm client {submitted}\", \
                 \"max_new_tokens\": 4, \"stream\": true}}"));
            submitted += 1;
        }
        if front.has_work() {
            front.tick()?;
        }
        // route replies into the virtual connections' bounded queues
        for (cid, line) in front.take_outbox() {
            if let Some(q) = queues.get_mut(&cid) {
                q.push(&line, Instant::now()).map_err(|_| {
                    anyhow::anyhow!("storm frame queue overflowed \
                                     (conn {cid})")
                })?;
                front.stats.note_queue_depth(q.len());
            }
        }
        if submitted >= clients && !front.has_work()
            && queues.values().all(OutQ::is_empty)
        {
            break;
        }
    }
    let span = t0.elapsed();
    anyhow::ensure!(
        finished == clients,
        "connection_storm lost replies: {finished} terminal lines for \
         {clients} clients");
    anyhow::ensure!(front.inflight() == 0 && front.queued() == 0,
                    "connection_storm leaked front bookkeeping");
    let shed_rate = front.stats.shed as f64 / clients as f64;
    let frame_p99_us = front.stats.frame_lat.p99_us();
    finish_record("connection_storm", &cfg, front.engine_mut(), span,
                  &before, cfg.batch, clients, shed_rate, frame_p99_us)
}

/// Chaos fuse of the `failover` row: control commands delivered to
/// the victim rank before it "dies".  Deep enough that the blow lands
/// mid-decode (after the opening prefill wave has filled the lanes),
/// shallow enough that every workload size reaches it.
pub const FAILOVER_FUSE: usize = 9;

/// The `failover` elastic-serving scenario (DESIGN.md §17): the
/// batched decode workload with one rank host wrapped in a chaos fuse
/// that severs it mid-decode.  The [`ElasticEngine`] must absorb the
/// loss — tear the fleet down, bring up replacement ranks, re-shard
/// the weights, replay every in-flight lane — and the row records
/// `recovery_stall_ms`, the gap a streaming client rode out.  The
/// recovered streams are pinned bit-identical to an undisturbed
/// plain-engine run of the same workload, and the run fails loudly if
/// the fuse never blew or any token was lost.
pub fn run_failover(cfg: &EngineConfig, quick: bool)
                    -> Result<ScenarioRecord> {
    let mut cfg = cfg.clone();
    cfg.batch = 4;
    cfg.validate()?;
    let requests: usize = if quick { 6 } else { 16 };
    let new_tokens: usize = if quick { 8 } else { 32 };
    let prompt = |i: usize| -> Vec<i32> {
        (0..8).map(|t| ((t * 13 + i * 7) % 200) as i32 + 1).collect()
    };

    // undisturbed reference run: the recovered streams must match
    // this bit for bit (greedy decode — DESIGN.md §17)
    let mut plain = Engine::new(cfg.clone())
        .with_context(|| format!("bringing up failover reference w{}",
                                 cfg.world))?;
    for i in 0..requests {
        plain.enqueue(prompt(i), new_tokens);
    }
    let mut expected: Vec<Completion> = plain.run_to_completion()?;
    expected.sort_by_key(|c| c.request_id);
    drop(plain);

    let factory = ChaosFactory {
        victim: cfg.world.saturating_sub(1),
        fuse: FAILOVER_FUSE,
        kills: 1,
    };
    let mut eng = ElasticEngine::new(cfg.clone(), Box::new(factory))
        .with_context(|| format!("bringing up failover w{}",
                                 cfg.world))?;
    for i in 0..requests {
        eng.enqueue(prompt(i), new_tokens);
    }
    let t0 = Instant::now();
    let mut done = eng.run_to_completion()?;
    let span = t0.elapsed();
    done.sort_by_key(|c| c.request_id);

    anyhow::ensure!(eng.recoveries() >= 1,
                    "failover fuse never blew: the workload finished \
                     in under {FAILOVER_FUSE} victim commands");
    anyhow::ensure!(eng.tokens_lost() == 0,
                    "failover lost {} tokens across recovery",
                    eng.tokens_lost());
    anyhow::ensure!(
        done.len() == expected.len()
            && done.iter().zip(&expected).all(
                |(d, e)| d.request_id == e.request_id
                    && d.tokens == e.tokens),
        "failover streams diverged from the undisturbed run");

    let recoveries = eng.recoveries();
    let stall = eng.last_recovery_stall_ms();
    // the rebuilt fleet's counters restart from zero, so the delta
    // base is the zero snapshot — `since` against a pre-kill baseline
    // from the discarded fleet would underflow
    let before = StatsSnapshot::default();
    let mut rec = finish_record("failover", &cfg, &mut eng, span,
                                &before, cfg.batch, requests, 0.0, 0)?;
    rec.recoveries = recoveries;
    rec.recovery_stall_ms = stall;
    Ok(rec)
}

/// Sweep the scenario suite over `worlds`, recording every scenario on
/// the blocked kernel plus, for `batched_decode`, the scalar baseline
/// and a single-threaded blocked run — the rows the ≥2× batched-decode
/// acceptance gate compares.  The decode-dominated scenarios
/// (`single_stream_decode`, `batched_decode`) additionally record an
/// `int8` weights+KV row next to the `f32` row, so every recording
/// carries its own quantization comparison (DESIGN.md §11).
/// `batched_decode` further records one row per instruction tier the
/// host can run (pinned `isa = scalar/avx2/avx512` at f32, plus the
/// `vnni` int8 row, which every host can run via the exact integer
/// emulation) — the DESIGN.md §14 per-ISA comparison.
///
/// Blocked rows run at a FIXED 2 threads when `base.threads` is 0
/// (auto): a host-independent thread count keeps `BENCH_*.json`
/// recordings comparable across machines.  An explicit `--threads N`
/// overrides it (floored at 2 so the threaded row always exists).
/// Row dtypes are likewise pinned (`f32` standard rows, `int8` quant
/// rows) regardless of the base config, so recordings always compare
/// like with like.
pub fn run_matrix(base: &EngineConfig, worlds: &[usize], quick: bool,
                  mut progress: impl FnMut(&str)) -> Result<Vec<ScenarioRecord>> {
    let scenarios: Vec<Scenario> = standard_suite()
        .into_iter()
        .map(|s| if quick { s.quicken() } else { s })
        .collect();
    let mut out = Vec::new();
    for &world in worlds {
        for sc in &scenarios {
            let mut cfg = base.clone();
            cfg.world = world;
            cfg.kernel = GemmKernel::Blocked;
            cfg.weight_dtype = Dtype::F32;
            cfg.kv_dtype = Dtype::F32;
            // standard rows are always whole-prompt fcfs; the chunked
            // and continuous comparison rows below are the only ones
            // that deviate
            cfg.prefill_chunk = 0;
            cfg.scheduler = SchedulerKind::Fcfs;
            cfg.threads = if base.threads == 0 {
                2
            } else {
                auto_threads(base.threads, world).max(2)
            };
            progress(&format!("{} w{world} blocked x{} f32", sc.name,
                              cfg.threads));
            out.push(run_scenario(&cfg, sc)?);
            // the §12 decode-stall pair: the same interactive workload
            // with chunked prefill, next to the whole-prompt baseline
            // row just recorded (reference backend only — xla has no
            // chunk segments)
            if cfg.backend == BackendKind::Reference
                && sc.name == "long_prompt_interactive"
            {
                let mut ck = cfg.clone();
                ck.prefill_chunk = BENCH_PREFILL_CHUNK;
                progress(&format!("{} w{world} blocked x{} f32 chunk{}",
                                  sc.name, ck.threads,
                                  ck.prefill_chunk));
                out.push(run_scenario(&ck, sc)?);
            }
            // the §13 scheduler pair: the system-prompt storm under
            // the continuous scheduler (shared-prefix reuse live),
            // next to the fcfs baseline row just recorded (reference
            // backend only — xla rejects continuous in validate())
            if cfg.backend == BackendKind::Reference
                && sc.name == "shared_prefix_storm"
            {
                let mut cont = cfg.clone();
                cont.scheduler = SchedulerKind::Continuous;
                progress(&format!("{} w{world} blocked x{} f32 \
                                   continuous",
                                  sc.name, cont.threads));
                out.push(run_scenario(&cont, sc)?);
            }
            // the §15 speculative pair: the same decode-dominated
            // workload with the nano draft speculating k=4, next to
            // the spec-off baseline row just recorded (reference
            // backend only — xla rejects spec_draft in validate()).
            // The pair shares every other knob, so the ms/token delta
            // is purely the draft+verify overhead vs. the tokens the
            // measured accept_rate recovered
            if cfg.backend == BackendKind::Reference
                && sc.name == "speculative_decode"
            {
                let mut sp = cfg.clone();
                sp.spec_draft = "nano".into();
                sp.spec_k = 4;
                progress(&format!("{} w{world} blocked x{} f32 spec4",
                                  sc.name, sp.threads));
                out.push(run_scenario(&sp, sc)?);
            }
            // int8 rows are a reference-backend feature; on an XLA
            // config the sweep stays f32-only instead of aborting on
            // the validate() dtype rejection
            if cfg.backend == BackendKind::Reference
                && matches!(sc.name.as_str(),
                            "single_stream_decode" | "batched_decode")
            {
                let mut q8 = cfg.clone();
                q8.weight_dtype = Dtype::Int8;
                q8.kv_dtype = Dtype::Int8;
                progress(&format!("{} w{world} blocked x{} int8",
                                  sc.name, q8.threads));
                out.push(run_scenario(&q8, sc)?);
            }
            if sc.name == "batched_decode" {
                let mut scalar = cfg.clone();
                scalar.kernel = GemmKernel::Scalar;
                scalar.threads = 1;
                // the pinned baseline stays the scalar *chain*: the
                // ≥2× acceptance ratio must not silently become a
                // SIMD-vs-SIMD comparison on a capable host
                scalar.isa = IsaKind::Scalar;
                progress(&format!("{} w{world} scalar baseline",
                                  sc.name));
                out.push(run_scenario(&scalar, sc)?);
                let mut one = cfg.clone();
                one.kernel = GemmKernel::Blocked;
                one.threads = 1;
                progress(&format!("{} w{world} blocked x1", sc.name));
                out.push(run_scenario(&one, sc)?);
            }
            // the §14 per-ISA batched_decode sweep: the same blocked
            // threaded workload pinned to each instruction tier the
            // host can run, plus the vnni int8 row (always runnable —
            // its integer kernel has an exact scalar emulation).
            // Appended AFTER the standard rows so the first-match
            // accessors above keep reading the auto-resolved rows.
            if cfg.backend == BackendKind::Reference
                && sc.name == "batched_decode"
            {
                for (kind, isa) in [(IsaKind::Scalar, Isa::Scalar),
                                    (IsaKind::Avx2, Isa::Avx2),
                                    (IsaKind::Avx512, Isa::Avx512)] {
                    if !simd::available(isa) {
                        continue;
                    }
                    let mut row = cfg.clone();
                    row.isa = kind;
                    progress(&format!("{} w{world} blocked x{} f32 \
                                       isa={kind}",
                                      sc.name, row.threads));
                    out.push(run_scenario(&row, sc)?);
                }
                let mut vn = cfg.clone();
                vn.isa = IsaKind::Vnni;
                vn.weight_dtype = Dtype::Int8;
                vn.kv_dtype = Dtype::Int8;
                progress(&format!("{} w{world} blocked x{} int8 \
                                   isa=vnni",
                                  sc.name, vn.threads));
                out.push(run_scenario(&vn, sc)?);
            }
        }
        // the §16 serving-front pair: connection_storm drives the
        // event front (admission, load shedding, bounded frame
        // queues) over the same engine, once per scheduler — the p99
        // frame latency + shed rate rows the storm-pair gate reads
        // (reference backend only: xla rejects continuous in
        // validate(), and the front pair must share every other knob)
        if base.backend == BackendKind::Reference {
            for kind in [SchedulerKind::Fcfs, SchedulerKind::Continuous]
            {
                let mut st = base.clone();
                st.world = world;
                st.kernel = GemmKernel::Blocked;
                st.weight_dtype = Dtype::F32;
                st.kv_dtype = Dtype::F32;
                st.prefill_chunk = 0;
                st.scheduler = kind;
                st.threads = if base.threads == 0 {
                    2
                } else {
                    auto_threads(base.threads, world).max(2)
                };
                progress(&format!(
                    "connection_storm w{world} blocked x{} f32 {kind}",
                    st.threads));
                out.push(run_storm(&st, quick)?);
            }
        }
        // the §17 elastic row: the batched workload with a chaos fuse
        // severing one rank mid-decode — records the recovery stall
        // and pins zero lost tokens against an undisturbed reference
        // run (reference backend only, like the other serving rows)
        if base.backend == BackendKind::Reference {
            let mut fo = base.clone();
            fo.world = world;
            fo.kernel = GemmKernel::Blocked;
            fo.weight_dtype = Dtype::F32;
            fo.kv_dtype = Dtype::F32;
            fo.prefill_chunk = 0;
            fo.scheduler = SchedulerKind::Fcfs;
            fo.threads = if base.threads == 0 {
                2
            } else {
                auto_threads(base.threads, world).max(2)
            };
            progress(&format!("failover w{world} blocked x{} f32",
                              fo.threads));
            out.push(run_failover(&fo, quick)?);
        }
    }
    Ok(out)
}

/// Assemble the full `xeonserve-bench/v1` document.  `worlds` is the
/// sweep the recording claims to cover; [`validate_bench`] checks the
/// rows against it.
pub fn matrix_to_json(bench: &str, model: &str, quick: bool,
                      worlds: &[usize], records: &[ScenarioRecord])
                      -> Json {
    let mut o = BTreeMap::new();
    o.insert("schema".into(), Json::Str(SCHEMA.into()));
    o.insert("bench".into(), Json::Str(bench.into()));
    o.insert("model".into(), Json::Str(model.into()));
    o.insert("quick".into(), Json::Bool(quick));
    o.insert(
        "worlds".into(),
        Json::Arr(worlds.iter().map(|&w| Json::Num(w as f64)).collect()),
    );
    let mut host = BTreeMap::new();
    host.insert(
        "available_parallelism".into(),
        Json::Num(std::thread::available_parallelism()
                      .map(|n| n.get()).unwrap_or(1) as f64),
    );
    host.insert(
        "best_isa".into(),
        Json::Str(simd::detect_best().to_string()),
    );
    o.insert("host".into(), Json::Obj(host));
    o.insert(
        "scenarios".into(),
        Json::Arr(records.iter().map(ScenarioRecord::to_json).collect()),
    );
    Json::Obj(o)
}

/// `ms_per_token` of the first `batched_decode` row matching (world,
/// kernel, ≥ min threads) whose weight AND KV dtypes both equal
/// `dtype` — mixed-dtype rows never enter a speedup figure, since
/// they'd compare different numeric contracts.  Rows recorded before
/// the dtype fields existed are treated as `f32`.
fn find_batched_ms(rows: &[Json], world: usize, kernel: &str,
                   min_threads: usize, dtype: &str) -> Option<f64> {
    rows.iter().find_map(|r| {
        let name = r.get("name")?.as_str()?;
        let w = r.get("world")?.as_usize()?;
        let k = r.get("kernel")?.as_str()?;
        let t = r.get("threads")?.as_usize()?;
        let wd = r.get("weight_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let kd = r.get("kv_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        if name == "batched_decode" && w == world && k == kernel
            && t >= min_threads && wd == dtype && kd == dtype
        {
            r.get("ms_per_token")?.as_f64()
        } else {
            None
        }
    })
}

/// Batched-decode speedup of the threaded blocked kernel over the
/// scalar baseline at world `w`, both at f32 (`None` if either row is
/// missing).
pub fn batched_speedup(j: &Json, world: usize) -> Option<f64> {
    let rows = j.get("scenarios")?.as_arr()?;
    let scalar = find_batched_ms(rows, world, "scalar", 1, "f32")?;
    let blocked = find_batched_ms(rows, world, "blocked", 2, "f32")?;
    if blocked > 0.0 {
        Some(scalar / blocked)
    } else {
        None
    }
}

/// Batched-decode speedup of int8 weights+KV over f32 on the threaded
/// blocked kernel at world `w` — the DESIGN.md §11 acceptance figure
/// (`None` if either row is missing).
pub fn int8_speedup(j: &Json, world: usize) -> Option<f64> {
    let rows = j.get("scenarios")?.as_arr()?;
    let f32_ms = find_batched_ms(rows, world, "blocked", 2, "f32")?;
    let int8_ms = find_batched_ms(rows, world, "blocked", 2, "int8")?;
    if int8_ms > 0.0 {
        Some(f32_ms / int8_ms)
    } else {
        None
    }
}

/// `decode_stall_p99_us` of the first `long_prompt_interactive` row
/// at `world` whose `prefill_chunk` matches `chunked` (any non-zero
/// chunk when true, exactly 0 when false).  Pinned to the threaded
/// blocked f32 rows, like [`find_batched_ms`], so a future sweep
/// adding scalar or int8 interactive rows can never pair rows from
/// different kernel/dtype contracts into one ratio.
fn find_stall_p99(rows: &[Json], world: usize, chunked: bool)
                  -> Option<f64> {
    rows.iter().find_map(|r| {
        let name = r.get("name")?.as_str()?;
        let w = r.get("world")?.as_usize()?;
        let kernel = r.get("kernel")?.as_str()?;
        let threads = r.get("threads")?.as_usize()?;
        let wd = r.get("weight_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let kd = r.get("kv_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let chunk = r.get("prefill_chunk")?.as_usize()?;
        if name == "long_prompt_interactive" && w == world
            && kernel == "blocked" && threads >= 2
            && wd == "f32" && kd == "f32"
            && (chunk > 0) == chunked
        {
            r.get("decode_stall_p99_us")?.as_f64()
        } else {
            None
        }
    })
}

/// Decode-stall reduction of chunked prefill at world `w`: whole-
/// prompt `long_prompt_interactive` stall p99 over the chunked row's —
/// the DESIGN.md §12 acceptance figure (`None` if either row is
/// missing or the chunked stall is 0).
pub fn chunked_stall_ratio(j: &Json, world: usize) -> Option<f64> {
    let rows = j.get("scenarios")?.as_arr()?;
    let whole = find_stall_p99(rows, world, false)?;
    let chunked = find_stall_p99(rows, world, true)?;
    if chunked > 0.0 {
        Some(whole / chunked)
    } else {
        None
    }
}

/// `(ttft_ms, tokens_per_s, prefix_hit_rate)` of the first
/// `shared_prefix_storm` row at `world` under `scheduler`, pinned to
/// the threaded blocked f32 rows like the other accessors — the
/// DESIGN.md §13 acceptance pair reads the `"fcfs"` row against the
/// `"continuous"` one (`None` if the row is missing).
pub fn storm_row(j: &Json, world: usize, scheduler: &str)
                 -> Option<(f64, f64, f64)> {
    let rows = j.get("scenarios")?.as_arr()?;
    rows.iter().find_map(|r| {
        let name = r.get("name")?.as_str()?;
        let w = r.get("world")?.as_usize()?;
        let kernel = r.get("kernel")?.as_str()?;
        let threads = r.get("threads")?.as_usize()?;
        let wd = r.get("weight_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let kd = r.get("kv_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let sched = r.get("scheduler")?.as_str()?;
        if name == "shared_prefix_storm" && w == world
            && kernel == "blocked" && threads >= 2
            && wd == "f32" && kd == "f32" && sched == scheduler
        {
            Some((r.get("ttft_ms")?.as_f64()?,
                  r.get("tokens_per_s")?.as_f64()?,
                  r.get("prefix_hit_rate")?.as_f64()?))
        } else {
            None
        }
    })
}

/// `(frame_p99_us, shed_rate, tokens_per_s)` of the first
/// `connection_storm` row at `world` under `scheduler`, pinned to the
/// threaded blocked f32 rows like the other accessors — the DESIGN.md
/// §16 serving-front pair reads the `"fcfs"` row against the
/// `"continuous"` one (`None` if the row is missing).
pub fn conn_storm_row(j: &Json, world: usize, scheduler: &str)
                      -> Option<(f64, f64, f64)> {
    let rows = j.get("scenarios")?.as_arr()?;
    rows.iter().find_map(|r| {
        let name = r.get("name")?.as_str()?;
        let w = r.get("world")?.as_usize()?;
        let kernel = r.get("kernel")?.as_str()?;
        let threads = r.get("threads")?.as_usize()?;
        let wd = r.get("weight_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let kd = r.get("kv_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let sched = r.get("scheduler")?.as_str()?;
        if name == "connection_storm" && w == world
            && kernel == "blocked" && threads >= 2
            && wd == "f32" && kd == "f32" && sched == scheduler
        {
            Some((r.get("frame_p99_us")?.as_f64()?,
                  r.get("shed_rate")?.as_f64()?,
                  r.get("tokens_per_s")?.as_f64()?))
        } else {
            None
        }
    })
}

/// `(recoveries, recovery_stall_ms, tokens_per_s)` of the first
/// `failover` row at `world`, pinned to the threaded blocked f32 rows
/// like the other accessors — the DESIGN.md §17 elastic gate reads
/// the recorded kill-and-recover (`None` if the row is missing).
pub fn failover_row(j: &Json, world: usize)
                    -> Option<(u64, u64, f64)> {
    let rows = j.get("scenarios")?.as_arr()?;
    rows.iter().find_map(|r| {
        let name = r.get("name")?.as_str()?;
        let w = r.get("world")?.as_usize()?;
        let kernel = r.get("kernel")?.as_str()?;
        let threads = r.get("threads")?.as_usize()?;
        let wd = r.get("weight_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let kd = r.get("kv_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        if name == "failover" && w == world && kernel == "blocked"
            && threads >= 2 && wd == "f32" && kd == "f32"
        {
            Some((r.get("recoveries")?.as_u64()?,
                  r.get("recovery_stall_ms")?.as_u64()?,
                  r.get("tokens_per_s")?.as_f64()?))
        } else {
            None
        }
    })
}

/// `(ms_per_token, tokens_per_s, accept_rate)` of the first
/// `speculative_decode` row at `world` with speculation on (`spec_k >
/// 0`) or off (`spec_k == 0`), pinned to the threaded blocked f32
/// rows like the other accessors — the DESIGN.md §15 acceptance pair
/// reads the spec-off row against the spec-on one (`None` if the row
/// is missing).
pub fn spec_row(j: &Json, world: usize, speculating: bool)
                -> Option<(f64, f64, f64)> {
    let rows = j.get("scenarios")?.as_arr()?;
    rows.iter().find_map(|r| {
        let name = r.get("name")?.as_str()?;
        let w = r.get("world")?.as_usize()?;
        let kernel = r.get("kernel")?.as_str()?;
        let threads = r.get("threads")?.as_usize()?;
        let wd = r.get("weight_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let kd = r.get("kv_dtype").and_then(Json::as_str)
            .unwrap_or("f32");
        let k = r.get("spec_k")?.as_usize()?;
        if name == "speculative_decode" && w == world
            && kernel == "blocked" && threads >= 2
            && wd == "f32" && kd == "f32"
            && (k > 0) == speculating
        {
            Some((r.get("ms_per_token")?.as_f64()?,
                  r.get("tokens_per_s")?.as_f64()?,
                  r.get("accept_rate")?.as_f64()?))
        } else {
            None
        }
    })
}

/// Structural + coverage validation of a `xeonserve-bench/v1`
/// document (the CI bench-smoke gate).  Checks the schema tag, the
/// per-row field types — including the dtype and memory-bytes fields
/// every row must carry since DESIGN.md §11, the `prefill_chunk` and
/// `decode_stall_p99_us` fields since §12, and the `scheduler` and
/// `prefix_hit_rate` fields since §13 — and that the rows cover every
/// world the document's `worlds` field declares × ≥4 scenarios,
/// including the threaded-vs-scalar batched-decode pair, the
/// int8-vs-f32 batched-decode pair, the whole-vs-chunked
/// `long_prompt_interactive` pair, the fcfs-vs-continuous
/// `shared_prefix_storm` pair, the spec-off-vs-spec-on
/// `speculative_decode` pair (§15), the fcfs-vs-continuous
/// `connection_storm` pair (§16), and the `failover` kill-and-recover
/// row with its `recoveries`/`recovery_stall_ms` fields (§17) the
/// acceptance gates read, and ≥ 2
/// distinct `isa` tiers among the `batched_decode` rows (§14) — so a
/// `--worlds 2` recording validates against its own sweep, while the
/// committed full recordings must actually contain what they claim.
/// (Recordings predating a required field no longer validate;
/// regenerate them — BENCH_pr4/pr5/pr6.json stay committed as
/// trajectory history.)
///
/// Every failure message begins `rule {name}: ` and names the
/// offending row, so a CI failure points at the exact check and datum
/// that tripped it (the rules are unit-tested one by one below).
pub fn validate_bench(j: &Json) -> Result<()> {
    match j.get("schema").and_then(Json::as_str) {
        Some(s) if s == SCHEMA => {}
        other => bail!("rule schema-tag: schema is {other:?}, \
                        expected {SCHEMA:?}"),
    }
    for key in ["bench", "model"] {
        j.get(key).and_then(Json::as_str).with_context(|| {
            format!("rule doc-strings: missing string field {key:?}")
        })?;
    }
    let declared: Vec<usize> = j
        .get("worlds")
        .and_then(Json::as_arr)
        .context("rule worlds-declared: missing worlds array")?
        .iter()
        .map(|w| {
            w.as_usize().context(
                "rule worlds-declared: worlds entries must be numbers")
        })
        .collect::<Result<_>>()?;
    if declared.is_empty() {
        bail!("rule worlds-declared: worlds array is empty");
    }
    let rows = j
        .get("scenarios")
        .and_then(Json::as_arr)
        .context("rule rows-present: missing scenarios array")?;
    if rows.is_empty() {
        bail!("rule rows-present: scenarios array is empty");
    }
    let mut names = std::collections::BTreeSet::new();
    let mut worlds = std::collections::BTreeSet::new();
    let mut batched_scalar = false;
    let mut batched_threaded = false;
    let mut batched_int8 = false;
    let mut interactive_whole = false;
    let mut interactive_chunked = false;
    let mut storm_fcfs = false;
    let mut storm_continuous = false;
    let mut cstorm_fcfs = false;
    let mut cstorm_continuous = false;
    let mut spec_off = false;
    let mut spec_on = false;
    let mut failover_recovered = false;
    let mut any_reference = false;
    let mut batched_isas = std::collections::BTreeSet::new();
    for (i, r) in rows.iter().enumerate() {
        let ctx = || format!("scenario row {i}");
        let name = r.get("name").and_then(Json::as_str)
            .with_context(|| {
                format!("rule row-name: {}: missing name", ctx())
            })?;
        for key in ["world", "threads", "batch", "requests",
                    "decode_p50_us", "decode_p95_us",
                    "decode_stall_p99_us", "prefill_p50_us",
                    "tokens_out", "requests_done", "weight_bytes",
                    "kv_bytes", "prefill_chunk", "frame_p99_us"] {
            let v = r.get(key).and_then(Json::as_f64).with_context(|| {
                format!("rule row-counter-fields: {} ({name}): \
                         missing numeric field {key:?}", ctx())
            })?;
            // these are all count/size fields: fractional values
            // would be silently truncated downstream (as_usize),
            // misclassifying rows — reject them like the config
            // parser rejects a fractional prefill_chunk
            if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
                bail!("rule row-counter-fields: {} ({name}): \
                       {key} = {v} must be a non-negative integer",
                      ctx());
            }
        }
        for key in ["ms_per_token", "ms_per_step", "ms_per_token_sim",
                    "ttft_ms", "tokens_per_s"] {
            let v = r.get(key).and_then(Json::as_f64).with_context(|| {
                format!("rule row-latency-fields: {} ({name}): \
                         missing numeric field {key:?}", ctx())
            })?;
            if !v.is_finite() || v < 0.0 {
                bail!("rule row-latency-fields: {} ({name}): \
                       {key} = {v} is not a sane latency", ctx());
            }
        }
        let kernel = r.get("kernel").and_then(Json::as_str)
            .with_context(|| {
                format!("rule row-kernel: {} ({name}): missing kernel",
                        ctx())
            })?;
        if kernel != "blocked" && kernel != "scalar" {
            bail!("rule row-kernel: {} ({name}): \
                   unknown kernel {kernel:?}", ctx());
        }
        // every row must say what instruction tier computed it — the
        // §14 per-ISA comparison is meaningless without it
        let isa = r.get("isa").and_then(Json::as_str)
            .with_context(|| {
                format!("rule row-isa: {} ({name}): missing isa",
                        ctx())
            })?;
        if !matches!(isa, "scalar" | "avx2" | "avx512" | "vnni") {
            bail!("rule row-isa: {} ({name}): unknown isa {isa:?}",
                  ctx());
        }
        let backend = r.get("backend").and_then(Json::as_str)
            .with_context(|| {
                format!("rule row-backend: {} ({name}): \
                         missing backend", ctx())
            })?;
        if backend != "reference" && backend != "xla" {
            bail!("rule row-backend: {} ({name}): \
                   unknown backend {backend:?}", ctx());
        }
        // every row must say what numeric contract it measured —
        // cross-dtype comparisons are meaningless without it
        let mut dtypes = [""; 2];
        for (slot, key) in
            dtypes.iter_mut().zip(["weight_dtype", "kv_dtype"])
        {
            let d = r.get(key).and_then(Json::as_str).with_context(
                || format!("rule row-dtype: {} ({name}): \
                            missing dtype field {key:?}", ctx()))?;
            if d != "f32" && d != "int8" {
                bail!("rule row-dtype: {} ({name}): \
                       unknown {key} {d:?}", ctx());
            }
            *slot = d;
        }
        r.get("comm").and_then(Json::as_obj).with_context(|| {
            format!("rule row-comm: {} ({name}): missing comm object",
                    ctx())
        })?;
        // every row must say what admission policy served it — the
        // §13 scheduler pair is meaningless without it
        let sched = r.get("scheduler").and_then(Json::as_str)
            .with_context(|| {
                format!("rule row-scheduler: {} ({name}): \
                         missing scheduler", ctx())
            })?;
        if sched != "fcfs" && sched != "continuous" {
            bail!("rule row-scheduler: {} ({name}): \
                   unknown scheduler {sched:?}", ctx());
        }
        let hit = r.get("prefix_hit_rate").and_then(Json::as_f64)
            .with_context(|| {
                format!("rule row-prefix-hit-rate: {} ({name}): \
                         missing numeric field \"prefix_hit_rate\"",
                        ctx())
            })?;
        if !hit.is_finite() || !(0.0..=1.0).contains(&hit) {
            bail!("rule row-prefix-hit-rate: {} ({name}): \
                   prefix_hit_rate = {hit} must lie in [0, 1]", ctx());
        }
        // every row must say what fraction of its offered load the
        // admission gate refused — the §16 storm pair is meaningless
        // without it (engine-direct rows record 0.0)
        let shed = r.get("shed_rate").and_then(Json::as_f64)
            .with_context(|| {
                format!("rule row-shed-rate: {} ({name}): missing \
                         numeric field \"shed_rate\"", ctx())
            })?;
        if !shed.is_finite() || !(0.0..=1.0).contains(&shed) {
            bail!("rule row-shed-rate: {} ({name}): \
                   shed_rate = {shed} must lie in [0, 1]", ctx());
        }
        // every row must say whether (and how deep) it speculated —
        // the §15 pair is meaningless without it
        let spec_k = r.get("spec_k").and_then(Json::as_f64)
            .with_context(|| {
                format!("rule spec-fields: {} ({name}): missing \
                         numeric field \"spec_k\"", ctx())
            })?;
        if !spec_k.is_finite() || spec_k.fract() != 0.0
            || !(0.0..=8.0).contains(&spec_k)
        {
            bail!("rule spec-fields: {} ({name}): spec_k = {spec_k} \
                   must be an integer in 0..=8", ctx());
        }
        let acc = r.get("accept_rate").and_then(Json::as_f64)
            .with_context(|| {
                format!("rule spec-fields: {} ({name}): missing \
                         numeric field \"accept_rate\"", ctx())
            })?;
        if !acc.is_finite() || !(0.0..=1.0).contains(&acc) {
            bail!("rule spec-fields: {} ({name}): accept_rate = {acc} \
                   must lie in [0, 1]", ctx());
        }
        if spec_k == 0.0 && acc != 0.0 {
            bail!("rule spec-fields: {} ({name}): a spec-off row \
                   (spec_k = 0) cannot have accept_rate = {acc}",
                  ctx());
        }
        // every row must say how many rank failures it absorbed and
        // the worst stall a recovery imposed (§17) — 0/0 everywhere
        // except the failover rows, which sever a rank on purpose
        let mut recovery = [0.0f64; 2];
        for (slot, key) in recovery.iter_mut()
            .zip(["recoveries", "recovery_stall_ms"])
        {
            let v = r.get(key).and_then(Json::as_f64).with_context(
                || format!("rule row-recovery: {} ({name}): missing \
                            numeric field {key:?}", ctx()))?;
            if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
                bail!("rule row-recovery: {} ({name}): {key} = {v} \
                       must be a non-negative integer", ctx());
            }
            *slot = v;
        }
        if recovery[0] == 0.0 && recovery[1] != 0.0 {
            bail!("rule row-recovery: {} ({name}): recovery_stall_ms \
                   = {} on a row that absorbed zero recoveries",
                  ctx(), recovery[1]);
        }
        if name == "failover" && recovery[0] == 0.0 {
            bail!("rule row-recovery: {} ({name}): a failover row \
                   must absorb at least one recovery", ctx());
        }
        let world = r.get("world").and_then(Json::as_usize).unwrap();
        let threads = r.get("threads").and_then(Json::as_usize).unwrap();
        names.insert(name.to_string());
        worlds.insert(world);
        any_reference |= backend == "reference";
        if name == "batched_decode" {
            if backend == "reference" {
                batched_isas.insert(isa.to_string());
            }
            let f32_row = dtypes == ["f32", "f32"];
            batched_scalar |= kernel == "scalar" && f32_row;
            batched_threaded |=
                kernel == "blocked" && threads >= 2 && f32_row;
            // threads >= 2 mirrors the f32 gate AND int8_speedup()'s
            // filter, so a certified document always yields the §11
            // acceptance figure
            batched_int8 |= kernel == "blocked" && threads >= 2
                && dtypes == ["int8", "int8"];
        }
        if name == "long_prompt_interactive" {
            let chunk =
                r.get("prefill_chunk").and_then(Json::as_usize).unwrap();
            interactive_whole |= chunk == 0;
            interactive_chunked |= chunk > 0;
        }
        if name == "shared_prefix_storm" {
            storm_fcfs |= sched == "fcfs";
            storm_continuous |= sched == "continuous";
        }
        if name == "connection_storm" {
            cstorm_fcfs |= sched == "fcfs";
            cstorm_continuous |= sched == "continuous";
        }
        if name == "speculative_decode" {
            spec_off |= spec_k == 0.0;
            spec_on |= spec_k > 0.0;
        }
        failover_recovered |= name == "failover" && recovery[0] >= 1.0;
    }
    if names.len() < 4 {
        bail!("rule coverage-scenarios: only {} distinct scenarios, \
               need >= 4: {names:?}", names.len());
    }
    for &w in &declared {
        if !worlds.contains(&w) {
            bail!("rule coverage-worlds: declared world={w} has no \
                   rows (rows cover {worlds:?})");
        }
    }
    // the kernel/threads/dtype acceptance pairs are reference-backend
    // semantics (the XLA backend ignores the GEMM knobs and has no
    // int8 or continuous path — run_matrix skips those rows there),
    // so an XLA-only recording is exempt from the pair gates
    if any_reference && !batched_scalar {
        bail!("rule pair-batched-scalar: no scalar-kernel f32 \
               batched_decode baseline row");
    }
    if any_reference && !batched_threaded {
        bail!("rule pair-batched-threaded: no blocked f32 \
               batched_decode row with threads >= 2");
    }
    if any_reference && !batched_int8 {
        bail!("rule pair-batched-int8: no int8 batched_decode row \
               (the DESIGN.md §11 quantization gate needs the \
               int8-vs-f32 pair on reference-backend recordings)");
    }
    // the DESIGN.md §12 chunked-prefill gate: reference recordings
    // must carry the whole-vs-chunked long_prompt_interactive pair so
    // chunked_stall_ratio() always yields the acceptance figure
    if any_reference && !(interactive_whole && interactive_chunked) {
        bail!("rule pair-interactive-chunked: missing \
               long_prompt_interactive prefill_chunk pair (need a \
               prefill_chunk = 0 row AND a chunked row on \
               reference-backend recordings — DESIGN.md §12)");
    }
    // the DESIGN.md §13 continuous-batching gate: reference
    // recordings must carry the fcfs-vs-continuous
    // shared_prefix_storm pair so storm_row() always yields the
    // acceptance comparison
    if any_reference && !(storm_fcfs && storm_continuous) {
        bail!("rule pair-storm-scheduler: missing shared_prefix_storm \
               scheduler pair (need a scheduler = \"fcfs\" row AND a \
               \"continuous\" row on reference-backend recordings — \
               DESIGN.md §13)");
    }
    // the DESIGN.md §15 speculative gate: reference recordings must
    // carry the spec-off/spec-on speculative_decode pair so
    // spec_row() always yields the acceptance comparison
    if any_reference && !(spec_off && spec_on) {
        bail!("rule pair-speculative: missing speculative_decode \
               spec_k pair (need a spec_k = 0 row AND a spec_k > 0 \
               row on reference-backend recordings — DESIGN.md §15)");
    }
    // the DESIGN.md §16 serving-front gate: reference recordings must
    // carry the fcfs-vs-continuous connection_storm pair so
    // conn_storm_row() always yields the frame-latency + shed-rate
    // comparison
    if any_reference && !(cstorm_fcfs && cstorm_continuous) {
        bail!("rule storm-pair: missing connection_storm scheduler \
               pair (need a scheduler = \"fcfs\" row AND a \
               \"continuous\" row on reference-backend recordings — \
               DESIGN.md §16)");
    }
    // the DESIGN.md §17 elastic gate: reference recordings must carry
    // a failover row that actually absorbed a kill, so the recorded
    // recovery_stall_ms always measures a real fleet rebuild
    if any_reference && !failover_recovered {
        bail!("rule failover-coverage: no failover row with \
               recoveries >= 1 (the DESIGN.md §17 elastic gate needs \
               a recorded kill-and-recover on reference-backend \
               recordings)");
    }
    // the DESIGN.md §14 ISA gate: reference recordings must compare
    // at least two instruction tiers on batched_decode — every host
    // can supply {scalar, vnni}, so availability is no excuse
    if any_reference && batched_isas.len() < 2 {
        bail!("rule isa-coverage: batched_decode rows cover only \
               {batched_isas:?}, need >= 2 distinct isa tiers on \
               reference-backend recordings (DESIGN.md §14)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;

    fn tiny_cfg() -> EngineConfig {
        EngineConfig {
            model: "tiny".into(),
            backend: BackendKind::Reference,
            ..Default::default()
        }
    }

    #[test]
    fn standard_suite_shape() {
        let s = standard_suite();
        assert!(s.len() >= 5);
        let names: Vec<&str> =
            s.iter().map(|x| x.name.as_str()).collect();
        for required in ["single_stream_decode", "batched_decode",
                         "prefill_heavy", "mixed",
                         "long_prompt_interactive",
                         "shared_prefix_storm",
                         "speculative_decode"] {
            assert!(names.contains(&required), "missing {required}");
        }
        for sc in &s {
            assert!(!sc.prompt_lens.is_empty());
            assert!(!sc.new_tokens.is_empty());
            assert!(sc.requests >= sc.batch);
        }
        // the storm's shared prefix must be shorter than its prompts
        // (a tail always remains to prefill) and page-aligned enough
        // to actually publish (>= one 16-token KV page)
        let storm = s.iter()
            .find(|x| x.name == "shared_prefix_storm")
            .unwrap();
        assert!(storm.shared_prefix_len >= 16);
        assert!(storm.prompt_lens.iter()
                     .all(|&p| p > storm.shared_prefix_len));
        // quick mode must keep more requests than lanes, so the reuse
        // wave (arrivals after the first misses publish) survives
        let q = storm.clone().quicken();
        assert!(q.requests > q.batch);
    }

    #[test]
    fn quicken_shrinks_but_keeps_shape() {
        let q = standard_suite()
            .into_iter()
            .map(Scenario::quicken)
            .collect::<Vec<_>>();
        for sc in &q {
            assert!(sc.new_tokens.iter().all(|&n| n >= 4));
            assert!(sc.requests >= 2);
        }
        // the quick interactive workload must keep its injected long
        // prompt (index batch..): requests > batch
        let li = q.iter()
            .find(|s| s.name == "long_prompt_interactive")
            .unwrap();
        assert!(li.requests > li.batch,
                "quick mode dropped the injected long prompt");
    }

    #[test]
    fn single_scenario_records_and_validates() {
        let mut cfg = tiny_cfg();
        cfg.world = 1;
        cfg.threads = 2;
        let sc = standard_suite()
            .into_iter()
            .find(|s| s.name == "batched_decode")
            .unwrap()
            .quicken();
        let rec = run_scenario(&cfg, &sc).unwrap();
        assert_eq!(rec.requests_done as usize, sc.requests);
        assert!(rec.tokens_out > 0);
        assert!(rec.ms_per_token >= 0.0);
        assert!(rec.comm.allreduces > 0);
        // the reference backend measures its footprint
        assert!(rec.weight_bytes > 0 && rec.kv_bytes > 0);
        let j = Json::parse(&rec.to_json().to_string()).unwrap();
        assert_eq!(j.get("name").and_then(Json::as_str),
                   Some("batched_decode"));
        assert_eq!(j.get("kernel").and_then(Json::as_str),
                   Some("blocked"));
        // auto-resolved, so host-dependent — but always a known tier,
        // and never vnni (vnni is opt-in only) unless the env
        // override forced it
        let isa = j.get("isa").and_then(Json::as_str).unwrap();
        if std::env::var_os(simd::FORCE_ISA_ENV).is_none() {
            assert!(matches!(isa, "scalar" | "avx2" | "avx512"),
                    "unexpected auto-resolved isa {isa:?}");
        }
        assert_eq!(j.get("backend").and_then(Json::as_str),
                   Some("reference"));
        assert_eq!(j.get("weight_dtype").and_then(Json::as_str),
                   Some("f32"));
        assert_eq!(j.get("kv_dtype").and_then(Json::as_str),
                   Some("f32"));
        assert!(j.get("weight_bytes").and_then(Json::as_u64).unwrap() > 0);
        assert!(j.get("kv_bytes").and_then(Json::as_u64).unwrap() > 0);
    }

    #[test]
    fn int8_scenario_records_smaller_footprint() {
        let mut f32_cfg = tiny_cfg();
        f32_cfg.world = 1;
        f32_cfg.threads = 2;
        let mut q8_cfg = f32_cfg.clone();
        q8_cfg.weight_dtype = crate::config::Dtype::Int8;
        q8_cfg.kv_dtype = crate::config::Dtype::Int8;
        let sc = standard_suite()
            .into_iter()
            .find(|s| s.name == "batched_decode")
            .unwrap()
            .quicken();
        let f = run_scenario(&f32_cfg, &sc).unwrap();
        let q = run_scenario(&q8_cfg, &sc).unwrap();
        assert!(q.weight_bytes < f.weight_bytes);
        // tiny's head_dim 8 puts the int8 KV ratio at 0.375, not ~¼
        assert!(q.kv_bytes * 2 < f.kv_bytes);
        let j = Json::parse(&q.to_json().to_string()).unwrap();
        assert_eq!(j.get("weight_dtype").and_then(Json::as_str),
                   Some("int8"));
        assert_eq!(j.get("kv_dtype").and_then(Json::as_str),
                   Some("int8"));
    }

    #[test]
    fn failover_scenario_recovers_bit_identically() {
        let mut cfg = tiny_cfg();
        cfg.world = 2;
        cfg.threads = 2;
        // run_failover pins the recovered streams against an
        // undisturbed plain-engine run internally; reaching Ok means
        // the fuse blew, the fleet rebuilt, and the streams matched
        let rec = run_failover(&cfg, true).unwrap();
        assert_eq!(rec.name, "failover");
        assert!(rec.recoveries >= 1);
        assert_eq!(rec.requests_done as usize, rec.requests);
        let j = Json::parse(&rec.to_json().to_string()).unwrap();
        assert!(j.get("recoveries").and_then(Json::as_u64).unwrap()
                    >= 1);
        assert!(j.get("recovery_stall_ms").and_then(Json::as_u64)
                    .is_some());
    }

    #[test]
    fn matrix_document_passes_validation() {
        // a forced ISA pins every row to one tier, so the matrix
        // can't cover the §14 comparison it normally records
        if std::env::var_os(simd::FORCE_ISA_ENV).is_some() {
            return;
        }
        // world=1-only matrix is fast; splice the same rows into
        // worlds 2 and 4 to exercise the full validator offline
        let recs =
            run_matrix(&tiny_cfg(), &[1], true, |_| {}).unwrap();
        // the fixed default keeps recordings host-independent
        assert!(recs.iter().all(|r| r.threads <= 2));
        let mut all = recs.clone();
        for w in [2usize, 4] {
            for r in &recs {
                let mut c = r.clone();
                c.world = w;
                all.push(c);
            }
        }
        let doc = matrix_to_json("unit", "tiny", true, &[1, 2, 4], &all);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        validate_bench(&parsed).unwrap();
        assert!(batched_speedup(&parsed, 1).is_some());
        assert!(int8_speedup(&parsed, 1).is_some());
        // the §14 per-ISA rows: scalar and vnni are host-independent,
        // so every matrix carries at least this comparison pair
        for isa in ["scalar", "vnni"] {
            assert!(recs.iter().any(|r| r.name == "batched_decode"
                                        && r.isa == isa),
                    "no batched_decode row at isa={isa}");
        }
        // the §13 scheduler pair is recorded, and the continuous row
        // actually exercised the reuse path (hits > 0 once the first
        // wave of misses published the prefix)
        let fcfs = storm_row(&parsed, 1, "fcfs").unwrap();
        let cont = storm_row(&parsed, 1, "continuous").unwrap();
        assert_eq!(fcfs.2, 0.0, "fcfs rows never attach prefixes");
        assert!(cont.2 > 0.0,
                "continuous storm row recorded no prefix hits");
        // the §12 pair is recorded, so the stall comparison resolves
        // whenever the chunked row measured a non-zero stall
        assert!(recs.iter().any(|r| r.name == "long_prompt_interactive"
                                    && r.prefill_chunk == 0));
        assert!(recs.iter().any(|r| r.name == "long_prompt_interactive"
                                    && r.prefill_chunk > 0));
        // the §15 speculative pair is recorded: the spec-off row never
        // accepts anything, the spec-on row ran the nano draft at k=4
        // through the full draft/verify/rollback path
        // the §16 serving-front pair is recorded: both scheduler rows
        // exist, rates are sane, and the quick fcfs storm actually
        // shed (the wave size outruns its 4-admissions-per-tick
        // drain, so the 64-deep queue must fill)
        let cs_fcfs = conn_storm_row(&parsed, 1, "fcfs").unwrap();
        let cs_cont = conn_storm_row(&parsed, 1, "continuous").unwrap();
        for row in [&cs_fcfs, &cs_cont] {
            // the opening wave outruns STORM_SHED_QUEUE before any
            // tick, so both scheduler rows must have shed something
            assert!(row.1 > 0.0 && row.1 <= 1.0,
                    "storm shed_rate out of (0, 1]: {}", row.1);
            assert!(row.0 >= 0.0);
        }
        // engine-direct rows never touch the serving front
        assert!(recs.iter()
                    .filter(|r| r.name != "connection_storm")
                    .all(|r| r.shed_rate == 0.0
                        && r.frame_p99_us == 0));
        // the §17 elastic row is recorded: the chaos fuse blew, the
        // fleet rebuilt, and the stall was measured
        let fo = failover_row(&parsed, 1).unwrap();
        assert!(fo.0 >= 1, "failover row absorbed no recovery");
        assert!(recs.iter()
                    .filter(|r| r.name != "failover")
                    .all(|r| r.recoveries == 0
                        && r.recovery_stall_ms == 0),
                "only failover rows may record recoveries");
        let off = spec_row(&parsed, 1, false).unwrap();
        let on = spec_row(&parsed, 1, true).unwrap();
        assert_eq!(off.2, 0.0, "spec-off rows cannot accept drafts");
        assert!((0.0..=1.0).contains(&on.2),
                "accept_rate out of range: {}", on.2);
        let on_rec = recs.iter()
            .find(|r| r.name == "speculative_decode" && r.spec_k > 0)
            .unwrap();
        assert_eq!(on_rec.spec_k, 4);
        assert_eq!(on_rec.requests_done as usize, on_rec.requests,
                   "speculating run must retire every request");

        // a narrower sweep validates against its own declared worlds
        let narrow = matrix_to_json("unit", "tiny", true, &[1], &recs);
        validate_bench(&Json::parse(&narrow.to_string()).unwrap())
            .unwrap();
    }

    #[test]
    fn validation_requires_dtype_and_memory_fields() {
        let recs =
            run_matrix(&tiny_cfg(), &[1], true, |_| {}).unwrap();
        let doc = matrix_to_json("unit", "tiny", true, &[1], &recs);
        let text = doc.to_string();
        // strip each required §11/§12/§13 field in turn; validation
        // must fail
        for field in ["weight_dtype", "kv_dtype", "weight_bytes",
                      "kv_bytes", "backend", "prefill_chunk",
                      "decode_stall_p99_us", "scheduler",
                      "prefix_hit_rate", "isa", "spec_k",
                      "accept_rate", "shed_rate", "frame_p99_us",
                      "recoveries", "recovery_stall_ms"] {
            let crippled =
                text.replace(&format!("\"{field}\""),
                             &format!("\"x_{field}\""));
            let parsed = Json::parse(&crippled).unwrap();
            assert!(validate_bench(&parsed).is_err(),
                    "validator accepted a document without {field}");
        }
        // a bogus dtype string must also fail
        let bad = text.replace("\"int8\"", "\"int4\"");
        assert!(validate_bench(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn validation_rejects_gaps() {
        let recs =
            run_matrix(&tiny_cfg(), &[1], true, |_| {}).unwrap();
        // document claims worlds {1,2,4} but only has world-1 rows
        let doc = matrix_to_json("unit", "tiny", true, &[1, 2, 4], &recs);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert!(validate_bench(&parsed).is_err());
        assert!(validate_bench(&Json::parse("{}").unwrap()).is_err());
    }

    /// Satellite: every validator rule, when tripped alone on an
    /// otherwise-valid document, must fail with a message naming that
    /// rule and the offending row — the CI failure output contract.
    #[test]
    fn validator_failures_name_their_rule() {
        // the corruptions below assume the matrix's normal per-ISA
        // row coverage, which a forced ISA collapses to one tier
        if std::env::var_os(simd::FORCE_ISA_ENV).is_some() {
            return;
        }
        let recs =
            run_matrix(&tiny_cfg(), &[1], true, |_| {}).unwrap();
        let doc = |rows: &[ScenarioRecord], worlds: &[usize]| {
            let d = matrix_to_json("unit", "tiny", true, worlds, rows);
            Json::parse(&d.to_string()).unwrap()
        };
        let err_of = |j: &Json| {
            format!("{:#}", validate_bench(j).unwrap_err())
        };

        // text-level corruptions: strip or mangle one token of the
        // serialized document
        let text = doc(&recs, &[1]).to_string();
        for (rule, from, to) in [
            ("rule schema-tag:", "xeonserve-bench/v1", "bogus/v0"),
            ("rule doc-strings:", "\"bench\"", "\"x_bench\""),
            ("rule worlds-declared:", "\"worlds\"", "\"x_worlds\""),
            ("rule rows-present:", "\"scenarios\"", "\"x_scenarios\""),
            ("rule row-name:", "\"name\"", "\"x_name\""),
            ("rule row-counter-fields:",
             "\"tokens_out\"", "\"x_tokens_out\""),
            ("rule row-latency-fields:", "\"ttft_ms\"", "\"x_ttft_ms\""),
            ("rule row-kernel:", "\"blocked\"", "\"warped\""),
            // "vnni" appears only as an isa value (never a kernel),
            // so this trips row-isa and nothing upstream of it
            ("rule row-isa:", "\"vnni\"", "\"mmx\""),
            ("rule row-backend:", "\"reference\"", "\"refurbished\""),
            ("rule row-dtype:", "\"f32\"", "\"f16\""),
            ("rule row-comm:", "\"comm\"", "\"x_comm\""),
            ("rule row-scheduler:", "\"continuous\"", "\"lottery\""),
            ("rule row-prefix-hit-rate:",
             "\"prefix_hit_rate\"", "\"x_prefix_hit_rate\""),
            ("rule row-shed-rate:", "\"shed_rate\"", "\"x_shed_rate\""),
            ("rule row-recovery:",
             "\"recovery_stall_ms\"", "\"x_recovery_stall_ms\""),
        ] {
            let parsed = Json::parse(&text.replace(from, to)).unwrap();
            let e = err_of(&parsed);
            assert!(e.contains(rule),
                    "{from} -> {to}: expected {rule:?} in {e:?}");
        }

        // value-level corruption: a hit rate outside [0, 1]
        let mut bad = recs.clone();
        bad[0].prefix_hit_rate = 1.5;
        assert!(err_of(&doc(&bad, &[1]))
                    .contains("rule row-prefix-hit-rate:"));

        // a shed rate outside [0, 1] likewise
        let mut bad = recs.clone();
        bad[0].shed_rate = 1.5;
        assert!(err_of(&doc(&bad, &[1]))
                    .contains("rule row-shed-rate:"));

        // spec-field value corruptions: an out-of-range accept rate,
        // an out-of-range depth, and a spec-off row claiming accepts
        let mut bad = recs.clone();
        bad[0].accept_rate = 1.5;
        bad[0].spec_k = 2;
        assert!(err_of(&doc(&bad, &[1])).contains("rule spec-fields:"));
        let mut bad = recs.clone();
        bad[0].spec_k = 9;
        assert!(err_of(&doc(&bad, &[1])).contains("rule spec-fields:"));
        let mut bad = recs.clone();
        bad[0].spec_k = 0;
        bad[0].accept_rate = 0.5;
        assert!(err_of(&doc(&bad, &[1])).contains("rule spec-fields:"));

        // recovery-field value corruptions: a stall on a row that
        // recovered nothing, and a failover row that never recovered
        let mut bad = recs.clone();
        bad[0].recovery_stall_ms = 250;
        assert!(err_of(&doc(&bad, &[1])).contains("rule row-recovery:"));
        let mut bad = recs.clone();
        for r in &mut bad {
            if r.name == "failover" {
                r.recoveries = 0;
                r.recovery_stall_ms = 0;
            }
        }
        assert!(err_of(&doc(&bad, &[1])).contains("rule row-recovery:"));

        // every batched_decode row on the same tier: each row is
        // individually fine, but the §14 comparison is gone
        let mut mono = recs.clone();
        for r in &mut mono {
            r.isa = "scalar".into();
        }
        assert!(err_of(&doc(&mono, &[1]))
                    .contains("rule isa-coverage:"));

        // coverage rules
        let one_name: Vec<ScenarioRecord> = recs.iter()
            .filter(|r| r.name == "batched_decode")
            .cloned()
            .collect();
        assert!(err_of(&doc(&one_name, &[1]))
                    .contains("rule coverage-scenarios:"));
        assert!(err_of(&doc(&recs, &[1, 2]))
                    .contains("rule coverage-worlds:"));

        // pair rules: drop one half of each acceptance pair
        let without = |pred: &dyn Fn(&ScenarioRecord) -> bool| {
            recs.iter()
                .filter(|r| !pred(r))
                .cloned()
                .collect::<Vec<ScenarioRecord>>()
        };
        for (rule, gone) in [
            ("rule pair-batched-scalar:",
             without(&|r| r.kernel == GemmKernel::Scalar)),
            ("rule pair-batched-threaded:",
             without(&|r| r.name == "batched_decode"
                 && r.kernel == GemmKernel::Blocked
                 && r.threads >= 2
                 && r.weight_dtype == Dtype::F32)),
            ("rule pair-batched-int8:",
             without(&|r| r.weight_dtype == Dtype::Int8)),
            ("rule pair-interactive-chunked:",
             without(&|r| r.prefill_chunk > 0)),
            ("rule pair-storm-scheduler:",
             without(&|r| r.scheduler == SchedulerKind::Continuous)),
            ("rule pair-speculative:", without(&|r| r.spec_k > 0)),
            // drop only the connection_storm continuous row, so the
            // shared_prefix_storm pair stays intact and storm-pair is
            // the first rule to trip
            ("rule storm-pair:",
             without(&|r| r.name == "connection_storm"
                 && r.scheduler == SchedulerKind::Continuous)),
            ("rule failover-coverage:",
             without(&|r| r.name == "failover")),
        ] {
            let e = err_of(&doc(&gone, &[1]));
            assert!(e.contains(rule), "expected {rule:?} in {e:?}");
        }
    }
}
