//! Tiny benchmarking harness (criterion substitute — offline build).
//!
//! Provides warmup + timed iterations with mean/p50/p95 statistics and a
//! uniform table/CSV output so every `rust/benches/*.rs` prints the rows
//! the corresponding paper table/figure reports (DESIGN.md §6 maps bench
//! → experiment).  `cargo bench` runs these binaries (harness = false).

use std::time::{Duration, Instant};

use crate::metrics::LatencyStats;

/// Result of one measured case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    /// free-form extra columns (bytes on wire, sim latency, ...)
    pub extra: Vec<(String, String)>,
}

/// Measure `f` (one logical iteration per call) `iters` times after
/// `warmup` unmeasured calls.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                           mut f: F) -> CaseResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = LatencyStats::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        stats.record(t0.elapsed());
    }
    CaseResult {
        name: name.to_string(),
        iters,
        mean_us: stats.mean_us(),
        p50_us: stats.p50_us(),
        p95_us: stats.p95_us(),
        extra: Vec::new(),
    }
}

/// Measure a fallible closure, propagating the first error.
pub fn measure_result<F>(name: &str, warmup: usize, iters: usize, mut f: F)
                         -> anyhow::Result<CaseResult>
where
    F: FnMut() -> anyhow::Result<()>,
{
    for _ in 0..warmup {
        f()?;
    }
    let mut stats = LatencyStats::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        stats.record(t0.elapsed());
    }
    Ok(CaseResult {
        name: name.to_string(),
        iters,
        mean_us: stats.mean_us(),
        p50_us: stats.p50_us(),
        p95_us: stats.p95_us(),
        extra: Vec::new(),
    })
}

impl CaseResult {
    pub fn with(mut self, key: &str, value: impl std::fmt::Display)
                -> CaseResult {
        self.extra.push((key.to_string(), value.to_string()));
        self
    }

    /// Build a case from externally collected samples (e.g. the engine's
    /// per-decode-step metrics).
    pub fn from_stats(name: &str, stats: &mut LatencyStats) -> CaseResult {
        CaseResult {
            name: name.to_string(),
            iters: stats.count(),
            mean_us: stats.mean_us(),
            p50_us: stats.p50_us(),
            p95_us: stats.p95_us(),
            extra: Vec::new(),
        }
    }
}

/// Render results as an aligned table with a title; also emits a
/// machine-readable `#csv` block for harvesting into EXPERIMENTS.md.
pub fn report(title: &str, results: &[CaseResult]) {
    println!("\n=== {title} ===");
    let name_w = results
        .iter()
        .map(|r| r.name.len())
        .max()
        .unwrap_or(4)
        .max(4);
    print!("{:<name_w$}  {:>10}  {:>10}  {:>10}  {:>6}", "case", "mean_us",
           "p50_us", "p95_us", "iters");
    let extras: Vec<String> = results
        .first()
        .map(|r| r.extra.iter().map(|(k, _)| k.clone()).collect())
        .unwrap_or_default();
    for k in &extras {
        print!("  {k:>12}");
    }
    println!();
    for r in results {
        print!(
            "{:<name_w$}  {:>10.1}  {:>10}  {:>10}  {:>6}",
            r.name, r.mean_us, r.p50_us, r.p95_us, r.iters
        );
        for k in &extras {
            let v = r
                .extra
                .iter()
                .find(|(ek, _)| ek == k)
                .map(|(_, v)| v.as_str())
                .unwrap_or("-");
            print!("  {v:>12}");
        }
        println!();
    }
    // csv block
    print!("#csv,case,mean_us,p50_us,p95_us,iters");
    for k in &extras {
        print!(",{k}");
    }
    println!();
    for r in results {
        print!("#csv,{},{:.1},{},{},{}", r.name, r.mean_us, r.p50_us,
               r.p95_us, r.iters);
        for k in &extras {
            let v = r
                .extra
                .iter()
                .find(|(ek, _)| ek == k)
                .map(|(_, v)| v.as_str())
                .unwrap_or("");
            print!(",{v}");
        }
        println!();
    }
}

/// `--quick` on the command line shrinks iteration counts (CI mode).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick" || a == "--test")
}

/// Scale an iteration count down in quick mode.
pub fn iters(full: usize) -> usize {
    if quick_mode() {
        (full / 8).max(1)
    } else {
        full
    }
}

/// Sleep-free busy wait used by calibration tests.
pub fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0;
        let r = measure("x", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_us >= 0.0);
    }

    #[test]
    fn measure_records_spin_time() {
        let r = measure("spin", 0, 3,
                        || spin_for(Duration::from_micros(200)));
        assert!(r.mean_us >= 150.0, "mean {}", r.mean_us);
    }

    #[test]
    fn extra_columns() {
        let r = measure("x", 0, 1, || {}).with("bytes", 42);
        assert_eq!(r.extra[0], ("bytes".to_string(), "42".to_string()));
    }

    #[test]
    fn measure_result_propagates_errors() {
        let r = measure_result("x", 0, 1, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }
}
