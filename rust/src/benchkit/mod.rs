//! Tiny benchmarking harness (criterion substitute — offline build).
//!
//! Provides warmup + timed iterations with mean/p50/p95 statistics, a
//! uniform table/CSV output, and machine-readable JSON so every
//! `rust/benches/*.rs` records the rows the corresponding paper
//! table/figure reports (DESIGN.md §6 maps bench → experiment, §10 the
//! recording workflow).  `cargo bench` runs these binaries
//! (`harness = false`); passing `--json PATH` to any of them persists
//! the tables for later diffing.
//!
//! The [`suite`] submodule is the serving-level counterpart: named
//! scenarios driven through the full engine, recorded to the
//! `BENCH_*.json` schema by `xeonserve bench`.
//!
//! # Example
//!
//! ```
//! use xeonserve::benchkit;
//!
//! let mut calls = 0;
//! let r = benchkit::measure("noop", /*warmup*/ 1, /*iters*/ 3, || {
//!     calls += 1;
//! });
//! assert_eq!(calls, 4); // warmup + timed iterations
//! assert_eq!(r.iters, 3);
//! let json = r.to_json().to_string();
//! assert!(json.contains("\"name\":\"noop\""));
//! ```

#![warn(missing_docs)]

pub mod suite;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::metrics::LatencyStats;
use crate::util::Json;

/// Result of one measured case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    /// case label (one table row)
    pub name: String,
    /// timed iterations contributing samples
    pub iters: usize,
    /// mean latency per iteration, microseconds
    pub mean_us: f64,
    /// nearest-rank median, microseconds
    pub p50_us: u64,
    /// nearest-rank 95th percentile, microseconds
    pub p95_us: u64,
    /// free-form extra columns (bytes on wire, sim latency, ...)
    pub extra: Vec<(String, String)>,
}

/// Measure `f` (one logical iteration per call) `iters` times after
/// `warmup` unmeasured calls.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                           mut f: F) -> CaseResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = LatencyStats::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        stats.record(t0.elapsed());
    }
    CaseResult {
        name: name.to_string(),
        iters,
        mean_us: stats.mean_us(),
        p50_us: stats.p50_us(),
        p95_us: stats.p95_us(),
        extra: Vec::new(),
    }
}

/// Measure a fallible closure, propagating the first error.
pub fn measure_result<F>(name: &str, warmup: usize, iters: usize, mut f: F)
                         -> anyhow::Result<CaseResult>
where
    F: FnMut() -> anyhow::Result<()>,
{
    for _ in 0..warmup {
        f()?;
    }
    let mut stats = LatencyStats::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        stats.record(t0.elapsed());
    }
    Ok(CaseResult {
        name: name.to_string(),
        iters,
        mean_us: stats.mean_us(),
        p50_us: stats.p50_us(),
        p95_us: stats.p95_us(),
        extra: Vec::new(),
    })
}

impl CaseResult {
    /// Attach an extra column (rendered in the table, CSV and JSON).
    pub fn with(mut self, key: &str, value: impl std::fmt::Display)
                -> CaseResult {
        self.extra.push((key.to_string(), value.to_string()));
        self
    }

    /// Build a case from externally collected samples (e.g. the engine's
    /// per-decode-step metrics).
    pub fn from_stats(name: &str, stats: &mut LatencyStats) -> CaseResult {
        CaseResult {
            name: name.to_string(),
            iters: stats.count(),
            mean_us: stats.mean_us(),
            p50_us: stats.p50_us(),
            p95_us: stats.p95_us(),
            extra: Vec::new(),
        }
    }

    /// Serialize to a JSON object:
    /// `{name, iters, mean_us, p50_us, p95_us, extra: {k: v}}`.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("iters".into(), Json::Num(self.iters as f64));
        o.insert("mean_us".into(), Json::Num(self.mean_us));
        o.insert("p50_us".into(), Json::Num(self.p50_us as f64));
        o.insert("p95_us".into(), Json::Num(self.p95_us as f64));
        let mut extra = BTreeMap::new();
        for (k, v) in &self.extra {
            extra.insert(k.clone(), Json::Str(v.clone()));
        }
        o.insert("extra".into(), Json::Obj(extra));
        Json::Obj(o)
    }
}

/// Render results as an aligned table with a title; also emits a
/// machine-readable `#csv` block for harvesting into EXPERIMENTS.md.
pub fn report(title: &str, results: &[CaseResult]) {
    println!("\n=== {title} ===");
    let name_w = results
        .iter()
        .map(|r| r.name.len())
        .max()
        .unwrap_or(4)
        .max(4);
    print!("{:<name_w$}  {:>10}  {:>10}  {:>10}  {:>6}", "case", "mean_us",
           "p50_us", "p95_us", "iters");
    let extras: Vec<String> = results
        .first()
        .map(|r| r.extra.iter().map(|(k, _)| k.clone()).collect())
        .unwrap_or_default();
    for k in &extras {
        print!("  {k:>12}");
    }
    println!();
    for r in results {
        print!(
            "{:<name_w$}  {:>10.1}  {:>10}  {:>10}  {:>6}",
            r.name, r.mean_us, r.p50_us, r.p95_us, r.iters
        );
        for k in &extras {
            let v = r
                .extra
                .iter()
                .find(|(ek, _)| ek == k)
                .map(|(_, v)| v.as_str())
                .unwrap_or("-");
            print!("  {v:>12}");
        }
        println!();
    }
    // csv block
    print!("#csv,case,mean_us,p50_us,p95_us,iters");
    for k in &extras {
        print!(",{k}");
    }
    println!();
    for r in results {
        print!("#csv,{},{:.1},{},{},{}", r.name, r.mean_us, r.p50_us,
               r.p95_us, r.iters);
        for k in &extras {
            let v = r
                .extra
                .iter()
                .find(|(ek, _)| ek == k)
                .map(|(_, v)| v.as_str())
                .unwrap_or("");
            print!(",{v}");
        }
        println!();
    }
}

/// Collects every section a bench binary reports and, when the process
/// was started with `--json PATH`, persists them as one JSON document
/// (`{"schema": "xeonserve-bench-micro/v1", "bench", "sections"}`).
///
/// Usage: replace bare [`report`] calls with [`JsonReport::section`]
/// and call [`JsonReport::finish`] at the end of `main`.
pub struct JsonReport {
    bench: String,
    sections: Vec<(String, Vec<CaseResult>)>,
}

impl JsonReport {
    /// Start a report for the named bench binary.
    ///
    /// # Panics
    /// When the process was started with a trailing valueless
    /// `--json` — failing before the sweep beats silently writing
    /// nothing after it.
    pub fn new(bench: &str) -> JsonReport {
        assert!(
            !json_flag_missing_path(),
            "--json requires a PATH argument (e.g. --json out.json)"
        );
        JsonReport { bench: bench.to_string(), sections: Vec::new() }
    }

    /// Print one table (exactly like [`report`]) and retain the rows
    /// for the JSON document.
    pub fn section(&mut self, title: &str, results: Vec<CaseResult>) {
        report(title, &results);
        self.sections.push((title.to_string(), results));
    }

    /// The full document as a [`Json`] value.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("schema".into(),
                 Json::Str("xeonserve-bench-micro/v1".into()));
        o.insert("bench".into(), Json::Str(self.bench.clone()));
        let sections = self
            .sections
            .iter()
            .map(|(title, cases)| {
                let mut s = BTreeMap::new();
                s.insert("title".into(), Json::Str(title.clone()));
                s.insert(
                    "cases".into(),
                    Json::Arr(cases.iter().map(CaseResult::to_json)
                                   .collect()),
                );
                Json::Obj(s)
            })
            .collect();
        o.insert("sections".into(), Json::Arr(sections));
        Json::Obj(o)
    }

    /// Write the document to the `--json PATH` argument, if one was
    /// given; otherwise a no-op.  A trailing `--json` with no PATH is
    /// an error (caught in [`JsonReport::new`] as well, before the
    /// sweep runs).
    pub fn finish(self) -> anyhow::Result<()> {
        if let Some(path) = json_path_arg() {
            std::fs::write(&path, self.to_json().to_string())?;
            eprintln!("wrote {}", path.display());
        } else if json_flag_missing_path() {
            anyhow::bail!("--json requires a PATH argument");
        }
        Ok(())
    }
}

/// The `PATH` of a `--json PATH` command-line argument, if present.
/// A valueless `--json` (trailing, or followed by another `-` flag)
/// yields `None` — benches should call [`JsonReport::new`] early,
/// which rejects that loudly instead of silently discarding a whole
/// sweep (or writing to a file named like a flag).
pub fn json_path_arg() -> Option<PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    // --json=PATH form
    if let Some(p) = args
        .iter()
        .find_map(|a| a.strip_prefix("--json="))
        .filter(|p| !p.is_empty() && !p.starts_with('-'))
    {
        return Some(PathBuf::from(p));
    }
    // --json PATH form
    args.windows(2)
        .find(|w| w[0] == "--json")
        .map(|w| w[1].clone())
        .filter(|p| !p.starts_with('-'))
        .map(PathBuf::from)
}

/// True when `--json` was passed (either form) but no usable PATH
/// operand came with it (end of argv, next token is another flag, or
/// an empty `--json=`).
fn json_flag_missing_path() -> bool {
    std::env::args().any(|a| a == "--json" || a.starts_with("--json="))
        && json_path_arg().is_none()
}

/// `--quick` on the command line shrinks iteration counts (CI mode).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick" || a == "--test")
}

/// Scale an iteration count down in quick mode.
pub fn iters(full: usize) -> usize {
    if quick_mode() {
        (full / 8).max(1)
    } else {
        full
    }
}

/// Sleep-free busy wait used by calibration tests.
pub fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0;
        let r = measure("x", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_us >= 0.0);
    }

    #[test]
    fn measure_records_spin_time() {
        let r = measure("spin", 0, 3,
                        || spin_for(Duration::from_micros(200)));
        assert!(r.mean_us >= 150.0, "mean {}", r.mean_us);
    }

    #[test]
    fn extra_columns() {
        let r = measure("x", 0, 1, || {}).with("bytes", 42);
        assert_eq!(r.extra[0], ("bytes".to_string(), "42".to_string()));
    }

    #[test]
    fn measure_result_propagates_errors() {
        let r = measure_result("x", 0, 1, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn case_json_roundtrips_through_parser() {
        let r = measure("case_a", 0, 2, || {}).with("kB", 7);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("case_a"));
        assert_eq!(j.get("iters").and_then(Json::as_usize), Some(2));
        assert_eq!(
            j.get("extra").and_then(|e| e.get("kB"))
                .and_then(Json::as_str),
            Some("7")
        );
    }

    #[test]
    fn json_report_document_shape() {
        let mut rep = JsonReport::new("unit_test");
        // section() prints; that is fine under cargo test capture
        rep.section("t1", vec![measure("a", 0, 1, || {})]);
        rep.section("t2", vec![measure("b", 0, 1, || {})]);
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str),
                   Some("xeonserve-bench-micro/v1"));
        assert_eq!(j.get("bench").and_then(Json::as_str),
                   Some("unit_test"));
        assert_eq!(j.get("sections").and_then(Json::as_arr).unwrap().len(),
                   2);
    }
}
