//! Configuration system: engine/runtime settings (TOML), model manifest,
//! and the optimization switches corresponding to the paper's §2.1–§2.3.
//!
//! Deserialization is hand-rolled over [`crate::util::Json`] (offline
//! build — no serde; the TOML parser shares the Json value model).

mod manifest;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use manifest::{GoldenMeta, Manifest, ModelPreset, SegmentMeta, TensorMeta};

use crate::ccl::wire::WireModel;
use crate::util::{parse_toml, Json};

/// Decoder block variant (DESIGN.md §2): `Parallel` fuses attention+FFN
/// into one segment (ONE allreduce/layer, the paper's §2.2); `Serial` is
/// the classic two-sync layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Parallel,
    Serial,
}

impl Variant {
    pub fn syncs_per_layer(&self) -> usize {
        match self {
            Variant::Parallel => 1,
            Variant::Serial => 2,
        }
    }

    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "parallel" => Ok(Variant::Parallel),
            "serial" => Ok(Variant::Serial),
            _ => bail!("unknown variant {s:?} (parallel|serial)"),
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Variant::Parallel => write!(f, "parallel"),
            Variant::Serial => write!(f, "serial"),
        }
    }
}

/// Which execution backend materializes the model math (DESIGN.md §9).
///
/// * `Reference` — the pure-Rust deterministic transformer: no native
///   deps, no artifacts, runs anywhere `cargo` runs.  The hermetic
///   test tier and the default build use it.
/// * `Xla` — the PJRT runtime executing AOT-compiled HLO segments from
///   `artifacts/` (requires building with `--features xla`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Reference,
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "reference" => Ok(BackendKind::Reference),
            "xla" => Ok(BackendKind::Xla),
            _ => bail!("unknown backend {s:?} (reference|xla)"),
        }
    }

    /// Build-dependent default: the XLA path when it is compiled in
    /// (so artifact-driven examples/benches keep their old behavior),
    /// the hermetic reference backend otherwise.
    pub fn build_default() -> BackendKind {
        if cfg!(feature = "xla") {
            BackendKind::Xla
        } else {
            BackendKind::Reference
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendKind::Reference => write!(f, "reference"),
            BackendKind::Xla => write!(f, "xla"),
        }
    }
}

/// Which GEMM implementation the reference backend's compute kernels
/// use (DESIGN.md §10).
///
/// * `Blocked` — cache-blocked, row-fused GEMMs fanned out over the
///   per-rank worker pool ([`EngineConfig::threads`]).  The default,
///   and the perf-bearing hermetic path.
/// * `Scalar` — the naive row-at-a-time loops, single-threaded.  Kept
///   as the recorded benchmark baseline; bit-identical outputs to
///   `Blocked` by construction.
///
/// The XLA backend ignores this knob (PJRT owns its own kernels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKernel {
    Blocked,
    Scalar,
}

impl GemmKernel {
    pub fn parse(s: &str) -> Result<GemmKernel> {
        match s {
            "blocked" => Ok(GemmKernel::Blocked),
            "scalar" => Ok(GemmKernel::Scalar),
            _ => bail!("unknown kernel {s:?} (blocked|scalar)"),
        }
    }
}

impl std::fmt::Display for GemmKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GemmKernel::Blocked => write!(f, "blocked"),
            GemmKernel::Scalar => write!(f, "scalar"),
        }
    }
}

/// Numeric storage of a tensor family on the reference backend's hot
/// path (DESIGN.md §11).
///
/// * `F32` — dense 4-byte floats (the default; exact).
/// * `Int8` — per-block symmetric INT8 with f32 scales
///   ([`crate::backend::quant`]): ~3.8× fewer resident (and streamed)
///   bytes for weights, ~3.9× for KV — the lever for memory-limited
///   nodes and bandwidth-bound decode.  Greedy decode stays
///   bit-identical across thread counts and world sizes *at a fixed
///   dtype*; changing the dtype changes the logits (quantization
///   error), so recordings must never compare across dtypes silently —
///   which is why the bench schema carries the dtype per row.
///
/// The XLA backend has no quantized artifacts; configs selecting it
/// with a non-f32 dtype are rejected at validation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Dtype {
    /// Dense 4-byte floats.
    #[default]
    F32,
    /// Per-block symmetric INT8 + f32 scales.
    Int8,
}

impl Dtype {
    /// Strict parse of the TOML/CLI spelling; unknown strings are a
    /// clean config error, never a silent fallback.
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "int8" => Ok(Dtype::Int8),
            _ => bail!("unknown dtype {s:?} (f32|int8)"),
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Dtype::F32 => write!(f, "f32"),
            Dtype::Int8 => write!(f, "int8"),
        }
    }
}

/// Admission policy in front of the engine (DESIGN.md §13).
///
/// * `Fcfs` — the classic queue: prefill bursts bounded by the
///   decode-interleave guard, no cross-request KV reuse.  The default,
///   and byte-for-byte the pre-§13 behavior.
/// * `Continuous` — continuous batching: lanes join and leave the
///   decode batch every step, prompts are admitted through the chunk
///   machinery capped at `max_seq` (no bucket truncation), and the KV
///   allocator shares page-aligned prompt prefixes across requests via
///   refcounted copy-on-write attach (DESIGN.md §13).
///
/// Greedy outputs are bit-identical under both policies — scheduling
/// changes *when* a request runs, never *what* it computes — which is
/// what `rust/tests/continuous_batching.rs` pins.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedulerKind {
    /// FCFS bucket admission (the classic path).
    #[default]
    Fcfs,
    /// Continuous per-step admission with shared-prefix KV reuse.
    Continuous,
}

impl SchedulerKind {
    /// Strict parse of the TOML/CLI spelling; unknown strings are a
    /// clean config error, never a silent fallback.
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        match s {
            "fcfs" => Ok(SchedulerKind::Fcfs),
            "continuous" => Ok(SchedulerKind::Continuous),
            _ => bail!("unknown scheduler {s:?} (fcfs|continuous)"),
        }
    }
}

impl std::fmt::Display for SchedulerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedulerKind::Fcfs => write!(f, "fcfs"),
            SchedulerKind::Continuous => write!(f, "continuous"),
        }
    }
}

/// Instruction-set tier for the reference backend's GEMM inner loops
/// (DESIGN.md §14).
///
/// * `Auto` — detect at startup and pick the widest *bit-identical*
///   f32 tier the CPU has (avx512 → avx2 → scalar).  The default:
///   every auto-selectable tier reproduces the scalar chain exactly,
///   so mixed fleets resolving different tiers still bit-agree.
/// * `Scalar` / `Avx2` / `Avx512` — force one tier.  Forcing a tier
///   the CPU lacks is a hard error at backend construction, never a
///   silent fallback.
/// * `Vnni` — the W8A8 integer scheme: activations quantized to u8
///   per weight-quant-group and multiplied against the int8 weights
///   in exact integer arithmetic (`vpdpbusd` on VNNI silicon, a
///   bit-identical integer emulation elsewhere, so the tier runs on
///   any host).  Different numerics from the f32 chain — never
///   auto-selected, and requires `weight_dtype = "int8"`.
///
/// The `XEONSERVE_FORCE_ISA` environment variable overrides this knob
/// per process (CI's ISA axis).  The XLA backend owns its own kernels
/// and only accepts `auto`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IsaKind {
    /// Runtime detection (widest bit-identical f32 tier).
    #[default]
    Auto,
    /// Force the pinned scalar baseline.
    Scalar,
    /// Force 8-lane AVX2 f32 rows.
    Avx2,
    /// Force 16-lane AVX-512F f32 rows.
    Avx512,
    /// Opt in to the W8A8 integer scheme (int8 weights only).
    Vnni,
}

impl IsaKind {
    /// Strict parse of the TOML/CLI spelling; unknown strings are a
    /// clean config error, never a silent fallback.
    pub fn parse(s: &str) -> Result<IsaKind> {
        match s {
            "auto" => Ok(IsaKind::Auto),
            "scalar" => Ok(IsaKind::Scalar),
            "avx2" => Ok(IsaKind::Avx2),
            "avx512" => Ok(IsaKind::Avx512),
            "vnni" => Ok(IsaKind::Vnni),
            _ => bail!(
                "unknown isa {s:?} (auto|scalar|avx2|avx512|vnni)"),
        }
    }
}

impl std::fmt::Display for IsaKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IsaKind::Auto => write!(f, "auto"),
            IsaKind::Scalar => write!(f, "scalar"),
            IsaKind::Avx2 => write!(f, "avx2"),
            IsaKind::Avx512 => write!(f, "avx512"),
            IsaKind::Vnni => write!(f, "vnni"),
        }
    }
}

/// The paper's three optimizations as independent switches, so every
/// bench can ablate them one at a time.
#[derive(Clone, Copy, Debug)]
pub struct OptFlags {
    /// §2.1a: broadcast token IDs (true) vs embedding activations (false)
    pub broadcast_ids: bool,
    /// §2.1b: per-rank local top-k + k-pair reduce (true) vs full-logit
    /// allgather (false)
    pub local_topk: bool,
    /// §2.3: zero-copy arena allreduce (true) vs staged ring (false)
    pub zero_copy: bool,
}

impl Default for OptFlags {
    fn default() -> Self {
        OptFlags { broadcast_ids: true, local_topk: true, zero_copy: true }
    }
}

impl OptFlags {
    /// The naive baseline the paper improves on.
    pub fn naive() -> Self {
        OptFlags { broadcast_ids: false, local_topk: false, zero_copy: false }
    }
}

/// Sampling parameters for generation.
#[derive(Clone, Copy, Debug)]
pub struct SamplingConfig {
    /// softmax temperature; 0 => greedy
    pub temperature: f32,
    /// per-rank top-k candidates (the k of §2.1b)
    pub top_k: usize,
    /// nucleus cutoff applied over the merged candidates; 1.0 => off
    pub top_p: f32,
    pub seed: u64,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig { temperature: 0.0, top_k: 40, top_p: 1.0, seed: 0 }
    }
}

/// Where rank weight shards come from.
#[derive(Clone, Debug)]
pub enum WeightSource {
    /// deterministic random weights (benches, examples)
    Synthetic { seed: u64 },
    /// .npy files exported by aot.py (golden parity tests)
    NpyDir { dir: PathBuf },
}

impl Default for WeightSource {
    fn default() -> Self {
        WeightSource::Synthetic { seed: 0 }
    }
}

/// Top-level engine configuration (TOML-loadable; presets in `configs/`).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// model preset name from the manifest ("tiny" | "small" | "medium")
    pub model: String,
    /// which execution backend runs the model math (DESIGN.md §9)
    pub backend: BackendKind,
    pub variant: Variant,
    /// tensor-parallel world size (ranks ≙ the paper's sockets)
    pub world: usize,
    /// batch lanes (decode batch bucket; must exist in the manifest)
    pub batch: usize,
    pub artifacts_dir: PathBuf,
    pub weights: WeightSource,
    pub opt: OptFlags,
    pub sampling: SamplingConfig,
    pub wire: WireModel,
    /// max new tokens per request unless the request says otherwise
    pub max_new_tokens: usize,
    /// compute threads per rank for the reference backend's blocked
    /// kernels; 0 = auto (available cores / world).  DESIGN.md §10.
    pub threads: usize,
    /// reference-backend GEMM implementation (blocked | scalar)
    pub kernel: GemmKernel,
    /// instruction-set tier for the reference backend's GEMM inner
    /// loops (auto | scalar | avx2 | avx512 | vnni) — DESIGN.md §14
    pub isa: IsaKind,
    /// weight storage on the reference backend (f32 | int8) —
    /// DESIGN.md §11
    pub weight_dtype: Dtype,
    /// KV-cache storage on the reference backend (f32 | int8) —
    /// DESIGN.md §11
    pub kv_dtype: Dtype,
    /// Prefill chunk size in tokens (DESIGN.md §12): 0 = whole-prompt
    /// prefill (one round per admitted request, the classic path);
    /// N > 0 splits each prompt into N-token chunks that interleave
    /// with batched decode steps, bounding how long any single prefill
    /// round can stall in-flight decodes.  Chunking is reference-
    /// backend-only (the AOT prefill segments are whole-frame) and
    /// bit-identical to whole-prompt prefill at any chunk size.
    pub prefill_chunk: usize,
    /// Admission policy (DESIGN.md §13): `fcfs` = classic bounded-burst
    /// queue; `continuous` = per-step admission with shared-prefix KV
    /// reuse.  Continuous batching is reference-backend-only (the AOT
    /// segments have no shared-segment attention reads).
    pub scheduler: SchedulerKind,
    /// Speculative-decoding draft model (DESIGN.md §15): "off" (the
    /// default) disables speculation; any other value names a built-in
    /// preset each rank hosts as a draft `ExecBackend` beside the
    /// target.  The draft proposes `spec_k` greedy tokens per step and
    /// one batched target step verifies them; the greedy-matching
    /// prefix is accepted, so outputs stay bit-identical to
    /// non-speculative decode.  Reference-backend-only, greedy-only,
    /// and the draft must differ from the target model.
    pub spec_draft: String,
    /// Draft tokens proposed per speculative step (1..=8); ignored
    /// while `spec_draft = "off"` — DESIGN.md §15.
    pub spec_k: usize,
    /// Load-shedding admission bound (DESIGN.md §16): refuse new API
    /// requests with `{"error": "shed"}` once this many are already
    /// queued ahead of the engine.  0 (the default) queues unboundedly
    /// — the pre-shed behavior.
    pub shed_queue: usize,
    /// Load-shedding wait SLO in milliseconds (DESIGN.md §16): refuse
    /// new API requests while the queue head has already waited at
    /// least this long.  0 (the default) disables the check.
    pub shed_wait_ms: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "tiny".into(),
            backend: BackendKind::build_default(),
            variant: Variant::Parallel,
            world: 2,
            batch: 2,
            artifacts_dir: PathBuf::from("artifacts"),
            weights: WeightSource::default(),
            opt: OptFlags::default(),
            sampling: SamplingConfig::default(),
            wire: WireModel::default(),
            max_new_tokens: 16,
            threads: 0,
            kernel: GemmKernel::Blocked,
            isa: IsaKind::Auto,
            weight_dtype: Dtype::F32,
            kv_dtype: Dtype::F32,
            prefill_chunk: 0,
            scheduler: SchedulerKind::Fcfs,
            spec_draft: "off".into(),
            spec_k: 4,
            shed_queue: 0,
            shed_wait_ms: 0,
        }
    }
}

impl EngineConfig {
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<EngineConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML; unspecified fields keep their defaults.
    pub fn from_toml_str(text: &str) -> Result<EngineConfig> {
        let j = parse_toml(text)?;
        let mut cfg = EngineConfig::default();

        if let Some(v) = j.get("model").and_then(Json::as_str) {
            cfg.model = v.to_string();
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            cfg.backend = BackendKind::parse(v)?;
        }
        if let Some(v) = j.get("variant").and_then(Json::as_str) {
            cfg.variant = Variant::parse(v)?;
        }
        if let Some(v) = j.get("world").and_then(Json::as_usize) {
            cfg.world = v;
        }
        if let Some(v) = j.get("batch").and_then(Json::as_usize) {
            cfg.batch = v;
        }
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("max_new_tokens").and_then(Json::as_usize) {
            cfg.max_new_tokens = v;
        }
        if let Some(v) = j.get("threads").and_then(Json::as_usize) {
            cfg.threads = v;
        }
        if let Some(v) = j.get("kernel").and_then(Json::as_str) {
            cfg.kernel = GemmKernel::parse(v)?;
        }
        if let Some(v) = j.get("isa") {
            // strict: present-but-invalid must error, never fall back
            let s = v.as_str().with_context(|| {
                format!("isa must be a string \
                         (auto|scalar|avx2|avx512|vnni), got {v:?}")
            })?;
            cfg.isa = IsaKind::parse(s)?;
        }
        if let Some(v) = j.get("weight_dtype").and_then(Json::as_str) {
            cfg.weight_dtype = Dtype::parse(v)?;
        }
        if let Some(v) = j.get("kv_dtype").and_then(Json::as_str) {
            cfg.kv_dtype = Dtype::parse(v)?;
        }
        if let Some(v) = j.get("prefill_chunk") {
            // strict: present-but-invalid must error, never fall back
            let n = v.as_f64().with_context(|| {
                format!("prefill_chunk must be a non-negative integer \
                         (0 = whole-prompt), got {v:?}")
            })?;
            if n.fract() != 0.0 || !(0.0..=1e9).contains(&n) {
                bail!("prefill_chunk must be a non-negative integer \
                       (0 = whole-prompt), got {n}");
            }
            cfg.prefill_chunk = n as usize;
        }
        if let Some(v) = j.get("scheduler") {
            // strict: present-but-invalid must error, never fall back
            let s = v.as_str().with_context(|| {
                format!("scheduler must be a string (fcfs|continuous), \
                         got {v:?}")
            })?;
            cfg.scheduler = SchedulerKind::parse(s)?;
        }
        if let Some(v) = j.get("spec_draft") {
            // strict: present-but-invalid must error, never fall back
            let s = v.as_str().with_context(|| {
                format!("spec_draft must be a string (\"off\" or a \
                         built-in preset name), got {v:?}")
            })?;
            cfg.spec_draft = s.to_string();
        }
        if let Some(v) = j.get("spec_k") {
            // strict: present-but-invalid must error, never fall back
            let n = v.as_f64().with_context(|| {
                format!("spec_k must be an integer in 1..=8, got {v:?}")
            })?;
            if n.fract() != 0.0 || !(1.0..=8.0).contains(&n) {
                bail!("spec_k must be an integer in 1..=8, got {n}");
            }
            cfg.spec_k = n as usize;
        }
        if let Some(v) = j.get("shed_queue") {
            // strict: present-but-invalid must error, never fall back
            let n = v.as_f64().with_context(|| {
                format!("shed_queue must be a non-negative integer \
                         (0 = unbounded), got {v:?}")
            })?;
            if n.fract() != 0.0 || !(0.0..=1e9).contains(&n) {
                bail!("shed_queue must be a non-negative integer \
                       (0 = unbounded), got {n}");
            }
            cfg.shed_queue = n as usize;
        }
        if let Some(v) = j.get("shed_wait_ms") {
            // strict: present-but-invalid must error, never fall back
            let n = v.as_f64().with_context(|| {
                format!("shed_wait_ms must be a non-negative integer \
                         (0 = disabled), got {v:?}")
            })?;
            if n.fract() != 0.0 || !(0.0..=1e9).contains(&n) {
                bail!("shed_wait_ms must be a non-negative integer \
                       (0 = disabled), got {n}");
            }
            cfg.shed_wait_ms = n as u64;
        }
        if let Some(w) = j.get("weights") {
            match w.get("kind").and_then(Json::as_str) {
                Some("synthetic") | None => {
                    cfg.weights = WeightSource::Synthetic {
                        seed: w.get("seed").and_then(Json::as_u64)
                            .unwrap_or(0),
                    }
                }
                Some("npydir") => {
                    cfg.weights = WeightSource::NpyDir {
                        dir: PathBuf::from(
                            w.get("dir")
                                .and_then(Json::as_str)
                                .context("weights.dir required")?,
                        ),
                    }
                }
                Some(k) => bail!("unknown weights.kind {k:?}"),
            }
        }
        if let Some(o) = j.get("opt") {
            if let Some(v) = o.get("broadcast_ids").and_then(Json::as_bool) {
                cfg.opt.broadcast_ids = v;
            }
            if let Some(v) = o.get("local_topk").and_then(Json::as_bool) {
                cfg.opt.local_topk = v;
            }
            if let Some(v) = o.get("zero_copy").and_then(Json::as_bool) {
                cfg.opt.zero_copy = v;
            }
        }
        if let Some(s) = j.get("sampling") {
            if let Some(v) = s.get("temperature").and_then(Json::as_f64) {
                cfg.sampling.temperature = v as f32;
            }
            if let Some(v) = s.get("top_k").and_then(Json::as_usize) {
                cfg.sampling.top_k = v;
            }
            if let Some(v) = s.get("top_p").and_then(Json::as_f64) {
                cfg.sampling.top_p = v as f32;
            }
            if let Some(v) = s.get("seed").and_then(Json::as_u64) {
                cfg.sampling.seed = v;
            }
        }
        if let Some(w) = j.get("wire") {
            if let Some(v) = w.get("alpha_us").and_then(Json::as_f64) {
                cfg.wire.alpha_us = v;
            }
            if let Some(v) = w.get("beta_gbps").and_then(Json::as_f64) {
                cfg.wire.beta_gbps = v;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to the same TOML dialect [`Self::from_toml_str`]
    /// parses.  This is how the launch coordinator ships the engine
    /// config to `xeonserve worker` processes (DESIGN.md §8): one
    /// source of truth on the coordinator, byte-identical settings on
    /// every rank.
    pub fn to_toml_string(&self) -> String {
        // names/paths must survive the trip through the TOML parser
        fn esc(s: impl std::fmt::Display) -> String {
            crate::util::toml_mini::escape(&s.to_string())
        }
        let mut s = String::new();
        use std::fmt::Write;
        let _ = writeln!(s, "model = \"{}\"", esc(&self.model));
        let _ = writeln!(s, "backend = \"{}\"", self.backend);
        let _ = writeln!(s, "variant = \"{}\"", self.variant);
        let _ = writeln!(s, "world = {}", self.world);
        let _ = writeln!(s, "batch = {}", self.batch);
        let _ = writeln!(s, "artifacts_dir = \"{}\"",
                         esc(self.artifacts_dir.display()));
        let _ = writeln!(s, "max_new_tokens = {}", self.max_new_tokens);
        let _ = writeln!(s, "threads = {}", self.threads);
        let _ = writeln!(s, "kernel = \"{}\"", self.kernel);
        let _ = writeln!(s, "isa = \"{}\"", self.isa);
        let _ = writeln!(s, "weight_dtype = \"{}\"", self.weight_dtype);
        let _ = writeln!(s, "kv_dtype = \"{}\"", self.kv_dtype);
        let _ = writeln!(s, "prefill_chunk = {}", self.prefill_chunk);
        let _ = writeln!(s, "scheduler = \"{}\"", self.scheduler);
        let _ = writeln!(s, "spec_draft = \"{}\"", esc(&self.spec_draft));
        let _ = writeln!(s, "spec_k = {}", self.spec_k);
        let _ = writeln!(s, "shed_queue = {}", self.shed_queue);
        let _ = writeln!(s, "shed_wait_ms = {}", self.shed_wait_ms);
        match &self.weights {
            WeightSource::Synthetic { seed } => {
                let _ = writeln!(
                    s, "[weights]\nkind = \"synthetic\"\nseed = {seed}");
            }
            WeightSource::NpyDir { dir } => {
                let _ = writeln!(
                    s, "[weights]\nkind = \"npydir\"\ndir = \"{}\"",
                    esc(dir.display()));
            }
        }
        let _ = writeln!(s, "[opt]");
        let _ = writeln!(s, "broadcast_ids = {}", self.opt.broadcast_ids);
        let _ = writeln!(s, "local_topk = {}", self.opt.local_topk);
        let _ = writeln!(s, "zero_copy = {}", self.opt.zero_copy);
        let _ = writeln!(s, "[sampling]");
        let _ = writeln!(s, "temperature = {}", self.sampling.temperature);
        let _ = writeln!(s, "top_k = {}", self.sampling.top_k);
        let _ = writeln!(s, "top_p = {}", self.sampling.top_p);
        let _ = writeln!(s, "seed = {}", self.sampling.seed);
        let _ = writeln!(s, "[wire]");
        let _ = writeln!(s, "alpha_us = {}", self.wire.alpha_us);
        let _ = writeln!(s, "beta_gbps = {}", self.wire.beta_gbps);
        s
    }

    pub fn validate(&self) -> Result<()> {
        if self.backend == BackendKind::Xla && !cfg!(feature = "xla") {
            bail!(
                "backend \"xla\" requires building with `--features xla` \
                 (this binary only has the pure-Rust reference backend)"
            );
        }
        if self.world == 0 || !self.world.is_power_of_two() {
            bail!("world must be a power of two, got {}", self.world);
        }
        if self.batch == 0 {
            bail!("batch must be >= 1");
        }
        if self.sampling.top_k == 0 {
            bail!("sampling.top_k must be >= 1");
        }
        // the pool clamps to 64 (backend::pool::auto_threads); reject
        // anything above instead of silently degrading it
        if self.threads > 64 {
            bail!("threads must be <= 64 (0 = auto), got {}",
                  self.threads);
        }
        if !(0.0..=1.0).contains(&self.sampling.top_p) {
            bail!("sampling.top_p must be in [0,1]");
        }
        // quantized storage is a reference-backend feature: the XLA
        // artifacts are lowered at f32, so accepting int8 there would
        // silently serve a different numeric contract than configured
        if self.backend == BackendKind::Xla
            && (self.weight_dtype != Dtype::F32
                || self.kv_dtype != Dtype::F32)
        {
            bail!(
                "backend \"xla\" only supports f32 dtypes (got \
                 weight_dtype={}, kv_dtype={}); int8 is a reference-\
                 backend feature (DESIGN.md §11)",
                self.weight_dtype, self.kv_dtype
            );
        }
        // the AOT prefill segments are lowered for whole-prompt frames
        // at offset 0 — chunk rounds have no segment to run on
        if self.backend == BackendKind::Xla && self.prefill_chunk != 0 {
            bail!(
                "backend \"xla\" does not support chunked prefill (got \
                 prefill_chunk={}); chunking is a reference-backend \
                 feature (DESIGN.md §12)",
                self.prefill_chunk
            );
        }
        // the ISA knob steers the reference backend's in-tree GEMM
        // loops; PJRT owns its own kernels, so forcing a tier there
        // would silently do nothing
        if self.backend == BackendKind::Xla && self.isa != IsaKind::Auto
        {
            bail!(
                "backend \"xla\" only supports isa = \"auto\" (got \
                 isa={}); the ISA tiers steer the reference backend's \
                 kernels (DESIGN.md §14)",
                self.isa
            );
        }
        // vnni computes weight matmuls in int8 — it has nothing to run
        // on when the weights are stored dense f32
        if self.isa == IsaKind::Vnni && self.weight_dtype != Dtype::Int8
        {
            bail!(
                "isa = \"vnni\" requires weight_dtype = \"int8\" (got \
                 weight_dtype={}); the W8A8 scheme computes int8 \
                 weight matmuls in integer arithmetic (DESIGN.md §14)",
                self.weight_dtype
            );
        }
        // shared-prefix attach reads KV across segment + lane storage;
        // the AOT attention segments only address the dense lane planes
        if self.backend == BackendKind::Xla
            && self.scheduler != SchedulerKind::Fcfs
        {
            bail!(
                "backend \"xla\" only supports the fcfs scheduler (got \
                 scheduler={}); continuous batching is a reference-\
                 backend feature (DESIGN.md §13)",
                self.scheduler
            );
        }
        if !(1..=8).contains(&self.spec_k) {
            bail!("spec_k must be in 1..=8, got {}", self.spec_k);
        }
        if self.spec_enabled() {
            // the draft backend is a second in-tree reference
            // transformer; the AOT segments have no draft counterpart
            // and no multi-position verify rows
            if self.backend == BackendKind::Xla {
                bail!(
                    "backend \"xla\" does not support speculative \
                     decoding (got spec_draft={:?}); it is a reference-\
                     backend feature (DESIGN.md §15)",
                    self.spec_draft
                );
            }
            // drafting with the target itself doubles every step's
            // cost for zero saved steps — always a config mistake
            if self.spec_draft == self.model {
                bail!(
                    "spec_draft must differ from the target model \
                     (both are {:?}); drafting with the target itself \
                     cannot save steps (DESIGN.md §15)",
                    self.model
                );
            }
            // greedy-prefix acceptance is only equivalent to plain
            // decode when the target samples its argmax; stochastic
            // sampling would need the rejection-resampling scheme
            if self.sampling.temperature > 0.0 {
                bail!(
                    "speculative decoding requires greedy sampling \
                     (got sampling.temperature = {}); greedy-prefix \
                     acceptance is only exact at temperature 0 \
                     (DESIGN.md §15)",
                    self.sampling.temperature
                );
            }
        }
        Ok(())
    }

    /// Is speculative decoding switched on (DESIGN.md §15)?
    pub fn spec_enabled(&self) -> bool {
        self.spec_draft != "off"
    }

    /// Resolve the draft model `spec_draft` names, checking it is
    /// compatible with the (already resolved) target: the draft must
    /// shard over the same world, and its vocab must not exceed the
    /// target's (every proposed id must be a valid target token).
    /// The draft preset's `max_seq` is widened to the target's so the
    /// draft KV can mirror the target KV row-for-row.
    pub fn resolve_draft_model(&self, target: &ModelPreset)
                               -> Result<ModelPreset> {
        if !self.spec_enabled() {
            bail!("spec_draft is \"off\" — no draft model to resolve");
        }
        let mut draft = ModelPreset::builtin(&self.spec_draft)
            .with_context(|| {
                format!("resolving spec_draft {:?}", self.spec_draft)
            })?;
        if !draft.supports_world(self.world) {
            bail!(
                "draft model {} does not shard over world={} \
                 (heads/ffn/vocab must divide evenly)",
                self.spec_draft, self.world
            );
        }
        // prompt tokens are folded into the draft vocab by `tok %
        // draft_vocab`, but draft *proposals* feed the target verbatim
        // — they must all be valid target ids
        if draft.vocab > target.vocab {
            bail!(
                "draft model {} (vocab {}) cannot draft for target {} \
                 (vocab {}): draft proposals must be valid target ids",
                self.spec_draft, draft.vocab, target.name, target.vocab
            );
        }
        draft.max_seq = target.max_seq;
        Ok(draft)
    }

    /// Load the manifest this config points at.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.artifacts_dir)
    }

    /// Resolve the model architecture this config names, from wherever
    /// the selected backend sources it: the artifact manifest for the
    /// XLA backend, the built-in preset table for the reference backend
    /// (which must run without any artifacts on disk).
    pub fn resolve_model(&self) -> Result<ResolvedModel> {
        let (preset, prefill_buckets, manifest) = match self.backend {
            BackendKind::Reference => {
                let preset = ModelPreset::builtin(&self.model)?;
                let buckets = preset.builtin_prefill_buckets();
                (preset, buckets, None)
            }
            BackendKind::Xla => {
                let manifest = self.manifest()?;
                let preset = manifest.preset(&self.model)?.clone();
                let buckets = manifest.prefill_buckets(
                    &self.model, self.world, self.batch);
                (preset, buckets, Some(manifest))
            }
        };
        if prefill_buckets.is_empty() {
            bail!(
                "no prefill segments for model={} world={} batch={}",
                self.model, self.world, self.batch
            );
        }
        if !preset.supports_world(self.world) {
            bail!(
                "model {} does not shard over world={} (heads/ffn/vocab \
                 must divide evenly)",
                self.model, self.world
            );
        }
        Ok(ResolvedModel { preset, prefill_buckets, manifest })
    }
}

/// A model architecture bound to a config: the preset plus the prefill
/// bucket ladder both the engine (admission) and the backends (segment
/// selection) agree on.  For the XLA backend the loaded manifest rides
/// along so backend construction does not parse it a second time.
#[derive(Debug)]
pub struct ResolvedModel {
    pub preset: ModelPreset,
    pub prefill_buckets: Vec<usize>,
    /// populated iff `backend == Xla`
    pub manifest: Option<Manifest>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        EngineConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_full_parse() {
        let text = r#"
model = "small"
variant = "serial"
world = 4
batch = 1
max_new_tokens = 32
[weights]
kind = "synthetic"
seed = 7
[opt]
zero_copy = false
local_topk = false
[sampling]
temperature = 0.8
top_k = 50
seed = 3
[wire]
alpha_us = 2.0
beta_gbps = 10.0
"#;
        let cfg = EngineConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.model, "small");
        assert_eq!(cfg.variant, Variant::Serial);
        assert_eq!(cfg.world, 4);
        assert!(!cfg.opt.zero_copy);
        assert!(!cfg.opt.local_topk);
        assert!(cfg.opt.broadcast_ids); // untouched default
        assert_eq!(cfg.sampling.top_k, 50);
        assert!((cfg.sampling.temperature - 0.8).abs() < 1e-6);
        assert!((cfg.wire.beta_gbps - 10.0).abs() < 1e-9);
        match cfg.weights {
            WeightSource::Synthetic { seed } => assert_eq!(seed, 7),
            _ => panic!("wrong weight source"),
        }
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let cfg =
            EngineConfig::from_toml_str("model = \"small\"\nworld = 4")
                .unwrap();
        assert_eq!(cfg.model, "small");
        assert_eq!(cfg.world, 4);
        assert!(cfg.opt.zero_copy);
        assert_eq!(cfg.batch, 2);
    }

    #[test]
    fn npydir_weights() {
        let cfg = EngineConfig::from_toml_str(
            "[weights]\nkind = \"npydir\"\ndir = \"/tmp/golden\"")
            .unwrap();
        match cfg.weights {
            WeightSource::NpyDir { dir } => {
                assert_eq!(dir, PathBuf::from("/tmp/golden"))
            }
            _ => panic!("wrong source"),
        }
    }

    #[test]
    fn toml_roundtrip() {
        // the launch coordinator ships configs as TOML; every field must
        // survive serialize → parse
        let mut cfg = EngineConfig {
            model: "small".into(),
            // pin the backend: int8 dtypes + the xla build default
            // would (correctly) fail validation on --features xla
            backend: BackendKind::Reference,
            variant: Variant::Serial,
            world: 4,
            batch: 1,
            // quotes and backslashes must survive the escaping layer
            artifacts_dir: PathBuf::from("some\\odd \"artifacts\" dir"),
            max_new_tokens: 9,
            threads: 3,
            kernel: GemmKernel::Scalar,
            isa: IsaKind::Vnni,
            weight_dtype: Dtype::Int8,
            kv_dtype: Dtype::Int8,
            prefill_chunk: 16,
            scheduler: SchedulerKind::Continuous,
            spec_draft: "nano".into(),
            spec_k: 2,
            shed_queue: 7,
            shed_wait_ms: 250,
            ..Default::default()
        };
        cfg.opt.zero_copy = false;
        cfg.sampling.temperature = 0.75;
        cfg.sampling.top_k = 13;
        cfg.sampling.seed = 42;
        cfg.wire.alpha_us = 2.5;
        cfg.weights = WeightSource::NpyDir { dir: PathBuf::from("/g/w") };

        let back =
            EngineConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.backend, cfg.backend);
        assert_eq!(back.variant, cfg.variant);
        assert_eq!(back.world, cfg.world);
        assert_eq!(back.batch, cfg.batch);
        assert_eq!(back.artifacts_dir, cfg.artifacts_dir);
        assert_eq!(back.max_new_tokens, cfg.max_new_tokens);
        assert_eq!(back.threads, 3);
        assert_eq!(back.kernel, GemmKernel::Scalar);
        assert_eq!(back.isa, IsaKind::Vnni);
        assert_eq!(back.weight_dtype, Dtype::Int8);
        assert_eq!(back.kv_dtype, Dtype::Int8);
        assert_eq!(back.prefill_chunk, 16);
        assert_eq!(back.scheduler, SchedulerKind::Continuous);
        assert_eq!(back.spec_draft, "nano");
        assert_eq!(back.spec_k, 2);
        assert_eq!(back.shed_queue, 7);
        assert_eq!(back.shed_wait_ms, 250);
        assert!(!back.opt.zero_copy);
        assert_eq!(back.opt.broadcast_ids, cfg.opt.broadcast_ids);
        assert_eq!(back.sampling.top_k, 13);
        assert_eq!(back.sampling.seed, 42);
        assert!((back.sampling.temperature - 0.75).abs() < 1e-6);
        assert!((back.wire.alpha_us - 2.5).abs() < 1e-9);
        match back.weights {
            WeightSource::NpyDir { dir } => {
                assert_eq!(dir, PathBuf::from("/g/w"))
            }
            _ => panic!("wrong weight source"),
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(EngineConfig::from_toml_str("world = 3").is_err());
        assert!(EngineConfig::from_toml_str("batch = 0").is_err());
        assert!(EngineConfig::from_toml_str("variant = \"weird\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "[sampling]\ntop_p = 1.5").is_err());
        assert!(EngineConfig::from_toml_str("threads = 10000").is_err());
        assert!(EngineConfig::from_toml_str("kernel = \"simd\"").is_err());
        // isa is strict-parsed: unknown tiers, wrong case, and
        // non-strings are clean config errors, never an auto fallback
        assert!(EngineConfig::from_toml_str("isa = \"sse\"").is_err());
        assert!(EngineConfig::from_toml_str("isa = \"AVX2\"").is_err());
        assert!(EngineConfig::from_toml_str("isa = 512").is_err());
        // vnni without int8 weights has nothing to compute in int8
        assert!(EngineConfig::from_toml_str("isa = \"vnni\"").is_err());
        // unknown dtype strings are clean errors, never a fallback
        assert!(EngineConfig::from_toml_str(
            "weight_dtype = \"int4\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "kv_dtype = \"fp16\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "weight_dtype = \"INT8\"").is_err());
        // prefill_chunk is strict-parsed: non-integers are clean
        // config errors, never a silent fallback or truncation
        assert!(EngineConfig::from_toml_str(
            "prefill_chunk = \"whole\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "prefill_chunk = 4.5").is_err());
        assert!(EngineConfig::from_toml_str(
            "prefill_chunk = -1").is_err());
        // scheduler is strict-parsed: unknown names and non-strings
        // are clean config errors, never a silent fcfs fallback
        assert!(EngineConfig::from_toml_str(
            "scheduler = \"weird\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "scheduler = \"FCFS\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "scheduler = 3").is_err());
        // spec knobs are strict-parsed: non-strings / non-integers /
        // out-of-range k are clean config errors, never a fallback
        assert!(EngineConfig::from_toml_str("spec_draft = 3").is_err());
        assert!(EngineConfig::from_toml_str("spec_k = 0").is_err());
        assert!(EngineConfig::from_toml_str("spec_k = 9").is_err());
        assert!(EngineConfig::from_toml_str("spec_k = 2.5").is_err());
        assert!(EngineConfig::from_toml_str("spec_k = \"four\"").is_err());
        // shed knobs are strict-parsed: non-integers and negatives are
        // clean config errors, never a silent never-shed fallback
        assert!(EngineConfig::from_toml_str(
            "shed_queue = -1").is_err());
        assert!(EngineConfig::from_toml_str(
            "shed_queue = 2.5").is_err());
        assert!(EngineConfig::from_toml_str(
            "shed_queue = \"none\"").is_err());
        assert!(EngineConfig::from_toml_str(
            "shed_wait_ms = -5").is_err());
        assert!(EngineConfig::from_toml_str(
            "shed_wait_ms = 0.5").is_err());
        assert!(EngineConfig::from_toml_str(
            "shed_wait_ms = \"1s\"").is_err());
        // drafting with the target itself is rejected
        assert!(EngineConfig::from_toml_str(
            "spec_draft = \"tiny\"").is_err());
        // speculation is greedy-only (DESIGN.md §15)
        assert!(EngineConfig::from_toml_str(
            "spec_draft = \"nano\"\n[sampling]\ntemperature = 0.5")
            .is_err());
    }

    #[test]
    fn spec_parse_and_defaults() {
        let d = EngineConfig::default();
        assert_eq!(d.spec_draft, "off");
        assert_eq!(d.spec_k, 4);
        assert!(!d.spec_enabled());
        let c = EngineConfig::from_toml_str(
            "spec_draft = \"nano\"\nspec_k = 2").unwrap();
        assert_eq!(c.spec_draft, "nano");
        assert_eq!(c.spec_k, 2);
        assert!(c.spec_enabled());
        // spec_k alone (speculation off) still parses and validates
        let k = EngineConfig::from_toml_str("spec_k = 8").unwrap();
        assert_eq!(k.spec_k, 8);
        assert!(!k.spec_enabled());
    }

    #[test]
    fn xla_backend_rejects_speculation() {
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            spec_draft: "nano".into(),
            ..Default::default()
        };
        // invalid regardless of whether the xla feature is compiled in
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn draft_model_resolution() {
        // nano drafts for tiny at every matrix world
        let cfg = EngineConfig {
            backend: BackendKind::Reference,
            spec_draft: "nano".into(),
            ..Default::default()
        };
        let target = cfg.resolve_model().unwrap().preset;
        for world in [1usize, 2, 4] {
            let mut c = cfg.clone();
            c.world = world;
            let draft = c.resolve_draft_model(&target).unwrap();
            assert_eq!(draft.name, "nano");
            assert!(draft.supports_world(world));
            // the draft KV must mirror the target's row range
            assert_eq!(draft.max_seq, target.max_seq);
            assert!(draft.vocab <= target.vocab);
        }
        // tiny (vocab 256 ≤ 32000, max_seq widened 64 → 1024) drafts
        // for small
        let cfg = EngineConfig {
            backend: BackendKind::Reference,
            model: "small".into(),
            spec_draft: "tiny".into(),
            ..Default::default()
        };
        let target = cfg.resolve_model().unwrap().preset;
        let draft = cfg.resolve_draft_model(&target).unwrap();
        assert_eq!(draft.max_seq, 1024);
        assert_eq!(draft.vocab, 256);
        // a draft with a *larger* vocab than its target is rejected:
        // its proposals would not all be valid target ids
        let back = EngineConfig {
            backend: BackendKind::Reference,
            model: "nano".into(),
            spec_draft: "small".into(),
            ..Default::default()
        };
        let nano = back.resolve_model().unwrap().preset;
        assert!(back.resolve_draft_model(&nano).is_err());
        // unknown draft preset and spec-off are clean errors
        let unk = EngineConfig {
            spec_draft: "huge".into(),
            ..Default::default()
        };
        assert!(unk.resolve_draft_model(&target).is_err());
        let off = EngineConfig::default();
        assert!(off.resolve_draft_model(&target).is_err());
    }

    #[test]
    fn toml_roundtrip_fuzz_seeded() {
        // every emitted config must survive serialize → parse exactly
        // (the launch coordinator round-trips configs through TOML on
        // every deployment) — walk a seeded grid of randomized configs
        // instead of one hand-picked sample
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            // splitmix64
            state = state.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        for _ in 0..200 {
            let mut cfg = EngineConfig {
                backend: BackendKind::Reference,
                ..Default::default()
            };
            cfg.model = ["tiny", "small", "medium"][next() as usize % 3]
                .to_string();
            cfg.world = 1 << (next() % 3);
            cfg.batch = 1 + (next() as usize % 8);
            cfg.max_new_tokens = 1 + (next() as usize % 64);
            cfg.threads = next() as usize % 9;
            cfg.kernel = if next() % 2 == 0 {
                GemmKernel::Blocked
            } else {
                GemmKernel::Scalar
            };
            cfg.weight_dtype =
                if next() % 2 == 0 { Dtype::F32 } else { Dtype::Int8 };
            cfg.kv_dtype =
                if next() % 2 == 0 { Dtype::F32 } else { Dtype::Int8 };
            cfg.isa = [IsaKind::Auto, IsaKind::Scalar, IsaKind::Avx2,
                       IsaKind::Avx512][next() as usize % 4];
            cfg.prefill_chunk = [0, 3, 16, 64][next() as usize % 4];
            cfg.scheduler = if next() % 2 == 0 {
                SchedulerKind::Fcfs
            } else {
                SchedulerKind::Continuous
            };
            cfg.spec_k = 1 + (next() as usize % 8);
            cfg.spec_draft = match next() % 3 {
                0 => "off".to_string(),
                1 => "nano".to_string(),
                // names with TOML-hostile bytes must survive escaping
                _ => "dr\\af\"t".to_string(),
            };
            if cfg.spec_draft == cfg.model {
                cfg.spec_draft = "off".into();
            }
            cfg.shed_queue = [0, 1, 8, 4096][next() as usize % 4];
            cfg.shed_wait_ms = [0, 5, 250, 60_000][next() as usize % 4];
            cfg.sampling.top_k = 1 + (next() as usize % 64);
            cfg.sampling.seed = next();
            cfg.opt.zero_copy = next() % 2 == 0;
            cfg.opt.local_topk = next() % 2 == 0;
            cfg.opt.broadcast_ids = next() % 2 == 0;
            cfg.validate().unwrap();

            let text = cfg.to_toml_string();
            let back = EngineConfig::from_toml_str(&text)
                .unwrap_or_else(|e| {
                    panic!("roundtrip parse failed: {e:#}\n---\n{text}")
                });
            assert_eq!(back.model, cfg.model, "{text}");
            assert_eq!(back.world, cfg.world);
            assert_eq!(back.batch, cfg.batch);
            assert_eq!(back.max_new_tokens, cfg.max_new_tokens);
            assert_eq!(back.threads, cfg.threads);
            assert_eq!(back.kernel, cfg.kernel);
            assert_eq!(back.weight_dtype, cfg.weight_dtype);
            assert_eq!(back.kv_dtype, cfg.kv_dtype);
            assert_eq!(back.isa, cfg.isa);
            assert_eq!(back.prefill_chunk, cfg.prefill_chunk);
            assert_eq!(back.scheduler, cfg.scheduler);
            assert_eq!(back.spec_draft, cfg.spec_draft, "{text}");
            assert_eq!(back.spec_k, cfg.spec_k);
            assert_eq!(back.shed_queue, cfg.shed_queue);
            assert_eq!(back.shed_wait_ms, cfg.shed_wait_ms);
            assert_eq!(back.sampling.top_k, cfg.sampling.top_k);
            assert_eq!(back.sampling.seed, cfg.sampling.seed);
            assert_eq!(back.opt.zero_copy, cfg.opt.zero_copy);
            assert_eq!(back.opt.local_topk, cfg.opt.local_topk);
            assert_eq!(back.opt.broadcast_ids, cfg.opt.broadcast_ids);
        }
    }

    #[test]
    fn scheduler_parse_and_defaults() {
        assert_eq!(EngineConfig::default().scheduler, SchedulerKind::Fcfs);
        let c = EngineConfig::from_toml_str("scheduler = \"continuous\"")
            .unwrap();
        assert_eq!(c.scheduler, SchedulerKind::Continuous);
        let f = EngineConfig::from_toml_str("scheduler = \"fcfs\"").unwrap();
        assert_eq!(f.scheduler, SchedulerKind::Fcfs);
        assert_eq!(SchedulerKind::Fcfs.to_string(), "fcfs");
        assert_eq!(SchedulerKind::Continuous.to_string(), "continuous");
    }

    #[test]
    fn isa_parse_and_defaults() {
        assert_eq!(EngineConfig::default().isa, IsaKind::Auto);
        for (text, want) in [
            ("isa = \"auto\"", IsaKind::Auto),
            ("isa = \"scalar\"", IsaKind::Scalar),
            ("isa = \"avx2\"", IsaKind::Avx2),
            ("isa = \"avx512\"", IsaKind::Avx512),
        ] {
            let c = EngineConfig::from_toml_str(text).unwrap();
            assert_eq!(c.isa, want);
        }
        // vnni parses, but only together with int8 weights
        let v = EngineConfig::from_toml_str(
            "isa = \"vnni\"\nweight_dtype = \"int8\"")
            .unwrap();
        assert_eq!(v.isa, IsaKind::Vnni);
        for k in [IsaKind::Auto, IsaKind::Scalar, IsaKind::Avx2,
                  IsaKind::Avx512, IsaKind::Vnni]
        {
            assert_eq!(IsaKind::parse(&k.to_string()).unwrap(), k);
        }
    }

    #[test]
    fn vnni_isa_requires_int8_weights() {
        let cfg = EngineConfig {
            isa: IsaKind::Vnni,
            weight_dtype: Dtype::Int8,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let bad = EngineConfig {
            isa: IsaKind::Vnni,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn xla_backend_rejects_forced_isa() {
        // forcing a reference-backend kernel tier on the PJRT backend
        // would silently do nothing — reject it at validation
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            isa: IsaKind::Scalar,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn xla_backend_rejects_continuous_scheduler() {
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            scheduler: SchedulerKind::Continuous,
            ..Default::default()
        };
        // invalid regardless of whether the xla feature is compiled in
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn prefill_chunk_parse_and_defaults() {
        assert_eq!(EngineConfig::default().prefill_chunk, 0);
        let c = EngineConfig::from_toml_str("prefill_chunk = 16").unwrap();
        assert_eq!(c.prefill_chunk, 16);
        let whole = EngineConfig::from_toml_str("prefill_chunk = 0")
            .unwrap();
        assert_eq!(whole.prefill_chunk, 0);
    }

    #[test]
    fn xla_backend_rejects_chunked_prefill() {
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            prefill_chunk: 16,
            ..Default::default()
        };
        // invalid regardless of whether the xla feature is compiled in
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn dtype_parse_and_defaults() {
        let d = EngineConfig::default();
        assert_eq!(d.weight_dtype, Dtype::F32);
        assert_eq!(d.kv_dtype, Dtype::F32);
        let cfg = EngineConfig::from_toml_str(
            "weight_dtype = \"int8\"\nkv_dtype = \"int8\"")
            .unwrap();
        assert_eq!(cfg.weight_dtype, Dtype::Int8);
        assert_eq!(cfg.kv_dtype, Dtype::Int8);
        // mixed dtypes are allowed (weights int8, KV f32 and vice versa)
        let m = EngineConfig::from_toml_str("kv_dtype = \"int8\"").unwrap();
        assert_eq!(m.weight_dtype, Dtype::F32);
        assert_eq!(m.kv_dtype, Dtype::Int8);
        assert_eq!(Dtype::F32.to_string(), "f32");
        assert_eq!(Dtype::Int8.to_string(), "int8");
    }

    #[test]
    fn xla_backend_rejects_int8_dtypes() {
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            weight_dtype: Dtype::Int8,
            ..Default::default()
        };
        // invalid regardless of whether the xla feature is compiled in
        assert!(cfg.validate().is_err());
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            kv_dtype: Dtype::Int8,
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn threads_and_kernel_parse() {
        let cfg = EngineConfig::from_toml_str(
            "threads = 4\nkernel = \"scalar\"").unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.kernel, GemmKernel::Scalar);
        // defaults: auto threads, blocked kernel
        let d = EngineConfig::default();
        assert_eq!(d.threads, 0);
        assert_eq!(d.kernel, GemmKernel::Blocked);
    }

    #[test]
    fn backend_toml_parse_and_feature_gate() {
        let r = EngineConfig::from_toml_str("backend = \"reference\"")
            .unwrap();
        assert_eq!(r.backend, BackendKind::Reference);
        let x = EngineConfig::from_toml_str("backend = \"xla\"");
        if cfg!(feature = "xla") {
            assert_eq!(x.unwrap().backend, BackendKind::Xla);
        } else {
            // hermetic build: asking for the XLA backend is a clean
            // config error, not a runtime surprise
            assert!(x.is_err());
        }
        assert!(EngineConfig::from_toml_str("backend = \"weird\"").is_err());
    }

    #[test]
    fn reference_backend_resolves_without_artifacts() {
        let cfg = EngineConfig {
            backend: BackendKind::Reference,
            artifacts_dir: PathBuf::from("/definitely/not/here"),
            ..Default::default()
        };
        let rm = cfg.resolve_model().unwrap();
        assert_eq!(rm.preset.name, "tiny");
        assert_eq!(rm.prefill_buckets, vec![16]);
        assert!(rm.preset.params > 0);

        // world that does not divide the head/ffn/vocab dims
        let bad = EngineConfig {
            backend: BackendKind::Reference,
            world: 16,
            ..Default::default()
        };
        assert!(bad.resolve_model().is_err());

        let unknown = EngineConfig {
            backend: BackendKind::Reference,
            model: "nonexistent".into(),
            ..Default::default()
        };
        assert!(unknown.resolve_model().is_err());
    }

    #[test]
    fn opt_flags_naive_all_off() {
        let n = OptFlags::naive();
        assert!(!n.broadcast_ids && !n.local_topk && !n.zero_copy);
    }

    #[test]
    fn variant_sync_counts() {
        assert_eq!(Variant::Parallel.syncs_per_layer(), 1);
        assert_eq!(Variant::Serial.syncs_per_layer(), 2);
    }
}
